"""CI perf trajectory gate.

Runs every registered ``--smoke`` benchmark (the ``benchmarks/run.py``
registry), collects their headline metrics into a machine-readable
``BENCH_<n>.json`` and compares against the last committed
``BENCH_*.json`` at the repo root with per-metric tolerance bands, so a
PR that silently regresses offline throughput, SLO attainment, the
DRAM-tier hit ratio or collective stalls fails CI instead of landing.

Usage::

  perf_gate.py                  # run smokes, compare vs latest BENCH_*,
                                # write --out (CI artifact); exit 1 on
                                # regression
  perf_gate.py --collect PATH   # run smokes, write PATH, no gating
                                # (how BENCH_<n>.json is regenerated
                                # after an intentional perf change)
  perf_gate.py --compare A B    # gate B against baseline A, no runs
  perf_gate.py --from-json F    # gate an already-collected metrics file
                                # (benchmarks/run.py --smoke-all --json F)
                                # against the latest BENCH_*, no runs
  perf_gate.py --self-test      # verify the comparator catches an
                                # injected >5% regression (no runs)

Per-metric direction: +1 = higher is better (tok/s, SLO attainment, hit
ratios, gains), -1 = lower is better (stalls, JCT, drain latency), 0 =
informational (recorded, never gated).  A metric regresses when it
moves against its direction by more than ``rel_tol`` (default 5%)
relative to the baseline, with a small absolute floor so near-zero
baselines (e.g. a 0.000 s collective stall) don't turn noise into
failures.  Metrics present in the baseline but missing from the current
run always fail — losing a headline metric is itself a regression.
"""
from __future__ import annotations

import argparse
import glob
import json
import math
import os
import re
import sys

if __package__ in (None, ""):       # direct `python benchmarks/<file>.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SCHEMA = 1
REL_TOL = 0.05

#: gating direction per headline metric (see module docstring)
DIRECTIONS = {
    "fig_tiered": {"dram_hit_ratio": +1, "snic_hit_saved_gb": +1,
                   "jct_max_s": -1},
    "fig_online_serving": {"offline_tok_s": +1, "slo_attainment": +1,
                           "overlap_gain": +1},
    # online SLO layer: attainments are deterministic sim outputs
    # (tight bands); the >=3x-over-0.17 hard acceptance is asserted in
    # the fig_slo smoke itself, the gate tracks the trajectory
    "fig_slo": {"slo_attainment": +1, "slo_attainment_baseline": 0,
                "slo_attainment_admission": +1,
                "slo_attainment_chunked": +1,
                "slo_attainment_classes": +1,
                "slo_attainment_all": +1,
                "slo_gain": +1,
                "slo_interactive_ttft_p99_s": -1,
                "slo_rejected_rounds": 0},
    "fig_interference": {"vl_collective_stall_s": -1,
                         "vl_slo_at_top_load": +1,
                         "fifo_slo_at_top_load": 0},
    "fig_elastic": {"elastic_tput_tok_s": +1,
                    "static_best_tput_tok_s": 0,
                    "elastic_gain": +1, "role_changes": 0,
                    "reconfig_drain_s": -1},
    "fig_resilience": {"slo_faulted_hedged_elastic": +1,
                       "slo_faulted_nohedge_static": 0,
                       "resilience_slo_gain": +1,
                       "slo_straggle_hedged": +1,
                       "straggle_ttft_p99_hedged_s": -1,
                       "straggle_ttft_p99_nohedge_s": 0,
                       "sim_hedged_reads": 0,
                       "sim_recovered_rounds": 0},
    "fig_bottleneck": {"storage_frac_storage_bound": +1,
                       "compute_frac_compute_bound": +1,
                       "storage_bound_ttft_mean_s": 0,
                       "max_decomp_err_s": -1,
                       "attr_ttft_rel_err": -1,
                       "trace_spans": 0},
    # fleet engine: simulated SLO/throughput are deterministic (tight
    # default bands); wall-clock-derived speedups get wide ABS_FLOOR
    # slack below — the hard >=50x acceptance is asserted inside the
    # fig_fleet smoke itself, the gate only tracks the trajectory
    "microbench_sim": {"micro_event_rate_ev_s": 0,
                       "micro_vec_rate_ev_s": +1,
                       "micro_speedup": +1},
    "fig_fleet": {"fleet_slo_10": +1, "fleet_slo_100": +1,
                  "fleet_slo_1000": +1,
                  "fleet_tput_10_tok_s": +1, "fleet_tput_100_tok_s": +1,
                  "fleet_tput_1000_tok_s": +1,
                  "fleet_1000_done": +1,
                  "fleet_speedup_100": +1,
                  "sim_events_per_sec": +1},
}

#: absolute slack added to every band, so near-zero baselines gate on
#: "stayed near zero" instead of "within 5% of zero"
ABS_FLOOR = {"vl_collective_stall_s": 1.0,
             # wall-clock-derived metrics on shared CI runners: wide
             # noise slack; the >=50x hard gate lives in the fig_fleet
             # smoke assert, not in these trajectory bands
             "fleet_speedup_100": 20.0,
             "sim_events_per_sec": 40_000.0,
             "micro_speedup": 4.0,
             "micro_vec_rate_ev_s": 40_000.0}
DEFAULT_ABS_FLOOR = 0.02


def collect() -> dict:
    from benchmarks.run import run_smoke_all
    return {"schema": SCHEMA, "metrics": run_smoke_all()}


def compare(baseline: dict, current: dict,
            rel_tol: float = REL_TOL) -> list:
    """Regressions of ``current`` vs ``baseline``; empty list = pass."""
    bad = []
    base_m = baseline.get("metrics", {})
    cur_m = current.get("metrics", {})
    for bench, metrics in base_m.items():
        cur = cur_m.get(bench)
        if cur is None:
            bad.append(f"{bench}: benchmark missing from current run")
            continue
        for name, base_v in metrics.items():
            # presence FIRST: losing a baseline metric is a regression
            # regardless of its gating direction
            if name not in cur:
                bad.append(f"{bench}.{name}: metric missing "
                           f"(baseline {base_v:.4g})")
                continue
            direction = DIRECTIONS.get(bench, {}).get(name)
            if direction == 0:
                continue
            if direction is None:
                # unknown metric: informational (new metrics must not
                # invalidate old baselines), but warn loudly
                print(f"perf_gate: no direction for {bench}.{name}; "
                      f"not gated", file=sys.stderr)
                continue
            cur_v = cur[name]
            # a non-finite current value against a finite baseline can
            # never pass a band check by arithmetic (every NaN compare
            # is False), so it must fail explicitly — a metric decaying
            # to NaN/inf is a lost metric, not within-band noise
            if not math.isfinite(cur_v):
                if isinstance(base_v, float) and not math.isfinite(base_v):
                    continue        # non-finite on both sides: recorded
                bad.append(f"{bench}.{name}: non-finite current value "
                           f"{cur_v!r} vs baseline {base_v:.4g}")
                continue
            band = rel_tol * abs(base_v) + \
                ABS_FLOOR.get(name, DEFAULT_ABS_FLOOR)
            delta = (cur_v - base_v) * direction
            if delta < -band:
                bad.append(
                    f"{bench}.{name}: {cur_v:.4g} vs baseline "
                    f"{base_v:.4g} ({'-' if direction > 0 else '+'}"
                    f"{abs(cur_v - base_v):.4g} > band {band:.4g})")
    return bad


def latest_baseline_path(exclude=None) -> str | None:
    paths = []
    for p in glob.glob(os.path.join(REPO_ROOT, "BENCH_*.json")):
        m = re.match(r"BENCH_(\d+)\.json$", os.path.basename(p))
        if m and p != exclude:
            paths.append((int(m.group(1)), p))
    return max(paths)[1] if paths else None


def self_test() -> None:
    """The comparator must catch an injected >5% regression in every
    gated direction, accept within-band noise, and flag lost metrics."""
    base = {"schema": SCHEMA, "metrics": {
        "fig_online_serving": {"offline_tok_s": 100.0,
                               "slo_attainment": 1.0},
        "fig_interference": {"vl_collective_stall_s": 0.0},
        "fig_elastic": {"reconfig_drain_s": 50.0},
    }}

    def mut(bench, name, value):
        cur = json.loads(json.dumps(base))
        cur["metrics"][bench][name] = value
        return cur

    # >5% drop in a higher-is-better metric fails
    assert compare(base, mut("fig_online_serving", "offline_tok_s", 90.0))
    # within-band noise passes
    assert not compare(base, mut("fig_online_serving", "offline_tok_s",
                                 96.0))
    # improvement passes
    assert not compare(base, mut("fig_online_serving", "offline_tok_s",
                                 140.0))
    # lower-is-better regression fails
    assert compare(base, mut("fig_elastic", "reconfig_drain_s", 60.0))
    # near-zero baseline: small absolute creep stays inside the floor,
    # a real stall does not
    assert not compare(base, mut("fig_interference",
                                 "vl_collective_stall_s", 0.5))
    assert compare(base, mut("fig_interference",
                             "vl_collective_stall_s", 5.0))
    # a gated metric decaying to NaN/inf must fail, not slip through
    # NaN-compares-false arithmetic; NaN-vs-NaN is merely recorded
    assert compare(base, mut("fig_online_serving", "offline_tok_s",
                             float("nan")))
    assert compare(base, mut("fig_elastic", "reconfig_drain_s",
                             float("inf")))
    nan_base = json.loads(json.dumps(base))
    nan_base["metrics"]["fig_elastic"]["reconfig_drain_s"] = float("nan")
    assert not compare(nan_base, json.loads(json.dumps(nan_base)))
    # losing a metric or a whole benchmark fails — including metrics
    # whose direction is informational (0) or unregistered
    base["metrics"]["fig_elastic"]["static_best_tput_tok_s"] = 1500.0
    base["metrics"]["fig_elastic"]["unregistered_metric"] = 1.0
    for bench, name in (("fig_online_serving", "slo_attainment"),
                        ("fig_elastic", "static_best_tput_tok_s"),
                        ("fig_elastic", "unregistered_metric")):
        cur = json.loads(json.dumps(base))
        del cur["metrics"][bench][name]
        assert compare(base, cur), (bench, name)
    cur = json.loads(json.dumps(base))
    del cur["metrics"]["fig_elastic"]
    assert compare(base, cur)
    print("perf_gate self-test: PASS")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--collect", metavar="PATH",
                    help="run smokes and write PATH without gating")
    ap.add_argument("--compare", nargs=2, metavar=("BASE", "CUR"),
                    help="gate CUR against BASE without running")
    ap.add_argument("--from-json", metavar="PATH",
                    help="gate an already-collected metrics file "
                         "against the latest BENCH_* without running")
    ap.add_argument("--out", default="bench_current.json",
                    help="where the gating run writes its metrics "
                         "(uploaded as a CI artifact)")
    ap.add_argument("--rel-tol", type=float, default=REL_TOL)
    ap.add_argument("--self-test", action="store_true")
    args = ap.parse_args(argv)

    if args.self_test:
        self_test()
        return 0
    if args.compare:
        with open(args.compare[0]) as f:
            base = json.load(f)
        with open(args.compare[1]) as f:
            cur = json.load(f)
        bad = compare(base, cur, rel_tol=args.rel_tol)
    elif args.from_json:
        with open(args.from_json) as f:
            cur = json.load(f)
        if cur.get("schema") != SCHEMA:
            print(f"perf_gate: {args.from_json} has schema "
                  f"{cur.get('schema')!r}, expected {SCHEMA}",
                  file=sys.stderr)
            return 1
        base_path = latest_baseline_path(
            exclude=os.path.abspath(args.from_json))
        if base_path is None:
            print("perf_gate: no committed BENCH_*.json baseline; "
                  "metrics recorded only")
            return 0
        with open(base_path) as f:
            base = json.load(f)
        print(f"perf_gate: comparing {args.from_json} against "
              f"{base_path}")
        bad = compare(base, cur, rel_tol=args.rel_tol)
    elif args.collect:
        data = collect()
        with open(args.collect, "w") as f:
            json.dump(data, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"perf_gate: wrote {args.collect}")
        return 0
    else:
        data = collect()
        with open(args.out, "w") as f:
            json.dump(data, f, indent=2, sort_keys=True)
            f.write("\n")
        base_path = latest_baseline_path(
            exclude=os.path.abspath(args.out))
        if base_path is None:
            print("perf_gate: no committed BENCH_*.json baseline; "
                  "metrics recorded only")
            return 0
        with open(base_path) as f:
            base = json.load(f)
        print(f"perf_gate: comparing against {base_path}")
        bad = compare(base, data, rel_tol=args.rel_tol)
    if bad:
        print("perf_gate: REGRESSION", file=sys.stderr)
        for b in bad:
            print(f"  {b}", file=sys.stderr)
        return 1
    print("perf_gate: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
