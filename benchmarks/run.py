"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--quick`` shrinks agent
counts (CI-sized); default sizes reproduce the paper's operating points
(fig7 at 1024 agents reaches the ~1.87x headline).

``--smoke-all`` runs every benchmark that declares a ``--smoke`` mode
(a ``smoke`` parameter on its ``run()``) and fails on the first
acceptance violation — the single CI entry point, so new figures are
covered by registering here instead of editing the workflow.  Smoke
runs return their headline metrics; ``benchmarks/perf_gate.py`` turns
those into the committed ``BENCH_*.json`` trajectory.
"""
import argparse
import inspect
import os
import sys

if __package__ in (None, ""):       # direct `python benchmarks/run.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))


def suite():
    from benchmarks import (fig7_offline, fig8_pd_ratio, fig9_append_gen,
                            fig10_online, fig12_ablation, fig13_balance,
                            fig_bottleneck, fig_elastic, fig_fleet,
                            fig_interference, fig_online_serving,
                            fig_resilience, fig_slo, fig_tiered_prefetch,
                            kernel_bench, micro_submit, microbench_sim,
                            roofline, table1_cache_compute, table3_scale)
    return {
        "table1": table1_cache_compute.run,
        "micro_submit": micro_submit.run,
        "kernels": kernel_bench.run,
        "fig7": fig7_offline.run,
        "fig8": fig8_pd_ratio.run,
        "fig9": fig9_append_gen.run,
        "fig10": fig10_online.run,
        "fig12": fig12_ablation.run,
        "fig13": fig13_balance.run,
        "fig_tiered": fig_tiered_prefetch.run,
        "fig_online_serving": fig_online_serving.run,
        "fig_slo": fig_slo.run,
        "fig_interference": fig_interference.run,
        "fig_elastic": fig_elastic.run,
        "fig_resilience": fig_resilience.run,
        "fig_bottleneck": fig_bottleneck.run,
        "microbench_sim": microbench_sim.run,
        "fig_fleet": fig_fleet.run,
        "table3": table3_scale.run,
        "roofline": roofline.run,
    }


def smoke_benchmarks(full=None):
    """The registered benchmarks that declare a smoke mode."""
    full = full or suite()
    return {name: fn for name, fn in full.items()
            if "smoke" in inspect.signature(fn).parameters}


def run_smoke_all(only=None) -> dict:
    """Run every smoke-capable benchmark (optionally filtered to the
    ``only`` name set); returns ``{name: metrics}`` with each smoke
    run's headline-metric dict (empty when a benchmark returns none).
    Raises on the first acceptance violation or an unknown name."""
    from benchmarks.common import header
    header()
    smokes = smoke_benchmarks()
    if only:
        unknown = set(only) - set(smokes)
        if unknown:
            raise SystemExit(f"--only names without a --smoke mode: "
                             f"{sorted(unknown)}")
        smokes = {n: fn for n, fn in smokes.items() if n in only}
    out = {}
    # Mark the shared-process suite run: wall-clock-gated benchmarks
    # (fig_fleet's >=50x assert) apply their hard thresholds only when
    # run in isolation — a long-lived suite process carries heap
    # fragmentation from earlier benchmarks that skews short timed
    # legs.  The metrics are still collected and band-gated by the
    # perf trajectory, suite-run against suite-run baselines.
    os.environ["REPRO_BENCH_SUITE"] = "1"
    try:
        for name, fn in smokes.items():
            metrics = fn(smoke=True)
            out[name] = dict(metrics or {})
            print(f"{name} smoke: PASS", file=sys.stderr)
            try:    # drop compiled programs between benchmarks: a long
                import jax  # single-process run OOMs the CPU LLVM JIT
                jax.clear_caches()  # (same guard as tests/conftest.py)
            except ImportError:
                pass
            import gc
            gc.collect()
    finally:
        os.environ.pop("REPRO_BENCH_SUITE", None)
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    ap.add_argument("--list", action="store_true",
                    help="list benchmark names and exit")
    ap.add_argument("--smoke-all", action="store_true",
                    help="run every benchmark that declares --smoke and "
                         "fail on the first acceptance violation")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="with --smoke-all: write the collected headline "
                         "metrics as perf_gate-schema JSON to PATH")
    args = ap.parse_args(argv)
    if args.json and not args.smoke_all:
        raise SystemExit("--json requires --smoke-all")

    from benchmarks.common import header

    full = suite()
    if args.list:
        smokes = smoke_benchmarks(full)
        for name, fn in full.items():
            doc = (sys.modules[fn.__module__].__doc__ or
                   "").strip().splitlines()
            tag = " [smoke]" if name in smokes else ""
            print(f"{name}{tag}: {doc[0] if doc else ''}")
        return
    only = set(args.only.split(",")) if args.only else None
    if args.smoke_all:
        metrics = run_smoke_all(only=only)
        if args.json:
            import json
            from benchmarks.perf_gate import SCHEMA
            with open(args.json, "w") as f:
                json.dump({"schema": SCHEMA, "metrics": metrics}, f,
                          indent=2, sort_keys=True)
                f.write("\n")
            print(f"wrote {args.json}", file=sys.stderr)
        return
    header()
    for name, fn in full.items():
        if only and name not in only:
            continue
        try:
            try:
                fn(quick=args.quick)
            except TypeError:
                fn()
        except Exception as e:  # noqa: BLE001
            print(f"{name},0.0,ERROR:{e!r}", file=sys.stderr)


if __name__ == "__main__":
    main()
