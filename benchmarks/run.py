"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--quick`` shrinks agent
counts (CI-sized); default sizes reproduce the paper's operating points
(fig7 at 1024 agents reaches the ~1.87x headline).
"""
import argparse
import sys


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    args = ap.parse_args(argv)

    from benchmarks import (fig7_offline, fig8_pd_ratio, fig9_append_gen,
                            fig10_online, fig12_ablation, fig13_balance,
                            kernel_bench, micro_submit, roofline,
                            table1_cache_compute, table3_scale)
    from benchmarks.common import header

    suite = {
        "table1": table1_cache_compute.run,
        "micro_submit": micro_submit.run,
        "kernels": kernel_bench.run,
        "fig7": fig7_offline.run,
        "fig8": fig8_pd_ratio.run,
        "fig9": fig9_append_gen.run,
        "fig10": fig10_online.run,
        "fig12": fig12_ablation.run,
        "fig13": fig13_balance.run,
        "table3": table3_scale.run,
        "roofline": roofline.run,
    }
    only = set(args.only.split(",")) if args.only else None
    header()
    for name, fn in suite.items():
        if only and name not in only:
            continue
        try:
            try:
                fn(quick=args.quick)
            except TypeError:
                fn()
        except Exception as e:  # noqa: BLE001
            print(f"{name},0.0,ERROR:{e!r}", file=sys.stderr)


if __name__ == "__main__":
    main()
