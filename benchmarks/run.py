"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--quick`` shrinks agent
counts (CI-sized); default sizes reproduce the paper's operating points
(fig7 at 1024 agents reaches the ~1.87x headline).
"""
import argparse
import os
import sys

if __package__ in (None, ""):       # direct `python benchmarks/run.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    ap.add_argument("--list", action="store_true",
                    help="list benchmark names and exit")
    args = ap.parse_args(argv)

    from benchmarks import (fig7_offline, fig8_pd_ratio, fig9_append_gen,
                            fig10_online, fig12_ablation, fig13_balance,
                            fig_interference, fig_online_serving,
                            fig_tiered_prefetch, kernel_bench, micro_submit,
                            roofline, table1_cache_compute, table3_scale)
    from benchmarks.common import header

    suite = {
        "table1": table1_cache_compute.run,
        "micro_submit": micro_submit.run,
        "kernels": kernel_bench.run,
        "fig7": fig7_offline.run,
        "fig8": fig8_pd_ratio.run,
        "fig9": fig9_append_gen.run,
        "fig10": fig10_online.run,
        "fig12": fig12_ablation.run,
        "fig13": fig13_balance.run,
        "fig_tiered": fig_tiered_prefetch.run,
        "fig_online_serving": fig_online_serving.run,
        "fig_interference": fig_interference.run,
        "table3": table3_scale.run,
        "roofline": roofline.run,
    }
    if args.list:
        for name, fn in suite.items():
            doc = (sys.modules[fn.__module__].__doc__ or
                   "").strip().splitlines()
            print(f"{name}: {doc[0] if doc else ''}")
        return
    only = set(args.only.split(",")) if args.only else None
    header()
    for name, fn in suite.items():
        if only and name not in only:
            continue
        try:
            try:
                fn(quick=args.quick)
            except TypeError:
                fn()
        except Exception as e:  # noqa: BLE001
            print(f"{name},0.0,ERROR:{e!r}", file=sys.stderr)


if __name__ == "__main__":
    main()
