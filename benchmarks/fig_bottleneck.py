"""Bottleneck attribution: where does TTFT go, per operating point?

The paper's storage-bandwidth-bottleneck claim is a statement about
*attribution*: at agentic operating points the time-to-first-token is
dominated by waiting on the storage NIC, not on compute.  This
benchmark makes that claim measurable end-to-end with the flight
recorder (``repro.obs``): each arm runs fully traced, the trace is
audited against the runtime's own conservation ledgers
(``obs/audit.py`` — every byte the counters saw must reappear in the
trace, exactly), and each finished request's TTFT is decomposed on the
critical path into waiting-on-{storage, compute, compute-net, drain,
queue} seconds (``obs/attribution.py``).

Arms:

* **sim/storage-bound** — SNICs throttled to 0.25 GB/s under a
  many-round agentic workload (each round re-reads the ~8k-token
  context from storage; arrivals staggered so queueing is negligible):
  reads dominate, attribution must name ``storage`` the bottleneck;
* **sim/compute-bound** — healthy SNICs, generated agentic workload:
  prefill dominates, attribution must name ``compute``;
* **serving** — the real-bytes runtime, run to completion (drained) so
  the persist audit can hold exactly.

Acceptance, asserted in ``--smoke`` mode (CI):

* every trace audit passes (byte sums == ledgers, hedge counts == the
  runtimes' counters);
* the per-request decomposition is an exact partition: the five
  components sum to the attribution window to < 1 µs on every request;
* the attribution windows reproduce each arm's *measured* mean TTFT
  (``results()`` / ``stats()``) to < 0.01% relative error;
* the two sim arms' dominant categories are ``storage`` and
  ``compute`` respectively;
* re-running an arm with a fresh tracer yields a **byte-identical**
  exported trace (deterministic recording);
* running untraced yields numerically identical results
  (zero-overhead-when-disabled).

``--trace-out PATH`` additionally exports the storage-bound arm's
Perfetto-loadable trace (the CI artifact; load at
https://ui.perfetto.dev).
"""
from __future__ import annotations

import argparse
import math
import os
import sys
from dataclasses import replace

if __package__ in (None, ""):       # direct `python benchmarks/<file>.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks.common import emit, header, timed

#: storage-bound operating point: every round after the first re-reads
#: the full ~8k-token context (~0.3 GB at DS_660B's 35 kB/token KV)
#: over SNICs throttled to 0.25 GB/s; arrivals 10 s apart keep PE
#: queueing out of the picture, so reads own the TTFT critical path
N_AGENTS_STORAGE = 6
SNIC_BW = 0.25e9
STORAGE_ROUNDS = ((8192, 16),) + ((256, 16),) * 5
ARRIVAL_GAP_S = 10.0
#: exactness bounds asserted in smoke mode
DECOMP_TOL_S = 1e-6
TTFT_REL_TOL = 1e-4


def _sim_arm(storage_bound: bool, quick: bool, tracer=None):
    from repro.sim import (DS_660B, HOPPER_NODE, Sim, SimConfig,
                           generate_dataset)
    from repro.sim.traces import Round, Trajectory
    if storage_bound:
        cfg = SimConfig(node=replace(HOPPER_NODE, g=1, snic_bw=SNIC_BW),
                        model=DS_660B, P=2, D=2, mode="dualpath",
                        nodes_per_pe_group=1, nodes_per_de_group=1,
                        split_reads=True)
        trajs = [Trajectory(i, [Round(*r) for r in STORAGE_ROUNDS])
                 for i in range(N_AGENTS_STORAGE)]
        arrivals = [i * ARRIVAL_GAP_S for i in range(len(trajs))]
    else:
        cfg = SimConfig(node=HOPPER_NODE, model=DS_660B, P=1, D=2,
                        mode="dualpath")
        trajs = generate_dataset(8 if quick else 16, 16384, seed=0)
        arrivals = None
    sim = Sim(cfg, trajs, tracer=tracer).run(arrivals=arrivals)
    return sim, sim.results()


def _serving_arm(tracer=None):
    import jax
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving import ServingSystem
    from repro.sim.spec import REDUCED_TEST_NODE
    from repro.sim.traces import Round, Trajectory

    cfg = get_config("qwen1.5-0.5b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    sys_ = ServingSystem(cfg, params, n_pe=1, n_de=2, block_tokens=16,
                         max_seq=160, de_slots=2, seed=0,
                         split_reads=True, node=REDUCED_TEST_NODE,
                         tracer=tracer)
    trajs = [Trajectory(i, [Round(24, 6, 0.5), Round(16, 4, 0.0)])
             for i in range(4)]
    sys_.run_online(trajs, [0.0, 0.1, 0.2, 0.3])
    return sys_, sys_.stats()


def _attributed(tracer, measured_ttft_mean: float):
    """Decompose + aggregate, and pin the exact-partition and
    matches-measured-TTFT properties."""
    from repro.obs import attribute_ttft, bottleneck_report
    per_req = attribute_ttft(tracer)
    rep = bottleneck_report(per_req)
    assert rep["n"] > 0, "no attributed requests in trace"
    assert rep["max_decomp_err_s"] < DECOMP_TOL_S, rep
    rel = abs(rep["ttft_mean_s"] - measured_ttft_mean) / \
        max(measured_ttft_mean, 1e-12)
    assert rel < TTFT_REL_TOL, (rep["ttft_mean_s"], measured_ttft_mean)
    rep["attr_ttft_rel_err"] = rel
    return rep


def run(quick: bool = False, smoke: bool = False, trace_out=None):
    from repro.obs import Tracer, audit_serving, audit_sim

    # ---- sim, storage-bound ---------------------------------------------
    with timed("fig_bottleneck/sim_storage_bound") as box:
        tr_s = Tracer()
        sim_s, res_s = _sim_arm(True, quick, tracer=tr_s)
        audit_sim(sim_s, tr_s)              # raises on any byte mismatch
        rep_s = _attributed(tr_s, res_s["ttft_mean"])
        box["derived"] = (
            f"bottleneck={rep_s['bottleneck']} "
            f"storage={rep_s['storage_frac']:.2f} "
            f"compute={rep_s['compute_frac']:.2f} "
            f"queue={rep_s['queue_frac']:.2f} n={rep_s['n']}")
    if trace_out:
        tr_s.export_json(trace_out)
        emit("fig_bottleneck/trace_export", 0.0,
             f"wrote {trace_out} ({len(tr_s.spans)} spans, "
             f"{len(tr_s.counters)} counter samples)")

    # ---- sim, compute-bound ---------------------------------------------
    with timed("fig_bottleneck/sim_compute_bound") as box:
        tr_c = Tracer()
        sim_c, res_c = _sim_arm(False, quick, tracer=tr_c)
        audit_sim(sim_c, tr_c)
        rep_c = _attributed(tr_c, res_c["ttft_mean"])
        box["derived"] = (
            f"bottleneck={rep_c['bottleneck']} "
            f"storage={rep_c['storage_frac']:.2f} "
            f"compute={rep_c['compute_frac']:.2f} n={rep_c['n']}")

    # ---- serving (real bytes), fully drained ----------------------------
    with timed("fig_bottleneck/serving") as box:
        tr_v = Tracer()
        srv, st = _serving_arm(tracer=tr_v)
        audit_serving(srv, tr_v, check_persists=True)
        rep_v = _attributed(tr_v, st["ttft_mean"])
        box["derived"] = (
            f"bottleneck={rep_v['bottleneck']} n={rep_v['n']} "
            f"ttft_mean={rep_v['ttft_mean_s']:.2e}s")

    # ---- determinism: same arm, fresh tracer, identical bytes ------------
    with timed("fig_bottleneck/determinism") as box:
        tr_v2 = Tracer()
        _serving_arm(tracer=tr_v2)
        serving_identical = tr_v2.export_bytes() == tr_v.export_bytes()
        tr_s2 = Tracer()
        _sim_arm(True, quick, tracer=tr_s2)
        sim_identical = tr_s2.export_bytes() == tr_s.export_bytes()
        box["derived"] = (f"serving_identical={serving_identical} "
                          f"sim_identical={sim_identical}")

    # ---- zero overhead: untraced run, identical numbers ------------------
    with timed("fig_bottleneck/untraced_identity") as box:
        _, res_s0 = _sim_arm(True, quick, tracer=None)
        diffs = [k for k in res_s0
                 if res_s0[k] != res_s[k]
                 and not (isinstance(res_s0[k], float)
                          and math.isnan(res_s0[k])
                          and math.isnan(res_s[k]))]
        box["derived"] = f"diffs={diffs}"

    # ---- acceptance ------------------------------------------------------
    assert rep_s["bottleneck"] == "storage", rep_s
    assert rep_c["bottleneck"] == "compute", rep_c
    assert serving_identical and sim_identical, "trace not deterministic"
    assert not diffs, f"tracing changed sim results: {diffs}"

    max_err = max(rep_s["max_decomp_err_s"], rep_c["max_decomp_err_s"],
                  rep_v["max_decomp_err_s"])
    max_rel = max(rep_s["attr_ttft_rel_err"], rep_c["attr_ttft_rel_err"],
                  rep_v["attr_ttft_rel_err"])
    emit("fig_bottleneck/acceptance", 0.0,
         f"ok: storage-bound storage_frac={rep_s['storage_frac']:.2f}, "
         f"compute-bound compute_frac={rep_c['compute_frac']:.2f}, "
         f"decomp_err<={max_err:.1e}s ttft_rel_err<={max_rel:.1e}, "
         f"audits exact, traces byte-identical")
    return {
        "storage_frac_storage_bound": rep_s["storage_frac"],
        "compute_frac_compute_bound": rep_c["compute_frac"],
        "storage_bound_ttft_mean_s": rep_s["ttft_mean_s"],
        "max_decomp_err_s": max_err,
        "attr_ttft_rel_err": max_rel,
        "trace_spans": float(len(tr_s.spans)),
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run that asserts the acceptance "
                         "criteria and exits nonzero on violation")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="export the storage-bound arm's Perfetto trace")
    args = ap.parse_args(argv)
    header()
    run(quick=args.quick, smoke=args.smoke, trace_out=args.trace_out)
    if args.smoke:
        print("fig_bottleneck smoke: PASS", file=sys.stderr)


if __name__ == "__main__":
    main()
