"""Failure & straggler resilience under SLO (sim/faults.py end-to-end).

The paper's SLO claims assume healthy hardware; this benchmark measures
what the dual-path system does when hardware misbehaves, sweeping a
seeded fault schedule's intensity over a storage-bound operating point
(SNICs throttled to 4 GB/s, 24k-token first-round contexts, so reads
dominate TTFT) and comparing resilience arms apples-to-apples on the
*same* schedule:

* **no-hedge / static** — PR-1..5 behaviour: a straggling read leg is
  waited out, a dead engine's capacity is simply gone;
* **hedged / static** — hedged split reads (core/loading
  ``hedge_water_fill`` + scheduler ``rebalance_remainder``): the
  straggler's unserved remainder re-water-fills onto the healthy SNIC
  mid-read;
* **hedged / elastic** — hedging plus the PR-5 controller: an engine
  death shifts per-role pressure, the PDController proposes a
  compensating flip, and the drain/requeue machinery re-homes work
  (role backfill).

The fault schedule composes all three fault processes: per-node SNIC
slowdown windows, per-(request, side) read-leg stragglers, and one DE
death at 30% of the run.  Intensity scales the window rate and
straggler probability; the death appears at full intensity.

Acceptance signals, asserted in ``--smoke`` mode (CI):

* every arm at every intensity finishes the full workload — faults
  delay rounds, they never lose them;
* at nonzero fault intensity, hedged+elastic SLO attainment strictly
  beats no-hedge static;
* with stragglers only (no death), hedging strictly improves SLO
  attainment and cuts TTFT p99;
* a zero-intensity (empty) schedule with hedging armed is
  *numerically identical* to ``faults=None`` — every ``results()``
  metric equal — on the simulator, and bit-identical tokens + equal
  stats on the real-bytes serving runtime;
* the serving runtime survives an engine death mid-run with recovered
  rounds and still generates bit-identical tokens (greedy decode
  restarting from persisted KV is deterministic).
"""
from __future__ import annotations

import argparse
import os
import sys
from dataclasses import replace

if __package__ in (None, ""):       # direct `python benchmarks/<file>.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks.common import emit, header, timed

# Storage-bound operating point: big first-round reads over throttled
# SNICs make the read path the TTFT bottleneck, so leg-level faults
# actually show up in the SLO numbers (at healthy 50 GB/s SNICs a
# straggling leg costs milliseconds and no hedge would ever trigger).
N_AGENTS = 12
SNIC_BW = 4e9
KV_HBM_FRAC = 0.04
DURATION_S = 175.0                  # ≈ healthy-run makespan (schedule span)
FAULT_SEED = 3
TTFT_SLO_S = 40.0
TPOT_SLO_S = 1.0


def _workload():
    from repro.sim.traces import Round, Trajectory
    return [Trajectory(i, [Round(24576, 32), Round(512, 128),
                           Round(256, 128)])
            for i in range(N_AGENTS)]


def _schedule(scale: float):
    """The seeded fault timeline at intensity ``scale`` (0 = healthy).
    Deaths target the DE side so the static arm loses decode capacity
    the elastic arm can back-fill."""
    from repro.sim import FaultSchedule
    if scale <= 0.0:
        return None
    return FaultSchedule.generate(
        seed=FAULT_SEED, duration_s=DURATION_S, nodes=range(4),
        engines=((2, 0), (3, 0)),
        snic_fault_rate=0.03 * scale, snic_factor=6.0,
        straggler_prob=0.3 * scale, straggler_severity=8.0,
        n_deaths=1 if scale >= 1.0 else 0, death_frac=0.3)


def _sim_arm(faults, hedge: bool, elastic: bool, trajs):
    from repro.core.config import ElasticConfig, ResilienceConfig
    from repro.sim import DS_660B, HOPPER_NODE, Sim, SimConfig
    cfg = SimConfig(node=replace(HOPPER_NODE, g=1, snic_bw=SNIC_BW),
                    model=DS_660B, P=2, D=2, mode="dualpath",
                    nodes_per_pe_group=1, nodes_per_de_group=1,
                    split_reads=True, kv_hbm_frac=KV_HBM_FRAC,
                    resilience=ResilienceConfig(faults=faults,
                                                hedge_reads=hedge),
                    elastic=ElasticConfig(enabled=elastic,
                                          reconfig_interval_s=4.0,
                                          reconfig_patience=2))
    fresh = [type(t)(t.tid, list(t.rounds)) for t in trajs]
    sim = Sim(cfg, fresh).run()
    r = sim.results()
    r["slo"] = sim.slo_attainment(ttft_slo_s=TTFT_SLO_S,
                                  tpot_slo_s=TPOT_SLO_S)
    return r


def _serving_resilience():
    """Fault injection on the real-bytes runtime: (a) an empty schedule
    with hedging armed must be *invisible* — identical tokens and
    identical stats to ``faults=None``; (b) SNIC windows + stragglers
    trigger issue-time hedges; (c) a DE death mid-run re-homes rounds.
    Every arm must generate bit-identical tokens: faults move time,
    never generation (restart from persisted KV + greedy decode)."""
    import jax
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving import ServingSystem
    from repro.sim.faults import (EngineDeath, FaultSchedule,
                                  SlowdownWindow, StragglerModel)
    from repro.sim.spec import REDUCED_TEST_NODE
    from repro.sim.traces import Round, Trajectory

    cfg = get_config("qwen1.5-0.5b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))

    def run(faults=None, hedge_reads=False):
        from repro.core.config import ResilienceConfig
        sys_ = ServingSystem(cfg, params, n_pe=2, n_de=2, block_tokens=16,
                             max_seq=160, de_slots=2, seed=0,
                             pipelined=True, split_reads=True,
                             node=REDUCED_TEST_NODE,
                             resilience=ResilienceConfig(
                                 faults=faults, hedge_reads=hedge_reads))
        trajs = [Trajectory(i, [Round(24, 4), Round(16, 4), Round(8, 4)])
                 for i in range(4)]
        sessions = sys_.run_online(trajs, [0.0, 0.1, 0.2, 0.3])
        return dict(tokens=[s.context for s in sessions],
                    st=sys_.stats())

    arms = {
        "baseline": run(),
        "empty+hedge": run(faults=FaultSchedule(), hedge_reads=True),
        "straggle+hedge": run(
            faults=FaultSchedule(
                windows=[SlowdownWindow("snic", 0.0, 1e9, 8.0, node=0)],
                straggler=StragglerModel(0.4, 8.0, seed=7)),
            hedge_reads=True),
        "de_death": run(
            faults=FaultSchedule(deaths=[EngineDeath(0.65, (2, 0))])),
    }
    return arms


def run(quick: bool = False, smoke: bool = False):
    trajs = _workload()
    scales = (0.0, 1.0) if (quick or smoke) else (0.0, 0.25, 0.5, 1.0)
    straggle_scale = 0.5            # stragglers + windows, no death
    arms = {"nohedge+static": (False, False),
            "hedged+static": (True, False),
            "hedged+elastic": (True, True)}
    res = {}
    for scale in (*scales, straggle_scale):
        if scale in res:
            continue
        fs = _schedule(scale)
        res[scale] = {}
        for name, (hedge, elastic) in arms.items():
            with timed(f"fig_resilience/x{scale:g}/{name}") as box:
                r = _sim_arm(fs, hedge, elastic, trajs)
                res[scale][name] = r
                box["derived"] = (
                    f"slo={r['slo']:.3f} ttft_p99={r['ttft_p99']:.1f}s "
                    f"jct={r['jct_mean']:.1f}s hedges={r['hedged_reads']} "
                    f"deaths={r['engine_deaths']} "
                    f"recovered={r['recovered_rounds']} "
                    f"flips={r['role_changes']}")

    # sim-side zero-fault identity: empty schedule + hedging armed is
    # numerically invisible (every results() metric equal)
    with timed("fig_resilience/zero_fault_identity") as box:
        from repro.sim import FaultSchedule
        base = _sim_arm(None, False, False, trajs)
        armed = _sim_arm(FaultSchedule(), True, False, trajs)
        diffs = [k for k in base if base[k] != armed[k]]
        box["derived"] = f"diffs={diffs}"
        assert not diffs, f"empty schedule changed sim results: {diffs}"

    with timed("fig_resilience/serving") as box:
        srv = _serving_resilience()
        st_d = srv["de_death"]["st"]
        st_s = srv["straggle+hedge"]["st"]
        box["derived"] = (
            f"deaths={st_d['engine_deaths']} "
            f"recovered={st_d['recovered_rounds']} "
            f"hedges={st_s['hedged_reads']} "
            f"moved={st_s['hedge_moved_tokens']}tok")

    # ---- acceptance ------------------------------------------------------
    for scale, by_arm in res.items():
        for name, r in by_arm.items():
            assert r["finished_agents"] == N_AGENTS, (scale, name, r)
    # nonzero fault intensity: hedged+elastic strictly beats no-hedge
    # static on SLO attainment (the tentpole claim)
    top = res[max(scales)]
    assert top["hedged+elastic"]["slo"] > top["nohedge+static"]["slo"], \
        (top["hedged+elastic"]["slo"], top["nohedge+static"]["slo"])
    assert top["nohedge+static"]["engine_deaths"] == 1
    assert top["hedged+elastic"]["recovered_rounds"] > 0
    assert top["hedged+elastic"]["hedged_reads"] > 0
    # stragglers only: hedging strictly improves attainment and the tail
    sg = res[straggle_scale]
    assert sg["hedged+static"]["slo"] > sg["nohedge+static"]["slo"], \
        (sg["hedged+static"]["slo"], sg["nohedge+static"]["slo"])
    assert sg["hedged+static"]["ttft_p99"] < sg["nohedge+static"]["ttft_p99"]
    assert sg["hedged+static"]["hedged_reads"] > 0
    # healthy runs: hedging armed changes nothing (asserted above for
    # the sim; serving must be token- AND stats-identical)
    assert srv["empty+hedge"]["tokens"] == srv["baseline"]["tokens"]
    assert srv["empty+hedge"]["st"] == srv["baseline"]["st"], \
        [k for k in srv["baseline"]["st"]
         if srv["baseline"]["st"][k] != srv["empty+hedge"]["st"][k]]
    # faults move time, never generation
    for name in ("straggle+hedge", "de_death"):
        assert srv[name]["tokens"] == srv["baseline"]["tokens"], name
    st_s = srv["straggle+hedge"]["st"]
    assert st_s["hedged_reads"] > 0 and st_s["hedge_moved_tokens"] > 0
    st_d = srv["de_death"]["st"]
    assert st_d["engine_deaths"] == 1 and st_d["recovered_rounds"] > 0
    assert st_d["n_de_final"] == 1

    gain = (top["hedged+elastic"]["slo"] - top["nohedge+static"]["slo"])
    emit("fig_resilience/acceptance", 0.0,
         f"ok: slo@x{max(scales):g} {top['nohedge+static']['slo']:.3f} -> "
         f"{top['hedged+elastic']['slo']:.3f} (+{gain:.3f}); straggle "
         f"ttft_p99 {sg['nohedge+static']['ttft_p99']:.1f}s -> "
         f"{sg['hedged+static']['ttft_p99']:.1f}s; serving recovered "
         f"{st_d['recovered_rounds']} round(s), {st_s['hedged_reads']} "
         f"hedge(s), tokens identical")
    return {
        "slo_faulted_hedged_elastic": top["hedged+elastic"]["slo"],
        "slo_faulted_nohedge_static": top["nohedge+static"]["slo"],
        "resilience_slo_gain": gain,
        "slo_straggle_hedged": sg["hedged+static"]["slo"],
        "straggle_ttft_p99_hedged_s": sg["hedged+static"]["ttft_p99"],
        "straggle_ttft_p99_nohedge_s": sg["nohedge+static"]["ttft_p99"],
        "sim_hedged_reads": float(top["hedged+elastic"]["hedged_reads"]),
        "sim_recovered_rounds": float(
            top["hedged+elastic"]["recovered_rounds"]),
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run that asserts the acceptance "
                         "criteria and exits nonzero on violation")
    args = ap.parse_args(argv)
    header()
    run(quick=args.quick, smoke=args.smoke)
    if args.smoke:
        print("fig_resilience smoke: PASS", file=sys.stderr)


if __name__ == "__main__":
    main()
