"""Fig. 10/11: online serving under Poisson arrivals — TTFT/TTST/TPOT vs
agent arrival rate (APS); SLO: TTFT ≤ 4 s, TPOT ≤ 50 ms.

Paper: DualPath sustains ~1.96× higher APS on average within SLO
(1.67× DS 27B, 2.25× DS 660B)."""
from __future__ import annotations

import numpy as np

from repro.configs import get_config
from repro.sim import DS_660B, HOPPER_NODE, Sim, SimConfig
from repro.sim.spec import ModelSimSpec
from repro.sim.traces import generate_dataset

from benchmarks.common import emit, timed

SLO_TTFT = 4.0
SLO_TPOT = 0.050


def capacity(model, P, D, label, aps_grid, n_agents):
    """Largest APS meeting the SLO, per mode."""
    caps = {}
    for mode in ("basic", "dualpath"):
        best = 0.0
        for aps in aps_grid:
            trajs = generate_dataset(n_agents, 32768, seed=1)
            rng = np.random.default_rng(0)
            arr = list(np.cumsum(rng.exponential(1 / aps, size=len(trajs))))
            cfg = SimConfig(node=HOPPER_NODE, model=model, P=P, D=D,
                            mode=mode, online=True)
            with timed(f"fig10/{label}/{mode}/aps{aps}") as box:
                r = Sim(cfg, trajs).run(arrivals=arr).results()
                ok = (r["ttft_p99"] <= SLO_TTFT and
                      r["tpot_mean"] <= SLO_TPOT and
                      r["finished_agents"] == len(trajs))
                box["derived"] = (f"ttft_p99={r['ttft_p99']:.2f}s "
                                  f"ttst={r['ttst_mean']:.2f}s "
                                  f"tpot={r['tpot_mean'] * 1e3:.1f}ms "
                                  f"{'OK' if ok else 'SLO-VIOLATION'}")
            if ok:
                best = aps
            else:
                break
        caps[mode] = best
    gain = caps["dualpath"] / max(caps["basic"], 1e-9)
    emit(f"fig10/{label}/capacity", 0.0,
         f"basic={caps['basic']}aps dualpath={caps['dualpath']}aps "
         f"gain={gain:.2f}x (paper avg 1.96x)")


def run(quick: bool = False):
    n = 96 if quick else 256
    capacity(DS_660B, 2, 4, "ds660b-2p4d",
             (0.25, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0), n)
    ds27 = ModelSimSpec.from_config(get_config("ds27b"), kv_dtype_bytes=1,
                                    param_dtype_bytes=1)
    capacity(ds27, 1, 1, "ds27b-1p1d",
             (0.25, 0.5, 1.0, 1.5, 2.0, 3.0), n)


if __name__ == "__main__":
    run()
