"""Fig. 7: offline (RL-rollout) JCT vs #agents × max agent length,
Basic / DualPath / Oracle.  Paper headline: DualPath up to 1.87× over
Basic on DS 660B; DualPath ≈ Oracle at 2P4D."""
from __future__ import annotations

from repro.sim import DS_660B, HOPPER_NODE, QWEN25_32B, Sim, SimConfig
from repro.sim.traces import generate_dataset

from benchmarks.common import emit, timed

MODES = ("basic", "dualpath", "oracle")


def run_point(model, P, D, n_agents, max_len, label):
    trajs = generate_dataset(n_agents, max_len, seed=0)
    jct = {}
    for mode in MODES:
        cfg = SimConfig(node=HOPPER_NODE, model=model, P=P, D=D, mode=mode)
        with timed(f"fig7/{label}/agents{n_agents}/mal{max_len//1024}k/"
                   f"{mode}") as box:
            r = Sim(cfg, trajs).run().results()
            jct[mode] = r["jct_max"]
            box["derived"] = (f"jct={r['jct_max']:.0f}s "
                              f"ttft={r['ttft_mean']:.2f}s "
                              f"tpot={r['tpot_mean'] * 1e3:.1f}ms")
    emit(f"fig7/{label}/agents{n_agents}/mal{max_len//1024}k/speedup", 0.0,
         f"dualpath_vs_basic={jct['basic'] / jct['dualpath']:.2f}x "
         f"oracle_gap={jct['dualpath'] / jct['oracle']:.2f}x")
    return jct


def run(quick: bool = False):
    agent_counts = (256,) if quick else (256, 1024)
    for n in agent_counts:
        for mal in (32768, 65536):
            run_point(DS_660B, 2, 4, n, mal, "ds660b-2p4d")
    # Qwen 32B 1P2D (dense GQA — bigger KV per token)
    run_point(QWEN25_32B, 1, 2, 128 if quick else 256, 32768, "qwen32b-1p2d")


if __name__ == "__main__":
    run()
