"""Tiered KV-cache ablation: off → LRU tier → LRU + think-time prefetch
(→ agentic-TTL + prefetch), on the Table-2 32K agent workload.

Beyond-paper subsystem (kvcache/tiers.py): a capacity-bounded node-local
DRAM tier over the remote KV store, warmed by the decode path and by a
prefetcher that stages the next round's predicted hit blocks during the
agent's inter-round think time.  Acceptance signals reported per arm —
and asserted in ``--smoke`` mode (CI):

* the prefetch arm shows a nonzero DRAM-tier hit ratio and strictly
  fewer demand SNIC hit-read bytes than the ``off`` arm;
* per-request byte conservation holds exactly: for every round,
  tier-served + SNIC-served load bytes == the plan's hit bytes
  (``RoundSim.charged`` over pe/de ``snic``+``tier`` resources).
"""
from __future__ import annotations

import argparse
import os
import sys

if __package__ in (None, ""):       # direct `python benchmarks/<file>.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from repro.sim import DS_660B, HOPPER_NODE, Sim, SimConfig
from repro.sim.traces import generate_dataset

from benchmarks.common import emit, header, timed

ARMS = [
    # (label, tier on, policy, prefetch)
    ("off", False, "lru", False),
    ("lru", True, "lru", False),
    ("lru+prefetch", True, "lru", True),
    ("ttl+prefetch", True, "agentic-ttl", True),
]


def _check_conservation(sim) -> int:
    """dram-served + snic-served == plan hit bytes, per round, exactly."""
    kpt = sim.kv_per_token
    checked = 0
    for rs in sim.rounds:
        if rs.done_t < 0 or rs.req.read_path is None:
            continue
        c = rs.charged
        served = (c.get("pe_snic", 0) + c.get("de_snic", 0) +
                  c.get("pe_tier", 0) + c.get("de_tier", 0))
        hit = rs.req.cached_tokens * kpt
        assert served == hit, (rs.req.rid, served, hit)
        checked += 1
    return checked


def run(quick: bool = False, smoke: bool = False):
    # per-node tier sized well below the workload's aggregate context
    # working set (~0.6 GB per 32K trajectory), so eviction pressure is
    # real and the prefetcher has evictions to repair
    if smoke:
        n_agents, think_s, tier_bytes = 12, 1.0, 0.75e9
    elif quick:
        n_agents, think_s, tier_bytes = 32, 3.0, 2e9
    else:
        n_agents, think_s, tier_bytes = 96, 3.0, 4e9
    trajs = generate_dataset(n_agents, 32768, seed=0, think_mean_s=think_s)
    res = {}
    for label, tier_on, policy, prefetch in ARMS:
        from repro.core.config import TierConfig
        cfg = SimConfig(node=HOPPER_NODE, model=DS_660B, P=1, D=2,
                        mode="dualpath",
                        tier=TierConfig(
                            dram_tier_bytes=tier_bytes if tier_on else 0.0,
                            tier_policy=policy, prefetch=prefetch))
        with timed(f"fig_tiered/{label}") as box:
            sim = Sim(cfg, trajs).run()
            r = sim.results()
            assert r["finished_agents"] == n_agents, (label, r)
            checked = _check_conservation(sim)
            assert checked > 0
            res[label] = r
            off = res["off"]
            saved = off["snic_hit_read_bytes"] - r["snic_hit_read_bytes"]
            box["derived"] = (
                f"jct={r['jct_max']:.0f}s "
                f"dram_hit_ratio={r['dram_hit_ratio']:.3f} "
                f"snic_hit={r['snic_hit_read_bytes'] / 1e9:.1f}GB "
                f"saved_vs_off={saved / 1e9:.1f}GB "
                f"prefetch={r['tier_prefetch_bytes'] / 1e9:.1f}GB "
                f"evictions={r['tier_evictions']}")
    pf, off = res["lru+prefetch"], res["off"]
    assert pf["dram_hit_ratio"] > 0, "prefetch arm never hit the DRAM tier"
    assert pf["snic_hit_read_bytes"] < off["snic_hit_read_bytes"], \
        "prefetch arm must read strictly fewer hit bytes from the SNICs"
    assert pf["dram_hit_ratio"] >= res["lru"]["dram_hit_ratio"], \
        "think-time prefetch should not lower the tier hit ratio"
    emit("fig_tiered/acceptance", 0.0,
         f"ok: conservation exact; prefetch hit_ratio "
         f"{pf['dram_hit_ratio']:.3f} > 0; snic hit bytes "
         f"{pf['snic_hit_read_bytes'] / 1e9:.1f}GB < off "
         f"{off['snic_hit_read_bytes'] / 1e9:.1f}GB")
    # headline metrics for the CI perf gate (benchmarks/perf_gate.py)
    return {
        "dram_hit_ratio": pf["dram_hit_ratio"],
        "snic_hit_saved_gb": (off["snic_hit_read_bytes"] -
                              pf["snic_hit_read_bytes"]) / 1e9,
        "jct_max_s": pf["jct_max"],
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run that asserts the acceptance "
                         "criteria and exits nonzero on violation")
    args = ap.parse_args(argv)
    header()
    run(quick=args.quick, smoke=args.smoke)
    if args.smoke:
        print("fig_tiered smoke: PASS", file=sys.stderr)


if __name__ == "__main__":
    main()
