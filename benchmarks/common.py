"""Shared benchmark utilities: timing + CSV emission."""
from __future__ import annotations

import time
from contextlib import contextmanager

ROWS = []


def emit(name: str, us_per_call: float, derived: str):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


@contextmanager
def timed(name: str):
    t0 = time.time()
    box = {}
    yield box
    us = (time.time() - t0) * 1e6
    emit(name, us, box.get("derived", ""))


def header():
    print("name,us_per_call,derived", flush=True)
