"""Interference avoidance on a finite compute network (§5.1, made
quantitative): weighted-VL arbitration vs naive FIFO sharing.

The paper claims the storage-to-decode path "avoids interference with
latency-critical model execution communications"; with the finite,
priority-arbitrated network model (repro.network) that claim becomes a
measurement.  The sweep raises background KV/PD transfer load on the
shared PE<->DE link (other tenants' dual-path reads, PD rebalancing —
``SimConfig.net_bg_load``) while per-layer model collectives ride the
same link, and compares two arbitration arms:

* ``vl``   — the paper's two-arbiter WRR: collectives own ~99 % of a
  contended link, KV keeps a starvation floor;
* ``fifo`` — class-blind processor sharing: every backlogged transfer
  dilutes the collectives' share.

Acceptance signals, asserted in ``--smoke`` mode (CI):

* with the VL arbiter, collective stall time ≈ 0 at EVERY swept load
  and SLO attainment ≥ the FIFO arm at every load;
* at the top load the FIFO arm shows real interference: collective
  stall well above the VL arm and strictly lower SLO attainment;
* the serving runtime preserves blocking-vs-pipelined token identity
  (PR 3) under the finite network (collectives on, both arbiters), and
  its contention-aware clock charges the FIFO arm at least the VL arm's
  collective stall.
"""
from __future__ import annotations

import argparse
import os
import sys

if __package__ in (None, ""):       # direct `python benchmarks/<file>.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks.common import emit, header, timed

# operating point: the link is a 200 Gb/s PD interconnect; the
# collective slice crossing it is sized so that, uncontended, every
# group step's collectives fit under its compute (~30 % of prefill
# compute) — the provisioning any sane deployment starts from.  The
# sweep then shows that FIFO sharing destroys that fit while the VL
# arbiter preserves it.
NET_BW = 25e9
COLL_BYTES_PER_TOKEN = 0.4e6
SLO_TTFT_S = 1.0
SLO_TPOT_S = 0.020


def _sim_arm(arbiter: str, load: float, n_agents: int):
    from repro.core.config import NetworkConfig
    from repro.sim import DS_660B, HOPPER_NODE, Sim, SimConfig, \
        generate_dataset
    trajs = generate_dataset(n_agents, 32768, seed=0)
    cfg = SimConfig(node=HOPPER_NODE, model=DS_660B, P=1, D=2,
                    mode="dualpath",
                    net=NetworkConfig(
                        net_bw=NET_BW, net_arbiter=arbiter,
                        collective_bytes_per_token=COLL_BYTES_PER_TOKEN,
                        net_bg_load=load))
    sim = Sim(cfg, trajs).run()
    r = sim.results()
    r["slo"] = sim.slo_attainment(SLO_TTFT_S, SLO_TPOT_S)
    return r


def _serving_identity(arbiter: str):
    """Blocking vs pipelined on the real-bytes runtime with collectives
    on the finite network: tokens must stay bit-identical (the PR 3
    invariant) and the contention-aware clock must account stalls."""
    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving import ServingSystem
    from repro.sim.spec import REDUCED_TEST_NODE
    from repro.sim.traces import Round, Trajectory

    cfg = get_config("qwen1.5-0.5b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    out = {}
    for arm in ("blocking", "pipelined"):
        # heterogeneous sessions desynchronise the phases, so reads and
        # PD transfers genuinely share ticks with stepping engines —
        # the co-occurrence the contention model resolves
        trajs = [Trajectory(i, [Round(24 + 8 * i, 4 + 2 * i),
                                Round(16 + 4 * i, 4), Round(8, 4)])
                 for i in range(4)]
        from repro.core.config import NetworkConfig
        sys_ = ServingSystem(cfg, params, n_pe=1, n_de=2, block_tokens=16,
                             max_seq=200, de_slots=2, seed=0,
                             split_reads=True,
                             pipelined=(arm == "pipelined"),
                             node=REDUCED_TEST_NODE,
                             net=NetworkConfig(net_arbiter=arbiter,
                                               collective_group_size=8))
        sessions = sys_.run_offline(trajs)
        out[arm] = dict(tokens=[s.context for s in sessions],
                        st=sys_.stats())
    return out


def run(quick: bool = False, smoke: bool = False):
    # the FIFO arm's backlog (and its collective dilution) builds over
    # the run, so the workload must be long enough for the interference
    # to develop — 16 agents is the smallest size where the top-load
    # FIFO stall is unambiguous
    n_agents = 16
    loads = (0.0, 0.9) if smoke else (0.0, 0.5, 0.9)

    res = {}
    for arbiter in ("vl", "fifo"):
        for load in loads:
            with timed(f"fig_interference/{arbiter}/load{load:g}") as box:
                r = _sim_arm(arbiter, load, n_agents)
                res[(arbiter, load)] = r
                box["derived"] = (
                    f"stall={r['collective_stall_s']:.3f}s "
                    f"backlog={r['transfer_backlog_s']:.1f}s "
                    f"ttft={r['ttft_mean']:.3f}s "
                    f"tpot={r['tpot_mean'] * 1e3:.2f}ms "
                    f"slo={r['slo']:.3f}")

    # ---- serving runtime under the finite network -----------------------
    ident = {}
    for arbiter in ("vl", "fifo"):
        with timed(f"fig_interference/serving/{arbiter}") as box:
            ident[arbiter] = _serving_identity(arbiter)
            st_p = ident[arbiter]["pipelined"]["st"]
            box["derived"] = (
                f"stall={st_p['collective_stall_s']:.4f}s "
                f"backlog={st_p['transfer_backlog_s']:.4f}s "
                f"congestion={st_p['net_congestion']:.2f}")

    # ---- acceptance ------------------------------------------------------
    top = max(loads)
    for load in loads:
        vl, fifo = res[("vl", load)], res[("fifo", load)]
        assert vl["finished_agents"] == n_agents
        assert fifo["finished_agents"] == n_agents
        # the claim: with the VL arbiter model execution never stalls on
        # cache movement — at ANY transfer load
        assert vl["collective_stall_s"] <= 0.01 * vl["sim_time"], \
            (load, vl["collective_stall_s"], vl["sim_time"])
        assert vl["slo"] >= fifo["slo"] - 1e-9, (load, vl["slo"],
                                                 fifo["slo"])
    # the ablation: FIFO sharing lets transfer load starve collectives
    vl_top, fifo_top = res[("vl", top)], res[("fifo", top)]
    assert fifo_top["collective_stall_s"] > \
        max(10 * vl_top["collective_stall_s"], 5.0), \
        (fifo_top["collective_stall_s"], vl_top["collective_stall_s"])
    assert fifo_top["slo"] < vl_top["slo"], (fifo_top["slo"], vl_top["slo"])
    # token identity (PR 3) survives the finite network, both arbiters
    for arbiter, arms in ident.items():
        assert arms["pipelined"]["tokens"] == arms["blocking"]["tokens"], \
            f"{arbiter}: pipelined generation diverged from blocking"
    # the serving clock sees real contention and charges FIFO more
    for arm in ("blocking", "pipelined"):
        vl_st = ident["vl"][arm]["st"]["collective_stall_s"]
        fifo_st = ident["fifo"][arm]["st"]["collective_stall_s"]
        assert fifo_st > 0 and fifo_st > vl_st, (arm, vl_st, fifo_st)

    emit("fig_interference/acceptance", 0.0,
         f"ok: vl stall {vl_top['collective_stall_s']:.3f}s ~ 0; "
         f"slo@load{top:g} vl {vl_top['slo']:.3f} >= fifo "
         f"{fifo_top['slo']:.3f}; fifo stall "
         f"{fifo_top['collective_stall_s']:.1f}s; tokens identical")
    # headline metrics for the CI perf gate (benchmarks/perf_gate.py)
    return {
        "vl_collective_stall_s": vl_top["collective_stall_s"],
        "vl_slo_at_top_load": vl_top["slo"],
        "fifo_slo_at_top_load": fifo_top["slo"],
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run that asserts the acceptance "
                         "criteria and exits nonzero on violation")
    args = ap.parse_args(argv)
    header()
    run(quick=args.quick, smoke=args.smoke)
    if args.smoke:
        print("fig_interference smoke: PASS", file=sys.stderr)


if __name__ == "__main__":
    main()
