"""Online SLO layer (PR 10): arrival-rate sweep of the three serving
mechanisms behind ``SloConfig`` — admission control, chunked prefill,
SLO-class scheduling — individually and composed.

Motivation: BENCH_8's ``fig_online_serving.slo_attainment`` sits at
0.17 — the serving stack admits everything, packs whole prompts, and
treats a human-facing round and a background sweep identically.  This
figure runs the event simulator at paper scale (DS 660B on a Hopper
node, 1 PE / 2 DEs, dualpath) under a mixed Poisson workload:

* **interactive** half — short-prompt agents (6 k ctx, appends x0.5),
  SLO TTFT <= 0.5 s;
* **batch** half — long-prompt agents (16 k ctx, appends x2.0) whose
  re-reads + prefills oversubscribe the PE.

Arms (all knobs live in ``repro.core.config.SloConfig``):

* ``baseline``     — the pre-PR system: everything structurally off.
* ``+admission``   — the load-aware gate defers/rejects rounds whose
  queueing-delay-aware TTFT estimate already blows the SLO.
* ``+chunked``     — ``prefill_chunk_tokens`` slices long prompts so
  a multi-second forward batch can no longer head-of-line block.
* ``+classes``     — ``class_aware`` priority in every queue an
  interactive round crosses (global queue, SNIC read queue, PE fifo).
  Alone it is bounded by batch granularity: priority cannot preempt a
  forward batch already in flight, so its headline contribution is
  small — but composed with chunking (which creates the preemption
  points) it pins interactive TTFT p99 inside the SLO.
* ``all``          — the three composed.

Acceptance, asserted in ``--smoke`` mode (CI):

* the composed arm's attainment is >= 3x the motivating 0.17 (>= 0.51)
  at the headline arrival rate;
* every mechanism arm >= baseline (no mechanism hurts);
* the composed arm's interactive TTFT p99 is inside the SLO.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core.config import SloConfig
from repro.sim import DS_660B, HOPPER_NODE, Sim, SimConfig
from repro.sim.traces import generate_dataset

from benchmarks.common import emit, header, timed

SLO_TTFT_S = 0.5
SLO_TPOT_S = 0.050
HEADLINE_APS = 4.0
MOTIVATING_ATTAINMENT = 0.17        # BENCH_8 fig_online_serving
ADMISSION = dict(admission=True, admission_ttft_slo_s=SLO_TTFT_S,
                 admission_defer_s=0.25, admission_max_defers=12)
CHUNK = 512

ARMS = (
    ("baseline", None),
    ("admission", SloConfig(**ADMISSION)),
    ("chunked", SloConfig(prefill_chunk_tokens=CHUNK)),
    ("classes", SloConfig(class_aware=True)),
    ("all", SloConfig(prefill_chunk_tokens=CHUNK, class_aware=True,
                      **ADMISSION)),
)


def workload(n: int):
    """Half interactive (short ctx, light appends), half batch (long
    ctx, heavy appends) — the batch half's storage re-reads and long
    prefills are what oversubscribe the single PE."""
    inter = generate_dataset(n // 2, 6000, seed=1)
    batch = generate_dataset(n - n // 2, 16384, seed=2)
    trajs = []
    for t in inter:
        t = t.scaled(append_scale=0.5, gen_scale=0.4)
        t.slo_class = "interactive"
        trajs.append(t)
    for t in batch:
        t = t.scaled(append_scale=2.0, gen_scale=0.5)
        t.slo_class = "batch"
        trajs.append(t)
    for i, t in enumerate(trajs):
        t.tid = i
    return trajs


def run_arm(slo: SloConfig | None, aps: float, n: int):
    trajs = workload(n)
    rng = np.random.default_rng(0)
    arrivals = list(np.cumsum(rng.exponential(1 / aps, size=len(trajs))))
    kw = {} if slo is None else dict(slo=slo)
    cfg = SimConfig(node=HOPPER_NODE, model=DS_660B, P=1, D=2,
                    mode="dualpath", online=True, beta_compute_s=1.0, **kw)
    sim = Sim(cfg, trajs)
    sim.run(arrivals=arrivals)
    return sim.results(), sim.slo_attainment(SLO_TTFT_S, SLO_TPOT_S)


def run(quick: bool = False, smoke: bool = False):
    header()
    metrics = {}
    n = 384
    rates = (HEADLINE_APS,) if (quick or smoke) else (2.0, HEADLINE_APS, 6.0)
    for aps in rates:
        att = {}
        for name, slo in ARMS:
            with timed(f"fig_slo/aps{aps:g}/{name}") as box:
                r, a = run_arm(slo, aps, n)
                att[name] = a
                cls = {c: round(v["ttft_p99"], 2)
                       for c, v in r["latency_by_class"].items()}
                box["derived"] = (
                    f"att={a:.3f} fin={r['finished_rounds']} "
                    f"def={r['deferred_rounds']} rej={r['rejected_rounds']} "
                    f"chunks={r['prefill_chunks']} "
                    f"ttft_p99={r['ttft_p99']:.2f}s "
                    f"cls_ttft_p99={cls}")
            if aps == HEADLINE_APS:
                metrics[f"slo_attainment_{name}"] = a
                if name == "all":
                    metrics["slo_attainment"] = a
                    metrics["slo_interactive_ttft_p99_s"] = \
                        r["latency_by_class"]["interactive"]["ttft_p99"]
                    metrics["slo_rejected_rounds"] = float(
                        r["rejected_rounds"])
        emit(f"fig_slo/aps{aps:g}/summary", 0.0,
             " ".join(f"{k}={v:.3f}" for k, v in att.items()) +
             f" gain={att['all'] / max(att['baseline'], 1e-9):.2f}x")
        if aps == HEADLINE_APS:
            metrics["slo_gain"] = att["all"] / max(att["baseline"], 1e-9)
            if smoke:
                assert att["all"] >= 3 * MOTIVATING_ATTAINMENT, (
                    f"composed attainment {att['all']:.3f} < 3x the "
                    f"motivating {MOTIVATING_ATTAINMENT}")
                for name, _ in ARMS:
                    assert att[name] >= att["baseline"] - 1e-9, (
                        f"{name} ({att[name]:.3f}) regresses baseline "
                        f"({att['baseline']:.3f})")
                assert (metrics["slo_interactive_ttft_p99_s"]
                        <= SLO_TTFT_S), (
                    f"composed interactive TTFT p99 "
                    f"{metrics['slo_interactive_ttft_p99_s']:.2f}s "
                    f"outside the {SLO_TTFT_S}s SLO")
    return metrics


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args(argv)
    run(quick=args.quick, smoke=args.smoke)


if __name__ == "__main__":
    main()
