"""Raw simulator engine throughput: events/sec, both engines.

A single mid-size saturated configuration (one shared link carrying a
few hundred standing flows) is run through the per-object event engine
(``Sim``) and the struct-of-arrays engine (``VectorSim``) on the
identical workload.  Reported rates are *event-equivalent*: both
engines are normalized by the per-object engine's processed event
count, so the vectorized rate reads as "events the per-object engine
would have needed, per wall second" — the honest apples-to-apples
number (the pool replaces per-flow check events with one boundary
event, so its own ``n_events`` is deliberately far smaller).

The fleet-scale operating points (and the gated >=50x headline) live in
``fig_fleet``; this microbench is the small fast canary that catches
engine-level throughput regressions without a multi-minute run.
"""
import argparse
import os
import sys
import time

if __package__ in (None, ""):
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks.common import emit, header
from repro.sim import DS_660B, HOPPER_NODE, Sim, SimConfig, VectorSim
from repro.sim.traces import generate_dataset

def _same(a, b):
    if isinstance(a, float) or isinstance(b, float):
        return a == b or (a != a and b != b)          # NaN == NaN
    return a == b


N_ENGINES = 10
N_AGENTS = 60
ARRIVAL_WINDOW_S = 4.0
HORIZON_S = 12.0
BW_PER_ENGINE = 1e9          # ~saturated: flows pile onto the link
BG_LOAD = 0.8
BG_CHUNK = 64e6
MAX_LEN = 8192


def _workload(seed=0):
    P = max(1, N_ENGINES // 4)
    from repro.core.config import NetworkConfig
    cfg = SimConfig(node=HOPPER_NODE, model=DS_660B,
                    P=P, D=N_ENGINES - P,
                    nodes_per_pe_group=1, nodes_per_de_group=1,
                    split_reads=True,
                    net=NetworkConfig(net_bw=BW_PER_ENGINE * N_ENGINES,
                                      net_bg_load=BG_LOAD,
                                      net_bg_chunk_bytes=BG_CHUNK))
    trajs = generate_dataset(N_AGENTS, MAX_LEN, seed=seed)
    step = ARRIVAL_WINDOW_S / max(N_AGENTS - 1, 1)
    arrivals = [i * step for i in range(N_AGENTS)]
    return cfg, trajs, arrivals


def _run(engine_cls, cfg, trajs, arrivals):
    t0 = time.perf_counter()
    sim = engine_cls(cfg, trajs).run(arrivals=list(arrivals),
                                     until=HORIZON_S)
    return sim, time.perf_counter() - t0


def run(quick=False, smoke=False):
    header()
    cfg, trajs, arrivals = _workload()
    esim, e_wall = _run(Sim, cfg, trajs, arrivals)
    vsim, v_wall = _run(VectorSim, cfg, trajs, arrivals)
    n_ev = esim.loop.n_events
    e_rate = n_ev / e_wall
    v_rate = n_ev / v_wall
    speedup = e_wall / v_wall
    emit("micro_event_engine", e_wall / n_ev * 1e6,
         f"{e_rate:,.0f} ev/s over {n_ev} events")
    emit("micro_vector_engine", v_wall / n_ev * 1e6,
         f"{v_rate:,.0f} event-equiv/s ({vsim.loop.n_events} own events)")
    emit("micro_speedup", 0.0, f"{speedup:.1f}x")
    if smoke:
        re_, rv = esim.results(), vsim.results()
        bad = [k for k in sorted(set(re_) | set(rv))
               if not _same(re_.get(k), rv.get(k))]
        assert not bad, ("engine results diverged on the microbench "
                         f"workload: {bad}")
        assert speedup > 1.0, f"vectorized engine slower ({speedup:.2f}x)"
    return {"micro_event_rate_ev_s": e_rate,
            "micro_vec_rate_ev_s": v_rate,
            "micro_speedup": speedup}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args(argv)
    run(quick=args.quick, smoke=args.smoke)


if __name__ == "__main__":
    main()
