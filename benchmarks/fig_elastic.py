"""Elastic PE<->DE role reconfiguration under a bursty two-phase load
(the abstract's "dynamically balances load across prefill and decode
engines", made a measurement).

The workload has two phases on 4 nodes: a prefill-heavy burst (agents
submitting large appends with tiny generations) followed by a
decode-heavy steady state (small appends, long generations, enough
concurrent sequences that decode is HBM-capacity-bound and scales with
the DE count).  A static topology must provision for the worst phase:

* ``3P1D`` is right for the burst and starves the steady state;
* ``1P3D`` is right for the steady state and crawls through the burst.

The elastic arm starts at the balanced ``2P2D`` and lets the control
loop (core/autoscale.py: hysteresis PDController + safe drain protocol)
converge to each phase's ratio — DE->PE during the burst, PE->DE twice
once decode pressure dominates — so it beats BOTH static arms on
total-token throughput.

Acceptance signals, asserted in ``--smoke`` mode (CI):

* every arm finishes the full workload;
* elastic throughput >= each static arm's throughput;
* the elastic arm reconfigured in *both* directions and ended
  decode-heavy (n_de_final > n_pe_final);
* on the real-bytes serving runtime, ``elastic=True`` generates
  bit-identical tokens to ``elastic=False`` (role flips may change
  timing, never generation) while performing at least one live role
  flip with a nonzero drain-protocol latency.
"""
from __future__ import annotations

import argparse
import os
import sys
from dataclasses import replace

if __package__ in (None, ""):       # direct `python benchmarks/<file>.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks.common import emit, header, timed

# Two-phase operating point (see module docstring).  kv_hbm_frac is
# tightened so phase 2's decode is HBM-capacity-bound — waves of ~83
# concurrent sequences per DE — which is what makes the DE count matter
# (with abundant HBM a single DE batches everything and the PD ratio is
# irrelevant to decode throughput).
N_BURST = 96            # phase-1 agents: one (append=8192, gen=8) round
N_STEADY = 240          # phase-2 agents: one (append=64, gen=1024) round
T_STEADY_S = 60.0       # phase-2 arrival time
KV_HBM_FRAC = 0.04
RECONFIG_INTERVAL_S = 4.0


def _workload():
    from repro.sim.traces import Round, Trajectory
    burst = [Trajectory(i, [Round(8192, 8)]) for i in range(N_BURST)]
    steady = [Trajectory(1000 + i, [Round(64, 1024)])
              for i in range(N_STEADY)]
    arrivals = [0.0] * N_BURST + [T_STEADY_S] * N_STEADY
    return burst + steady, arrivals


def _sim_arm(P: int, D: int, elastic: bool, trajs, arrivals,
             drain_policy: str = "idlest"):
    from repro.core.config import ElasticConfig
    from repro.sim import DS_660B, HOPPER_NODE, Sim, SimConfig
    cfg = SimConfig(node=replace(HOPPER_NODE, g=1), model=DS_660B,
                    P=P, D=D, mode="dualpath",
                    nodes_per_pe_group=1, nodes_per_de_group=1,
                    kv_hbm_frac=KV_HBM_FRAC,
                    elastic=ElasticConfig(
                        enabled=elastic, drain_policy=drain_policy,
                        reconfig_interval_s=RECONFIG_INTERVAL_S,
                        reconfig_patience=2))
    sim = Sim(cfg, trajs).run(arrivals=arrivals)
    r = sim.results()
    r["tput"] = (r["prompt_tokens"] + r["gen_tokens"]) / r["sim_time"]
    return r


def _serving_identity():
    """elastic=True vs elastic=False on the real-bytes runtime: role
    flips must be invisible to generation (bit-identical tokens) while
    the elastic arm performs at least one live engine flip."""
    import jax
    from repro.configs import get_config
    from repro.core.config import ElasticConfig
    from repro.models import init_params
    from repro.serving import ServingSystem
    from repro.sim.spec import REDUCED_TEST_NODE
    from repro.sim.traces import Round, Trajectory

    cfg = get_config("qwen1.5-0.5b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    # a miniature two-phase shape: prefill-heavy rounds, then
    # decode-heavy rounds that queue on de_slots=1 and pull the
    # controller toward PE->DE
    trajs = [Trajectory(i, [Round(64, 1)]) for i in range(3)] + \
            [Trajectory(10 + i, [Round(4, 16)]) for i in range(3)]
    arrivals = [0.0] * 3 + [1.5] * 3
    out = {}
    for arm in ("static", "elastic"):
        sys_ = ServingSystem(cfg, params, n_pe=2, n_de=2, block_tokens=16,
                             max_seq=96, de_slots=1, seed=0, pipelined=True,
                             node=REDUCED_TEST_NODE,
                             elastic=ElasticConfig(
                                 enabled=(arm == "elastic"),
                                 reconfig_interval_s=0.05,
                                 reconfig_patience=2,
                                 reconfig_idle_floor_s=1e-4))
        sessions = sys_.run_online(trajs, arrivals)
        out[arm] = dict(tokens=[s.context for s in sessions],
                        st=sys_.stats())
    return out


def run(quick: bool = False, smoke: bool = False):
    trajs, arrivals = _workload()
    arms = {"3P1D": (3, 1, False), "1P3D": (1, 3, False),
            "2P2D+elastic": (2, 2, True)}
    res = {}
    for name, (P, D, elastic) in arms.items():
        with timed(f"fig_elastic/{name}") as box:
            r = _sim_arm(P, D, elastic, trajs, arrivals)
            res[name] = r
            box["derived"] = (
                f"tput={r['tput']:.0f}tok/s t={r['sim_time']:.0f}s "
                f"flips={r['role_changes']} "
                f"final={r['n_pe_final']}P{r['n_de_final']}D "
                f"drain={r['reconfig_drain_s']:.1f}s")
    if not (quick or smoke):
        # victim-selection ablation rides along at full size
        with timed("fig_elastic/2P2D+elastic/rotate") as box:
            r = _sim_arm(2, 2, True, trajs, arrivals,
                         drain_policy="rotate")
            res["rotate"] = r
            box["derived"] = (f"tput={r['tput']:.0f}tok/s "
                              f"flips={r['role_changes']}")

    with timed("fig_elastic/serving_identity") as box:
        ident = _serving_identity()
        st_e = ident["elastic"]["st"]
        box["derived"] = (
            f"flips={st_e['role_changes']} "
            f"final={st_e['n_pe_final']}P{st_e['n_de_final']}D "
            f"drain={st_e['reconfig_drain_s']:.2f}s "
            f"weight={st_e['reconfig_weight_bytes']:.0f}B")

    # ---- acceptance ------------------------------------------------------
    n_agents = len(trajs)
    for name, r in res.items():
        assert r["finished_agents"] == n_agents, (name,
                                                  r["finished_agents"])
    el, s31, s13 = res["2P2D+elastic"], res["3P1D"], res["1P3D"]
    # the claim: one elastic deployment >= every static provisioning
    assert el["tput"] >= s31["tput"], (el["tput"], s31["tput"])
    assert el["tput"] >= s13["tput"], (el["tput"], s13["tput"])
    # ...by actually adapting: flips in both directions, ending
    # decode-heavy for the steady state
    dirs = el["role_changes_by_direction"]
    assert dirs["de->pe"] >= 1 and dirs["pe->de"] >= 1, dirs
    assert el["n_de_final"] > el["n_pe_final"], (el["n_pe_final"],
                                                 el["n_de_final"])
    assert el["reconfig_drain_s"] > 0 and el["reconfig_weight_bytes"] > 0
    # statics must not have reconfigured
    assert s31["role_changes"] == 0 and s13["role_changes"] == 0
    # serving runtime: flips change timing, never generation
    assert ident["elastic"]["tokens"] == ident["static"]["tokens"], \
        "elastic serving generation diverged from static"
    st_e = ident["elastic"]["st"]
    assert st_e["role_changes"] >= 1 and st_e["reconfig_drain_s"] > 0, \
        (st_e["role_changes"], st_e["reconfig_drain_s"])
    assert ident["static"]["st"]["role_changes"] == 0

    gain = el["tput"] / max(s31["tput"], s13["tput"])
    emit("fig_elastic/acceptance", 0.0,
         f"ok: elastic {el['tput']:.0f}tok/s >= static max "
         f"{max(s31['tput'], s13['tput']):.0f} (x{gain:.2f}); "
         f"flips {dirs['de->pe']}+{dirs['pe->de']} -> "
         f"{el['n_pe_final']}P{el['n_de_final']}D; serving tokens "
         f"identical with {st_e['role_changes']} live flip(s)")
    return {
        "elastic_tput_tok_s": el["tput"],
        "static_best_tput_tok_s": max(s31["tput"], s13["tput"]),
        "elastic_gain": gain,
        "role_changes": float(el["role_changes"]),
        "reconfig_drain_s": el["reconfig_drain_s"],
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run that asserts the acceptance "
                         "criteria and exits nonzero on violation")
    args = ap.parse_args(argv)
    header()
    run(quick=args.quick, smoke=args.smoke)
    if args.smoke:
        print("fig_elastic smoke: PASS", file=sys.stderr)


if __name__ == "__main__":
    main()
