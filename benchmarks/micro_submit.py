"""§5.2 micro-benchmark: transfer-submission cost — CNIC RDMA WR with
doorbell batching vs per-op submission vs cudaMemcpyAsync model, plus
measured wall time of the TrafficManager fast path."""
from __future__ import annotations

import time

from repro.core.traffic import SubmitCostModel, TrafficClass, TrafficManager

from benchmarks.common import emit


def run(quick: bool = False):
    c = SubmitCostModel()
    n = 4096
    emit("micro/submit/cuda-memcpy-model", c.cuda_seconds(n) / n * 1e6,
         f"{n} chunks (paper 5-7us each)")
    emit("micro/submit/rdma-unbatched-model",
         c.rdma_unbatched_seconds(n) / n * 1e6, f"{n} WRs")
    emit("micro/submit/rdma-doorbell-batched-model",
         c.rdma_batch_seconds(n) / n * 1e6,
         f"{n} WRs, one doorbell (paper ~1us/WR amortised)")

    # measured: TrafficManager queue/drain overhead per transfer
    tm = TrafficManager(doorbell_batch=64)
    nops = 20000
    t0 = time.perf_counter()
    for i in range(nops):
        tm.submit(lambda: None, 4096, TrafficClass.KV_TRANSFER)
    tm.drain()
    dt = (time.perf_counter() - t0) / nops * 1e6
    emit("micro/submit/traffic-manager-measured", dt,
         f"python-side submit+drain per op ({nops} ops)")


if __name__ == "__main__":
    run()
