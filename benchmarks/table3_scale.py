"""Table 3: large-scale scalability — JCT parity between one 2P4D unit
with 2K agents and N units with N×2K agents (paper: 48 units, 1152 GPUs,
3167 s vs 3201 s).

Simulating 48K agents × 100+ rounds is ~75 M events; the default run
scales the experiment down (unit → 8 units) and checks the same
property: JCT stays flat as units and agents scale together.  Pass
``--full`` (env BENCH_FULL=1) for the 48-unit point.
"""
from __future__ import annotations

import os

from repro.sim import DS_660B, HOPPER_NODE, Sim, SimConfig
from repro.sim.traces import generate_dataset

from benchmarks.common import emit, timed


def run(quick: bool = False):
    full = os.environ.get("BENCH_FULL") == "1"
    agents_per_unit = 64 if quick else 128
    units = (1, 4) if quick else ((1, 8, 48) if full else (1, 4, 8))
    jcts = {}
    for u in units:
        trajs = generate_dataset(agents_per_unit * u, 32768, seed=0)
        cfg = SimConfig(node=HOPPER_NODE, model=DS_660B, P=2 * u, D=4 * u,
                        mode="dualpath",
                        nodes_per_pe_group=2, nodes_per_de_group=4)
        with timed(f"table3/units{u}/agents{len(trajs)}") as box:
            r = Sim(cfg, trajs).run().results()
            jcts[u] = r["jct_max"]
            box["derived"] = (f"engines={(2 + 4) * u * 8} "
                              f"jct={r['jct_max']:.0f}s "
                              f"tpot={r['tpot_mean'] * 1e3:.1f}ms")
    base = jcts[units[0]]
    worst = max(abs(jcts[u] - base) / base for u in units)
    emit("table3/summary", 0.0,
         f"jct_spread={100 * worst:.1f}% across {units} units "
         f"(paper: 3167s vs 3201s = 1.1%)")


if __name__ == "__main__":
    run()
