"""Table 1: cache-compute ratio (GB of KV to load per PFLOP of compute),
append length 429, across context lengths 16k–64k.

Paper targets:
    Qwen2.5-32B (FP16)   117–267
    GPT-OSS-120B          47–95
    Qwen3-235B-A22B       39–60
    DeepSeek-V3.2 660B    13–36
    DeepSeek-V3 660B     4.8–5.8
plus the ten assigned architectures (bf16 KV, TPU target) for context.
"""
from __future__ import annotations

from repro.configs import ARCH_IDS, get_config
from repro.sim.spec import ModelSimSpec

from benchmarks.common import timed

# analytic descriptors of the paper's Table 1 models -----------------------
TABLE1_MODELS = {
    # Qwen2.5-32B, GQA kv=8 hd=128, 64L, FP16 KV
    "qwen2.5-32b-fp16": ModelSimSpec(
        name="qwen2.5-32b", n_layers=64,
        kv_bytes_per_token=64 * 2 * 8 * 128 * 2,
        active_param_bytes=65.6e9, active_params=32.8e9,
        n_heads=40, qk_head_dim=128),
    # GPT-OSS-120B: 36L, GQA kv=8 hd=64, a5.1b, fp8 KV, sliding-window half
    "gpt-oss-120b": ModelSimSpec(
        name="gpt-oss-120b", n_layers=36,
        kv_bytes_per_token=36 * 2 * 8 * 64 * 1,
        active_param_bytes=5.1e9, active_params=5.1e9,
        n_heads=64, qk_head_dim=64),
    # Qwen3-235B-A22B: 94L, GQA kv=4 hd=128, fp8 KV
    "qwen3-235b-a22b": ModelSimSpec(
        name="qwen3-235b", n_layers=94,
        kv_bytes_per_token=94 * 2 * 4 * 128 * 1,
        active_param_bytes=22e9, active_params=22e9,
        n_heads=64, qk_head_dim=128),
    # DeepSeek-V3.2 (DSA topk 2048 + lightning indexer ~0.6 MFLOP/ctx
    # token), MLA absorbed scores (rank 512 + rope 64 = 576 dims), fp8 KV
    "ds-v3.2-660b": ModelSimSpec(
        name="ds-v3.2", n_layers=61,
        kv_bytes_per_token=61 * (512 + 64) * 1,
        active_param_bytes=37e9, active_params=37e9,
        n_heads=128, qk_head_dim=576, sparse_topk=2048,
        linear_ctx_flops=0.6e6),
    # DeepSeek-V3 (dense MLA attention, absorbed scores)
    "ds-v3-660b": ModelSimSpec(
        name="ds-v3", n_layers=61,
        kv_bytes_per_token=61 * (512 + 64) * 1,
        active_param_bytes=37e9, active_params=37e9,
        n_heads=128, qk_head_dim=576),
}

PAPER_RANGES = {
    "qwen2.5-32b-fp16": (117, 267),
    "gpt-oss-120b": (47, 95),
    "qwen3-235b-a22b": (39, 60),
    "ds-v3.2-660b": (13, 36),
    "ds-v3-660b": (4.8, 5.8),
}

APPEND = 429


def run():
    for name, spec in TABLE1_MODELS.items():
        with timed(f"table1/{name}") as box:
            r16 = spec.cache_compute_ratio(16 * 1024, APPEND)
            r64 = spec.cache_compute_ratio(64 * 1024, APPEND)
            lo, hi = PAPER_RANGES[name]
            box["derived"] = (f"GB/PFLOP[16k-64k]={r16:.1f}-{r64:.1f} "
                              f"(paper {lo}-{hi})")
    # assigned archs (bf16 KV on TPU target)
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        spec = ModelSimSpec.from_config(cfg)
        with timed(f"table1/assigned/{arch}") as box:
            r16 = spec.cache_compute_ratio(16 * 1024, APPEND)
            r64 = spec.cache_compute_ratio(64 * 1024, APPEND)
            box["derived"] = f"GB/PFLOP[16k-64k]={r16:.1f}-{r64:.1f}"


if __name__ == "__main__":
    run()
