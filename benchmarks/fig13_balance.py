"""Fig. 13/14: load balance — storage-NIC traffic Max/Avg (adaptive vs
round-robin; paper 1.18 vs 1.53) and attention-time Max/Avg within an
EP group during the busy phase (paper ≤ 1.06)."""
from __future__ import annotations

import numpy as np

from repro.sim import DS_660B, HOPPER_NODE, Sim, SimConfig
from repro.sim.traces import generate_dataset

from benchmarks.common import emit, timed


def nic_balance(sim, window=10.0):
    """Mean over time windows of max/avg traffic across storage NICs,
    during the busy phase (first 60% of makespan, as in the paper)."""
    end = sim.loop.now * 0.6
    buckets = {}
    for node, nic in sim.snic.items():
        for t, b in nic.samples:
            if t > end:
                continue
            w = int(t / window)
            buckets.setdefault(w, {}).setdefault(node, 0)
            buckets[w][node] += b
    ratios = []
    n_nodes = len(sim.snic)
    for w, per_node in buckets.items():
        vals = [per_node.get(n, 0) for n in range(n_nodes)]
        if sum(vals) == 0:
            continue
        ratios.append(max(vals) / (np.mean(vals) + 1e-9))
    return float(np.mean(ratios)) if ratios else float("nan")


def attn_balance(sim):
    """Max/Avg attention time across engines per forward, early phase."""
    if not sim.attn_balance:
        return float("nan")
    end = sim.loop.now * 0.05
    vals = [r for t, r in sim.attn_balance if t <= end]
    if not vals:
        vals = [r for _, r in sim.attn_balance]
    return float(np.mean(vals))


def run(quick: bool = False):
    n_agents = 192 if quick else 512
    trajs = generate_dataset(n_agents, 32768, seed=0)
    res = {}
    for sched in ("adaptive", "rr"):
        cfg = SimConfig(node=HOPPER_NODE, model=DS_660B, P=1, D=2,
                        mode="dualpath", scheduler=sched)
        with timed(f"fig13/nic-balance/{sched}") as box:
            sim = Sim(cfg, trajs).run()
            res[sched] = nic_balance(sim)
            box["derived"] = f"max/avg={res[sched]:.2f}"
            if sched == "adaptive":
                ab = attn_balance(sim)
                emit("fig14/attn-balance/adaptive", 0.0,
                     f"max/avg={ab:.3f} (paper <=1.06 early phase)")
    emit("fig13/summary", 0.0,
         f"adaptive={res['adaptive']:.2f} rr={res['rr']:.2f} "
         f"(paper 1.18 vs 1.53)")


if __name__ == "__main__":
    run()
