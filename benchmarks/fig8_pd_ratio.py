"""Fig. 8: impact of the prefill-decode ratio (DS 27B: 1P1D, 2P1D, 1P2D).

Paper observations to reproduce:
  * DualPath wins at every ratio (avg 1.64×, up to 2.46×),
  * Basic 1P1D ≈ Basic 1P2D (same PE-side storage bandwidth),
  * DualPath 1P1D ≈ Basic 2P1D (2 SNICs each),
  * DualPath 2P1D ≈ DualPath 1P2D (3 SNICs each).
"""
from __future__ import annotations

from repro.configs import get_config
from repro.sim import HOPPER_NODE, Sim, SimConfig
from repro.sim.spec import ModelSimSpec
from repro.sim.traces import generate_dataset

from benchmarks.common import emit, timed

DS27B = ModelSimSpec.from_config(get_config("ds27b"), kv_dtype_bytes=1,
                                 param_dtype_bytes=1)


def run(quick: bool = False):
    n_agents = 128 if quick else 384
    trajs = generate_dataset(n_agents, 32768, seed=0)
    jct = {}
    for P, D in ((1, 1), (2, 1), (1, 2)):
        for mode in ("basic", "dualpath"):
            cfg = SimConfig(node=HOPPER_NODE, model=DS27B, P=P, D=D,
                            mode=mode)
            with timed(f"fig8/ds27b/{P}P{D}D/{mode}") as box:
                r = Sim(cfg, trajs).run().results()
                jct[(P, D, mode)] = r["jct_max"]
                box["derived"] = f"jct={r['jct_max']:.0f}s"
    sp = [jct[(p, d, 'basic')] / jct[(p, d, 'dualpath')]
          for p, d in ((1, 1), (2, 1), (1, 2))]
    emit("fig8/summary", 0.0,
         f"speedups={['%.2f' % s for s in sp]} avg={sum(sp)/3:.2f} "
         f"(paper avg 1.64 up to 2.46); "
         f"basic1P1D/basic1P2D={jct[(1,1,'basic')]/jct[(1,2,'basic')]:.2f} "
         f"dp1P1D/basic2P1D={jct[(1,1,'dualpath')]/jct[(2,1,'basic')]:.2f} "
         f"dp2P1D/dp1P2D={jct[(2,1,'dualpath')]/jct[(1,2,'dualpath')]:.2f}")


if __name__ == "__main__":
    run()
