"""Fleet-scale sweep: 10 -> 1000 engines on the vectorized event engine.

The per-object simulator (``repro.sim.Sim``) prices a processor-sharing
reshare at O(k) Python — one settle plus one heap event per affected
flow — so a fleet-sized shared link carrying thousands of concurrent
KV transfers makes the *simulator* the bottleneck long before the
modelled system is.  :class:`repro.sim.VectorSim` replaces that loop
with struct-of-arrays kernels (sim/vectorized.py) while keeping the
results contract bit-for-bit; this benchmark is the scale demonstration
and the perf gate for both halves of that claim.

Two operating points, both with power-law (Zipf) multi-tenant arrivals:

* **serving sweep** — E in {10, 100, 1000} engines (P:D = 1:3, one
  engine per node), per-engine provisioning held constant so the shared
  link grows linearly with E.  Run on the vectorized engine to a fixed
  sim horizon; reports fleet SLO attainment (TTFT <= SLO_TTFT_S) and
  generation throughput per engine count — the fleet SLO/throughput
  curves.
* **burst point** — E = 100 under an agentic incast: every tenant's
  agents arrive inside a few seconds and the fleet link is ~3x
  oversubscribed, so in-flight transfers ramp to several thousand.
  BOTH engines simulate the identical bounded horizon (``until=``):
  ``results()`` must agree key-for-key (the at-scale equivalence
  check), and the wall-clock ratio is the headline
  ``fleet_speedup_100`` (target >= 50x).  ``sim_events_per_sec`` is
  the event-equivalent simulation rate of the vectorized engine: the
  per-object engine's processed-event count for the horizon divided by
  the vectorized engine's wall time.

Acceptance, asserted in ``--smoke`` mode (CI):

* the small-config equivalence matrix passes exactly (every counter,
  byte and time key identical between engines);
* the burst-point results agree between engines at E = 100;
* ``fleet_speedup_100 >= SPEEDUP_TARGET`` (50x) — asserted only when
  the benchmark runs in its own process (the dedicated CI ``fleet``
  job); a shared-process suite run records the metric but leaves
  gating to the perf trajectory bands (see ``run.py``);
* the E = 1000 serving point completes (``fleet_1000_done``).

Wall-clock-sensitive metrics (speedup, events/sec) gate with generous
absolute floors in benchmarks/perf_gate.py — they measure this
machine, not the model.
"""
from __future__ import annotations

import argparse
import gc
import os
import sys
import time

if __package__ in (None, ""):       # direct `python benchmarks/<file>.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks.common import emit, header

# --- fleet shape -----------------------------------------------------------
#: serving sweep: engines per point (P:D = 1:3, one engine per node)
ENGINES = (10, 100, 1000)
#: Zipf exponent for tenant arrival rates (heavy-tailed multi-tenancy)
ZIPF_S = 1.2
#: agents per engine and arrival window at the serving point
SERVE_AGENTS_PER_ENGINE = 2
SERVE_ARRIVAL_WINDOW_S = 20.0
SERVE_HORIZON_S = 60.0
#: per-engine shared-link provisioning at the serving point [B/s] —
#: constant per engine, so the fleet link scales linearly with E
SERVE_BW_PER_ENGINE = 25e9
SERVE_BG_LOAD = 0.5
#: context length and TTFT SLO for the fleet curves
MAX_LEN = 8192
SLO_TTFT_S = 20.0

#: burst point: an agentic incast at E = 100 — everything arrives in
#: BURST_ARRIVAL_WINDOW_S and the link is oversubscribed ~3x, so the
#: in-flight transfer population ramps into the thousands (the regime
#: where the per-object engine's O(k)-per-reshare cost explodes)
BURST_E = 100
BURST_AGENTS_PER_ENGINE = 24
BURST_ARRIVAL_WINDOW_S = 5.0
BURST_HORIZON_S = 8.0
BURST_BW_PER_ENGINE = 0.2e9
BURST_BG_LOAD = 0.9
BURST_BG_CHUNK = 64e6
#: coarser decode quota at the burst point: the shared scheduler tick
#: is identical Python in both engines, so a fine quota only dilutes
#: the drain-plane comparison the burst point exists to make
BURST_QUOTA_S = 1.0

SPEEDUP_TARGET = 50.0


def _fleet_cfg(E, bw_per_engine, bg_load, bg_chunk=512e6, **kw):
    from repro.core.config import NetworkConfig
    from repro.sim import DS_660B, HOPPER_NODE, SimConfig
    P = max(1, E // 4)
    return SimConfig(node=HOPPER_NODE, model=DS_660B, P=P, D=E - P,
                     nodes_per_pe_group=1, nodes_per_de_group=1,
                     split_reads=True,
                     net=NetworkConfig(net_bw=bw_per_engine * E,
                                       net_bg_load=bg_load,
                                       net_bg_chunk_bytes=bg_chunk),
                     **kw)


def _fleet_workload(E, agents_per_engine, window_s, seed=0):
    """Power-law multi-tenant arrivals: tenant t's arrival rate is
    proportional to 1/t^ZIPF_S, realised as a Zipf-weighted tenant
    assignment over a uniform arrival window — per-tenant volume is
    heavy-tailed while the merged process stays seed-deterministic."""
    import numpy as np
    from repro.sim import generate_dataset
    n = agents_per_engine * E
    trajs = generate_dataset(n, MAX_LEN, seed=seed)
    rng = np.random.default_rng(seed)
    n_tenants = max(4, E // 4)
    w = 1.0 / np.arange(1, n_tenants + 1, dtype=np.float64) ** ZIPF_S
    w /= w.sum()
    tenants = rng.choice(n_tenants, size=n, p=w)
    arrivals = np.sort(rng.uniform(0.0, window_s, n))
    return trajs, arrivals.tolist(), tenants


def _fleet_stats(sim, horizon_s):
    """SLO/throughput from the struct-of-arrays request table: rounds
    that finished inside the horizon count toward SLO (TTFT <=
    SLO_TTFT_S); throughput is generated tokens per modelled second."""
    import numpy as np
    t = sim.request_table()
    started = t["submit_t"] >= 0
    done = (t["done_t"] >= 0) & started
    ttft = t["first_decode_t"] - t["submit_t"]
    ok = done & (ttft <= SLO_TTFT_S)
    n_started = int(started.sum())
    gen = int(t["gen_tokens"][done].sum())
    return {
        "rounds_started": n_started,
        "rounds_done": int(done.sum()),
        "slo": float(ok.sum()) / max(n_started, 1),
        "tput_tok_s": gen / horizon_s,
    }


def _run_engine(engine_cls, cfg, trajs, arrivals, horizon_s):
    t0 = time.perf_counter()
    sim = engine_cls(cfg, trajs)
    sim.run(until=horizon_s, arrivals=list(arrivals))
    return sim, time.perf_counter() - t0


def _equivalence_matrix(quick):
    """Small-config engine-equivalence check: every results() key must
    match exactly (the full randomized matrix lives in
    tests/test_vectorized.py; this is the benchmark's own guard that
    the speedup being measured is a speedup of the *same* model)."""
    from repro.core.config import (NetworkConfig, ResilienceConfig,
                                   TierConfig)
    from repro.sim import (DS_660B, HOPPER_NODE, Sim, SimConfig,
                           VectorSim, generate_dataset)
    from repro.sim.faults import (FaultSchedule, SlowdownWindow,
                                  StragglerModel)
    faults = FaultSchedule(
        windows=[SlowdownWindow("snic", 5.0, 25.0, 3.0, node=0),
                 SlowdownWindow("net", 10.0, 14.0, 2.0)],
        straggler=StragglerModel(0.3, 4.0, seed=7))
    matrix = [
        ("dualpath", dict()),
        ("split+tier", dict(split_reads=True,
                            tier=TierConfig(dram_tier_bytes=64e9,
                                            prefetch=True))),
        ("net-vl-bg", dict(net=NetworkConfig(net_bw=400e9,
                                             net_bg_load=0.4))),
        ("net-fifo-bg", dict(net=NetworkConfig(net_bw=400e9,
                                               net_arbiter="fifo",
                                               net_bg_load=0.4))),
        ("faults", dict(resilience=ResilienceConfig(faults=faults),
                        net=NetworkConfig(net_bw=300e9, net_bg_load=0.3))),
        ("basic-rr", dict(mode="basic", scheduler="rr")),
    ]
    if quick:
        matrix = matrix[:3]
    n_agents = 6
    trajs = generate_dataset(n_agents, MAX_LEN, seed=3)
    for name, kw in matrix:
        cfg = SimConfig(node=HOPPER_NODE, model=DS_660B, P=1, D=2, **kw)
        r0 = Sim(cfg, trajs).run().results()
        r1 = VectorSim(cfg, trajs).run().results()
        keys = set(r0) | set(r1)
        bad = [k for k in sorted(keys)
               if not _same(r0.get(k), r1.get(k))]
        assert not bad, (
            f"equivalence[{name}]: engines disagree on "
            f"{[(k, r0.get(k), r1.get(k)) for k in bad]}")
    return len(matrix)


def _same(a, b):
    if isinstance(a, float) or isinstance(b, float):
        return a == b or (a != a and b != b)      # NaN == NaN
    return a == b


def run(quick: bool = False, smoke: bool = False):
    from repro.sim import Sim, VectorSim
    header()
    metrics = {}

    # --- engine equivalence guard -------------------------------------
    t0 = time.perf_counter()
    n_cfg = _equivalence_matrix(quick=quick or smoke)
    emit("fleet_equivalence_matrix", (time.perf_counter() - t0) * 1e6,
         f"{n_cfg} configs exact")

    # --- serving sweep (vectorized engine) ----------------------------
    horizon = SERVE_HORIZON_S / 2 if (quick or smoke) else SERVE_HORIZON_S
    engines = ENGINES if not quick else ENGINES[:2]
    for E in engines:
        cfg = _fleet_cfg(E, SERVE_BW_PER_ENGINE, SERVE_BG_LOAD,
                         bg_chunk=512e6 * max(E, 10) / 10.0)
        trajs, arrivals, _ = _fleet_workload(
            E, SERVE_AGENTS_PER_ENGINE, SERVE_ARRIVAL_WINDOW_S, seed=E)
        sim, wall = _run_engine(VectorSim, cfg, trajs, arrivals, horizon)
        st = _fleet_stats(sim, horizon)
        emit(f"fleet_serve_E{E}", wall * 1e6,
             f"slo={st['slo']:.3f} tput={st['tput_tok_s']:.0f}tok/s "
             f"peak_flows={sim.pool.peak_flows} "
             f"reshares={sim.pool.n_reshares}")
        metrics[f"fleet_slo_{E}"] = st["slo"]
        metrics[f"fleet_tput_{E}_tok_s"] = st["tput_tok_s"]
        if E == max(ENGINES):
            metrics["fleet_1000_done"] = 1.0
            if smoke:
                assert st["rounds_started"] > 0, \
                    "1000-engine point started no rounds"

    # --- burst point: both engines, identical horizon -----------------
    # Drop the serving sweep's heap (the 1000-engine sim holds GBs of
    # per-round objects) and freeze the survivors out of gen-2 scans:
    # the vectorized leg is a short allocation-heavy run, and full-heap
    # collections otherwise dominate its wall time while staying
    # invisible inside the ~100x-longer per-object leg — skewing the
    # exact ratio this section exists to measure.
    del sim, trajs, arrivals, cfg, st
    gc.collect()
    gc.freeze()
    E = BURST_E
    cfg = _fleet_cfg(E, BURST_BW_PER_ENGINE, BURST_BG_LOAD,
                     bg_chunk=BURST_BG_CHUNK, quota_s=BURST_QUOTA_S)
    trajs, arrivals, _ = _fleet_workload(
        E, BURST_AGENTS_PER_ENGINE, BURST_ARRIVAL_WINDOW_S, seed=1)
    horizon = BURST_HORIZON_S
    vsim, v_wall = _run_engine(VectorSim, cfg, trajs, arrivals, horizon)
    esim, e_wall = _run_engine(Sim, cfg, trajs, arrivals, horizon)
    rv, re_ = vsim.results(), esim.results()
    bad = [k for k in sorted(set(rv) | set(re_))
           if not _same(rv.get(k), re_.get(k))]
    assert not bad, (
        f"burst-point engines disagree: "
        f"{[(k, re_.get(k), rv.get(k)) for k in bad]}")
    speedup = e_wall / v_wall
    ev_s = esim.loop.n_events / v_wall
    emit(f"fleet_burst_E{E}_vec", v_wall * 1e6,
         f"peak_flows={vsim.pool.peak_flows} "
         f"reshares={vsim.pool.n_reshares}")
    emit(f"fleet_burst_E{E}_event", e_wall * 1e6,
         f"events={esim.loop.n_events}")
    emit("fleet_speedup", speedup, f"{speedup:.1f}x at E={E}; "
         f"event-equivalent {ev_s:,.0f} events/s")
    metrics["fleet_speedup_100"] = speedup
    metrics["sim_events_per_sec"] = ev_s
    # The hard >=50x wall-clock gate applies only to isolated runs (the
    # dedicated CI `fleet` job): inside a shared-process suite run
    # (run.py --smoke-all / perf_gate --collect) the heap left by
    # earlier benchmarks slows the short vectorized leg far more than
    # the ~200 s per-object leg, deflating the ratio for reasons that
    # have nothing to do with either engine.  Suite runs still record
    # the metric; the perf trajectory bands gate it suite-vs-suite.
    if smoke and os.environ.get("REPRO_BENCH_SUITE") != "1":
        assert speedup >= SPEEDUP_TARGET, (
            f"fleet speedup {speedup:.1f}x < {SPEEDUP_TARGET}x at "
            f"E={E} (vec {v_wall:.1f}s vs event {e_wall:.1f}s)")
    return metrics


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args(argv)
    run(quick=args.quick, smoke=args.smoke)


if __name__ == "__main__":
    main()
