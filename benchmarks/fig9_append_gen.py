"""Fig. 9: varying append / generation length scale (DS 660B, 64K).

Paper: with longer appends, Basic approaches DualPath/Oracle (compute
pressure dominates); DualPath keeps 1.82–1.99× at the paper's append
scales; the same holds for generation-length scaling."""
from __future__ import annotations

from repro.sim import DS_660B, HOPPER_NODE, Sim, SimConfig
from repro.sim.traces import generate_dataset

from benchmarks.common import emit, timed


def run(quick: bool = False):
    n_agents = 128 if quick else 512
    base = generate_dataset(n_agents, 65536, seed=0)
    for kind in ("append", "gen"):
        sp = []
        for scale in (0.5, 1.0, 2.0, 4.0):
            trajs = [t.scaled(append_scale=scale if kind == "append" else 1.0,
                              gen_scale=scale if kind == "gen" else 1.0,
                              max_len=65536) for t in base]
            jct = {}
            for mode in ("basic", "dualpath"):
                cfg = SimConfig(node=HOPPER_NODE, model=DS_660B, P=2, D=4,
                                mode=mode)
                with timed(f"fig9/{kind}x{scale}/{mode}") as box:
                    jct[mode] = Sim(cfg, trajs).run().results()["jct_max"]
                    box["derived"] = f"jct={jct[mode]:.0f}s"
            sp.append(jct["basic"] / jct["dualpath"])
        emit(f"fig9/{kind}/summary", 0.0,
             f"speedup_by_scale={['%.2f' % s for s in sp]} "
             f"(paper: 1.82-1.99 shrinking with {kind} scale)")


if __name__ == "__main__":
    run()
