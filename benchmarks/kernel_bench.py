"""Kernel micro-benchmarks (interpret mode on CPU — wall times are NOT
TPU times; the TPU-side performance story lives in §Roofline, derived
from the compiled dry-run.  These runs exist to (a) exercise the kernels
at paper-realistic shapes and (b) report the modelled MXU utilisation of
the chosen BlockSpecs)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops

from benchmarks.common import emit


def _bench(fn, *args, iters=3, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run(quick: bool = False):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 8)

    # prefix-append flash attention at an agentic shape: 429-token append
    # over a 4k prefix (scaled down 8x for interpret-mode runtime)
    b, hq, hkv, dh = 1, 8, 2, 64
    sq, skv = 64, 512
    q = jax.random.normal(ks[0], (b, hq, sq, dh), jnp.float32)
    k = jax.random.normal(ks[1], (b, hkv, skv, dh), jnp.float32)
    v = jax.random.normal(ks[2], (b, hkv, skv, dh), jnp.float32)
    us = _bench(ops.flash_attention, q, k, v, block_q=32, block_k=128)
    flops = 4 * b * hq * sq * skv * dh
    emit("kernel/flash_attention/append64_prefix512", us,
         f"{flops / 1e6:.1f} MFLOP interpret-mode")

    # paged decode attention
    npool, pt, npages = 64, 16, 16
    g = hq // hkv
    q1 = jax.random.normal(ks[3], (b, hkv, g, dh), jnp.float32)
    kp = jax.random.normal(ks[4], (npool, pt, hkv, dh), jnp.float32)
    vp = jax.random.normal(ks[5], (npool, pt, hkv, dh), jnp.float32)
    tbl = jax.random.randint(ks[6], (b, npages), 0, npool)
    ln = jnp.array([npages * pt - 3], jnp.int32)
    us = _bench(ops.paged_attention, q1, kp, vp, tbl, ln)
    emit("kernel/paged_attention/256tok", us, "decode 1 token vs 256 paged")

    # layer-block gather (layerwise prefill hotspot)
    pool = jax.random.randint(ks[7], (64, 8, 16, 256), 0, 255
                              ).astype(jnp.uint8)
    table = jnp.arange(32, dtype=jnp.int32)
    us = _bench(ops.kv_layer_gather, pool, table, layer=3)
    emit("kernel/kv_layer_gather/32blocks", us,
         f"{32 * 16 * 256 / 1024:.0f} KiB gathered")


if __name__ == "__main__":
    run()
