"""§Roofline: three-term roofline per (arch × shape) from the dry-run.

    compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory term     = HLO_bytes / (chips × HBM_bw)
    collective term = collective_bytes / (chips × link_bw)

HLO metrics are per-device (GSPMD-partitioned module, loop-aware — see
repro.roofline.hlo), so chips=1 in the denominators here; hardware:
TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

Reads results/dryrun_all.json (produced by repro.launch.dryrun); emits
the full baseline table plus dominant-term identification and the
MODEL_FLOPS/HLO_FLOPs usefulness ratio.
"""
from __future__ import annotations

import json
import os

from repro.configs import SHAPES, get_config

from benchmarks.common import emit

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun_all.json")


def model_flops(arch: str, shape_name: str) -> float:
    """Analytic MODEL_FLOPS: 6·N·D (dense) / 6·N_active·D (MoE) for
    training; 2·N_active·D for prefill; 2·N_active·B for decode."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_act = cfg.active_param_count()
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n_act * tokens
    if shape.kind == "prefill":
        return 2.0 * n_act * tokens
    return 2.0 * n_act * shape.global_batch          # decode: one token/seq


def terms(rec: dict) -> dict:
    n_dev = rec.get("n_devices", 256)
    t_c = rec["flops"] / PEAK_FLOPS          # per-device already
    t_m = rec["bytes_accessed"] / HBM_BW
    t_x = rec["collective_bytes"] / LINK_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])[0]
    mf = model_flops(rec["arch"], rec["shape"])
    useful = mf / max(rec["flops"] * n_dev, 1.0)
    return dict(t_compute=t_c, t_memory=t_m, t_collective=t_x,
                dominant=dom, model_flops=mf, useful_ratio=useful)


def load(path=RESULTS):
    with open(path) as f:
        return json.load(f)


def run(quick: bool = False, path=RESULTS):
    if not os.path.exists(path):
        emit("roofline/missing", 0.0,
             f"run `python -m repro.launch.dryrun --all --mesh both --out "
             f"{path}` first")
        return
    for rec in load(path):
        name = f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}"
        if rec["status"] == "skipped":
            emit(name, 0.0, f"SKIP: {rec['reason']}")
            continue
        if rec["status"] != "ok":
            emit(name, 0.0, f"ERROR: {rec.get('error', '?')[:120]}")
            continue
        if rec["mesh"] != "single":
            continue        # roofline table is single-pod (spec)
        t = terms(rec)
        emit(name, 0.0,
             f"compute={t['t_compute'] * 1e3:.2f}ms "
             f"memory={t['t_memory'] * 1e3:.2f}ms "
             f"collective={t['t_collective'] * 1e3:.2f}ms "
             f"dominant={t['dominant']} "
             f"useful={t['useful_ratio']:.2f}")


if __name__ == "__main__":
    run()
