"""Online serving on the real-bytes runtime: arrival-rate sweep with
blocking vs pipelined arms and SLO-attainment columns (§7.4, made
functional — the simulator's counterpart is fig10_online).

The event-driven ``ServingSystem`` generates real tokens and moves real
KV bytes; its wall clock advances by modelled seconds (a NodeSpec
scaled down to the reduced test model, so storage reads cost time
comparable to compute — the bandwidth-bound regime the paper's overlap
claim lives in).  Per tick the pipelined runtime charges
``max(transfer, compute)`` where the blocking lock-step charges their
sum, so the sweep shows where overlap buys SLO headroom.

Acceptance signals, asserted in ``--smoke`` mode (CI):

* both arms generate **bit-identical tokens** on the reference offline
  workload (the pipelining refactor must not change generation);
* pipelined offline throughput ≥ blocking (tokens per modelled second);
* pipelined online SLO attainment ≥ blocking at the highest swept
  arrival rate, and its doorbell count is strictly smaller (the batched
  submission half is real).
"""
from __future__ import annotations

import argparse
import os
import sys

if __package__ in (None, ""):       # direct `python benchmarks/<file>.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

import numpy as np

from benchmarks.common import emit, header, timed

SLO_TTFT_S = 0.5
SLO_TPOT_S = 0.010


def _node():
    from repro.sim.spec import REDUCED_TEST_NODE
    return REDUCED_TEST_NODE


def _workload(n_agents: int, think_s: float):
    from repro.sim.traces import Round, Trajectory
    rounds = [Round(24, 4, think_s), Round(16, 4, think_s), Round(8, 4, 0.0)]
    return [Trajectory(i, [Round(r.append, r.gen, r.think) for r in rounds])
            for i in range(n_agents)]


def _system(cfg, params, pipelined: bool):
    from repro.serving import ServingSystem
    return ServingSystem(cfg, params, n_pe=1, n_de=2, de_group_size=1,
                         block_tokens=16, max_seq=160, de_slots=4, seed=0,
                         split_reads=True, pipelined=pipelined, node=_node())


def run(quick: bool = False, smoke: bool = False):
    import jax
    from repro.configs import get_config
    from repro.models import init_params

    n_agents = 4 if smoke else (6 if quick else 10)
    rates = (2.0, 8.0) if smoke else (1.0, 4.0, 16.0)
    cfg = get_config("qwen1.5-0.5b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))

    # ---- offline reference workload: throughput per arm -----------------
    off = {}
    for arm in ("blocking", "pipelined"):
        with timed(f"fig_online_serving/offline/{arm}") as box:
            sys_ = _system(cfg, params, pipelined=(arm == "pipelined"))
            sessions = sys_.run_offline(_workload(n_agents, 0.0))
            st = sys_.stats()
            tput = (st["prefill_tokens"] + st["gen_tokens"]) / st["wall_s"]
            off[arm] = dict(st=st, tput=tput,
                            tokens=[s.context for s in sessions])
            box["derived"] = (f"tok/s={tput:.1f} wall={st['wall_s']:.3f}s "
                              f"doorbells={st['doorbells']}")

    # ---- online arrival-rate sweep: TTFT/TPOT + SLO attainment ----------
    online = {}
    for arm in ("blocking", "pipelined"):
        for aps in rates:
            trajs = _workload(n_agents, think_s=0.2)
            rng = np.random.default_rng(7)
            arrivals = list(np.cumsum(rng.exponential(1 / aps,
                                                      size=len(trajs))))
            with timed(f"fig_online_serving/{arm}/aps{aps:g}") as box:
                sys_ = _system(cfg, params,
                               pipelined=(arm == "pipelined"))
                sys_.run_online(trajs, arrivals)
                st = sys_.stats()
                att = sys_.slo_attainment(SLO_TTFT_S, SLO_TPOT_S)
                online[(arm, aps)] = dict(st=st, att=att)
                box["derived"] = (
                    f"ttft_p99={st['ttft_p99']:.3f}s "
                    f"tpot={st['tpot_mean'] * 1e3:.2f}ms "
                    f"slo_attain={att:.2f} wall={st['wall_s']:.2f}s")

    # ---- acceptance ------------------------------------------------------
    # structural invariants hold at every size; the SLO-attainment
    # comparison is threshold-dependent and only asserted at the smoke
    # operating point CI validates
    assert off["pipelined"]["tokens"] == off["blocking"]["tokens"], \
        "pipelined offline generation diverged from blocking"
    assert off["pipelined"]["tput"] >= off["blocking"]["tput"], \
        (off["pipelined"]["tput"], off["blocking"]["tput"])
    assert off["pipelined"]["st"]["doorbells"] < \
        off["blocking"]["st"]["doorbells"]
    top = max(rates)
    att_p = online[("pipelined", top)]["att"]
    att_b = online[("blocking", top)]["att"]
    if smoke:
        assert att_p >= att_b, (att_p, att_b)
    emit("fig_online_serving/acceptance", 0.0,
         f"ok: tokens identical; offline tok/s pipelined "
         f"{off['pipelined']['tput']:.1f} >= blocking "
         f"{off['blocking']['tput']:.1f}; slo_attain@{top:g}aps "
         f"{att_p:.2f} >= {att_b:.2f}")
    # headline metrics for the CI perf gate (benchmarks/perf_gate.py)
    return {
        "offline_tok_s": off["pipelined"]["tput"],
        "slo_attainment": att_p,
        "overlap_gain": off["pipelined"]["tput"] /
        max(off["blocking"]["tput"], 1e-9),
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run that asserts the acceptance "
                         "criteria and exits nonzero on violation")
    args = ap.parse_args(argv)
    header()
    run(quick=args.quick, smoke=args.smoke)
    if args.smoke:
        print("fig_online_serving smoke: PASS", file=sys.stderr)


if __name__ == "__main__":
    main()
