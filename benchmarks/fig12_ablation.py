"""Fig. 12 (right): ablation — Basic → +layerwise → +dual-path → +sched,
plus the beyond-paper `+split` arm (§6.1 future work: one request's hit
bytes partitioned across BOTH sides' storage NICs).

Paper (DS 660B, 64K): layerwise −17.21 %, +DPL −38.19 %, +sched −45.62 %
JCT vs Basic.  The split arm additionally reports how many rounds were
actually split and that both the PE-side and DE-side storage NICs moved
read bytes — the acceptance signal that split legs charge both `snic`
resources concurrently (per-round byte sums are pinned against the
loading plans, and thereby Eq. 1–8, in tests/test_sim.py)."""
from __future__ import annotations

from repro.sim import DS_660B, HOPPER_NODE, Sim, SimConfig
from repro.sim.traces import generate_dataset

from benchmarks.common import emit, timed

STAGES = [
    # (label, mode, layerwise, scheduler, split_reads)
    ("basic", "basic", False, "adaptive", False),
    ("+layerwise", "basic", True, "adaptive", False),
    ("+dualpath", "dualpath", True, "rr", False),
    ("+sched", "dualpath", True, "adaptive", False),
    ("+split", "dualpath", True, "adaptive", True),
]


def run(quick: bool = False):
    n_agents = 256 if quick else 1024
    trajs = generate_dataset(n_agents, 65536, seed=0)
    base = None
    for label, mode, lw, sched, split in STAGES:
        cfg = SimConfig(node=HOPPER_NODE, model=DS_660B, P=2, D=4,
                        mode=mode, layerwise=lw, scheduler=sched,
                        split_reads=split)
        with timed(f"fig12/{label}") as box:
            sim = Sim(cfg, trajs).run()
            jct = sim.results()["jct_max"]
            if base is None:
                base = jct
            box["derived"] = (f"jct={jct:.0f}s "
                              f"delta_vs_basic={100 * (1 - jct / base):.1f}%")
            if split:
                n_split = sum(1 for rs in sim.rounds
                              if 0.0 < rs.req.pe_read_frac < 1.0)
                pe_rd = sum(sim.snic[n].read_bytes for n in range(cfg.P))
                de_rd = sum(sim.snic[n].read_bytes
                            for n in range(cfg.P, cfg.P + cfg.D))
                box["derived"] += (
                    f" split_rounds={n_split}/{len(sim.rounds)}"
                    f" pe_snic_read={pe_rd / 1e9:.1f}GB"
                    f" de_snic_read={de_rd / 1e9:.1f}GB")
                assert pe_rd > 0 and de_rd > 0, \
                    "split arm must engage both sides' storage NICs"
    emit("fig12/paper-reference", 0.0,
         "paper deltas: layerwise -17.21%, +DPL -38.19%, +sched -45.62%; "
         "+split is beyond-paper (§6.1 future work)")


if __name__ == "__main__":
    run()
