"""Fig. 12 (right): ablation — Basic → +layerwise → +dual-path → +sched.

Paper (DS 660B, 64K): layerwise −17.21 %, +DPL −38.19 %, +sched −45.62 %
JCT vs Basic."""
from __future__ import annotations

from repro.sim import DS_660B, HOPPER_NODE, Sim, SimConfig
from repro.sim.traces import generate_dataset

from benchmarks.common import emit, timed

STAGES = [
    # (label, mode, layerwise, scheduler)
    ("basic", "basic", False, "adaptive"),
    ("+layerwise", "basic", True, "adaptive"),
    ("+dualpath", "dualpath", True, "rr"),
    ("+sched", "dualpath", True, "adaptive"),
]


def run(quick: bool = False):
    n_agents = 256 if quick else 1024
    trajs = generate_dataset(n_agents, 65536, seed=0)
    base = None
    for label, mode, lw, sched in STAGES:
        cfg = SimConfig(node=HOPPER_NODE, model=DS_660B, P=2, D=4,
                        mode=mode, layerwise=lw, scheduler=sched)
        with timed(f"fig12/{label}") as box:
            jct = Sim(cfg, trajs).run().results()["jct_max"]
            if base is None:
                base = jct
            box["derived"] = (f"jct={jct:.0f}s "
                              f"delta_vs_basic={100 * (1 - jct / base):.1f}%")
    emit("fig12/paper-reference", 0.0,
         "paper deltas: layerwise -17.21%, +DPL -38.19%, +sched -45.62%")


if __name__ == "__main__":
    run()
