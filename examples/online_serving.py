"""Online serving under Poisson arrivals (§7.4): sweep the agent arrival
rate and report TTFT/TTST/TPOT against the paper's SLO (TTFT ≤ 4 s,
TPOT ≤ 50 ms) for Basic vs DualPath — first on the discrete-event
simulator at paper scale, then on the *real-bytes* event-driven runtime
(serving/system.py) at small scale, blocking vs pipelined.

    PYTHONPATH=src python examples/online_serving.py
"""
import numpy as np

from repro.sim import DS_660B, HOPPER_NODE, Sim, SimConfig
from repro.sim.traces import Round, Trajectory, generate_dataset

SLO_TTFT, SLO_TPOT = 4.0, 0.050


def sim_sweep():
    print("=== discrete-event simulator (DS-660B, paper scale) ===")
    print(f"{'mode':10s} {'APS':>5s} {'TTFT p99':>9s} {'TTST':>7s} "
          f"{'TPOT':>8s}  SLO")
    for mode in ("basic", "dualpath"):
        for aps in (0.5, 1.0, 2.0, 3.0):
            trajs = generate_dataset(128, 32768, seed=1)
            rng = np.random.default_rng(0)
            arrivals = list(np.cumsum(rng.exponential(1 / aps,
                                                      size=len(trajs))))
            cfg = SimConfig(node=HOPPER_NODE, model=DS_660B, P=2, D=4,
                            mode=mode, online=True)
            r = Sim(cfg, trajs).run(arrivals=arrivals).results()
            ok = r["ttft_p99"] <= SLO_TTFT and r["tpot_mean"] <= SLO_TPOT
            print(f"{mode:10s} {aps:5.1f} {r['ttft_p99']:8.2f}s "
                  f"{r['ttst_mean']:6.2f}s {r['tpot_mean'] * 1e3:6.1f}ms  "
                  f"{'OK' if ok else 'VIOLATED'}")


def real_bytes_sweep():
    """The same experiment, functional: real tokens, real KV bytes, the
    event-driven runtime's modelled wall clock — reusing the benchmark's
    operating point (system topology, workload, scaled NodeSpec and SLO
    thresholds) so this table and fig_online_serving measure one
    regime."""
    import os
    import sys

    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    import jax

    from benchmarks.fig_online_serving import (SLO_TPOT_S, SLO_TTFT_S,
                                               _system, _workload)
    from repro.configs import get_config
    from repro.models import init_params

    cfg = get_config("qwen1.5-0.5b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    print("\n=== real-bytes runtime (reduced qwen-0.5b, scaled node; "
          f"SLO ttft<={SLO_TTFT_S}s tpot<={SLO_TPOT_S * 1e3:.0f}ms) ===")
    print(f"{'runtime':10s} {'APS':>5s} {'TTFT p99':>9s} {'TTST':>7s} "
          f"{'TPOT':>8s} {'attain':>7s}")
    for pipelined in (False, True):
        label = "pipelined" if pipelined else "blocking"
        for aps in (2.0, 8.0):
            trajs = _workload(6, think_s=0.2)
            rng = np.random.default_rng(7)
            arrivals = list(np.cumsum(rng.exponential(1 / aps,
                                                      size=len(trajs))))
            sys_ = _system(cfg, params, pipelined=pipelined)
            sys_.run_online(trajs, arrivals)
            st = sys_.stats()
            att = sys_.slo_attainment(SLO_TTFT_S, SLO_TPOT_S)
            print(f"{label:10s} {aps:5.1f} {st['ttft_p99']:8.3f}s "
                  f"{st['ttst_mean']:6.3f}s "
                  f"{st['tpot_mean'] * 1e3:6.2f}ms {att:7.2f}")


def main():
    sim_sweep()
    real_bytes_sweep()


if __name__ == "__main__":
    main()
