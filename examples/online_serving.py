"""Online serving under Poisson arrivals (§7.4): sweep the agent arrival
rate and report TTFT/TTST/TPOT against the paper's SLO (TTFT ≤ 4 s,
TPOT ≤ 50 ms) for Basic vs DualPath.

    PYTHONPATH=src python examples/online_serving.py
"""
import numpy as np

from repro.sim import DS_660B, HOPPER_NODE, Sim, SimConfig
from repro.sim.traces import generate_dataset

SLO_TTFT, SLO_TPOT = 4.0, 0.050


def main():
    print(f"{'mode':10s} {'APS':>5s} {'TTFT p99':>9s} {'TTST':>7s} "
          f"{'TPOT':>8s}  SLO")
    for mode in ("basic", "dualpath"):
        for aps in (0.5, 1.0, 2.0, 3.0):
            trajs = generate_dataset(128, 32768, seed=1)
            rng = np.random.default_rng(0)
            arrivals = list(np.cumsum(rng.exponential(1 / aps,
                                                      size=len(trajs))))
            cfg = SimConfig(node=HOPPER_NODE, model=DS_660B, P=2, D=4,
                            mode=mode, online=True)
            r = Sim(cfg, trajs).run(arrivals=arrivals).results()
            ok = r["ttft_p99"] <= SLO_TTFT and r["tpot_mean"] <= SLO_TPOT
            print(f"{mode:10s} {aps:5.1f} {r['ttft_p99']:8.2f}s "
                  f"{r['ttst_mean']:6.2f}s {r['tpot_mean'] * 1e3:6.1f}ms  "
                  f"{'OK' if ok else 'VIOLATED'}")


if __name__ == "__main__":
    main()
