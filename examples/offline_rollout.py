"""End-to-end offline agentic batch inference (the paper's RL-rollout
scenario, §7.3): a fleet of agents replays multi-turn trajectories
through the real engines with dual-path loading, then the cluster
simulator projects the same workload at paper scale (DS 660B, 2P4D)
for the Basic/DualPath/Oracle JCT comparison.

    PYTHONPATH=src python examples/offline_rollout.py [--agents 6]
"""
import argparse
import time

import jax

from repro.configs import get_config
from repro.models import init_params
from repro.serving import ServingSystem
from repro.sim import DS_660B, HOPPER_NODE, Sim, SimConfig
from repro.sim.traces import Round, Trajectory, generate_dataset


def functional_rollout(n_agents: int):
    print(f"=== functional rollout: {n_agents} agents on real engines ===")
    cfg = get_config("qwen1.5-0.5b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    trajs = [Trajectory(i, [Round(20, 4), Round(14, 4), Round(10, 4)])
             for i in range(n_agents)]
    for mode in ("basic", "dualpath"):
        system = ServingSystem(cfg, params, n_pe=1, n_de=1, mode=mode,
                               block_tokens=16, max_seq=192,
                               de_slots=max(4, n_agents))
        t0 = time.time()
        system.run_offline(trajs)
        st = system.stats()
        print(f"  {mode:9s}: reads pe/de = "
              f"{st['read_bytes_pe_side']:,}/{st['read_bytes_de_side']:,} B, "
              f"prefill {st['prefill_tokens']} tok, "
              f"wall {time.time() - t0:.1f}s")


def projected_rollout():
    print("\n=== projected at paper scale: DS 660B, 2P4D, 512 agents, "
          "64K MAL ===")
    trajs = generate_dataset(512, 65536, seed=0)
    for mode in ("basic", "dualpath", "oracle"):
        cfg = SimConfig(node=HOPPER_NODE, model=DS_660B, P=2, D=4, mode=mode)
        r = Sim(cfg, trajs).run().results()
        print(f"  {mode:9s}: JCT={r['jct_max']:7.0f}s "
              f"ttft={r['ttft_mean']:5.2f}s "
              f"tpot={r['tpot_mean'] * 1e3:5.1f}ms")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--agents", type=int, default=6)
    args = ap.parse_args()
    functional_rollout(args.agents)
    projected_rollout()


if __name__ == "__main__":
    main()
