"""Train a small LM on agent-trajectory-packed data with checkpoints and
crash recovery — the training substrate the rollout phase feeds.

    PYTHONPATH=src python examples/train_agent_lm.py --steps 60
"""
import argparse
import os
import tempfile

import jax

from repro.configs import get_config
from repro.ckpt import FaultTolerantRunner
from repro.models import count_params_analytic, init_params
from repro.training import TrajectoryLM, make_train_step, wsd


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    print(f"training {cfg.name} (reduced, "
          f"{count_params_analytic(cfg) / 1e6:.1f}M params), "
          f"optimizer={cfg.optimizer}")
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_init, train_step = make_train_step(cfg, lr=1e-3, n_microbatches=2)
    ts = jax.jit(train_step, donate_argnums=(0, 1))
    pipe = TrajectoryLM(cfg.vocab_size, batch=4, seq=64, seed=0)

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_ckpt_")
    runner = FaultTolerantRunner(ckpt_dir, ts, params, opt_init(params),
                                 pipe, ckpt_every=20)
    if runner.try_resume():
        print(f"resumed from checkpoint at step {runner.step}")
    losses = runner.run(args.steps)
    for i in range(0, len(losses), max(len(losses) // 10, 1)):
        step = runner.step - len(losses) + i + 1
        print(f"  step {step:4d}  loss {losses[i]:7.3f}  "
              f"lr {wsd(step, peak_lr=1e-3, warmup=10, stable=400, decay=50):.2e}")
    print(f"final loss {losses[-1]:.3f}; checkpoints in {ckpt_dir}")


if __name__ == "__main__":
    main()
