"""Quickstart: serve a small model with multi-turn KV-Cache reuse.

Builds a reduced qwen1.5 config, runs a 3-round agent trajectory through
the full DualPath stack (trie hits → dual-path FullBlock loading →
quota-packed chunked prefill → PD transfer → slot-batched decode →
block persistence) and prints what moved where.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import get_config
from repro.models import init_params
from repro.serving import ServingSystem
from repro.sim.traces import Round, Trajectory


def main():
    cfg = get_config("qwen1.5-0.5b").reduced()
    print(f"model: {cfg.name} (reduced: {cfg.n_layers}L d={cfg.d_model})")
    params = init_params(cfg, jax.random.PRNGKey(0))

    system = ServingSystem(cfg, params, n_pe=1, n_de=1, mode="dualpath",
                           block_tokens=16, max_seq=192, de_slots=4)
    traj = Trajectory(0, [Round(24, 6), Round(14, 5), Round(10, 4)])
    print(f"agent: {traj.n_rounds} rounds, "
          f"{traj.total_tokens} total tokens")

    sessions = system.run_offline([traj])
    s = sessions[0]
    print(f"\nrounds completed: {s.rounds_done}")
    print(f"final context length: {len(s.context)} tokens")
    stats = system.stats()
    hit = stats["store_reads"]
    print(f"KV bytes loaded from storage:  {hit:,} "
          f"(pe-side {stats['read_bytes_pe_side']:,} / "
          f"de-side {stats['read_bytes_de_side']:,})")
    print(f"KV bytes persisted to storage: {stats['store_writes']:,} "
          f"in {stats['trie_blocks']} trie blocks")
    # without reuse every round would re-prefill its whole prompt
    total_prompt = sum(len(s.context) - sum(r.gen for r in traj.rounds[i:])
                       - sum(r.append for r in traj.rounds[i + 1:])
                       - traj.rounds[i].gen
                       for i in range(traj.n_rounds))
    print(f"prefill compute: {stats['prefill_tokens']} tokens "
          f"(vs {total_prompt} without reuse = "
          f"{1 - stats['prefill_tokens'] / total_prompt:.0%} saved by "
          f"cache hits)")
    print(f"decode steps: {stats['decode_steps']}")


if __name__ == "__main__":
    main()
