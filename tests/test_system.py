"""Top-level system behaviour tests (the paper's end-to-end story)."""
import os
import subprocess
import sys


from repro.configs import ARCH_IDS, cells_for, get_config
from repro.core.analysis import ClusterSpec, is_bottleneck_free
from repro.sim import DS_660B, HOPPER_NODE, Sim, SimConfig, generate_dataset

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_assigned_cells_accounting():
    """40 assigned cells = 31 runnable + 9 documented skips."""
    runnable = skipped = 0
    for arch in ARCH_IDS:
        for shape, ok, why in cells_for(get_config(arch)):
            if ok:
                runnable += 1
            else:
                skipped += 1
                assert why, (arch, shape.name)
    assert runnable + skipped == 40
    assert runnable == 31 and skipped == 9


def test_paper_deployments_inside_bottleneck_free_range():
    spec = ClusterSpec()
    for P, D in [(2, 4), (1, 2), (1, 1), (48, 96), (44, 88)]:
        assert is_bottleneck_free(P, D, spec)[0]


def test_offline_speedup_reproduces_paper_headline():
    """Paper: DualPath improves offline throughput up to 1.87x over
    Basic.  At 192 agents/2P4D/64K we assert >=1.10x (the full
    1024-agent point reaches ~1.86x, run in benchmarks/fig7)."""
    trajs = generate_dataset(192, 65536, seed=0)
    res = {}
    for mode in ("basic", "dualpath"):
        cfg = SimConfig(node=HOPPER_NODE, model=DS_660B, P=2, D=4, mode=mode)
        res[mode] = Sim(cfg, trajs).run().results()["jct_max"]
    speedup = res["basic"] / res["dualpath"]
    assert speedup > 1.10, res


def test_dryrun_entrypoint_subprocess():
    """The dry-run must run as its own process (512 fake devices)."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "qwen1.5-0.5b", "--shape", "decode_32k",
         "--mesh", "single"],
        env=dict(os.environ, PYTHONPATH=os.path.join(REPO, "src")),
        capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
