"""Correctness of the §Perf hillclimb variants (EXPERIMENTS.md §Perf)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_decode_state, init_params
from repro.models.model import decode_step
from repro.models.moe import moe_ffn

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "gemma2-2b",
                                  "llava-next-34b"])
def test_decode_cache_carry_bitexact(arch):
    """cache_mode='carry' (in-place scan carry) == scan_xs, bitwise."""
    cfg = get_config(arch).reduced()
    params = init_params(cfg, KEY)
    b = 2
    st1 = init_decode_state(cfg, b, 16)
    st2 = init_decode_state(cfg, b, 16)
    toks = jnp.array([3, 5], jnp.int32)
    for i in range(5):
        lengths = jnp.full((b,), i, jnp.int32)
        l1, st1 = decode_step(params, cfg, toks, st1, lengths)
        l2, st2 = decode_step(params, cfg, toks, st2, lengths,
                              cache_mode="carry")
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    for a, c in zip(jax.tree.leaves(st1), jax.tree.leaves(st2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


def _moe_fixture():
    cfg = get_config("granite-moe-3b-a800m").reduced()
    params = init_params(cfg, KEY)
    p = jax.tree.map(lambda a: a[0], params["super_blocks"]["moe"])["moe"]
    x = jax.random.normal(jax.random.PRNGKey(3), (3, 16, cfg.d_model),
                          jnp.float32)
    return cfg, p, x


def test_moe_dense_matches_ragged():
    """dense all-experts == dropless ragged (exact routing, no capacity)."""
    cfg, p, x = _moe_fixture()
    y1 = moe_ffn(p, cfg, x, impl="ragged")
    y2 = moe_ffn(p, cfg, x, impl="dense")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5)


def test_moe_ep_local_matches_ragged_without_drops():
    cfg, p, x = _moe_fixture()
    y1 = moe_ffn(p, cfg, x, impl="ragged")
    y2 = moe_ffn(p, cfg, x, impl="ep_local", capacity_factor=1000.0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5)


def test_remat_policies_equivalent_loss():
    from repro.training import make_train_step
    cfg = get_config("qwen1.5-0.5b").reduced()
    params = init_params(cfg, KEY)
    batch = jax.random.randint(KEY, (2, 17), 0, cfg.vocab_size)
    losses = []
    for remat in ("full", "dots", False):
        opt_init, ts = make_train_step(cfg, n_microbatches=1, remat=remat)
        _, _, loss = ts(params, opt_init(params), batch)
        losses.append(float(loss))
    assert max(losses) - min(losses) < 1e-3, losses
