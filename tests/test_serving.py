"""End-to-end serving integration: dual-path loading with real KV bytes.

The decisive test: multi-turn generation through the full system (trie
hits, FullBlock reads on either path, chunked prefill, PD transfer,
slot-batched decode, block persistence) must produce the SAME tokens as
a cache-free reference that re-prefills the whole prompt every round.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.config import TierConfig
from repro.models import decode_step, forward, init_decode_state, init_params
from repro.serving import ServingSystem
from repro.sim.traces import Round, Trajectory

KEY = jax.random.PRNGKey(0)


def reference_generate(cfg, params, rounds, rng):
    """Cache-free oracle: full forward per round, greedy decode."""
    context = []
    all_gen = []
    for rnd in rounds:
        append = list(rng.integers(2, cfg.vocab_size, size=rnd.append))
        prompt = context + append
        toks = jnp.asarray([prompt], jnp.int32)
        logits, _ = forward(params, cfg, toks)
        first = int(jnp.argmax(logits[0, -1]))
        gen = [first]
        st = init_decode_state(cfg, 1, len(prompt) + rnd.gen + 4)
        _, st = __import__("repro.models.model", fromlist=["append_step"]) \
            .append_step(params, cfg, toks, st, jnp.zeros((1,), jnp.int32))
        cur = first
        for i in range(rnd.gen - 1):
            lg, st = decode_step(params, cfg, jnp.asarray([cur], jnp.int32),
                                 st, jnp.asarray([len(prompt) + i], jnp.int32))
            cur = int(jnp.argmax(lg[0]))
            gen.append(cur)
        all_gen.append(gen)
        context = prompt + gen
    return all_gen


@pytest.mark.parametrize("mode", ["dualpath", "basic", "split", "tiered",
                                  "tiered-small"])
def test_generation_with_cache_reuse_matches_reference(mode):
    """tiered: big DRAM tier + think-time prefetch (round-start reads
    served from node DRAM); tiered-small: a tier of a few blocks, so
    eviction churns constantly mid-trajectory.  Generation must stay
    bit-identical to the cache-free reference in every arm."""
    cfg = get_config("qwen1.5-0.5b").reduced()
    params = init_params(cfg, KEY)
    rounds = [Round(20, 4), Round(13, 3), Round(9, 4)]
    traj = Trajectory(0, rounds)
    tier_kw = {}
    if mode == "tiered":
        tier_kw = dict(tier=TierConfig(dram_tier_bytes=1 << 30,
                                       prefetch=True))
    elif mode == "tiered-small":
        tier_kw = dict(tier=TierConfig(dram_tier_bytes=32768, prefetch=True,
                                       tier_policy="agentic-ttl"))
    sys_ = ServingSystem(cfg, params, n_pe=1, n_de=1,
                         mode="basic" if mode == "basic" else "dualpath",
                         split_reads=(mode == "split"),
                         block_tokens=16, max_seq=160, de_slots=2, seed=0,
                         **tier_kw)
    sessions = sys_.run_offline([traj])
    assert sessions[0].rounds_done == 3
    ref = reference_generate(cfg, params, rounds,
                             np.random.default_rng(1000))
    ctx = sessions[0].context
    # reconstruct per-round gens from the final context? easier: compare
    # final context suffix — instead regenerate via the recorded sessions
    # by replaying; simplest strong check: final context equality.
    ref_context = []
    rng = np.random.default_rng(1000)
    for rnd, gen in zip(rounds, ref):
        append = list(rng.integers(2, cfg.vocab_size, size=rnd.append))
        ref_context = ref_context + append + gen
    assert ctx == ref_context, (
        f"cache-reuse generation diverged from cache-free reference "
        f"({mode}); first mismatch at "
        f"{next(i for i, (a, b) in enumerate(zip(ctx, ref_context)) if a != b)}")


def test_multi_agent_multi_engine():
    cfg = get_config("qwen1.5-0.5b").reduced()
    params = init_params(cfg, KEY)
    trajs = [Trajectory(i, [Round(18, 3), Round(12, 3)]) for i in range(5)]
    sys_ = ServingSystem(cfg, params, n_pe=2, n_de=2, mode="dualpath",
                         block_tokens=16, max_seq=128, de_slots=4, seed=0)
    sessions = sys_.run_offline(trajs)
    assert all(s.rounds_done == 2 for s in sessions)
    st = sys_.stats()
    assert st["store_reads"] > 0          # round 2 hit the cache
    assert st["trie_blocks"] > 0
    assert st["decode_steps"] > 0


def test_dualpath_uses_both_sides_under_load():
    cfg = get_config("qwen1.5-0.5b").reduced()
    params = init_params(cfg, KEY)
    trajs = [Trajectory(i, [Round(24, 3), Round(16, 3), Round(8, 3)])
             for i in range(6)]
    sys_ = ServingSystem(cfg, params, n_pe=1, n_de=1, mode="dualpath",
                         block_tokens=16, max_seq=160, de_slots=8, seed=0)
    sys_.run_offline(trajs)
    st = sys_.stats()
    assert st["read_bytes_de_side"] > 0, "storage->DE path never used"
    assert st["read_bytes_pe_side"] > 0


def test_split_reads_use_both_sides_within_one_request():
    """§6.1 future work executed for real: with split_reads the hit
    FullBlocks of a single request are read partly on the PE side and
    partly on the DE side (block-granular partition), and generation
    still matches — asserted via the split arm of the reference test
    above; here we check the split actually happened."""
    cfg = get_config("qwen1.5-0.5b").reduced()
    params = init_params(cfg, KEY)
    trajs = [Trajectory(i, [Round(32, 3), Round(16, 3)]) for i in range(3)]
    sys_ = ServingSystem(cfg, params, n_pe=1, n_de=1, mode="dualpath",
                         split_reads=True, block_tokens=16, max_seq=160,
                         de_slots=4, seed=0)
    sys_.run_offline(trajs)
    st = sys_.stats()
    assert st["split_reads"] > 0, "no request was split"
    assert st["read_bytes_pe_side"] > 0
    assert st["read_bytes_de_side"] > 0


def test_basic_mode_never_uses_de_side():
    cfg = get_config("qwen1.5-0.5b").reduced()
    params = init_params(cfg, KEY)
    trajs = [Trajectory(i, [Round(20, 3), Round(12, 3)]) for i in range(4)]
    sys_ = ServingSystem(cfg, params, n_pe=1, n_de=1, mode="basic",
                         block_tokens=16, max_seq=128, de_slots=4, seed=0)
    sys_.run_offline(trajs)
    assert sys_.stats()["read_bytes_de_side"] == 0


def test_tiered_serving_serves_hits_from_dram_and_conserves():
    """With a warm DRAM tier the round-start reads bypass the store (=
    the storage NIC): after round 1 every hit byte is a DRAM hit, and
    dram-served + store-read (SNIC) bytes == total hit bytes."""
    cfg = get_config("qwen1.5-0.5b").reduced()
    params = init_params(cfg, KEY)
    trajs = [Trajectory(i, [Round(24, 3), Round(16, 3), Round(8, 3)])
             for i in range(3)]
    sys_ = ServingSystem(cfg, params, n_pe=1, n_de=1, mode="dualpath",
                         block_tokens=16, max_seq=160, de_slots=4, seed=0,
                         tier=TierConfig(dram_tier_bytes=1 << 30,
                                         prefetch=True))
    sys_.run_offline(trajs)
    st = sys_.stats()
    assert st["dram_hit_bytes"] > 0, "tier never served a hit"
    # conservation: every hit byte was served from DRAM or the store,
    # and the per-side counters partition exactly along that line
    # (read_bytes_* is SNIC traffic only, matching the sim's convention)
    assert st["dram_hit_bytes"] == (st["dram_bytes_pe_side"] +
                                    st["dram_bytes_de_side"])
    assert st["tier_miss_bytes"] == (st["read_bytes_pe_side"] +
                                     st["read_bytes_de_side"])
    # with ample capacity nothing is evicted and, past the cold start,
    # nothing needs the SNIC: all store reads come from tier misses
    assert st["tier_evicted_bytes"] == 0
    assert st["store_reads"] == st["tier_miss_bytes"] + \
        st["tier_prefetch_bytes"]
    for tier in sys_.tiers.values():
        assert tier.pinned_bytes() == 0      # all read leases released


def test_ssm_state_blob_reuse():
    cfg = get_config("mamba2-1.3b").reduced()
    params = init_params(cfg, KEY)
    sys_ = ServingSystem(cfg, params, n_pe=1, n_de=1, max_seq=128,
                         de_slots=2, seed=0)
    sessions = sys_.run_offline([Trajectory(0, [Round(16, 3), Round(8, 3)])])
    assert sessions[0].rounds_done == 2
    assert sys_.blob_store.bytes_read > 0, "state blob never reused"


def test_mla_arch_serving():
    cfg = get_config("ds27b").reduced()
    params = init_params(cfg, KEY)
    sys_ = ServingSystem(cfg, params, n_pe=1, n_de=1, max_seq=128,
                         block_tokens=16, de_slots=2, seed=0)
    sessions = sys_.run_offline([Trajectory(0, [Round(18, 3), Round(10, 3)])])
    assert sessions[0].rounds_done == 2
    assert sys_.stats()["store_reads"] > 0
