"""Bottleneck-free analysis (paper §4.2) — exact paper numbers + properties."""
import math

from hypothesis import given, settings, strategies as st

from repro.core.analysis import (ClusterSpec, bottleneck_free_range,
                                 is_bottleneck_free, link_utilisation,
                                 link_utilisation_mix,
                                 max_aggregate_load_bw, pair_traffic,
                                 safe_pd_splits)


def test_paper_range():
    """Paper: for (g=8, s=1, M≈500 GB/s, Bs≈50 GB/s): 1/7 ≤ P/D ≤ 7/2."""
    spec = ClusterSpec(g=8, B=50e9, s=1.0, M=500e9)
    lo, hi = bottleneck_free_range(spec)
    assert math.isclose(lo, 1 / 7)
    assert math.isclose(hi, 3.5)


def test_eq9_terms():
    """hi = min{(g-2s)/s, (g-s)/2s, (M/Bs-3)/2} — each term correct."""
    spec = ClusterSpec(g=8, B=50e9, s=1.0, M=500e9)
    assert math.isclose((spec.g - 2 * spec.s) / spec.s, 6.0)
    assert math.isclose((spec.g - spec.s) / (2 * spec.s), 3.5)
    assert math.isclose((spec.M / (spec.B * spec.s) - 3) / 2, 3.5)


def test_paper_default_deployments_are_safe():
    spec = ClusterSpec()
    for P, D in [(2, 4), (1, 2), (1, 1), (2, 1), (1, 2), (48, 96), (44, 88)]:
        ok, worst = is_bottleneck_free(P, D, spec)
        assert ok, (P, D, worst, link_utilisation(P, D, spec))


def test_outside_range_binds():
    spec = ClusterSpec()
    ok, worst = is_bottleneck_free(8, 1, spec)     # P/D = 8 > 3.5
    assert not ok
    ok, _ = is_bottleneck_free(1, 8, spec)         # P/D = 1/8 < 1/7
    assert not ok


@given(P=st.integers(1, 64), D=st.integers(1, 64),
       g=st.integers(2, 16), s_frac=st.floats(0.25, 1.0))
@settings(max_examples=200, deadline=None)
def test_utilisation_matches_range(P, D, g, s_frac):
    """Eq.1–8 utilisations ≤ 1 ⟺ P/D inside the Eq.9 range (up to the
    always-true read constraint)."""
    spec = ClusterSpec(g=g, B=50e9, s=s_frac, M=500e9)
    lo, hi = bottleneck_free_range(spec)
    util = link_utilisation(P, D, spec)
    inside = lo - 1e-9 <= P / D <= hi + 1e-9
    # pe_cnic_read is bottleneck-free whenever s <= g (always here)
    assert util["pe_cnic_read"] <= 1 + 1e-9
    constrained = {k: v for k, v in util.items() if k != "pe_cnic_read"}
    if inside:
        assert max(constrained.values()) <= 1 + 1e-6, constrained
    else:
        assert max(constrained.values()) > 1 - 1e-6, constrained


def test_aggregate_bandwidth_equivalences():
    """§7.3: Basic 2P1D == DualPath 1P1D == 2 SNICs of load bandwidth."""
    spec = ClusterSpec()
    assert max_aggregate_load_bw(2, 1, spec, dualpath=False) == \
        max_aggregate_load_bw(1, 1, spec, dualpath=True)
    assert max_aggregate_load_bw(2, 1, spec, dualpath=True) == \
        max_aggregate_load_bw(1, 2, spec, dualpath=True)


def test_safe_splits_elastic():
    spec = ClusterSpec()
    splits = safe_pd_splits(6, spec)
    assert (2, 4) in splits and (3, 3) in splits
    for P, D in splits:
        assert is_bottleneck_free(P, D, spec)[0]


@given(P=st.integers(1, 32), D=st.integers(1, 32),
       g=st.integers(2, 16), s_frac=st.floats(0.25, 1.0))
@settings(max_examples=100, deadline=None)
def test_mix_reduces_to_eq18_at_saturating_phi(P, D, g, s_frac):
    """The split-read generalisation evaluated at the saturating mix
    φ* = P/(P+D) IS Eq. 1–8: every resource utilisation coincides."""
    spec = ClusterSpec(g=g, B=50e9, s=s_frac, M=500e9)
    a = link_utilisation(P, D, spec)
    b = link_utilisation_mix(P, D, spec)
    assert set(a) == set(b)
    for k in a:
        assert math.isclose(a[k], b[k], rel_tol=1e-12), (k, a[k], b[k])


@given(P=st.integers(1, 16), D=st.integers(1, 16),
       phi=st.floats(0.01, 0.99))
@settings(max_examples=100, deadline=None)
def test_mix_aggregate_traffic_identities(P, D, phi):
    """For any mix φ, the utilisations returned by link_utilisation_mix
    must satisfy the plan-coefficient identities: aggregate PE-CNIC
    read traffic is 2× the PE-side load (Fig. 4a paths 3+5), DE-CNIC
    read is (2−φ)× the load (DE share twice + every byte's HBM pass),
    PE DRAM 2φ×, DE DRAM (3−φ)× — and the implied load never exceeds
    the both-sides-saturated optimum L(φ*) = (P+D)·sB, which is why
    water-filling steers the average mix toward φ*."""
    spec = ClusterSpec()
    util = link_utilisation_mix(P, D, spec, phi=phi)
    B, g, M = spec.B, spec.g, spec.M
    L = min(P * spec.snic_bw / phi, D * spec.snic_bw / (1 - phi))
    assert math.isclose(util["pe_cnic_read"] * P * g * B, 2 * phi * L,
                        rel_tol=1e-9)
    assert math.isclose(util["de_cnic_read"] * D * g * B, (2 - phi) * L,
                        rel_tol=1e-9)
    assert math.isclose(util["pe_dram"] * M * P, 2 * phi * L, rel_tol=1e-9)
    assert math.isclose(util["de_dram"] * M * D, (3 - phi) * L,
                        rel_tol=1e-9)
    assert L <= (P + D) * spec.snic_bw * (1 + 1e-9)


@given(P=st.integers(1, 32), D=st.integers(1, 32))
@settings(max_examples=100, deadline=None)
def test_pair_traffic_saturates_snics(P, D):
    """Σ pair traffic over all pairs == aggregate storage bandwidth of
    each side (the loading paths fully drain the NICs they use)."""
    spec = ClusterSpec()
    T_p, T_c = pair_traffic(P, D, spec)
    n_pairs = P * spec.g * D * spec.g
    assert math.isclose(T_p * n_pairs, P * spec.snic_bw, rel_tol=1e-9)
    assert math.isclose(T_c * n_pairs, D * spec.snic_bw, rel_tol=1e-9)
