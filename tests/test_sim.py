"""Discrete-event simulator: paper-claim directionality + invariants."""
import numpy as np
import pytest

from repro.core.analysis import ClusterSpec, link_utilisation
from repro.sim import (DS_660B, HOPPER_NODE, QWEN25_32B, Sim, SimConfig,
                       generate_dataset)


def run(mode, n_agents=96, max_len=32768, scheduler="adaptive", P=1, D=2,
        **kw):
    trajs = generate_dataset(n_agents, max_len, seed=0)
    cfg = SimConfig(node=HOPPER_NODE, model=DS_660B, P=P, D=D, mode=mode,
                    scheduler=scheduler, **kw)
    return Sim(cfg, trajs).run().results()


def test_all_agents_finish():
    for mode in ("basic", "dualpath", "oracle"):
        r = run(mode, n_agents=24)
        assert r["finished_agents"] == 24, (mode, r)


def test_dualpath_beats_basic_when_io_bound():
    """Needs a storage-bound operating point: 2P4D / 64K contexts (at
    1P2D/32K decode capacity binds first and all modes tie — verified;
    that P/D sensitivity is itself a paper finding, Fig. 8)."""
    rb = run("basic", n_agents=192, max_len=65536, P=2, D=4)
    rd = run("dualpath", n_agents=192, max_len=65536, P=2, D=4)
    ro = run("oracle", n_agents=192, max_len=65536, P=2, D=4)
    assert rd["jct_max"] < rb["jct_max"] * 0.95, (rb, rd)
    assert ro["jct_max"] <= rd["jct_max"] * 1.02


def test_oracle_is_lower_bound_on_ttft():
    rb = run("basic", n_agents=48)
    ro = run("oracle", n_agents=48)
    assert ro["ttft_mean"] <= rb["ttft_mean"] * 1.05


def test_tpot_unaffected_by_dualpath():
    """Paper §7.4: DualPath introduces no additional decoding overhead."""
    rb = run("basic", n_agents=48)
    rd = run("dualpath", n_agents=48)
    assert abs(rd["tpot_mean"] - rb["tpot_mean"]) / rb["tpot_mean"] < 0.15


def test_adaptive_no_worse_than_round_robin():
    """Fig. 13 caveat (documented in EXPERIMENTS.md): our RR baseline
    already includes read-path alternation, which is structurally
    well-balanced for small P:D node ratios, so the paper's Max/Avg gap
    (1.53 -> 1.18) is not reproduced under this stronger RR.  The
    throughput-level guarantee holds: adaptive JCT <= RR JCT, and
    adaptive engages every storage NIC."""
    import dataclasses
    slow = dataclasses.replace(HOPPER_NODE, snic_bw=10e9)  # force I/O-bound
    res = {}
    for scheduler in ("adaptive", "rr"):
        trajs = generate_dataset(96, 32768, seed=0)
        cfg = SimConfig(node=slow, model=DS_660B, P=1, D=2,
                        mode="dualpath", scheduler=scheduler)
        sim = Sim(cfg, trajs).run()
        res[scheduler] = sim.results()["jct_max"]
        assert all(n.total_bytes > 0 for n in sim.snic.values())
    assert res["adaptive"] <= res["rr"] * 1.03, res


def test_online_poisson_slo():
    trajs = generate_dataset(32, 32768, seed=1)
    rng = np.random.default_rng(0)
    arrivals = np.cumsum(rng.exponential(1 / 0.5, size=len(trajs)))
    cfg = SimConfig(node=HOPPER_NODE, model=DS_660B, P=1, D=2,
                    mode="dualpath", online=True)
    r = Sim(cfg, trajs).run(arrivals=list(arrivals)).results()
    assert r["finished_agents"] == 32
    assert r["tpot_mean"] < 0.050          # SLO from the paper


def test_sim_steady_state_matches_analysis():
    """Aggregate storage bandwidth used by dualpath ≈ all NICs (the
    §4.2 assumption the closed form is built on)."""
    trajs = generate_dataset(96, 32768, seed=0)
    cfg = SimConfig(node=HOPPER_NODE, model=DS_660B, P=1, D=2,
                    mode="dualpath")
    sim = Sim(cfg, trajs).run()
    tot = [n.total_bytes for n in sim.snic.values()]
    # every node's storage NIC moved bytes (PE-only systems leave D idle)
    assert all(t > 0 for t in tot), tot

    cfgb = SimConfig(node=HOPPER_NODE, model=DS_660B, P=1, D=2, mode="basic")
    simb = Sim(cfgb, trajs).run()
    totb = [n.total_bytes for n in simb.snic.values()]
    assert totb[1] == 0 or totb[1] < totb[0] * 0.05  # DE NICs ~idle in basic


def test_split_reads_option_is_safe():
    """Beyond-paper: the paper's future-work read splitting (scheduler
    split_reads=True) now executes genuine intra-request read
    parallelism — one request's hit bytes served by BOTH sides' storage
    NICs concurrently.  Under storage-bound load it must never regress
    JCT (and usually improves it)."""
    import dataclasses
    slow = dataclasses.replace(HOPPER_NODE, snic_bw=10e9)
    trajs = generate_dataset(64, 32768, seed=0)
    res = {}
    for split in (False, True):
        cfg = SimConfig(node=slow, model=DS_660B, P=1, D=2,
                        mode="dualpath", split_reads=split)
        r = Sim(cfg, trajs).run().results()
        assert r["finished_agents"] == 64
        res[split] = r["jct_max"]
    assert res[True] <= res[False] * 1.05


def test_split_reads_engage_both_nics_concurrently():
    """Acceptance: during a single split request's load phase the
    PE-side and DE-side storage NICs are busy at the same time —
    service intervals of the request's two load legs overlap."""
    import dataclasses
    slow = dataclasses.replace(HOPPER_NODE, snic_bw=10e9)
    trajs = generate_dataset(8, 32768, seed=0)
    cfg = SimConfig(node=slow, model=DS_660B, P=1, D=1,
                    mode="dualpath", split_reads=True)
    sim = Sim(cfg, trajs).run()
    assert sim.results()["finished_agents"] == 8
    split_rounds = [rs for rs in sim.rounds
                    if 0.0 < rs.req.pe_read_frac < 1.0]
    assert split_rounds, "no round produced a split read"
    overlapped = 0
    for rs in split_rounds:
        legs = {e[0]: e for e in rs.read_legs}
        assert set(legs) == {"pe", "de"}, rs.read_legs
        start = max(legs["pe"][2], legs["de"][2])
        first_done = min(legs["pe"][3], legs["de"][3])
        if first_done > start >= 0:
            overlapped += 1
    assert overlapped > 0, "no split round had concurrent NIC service"
    # both nodes' NICs moved read bytes for loads (not only persists)
    assert all(n.read_bytes > 0 for n in sim.snic.values())


def test_sim_charges_match_loading_plans_to_the_byte():
    """The sim executes exactly the plan legs: per-round charged bytes
    per symbolic resource equal core/loading's plan sums (which are in
    turn pinned to the §4.2 Eq. 1–8 coefficients in test_loading.py) —
    byte-exact, for pure and split reads alike."""
    from repro.core.loading import resource_bytes
    trajs = generate_dataset(6, 32768, seed=2)
    for split in (False, True):
        cfg = SimConfig(node=HOPPER_NODE, model=DS_660B, P=1, D=1,
                        mode="dualpath", split_reads=split)
        sim = Sim(cfg, trajs).run()
        checked = 0
        for rs in sim.rounds:
            if rs.done_t < 0 or rs.req.read_path is None:
                continue
            legs = [l for l in sim._request_legs(rs.req)
                    if l.phase != "decode"]     # persists aggregate per block
            exp = {k: v for k, v in resource_bytes(legs).items() if v}
            got = {k: v for k, v in rs.charged.items() if v}
            assert got == exp, (split, rs.req.rid, got, exp)
            checked += 1
        assert checked > 0
