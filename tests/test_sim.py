"""Discrete-event simulator: paper-claim directionality + invariants."""
import numpy as np

from repro.core.config import TierConfig
from repro.sim import (DS_660B, HOPPER_NODE, Sim, SimConfig,
                       generate_dataset)


def run(mode, n_agents=96, max_len=32768, scheduler="adaptive", P=1, D=2,
        **kw):
    trajs = generate_dataset(n_agents, max_len, seed=0)
    cfg = SimConfig(node=HOPPER_NODE, model=DS_660B, P=P, D=D, mode=mode,
                    scheduler=scheduler, **kw)
    return Sim(cfg, trajs).run().results()


def test_all_agents_finish():
    for mode in ("basic", "dualpath", "oracle"):
        r = run(mode, n_agents=24)
        assert r["finished_agents"] == 24, (mode, r)


def test_dualpath_beats_basic_when_io_bound():
    """Needs a storage-bound operating point: 2P4D / 64K contexts (at
    1P2D/32K decode capacity binds first and all modes tie — verified;
    that P/D sensitivity is itself a paper finding, Fig. 8)."""
    rb = run("basic", n_agents=192, max_len=65536, P=2, D=4)
    rd = run("dualpath", n_agents=192, max_len=65536, P=2, D=4)
    ro = run("oracle", n_agents=192, max_len=65536, P=2, D=4)
    assert rd["jct_max"] < rb["jct_max"] * 0.95, (rb, rd)
    assert ro["jct_max"] <= rd["jct_max"] * 1.02


def test_oracle_is_lower_bound_on_ttft():
    rb = run("basic", n_agents=48)
    ro = run("oracle", n_agents=48)
    assert ro["ttft_mean"] <= rb["ttft_mean"] * 1.05


def test_tpot_unaffected_by_dualpath():
    """Paper §7.4: DualPath introduces no additional decoding overhead."""
    rb = run("basic", n_agents=48)
    rd = run("dualpath", n_agents=48)
    assert abs(rd["tpot_mean"] - rb["tpot_mean"]) / rb["tpot_mean"] < 0.15


def test_adaptive_no_worse_than_round_robin():
    """Fig. 13 caveat (documented in EXPERIMENTS.md): our RR baseline
    already includes read-path alternation, which is structurally
    well-balanced for small P:D node ratios, so the paper's Max/Avg gap
    (1.53 -> 1.18) is not reproduced under this stronger RR.  The
    throughput-level guarantee holds: adaptive JCT <= RR JCT, and
    adaptive engages every storage NIC."""
    import dataclasses
    slow = dataclasses.replace(HOPPER_NODE, snic_bw=10e9)  # force I/O-bound
    res = {}
    for scheduler in ("adaptive", "rr"):
        trajs = generate_dataset(96, 32768, seed=0)
        cfg = SimConfig(node=slow, model=DS_660B, P=1, D=2,
                        mode="dualpath", scheduler=scheduler)
        sim = Sim(cfg, trajs).run()
        res[scheduler] = sim.results()["jct_max"]
        assert all(n.total_bytes > 0 for n in sim.snic.values())
    assert res["adaptive"] <= res["rr"] * 1.03, res


def test_online_poisson_slo():
    trajs = generate_dataset(32, 32768, seed=1)
    rng = np.random.default_rng(0)
    arrivals = np.cumsum(rng.exponential(1 / 0.5, size=len(trajs)))
    cfg = SimConfig(node=HOPPER_NODE, model=DS_660B, P=1, D=2,
                    mode="dualpath", online=True)
    r = Sim(cfg, trajs).run(arrivals=list(arrivals)).results()
    assert r["finished_agents"] == 32
    assert r["tpot_mean"] < 0.050          # SLO from the paper


def test_sim_steady_state_matches_analysis():
    """Aggregate storage bandwidth used by dualpath ≈ all NICs (the
    §4.2 assumption the closed form is built on)."""
    trajs = generate_dataset(96, 32768, seed=0)
    cfg = SimConfig(node=HOPPER_NODE, model=DS_660B, P=1, D=2,
                    mode="dualpath")
    sim = Sim(cfg, trajs).run()
    tot = [n.total_bytes for n in sim.snic.values()]
    # every node's storage NIC moved bytes (PE-only systems leave D idle)
    assert all(t > 0 for t in tot), tot

    cfgb = SimConfig(node=HOPPER_NODE, model=DS_660B, P=1, D=2, mode="basic")
    simb = Sim(cfgb, trajs).run()
    totb = [n.total_bytes for n in simb.snic.values()]
    assert totb[1] == 0 or totb[1] < totb[0] * 0.05  # DE NICs ~idle in basic


def test_split_reads_option_is_safe():
    """Beyond-paper: the paper's future-work read splitting (scheduler
    split_reads=True) now executes genuine intra-request read
    parallelism — one request's hit bytes served by BOTH sides' storage
    NICs concurrently.  Under storage-bound load it must never regress
    JCT (and usually improves it)."""
    import dataclasses
    slow = dataclasses.replace(HOPPER_NODE, snic_bw=10e9)
    trajs = generate_dataset(64, 32768, seed=0)
    res = {}
    for split in (False, True):
        cfg = SimConfig(node=slow, model=DS_660B, P=1, D=2,
                        mode="dualpath", split_reads=split)
        r = Sim(cfg, trajs).run().results()
        assert r["finished_agents"] == 64
        res[split] = r["jct_max"]
    assert res[True] <= res[False] * 1.05


def test_split_reads_engage_both_nics_concurrently():
    """Acceptance: during a single split request's load phase the
    PE-side and DE-side storage NICs are busy at the same time —
    service intervals of the request's two load legs overlap."""
    import dataclasses
    slow = dataclasses.replace(HOPPER_NODE, snic_bw=10e9)
    trajs = generate_dataset(8, 32768, seed=0)
    cfg = SimConfig(node=slow, model=DS_660B, P=1, D=1,
                    mode="dualpath", split_reads=True)
    sim = Sim(cfg, trajs).run()
    assert sim.results()["finished_agents"] == 8
    split_rounds = [rs for rs in sim.rounds
                    if 0.0 < rs.req.pe_read_frac < 1.0]
    assert split_rounds, "no round produced a split read"
    overlapped = 0
    for rs in split_rounds:
        legs = {e[0]: e for e in rs.read_legs}
        assert set(legs) == {"pe", "de"}, rs.read_legs
        start = max(legs["pe"][2], legs["de"][2])
        first_done = min(legs["pe"][3], legs["de"][3])
        if first_done > start >= 0:
            overlapped += 1
    assert overlapped > 0, "no split round had concurrent NIC service"
    # both nodes' NICs moved read bytes for loads (not only persists)
    assert all(n.read_bytes > 0 for n in sim.snic.values())


def test_sim_charges_match_loading_plans_to_the_byte():
    """The sim executes exactly the plan legs: per-round charged bytes
    per symbolic resource equal core/loading's plan sums (which are in
    turn pinned to the §4.2 Eq. 1–8 coefficients in test_loading.py) —
    byte-exact, for pure, split and DRAM-tiered reads alike."""
    from repro.core.loading import resource_bytes
    trajs = generate_dataset(6, 32768, seed=2)
    for split, tier in ((False, 0.0), (True, 0.0), (False, 2e9),
                        (True, 2e9)):
        cfg = SimConfig(node=HOPPER_NODE, model=DS_660B, P=1, D=1,
                        mode="dualpath", split_reads=split,
                        tier=TierConfig(dram_tier_bytes=tier))
        sim = Sim(cfg, trajs).run()
        checked = tiered = 0
        for rs in sim.rounds:
            if rs.done_t < 0 or rs.req.read_path is None:
                continue
            legs = [leg for leg in sim._request_legs(rs.req)
                    if leg.phase != "decode"]     # persists aggregate per block
            exp = {k: v for k, v in resource_bytes(legs).items() if v}
            got = {k: v for k, v in rs.charged.items() if v}
            assert got == exp, (split, tier, rs.req.rid, got, exp)
            checked += 1
            tiered += bool(rs.req.dram_tokens)
        assert checked > 0
        if tier:
            assert tiered > 0, "tier arm never served a DRAM hit"


# ---------------------------------------------------------------------------
# tiered KV-cache (kvcache/tiers.py) in the simulator
# ---------------------------------------------------------------------------


def test_tiered_sim_conserves_bytes_and_saves_snic_reads():
    """ISSUE acceptance on the Table-2 32K workload: the prefetch arm
    reports a nonzero DRAM-tier hit ratio and strictly fewer SNIC
    hit-read bytes than the off arm, while per-request conservation
    (dram-served + snic-served == hit bytes) holds exactly."""
    trajs = generate_dataset(16, 32768, seed=0, think_mean_s=2.0)
    res = {}
    for label, tier, pf in (("off", 0.0, False), ("lru", 1.5e9, False),
                            ("lru+pf", 1.5e9, True)):
        cfg = SimConfig(node=HOPPER_NODE, model=DS_660B, P=1, D=2,
                        mode="dualpath",
                        tier=TierConfig(dram_tier_bytes=tier, prefetch=pf))
        sim = Sim(cfg, trajs).run()
        r = sim.results()
        assert r["finished_agents"] == 16, (label, r)
        checked = 0
        for rs in sim.rounds:
            if rs.done_t < 0 or rs.req.read_path is None:
                continue
            c = rs.charged
            served = (c.get("pe_snic", 0) + c.get("de_snic", 0) +
                      c.get("pe_tier", 0) + c.get("de_tier", 0))
            assert served == rs.req.cached_tokens * sim.kv_per_token, \
                (label, rs.req.rid)
            checked += 1
        assert checked > 0
        res[label] = r
    assert res["off"]["dram_hit_ratio"] == 0.0
    for arm in ("lru", "lru+pf"):
        assert res[arm]["dram_hit_ratio"] > 0.0, arm
        assert res[arm]["snic_hit_read_bytes"] < \
            res["off"]["snic_hit_read_bytes"], arm
    # think-time prefetch staged bytes and did not lower the hit ratio
    assert res["lru+pf"]["tier_prefetch_bytes"] > 0
    assert res["lru+pf"]["dram_hit_ratio"] >= res["lru"]["dram_hit_ratio"]


def test_tiered_sim_pins_never_exceed_capacity_and_policies_run():
    for policy in ("lru", "agentic-ttl"):
        trajs = generate_dataset(8, 32768, seed=3, think_mean_s=1.0)
        cfg = SimConfig(node=HOPPER_NODE, model=DS_660B, P=1, D=1,
                        mode="dualpath",
                        tier=TierConfig(dram_tier_bytes=1e9,
                                        tier_policy=policy, prefetch=True))
        sim = Sim(cfg, trajs).run()
        assert sim.results()["finished_agents"] == 8
        for tier in sim.tiers.values():
            assert tier.used_bytes <= tier.capacity_bytes
            # every in-flight pin was released at round end
            assert tier.pinned_bytes() == 0, policy


def test_think_time_delays_next_round_submission():
    """A round's think gap separates the previous completion from the
    next submission — the idle window the prefetcher uses."""
    from repro.sim.traces import Round, Trajectory
    traj = Trajectory(0, [Round(256, 8), Round(64, 8, think=5.0)])
    cfg = SimConfig(node=HOPPER_NODE, model=DS_660B, P=1, D=1,
                    mode="dualpath")
    sim = Sim(cfg, [traj]).run()
    assert sim.results()["finished_agents"] == 1
    r0, r1 = sim.rounds[0], sim.rounds[1]
    assert r1.submit_t - r0.done_t >= 5.0 - 1e-9
