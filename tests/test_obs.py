"""Flight recorder (repro.obs): determinism, schema, attribution,
audit.

Pins the ISSUE-7 tentpole contracts:

* **byte-determinism** — the same (workload, seed, FaultSchedule)
  yields a byte-identical exported trace;
* **zero overhead when disabled** — an untraced run's ``results()`` /
  ``stats()`` are numerically identical to a traced run's;
* **schema two-way closure** — both runtimes emit exactly the
  registered metric keys: no unregistered keys (``conforming``
  raises), no orphaned registrations (``orphans`` is empty);
* **attribution exactness** — the TTFT decomposition is a partition:
  components sum to the window exactly, category priority and the
  queue residual behave as documented;
* **audit** — span/event byte sums equal the runtimes' conservation
  ledgers, and any tampering (dropped or inflated record) raises
  :class:`TraceAuditError`;
* **fault annotation** — a FaultSchedule's windows and deaths appear
  as spans/events with the schedule's exact boundaries.
"""
import math

import numpy as np
import pytest

from repro.obs import (Counter, Gauge, Histogram, MetricsRegistry,
                       TraceAuditError, Tracer, attribute_ttft,
                       audit_sim, bottleneck_report, conforming, orphans,
                       registered_keys)
from repro.core.config import ResilienceConfig
from repro.sim import DS_660B, HOPPER_NODE, Sim, SimConfig
from repro.sim.faults import EngineDeath, FaultSchedule, SlowdownWindow
from repro.sim.traces import Round, Trajectory


def _trajs(n=6, rounds=((2048, 16), (256, 16), (256, 16))):
    return [Trajectory(i, [Round(*r) for r in rounds]) for i in range(n)]


def _sim(tracer=None, faults=None, **kw):
    cfg = SimConfig(node=HOPPER_NODE, model=DS_660B, P=1, D=2,
                    mode="dualpath",
                    resilience=ResilienceConfig(faults=faults), **kw)
    return Sim(cfg, _trajs(), tracer=tracer).run()


# ---------------------------------------------------------------------------
# tracer unit behaviour
# ---------------------------------------------------------------------------

def test_tracer_requires_bound_clock_for_default_timestamps():
    tr = Tracer()
    with pytest.raises(RuntimeError):
        tr.event("x", "no-clock")
    tr.event("x", "explicit", t=1.5)     # explicit t needs no clock
    tr.bind_clock(lambda: 2.0)
    tr.event("x", "bound")
    assert [(t, n) for _, n, t, _ in tr.iter_events()] == \
        [(1.5, "explicit"), (2.0, "bound")]


def test_span_event_counter_separation():
    tr = Tracer(now_fn=lambda: 0.0)
    tr.span("a/t", "s", 1.0, 2.0, k=1)
    tr.event("a/t", "e", t=1.5)
    tr.counter("a/q", t=1.0, depth=3)
    assert [n for _, n, *_ in tr.iter_spans()] == ["s"]
    assert [n for _, n, *_ in tr.iter_events()] == ["e"]
    trace = tr.to_chrome_trace()["traceEvents"]
    assert [r["ph"] for r in trace if r["ph"] != "M"] == ["X", "C", "i"]
    # hierarchical tracks: one pid per first path component
    meta = {r["name"]: r for r in trace if r["ph"] == "M"}
    assert meta["process_name"]["args"]["name"] == "a"


def test_export_bytes_deterministic_under_record_content():
    def build():
        tr = Tracer(now_fn=lambda: 0.0)
        tr.span("snic/node0", "nic_xfer", 0.0, 1.0, tag="read",
                nbytes=10)
        tr.event("req/1", "first_token", t=1.0)
        tr.counter("snic/node0/queue", t=1.0, queued_bytes=5)
        return tr.export_bytes()
    assert build() == build()


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_metrics_primitives():
    c = Counter("gen_tokens")
    c.inc(); c.inc(4)
    assert c.value == 5
    with pytest.raises(ValueError):
        c.inc(-1)
    g = Gauge("net_congestion")
    assert math.isnan(g.value)
    g.set(0.25)
    assert g.value == 0.25
    h = Histogram("ttft_s")
    assert math.isnan(h.percentile(50))
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    assert h.percentile(50) == 2.0          # nearest-rank
    assert h.percentile(100) == 4.0
    s = h.summary()
    assert s["count"] == 4 and s["mean"] == 2.5


def test_registry_get_or_create_and_kind_conflicts():
    r = MetricsRegistry()
    c = r.counter("doorbells")
    assert r.counter("doorbells") is c
    with pytest.raises(TypeError):
        r.gauge("doorbells")
    r.gauge("wall_s").set(1.0)
    c.inc(3)
    snap = r.snapshot()
    assert snap["doorbells"] == 3 and snap["wall_s"] == 1.0
    assert list(snap) == sorted(snap)


# ---------------------------------------------------------------------------
# metric-key schema: two-way closure on both runtimes
# ---------------------------------------------------------------------------

def test_conforming_rejects_unregistered_keys():
    with pytest.raises(KeyError, match="not_a_registered_metric"):
        conforming({"not_a_registered_metric": 1}, "sim")


def test_sim_results_schema_two_way():
    r = _sim().results()
    assert conforming(r, "sim") is r        # no unregistered keys
    assert orphans(r, "sim") == set()       # no registered-but-missing


def test_serving_stats_schema_two_way(serving_run):
    st = serving_run["st"]
    assert conforming(st, "serving") is st
    assert orphans(st, "serving") == set()
    # the shared keys really are shared
    shared = registered_keys("sim") & registered_keys("serving")
    assert {"gen_tokens", "ttft_mean", "finished_rounds"} <= shared


# ---------------------------------------------------------------------------
# determinism + zero overhead (simulator; the serving side of both
# properties is pinned by benchmarks/fig_bottleneck.py --smoke in CI)
# ---------------------------------------------------------------------------

def test_sim_trace_byte_identical_across_runs():
    tr1, tr2 = Tracer(), Tracer()
    _sim(tracer=tr1)
    _sim(tracer=tr2)
    b = tr1.export_bytes()
    assert b == tr2.export_bytes()
    assert b.endswith(b"\n") and len(b) > 1000


def test_sim_results_identical_with_and_without_tracer():
    r0 = _sim().results()
    r1 = _sim(tracer=Tracer()).results()
    for k in r0:
        if isinstance(r0[k], float) and math.isnan(r0[k]):
            assert math.isnan(r1[k]), k
        else:
            assert r0[k] == r1[k], k


# ---------------------------------------------------------------------------
# fault annotation
# ---------------------------------------------------------------------------

def test_fault_schedule_annotation_boundaries():
    fs = FaultSchedule(
        windows=[SlowdownWindow("snic", 2.0, 5.0, 8.0, node=1),
                 SlowdownWindow("net", 1.0, 3.0, 2.0)],
        deaths=[EngineDeath(4.5, (1, 0))])
    tr = Tracer()
    tr.annotate_faults(fs)
    spans = {(trk, t0, t1): args for trk, _, t0, t1, args
             in tr.iter_spans(None, "fault_window")}
    assert spans[("faults/snic", 2.0, 5.0)] == {"factor": 8.0, "node": 1}
    assert spans[("faults/net", 1.0, 3.0)] == {"factor": 2.0,
                                               "node": "all"}
    deaths = [(t, args) for _, _, t, args
              in tr.iter_events("engine_death_scheduled")]
    assert deaths == [(4.5, {"engine": [1, 0]})]


def test_sim_death_and_recovery_events_recorded():
    tr = Tracer()
    sim = _sim(tracer=tr,
               faults=FaultSchedule(deaths=[EngineDeath(1.0, (1, 0))]))
    r = sim.results()
    assert r["engine_deaths"] == 1
    deaths = [args for _, _, _, args in tr.iter_events("engine_death")]
    assert deaths and deaths[0]["engine"] == [1, 0]
    recovered = list(tr.iter_events("recovered"))
    assert len(recovered) == r["recovered_rounds"]
    audit_sim(sim, tr)                      # ledgers still exact


# ---------------------------------------------------------------------------
# attribution: exact partition, priority, residual
# ---------------------------------------------------------------------------

def _synthetic_tracer():
    """One request with hand-built spans:

      window [0, 10]; read_leg [1, 4]; prefill [3, 7] (overlaps the
      read 1 s); pd_transfer [7, 8]; drain [8.5, 9] on the global
      track; first_token at 10.
    Priority storage > compute > net > drain > queue gives
      storage 3, compute 3, net 1, drain 0.5, queue 2.5.
    """
    tr = Tracer(now_fn=lambda: 0.0)
    tr.span("req/5", "scheduled", 0.0, 1.0)
    tr.span("req/5", "read_leg", 1.0, 4.0, side="pe", nbytes=10)
    tr.span("req/5", "prefill", 3.0, 7.0)
    tr.span("req/5", "pd_transfer", 7.0, 8.0)
    tr.span("reconfig", "drain", 8.5, 9.0, engine=[0, 0])
    tr.event("req/5", "first_token", t=10.0)
    return tr


def test_attribution_hand_computed_partition():
    per = attribute_ttft(_synthetic_tracer())
    rec = per[5]
    assert rec["ttft_s"] == pytest.approx(10.0)
    assert rec["storage_s"] == pytest.approx(3.0)
    assert rec["compute_s"] == pytest.approx(3.0)   # overlap -> storage
    assert rec["net_s"] == pytest.approx(1.0)
    assert rec["drain_s"] == pytest.approx(0.5)
    assert rec["queue_s"] == pytest.approx(2.5)
    parts = sum(rec[c] for c in ("storage_s", "compute_s", "net_s",
                                 "drain_s", "queue_s"))
    assert parts == pytest.approx(rec["ttft_s"], abs=1e-12)
    rep = bottleneck_report(per)
    assert rep["n"] == 1
    assert rep["bottleneck"] in ("storage", "compute")
    assert rep["max_decomp_err_s"] < 1e-12


def test_attribution_empty_report_is_nan_not_crash():
    rep = bottleneck_report({})
    assert rep["n"] == 0 and rep["bottleneck"] == "none"
    assert math.isnan(rep["ttft_mean_s"])


def test_sim_attribution_matches_measured_ttft_exactly():
    tr = Tracer()
    sim = _sim(tracer=tr)
    r = sim.results()
    rep = bottleneck_report(attribute_ttft(tr))
    assert rep["n"] == r["finished_rounds"]
    assert rep["max_decomp_err_s"] < 1e-9
    assert rep["ttft_mean_s"] == pytest.approx(r["ttft_mean"], rel=1e-9)


# ---------------------------------------------------------------------------
# audit: exactness + tamper detection
# ---------------------------------------------------------------------------

def test_sim_audit_passes_and_detects_tampering():
    tr = Tracer()
    sim = _sim(tracer=tr)
    out = audit_sim(sim, tr)
    by_node = out["snic_bytes_by_node"]
    assert sum(t.get("read", 0) for t in by_node.values()) > 0
    # inflate one NIC span's byte count -> the ledger check must fail
    for i, (seq, track, name, t0, t1, args) in enumerate(tr.spans):
        if name == "nic_xfer" and args.get("tag") == "read":
            tampered = dict(args, nbytes=args["nbytes"] + 1)
            tr.spans[i] = (seq, track, name, t0, t1, tampered)
            break
    with pytest.raises(TraceAuditError, match="read span bytes"):
        audit_sim(sim, tr)


def test_sim_audit_rejects_unknown_tags():
    tr = Tracer()
    sim = _sim(tracer=tr)
    tr.span("snic/node0", "nic_xfer", 0.0, 1.0, tag="mystery", nbytes=0)
    with pytest.raises(TraceAuditError, match="unknown"):
        audit_sim(sim, tr)


# ---------------------------------------------------------------------------
# serving runtime (one traced online run, shared across tests)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def serving_run():
    import jax
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving import ServingSystem
    from repro.sim.spec import REDUCED_TEST_NODE

    cfg = get_config("qwen1.5-0.5b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))

    def run(tracer):
        s = ServingSystem(cfg, params, n_pe=1, n_de=2, block_tokens=16,
                          max_seq=160, de_slots=2, seed=0,
                          split_reads=True, node=REDUCED_TEST_NODE,
                          tracer=tracer)
        trajs = [Trajectory(i, [Round(24, 6, 0.5), Round(16, 4, 0.0)])
                 for i in range(4)]
        sessions = s.run_online(trajs, [0.0, 0.1, 0.2, 0.3])
        return s, [list(x.context) for x in sessions]

    tr = Tracer()
    sys_, tokens = run(tr)
    sys0, tokens0 = run(None)
    return {"system": sys_, "tracer": tr, "st": sys_.stats(),
            "tokens": tokens, "untraced_st": sys0.stats(),
            "untraced_tokens": tokens0}


def test_serving_untraced_bit_identity(serving_run):
    assert serving_run["tokens"] == serving_run["untraced_tokens"]
    st, st0 = serving_run["st"], serving_run["untraced_st"]
    for k in st0:
        if isinstance(st0[k], float) and math.isnan(st0[k]):
            assert math.isnan(st[k]), k
        else:
            assert st0[k] == st[k], k


def test_serving_lifecycle_spans_cover_the_state_machine(serving_run):
    tr = serving_run["tracer"]
    names = {n for _, n, *_ in tr.iter_spans("req/")}
    # persist/reading can legitimately be zero-width (state entered and
    # left within one tick) and zero-width state spans are elided
    assert {"scheduled", "prefill", "decode"} <= names
    # TTFT endpoints: one first_token per finished round
    firsts = list(tr.iter_events("first_token"))
    assert len(firsts) == serving_run["st"]["finished_rounds"]


def test_serving_audit_and_attribution(serving_run):
    from repro.obs import audit_serving
    st = serving_run["st"]
    out = audit_serving(serving_run["system"], serving_run["tracer"],
                        check_persists=True)
    assert out["persist_bytes"] == st["store_writes"]
    rep = bottleneck_report(attribute_ttft(serving_run["tracer"]))
    assert rep["n"] == st["finished_rounds"]
    assert rep["max_decomp_err_s"] < 1e-9
    assert rep["ttft_mean_s"] == pytest.approx(st["ttft_mean"], rel=1e-9)


def test_serving_audit_detects_missing_read_event(serving_run):
    from repro.obs import audit_serving
    tr = serving_run["tracer"]
    snap = list(tr.spans)
    try:
        for i, (seq, track, name, t0, t1, args) in enumerate(tr.spans):
            if name == "storage_read":
                del tr.spans[i]
                break
        with pytest.raises(TraceAuditError, match="storage_read"):
            audit_serving(serving_run["system"], tr,
                          check_persists=False)
    finally:
        tr.spans[:] = snap
