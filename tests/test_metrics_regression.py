"""Fixture-pinned regression tests for the latency estimators.

``latency_summary`` / ``slo_attainment`` (serving/events.py) are the
single definition of TTFT/TTST/TPOT and SLO attainment for BOTH
runtimes — ``ServingSystem.stats()`` embeds the summary and
``Sim.slo_attainment`` routes through the same functions.  The
contention-aware time model (repro.network) now feeds these estimators,
so their arithmetic is pinned here against hand-computed values: any
silent shift in percentile interpolation, TPOT denominators or SLO
judging breaks a fixture, not a downstream benchmark."""
import numpy as np
import pytest

from repro.serving.events import (RoundMetrics, latency_summary,
                                  slo_attainment)


def _round(rid, submit, prefill, first, second, done, gen):
    return RoundMetrics(rid=rid, gen_tokens=gen, submit_t=submit,
                        prefill_done_t=prefill, first_decode_t=first,
                        second_token_t=second, done_t=done)


# Five finished rounds with hand-computed latencies
# (TPOT = (done - first_decode) / (gen - 1)):
#   rid  submit prefill first second done   gen   TTFT  TTST  TPOT
#   0    0.0    1.0     1.5   2.0    5.5    9     1.0   2.0   0.5
#   1    1.0    3.0     3.5   4.0    7.5    5     2.0   3.0   1.0
#   2    2.0    5.0     5.25  5.5    9.25   17    3.0   3.5   0.25
#   3    3.0    7.0     7.5   8.0    11.5   11    4.0   5.0   0.4
#   4    4.0    14.0    15.0  16.0   18.0   2     10.0  12.0  3.0
FIXTURE = [
    _round(0, 0.0, 1.0, 1.5, 2.0, 5.5, 9),
    _round(1, 1.0, 3.0, 3.5, 4.0, 7.5, 5),
    _round(2, 2.0, 5.0, 5.25, 5.5, 9.25, 17),
    _round(3, 3.0, 7.0, 7.5, 8.0, 11.5, 11),
    _round(4, 4.0, 14.0, 15.0, 16.0, 18.0, 2),
]
TTFTS = [1.0, 2.0, 3.0, 4.0, 10.0]
TTSTS = [2.0, 3.0, 3.5, 5.0, 12.0]
TPOTS = [0.5, 1.0, 0.25, 0.4, 3.0]


def test_per_round_latency_definitions():
    for m, ttft, ttst, tpot in zip(FIXTURE, TTFTS, TTSTS, TPOTS):
        assert m.finished
        assert m.ttft == pytest.approx(ttft)
        assert m.ttst == pytest.approx(ttst)
        assert m.tpot == pytest.approx(tpot)


def test_latency_summary_pinned_values():
    s = latency_summary(FIXTURE)
    assert s["finished_rounds"] == 5
    assert s["ttft_mean"] == pytest.approx(4.0)        # (1+2+3+4+10)/5
    # numpy's default (linear-interpolation) percentile at q=99 over a
    # sorted 5-sample vector: x[3] + (4 - 3.96)... rank = 0.99*4 = 3.96
    # -> 4 + 0.96 * (10 - 4) = 9.76
    assert s["ttft_p99"] == pytest.approx(9.76)
    assert s["ttst_mean"] == pytest.approx(np.mean(TTSTS))
    assert s["tpot_mean"] == pytest.approx(np.mean(TPOTS))
    # sorted TPOTs: [0.25, 0.4, 0.5, 1.0, 3.0]; rank 3.96 ->
    # 1.0 + 0.96 * (3.0 - 1.0) = 2.92
    assert s["tpot_p99"] == pytest.approx(2.92)


def test_unfinished_rounds_are_excluded():
    metrics = FIXTURE + [
        RoundMetrics(rid=9, gen_tokens=4, submit_t=5.0, prefill_done_t=6.0),
    ]
    s = latency_summary(metrics)
    assert s["finished_rounds"] == 5
    assert s["ttft_mean"] == pytest.approx(4.0)        # unchanged
    assert np.isnan(slo_attainment([metrics[-1]], 1.0, 1.0))


def test_single_token_round_has_no_tpot():
    m = _round(0, 0.0, 1.0, 1.5, -1.0, 1.5, 1)
    assert m.tpot is None
    s = latency_summary([m])
    assert s["finished_rounds"] == 1
    assert np.isnan(s["tpot_mean"])


def test_slo_attainment_pinned():
    """Hand-judged against TTFT<=3.5, TPOT<=0.6:
    rid 0: ttft 1.0 ok, tpot 0.5 ok    -> pass
    rid 1: ttft 2.0 ok, tpot 1.0 fail  -> fail
    rid 2: ttft 3.0 ok, tpot 0.25 ok   -> pass
    rid 3: ttft 4.0 fail               -> fail
    rid 4: ttft 10.0 fail              -> fail
    => 2/5."""
    assert slo_attainment(FIXTURE, 3.5, 0.6) == pytest.approx(0.4)
    # all pass / all fail endpoints
    assert slo_attainment(FIXTURE, 100.0, 100.0) == 1.0
    assert slo_attainment(FIXTURE, 0.0, 0.0) == 0.0


def test_slo_judges_single_token_rounds_on_ttft_alone():
    single = _round(0, 0.0, 1.0, 1.5, -1.0, 1.5, 1)
    assert slo_attainment([single], ttft_slo_s=2.0,
                          tpot_slo_s=1e-9) == 1.0
    assert slo_attainment([single], ttft_slo_s=0.5,
                          tpot_slo_s=1e9) == 0.0


# ---------------------------------------------------------------------------
# recovery accounting (engine death -> resubmit, sim/faults.py)
# ---------------------------------------------------------------------------
# When an engine dies, the runtime resubmits its in-flight rounds under
# the ORIGINAL submission time, and milestone stamps are set-once: a
# milestone reached before the death keeps its first-attempt value, one
# never reached is stamped by the recovery attempt.  ``done_t`` is
# always the true completion, so the recovery gap lands in TPOT (for a
# mid-decode death) or TTFT (for a pre-prefill death) — the SLO judge
# sees the fault, never a reset clock.
#
# Hand-computed single-fault scenario (death at t=5.0):
#   rid 7 — mid-decode death.  submit 1.0, prefill 3.0, first token
#     3.5, second 4.0 (all pre-death stamps survive); recovery finishes
#     the round at done 20.0 with gen 9.
#       TTFT = 3.0 - 1.0 = 2.0        (unchanged by the fault)
#       TTST = 4.0 - 1.0 = 3.0
#       TPOT = (20.0 - 3.5) / 8 = 2.0625   (recovery gap included)
#   rid 8 — death before prefill.  submit 2.0; no stamp existed, so the
#     recovery attempt stamps prefill 9.0, first 9.5, second 10.0,
#     done 12.0 with gen 6.
#       TTFT = 9.0 - 2.0 = 7.0        (the re-queue wait is charged)
#       TTST = 10.0 - 2.0 = 8.0
#       TPOT = (12.0 - 9.5) / 5 = 0.5
RECOVERY_FIXTURE = [
    _round(7, 1.0, 3.0, 3.5, 4.0, 20.0, 9),
    _round(8, 2.0, 9.0, 9.5, 10.0, 12.0, 6),
]


def test_recovery_round_latencies_pinned():
    mid, pre = RECOVERY_FIXTURE
    assert mid.finished and pre.finished
    assert mid.ttft == pytest.approx(2.0)
    assert mid.ttst == pytest.approx(3.0)
    assert mid.tpot == pytest.approx(2.0625)
    assert pre.ttft == pytest.approx(7.0)
    assert pre.ttst == pytest.approx(8.0)
    assert pre.tpot == pytest.approx(0.5)
    s = latency_summary(RECOVERY_FIXTURE)
    assert s["finished_rounds"] == 2
    assert s["ttft_mean"] == pytest.approx(4.5)
    assert s["tpot_mean"] == pytest.approx(1.28125)


def test_recovery_slo_judging_pinned():
    """Hand-judged against TTFT<=3.0, TPOT<=1.0:
    rid 7: ttft 2.0 ok, tpot 2.0625 fail  -> fail  (decode gap counted)
    rid 8: ttft 7.0 fail                  -> fail  (requeue wait counted)
    => 0/2; relaxing TPOT admits rid 7 only => 1/2."""
    assert slo_attainment(RECOVERY_FIXTURE, 3.0, 1.0) == 0.0
    assert slo_attainment(RECOVERY_FIXTURE, 3.0, 2.1) == pytest.approx(0.5)
    assert slo_attainment(RECOVERY_FIXTURE, 7.5, 2.1) == 1.0


def test_empty_summary_propagates_nan_not_crash():
    """The NaN contract: no finished rounds -> every mean/percentile is
    NaN, finished_rounds is 0, and nothing raises — downstream (stats(),
    fig_* smokes, the perf gate) sees NaN, never an exception."""
    for metrics in ([], [RoundMetrics(rid=0, gen_tokens=4, submit_t=0.0)]):
        s = latency_summary(metrics)
        assert s["finished_rounds"] == 0
        for k in ("ttft_mean", "ttft_p99", "ttst_mean", "tpot_mean",
                  "tpot_p99"):
            assert np.isnan(s[k]), (k, s)
        assert np.isnan(slo_attainment(metrics, 1.0, 1.0))


def test_finished_round_without_prefill_stamp_is_excluded():
    """A finished round whose prefill milestone was never stamped must
    not feed a garbage negative TTFT into the summary."""
    broken = RoundMetrics(rid=0, gen_tokens=2, submit_t=1.0,
                          first_decode_t=2.0, done_t=3.0)
    assert broken.finished and broken.prefill_done_t < 0
    s = latency_summary([broken])
    assert s["finished_rounds"] == 1        # it did finish...
    assert np.isnan(s["ttft_mean"])         # ...but has no TTFT
    s2 = latency_summary(FIXTURE + [broken])
    assert s2["ttft_mean"] == pytest.approx(4.0)    # fixture unchanged


def test_perf_gate_rejects_nan_against_finite_baseline():
    """The gate's comparator must not let a gated metric decay to NaN
    slip through NaN-compares-false arithmetic (the documented exit of
    the NaN contract)."""
    import sys
    sys.path.insert(0, str(__import__("pathlib").Path(
        __file__).resolve().parents[1]))
    from benchmarks.perf_gate import SCHEMA, compare
    base = {"schema": SCHEMA, "metrics": {
        "fig_online_serving": {"slo_attainment": 1.0}}}
    cur = {"schema": SCHEMA, "metrics": {
        "fig_online_serving": {"slo_attainment": float("nan")}}}
    assert compare(base, cur)               # NaN vs finite: regression
    assert not compare(base, base)          # finite vs itself: pass
    nan_both = {"schema": SCHEMA, "metrics": {
        "fig_online_serving": {"slo_attainment": float("nan")}}}
    assert not compare(nan_both, nan_both)  # NaN vs NaN: recorded only


def test_summary_mirrors_sim_results_estimators():
    """The serving summary and Sim.results() compute TTFT/TPOT/TTST the
    same way: means and percentiles over the same per-round values."""
    ttfts = np.array(TTFTS)
    assert latency_summary(FIXTURE)["ttft_p99"] == pytest.approx(
        float(np.percentile(ttfts, 99)))
