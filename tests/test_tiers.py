"""Tiered KV-cache (kvcache/tiers.py): pinning, eviction, conservation.

The two load-bearing invariants (ISSUE acceptance):

* **pinned blocks survive arbitrary eviction pressure** — ref-counted
  pins make in-flight / trie-held blocks ineligible victims, no matter
  how much admission pressure the tier sees;
* **byte accounting conserves exactly** — a tiered loading plan's
  DRAM-served + SNIC-served load bytes equal the hit bytes, and the
  plan's non-storage resources are byte-identical to the equivalent
  split plan (the tier only changes *where* hit bytes come from, never
  how many move downstream).
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.blocks import BlockLayout
from repro.core.loading import (plan_for,
                                resource_bytes, split_read_plan,
                                tiered_read_plan)
from repro.core.scheduler import Request, Scheduler
from repro.kvcache.store import MemoryKVStore
from repro.kvcache.tiers import (AgenticTTLPolicy, DramTier, LRUPolicy,
                                 ThinkTimePrefetcher, make_policy)

BLOCK = 100          # bytes per block in the accounting-only tests


# ---------------------------------------------------------------------------
# pinning under pressure (property)
# ---------------------------------------------------------------------------


@given(cap_blocks=st.integers(2, 40),
       n_pinned=st.integers(1, 10),
       pressure=st.integers(0, 300),
       policy=st.sampled_from(["lru", "agentic-ttl"]))
@settings(max_examples=60, deadline=None)
def test_pinned_blocks_survive_arbitrary_eviction_pressure(
        cap_blocks, n_pinned, pressure, policy):
    tier = DramTier(cap_blocks * BLOCK, policy=policy)
    n_pinned = min(n_pinned, cap_blocks)
    pinned = [("pin", i) for i in range(n_pinned)]
    for ref in pinned:
        assert tier.admit(ref, BLOCK, owner="infl")
    tier.pin(pinned)
    # arbitrary admission pressure from other owners
    for i in range(pressure):
        tier.admit(("flood", i), BLOCK, owner=f"o{i % 7}")
        tier.note_done(f"o{i % 3}")     # some trajectories die mid-flood
    for ref in pinned:
        assert tier.contains(ref), f"pinned block {ref} was evicted"
    assert tier.used_bytes <= tier.capacity_bytes
    # after unpinning, the same pressure CAN evict them
    tier.unpin(pinned)
    for i in range(cap_blocks + n_pinned):
        tier.admit(("flood2", i), BLOCK, owner="o-new")
    if pressure >= cap_blocks:          # tier was genuinely full
        assert not all(tier.contains(r) for r in pinned)


def test_fully_pinned_tier_rejects_rather_than_evicts():
    tier = DramTier(3 * BLOCK)
    refs = ["a", "b", "c"]
    for r in refs:
        tier.admit(r, BLOCK)
    tier.pin(refs)
    assert not tier.admit("d", BLOCK)
    assert tier.rejected_bytes == BLOCK
    assert all(tier.contains(r) for r in refs)
    tier.unpin(["a"])
    assert tier.admit("d", BLOCK)       # now "a" is a legal victim
    assert not tier.contains("a")


# ---------------------------------------------------------------------------
# byte conservation (property)
# ---------------------------------------------------------------------------


@given(hit=st.integers(0, 10 ** 9), miss=st.integers(0, 10 ** 7),
       gen=st.integers(0, 10 ** 7), data=st.data())
@settings(max_examples=100, deadline=None)
def test_tiered_plan_conserves_and_matches_split_plan(hit, miss, gen, data):
    """dram-served + snic-served == hit bytes exactly, and every
    non-storage resource moves the same bytes as the pure split plan
    with the same per-side totals."""
    a = data.draw(st.integers(0, hit)) if hit else 0
    b = data.draw(st.integers(0, hit - a)) if hit - a else 0
    c = data.draw(st.integers(0, hit - a - b)) if hit - a - b else 0
    pe_snic, de_snic, pe_tier, de_tier = a, b, c, hit - a - b - c
    plan = tiered_read_plan(hit, miss, gen, pe_snic, de_snic,
                            pe_tier, de_tier)
    rb = resource_bytes(plan)
    # load-phase conservation, byte-exact (the de_snic resource also
    # carries decode-phase persists, so restrict to load legs)
    load = resource_bytes([leg for leg in plan if leg.phase == "load"])
    storage = {k: v for k, v in load.items()
               if k in ("pe_snic", "de_snic", "pe_tier", "de_tier")}
    assert sum(storage.values()) == hit
    assert load.get("pe_snic", 0) == pe_snic
    assert load.get("de_snic", 0) == de_snic
    assert load.get("pe_tier", 0) == pe_tier
    assert load.get("de_tier", 0) == de_tier
    # non-storage resources identical to the split plan at the same
    # per-side totals — minus the side's DRAM staging write the SNIC
    # leg would have performed (tier bytes are already in DRAM)
    rb_split = resource_bytes(split_read_plan(hit, miss, gen,
                                              pe_snic + pe_tier))
    for k in set(rb) | set(rb_split):
        if k.endswith("_tier"):
            continue
        if k == "pe_snic":
            assert rb_split.get(k, 0) - rb.get(k, 0) == pe_tier
            continue
        if k == "de_snic":
            # split plan's de_snic carries the de hit share + persists;
            # the tiered plan omits the tier-served share
            assert rb_split.get(k, 0) - rb.get(k, 0) == de_tier
            continue
        if k == "pe_dram":
            assert rb_split.get(k, 0) - rb.get(k, 0) == pe_tier
            continue
        if k == "de_dram":
            assert rb_split.get(k, 0) - rb.get(k, 0) == de_tier
            continue
        assert rb.get(k, 0) == rb_split.get(k, 0), k


def test_tiered_plan_zero_tier_equals_split_plan():
    for pe_b in (0, 37, 500, 1000):
        assert tiered_read_plan(1000, 10, 5, pe_b, 1000 - pe_b, 0, 0) == \
            split_read_plan(1000, 10, 5, pe_b)


def test_plan_for_tier_dispatch():
    """plan_for(tier=...) is the single dispatch the sim shares with the
    tests — identical to calling tiered_read_plan directly."""
    part = (300, 200, 400, 100)
    assert plan_for("pe", 0.7, 1000, 10, 5, tier=part) == \
        tiered_read_plan(1000, 10, 5, *part)


@given(cap_blocks=st.integers(1, 30), n_reads=st.integers(0, 60))
@settings(max_examples=40, deadline=None)
def test_backing_tier_read_accounting_conserves(cap_blocks, n_reads):
    """Every byte requested through the tier is either a DRAM hit or a
    backing (SNIC) read: dram_hit + miss == total requested."""
    layout = BlockLayout(n_layers=2, block_tokens=4, bytes_per_token_layer=8)
    store = MemoryKVStore(layout)
    refs = []
    for _ in range(12):
        r = store.alloc_ref()
        store.write_block(r, np.zeros(layout.full_block_shape(), np.uint8))
        refs.append(r)
    tier = DramTier(cap_blocks * layout.full_block_bytes, backing=store)
    base_reads = store.bytes_read
    rng = np.random.default_rng(0)
    requested = 0
    for _ in range(n_reads):
        ref = refs[int(rng.integers(0, len(refs)))]
        blk = tier.read_block(ref)
        assert blk.shape == layout.full_block_shape()
        requested += layout.full_block_bytes
    assert tier.dram_hit_bytes + tier.miss_bytes == requested
    assert store.bytes_read - base_reads == tier.miss_bytes
    assert tier.used_bytes <= tier.capacity_bytes


# ---------------------------------------------------------------------------
# eviction policies
# ---------------------------------------------------------------------------


def test_lru_evicts_least_recently_used_first():
    tier = DramTier(3 * BLOCK, policy="lru")
    for r in ("a", "b", "c"):
        tier.admit(r, BLOCK)
    tier.touch(["a"])                  # a is now the most recent
    tier.admit("d", BLOCK)             # evicts b (oldest untouched)
    assert tier.contains("a") and not tier.contains("b")
    tier.admit("e", BLOCK)             # evicts c
    assert not tier.contains("c")
    assert tier.contains("a")


def test_agentic_ttl_evicts_dead_trajectories_before_live_ones():
    tier = DramTier(4 * BLOCK, policy="agentic-ttl", ttl_s=100.0)
    tier.admit("live1", BLOCK, owner="t_live", now=0.0)
    tier.admit("dead1", BLOCK, owner="t_dead", now=1.0)
    tier.admit("dead2", BLOCK, owner="t_dead", now=2.0)
    tier.admit("live2", BLOCK, owner="t_live", now=3.0)
    tier.note_alive("t_live", now=3.0)
    tier.note_done("t_dead")
    # dead blocks are MORE recent than live1, yet they go first
    tier.admit("new1", BLOCK, owner="t_live", now=4.0)
    tier.admit("new2", BLOCK, owner="t_live", now=4.0)
    assert not tier.contains("dead1") and not tier.contains("dead2")
    assert tier.contains("live1") and tier.contains("live2")


def test_agentic_ttl_expires_idle_trajectories():
    tier = DramTier(2 * BLOCK, policy="agentic-ttl", ttl_s=10.0)
    tier.admit("idle", BLOCK, owner="t_idle", now=0.0)
    tier.note_alive("t_idle", now=0.0)
    tier.admit("act", BLOCK, owner="t_act", now=50.0)
    tier.note_alive("t_act", now=50.0)
    # t_idle has been idle for 50s > ttl: evicted before the LRU choice
    tier.admit("new", BLOCK, owner="t_act", now=51.0)
    assert not tier.contains("idle")
    assert tier.contains("act")


def test_make_policy():
    assert isinstance(make_policy("lru"), LRUPolicy)
    assert isinstance(make_policy("agentic-ttl"), AgenticTTLPolicy)
    assert make_policy("agentic-ttl", ttl_s=5.0).ttl_s == 5.0
    with pytest.raises(ValueError):
        make_policy("fifo")


# ---------------------------------------------------------------------------
# resident prefix + prefetch planning
# ---------------------------------------------------------------------------


def test_resident_prefix_counts_only_leading_blocks():
    tier = DramTier(10 * BLOCK)
    refs = [("t", i) for i in range(5)]
    for r in (refs[0], refs[1], refs[3]):   # hole at index 2
        tier.admit(r, BLOCK)
    assert tier.resident_prefix(refs) == 2
    tier.admit(refs[2], BLOCK)
    assert tier.resident_prefix(refs) == 4


def test_prefetcher_plans_missing_blocks_in_chunked_order():
    tier = DramTier(100 * BLOCK)
    refs = [("t", i) for i in range(10)]
    for r in refs[:3]:
        tier.admit(r, BLOCK)
    pf = ThinkTimePrefetcher(chunk_blocks=4)
    chunks = pf.plan(tier, refs)
    assert [r for ch in chunks for r in ch] == refs[3:]
    assert all(len(ch) <= 4 for ch in chunks)
    assert pf.blocks_planned == 7
    # fully resident -> nothing to stage
    for r in refs:
        tier.admit(r, BLOCK)
    assert pf.plan(tier, refs) == []


# ---------------------------------------------------------------------------
# tier-aware read-path selection (scheduler integration)
# ---------------------------------------------------------------------------


def _sched(**kw):
    s = Scheduler(alpha=1 << 30, beta=1 << 30, **kw)
    s.register_engine((0, 0), node=0, kind="pe", group=0)
    st_ = s.register_engine((1, 0), node=1, kind="de", group=1000)
    st_.free_hbm_tokens = 1 << 30
    return s


def test_scheduler_prefers_side_whose_dram_holds_the_hit():
    s = _sched()
    r = Request(rid=0, cached_tokens=100, new_tokens=10, gen_tokens=10)
    r.pe, r.de = (0, 0), (1, 0)
    s.engines[(0, 0)].read_q = 0        # PE queue shorter...
    s.engines[(1, 0)].read_q = 50
    path = s.choose_read_path(r, tier_tokens={"pe": 0, "de": 60})
    assert path == "de"                 # ...but the DE tier holds the hit
    assert r.dram_side == "de" and r.dram_tokens == 60
    # the cold remainder is routed by queue depth (PE is idle), and only
    # SNIC tokens charge the disk reading queues
    assert r.read_tokens_by_side() == {"pe": 40, "de": 0}
    assert s.engines[(0, 0)].read_q == 40
    assert s.engines[(1, 0)].read_q == 50
    # partition sums to the full hit in bytes, tier side carries the hit
    assert r.hit_bytes_partition(7) == (40 * 7, 0, 0, 60 * 7)
    assert r.pe_read_frac == pytest.approx(0.4)
    # block-granular realisation agrees: 6 tier blocks, then 4 PE blocks
    assert r.hit_blocks_by_side(10) == {"tier": 6, "pe": 4, "de": 0}


def test_scheduler_tiny_tier_prefix_cannot_hijack_the_cold_remainder():
    """A 1-block warm prefix must not drag a 10k-token cold read onto a
    backlogged NIC: the remainder goes to the shorter queue, exactly as
    a tier-less read would."""
    s = _sched()
    r = Request(rid=0, cached_tokens=10016, new_tokens=10, gen_tokens=10)
    r.pe, r.de = (0, 0), (1, 0)
    s.engines[(0, 0)].read_q = 100_000      # PE badly backlogged
    s.engines[(1, 0)].read_q = 0
    s.choose_read_path(r, tier_tokens={"pe": 16, "de": 0})
    assert r.dram_side == "pe" and r.dram_tokens == 16
    assert r.read_tokens_by_side() == {"pe": 0, "de": 10000}
    assert s.engines[(0, 0)].read_q == 100_000      # untouched
    assert s.engines[(1, 0)].read_q == 10000


def test_scheduler_tier_with_split_reads_water_fills_remainder():
    s = _sched(split_reads=True)
    r = Request(rid=0, cached_tokens=100, new_tokens=10, gen_tokens=10)
    r.pe, r.de = (0, 0), (1, 0)
    s.choose_read_path(r, tier_tokens={"pe": 40, "de": 0})
    assert r.dram_side == "pe" and r.dram_tokens == 40
    tok = r.read_tokens_by_side()
    assert tok["pe"] + tok["de"] == 60          # remainder water-filled
    pe_s, de_s, pe_t, de_t = r.hit_bytes_partition(1)
    assert pe_s + de_s + pe_t + de_t == 100
    assert pe_t == 40 and de_t == 0


def test_scheduler_without_tier_tokens_behaves_as_before():
    s = _sched()
    r = Request(rid=0, cached_tokens=100, new_tokens=10, gen_tokens=10)
    r.pe, r.de = (0, 0), (1, 0)
    s.choose_read_path(r)
    assert r.dram_tokens == 0 and r.snic_tokens is None
    assert r.hit_bytes_partition(7) is None
    assert sum(r.read_tokens_by_side().values()) == 100
