"""The online SLO layer (PR 10): admission control, chunked prefill,
and SLO-class scheduling — unit contracts plus the conservation and
bit-identity properties that let every knob default to "structurally
off".

* ``AdmissionGate``: the queueing-network TTFT estimate, the
  admit/defer/reject escalation, and the per-arrival defer counter.
* Chunked prefill: slicing changes *when* tokens are computed, never
  *how many* — token totals and finished counts are conservation-exact
  against the unchunked run in the sim, and the serving runtime's
  generation is bit-identical offline (chunking only reorders compute
  inside one engine's deterministic fifo).
* Class-aware scheduling: priority ordering in the global queues, the
  PE prefill fifo, and the storage-NIC queue; the interactive share
  reported to the elastic controller double-counts into the pressure.
"""
import numpy as np
import pytest

from repro.core.admission import ADMIT, DEFER, REJECT, AdmissionGate
from repro.core.autoscale import LoadSignals
from repro.core.config import SloConfig, TierConfig
from repro.core.intra import PrefillWork, class_insert_index
from repro.core.scheduler import Request, Scheduler
from repro.sim import DS_660B, HOPPER_NODE, Sim, SimConfig
from repro.sim.spec import ModelSimSpec
from repro.sim.traces import Round, Trajectory, generate_dataset

SLO_TTFT = 0.5
SLO_TPOT = 0.050


def _mixed_workload(n):
    """Half interactive / half batch, batch heavy enough to contend."""
    inter = generate_dataset(n // 2, 6000, seed=1)
    batch = generate_dataset(n - n // 2, 16384, seed=2)
    trajs = []
    for t in inter:
        t = t.scaled(append_scale=0.5, gen_scale=0.4)
        t.slo_class = "interactive"
        trajs.append(t)
    for t in batch:
        t = t.scaled(append_scale=2.0, gen_scale=0.5)
        t.slo_class = "batch"
        trajs.append(t)
    for i, t in enumerate(trajs):
        t.tid = i
    return trajs


def _run_online(slo, n=96, aps=4.0):
    trajs = _mixed_workload(n)
    rng = np.random.default_rng(0)
    arr = list(np.cumsum(rng.exponential(1 / aps, size=len(trajs))))
    kw = {} if slo is None else dict(slo=slo)
    cfg = SimConfig(node=HOPPER_NODE, model=DS_660B, P=1, D=2,
                    mode="dualpath", online=True, beta_compute_s=1.0, **kw)
    sim = Sim(cfg, trajs)
    sim.run(arrivals=arr)
    return sim


# ---------------------------------------------------------------------------
# admission gate
# ---------------------------------------------------------------------------


def test_admission_estimate_is_backlog_over_servers_plus_own_service():
    gate = AdmissionGate(SloConfig(admission=True))
    sig = LoadSignals(n_pe=2, n_de=1, pe_queued_s=3.0, pe_busy_s=1.0,
                      de_queued_s=0.0, de_busy_s=0.0, pe_read_q_s=2.0)
    assert gate.ttft_estimate(sig, read_s=0.5, prefill_s=0.25) == \
        pytest.approx((3.0 + 1.0 + 2.0) / 2 + 0.5 + 0.25)


def test_admission_escalates_defer_to_reject():
    slo = SloConfig(admission=True, admission_ttft_slo_s=1.0,
                    admission_max_defers=3)
    gate = AdmissionGate(slo)
    key = (7, 0)
    assert gate.decide(key, 0.8) == ADMIT
    for _ in range(3):
        assert gate.decide(key, 2.0) == DEFER
    assert gate.decide(key, 2.0) == REJECT
    # the counter resets with the rejection: a fresh round starts over
    assert gate.decide(key, 2.0) == DEFER
    assert (gate.admitted_rounds, gate.deferred_rounds,
            gate.rejected_rounds) == (1, 4, 1)


def test_admission_clears_counter_on_admit():
    slo = SloConfig(admission=True, admission_ttft_slo_s=1.0,
                    admission_max_defers=2)
    gate = AdmissionGate(slo)
    assert gate.decide("k", 5.0) == DEFER
    assert gate.decide("k", 0.5) == ADMIT
    # post-admit the escalation starts from zero again
    assert gate.decide("k", 5.0) == DEFER
    assert gate.decide("k", 5.0) == DEFER
    assert gate.decide("k", 5.0) == REJECT


def test_sim_admission_sheds_load_and_lifts_attainment():
    base = _run_online(None)
    gated = _run_online(SloConfig(admission=True,
                                  admission_ttft_slo_s=SLO_TTFT,
                                  admission_defer_s=0.25,
                                  admission_max_defers=12))
    rb, rg = base.results(), gated.results()
    assert rb["deferred_rounds"] == rb["rejected_rounds"] == 0
    assert rg["deferred_rounds"] > 0 and rg["rejected_rounds"] > 0
    # shedding trades finished rounds for SLO attainment
    assert rg["finished_rounds"] < rb["finished_rounds"]
    assert gated.slo_attainment(SLO_TTFT, SLO_TPOT) > \
        base.slo_attainment(SLO_TTFT, SLO_TPOT)


# ---------------------------------------------------------------------------
# chunked prefill
# ---------------------------------------------------------------------------


def test_sim_chunked_prefill_is_conservation_exact():
    def run(**kw):
        trajs = generate_dataset(6, 8192, seed=4)
        return Sim(SimConfig(node=HOPPER_NODE, model=DS_660B, P=1, D=1,
                             mode="dualpath", **kw), trajs).run()

    plain = run()
    chunked = run(slo=SloConfig(prefill_chunk_tokens=512))
    rp, rc = plain.results(), chunked.results()
    assert rp["prefill_chunks"] == 0
    assert rc["prefill_chunks"] > 0
    # slicing moves prefill compute in time, never in amount
    for key in ("finished_agents", "finished_rounds", "prompt_tokens"):
        assert rc[key] == rp[key], key
    # every round still decodes its full requested generation (decode
    # block rounding may overshoot, in both runs alike — gen_left<=0)
    for sim in (plain, chunked):
        assert all(r.gen_left <= 0 for r in sim.rounds)
    assert sum(r.gen_total for r in chunked.rounds) == \
        sum(r.gen_total for r in plain.rounds)


def test_serving_chunked_prefill_is_bit_identical_and_enters_substate():
    jax = pytest.importorskip("jax")
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving import ServingSystem
    from repro.serving.events import ReqState
    from repro.sim.spec import REDUCED_TEST_NODE

    cfg = get_config("qwen1.5-0.5b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    traj = [Trajectory(0, [Round(40, 4, 0.0)])]

    def run(slo, record=None):
        s = ServingSystem(cfg, params, n_pe=1, n_de=1, block_tokens=16,
                          max_seq=96, de_slots=2, seed=0,
                          node=REDUCED_TEST_NODE,
                          **({} if slo is None else dict(slo=slo)))
        if record is not None:
            orig = s._set_state
            s._set_state = lambda er, st: (record.append(st), orig(er, st))
        out = s.run_offline([Trajectory(t.tid, list(t.rounds))
                             for t in traj])
        return out[0].context, s.stats()

    plain_ctx, plain_stats = run(None)
    states = []
    chunk_ctx, chunk_stats = run(SloConfig(prefill_chunk_tokens=16), states)
    assert chunk_ctx == plain_ctx          # generation is untouched
    assert plain_stats["prefill_chunks"] == 0
    assert chunk_stats["prefill_chunks"] > 0
    assert ReqState.PREFILL_CHUNKED in states


# ---------------------------------------------------------------------------
# class-aware scheduling
# ---------------------------------------------------------------------------


def _req(rid, slo_class, arrival):
    return Request(rid=rid, cached_tokens=0, new_tokens=8, gen_tokens=4,
                   arrival=arrival, slo_class=slo_class)


def test_scheduler_global_queue_orders_by_class_then_arrival():
    fifo = Scheduler(alpha=1, beta=1)
    aware = Scheduler(alpha=1, beta=1, class_aware=True)
    reqs = [_req(0, "batch", 0.0), _req(1, "batch", 1.0),
            _req(2, "interactive", 2.0), _req(3, "interactive", 0.5)]
    for s in (fifo, aware):
        for r in reqs:
            s.submit(r)
    assert [r.rid for r in fifo.pe_queue] == [0, 1, 2, 3]
    assert [r.rid for r in aware.pe_queue] == [3, 2, 0, 1]
    assert [r.rid for r in aware.de_global_queue] == [3, 2, 0, 1]


def test_class_insert_index_is_stable_and_rank_ordered():
    keys = [(0, 1.0, 1), (1, 0.0, 2), (1, 2.0, 3)]
    # equal-priority appends at the end of its rank band (stability)
    assert class_insert_index(keys, (1, 2.0, 4)) == 3
    assert class_insert_index(keys, (0, 5.0, 5)) == 1
    assert class_insert_index(keys, (0, 0.5, 6)) == 0
    assert class_insert_index([], (1, 0.0, 0)) == 0
    w = PrefillWork(9, 0, 8, rank=1, arrival=3.0)
    assert w.key() == (1, 3.0, 9)


def test_snic_queue_serves_interactive_reads_first():
    spec = ModelSimSpec(name="toy", n_layers=2, kv_bytes_per_token=1024,
                        active_param_bytes=1e6, active_params=5e5,
                        n_heads=4, qk_head_dim=32)
    sim = Sim(SimConfig(node=HOPPER_NODE, model=spec, P=1, D=1),
              [Trajectory(0, [Round(8, 4)])])
    nic = sim.snic[0]
    nic.enqueue(1e6, lambda: None)             # occupies the server
    nic.enqueue(1e6, lambda: None, rank=1)
    nic.enqueue(1e6, lambda: None, rank=1)
    nic.enqueue(1e6, lambda: None, rank=0)     # interactive demand read
    assert [j.rank for j in nic.queue] == [0, 1, 1]
    # neutral-rank traffic stays pure FIFO (the bit-identity default)
    nic.enqueue(1e6, lambda: None, rank=1)
    assert [j.rank for j in nic.queue] == [0, 1, 1, 1]


def test_sim_class_aware_protects_interactive_ttft_under_chunking():
    """Priority alone cannot preempt an in-flight forward batch; with
    chunking providing the preemption points, class-aware scheduling
    must pull interactive TTFT p99 well below the batch class."""
    chunk = _run_online(SloConfig(prefill_chunk_tokens=512))
    both = _run_online(SloConfig(prefill_chunk_tokens=512,
                                 class_aware=True))
    lat_c = chunk.results()["latency_by_class"]
    lat_b = both.results()["latency_by_class"]
    assert lat_b["interactive"]["ttft_p99"] < \
        lat_c["interactive"]["ttft_p99"]
    assert lat_b["interactive"]["ttft_p99"] < lat_b["batch"]["ttft_p99"]


def test_load_signals_double_count_interactive_backlog():
    sig = LoadSignals(n_pe=2, n_de=2, pe_queued_s=4.0, pe_busy_s=1.0,
                      de_queued_s=2.0, de_busy_s=1.0,
                      pe_queued_interactive_s=3.0,
                      de_queued_interactive_s=1.0)
    assert sig.pe_pressure == pytest.approx((4.0 + 1.0 + 3.0) / 2)
    assert sig.de_pressure == pytest.approx((2.0 + 1.0 + 1.0) / 2)
    # class-aware off: the fields default to 0 and the legacy
    # expressions come back exactly
    off = LoadSignals(n_pe=2, n_de=2, pe_queued_s=4.0, pe_busy_s=1.0,
                      de_queued_s=2.0, de_busy_s=1.0)
    assert off.pe_pressure == pytest.approx(2.5)


def test_sim_reports_class_signals_only_when_aware():
    def build(slo):
        kw = {} if slo is None else dict(slo=slo)
        sim = Sim(SimConfig(node=HOPPER_NODE, model=DS_660B, P=1, D=1,
                            mode="dualpath", **kw),
                  [Trajectory(0, [Round(8, 4)])])
        sim.sched.submit(_req(0, "interactive", 0.0))
        sim.sched.submit(_req(1, "batch", 0.1))
        return sim._elastic_signals()

    aware = build(SloConfig(class_aware=True))
    assert 0.0 < aware.pe_queued_interactive_s <= aware.pe_queued_s
    off = build(None)
    assert off.pe_queued_interactive_s == 0.0
    assert off.de_queued_interactive_s == 0.0
    assert off.pe_queued_s == aware.pe_queued_s
