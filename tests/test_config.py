"""The grouped config API (repro.core.config): cross-runtime default
parity, the flat-kwarg deprecation shim, and the tier-clock regression.

These tests pin the api_redesign contracts:

* both runtimes hold the SAME five group dataclasses by composition, so
  a default can no longer drift between them — every flat field is
  either identical-by-construction or listed (with a reason) in
  ``PARITY_EXCLUSIONS``;
* the old flat kwargs still construct bit-identical systems for one
  release, warning with ``ConfigDeprecationWarning`` (which the suite
  turns into an error everywhere else — only this module may trigger
  it, via ``pytest.warns``);
* tier timestamps come from the modelled wall clock in BOTH serving
  modes (offline used to fall back to the tier's internal operation
  counter, silently redefining ``tier_ttl_s`` as "operations").
"""
import dataclasses

import jax
import pytest

from repro.configs import get_config
from repro.core.config import (PARITY_EXCLUSIONS, FLAT_FIELDS, GROUP_FIELDS,
                               ConfigDeprecationWarning, ElasticConfig,
                               NetworkConfig, ResilienceConfig, SloConfig,
                               TierConfig, group_defaults, resolve_groups)
from repro.models import init_params
from repro.serving import ServingSystem
from repro.sim.simulator import SimConfig
from repro.sim.spec import REDUCED_TEST_NODE, HOPPER_NODE, ModelSimSpec
from repro.sim.traces import Round, Trajectory

KEY = jax.random.PRNGKey(0)


def _sim_cfg(**kw):
    spec = ModelSimSpec(name="toy", n_layers=2, kv_bytes_per_token=1024,
                        active_param_bytes=1e6, active_params=5e5,
                        n_heads=4, qk_head_dim=32)
    return SimConfig(node=HOPPER_NODE, model=spec, P=1, D=1, **kw)


# ---------------------------------------------------------------------------
# config parity
# ---------------------------------------------------------------------------


def test_both_runtimes_hold_identical_default_groups():
    """The decisive anti-drift property: an all-default SimConfig and an
    all-default ServingSystem hold equal group instances — the single
    shared definition, not two copies that happen to agree today."""
    cfg = get_config("qwen1.5-0.5b").reduced()
    params = init_params(cfg, KEY)
    sys_ = ServingSystem(cfg, params, n_pe=1, n_de=1, block_tokens=16,
                         max_seq=64, de_slots=2, seed=0)
    sim_cfg = _sim_cfg()
    serving_groups = dict(tier=sys_.tier_cfg, net=sys_.net_cfg,
                          elastic=sys_.elastic_cfg,
                          resilience=sys_.resilience_cfg, slo=sys_.slo_cfg)
    for name in GROUP_FIELDS:
        assert getattr(sim_cfg, name) == serving_groups[name] \
            == group_defaults(name), name


def test_parity_exclusions_are_documented_and_not_stale():
    """Every exclusion names a real field (a flat-shim field or the one
    per-runtime core field) and carries a non-empty reason."""
    known = set(FLAT_FIELDS) | {"block_tokens"}
    for name, reason in PARITY_EXCLUSIONS.items():
        assert name in known, f"stale exclusion {name!r}"
        assert reason.strip(), f"undocumented exclusion {name!r}"


def test_resolved_drift_defaults():
    """The documented winners of the historical default drift."""
    assert ElasticConfig().reconfig_interval_s == 5.0
    assert TierConfig().tier_ttl_s is None
    # block_tokens stays per-runtime — the one excluded core field
    assert _sim_cfg().block_tokens == 64
    assert "block_tokens" in PARITY_EXCLUSIONS


# ---------------------------------------------------------------------------
# the deprecation shim
# ---------------------------------------------------------------------------


def test_flat_kwargs_fold_into_groups_with_warning():
    with pytest.warns(ConfigDeprecationWarning):
        cfg = _sim_cfg(dram_tier_bytes=1e9, prefetch=True,
                       reconfig_interval_s=7.5, hedge_reads=True)
    assert cfg.tier == TierConfig(dram_tier_bytes=1e9, prefetch=True)
    assert cfg.elastic == ElasticConfig(reconfig_interval_s=7.5)
    assert cfg.resilience == ResilienceConfig(hedge_reads=True)
    # flat reads still work (delegating properties)
    assert cfg.dram_tier_bytes == 1e9 and cfg.reconfig_interval_s == 7.5


def test_legacy_elastic_bool_routes_to_enabled():
    with pytest.warns(ConfigDeprecationWarning):
        cfg = _sim_cfg(elastic=True)
    assert isinstance(cfg.elastic, ElasticConfig) and cfg.elastic.enabled
    assert bool(cfg.elastic)
    assert not bool(_sim_cfg().elastic)


def test_explicit_groups_are_never_mutated_by_flat_overrides():
    tier = TierConfig(dram_tier_bytes=5.0)
    with pytest.warns(ConfigDeprecationWarning):
        g = resolve_groups({"prefetch": True}, tier=tier)
    assert g["tier"].prefetch and g["tier"].dram_tier_bytes == 5.0
    assert not tier.prefetch            # caller's instance untouched


def test_unknown_kwargs_raise_type_error():
    with pytest.raises(TypeError, match="bogus_knob"):
        _sim_cfg(bogus_knob=1)
    cfg = get_config("qwen1.5-0.5b").reduced()
    params = init_params(cfg, KEY)
    with pytest.raises(TypeError, match="bogus_knob"):
        ServingSystem(cfg, params, n_pe=1, n_de=1, block_tokens=16,
                      max_seq=64, de_slots=2, bogus_knob=1)


def test_grouped_and_flat_serving_systems_are_bit_identical():
    """The shim round-trip: the old flat spelling must construct a
    system whose generation (and stats) are bit-identical to the
    grouped spelling — deprecation changes the API, not the events."""
    cfg = get_config("qwen1.5-0.5b").reduced()
    params = init_params(cfg, KEY)

    def run(**kw):
        s = ServingSystem(cfg, params, n_pe=1, n_de=1, block_tokens=16,
                          max_seq=96, de_slots=2, seed=0,
                          node=REDUCED_TEST_NODE, **kw)
        sessions = s.run_offline([Trajectory(0, [Round(20, 4, 0.1),
                                                 Round(12, 4)])])
        return sessions[0].context, s.stats()

    grouped_ctx, grouped_stats = run(
        tier=TierConfig(dram_tier_bytes=1 << 30, prefetch=True))
    with pytest.warns(ConfigDeprecationWarning):
        flat_ctx, flat_stats = run(dram_tier_bytes=1 << 30, prefetch=True)
    assert flat_ctx == grouped_ctx
    assert flat_stats == grouped_stats


def test_sim_flat_and_grouped_runs_match():
    from repro.sim import DS_660B, Sim, generate_dataset

    trajs = generate_dataset(8, 8192, seed=3)
    base = dict(node=HOPPER_NODE, model=DS_660B, P=1, D=2, seed=0)
    grouped = Sim(SimConfig(tier=TierConfig(dram_tier_bytes=1e9), **base),
                  trajs).run()
    with pytest.warns(ConfigDeprecationWarning):
        flat_cfg = SimConfig(dram_tier_bytes=1e9, **base)
    flat = Sim(flat_cfg, trajs).run()
    assert flat.results() == grouped.results()


# ---------------------------------------------------------------------------
# tier clock regression (the offline op-counter bug)
# ---------------------------------------------------------------------------


def test_offline_tier_timestamps_use_modelled_clock():
    """Offline serving used to let DramTier fall back to its internal
    per-operation counter (``now=None``), so an agentic ``tier_ttl_s``
    meant *operations* offline but *seconds* online.  Every tier call
    must now pass the modelled wall clock: the fallback counter stays
    untouched across a full tiered offline run."""
    cfg = get_config("qwen1.5-0.5b").reduced()
    params = init_params(cfg, KEY)
    sys_ = ServingSystem(cfg, params, n_pe=1, n_de=1, block_tokens=16,
                         max_seq=96, de_slots=2, seed=0,
                         node=REDUCED_TEST_NODE,
                         tier=TierConfig(dram_tier_bytes=1 << 30,
                                         tier_policy="agentic-ttl",
                                         tier_ttl_s=60.0))
    sys_.run_offline([Trajectory(0, [Round(20, 4, 0.1), Round(12, 4)])])
    assert sys_.clock.now > 0.0         # the modelled clock did advance
    for tier in sys_.tiers.values():
        # itertools.count() only advances via the now=None fallback —
        # first observation being 0 proves no tier call ever took it
        assert next(tier._tick) == 0
    assert sys_._tier_now() == sys_.clock.now


def test_group_dataclasses_are_plain_and_replaceable():
    """The groups must stay dataclasses.replace-able (the shim relies
    on it) and hashable-field-only on the comparison path."""
    for name in GROUP_FIELDS:
        g = group_defaults(name)
        assert dataclasses.replace(g) == g


def test_slo_defaults_keep_the_layer_structurally_off():
    s = SloConfig()
    assert not s.admission and s.prefill_chunk_tokens is None \
        and not s.class_aware
    assert NetworkConfig().net_bw is None
