"""Chaos suite for the fault model (sim/faults.py) and both runtimes.

Three layers, mirroring the module's design rules:

1. **Schedule unit tests** — validation, multiplicative window
   composition, the hash-based (order-free) straggler draw, and
   ``generate`` determinism.  A chaos failure must reproduce from
   ``(seed, rates)`` alone, so the schedule itself has to be pure data.
2. **Simulator fuzz** — randomized schedules over a small dual-path
   operating point, asserting the liveness/conservation invariants that
   must hold under *any* schedule: every round finishes, deaths are
   recovered, a zero-fault schedule is result-identical to
   ``faults=None``.
3. **Serving-runtime chaos** — the real-bytes runtime under pinned and
   seeded schedules.  Faults only perturb *timing*, never computation,
   so greedy decode must emit bit-identical tokens in every arm; engine
   death must re-home rounds with persists firing exactly once
   (``store_writes`` and ``trie_blocks`` equal the fault-free run —
   the dead engine's deferred store writes never execute, the recovery
   round re-persists once).

``CHAOS_SEED`` (CI matrix: 0/1/2) re-seeds every randomized schedule so
the three chaos jobs explore disjoint fault timelines.
"""
import os
from dataclasses import replace

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.config import ElasticConfig, ResilienceConfig
from repro.sim import DS_660B, HOPPER_NODE, Sim, SimConfig
from repro.sim.faults import (EngineDeath, FaultSchedule, SlowdownWindow,
                              StragglerModel)
from repro.sim.traces import Round, Trajectory

CHAOS_SEED = int(os.environ.get("CHAOS_SEED", "0"))


# ---------------------------------------------------------------------------
# FaultSchedule: pure data, deterministic queries
# ---------------------------------------------------------------------------


def test_window_validation():
    with pytest.raises(ValueError):
        SlowdownWindow("disk", 0.0, 1.0, 2.0)     # unknown resource
    with pytest.raises(ValueError):
        SlowdownWindow("snic", 1.0, 1.0, 2.0)     # empty interval
    with pytest.raises(ValueError):
        SlowdownWindow("snic", 0.0, 1.0, 0.5)     # speedups forbidden
    with pytest.raises(ValueError):
        StragglerModel(prob=1.5, severity=2.0)
    with pytest.raises(ValueError):
        StragglerModel(prob=0.5, severity=0.9)


def test_windows_compose_multiplicatively():
    fs = FaultSchedule(windows=[
        SlowdownWindow("snic", 0.0, 10.0, 4.0),            # fabric-wide
        SlowdownWindow("snic", 5.0, 15.0, 2.0, node=0),    # node 0 only
        SlowdownWindow("net", 2.0, 3.0, 3.0),
    ])
    assert fs.snic_factor(0, 1.0) == 4.0
    assert fs.snic_factor(0, 7.0) == 8.0          # overlap: 4 * 2
    assert fs.snic_factor(1, 7.0) == 4.0          # node window misses
    assert fs.snic_factor(0, 12.0) == 2.0
    assert fs.snic_factor(0, 15.0) == 1.0         # t1 exclusive
    assert fs.snic_factor(0, 0.0) == 4.0          # t0 inclusive
    assert fs.net_factor(2.5) == 3.0 and fs.net_factor(3.0) == 1.0
    assert fs.boundaries("snic") == [0.0, 5.0, 10.0, 15.0]
    assert fs.boundaries("net") == [2.0, 3.0]


def test_schedule_sorts_regardless_of_construction_order():
    a = SlowdownWindow("snic", 5.0, 6.0, 2.0)
    b = SlowdownWindow("net", 1.0, 2.0, 2.0)
    d1, d2 = EngineDeath(9.0, (1, 0)), EngineDeath(3.0, (0, 0))
    fs = FaultSchedule(windows=[a, b], deaths=[d1, d2])
    assert fs.windows == [b, a]
    assert fs.deaths == [d2, d1]


def test_empty_property():
    assert FaultSchedule().empty
    assert FaultSchedule(straggler=StragglerModel(0.0, 4.0)).empty
    assert not FaultSchedule(
        windows=[SlowdownWindow("snic", 0.0, 1.0, 2.0)]).empty
    assert not FaultSchedule(deaths=[EngineDeath(1.0, (0, 0))]).empty
    assert not FaultSchedule(straggler=StragglerModel(0.1, 4.0)).empty


def test_straggler_draw_deterministic_and_side_independent():
    m = StragglerModel(prob=0.5, severity=6.0, seed=CHAOS_SEED)
    draws = {(rid, side): m.factor(rid, side)
             for rid in range(200) for side in ("pe", "de")}
    # pure function: re-query in any order, same answer
    for (rid, side), f in sorted(draws.items(), reverse=True):
        assert m.factor(rid, side) == f
        assert f in (1.0, 6.0)
    # the md5 draw decorrelates the two sides of one request (a linear
    # hash made them straggle in lockstep); at prob=0.5 over 200 rids
    # some request must straggle on exactly one side
    split = [rid for rid in range(200)
             if draws[(rid, "pe")] != draws[(rid, "de")]]
    assert split, "pe/de draws perfectly correlated"
    # and the empirical rate is near prob (binomial, 400 draws)
    frac = sum(f > 1.0 for f in draws.values()) / len(draws)
    assert 0.3 < frac < 0.7


def test_generate_is_deterministic_in_seed():
    kw = dict(duration_s=100.0, nodes=range(4),
              engines=((2, 0), (3, 0)), snic_fault_rate=0.05,
              link_flap_rate=0.03, straggler_prob=0.2, n_deaths=2,
              death_frac=0.4)
    a = FaultSchedule.generate(seed=7, **kw)
    b = FaultSchedule.generate(seed=7, **kw)
    c = FaultSchedule.generate(seed=8, **kw)
    assert a.windows == b.windows and a.deaths == b.deaths
    assert a.straggler == b.straggler
    assert a.windows != c.windows
    # expected window counts and death placement
    assert len(a.windows) == round(0.05 * 100) + round(0.03 * 100)
    assert len(a.deaths) == 2
    for d in a.deaths:
        assert d.engine in ((2, 0), (3, 0))
        assert 0.9 * 40.0 <= d.t <= 1.1 * 40.0     # death_frac +/- 10%
    assert all(w.factor >= 1.0 for w in a.windows)


# ---------------------------------------------------------------------------
# simulator chaos: liveness + conservation under any schedule
# ---------------------------------------------------------------------------

_NODE = replace(HOPPER_NODE, g=1, snic_bw=4e9)   # storage-bound point
_N_AGENTS, _N_ROUNDS = 4, 2


def _sim_run(faults=None, hedge=False, elastic=False):
    cfg = SimConfig(node=_NODE, model=DS_660B, P=2, D=2, mode="dualpath",
                    nodes_per_pe_group=1, nodes_per_de_group=1,
                    split_reads=True, kv_hbm_frac=0.04,
                    resilience=ResilienceConfig(faults=faults,
                                                hedge_reads=hedge),
                    elastic=ElasticConfig(enabled=elastic,
                                          reconfig_interval_s=4.0,
                                          reconfig_patience=2))
    trajs = [Trajectory(i, [Round(8192, 16), Round(2048, 32)])
             for i in range(_N_AGENTS)]
    return Sim(cfg, trajs).run()


def test_sim_zero_fault_schedule_is_invisible():
    """Design rule 'empty = invisible': an empty schedule with hedging
    armed must produce a bit-identical results() dict to faults=None."""
    r0 = _sim_run().results()
    r1 = _sim_run(faults=FaultSchedule(), hedge=True).results()
    assert r0 == r1
    assert r0["hedged_reads"] == 0 and r0["engine_deaths"] == 0


def test_sim_pinned_death_recovers_all_rounds():
    """One DE dies mid-run: its in-flight rounds are re-homed and every
    agent still finishes on the surviving engines."""
    fs = FaultSchedule(deaths=[EngineDeath(4.0, (3, 0))])
    sim = _sim_run(faults=fs)
    r = sim.results()
    assert r["finished_agents"] == _N_AGENTS
    assert r["finished_rounds"] == _N_AGENTS * _N_ROUNDS
    assert r["engine_deaths"] == 1
    assert r["recovered_rounds"] > 0
    assert r["n_de_final"] == 1
    # the fault-free run is strictly no slower (it lost an engine)
    assert r["sim_time"] > 0


@given(draw=st.integers(0, 1 << 16),
       snic_rate=st.floats(0.0, 0.2),
       strag_prob=st.floats(0.0, 0.5),
       flap_rate=st.floats(0.0, 0.1),
       n_deaths=st.integers(0, 1),
       hedge=st.booleans())
@settings(max_examples=20, deadline=None)
def test_chaos_sim_completes_under_any_schedule(draw, snic_rate,
                                                strag_prob, flap_rate,
                                                n_deaths, hedge):
    """The fuzz core: whatever the schedule, every admitted round
    completes, deaths never exceed the schedule, and recovery counters
    are only non-zero when a death actually fired."""
    fs = FaultSchedule.generate(
        seed=draw ^ (CHAOS_SEED << 17), duration_s=20.0, nodes=range(4),
        engines=((2, 0), (3, 0)),
        snic_fault_rate=snic_rate, snic_factor=6.0,
        straggler_prob=strag_prob, straggler_severity=8.0,
        link_flap_rate=flap_rate, link_factor=3.0,
        n_deaths=n_deaths, death_frac=0.3)
    sim = _sim_run(faults=None if fs.empty else fs, hedge=hedge)
    r = sim.results()
    assert r["finished_agents"] == _N_AGENTS
    assert r["finished_rounds"] == _N_AGENTS * _N_ROUNDS
    assert r["engine_deaths"] <= len(fs.deaths)
    if r["engine_deaths"] == 0:
        assert r["recovered_rounds"] == 0
    else:
        assert r["n_pe_final"] + r["n_de_final"] < 4
    assert r["hedge_moved_tokens"] >= 0
    if not hedge:
        assert r["hedged_reads"] == 0
    if r["hedged_reads"] == 0:
        assert r["hedge_moved_tokens"] == 0
    # every finished round carries complete latency stamps
    assert sim.slo_attainment(ttft_slo_s=1e9, tpot_slo_s=1e9) == 1.0


def test_boundaries_array_pins_window_crossing():
    """Regression pin for :meth:`FaultSchedule.boundaries_array` — both
    engines schedule one re-share per edge off this array, so its exact
    contents (sorted, deduplicated, per-resource, float64) decide where
    a flow crossing a slowdown window switches drain rate."""
    import numpy as np
    fs = FaultSchedule(windows=[
        SlowdownWindow("net", 5.0, 9.0, 2.0),
        SlowdownWindow("net", 7.0, 15.0, 1.5),   # overlaps the first
        SlowdownWindow("net", 9.0, 20.0, 3.0),   # t0 == prior t1: dedup
        SlowdownWindow("snic", 2.0, 20.0, 3.0, node=0),
    ])
    edges = fs.boundaries_array("net")
    assert edges.dtype == np.float64
    assert edges.tolist() == [5.0, 7.0, 9.0, 15.0, 20.0]
    # list form stays a view of the same truth
    assert fs.boundaries("net") == edges.tolist()
    # per-resource isolation: snic edges never leak into net
    assert fs.boundaries_array("snic").tolist() == [2.0, 20.0]
    assert fs.boundaries_array("dram").size == 0
    # the piecewise factor the edges delimit: nested windows multiply
    for t, f in ((4.9, 1.0), (5.0, 2.0), (7.5, 3.0), (9.5, 4.5),
                 (15.5, 3.0), (20.0, 1.0)):
        assert fs.net_factor(t) == f, (t, f)


def test_chaos_sim_death_under_elastic_backfill():
    """Death + elastic controller: the lost DE role is backfillable via
    a compensating flip and the run still completes every round."""
    fs = FaultSchedule(deaths=[EngineDeath(4.0, (3, 0))])
    r = _sim_run(faults=fs, elastic=True).results()
    assert r["finished_agents"] == _N_AGENTS
    assert r["engine_deaths"] == 1


# ---------------------------------------------------------------------------
# serving-runtime chaos: real bytes, real tokens
# ---------------------------------------------------------------------------
# Faults perturb when work happens, never what is computed: greedy
# decode must emit bit-identical tokens under every schedule, and the
# store/trie must end byte-identical to the fault-free run (persists
# fire exactly once even across an engine death).

jax = pytest.importorskip("jax")

from repro.configs import get_config          # noqa: E402
from repro.models import init_params          # noqa: E402
from repro.serving import ServingSystem       # noqa: E402
from repro.sim.spec import REDUCED_TEST_NODE  # noqa: E402


@pytest.fixture(scope="module")
def cfg_params():
    cfg = get_config("qwen1.5-0.5b").reduced()
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


def _serve(cfg_params, faults=None, hedge_reads=False):
    cfg, params = cfg_params
    sys_ = ServingSystem(cfg, params, n_pe=2, n_de=2, block_tokens=16,
                         max_seq=160, de_slots=2, seed=0, pipelined=True,
                         split_reads=True, node=REDUCED_TEST_NODE,
                         resilience=ResilienceConfig(
                             faults=faults, hedge_reads=hedge_reads))
    trajs = [Trajectory(i, [Round(24, 4), Round(16, 4), Round(8, 4)])
             for i in range(4)]
    sessions = sys_.run_online(trajs, [0.0, 0.1, 0.2, 0.3])
    return sys_, sessions


@pytest.fixture(scope="module")
def baseline(cfg_params):
    sys_, sessions = _serve(cfg_params)
    return sys_.stats(), [s.context for s in sessions]


def _assert_chaos_invariants(sys_, sessions, base):
    """The invariants every serving chaos arm must satisfy."""
    base_stats, base_tokens = base
    st_ = sys_.stats()
    # 1. every admitted request completes
    assert all(s.done() for s in sessions)
    # 2. timing-only faults: token streams bit-identical
    assert [s.context for s in sessions] == base_tokens
    # 3. persists fire exactly once — a dead engine's deferred store
    #    writes never execute and the recovery round re-persists, so
    #    total bytes written and trie blocks match the fault-free run
    assert st_["store_writes"] == base_stats["store_writes"]
    assert st_["trie_blocks"] == base_stats["trie_blocks"]
    # 4. per-side byte conservation through hedge rebalances: moving a
    #    remainder between sides never creates or destroys read bytes.
    #    Recovery legitimately re-reads a restarted round's KV, so with
    #    recovered rounds the total may only grow, never shrink
    total = st_["read_bytes_pe_side"] + st_["read_bytes_de_side"]
    base_total = (base_stats["read_bytes_pe_side"] +
                  base_stats["read_bytes_de_side"])
    if st_["recovered_rounds"] == 0:
        assert total == base_total
    else:
        assert total >= base_total
    return st_


def test_serving_zero_fault_schedule_is_invisible(cfg_params, baseline):
    """Empty schedule + hedging armed: the whole stats() dict — wall
    clock included — must be identical to faults=None."""
    sys_, sessions = _serve(cfg_params, faults=FaultSchedule(),
                            hedge_reads=True)
    base_stats, base_tokens = baseline
    assert [s.context for s in sessions] == base_tokens
    st_ = sys_.stats()
    assert st_ == base_stats


def test_serving_chaos_straggle_hedged(cfg_params, baseline):
    """A degraded node-0 SNIC plus per-leg stragglers, hedging on: the
    hedge re-water-fills straggling remainders to the healthy side with
    byte-exact accounting and identical tokens."""
    fs = FaultSchedule(
        windows=[SlowdownWindow("snic", 0.0, 1e9, 8.0, node=0)],
        straggler=StragglerModel(0.4, 8.0, seed=7))
    sys_, sessions = _serve(cfg_params, faults=fs, hedge_reads=True)
    st_ = _assert_chaos_invariants(sys_, sessions, baseline)
    assert st_["hedged_reads"] > 0
    assert st_["hedge_moved_tokens"] > 0


def test_serving_chaos_de_death_recovers(cfg_params, baseline):
    """A DE dies mid-run: its in-flight rounds restart on the survivor
    from persisted KV, exactly-once persists, identical tokens."""
    fs = FaultSchedule(deaths=[EngineDeath(0.65, (2, 0))])
    sys_, sessions = _serve(cfg_params, faults=fs)
    st_ = _assert_chaos_invariants(sys_, sessions, baseline)
    assert st_["engine_deaths"] == 1
    assert st_["recovered_rounds"] > 0
    assert st_["n_de_final"] == 1
    # recovery re-reads the restarted rounds' KV: reads grow, never shrink
    assert st_["store_reads"] >= baseline[0]["store_reads"]


def test_serving_chaos_randomized_schedule(cfg_params, baseline):
    """The CI chaos matrix: a generated schedule (windows + stragglers,
    re-seeded per CHAOS_SEED) with hedging must preserve all chaos
    invariants on the real runtime."""
    fs = FaultSchedule.generate(
        seed=CHAOS_SEED, duration_s=2.0, nodes=range(2),
        snic_fault_rate=1.0, snic_factor=4.0, snic_window_s=0.5,
        link_flap_rate=0.5, link_factor=2.0, link_window_s=0.5,
        straggler_prob=0.3, straggler_severity=6.0)
    assert not fs.empty
    sys_, sessions = _serve(cfg_params, faults=fs, hedge_reads=True)
    _assert_chaos_invariants(sys_, sessions, baseline)
