"""Event-driven serving runtime (serving/events.py + serving/system.py).

Pins the refactor's contract: the pipelined runtime must be an
*observably identical* generation machine to the blocking lock-step
reference — bit-identical tokens AND identical per-side byte accounting
— while overlapping transfers with compute (strictly smaller modelled
makespan in the bandwidth-bound regime), supporting ≥ 2 scheduler
groups per engine kind (DE phase-1 balancing end-to-end), and serving
online arrivals with TTFT/TTST/TPOT + SLO accounting that mirrors
``Sim.results()``.
"""
import math

import jax
import pytest

from repro.configs import get_config
from repro.core.config import TierConfig
from repro.models import init_params
from repro.serving import ServingSystem
from repro.sim.spec import REDUCED_TEST_NODE as SLOW_NODE
from repro.sim.traces import Round, Trajectory

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def cfg_params():
    cfg = get_config("qwen1.5-0.5b").reduced()
    return cfg, init_params(cfg, KEY)


def _trajs(n, rounds):
    return [Trajectory(i, [Round(*r) for r in rounds]) for i in range(n)]


def _run(cfg, params, trajs, *, pipelined, arrivals=None, **kw):
    sys_ = ServingSystem(cfg, params, pipelined=pipelined, seed=0, **kw)
    fresh = [Trajectory(t.tid, list(t.rounds)) for t in trajs]
    if arrivals is None:
        sessions = sys_.run_offline(fresh)
    else:
        sessions = sys_.run_online(fresh, arrivals)
    return sys_, sessions


BYTE_KEYS = ("read_bytes_pe_side", "read_bytes_de_side",
             "dram_bytes_pe_side", "dram_bytes_de_side",
             "split_reads", "store_reads", "store_writes",
             "dram_hit_bytes", "tier_miss_bytes",
             "prefill_tokens", "gen_tokens")


@pytest.mark.parametrize("tier_kw", [
    # mixed tier/split: a tier of a few blocks (constant eviction churn)
    # with split reads, so DRAM-served prefixes, split SNIC reads and
    # admission pressure all happen at once
    dict(split_reads=True,
         tier=TierConfig(dram_tier_bytes=32768, prefetch=True)),
    # pure split, no tier: every hit byte water-fills across both SNICs
    dict(split_reads=True),
], ids=["tier+split", "split"])
def test_pipelined_equals_blocking_tokens_and_bytes(cfg_params, tier_kw):
    """S4: pipelined vs blocking — identical generated tokens and
    identical read_bytes_by_side / dram_bytes_by_side accounting."""
    cfg, params = cfg_params
    trajs = _trajs(4, [(24, 3), (16, 3), (8, 3)])
    kw = dict(n_pe=1, n_de=1, block_tokens=16, max_seq=160, de_slots=4,
              **tier_kw)
    sys_b, ses_b = _run(cfg, params, trajs, pipelined=False, **kw)
    sys_p, ses_p = _run(cfg, params, trajs, pipelined=True, **kw)
    assert [s.context for s in ses_p] == [s.context for s in ses_b], \
        "pipelined runtime diverged from the blocking reference"
    st_b, st_p = sys_b.stats(), sys_p.stats()
    for k in BYTE_KEYS:
        assert st_p[k] == st_b[k], (k, st_b[k], st_p[k])
    # exact byte conservation, in both arms: every hit byte was served
    # from a DRAM tier or a storage NIC, partitioned per side
    for st in (st_b, st_p):
        assert st["dram_hit_bytes"] == (st["dram_bytes_pe_side"] +
                                        st["dram_bytes_de_side"])
        if tier_kw.get("tier") is not None:
            assert st["tier_miss_bytes"] == (st["read_bytes_pe_side"] +
                                             st["read_bytes_de_side"])
    if tier_kw == dict(split_reads=True):
        assert st_p["split_reads"] > 0, "split workload never split"


def test_pipelined_overlaps_transfers_with_compute(cfg_params):
    """The point of the refactor: with reads in flight across engine
    steps, the modelled makespan charges max(transfer, compute) per
    tick instead of their sum — strictly faster in the bandwidth-bound
    regime, at identical generated tokens."""
    cfg, params = cfg_params
    trajs = _trajs(6, [(24, 4), (16, 4), (8, 4)])
    kw = dict(n_pe=1, n_de=1, block_tokens=16, max_seq=160, de_slots=4,
              node=SLOW_NODE)
    sys_b, ses_b = _run(cfg, params, trajs, pipelined=False, **kw)
    sys_p, ses_p = _run(cfg, params, trajs, pipelined=True, **kw)
    assert [s.context for s in ses_p] == [s.context for s in ses_b]
    st_b, st_p = sys_b.stats(), sys_p.stats()
    assert st_p["wall_s"] < st_b["wall_s"], (st_p["wall_s"], st_b["wall_s"])
    # doorbell batching is real: the pipelined runtime posts multi-WR
    # batches where the blocking runtime rings one doorbell per drain
    assert st_p["doorbells"] < st_b["doorbells"]


def test_multi_group_de_phase1_balances_and_matches_reference(cfg_params):
    """S3: ≥ 2 DE groups — de_phase1 spreads the global queue across
    groups by token load, and the output is bit-identical to the
    single-group reference topology."""
    cfg, params = cfg_params
    trajs = _trajs(6, [(18, 3), (12, 3)])
    kw = dict(n_pe=2, n_de=2, block_tokens=16, max_seq=128, de_slots=4)
    ref, ref_s = _run(cfg, params, trajs, pipelined=True, **kw)
    mg, mg_s = _run(cfg, params, trajs, pipelined=True,
                    pe_group_size=1, de_group_size=1, **kw)
    assert sorted(mg.sched.groups("de")) == [1000, 1001]
    assert sorted(mg.sched.groups("pe")) == [0, 1]
    assert [s.context for s in mg_s] == [s.context for s in ref_s], \
        "multi-group topology changed generation"
    # both DE groups actually served decode work, and the per-group
    # loads are balanced (each group's single DE saw ~half the steps)
    steps = {}
    for eid, de in mg.des.items():
        g = mg.sched.engines[eid].group
        steps[g] = steps.get(g, 0) + de.decode_steps
    assert all(v > 0 for v in steps.values()), steps
    assert max(steps.values()) <= 3 * min(steps.values()), steps


def test_run_online_arrivals_think_and_slo_accounting(cfg_params):
    """run_online: arrivals and think gaps ride the wall clock, every
    round finishes, and stats() reports the Sim.results()-style
    TTFT/TTST/TPOT percentiles plus SLO attainment."""
    cfg, params = cfg_params
    trajs = _trajs(4, [(20, 4, 0.5), (12, 3, 0.3)])
    arrivals = [0.0, 0.2, 0.4, 0.6]
    kw = dict(n_pe=1, n_de=1, block_tokens=16, max_seq=160, de_slots=4,
              node=SLOW_NODE)
    out = {}
    for arm in (False, True):
        sys_, sessions = _run(cfg, params, trajs, pipelined=arm,
                              arrivals=arrivals, **kw)
        assert all(s.done() for s in sessions)
        st = sys_.stats()
        assert st["finished_rounds"] == sum(t.n_rounds for t in trajs)
        for k in ("ttft_mean", "ttft_p99", "ttst_mean", "tpot_mean",
                  "tpot_p99"):
            assert math.isfinite(st[k]) and st[k] >= 0, (k, st[k])
        # the clock honoured the last arrival and the inter-round think
        # gap (Round.think is the gap BEFORE that round's submission)
        assert st["wall_s"] >= arrivals[-1] + trajs[0].rounds[1].think
        att = sys_.slo_attainment(ttft_slo_s=10.0, tpot_slo_s=10.0)
        assert att == 1.0            # infinitely lax SLOs always attained
        att = sys_.slo_attainment(ttft_slo_s=0.0, tpot_slo_s=0.0)
        assert att == 0.0            # impossible SLOs never attained
        out[arm] = [s.context for s in sessions]
    assert out[True] == out[False], "online arms diverged"


def test_online_tier_ttl_uses_wall_seconds(cfg_params):
    """Online serving feeds the wall clock to the agentic-TTL tier: a
    trajectory idle past the TTL gets its blocks evicted first.  Here
    every think gap exceeds the TTL, so TTL-based victims exist as soon
    as capacity pressure arrives — the run must stay correct (bit-exact
    generation is covered by the equivalence tests; this pins that the
    seconds-based policy path executes end-to-end)."""
    cfg, params = cfg_params
    trajs = _trajs(3, [(24, 3, 0.5), (16, 3, 0.5), (8, 3, 0.5)])
    sys_, sessions = _run(cfg, params, trajs, pipelined=True,
                          arrivals=[0.0, 0.1, 0.2],
                          n_pe=1, n_de=1, block_tokens=16, max_seq=160,
                          de_slots=4,
                          tier=TierConfig(dram_tier_bytes=32768,
                                          prefetch=True,
                                          tier_policy="agentic-ttl",
                                          tier_ttl_s=0.05),
                          node=SLOW_NODE)
    assert all(s.done() for s in sessions)
    st = sys_.stats()
    assert st["dram_hit_bytes"] + st["tier_miss_bytes"] > 0
    for tier in sys_.tiers.values():
        assert tier.pinned_bytes() == 0
