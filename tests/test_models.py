"""Model zoo: per-arch smoke tests + decode/append consistency oracles.

Smoke (assignment requirement): every assigned architecture instantiates
a REDUCED same-family config and runs one forward + one train step on
CPU asserting output shapes and no NaNs.

Oracles: token-by-token decode and chunked append must reproduce the
full-sequence forward.  Exact (bitwise) for non-MoE archs; MoE archs get
a tolerance because chunk-shape-dependent matmul accumulation (1-ulp in
bf16) can flip top-k routing — the known chunked-prefill/MoE
non-reproducibility (documented in DESIGN.md).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, EXTRA_ARCH_IDS, get_config
from repro.models import (count_active_params_analytic,
                          count_params_analytic, decode_step, forward,
                          init_decode_state, init_params)
from repro.models.model import append_step
from repro.training import make_train_step

KEY = jax.random.PRNGKey(0)
ALL_ARCHS = list(ARCH_IDS) + list(EXTRA_ARCH_IDS)
MOE_ARCHS = {"granite-moe-3b-a800m", "llama4-maverick-400b-a17b", "ds27b"}


def _inputs(cfg, b, s, key):
    if cfg.frontend_embed_dim:
        return jax.random.normal(key, (b, s, cfg.frontend_embed_dim),
                                 jnp.float32)
    return jax.random.randint(key, (b, s), 0, cfg.vocab_size)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, KEY)
    b, s = 2, 16
    x = _inputs(cfg, b, s, KEY)
    logits, _ = forward(params, cfg, x)
    assert logits.shape == (b, s, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any()), f"{arch}: NaN in forward"

    opt_init, train_step = make_train_step(cfg, n_microbatches=1)
    opt = opt_init(params)
    if cfg.frontend_embed_dim:
        batch = {"inputs": x,
                 "labels": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)}
    else:
        batch = {"tokens": jax.random.randint(KEY, (b, s + 1), 0,
                                              cfg.vocab_size)}
    new_params, new_opt, loss = train_step(params, opt, batch)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    # params actually changed
    moved = jax.tree.leaves(jax.tree.map(
        lambda a, b_: bool(jnp.any(a != b_)), params, new_params))
    assert any(moved), f"{arch}: train step did not update params"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    if not cfg.supports_decode:
        pytest.skip("encoder-only")
    params = init_params(cfg, KEY)
    b, s = 2, 10
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    full, _ = forward(params, cfg, toks)
    st = init_decode_state(cfg, b, 2 * s)
    errs = []
    for i in range(s):
        lg, st = decode_step(params, cfg, toks[:, i], st,
                             jnp.full((b,), i, jnp.int32))
        errs.append(float(jnp.max(jnp.abs(lg - full[:, i]))))
    tol = 0.25 if arch in MOE_ARCHS or cfg.attn_variant == "mla" else 0.0
    assert max(errs) <= tol, f"{arch}: decode-vs-forward err {max(errs)}"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_append_matches_forward(arch):
    cfg = get_config(arch).reduced()
    if not cfg.supports_decode:
        pytest.skip("encoder-only")
    params = init_params(cfg, KEY)
    b, s = 2, 12
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    full, _ = forward(params, cfg, toks)
    st = init_decode_state(cfg, b, 2 * s)
    errs, off = [], 0
    for chunk in (5, 4, 3):
        lg, st = append_step(params, cfg, toks[:, off:off + chunk], st,
                             jnp.full((b,), off, jnp.int32))
        errs.append(float(jnp.max(jnp.abs(lg - full[:, off:off + chunk]))))
        off += chunk
    tol = 0.25 if arch in MOE_ARCHS else 0.0
    assert max(errs) <= tol, f"{arch}: append-vs-forward err {max(errs)}"


def test_encoder_bidirectional():
    """hubert: flipping a late token changes early logits (no causality)."""
    cfg = get_config("hubert-xlarge").reduced()
    params = init_params(cfg, KEY)
    x = jax.random.normal(KEY, (1, 8, cfg.frontend_embed_dim), jnp.float32)
    l1, _ = forward(params, cfg, x)
    x2 = x.at[:, -1].add(1.0)
    l2, _ = forward(params, cfg, x2)
    assert bool(jnp.any(jnp.abs(l1[:, 0] - l2[:, 0]) > 0)), \
        "encoder is unexpectedly causal"


def test_causal_lm_is_causal():
    cfg = get_config("qwen1.5-0.5b").reduced()
    params = init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (1, 8), 0, cfg.vocab_size)
    l1, _ = forward(params, cfg, toks)
    toks2 = toks.at[:, -1].set((toks[:, -1] + 1) % cfg.vocab_size)
    l2, _ = forward(params, cfg, toks2)
    np.testing.assert_array_equal(np.asarray(l1[:, :-1]),
                                  np.asarray(l2[:, :-1]))


def test_gemma2_local_global_differ():
    """Local layers mask beyond the window — perturbing a distant token
    must still reach the output through global layers only."""
    cfg = get_config("gemma2-2b").reduced()
    assert cfg.local_window > 0 and cfg.local_global_period == 2
    params = init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (1, 16), 0, cfg.vocab_size)
    logits, _ = forward(params, cfg, toks)
    assert not bool(jnp.isnan(logits).any())


def test_param_counts_match_designations():
    expected = {
        "llava-next-34b": (34e9, 0.10),
        "llama4-maverick-400b-a17b": (400e9, 0.05),
        "granite-moe-3b-a800m": (3e9, 0.15),
        "qwen1.5-0.5b": (0.5e9, 0.15),
        "minicpm-2b": (2.7e9, 0.15),
        "gemma2-2b": (2.6e9, 0.15),
        "nemotron-4-15b": (15e9, 0.10),
        "mamba2-1.3b": (1.3e9, 0.10),
        "hubert-xlarge": (0.96e9, 0.10),
        "zamba2-2.7b": (2.7e9, 0.20),
        "ds27b": (27e9, 0.10),
    }
    for name, (n, tol) in expected.items():
        got = count_params_analytic(get_config(name))
        assert abs(got - n) / n < tol, (name, got / 1e9)


def test_active_params():
    a = count_active_params_analytic(get_config("llama4-maverick-400b-a17b"))
    assert 10e9 < a < 20e9          # a17b
    g = count_active_params_analytic(get_config("granite-moe-3b-a800m"))
    assert 0.5e9 < g < 1.1e9        # a800m


def test_moe_ep_matches_ragged_without_drops():
    from repro.models.moe import moe_ffn
    cfg = get_config("granite-moe-3b-a800m").reduced()
    params = init_params(cfg, KEY)
    p = jax.tree.map(lambda a: a[0], params["super_blocks"]["moe"])["moe"]
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 8, cfg.d_model),
                          jnp.float32)
    y1 = moe_ffn(p, cfg, x, impl="ragged")
    y2 = moe_ffn(p, cfg, x, impl="ep", capacity_factor=1000.0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


def test_moe_ep_capacity_drops_tokens():
    from repro.models.moe import moe_ep, route
    cfg = get_config("granite-moe-3b-a800m").reduced()
    params = init_params(cfg, KEY)
    p = jax.tree.map(lambda a: a[0], params["super_blocks"]["moe"])["moe"]
    x = jax.random.normal(KEY, (64, cfg.d_model), jnp.float32)
    y_tight = moe_ep(p, cfg, x, capacity_factor=0.1)
    y_loose = moe_ep(p, cfg, x, capacity_factor=1000.0)
    assert bool(jnp.any(jnp.abs(y_tight - y_loose) > 1e-6))
