"""Loading plans (Fig. 4) must reproduce the §4.2 per-resource coefficients."""
from fractions import Fraction

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.loading import (basic_plan, de_read_plan, hedge_water_fill,
                                oracle_plan, pe_read_plan, plan_for,
                                rebalance_remainder, resource_bytes,
                                split_read_plan)


@given(hit=st.integers(0, 10**9), miss=st.integers(0, 10**7),
       gen=st.integers(0, 10**7))
@settings(max_examples=100, deadline=None)
def test_pe_plan_matches_eq_coefficients(hit, miss, gen):
    """PE-read path: PE CNIC reads 2×T_p (Eq.1: paths 3 and 5), DE CNIC
    writes 2×T_p (Eq.6: paths 7 and 9), DE CNIC reads T_p (Eq.4: path 8)
    — with hit ≈ full (99% hit rate) the plan's per-resource sums follow
    exactly these multiplicities."""
    full = hit + miss
    rb = resource_bytes(pe_read_plan(hit, miss, gen))
    assert rb.get("pe_snic", 0) == hit                       # storage read
    assert rb.get("pe_cnic_rd", 0) == hit + full             # paths 3+5
    assert rb.get("pe_cnic_wr", 0) == hit                    # path 4
    persist = miss + gen
    assert rb.get("de_cnic_wr", 0) == full + full + persist  # paths 7+9(+persist)
    assert rb.get("de_cnic_rd", 0) == full + persist         # path 8
    assert rb.get("de_snic", 0) == persist


@given(hit=st.integers(0, 10**9), miss=st.integers(0, 10**7),
       gen=st.integers(0, 10**7))
@settings(max_examples=100, deadline=None)
def test_de_plan_matches_eq_coefficients(hit, miss, gen):
    """DE-read path: DE CNIC reads 2×T_c (Eq.4: paths 3/6), PE CNIC
    writes T_c (Eq.2: path 5), DE CNIC writes T_c (Eq.6: path 7)."""
    full = hit + miss
    rb = resource_bytes(de_read_plan(hit, miss, gen))
    persist = miss + gen
    assert rb.get("de_snic", 0) == hit + persist   # read + block persists
    assert rb.get("de_cnic_rd", 0) == hit + full + persist   # paths 3+6
    assert rb.get("pe_cnic_wr", 0) == hit                    # path 5
    assert rb.get("de_cnic_wr", 0) == miss + full + persist  # path 7 (+miss merge)
    assert rb.get("pe_cnic_rd", 0) == miss                   # miss-back


def test_oracle_plan_empty():
    assert oracle_plan(10**9, 10**6, 10**6) == []


def test_basic_plan_pe_only_storage():
    rb = resource_bytes(basic_plan(1000, 10, 5))
    assert "de_snic" in rb and rb["de_snic"] == 15   # only persists
    assert rb["pe_snic"] == 1000                     # all loads on PE side


def test_layerwise_legs_marked():
    plan = pe_read_plan(1000, 10, 5)
    lw = [leg.name for leg in plan if leg.layerwise]
    assert "pe_buf_to_pe_hbm" in lw and "pe_hbm_to_de_buf" in lw
    assert all(not leg.layerwise for leg in plan if leg.phase == "load")


# ---------------------------------------------------------------------------
# split reads (§6.1 future work, beyond-paper)
# ---------------------------------------------------------------------------


@given(hit=st.integers(0, 10**9), miss=st.integers(0, 10**7),
       gen=st.integers(0, 10**7), r=st.floats(0.0, 1.0))
@settings(max_examples=100, deadline=None)
def test_split_plan_is_convex_combination_of_pure_plans(hit, miss, gen, r):
    """For any split ratio r∈[0,1], the per-resource byte sums of a
    split plan equal the convex combination r·PE + (1−r)·DE of the pure
    plans — byte-exact (checked in rational arithmetic).  This is what
    lets the §4.2 analysis, the simulator and the engines stay
    byte-identical under split reads: the miss/persist legs occupy the
    same resources on both paths, and the hit legs interpolate."""
    pe_bytes = int(hit * r)
    rb_s = resource_bytes(split_read_plan(hit, miss, gen, pe_bytes))
    rb_pe = resource_bytes(pe_read_plan(hit, miss, gen))
    rb_de = resource_bytes(de_read_plan(hit, miss, gen))
    keys = set(rb_s) | set(rb_pe) | set(rb_de)
    if hit == 0:
        # no hit bytes: both pure plans degenerate to the same sums
        for k in keys:
            assert rb_s.get(k, 0) == rb_pe.get(k, 0) == rb_de.get(k, 0)
        return
    frac = Fraction(pe_bytes, hit)
    for k in keys:
        expect = frac * rb_pe.get(k, 0) + (1 - frac) * rb_de.get(k, 0)
        assert Fraction(rb_s.get(k, 0)) == expect, (k, rb_s.get(k, 0), expect)


@given(hit=st.integers(1, 10**9), miss=st.integers(0, 10**7),
       gen=st.integers(0, 10**7))
@settings(max_examples=50, deadline=None)
def test_split_plan_endpoints_equal_pure_plans(hit, miss, gen):
    rb_pe = resource_bytes(pe_read_plan(hit, miss, gen))
    rb_de = resource_bytes(de_read_plan(hit, miss, gen))
    at_pe = resource_bytes(split_read_plan(hit, miss, gen, hit))
    at_de = resource_bytes(split_read_plan(hit, miss, gen, 0))
    for k in set(rb_pe) | set(at_pe):
        assert at_pe.get(k, 0) == rb_pe.get(k, 0)
    for k in set(rb_de) | set(at_de):
        assert at_de.get(k, 0) == rb_de.get(k, 0)


def test_split_plan_load_legs_occupy_both_snics():
    """A genuine split must put one load leg on each side's storage NIC
    (the two legs the simulator serves concurrently)."""
    plan = split_read_plan(1000, 10, 5, 400)
    load = [leg for leg in plan if leg.phase == "load"]
    assert len(load) == 2
    snics = {r for leg in load for r in leg.resources if r.endswith("snic")}
    assert snics == {"pe_snic", "de_snic"}
    assert sum(leg.nbytes for leg in load) == 1000


# ---------------------------------------------------------------------------
# hedged split reads: the pure remainder re-partition (sim/faults.py)
# ---------------------------------------------------------------------------


@given(pe=st.integers(0, 1 << 20), de=st.integers(0, 1 << 20),
       rem_frac=st.floats(0.0, 1.0),
       move=st.integers(-(1 << 10), 1 << 21),
       side=st.sampled_from(["pe", "de"]))
@settings(max_examples=100, deadline=None)
def test_property_rebalance_remainder_conserves_exactly(pe, de, rem_frac,
                                                        move, side):
    """The docstring invariants: new_pe + new_de == pe + de exactly, and
    whatever move is requested (negative, or beyond the remainder), the
    realised fraction moved / remainder stays in [0, 1]."""
    src = pe if side == "pe" else de
    rem = int(src * rem_frac)
    new_pe, new_de = rebalance_remainder(pe, de, side, rem, move)
    assert new_pe + new_de == pe + de
    assert new_pe >= 0 and new_de >= 0
    moved = (pe - new_pe) if side == "pe" else (de - new_de)
    assert 0 <= moved <= rem
    if rem:
        assert 0.0 <= moved / rem <= 1.0
    # the other side only ever gains
    gained = (new_de - de) if side == "pe" else (new_pe - pe)
    assert gained == moved


def test_rebalance_remainder_rejects_remainder_beyond_snic_share():
    """Tier-hit bytes are not an input: a remainder larger than the
    side's SNIC share means the caller tried to re-charge bytes that
    never belonged to a storage NIC — rejected, not clamped away."""
    with pytest.raises(AssertionError):
        rebalance_remainder(10, 50, "pe", 11, 5)
    with pytest.raises(AssertionError):
        rebalance_remainder(50, 10, "de", 11, 5)
    # at exactly the share it is a legal full-remainder hedge
    assert rebalance_remainder(10, 50, "pe", 10, 10) == (0, 60)


@given(rem=st.integers(0, 1 << 20), backlog=st.integers(0, 1 << 20),
       sev=st.floats(1.0, 128.0))
@settings(max_examples=100, deadline=None)
def test_property_hedge_water_fill_equalises_completion(rem, backlog,
                                                        sev):
    """Unclamped, the water-fill solves backlog + x == (rem - x) * s;
    clamped, it pins to the [0, remainder] boundary."""
    x = hedge_water_fill(rem, sev, backlog)
    assert 0 <= x <= rem
    ideal = (sev * rem - backlog) / (1.0 + sev)
    if 0 < x < rem:
        assert abs(x - ideal) <= 1.0          # int truncation only
    elif x == 0:
        assert ideal < 1.0
    else:
        assert ideal >= rem - 1.0


def test_plan_for_dispatch():
    """plan_for is the single dispatch the sim and engines share."""
    assert resource_bytes(plan_for("pe", 1.0, 100, 10, 5)) == \
        resource_bytes(pe_read_plan(100, 10, 5))
    assert resource_bytes(plan_for("de", 1.0, 100, 10, 5)) == \
        resource_bytes(de_read_plan(100, 10, 5))
    # read_path carries the majority side; read_split its fraction
    rb = resource_bytes(plan_for("pe", 0.6, 100, 10, 5))
    assert rb == resource_bytes(split_read_plan(100, 10, 5, 60))
    rb = resource_bytes(plan_for("de", 0.7, 100, 10, 5))
    assert rb == resource_bytes(split_read_plan(100, 10, 5, 30))
