"""Loading plans (Fig. 4) must reproduce the §4.2 per-resource coefficients."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.loading import (basic_plan, de_read_plan, oracle_plan,
                                pe_read_plan, resource_bytes)


@given(hit=st.integers(0, 10**9), miss=st.integers(0, 10**7),
       gen=st.integers(0, 10**7))
@settings(max_examples=100, deadline=None)
def test_pe_plan_matches_eq_coefficients(hit, miss, gen):
    """PE-read path: PE CNIC reads 2×T_p (Eq.1: paths 3 and 5), DE CNIC
    writes 2×T_p (Eq.6: paths 7 and 9), DE CNIC reads T_p (Eq.4: path 8)
    — with hit ≈ full (99% hit rate) the plan's per-resource sums follow
    exactly these multiplicities."""
    full = hit + miss
    rb = resource_bytes(pe_read_plan(hit, miss, gen))
    assert rb.get("pe_snic", 0) == hit                       # storage read
    assert rb.get("pe_cnic_rd", 0) == hit + full             # paths 3+5
    assert rb.get("pe_cnic_wr", 0) == hit                    # path 4
    persist = miss + gen
    assert rb.get("de_cnic_wr", 0) == full + full + persist  # paths 7+9(+persist)
    assert rb.get("de_cnic_rd", 0) == full + persist         # path 8
    assert rb.get("de_snic", 0) == persist


@given(hit=st.integers(0, 10**9), miss=st.integers(0, 10**7),
       gen=st.integers(0, 10**7))
@settings(max_examples=100, deadline=None)
def test_de_plan_matches_eq_coefficients(hit, miss, gen):
    """DE-read path: DE CNIC reads 2×T_c (Eq.4: paths 3/6), PE CNIC
    writes T_c (Eq.2: path 5), DE CNIC writes T_c (Eq.6: path 7)."""
    full = hit + miss
    rb = resource_bytes(de_read_plan(hit, miss, gen))
    persist = miss + gen
    assert rb.get("de_snic", 0) == hit + persist   # read + block persists
    assert rb.get("de_cnic_rd", 0) == hit + full + persist   # paths 3+6
    assert rb.get("pe_cnic_wr", 0) == hit                    # path 5
    assert rb.get("de_cnic_wr", 0) == miss + full + persist  # path 7 (+miss merge)
    assert rb.get("pe_cnic_rd", 0) == miss                   # miss-back


def test_oracle_plan_empty():
    assert oracle_plan(10**9, 10**6, 10**6) == []


def test_basic_plan_pe_only_storage():
    rb = resource_bytes(basic_plan(1000, 10, 5))
    assert "de_snic" in rb and rb["de_snic"] == 15   # only persists
    assert rb["pe_snic"] == 1000                     # all loads on PE side


def test_layerwise_legs_marked():
    plan = pe_read_plan(1000, 10, 5)
    lw = [l.name for l in plan if l.layerwise]
    assert "pe_buf_to_pe_hbm" in lw and "pe_hbm_to_de_buf" in lw
    assert all(not l.layerwise for l in plan if l.phase == "load")
