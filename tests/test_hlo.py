"""Loop-aware HLO metrics parser (the roofline's data source)."""
import jax
import jax.numpy as jnp
import pytest

from repro.roofline.hlo import parse_hlo_metrics, shape_bytes, \
    xla_cost_analysis

PER_MM = 2 * 128 ** 3


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_shape_bytes():
    assert shape_bytes("f32[128,128]{1,0}") == 128 * 128 * 4
    assert shape_bytes("(bf16[4,2], s32[3])") == 16 + 12
    assert shape_bytes("pred[]") == 1


def test_scan_trip_count_multiplied():
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def f(x, w):
        def body(c, _):
            return c @ w, None
        return jax.lax.scan(body, x, None, length=7)[0]

    m = parse_hlo_metrics(_compile(f, x, x))
    assert abs(m["flops"] / PER_MM - 7) < 0.01


def test_nested_scan():
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def g(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            return jax.lax.scan(inner, c, None, length=3)[0], None
        return jax.lax.scan(outer, x, None, length=5)[0]

    m = parse_hlo_metrics(_compile(g, x, x))
    assert abs(m["flops"] / PER_MM - 15) < 0.01


def test_unrolled_matches():
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def h(x, w):
        for _ in range(4):
            x = x @ w
        return x

    m = parse_hlo_metrics(_compile(h, x, x))
    assert abs(m["flops"] / PER_MM - 4) < 0.01


def test_collective_bytes_sharded_matmul():
    if jax.device_count() < 2:
        pytest.skip("needs >1 device")


def test_bytes_nonzero_and_flops_match_xla_for_straightline():
    """For a loop-free graph our dot FLOPs == XLA cost_analysis flops."""
    x = jax.ShapeDtypeStruct((64, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 32), jnp.float32)

    def f(x, w):
        return jax.nn.relu(x @ w)

    c = jax.jit(f).lower(x, w).compile()
    m = parse_hlo_metrics(c.as_text())
    # xla_cost_analysis normalises the list-vs-dict return across JAX
    # versions (newer JAX returns a per-device list)
    xla = xla_cost_analysis(c)["flops"]
    assert abs(m["flops"] - 2 * 64 * 256 * 32) <= xla * 0.01
    assert m["bytes"] > 0
