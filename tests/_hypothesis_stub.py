"""Deterministic fallback for `hypothesis` when it is not installed.

The property tests in this suite use a small, fixed subset of the
hypothesis API (``given``, ``settings``, ``strategies.integers/floats/
lists/tuples/data``).  In hermetic containers without network access the
real package may be absent; rather than skipping the property tests —
they pin the loading plans to the §4.2 closed form, which is the
repo's core invariant — conftest.py registers this module under the
``hypothesis`` name and the tests run against a deterministic
mini-runner:

* each ``@given`` test runs ``max_examples`` examples (capped at 25 to
  keep the fallback fast) from a per-test seeded RNG, so failures are
  reproducible run-to-run;
* example 0 draws every strategy's minimum and example 1 its maximum,
  so boundary cases (hit=0, empty lists, ...) are always exercised;
* on failure the drawn arguments are attached to the assertion so the
  counterexample is visible, mimicking hypothesis' falsifying-example
  report.

Install the real package (`pip install -r requirements-dev.txt`) to get
shrinking and full coverage; this stub keeps `pytest -q` green and
meaningful without it.
"""
from __future__ import annotations

import functools
import zlib

import numpy as np

_FALLBACK_MAX_EXAMPLES = 25


class _Strategy:
    """Base strategy: subclasses implement draw(rng, mode).

    mode: 'min' | 'max' | 'random' — min/max produce the boundary
    example, random draws from the seeded generator.
    """

    def draw(self, rng: np.random.Generator, mode: str):  # pragma: no cover
        raise NotImplementedError

    def map(self, fn):
        return _MappedStrategy(self, fn)

    def filter(self, pred, _tries: int = 100):
        return _FilteredStrategy(self, pred, _tries)


class _MappedStrategy(_Strategy):
    def __init__(self, base, fn):
        self.base, self.fn = base, fn

    def draw(self, rng, mode):
        return self.fn(self.base.draw(rng, mode))


class _FilteredStrategy(_Strategy):
    def __init__(self, base, pred, tries):
        self.base, self.pred, self.tries = base, pred, tries

    def draw(self, rng, mode):
        for _ in range(self.tries):
            v = self.base.draw(rng, mode)
            if self.pred(v):
                return v
            mode = "random"      # boundary value rejected: sample instead
        raise AssertionError("filter predicate never satisfied")


class _Integers(_Strategy):
    def __init__(self, lo, hi):
        self.lo, self.hi = int(lo), int(hi)

    def draw(self, rng, mode):
        if mode == "min":
            return self.lo
        if mode == "max":
            return self.hi
        return int(rng.integers(self.lo, self.hi + 1))


class _Floats(_Strategy):
    def __init__(self, lo, hi):
        self.lo, self.hi = float(lo), float(hi)

    def draw(self, rng, mode):
        if mode == "min":
            return self.lo
        if mode == "max":
            return self.hi
        return float(rng.uniform(self.lo, self.hi))


class _Booleans(_Strategy):
    def draw(self, rng, mode):
        if mode == "min":
            return False
        if mode == "max":
            return True
        return bool(rng.integers(0, 2))


class _SampledFrom(_Strategy):
    def __init__(self, seq):
        self.seq = list(seq)

    def draw(self, rng, mode):
        if mode == "min":
            return self.seq[0]
        if mode == "max":
            return self.seq[-1]
        return self.seq[int(rng.integers(0, len(self.seq)))]


class _Lists(_Strategy):
    def __init__(self, elem, min_size=0, max_size=None):
        self.elem = elem
        self.min_size = min_size
        self.max_size = max_size if max_size is not None else min_size + 10

    def draw(self, rng, mode):
        if mode == "min":
            n = self.min_size
        elif mode == "max":
            n = self.max_size
        else:
            n = int(rng.integers(self.min_size, self.max_size + 1))
        return [self.elem.draw(rng, mode) for _ in range(n)]


class _Tuples(_Strategy):
    def __init__(self, *elems):
        self.elems = elems

    def draw(self, rng, mode):
        return tuple(e.draw(rng, mode) for e in self.elems)


class _DataObject:
    """Interactive draws (`st.data()`), always random but seeded."""

    def __init__(self, rng):
        self._rng = rng
        self.drawn = []

    def draw(self, strategy, label=None):
        v = strategy.draw(self._rng, "random")
        self.drawn.append(v)
        return v


class _DataStrategy(_Strategy):
    def draw(self, rng, mode):
        return _DataObject(rng)


class strategies:          # noqa: N801 — mirrors `hypothesis.strategies`
    integers = staticmethod(lambda min_value=0, max_value=1 << 30,
                            **kw: _Integers(min_value, max_value))
    floats = staticmethod(lambda min_value=0.0, max_value=1.0,
                          **kw: _Floats(min_value, max_value))
    booleans = staticmethod(lambda: _Booleans())
    sampled_from = staticmethod(lambda seq: _SampledFrom(seq))
    lists = staticmethod(lambda elem, min_size=0, max_size=None,
                         **kw: _Lists(elem, min_size, max_size))
    tuples = staticmethod(lambda *elems: _Tuples(*elems))
    data = staticmethod(lambda: _DataStrategy())


class HealthCheck:
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"

    @classmethod
    def all(cls):
        return [cls.too_slow, cls.data_too_large, cls.filter_too_much]


def settings(max_examples=None, deadline=None, **kw):
    """Decorator marking a test's settings; consumed by @given."""

    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def assume(condition):
    if not condition:
        raise _UnsatisfiedAssumption()


class _UnsatisfiedAssumption(Exception):
    pass


def given(*arg_strategies, **kw_strategies):
    def deco(fn):
        n = getattr(fn, "_stub_max_examples", None) or _FALLBACK_MAX_EXAMPLES
        n = min(n, _FALLBACK_MAX_EXAMPLES)
        seed = zlib.crc32(f"{fn.__module__}.{fn.__qualname__}".encode())

        @functools.wraps(fn)
        def wrapper():
            rng = np.random.default_rng(seed)
            for i in range(n):
                mode = "min" if i == 0 else ("max" if i == 1 else "random")
                args = [s.draw(rng, mode) for s in arg_strategies]
                kwargs = {k: s.draw(rng, mode)
                          for k, s in kw_strategies.items()}
                try:
                    fn(*args, **kwargs)
                except _UnsatisfiedAssumption:
                    continue
                except Exception as e:
                    shown = {f"arg{j}": a for j, a in enumerate(args)}
                    shown.update(kwargs)
                    raise AssertionError(
                        f"falsifying example (stub runner, example {i}): "
                        f"{shown!r}") from e

        # pytest must not treat strategy params as fixtures
        wrapper.__signature__ = __import__("inspect").Signature()
        return wrapper

    return deco


def register(sys_modules):
    """Install this module as `hypothesis` (+`hypothesis.strategies`)."""
    import types

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.assume = assume
    mod.strategies = strategies
    mod.HealthCheck = HealthCheck
    mod.__stub__ = True
    st_mod = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "sampled_from", "lists",
                 "tuples", "data"):
        setattr(st_mod, name, getattr(strategies, name))
    mod.strategies = st_mod
    sys_modules["hypothesis"] = mod
    sys_modules["hypothesis.strategies"] = st_mod
