"""Block layouts (§A.5) + trie store properties."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core.blocks import (BlockLayout, full_from_layer_blocks,
                               layer_blocks_from_full, layout_for,
                               pack_kv_to_blocks, unpack_blocks_to_kv)
from repro.kvcache.trie import BlockTrie


def test_layer_full_roundtrip():
    lay = BlockLayout(n_layers=4, block_tokens=8, bytes_per_token_layer=16)
    full = np.random.default_rng(0).integers(
        0, 255, lay.full_block_shape(), dtype=np.uint8)
    layers = layer_blocks_from_full(full)
    assert all(lb.shape == lay.layer_block_shape() for lb in layers)
    re = full_from_layer_blocks(layers)
    np.testing.assert_array_equal(re, full)


@given(tokens=st.integers(0, 1000))
@settings(max_examples=50, deadline=None)
def test_whole_block_persistence(tokens):
    """Only whole blocks persist (paper: per accumulated 64-token block)."""
    lay = BlockLayout(n_layers=2, block_tokens=64, bytes_per_token_layer=4)
    kv = np.zeros((2, tokens, 4), np.uint8)
    blocks = pack_kv_to_blocks(kv, lay)
    assert len(blocks) == tokens // 64
    back = unpack_blocks_to_kv(blocks, lay)
    assert back.shape[1] == (tokens // 64) * 64


def test_layout_for_known_archs():
    assert layout_for(get_config("llava-next-34b")).bytes_per_token_layer \
        == 2 * 8 * 128 * 2
    assert layout_for(get_config("ds27b")).bytes_per_token_layer == \
        (512 + 64) * 2
    assert layout_for(get_config("mamba2-1.3b")).bytes_per_token_layer == 0
    # zamba2: 9 shared-attention applications carry the per-token KV
    assert layout_for(get_config("zamba2-2.7b")).n_layers == 9


# ---------------------------------------------------------------------------
# trie
# ---------------------------------------------------------------------------


def test_trie_match_insert():
    t = BlockTrie(block_tokens=4)
    toks = list(range(16))
    assert t.match(toks) == (0, [])
    ins = t.insert(toks, [101, 102, 103, 104])
    assert ins == [101, 102, 103, 104]
    hit, refs = t.match(toks + [99, 98])
    assert hit == 16 and refs == [101, 102, 103, 104]
    # diverging suffix hits only the shared prefix
    hit, refs = t.match(toks[:8] + [55] * 8)
    assert hit == 8 and refs == [101, 102]


@given(st.data())
@settings(max_examples=50, deadline=None)
def test_trie_properties(data):
    bt = data.draw(st.integers(1, 8))
    t = BlockTrie(block_tokens=bt)
    ref_counter = [0]

    def fresh_refs(n):
        out = list(range(ref_counter[0], ref_counter[0] + n))
        ref_counter[0] += n
        return out

    seqs = data.draw(st.lists(
        st.lists(st.integers(0, 3), min_size=0, max_size=40),
        min_size=1, max_size=10))
    for s in seqs:
        n_blocks = len(s) // bt
        t.insert(s, fresh_refs(n_blocks))
    for s in seqs:
        hit, refs = t.match(s)
        # inserted sequences always fully hit their whole-block prefix
        assert hit == (len(s) // bt) * bt
        assert len(refs) == hit // bt
        # hit is monotone: prefixes hit at least as much (up to their length)
        half = s[:len(s) // 2]
        h2, _ = t.match(half)
        assert h2 == (len(half) // bt) * bt


def test_trie_lru_eviction():
    t = BlockTrie(block_tokens=2)
    t.insert([1, 2, 3, 4], [1, 2])
    t.insert([1, 2, 9, 9], [3])
    t.match([1, 2, 3, 4])          # touch the 3,4 branch
    evicted = t.evict_lru(1)
    assert evicted == [3]          # LRU leaf was the untouched 9,9 block
    hit, _ = t.match([1, 2, 9, 9])
    assert hit == 2
