"""Intra-engine compute-quota packing (§6.2)."""
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core.intra import (AttnTimeModel, PrefillWork, QuotaPacker,
                              attn_flops)

CFG = get_config("qwen1.5-0.5b")
TM = AttnTimeModel(effective_flops=1e12, base_overhead_s=0.0)


def packer(quota=0.3):
    return QuotaPacker(CFG, TM, quota_s=quota, min_chunk=16)


def test_pack_respects_quota():
    p = packer(quota=0.050)
    fifo = [PrefillWork(i, 30_000, 2000) for i in range(8)]
    batch = p.pack(fifo)
    assert batch
    assert p.predict_batch_seconds([(b.cached, b.bsz) for b in batch]) \
        <= p.quota_s + 1e-9


def test_chunked_prefill_binary_search():
    p = packer(quota=1.0)      # fits ~100 tokens at 100k context
    fifo = [PrefillWork(0, 100_000, 50_000)]
    batch = p.pack(fifo)
    assert len(batch) == 1 and batch[0].chunked
    bsz = batch[0].bsz
    # maximality: bsz+1 would exceed the quota
    assert p.predict_batch_seconds([(100_000, bsz)]) <= p.quota_s
    assert p.predict_batch_seconds([(100_000, bsz + 1)]) > p.quota_s
    # fifo head advanced, not removed
    assert fifo and fifo[0].remaining == 50_000 - bsz


def test_fifo_order():
    p = packer(quota=1000.0)
    fifo = [PrefillWork(i, 10, 100) for i in range(5)]
    batch = p.pack(fifo)
    assert [b.rid for b in batch] == [0, 1, 2, 3, 4]
    assert fifo == []


@given(quota=st.floats(0.001, 1.0),
       works=st.lists(st.tuples(st.integers(0, 100_000),
                                st.integers(1, 10_000)),
                      min_size=1, max_size=20))
@settings(max_examples=50, deadline=None)
def test_property_quota_never_exceeded(quota, works):
    p = packer(quota=quota)
    fifo = [PrefillWork(i, c, b) for i, (c, b) in enumerate(works)]
    batch = p.pack(fifo)
    if batch:
        t = p.predict_batch_seconds([(b.cached, b.bsz) for b in batch])
        assert t <= quota + 1e-9
        for b in batch:
            assert b.bsz >= 1


def test_time_model_fit():
    m = AttnTimeModel(effective_flops=2e12, base_overhead_s=1e-4)
    samples = [(f, m.seconds(f)) for f in (1e9, 5e9, 2e10, 1e11)]
    fit = AttnTimeModel.fit(samples)
    assert abs(fit.effective_flops - 2e12) / 2e12 < 1e-6
    assert abs(fit.base_overhead_s - 1e-4) < 1e-8


def test_attn_flops_quadratic_in_context():
    f1 = attn_flops(CFG, [(1000, 100)])
    f2 = attn_flops(CFG, [(2000, 100)])
    assert f2 > f1 * 1.9
