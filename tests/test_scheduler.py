"""Inter-engine scheduler (§6.1, Algorithm 1) — behaviour + invariants."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.scheduler import Request, RoundRobinScheduler, Scheduler


def mk_sched(alpha=100, beta=1000, n_pe=3, n_de=3, **kw):
    s = Scheduler(alpha=alpha, beta=beta, **kw)
    for i in range(n_pe):
        s.register_engine((i, 0), node=i, kind="pe", group=0)
    for j in range(n_de):
        st_ = s.register_engine((10 + j, 0), node=10 + j, kind="de",
                                group=1000)
        st_.free_hbm_tokens = 10_000
    return s


def reqs(*sizes, gen=10):
    return [Request(rid=i, cached_tokens=s, new_tokens=10, gen_tokens=gen)
            for i, s in enumerate(sizes)]


# ---------------------------------------------------------------------------
# Algorithm 1
# ---------------------------------------------------------------------------


def test_alg1_prefers_short_read_queue():
    s = mk_sched(alpha=100)
    s.engines[(0, 0)].read_q = 500     # C3: long read queue
    s.engines[(1, 0)].read_q = 50      # C2
    s.engines[(2, 0)].read_q = 40      # C2, higher tok
    s.engines[(2, 0)].tok = 100
    for r in reqs(100):
        s.submit(r)
    out = s.on_pe_fetch(0)
    assert out[0].engine == (1, 0)     # C2 with min tok


def test_alg1_skips_overloaded():
    s = mk_sched(beta=100)
    s.engines[(0, 0)].tok = 150        # C1: overloaded
    s.engines[(1, 0)].tok = 150
    s.engines[(2, 0)].tok = 50
    for r in reqs(10, 10, 10):
        s.submit(r)
    out = s.on_pe_fetch(0)
    assert all(a.engine == (2, 0) for a in out[:1])


def test_alg1_terminates_when_all_overloaded():
    s = mk_sched(beta=10)
    for e in s.engines.values():
        if e.kind == "pe":
            e.tok = 100
    for r in reqs(10, 10):
        s.submit(r)
    out = s.on_pe_fetch(0)
    assert out == []
    assert len(s.pe_queue) == 2        # queue preserved


def test_alg1_reclassifies_after_assignment():
    """An engine pushed over beta by an assignment stops receiving."""
    s = mk_sched(beta=100, n_pe=2)
    s.engines[(1, 0)].tok = 90
    s.engines[(0, 0)].tok = 80
    for r in reqs(50, 50, 50):        # prompt = cached+new = 60 each
        s.submit(r)
    out = s.on_pe_fetch(0)
    # first -> (0,0) tok 80->140 (overloaded); second -> (1,0) 90->150;
    # third: no engine left
    assert [a.engine for a in out] == [(0, 0), (1, 0)]
    assert len(s.pe_queue) == 1


def test_fifo_order_preserved():
    s = mk_sched()
    rs = reqs(10, 20, 30, 40)
    for r in rs:
        s.submit(r)
    out = s.on_pe_fetch(0)
    assert [a.request.rid for a in out] == [0, 1, 2, 3]


# ---------------------------------------------------------------------------
# DE scheduling
# ---------------------------------------------------------------------------


def test_de_phase1_balances_groups():
    s = Scheduler(alpha=10, beta=10_000)
    for j in range(2):
        for k in range(2):
            st_ = s.register_engine((j, k), node=j, kind="de", group=j)
            st_.free_hbm_tokens = 100_000
    s.engines[(0, 0)].tok = 5000       # group 0 heavily loaded
    for r in reqs(100, 100, 100, 100):
        s.submit(r)
        s.de_global_queue[-1]          # in queue
    s.de_phase1()
    # group 1 (empty) should receive more work
    assert len(s.de_private[1]) >= len(s.de_private[0])


def test_de_within_group_hbm_admission():
    s = mk_sched(n_de=2)
    for st_ in s.engines.values():
        if st_.kind == "de":
            st_.free_hbm_tokens = 100
    big = Request(rid=0, cached_tokens=500, new_tokens=10, gen_tokens=10)
    s.submit(big)
    out = s.on_de_fetch(1000)
    assert out == []                   # no DE has enough HBM
    small = Request(rid=1, cached_tokens=10, new_tokens=10, gen_tokens=10)
    s.submit(small)
    out = s.on_de_fetch(1000)
    # FIFO head (big) still blocks the queue — the paper pops from head
    assert out == []


def test_de_prefers_low_token_class_by_seq():
    s = mk_sched(n_de=3)
    des = [e for e in s.engines.values() if e.kind == "de"]
    des[0].tok, des[0].seq = 10, 5
    des[1].tok, des[1].seq = 20, 1
    des[2].tok, des[2].seq = 100_000, 0    # will exceed Z
    r = Request(rid=0, cached_tokens=100, new_tokens=10, gen_tokens=10)
    s.submit(r)
    out = s.on_de_fetch(1000)
    assert out[0].engine == des[1].engine  # min seq among low-token class


# ---------------------------------------------------------------------------
# read-path selection
# ---------------------------------------------------------------------------


def test_read_path_shorter_queue_wins():
    s = mk_sched()
    r = Request(rid=0, cached_tokens=100, new_tokens=10, gen_tokens=10)
    r.pe, r.de = (0, 0), (10, 0)
    s.engines[(0, 0)].read_q = 1000
    s.engines[(10, 0)].read_q = 10
    assert s.choose_read_path(r) == "de"
    # the chosen side's queue grows by the request's cached tokens
    assert s.engines[(10, 0)].read_q == 110


def test_read_path_tie_prefers_pe():
    s = mk_sched()
    r = Request(rid=0, cached_tokens=100, new_tokens=10, gen_tokens=10)
    r.pe, r.de = (0, 0), (10, 0)
    assert s.choose_read_path(r) == "pe"


# ---------------------------------------------------------------------------
# split reads (§6.1 future work)
# ---------------------------------------------------------------------------


def test_split_read_even_when_queues_equal():
    s = mk_sched(split_reads=True)
    r = Request(rid=0, cached_tokens=100, new_tokens=10, gen_tokens=10)
    r.pe, r.de = (0, 0), (10, 0)
    s.choose_read_path(r)
    assert r.read_split == 0.5 and r.pe_read_frac == 0.5
    # both sides' disk queues are charged their share
    assert s.engines[(0, 0)].read_q == 50
    assert s.engines[(10, 0)].read_q == 50


def test_split_read_water_filling_equalises_queues():
    """The split equalises pe_q + x·h == de_q + (1−x)·h."""
    s = mk_sched(split_reads=True)
    s.engines[(10, 0)].read_q = 30     # DE backlogged by 30
    r = Request(rid=0, cached_tokens=100, new_tokens=10, gen_tokens=10)
    r.pe, r.de = (0, 0), (10, 0)
    s.choose_read_path(r)
    # x = (30 - 0 + 100) / 200 = 0.65 -> PE majority side
    assert r.read_path == "pe" and abs(r.read_split - 0.65) < 1e-12
    tokens = r.read_tokens_by_side()
    assert tokens == {"pe": 65, "de": 35}
    assert s.engines[(0, 0)].read_q == 65       # 0 + 65
    assert s.engines[(10, 0)].read_q == 65      # 30 + 35: equalised


def test_split_read_collapses_to_pure_side_under_heavy_skew():
    """When one queue exceeds the other by more than the request's own
    read, water-filling clamps to a pure read on the short side."""
    s = mk_sched(split_reads=True)
    s.engines[(0, 0)].read_q = 1000
    r = Request(rid=0, cached_tokens=100, new_tokens=10, gen_tokens=10)
    r.pe, r.de = (0, 0), (10, 0)
    s.choose_read_path(r)
    assert r.read_path == "de" and r.read_split == 1.0
    assert r.pe_read_frac == 0.0
    assert s.engines[(0, 0)].read_q == 1000     # untouched
    assert s.engines[(10, 0)].read_q == 100


def test_split_read_tokens_always_sum_to_cached():
    s = mk_sched(split_reads=True)
    for pe_q, de_q, cached in [(0, 0, 101), (7, 19, 33), (5, 0, 1)]:
        s.engines[(0, 0)].read_q = pe_q
        s.engines[(10, 0)].read_q = de_q
        r = Request(rid=0, cached_tokens=cached, new_tokens=1, gen_tokens=1)
        r.pe, r.de = (0, 0), (10, 0)
        s.choose_read_path(r)
        tokens = r.read_tokens_by_side()
        assert tokens["pe"] + tokens["de"] == cached
        # on_read_done per side restores the queues exactly
        s.on_read_done((0, 0), tokens["pe"])
        s.on_read_done((10, 0), tokens["de"])
        assert s.engines[(0, 0)].read_q == pe_q
        assert s.engines[(10, 0)].read_q == de_q


# ---------------------------------------------------------------------------
# properties
# ---------------------------------------------------------------------------


@given(sizes=st.lists(st.integers(0, 2000), min_size=1, max_size=40),
       beta=st.integers(100, 5000))
@settings(max_examples=50, deadline=None)
def test_property_assignments_complete_and_balanced(sizes, beta):
    s = mk_sched(alpha=1 << 30, beta=beta)
    rs = reqs(*sizes)
    for r in rs:
        s.submit(r)
    out = s.on_pe_fetch(0)
    # every assignment has a PE; FIFO prefix property
    assert [a.request.rid for a in out] == list(range(len(out)))
    for a in out:
        assert a.request.pe is not None
    # no engine exceeds beta by more than one request's prompt
    for e in s.engines.values():
        if e.kind == "pe" and e.tok > beta:
            assert e.tok - beta <= max(r.prompt_tokens for r in rs)


@given(n=st.integers(1, 30))
@settings(max_examples=30, deadline=None)
def test_property_de_hbm_never_oversubscribed(n):
    s = mk_sched(n_de=3)
    cap = 10_000
    for r in reqs(*([300] * n)):
        s.submit(r)
    out = s.on_de_fetch(1000)
    used = {}
    for a in out:
        used[a.engine] = used.get(a.engine, 0) + a.request.hbm_tokens
    for e, u in used.items():
        assert u <= cap


# ---------------------------------------------------------------------------
# water-fill / read-token partition properties (fuzzed)
# ---------------------------------------------------------------------------


@given(pe_q=st.integers(0, 1 << 20), de_q=st.integers(0, 1 << 20),
       h=st.integers(1, 1 << 20))
@settings(max_examples=100, deadline=None)
def test_property_water_fill_frac_in_unit_interval(pe_q, de_q, h):
    s = mk_sched(split_reads=True)
    x = s._water_fill_frac(pe_q, de_q, h)
    assert 0.0 <= x <= 1.0
    # equalisation when neither side clamps: pe_q + xh == de_q + (1-x)h
    if 0.0 < x < 1.0:
        assert pe_q + x * h == pytest.approx(de_q + (1 - x) * h)


@given(pe_q=st.integers(0, 100_000), h=st.integers(1, 100_000),
       skews=st.lists(st.integers(0, 50_000), min_size=2, max_size=10))
@settings(max_examples=50, deadline=None)
def test_property_water_fill_monotone_in_queue_skew(pe_q, h, skews):
    """The PE share never decreases as the DE queue grows deeper."""
    s = mk_sched(split_reads=True)
    fracs = [s._water_fill_frac(pe_q, pe_q + d, h) for d in sorted(skews)]
    assert all(b >= a - 1e-12 for a, b in zip(fracs, fracs[1:])), fracs


@given(alpha=st.integers(1, 1 << 20), beta=st.integers(1, 1 << 20),
       pe_q=st.integers(0, 1 << 16), de_q=st.integers(0, 1 << 16),
       cached=st.integers(0, 1 << 16), split=st.booleans())
@settings(max_examples=100, deadline=None)
def test_property_read_tokens_conserve_hit(alpha, beta, pe_q, de_q,
                                           cached, split):
    """Whatever alpha/beta/queues/hit sizes the scheduler sees, the
    per-side read tokens sum to exactly the hit, the fraction stays in
    [0, 1], and on_read_done restores both queues exactly."""
    s = mk_sched(alpha=alpha, beta=beta, split_reads=split)
    s.engines[(0, 0)].read_q = pe_q
    s.engines[(10, 0)].read_q = de_q
    r = Request(rid=0, cached_tokens=cached, new_tokens=1, gen_tokens=1)
    r.pe, r.de = (0, 0), (10, 0)
    s.choose_read_path(r)
    assert 0.0 <= r.pe_read_frac <= 1.0
    tokens = r.read_tokens_by_side()
    assert tokens["pe"] >= 0 and tokens["de"] >= 0
    assert tokens["pe"] + tokens["de"] == cached
    s.on_read_done((0, 0), tokens["pe"])
    s.on_read_done((10, 0), tokens["de"])
    assert s.engines[(0, 0)].read_q == pe_q
    assert s.engines[(10, 0)].read_q == de_q


@given(cached=st.integers(1, 1 << 16), t_pe=st.integers(0, 1 << 16),
       t_de=st.integers(0, 1 << 16), pe_q=st.integers(0, 1 << 16),
       de_q=st.integers(0, 1 << 16), split=st.booleans())
@settings(max_examples=100, deadline=None)
def test_property_tier_partition_conserves_hit(cached, t_pe, t_de, pe_q,
                                               de_q, split):
    """With a DRAM-tier prefix the explicit partition still conserves:
    dram + snic_pe + snic_de == cached, block partition included."""
    s = mk_sched(split_reads=split)
    s.engines[(0, 0)].read_q = pe_q
    s.engines[(10, 0)].read_q = de_q
    r = Request(rid=0, cached_tokens=cached, new_tokens=1, gen_tokens=1)
    r.pe, r.de = (0, 0), (10, 0)
    s.choose_read_path(r, tier_tokens={"pe": t_pe, "de": t_de})
    if r.snic_tokens is not None:
        assert (r.dram_tokens + r.snic_tokens["pe"] +
                r.snic_tokens["de"]) == cached
        assert 0.0 <= r.pe_read_frac <= 1.0
        n_blocks = cached        # 1 token per block: exact partition
        part = r.hit_blocks_by_side(n_blocks)
        assert part["tier"] + part["pe"] + part["de"] == n_blocks


# ---------------------------------------------------------------------------
# hedged split reads (fault tolerance — sim/faults.py)
# ---------------------------------------------------------------------------


@given(rem=st.integers(0, 1 << 16), backlog=st.integers(0, 1 << 16),
       sevs=st.lists(st.floats(1.0, 64.0), min_size=2, max_size=8))
@settings(max_examples=100, deadline=None)
def test_property_hedge_water_fill_monotone_in_severity(rem, backlog,
                                                        sevs):
    """The loading.hedge_water_fill contract: the moved share stays in
    [0, remainder] and never decreases as the observed straggle severity
    grows — a worse straggler never hedges less."""
    from repro.core.loading import hedge_water_fill
    moves = [hedge_water_fill(rem, s, backlog) for s in sorted(sevs)]
    assert all(0 <= m <= rem for m in moves)
    assert all(b >= a for a, b in zip(moves, moves[1:])), moves


@given(rem=st.integers(0, 1 << 16), backlog=st.integers(0, 1 << 16))
@settings(max_examples=100, deadline=None)
def test_property_hedge_water_fill_zero_iff_healthy_and_unloaded(rem,
                                                                 backlog):
    """At severity 1 the hedge moves nothing exactly when the healthy
    side's backlog already covers the remainder (the equalising
    water level is non-positive)."""
    from repro.core.loading import hedge_water_fill
    moved = hedge_water_fill(rem, 1.0, backlog)
    if backlog >= rem:
        assert moved == 0
    else:
        assert moved == (rem - backlog) // 2


@given(cached=st.integers(1, 1 << 14), pe_q=st.integers(0, 1 << 14),
       de_q=st.integers(0, 1 << 14), rem_frac=st.floats(0.0, 1.0),
       sev=st.floats(1.0, 32.0), backlog=st.integers(0, 1 << 14),
       side=st.sampled_from(["pe", "de"]))
@settings(max_examples=100, deadline=None)
def test_property_scheduler_rebalance_conserves_charge(cached, pe_q, de_q,
                                                       rem_frac, sev,
                                                       backlog, side):
    """Scheduler.rebalance_remainder: the per-side token partition
    conserves the hit exactly, the moved share never exceeds the
    remainder, the disk-queue charge transfers atomically, and the
    final on_read_done releases balance both queues to their
    pre-request values."""
    s = mk_sched(split_reads=True)
    s.engines[(0, 0)].read_q = pe_q
    s.engines[(10, 0)].read_q = de_q
    r = Request(rid=0, cached_tokens=cached, new_tokens=1, gen_tokens=1)
    r.pe, r.de = (0, 0), (10, 0)
    s.choose_read_path(r)
    before = dict(r.read_tokens_by_side())
    q_pe = s.engines[(0, 0)].read_q
    q_de = s.engines[(10, 0)].read_q
    rem = int(before[side] * rem_frac)
    moved = s.rebalance_remainder(r, side, rem, sev,
                                  healthy_backlog_tokens=backlog)
    after = r.read_tokens_by_side()
    assert 0 <= moved <= rem                      # fraction in [0, 1]
    assert after["pe"] + after["de"] == cached    # conservation, exact
    assert after[side] == before[side] - moved
    sign = -1 if side == "pe" else +1
    assert s.engines[(0, 0)].read_q == q_pe + sign * moved
    assert s.engines[(10, 0)].read_q == q_de - sign * moved
    assert 0.0 <= r.read_split <= 1.0
    # each side's eventual on_read_done releases its *current* share:
    # the books balance to the pre-request queues exactly
    s.on_read_done((0, 0), after["pe"])
    s.on_read_done((10, 0), after["de"])
    assert s.engines[(0, 0)].read_q == pe_q
    assert s.engines[(10, 0)].read_q == de_q


@given(cached=st.integers(1, 1 << 14), rem=st.integers(0, 1 << 14),
       backlog=st.integers(0, 1 << 14),
       sevs=st.lists(st.floats(1.0, 32.0), min_size=2, max_size=6))
@settings(max_examples=50, deadline=None)
def test_property_scheduler_rebalance_monotone_in_severity(cached, rem,
                                                           backlog, sevs):
    """For a fixed pre-hedge state, the moved token count is monotone
    non-decreasing in the observed straggle severity."""
    moves = []
    for sev in sorted(sevs):
        s = mk_sched(split_reads=True)
        r = Request(rid=0, cached_tokens=cached, new_tokens=1,
                    gen_tokens=1)
        r.pe, r.de = (0, 0), (10, 0)
        s.choose_read_path(r)
        moves.append(s.rebalance_remainder(
            r, "pe", rem, sev, healthy_backlog_tokens=backlog))
    assert all(b >= a for a, b in zip(moves, moves[1:])), moves


def test_rebalance_never_recharges_tier_hits_to_a_snic():
    """A request whose hit is partly DRAM-tier served: the hedge's
    remainder clamps to the straggling side's SNIC share, and the tier
    partition is untouched — tier-hit tokens can never migrate into a
    storage-NIC charge."""
    s = mk_sched(split_reads=True)
    r = Request(rid=0, cached_tokens=100, new_tokens=10, gen_tokens=10)
    r.pe, r.de = (0, 0), (10, 0)
    s.choose_read_path(r, tier_tokens={"pe": 40, "de": 0})
    dram = (r.dram_side, r.dram_tokens)
    before = dict(r.snic_tokens)
    # ask to move "everything": only the DE SNIC share is movable
    moved = s.rebalance_remainder(r, "de", 10 ** 9, severity=32.0)
    assert moved <= before["de"]
    assert (r.dram_side, r.dram_tokens) == dram
    assert (r.snic_tokens["pe"] + r.snic_tokens["de"] ==
            before["pe"] + before["de"])
    assert (r.dram_tokens + r.snic_tokens["pe"] +
            r.snic_tokens["de"]) == 100


def test_rebalance_zero_move_leaves_request_untouched():
    s = mk_sched(split_reads=True)
    r = Request(rid=0, cached_tokens=100, new_tokens=10, gen_tokens=10)
    r.pe, r.de = (0, 0), (10, 0)
    s.choose_read_path(r)
    state = (r.read_path, r.read_split, dict(r.read_tokens_by_side()))
    # severity 1, no backlog advantage over an empty remainder
    assert s.rebalance_remainder(r, "pe", 0, 8.0) == 0
    assert (r.read_path, r.read_split,
            dict(r.read_tokens_by_side())) == state


# ---------------------------------------------------------------------------
# fail-stop engine removal (sim/faults.py EngineDeath)
# ---------------------------------------------------------------------------


def test_fail_engine_removes_from_registry_and_tolerates_late_hooks():
    s = mk_sched()
    rs = reqs(50, 60)
    for r in rs:
        s.submit(r)
    out = s.on_pe_fetch(0)
    victim = out[0].engine
    st_ = s.fail_engine(victim)
    assert st_.engine == victim
    assert victim not in s.engines
    assert victim not in s._groups.get(0, [])
    # late completion hooks from in-flight work are swallowed, not raised
    s.on_read_done(victim, 100)
    s.on_request_done(victim, out[0].request)
    # the survivors keep scheduling
    s.submit(Request(rid=9, cached_tokens=10, new_tokens=10,
                     gen_tokens=10))
    out2 = s.on_pe_fetch(0)
    assert out2 and all(a.engine != victim for a in out2)


def test_fail_engine_reroutes_orphaned_private_queue():
    """Killing a DE group's last member must push its private queue
    back to the global queue (in submission order) for re-routing —
    requests conserved, nothing stranded."""
    s = Scheduler(alpha=10, beta=10_000)
    for j in range(2):
        st_ = s.register_engine((j, 0), node=j, kind="de", group=j)
        st_.free_hbm_tokens = 10_000
    for r in reqs(100, 100, 100, 100):
        s.submit(r)
    s.de_phase1()
    total = (len(s.de_global_queue) +
             sum(len(q) for q in s.de_private.values()))
    assert total == 4
    s.fail_engine((0, 0))
    assert (0, 0) not in s.engines
    assert 0 not in s.de_private          # orphaned queue dissolved
    left = (len(s.de_global_queue) +
            sum(len(q) for q in s.de_private.values()))
    assert left == total                  # every request conserved
    assert [r.rid for r in s.de_global_queue] == \
        sorted(r.rid for r in s.de_global_queue)


# ---------------------------------------------------------------------------
# compute-network back-pressure (repro.network congestion signal)
# ---------------------------------------------------------------------------


def test_congestion_shifts_split_read_toward_pe():
    """Only DE-side reads cross the PE<->DE link, so a congested link
    must shift the water-filled fraction toward the PE side."""
    s = mk_sched(split_reads=True)
    r = Request(rid=0, cached_tokens=100, new_tokens=10, gen_tokens=10)
    r.pe, r.de = (0, 0), (10, 0)
    s.choose_read_path(r, net_congestion=1.0)
    assert r.pe_read_frac > 0.5
    tokens = r.read_tokens_by_side()
    assert tokens["pe"] > tokens["de"]
    assert tokens["pe"] + tokens["de"] == 100


def test_congestion_biases_pure_read_choice():
    s = mk_sched()
    s.engines[(0, 0)].read_q = 120      # PE slightly deeper
    s.engines[(10, 0)].read_q = 100
    r = Request(rid=0, cached_tokens=100, new_tokens=10, gen_tokens=10)
    r.pe, r.de = (0, 0), (10, 0)
    # uncongested: DE wins (shorter queue); congested: PE wins
    assert s.choose_read_path(r, net_congestion=0.0) == "de"
    s.on_read_done((10, 0), 100)
    r2 = Request(rid=1, cached_tokens=100, new_tokens=10, gen_tokens=10)
    r2.pe, r2.de = (0, 0), (10, 0)
    assert s.choose_read_path(r2, net_congestion=0.5) == "pe"


def test_zero_congestion_is_bitwise_legacy():
    """net_congestion=0 (and omitting it) must reproduce the historical
    choice exactly — the congestion bias is strictly additive."""
    a, b = mk_sched(split_reads=True), mk_sched(split_reads=True)
    for pe_q, de_q, cached in [(0, 0, 101), (7, 19, 33), (500, 2, 64)]:
        got = []
        for s, kw in ((a, {}), (b, {"net_congestion": 0.0})):
            s.engines[(0, 0)].read_q = pe_q
            s.engines[(10, 0)].read_q = de_q
            r = Request(rid=0, cached_tokens=cached, new_tokens=1,
                        gen_tokens=1)
            r.pe, r.de = (0, 0), (10, 0)
            s.choose_read_path(r, **kw)
            got.append((r.read_path, r.read_split,
                        tuple(sorted(r.read_tokens_by_side().items()))))
        assert got[0] == got[1], (pe_q, de_q, cached, got)


# ---------------------------------------------------------------------------
# RoundRobinScheduler tier awareness (parity with Scheduler)
# ---------------------------------------------------------------------------


def mk_rr(**kw):
    s = RoundRobinScheduler(alpha=100, beta=1000, **kw)
    s.register_engine((0, 0), node=0, kind="pe", group=0)
    st_ = s.register_engine((10, 0), node=10, kind="de", group=1000)
    st_.free_hbm_tokens = 10_000
    return s


def test_rr_choose_read_path_uses_tier_tokens():
    """The RR baseline no longer ignores tier residency: the side whose
    DRAM holds the hit prefix serves it without charging any read_q."""
    s = mk_rr()
    r = Request(rid=0, cached_tokens=100, new_tokens=10, gen_tokens=10)
    r.pe, r.de = (0, 0), (10, 0)
    s.choose_read_path(r, tier_tokens={"pe": 0, "de": 60})
    assert r.dram_side == "de" and r.dram_tokens == 60
    assert r.snic_tokens["pe"] + r.snic_tokens["de"] == 40
    # tier-served tokens never enter a disk reading queue
    assert (s.engines[(0, 0)].read_q +
            s.engines[(10, 0)].read_q) == 40


def test_rr_tier_preference_parity_with_scheduler():
    """On unequal tier prefixes RR picks the same DRAM side and token
    count as the adaptive scheduler — the tier preference is data
    locality, not scheduling policy."""
    cases = [({"pe": 80, "de": 0}, "pe", 80),
             ({"pe": 16, "de": 48}, "de", 48),
             ({"pe": 200, "de": 0}, "pe", 100)]   # clamped to the hit
    for tier, want_side, want_tokens in cases:
        for mk in (mk_sched, mk_rr):
            s = mk()
            r = Request(rid=0, cached_tokens=100, new_tokens=10,
                        gen_tokens=10)
            r.pe, r.de = ((0, 0), (10, 0))
            s.choose_read_path(r, tier_tokens=dict(tier))
            assert r.dram_side == want_side, (mk.__name__, tier)
            assert r.dram_tokens == want_tokens, (mk.__name__, tier)
            assert (r.dram_tokens + r.snic_tokens["pe"] +
                    r.snic_tokens["de"]) == 100


def test_rr_equal_tier_prefixes_actually_alternate():
    """Equal warm prefixes on both sides: the chosen side must flip
    across requests (a double counter draw per request would freeze the
    parity and pin every request to one side)."""
    s = mk_rr()
    picks = []
    for i in range(4):
        r = Request(rid=i, cached_tokens=100, new_tokens=10, gen_tokens=10)
        r.pe, r.de = (0, 0), (10, 0)
        s.choose_read_path(r, tier_tokens={"pe": 40, "de": 40})
        picks.append((r.dram_side,
                      "pe" if r.snic_tokens["pe"] else "de"))
    assert picks == [("pe", "pe"), ("de", "de"),
                     ("pe", "pe"), ("de", "de")]
    # the two sides' disk queues are charged symmetrically over a pair
    assert s.engines[(0, 0)].read_q == s.engines[(10, 0)].read_q == 120


def test_rr_cold_remainder_keeps_alternation():
    """The cold (SNIC) remainder alternates sides across requests —
    the RR property Fig. 13 isolates — instead of following queues."""
    s = mk_rr()
    sides = []
    for i in range(4):
        r = Request(rid=i, cached_tokens=100, new_tokens=10, gen_tokens=10)
        r.pe, r.de = (0, 0), (10, 0)
        s.choose_read_path(r, tier_tokens={"pe": 20, "de": 0})
        sides.append("pe" if r.snic_tokens["pe"] else "de")
    assert sides == ["pe", "de", "pe", "de"]


def test_rr_tiered_sim_serves_dram_hits():
    """End-to-end parity: a tiered simulator run under the RR baseline
    now reports DRAM-tier hits (it reported none before the fix)."""
    from repro.sim import DS_660B, HOPPER_NODE, Sim, SimConfig, \
        generate_dataset
    trajs = generate_dataset(6, 32768, seed=0, think_mean_s=1.0)
    res = {}
    for scheduler in ("adaptive", "rr"):
        from repro.core.config import TierConfig
        cfg = SimConfig(node=HOPPER_NODE, model=DS_660B, P=1, D=1,
                        mode="dualpath", scheduler=scheduler,
                        tier=TierConfig(dram_tier_bytes=2e9))
        r = Sim(cfg, trajs).run().results()
        assert r["finished_agents"] == 6, scheduler
        res[scheduler] = r
    assert res["rr"]["dram_hit_ratio"] > 0.0
    # per-request conservation holds under RR too (charged legs match
    # the plans, already asserted per round by the sim charge test)


def test_round_robin_baseline():
    s = RoundRobinScheduler(alpha=10, beta=10)
    for i in range(2):
        s.register_engine((i, 0), node=i, kind="pe", group=0)
        st_ = s.register_engine((10 + i, 0), node=10 + i, kind="de",
                                group=1000)
        st_.free_hbm_tokens = 10_000
    for r in reqs(10, 10, 10, 10):
        s.submit(r)
    out = s.on_pe_fetch(0)
    assert [a.engine for a in out] == [(0, 0), (1, 0), (0, 0), (1, 0)]
