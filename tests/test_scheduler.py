"""Inter-engine scheduler (§6.1, Algorithm 1) — behaviour + invariants."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.scheduler import Request, RoundRobinScheduler, Scheduler


def mk_sched(alpha=100, beta=1000, n_pe=3, n_de=3, **kw):
    s = Scheduler(alpha=alpha, beta=beta, **kw)
    for i in range(n_pe):
        s.register_engine((i, 0), node=i, kind="pe", group=0)
    for j in range(n_de):
        st_ = s.register_engine((10 + j, 0), node=10 + j, kind="de",
                                group=1000)
        st_.free_hbm_tokens = 10_000
    return s


def reqs(*sizes, gen=10):
    return [Request(rid=i, cached_tokens=s, new_tokens=10, gen_tokens=gen)
            for i, s in enumerate(sizes)]


# ---------------------------------------------------------------------------
# Algorithm 1
# ---------------------------------------------------------------------------


def test_alg1_prefers_short_read_queue():
    s = mk_sched(alpha=100)
    s.engines[(0, 0)].read_q = 500     # C3: long read queue
    s.engines[(1, 0)].read_q = 50      # C2
    s.engines[(2, 0)].read_q = 40      # C2, higher tok
    s.engines[(2, 0)].tok = 100
    for r in reqs(100):
        s.submit(r)
    out = s.on_pe_fetch(0)
    assert out[0].engine == (1, 0)     # C2 with min tok


def test_alg1_skips_overloaded():
    s = mk_sched(beta=100)
    s.engines[(0, 0)].tok = 150        # C1: overloaded
    s.engines[(1, 0)].tok = 150
    s.engines[(2, 0)].tok = 50
    for r in reqs(10, 10, 10):
        s.submit(r)
    out = s.on_pe_fetch(0)
    assert all(a.engine == (2, 0) for a in out[:1])


def test_alg1_terminates_when_all_overloaded():
    s = mk_sched(beta=10)
    for e in s.engines.values():
        if e.kind == "pe":
            e.tok = 100
    for r in reqs(10, 10):
        s.submit(r)
    out = s.on_pe_fetch(0)
    assert out == []
    assert len(s.pe_queue) == 2        # queue preserved


def test_alg1_reclassifies_after_assignment():
    """An engine pushed over beta by an assignment stops receiving."""
    s = mk_sched(beta=100, n_pe=2)
    s.engines[(1, 0)].tok = 90
    s.engines[(0, 0)].tok = 80
    for r in reqs(50, 50, 50):        # prompt = cached+new = 60 each
        s.submit(r)
    out = s.on_pe_fetch(0)
    # first -> (0,0) tok 80->140 (overloaded); second -> (1,0) 90->150;
    # third: no engine left
    assert [a.engine for a in out] == [(0, 0), (1, 0)]
    assert len(s.pe_queue) == 1


def test_fifo_order_preserved():
    s = mk_sched()
    rs = reqs(10, 20, 30, 40)
    for r in rs:
        s.submit(r)
    out = s.on_pe_fetch(0)
    assert [a.request.rid for a in out] == [0, 1, 2, 3]


# ---------------------------------------------------------------------------
# DE scheduling
# ---------------------------------------------------------------------------


def test_de_phase1_balances_groups():
    s = Scheduler(alpha=10, beta=10_000)
    for j in range(2):
        for k in range(2):
            st_ = s.register_engine((j, k), node=j, kind="de", group=j)
            st_.free_hbm_tokens = 100_000
    s.engines[(0, 0)].tok = 5000       # group 0 heavily loaded
    for r in reqs(100, 100, 100, 100):
        s.submit(r)
        s.de_global_queue[-1]          # in queue
    s.de_phase1()
    # group 1 (empty) should receive more work
    assert len(s.de_private[1]) >= len(s.de_private[0])


def test_de_within_group_hbm_admission():
    s = mk_sched(n_de=2)
    for st_ in s.engines.values():
        if st_.kind == "de":
            st_.free_hbm_tokens = 100
    big = Request(rid=0, cached_tokens=500, new_tokens=10, gen_tokens=10)
    s.submit(big)
    out = s.on_de_fetch(1000)
    assert out == []                   # no DE has enough HBM
    small = Request(rid=1, cached_tokens=10, new_tokens=10, gen_tokens=10)
    s.submit(small)
    out = s.on_de_fetch(1000)
    # FIFO head (big) still blocks the queue — the paper pops from head
    assert out == []


def test_de_prefers_low_token_class_by_seq():
    s = mk_sched(n_de=3)
    des = [e for e in s.engines.values() if e.kind == "de"]
    des[0].tok, des[0].seq = 10, 5
    des[1].tok, des[1].seq = 20, 1
    des[2].tok, des[2].seq = 100_000, 0    # will exceed Z
    r = Request(rid=0, cached_tokens=100, new_tokens=10, gen_tokens=10)
    s.submit(r)
    out = s.on_de_fetch(1000)
    assert out[0].engine == des[1].engine  # min seq among low-token class


# ---------------------------------------------------------------------------
# read-path selection
# ---------------------------------------------------------------------------


def test_read_path_shorter_queue_wins():
    s = mk_sched()
    r = Request(rid=0, cached_tokens=100, new_tokens=10, gen_tokens=10)
    r.pe, r.de = (0, 0), (10, 0)
    s.engines[(0, 0)].read_q = 1000
    s.engines[(10, 0)].read_q = 10
    assert s.choose_read_path(r) == "de"
    # the chosen side's queue grows by the request's cached tokens
    assert s.engines[(10, 0)].read_q == 110


def test_read_path_tie_prefers_pe():
    s = mk_sched()
    r = Request(rid=0, cached_tokens=100, new_tokens=10, gen_tokens=10)
    r.pe, r.de = (0, 0), (10, 0)
    assert s.choose_read_path(r) == "pe"


# ---------------------------------------------------------------------------
# split reads (§6.1 future work)
# ---------------------------------------------------------------------------


def test_split_read_even_when_queues_equal():
    s = mk_sched(split_reads=True)
    r = Request(rid=0, cached_tokens=100, new_tokens=10, gen_tokens=10)
    r.pe, r.de = (0, 0), (10, 0)
    s.choose_read_path(r)
    assert r.read_split == 0.5 and r.pe_read_frac == 0.5
    # both sides' disk queues are charged their share
    assert s.engines[(0, 0)].read_q == 50
    assert s.engines[(10, 0)].read_q == 50


def test_split_read_water_filling_equalises_queues():
    """The split equalises pe_q + x·h == de_q + (1−x)·h."""
    s = mk_sched(split_reads=True)
    s.engines[(10, 0)].read_q = 30     # DE backlogged by 30
    r = Request(rid=0, cached_tokens=100, new_tokens=10, gen_tokens=10)
    r.pe, r.de = (0, 0), (10, 0)
    s.choose_read_path(r)
    # x = (30 - 0 + 100) / 200 = 0.65 -> PE majority side
    assert r.read_path == "pe" and abs(r.read_split - 0.65) < 1e-12
    tokens = r.read_tokens_by_side()
    assert tokens == {"pe": 65, "de": 35}
    assert s.engines[(0, 0)].read_q == 65       # 0 + 65
    assert s.engines[(10, 0)].read_q == 65      # 30 + 35: equalised


def test_split_read_collapses_to_pure_side_under_heavy_skew():
    """When one queue exceeds the other by more than the request's own
    read, water-filling clamps to a pure read on the short side."""
    s = mk_sched(split_reads=True)
    s.engines[(0, 0)].read_q = 1000
    r = Request(rid=0, cached_tokens=100, new_tokens=10, gen_tokens=10)
    r.pe, r.de = (0, 0), (10, 0)
    s.choose_read_path(r)
    assert r.read_path == "de" and r.read_split == 1.0
    assert r.pe_read_frac == 0.0
    assert s.engines[(0, 0)].read_q == 1000     # untouched
    assert s.engines[(10, 0)].read_q == 100


def test_split_read_tokens_always_sum_to_cached():
    s = mk_sched(split_reads=True)
    for pe_q, de_q, cached in [(0, 0, 101), (7, 19, 33), (5, 0, 1)]:
        s.engines[(0, 0)].read_q = pe_q
        s.engines[(10, 0)].read_q = de_q
        r = Request(rid=0, cached_tokens=cached, new_tokens=1, gen_tokens=1)
        r.pe, r.de = (0, 0), (10, 0)
        s.choose_read_path(r)
        tokens = r.read_tokens_by_side()
        assert tokens["pe"] + tokens["de"] == cached
        # on_read_done per side restores the queues exactly
        s.on_read_done((0, 0), tokens["pe"])
        s.on_read_done((10, 0), tokens["de"])
        assert s.engines[(0, 0)].read_q == pe_q
        assert s.engines[(10, 0)].read_q == de_q


# ---------------------------------------------------------------------------
# properties
# ---------------------------------------------------------------------------


@given(sizes=st.lists(st.integers(0, 2000), min_size=1, max_size=40),
       beta=st.integers(100, 5000))
@settings(max_examples=50, deadline=None)
def test_property_assignments_complete_and_balanced(sizes, beta):
    s = mk_sched(alpha=1 << 30, beta=beta)
    rs = reqs(*sizes)
    for r in rs:
        s.submit(r)
    out = s.on_pe_fetch(0)
    # every assignment has a PE; FIFO prefix property
    assert [a.request.rid for a in out] == list(range(len(out)))
    for a in out:
        assert a.request.pe is not None
    # no engine exceeds beta by more than one request's prompt
    for e in s.engines.values():
        if e.kind == "pe" and e.tok > beta:
            assert e.tok - beta <= max(r.prompt_tokens for r in rs)


@given(n=st.integers(1, 30))
@settings(max_examples=30, deadline=None)
def test_property_de_hbm_never_oversubscribed(n):
    s = mk_sched(n_de=3)
    cap = 10_000
    for r in reqs(*([300] * n)):
        s.submit(r)
    out = s.on_de_fetch(1000)
    used = {}
    for a in out:
        used[a.engine] = used.get(a.engine, 0) + a.request.hbm_tokens
    for e, u in used.items():
        assert u <= cap


def test_round_robin_baseline():
    s = RoundRobinScheduler(alpha=10, beta=10)
    for i in range(2):
        s.register_engine((i, 0), node=i, kind="pe", group=0)
        st_ = s.register_engine((10 + i, 0), node=10 + i, kind="de",
                                group=1000)
        st_.free_hbm_tokens = 10_000
    for r in reqs(10, 10, 10, 10):
        s.submit(r)
    out = s.on_pe_fetch(0)
    assert [a.engine for a in out] == [(0, 0), (1, 0), (0, 0), (1, 0)]
