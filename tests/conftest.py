# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see
# the single real CPU device; only launch/dryrun.py forces 512 devices.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:                                   # property tests prefer the real thing
    import hypothesis                  # noqa: F401
except ImportError:                    # hermetic container: deterministic stub
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub

    _hypothesis_stub.register(sys.modules)

import jax
import pytest


def pytest_report_header(config):
    """Which property-test arm is active: the real hypothesis (CI, with
    shrinking) or the deterministic no-dep stub (hermetic containers).
    Asserting this in the header makes a CI run that silently fell back
    to the stub visible in its logs."""
    import hypothesis

    if getattr(hypothesis, "__stub__", False):
        return ("property tests: hypothesis STUB "
                "(tests/_hypothesis_stub.py — deterministic fallback)")
    return (f"property tests: hypothesis {hypothesis.__version__} "
            f"(real shrinking)")


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    # the suite compiles hundreds of XLA programs; on the CPU backend the
    # LLVM JIT memory is never returned, so long single-process runs OOM
    # ("Cannot allocate memory" in execution_engine) — clear per module
    yield
    jax.clear_caches()
