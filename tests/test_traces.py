"""Trace generator vs paper Table 2."""
import numpy as np
import pytest

from repro.sim.traces import TABLE2, dataset_stats, generate_dataset


@pytest.mark.parametrize("max_len", [32768, 49152, 65536])
def test_table2_stats(max_len):
    st = dataset_stats(generate_dataset(200, max_len, seed=0))
    tgt = TABLE2[max_len]
    # Total and Gen are matched tightly; Turns/Append are jointly
    # inconsistent in the paper's pooling (see traces.py) — matched to 35%.
    assert abs(st["total"] - tgt["total"]) / tgt["total"] < 0.15
    assert abs(st["gen"] - tgt["gen"]) / tgt["gen"] < 0.10
    assert abs(st["turns"] - tgt["turns"]) / tgt["turns"] < 0.40
    assert abs(st["append"] - tgt["append"]) / tgt["append"] < 0.35
    assert abs(st["context"] - tgt["context"]) / tgt["context"] < 0.25


def test_hit_rate_matches_paper():
    """Paper §3: 98.7% KV hit rate on the 64K trace."""
    st = dataset_stats(generate_dataset(300, 65536, seed=0))
    assert st["hit_rate"] > 0.98


def test_deterministic():
    a = generate_dataset(20, 32768, seed=7)
    b = generate_dataset(20, 32768, seed=7)
    for x, y in zip(a, b):
        assert [(r.append, r.gen) for r in x.rounds] == \
            [(r.append, r.gen) for r in y.rounds]


def test_scaling_truncates():
    t = generate_dataset(5, 65536, seed=0)[0]
    s = t.scaled(append_scale=4.0, max_len=65536)
    assert s.total_tokens <= 65536
    mean_a = np.mean([r.append for r in s.rounds])
    assert mean_a > np.mean([r.append for r in t.rounds]) * 1.5


def test_augmentation_prepends_synthetic_round():
    ds = generate_dataset(510, 32768, seed=0, base=500)
    aug = ds[505]
    assert aug.rounds[0].gen == 1      # synthetic first round (§A.3)
