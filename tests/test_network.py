"""Finite compute-network model (repro.network): SharedLink arbitration,
the fluid two-class drain, collective volumes, and the simulator-level
interference-avoidance claim (§5.1)."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.traffic import DEFAULT_ARBITER, TrafficClass
from repro.network import (ARBITERS, CollectiveVolumeModel, SharedLink,
                           drain_times, kv_share_when_contended)


class _FakeFlow:
    def __init__(self, tclass, nbytes=100.0):
        self.tclass = tclass
        self.nbytes_left = float(nbytes)
        self.nbytes_total = float(nbytes)
        self.t_enter = 0.0


def _link(arbiter, cap=100e9):
    return SharedLink("net", cap, arbiter=arbiter)


# ---------------------------------------------------------------------------
# SharedLink rate allocation
# ---------------------------------------------------------------------------


def test_vl_link_gives_collectives_priority():
    """One collective vs many KV flows: under 'vl' the collective keeps
    >= ~94% of the link no matter how deep the KV backlog."""
    link = _link("vl")
    coll = _FakeFlow(TrafficClass.MODEL_COLLECTIVE)
    kvs = [_FakeFlow(TrafficClass.KV_TRANSFER) for _ in range(10)]
    link.flows.update([coll] + kvs)
    assert link.rate_of(coll) >= 0.94 * link.cap
    # KV never starves, and the class share splits fairly within class
    kv_rate = link.rate_of(kvs[0])
    assert kv_rate > 0
    assert kv_rate == pytest.approx(link.rate_of(kvs[5]))
    # conservation: class shares sum to the capacity
    total = link.rate_of(coll) + 10 * kv_rate
    assert total == pytest.approx(link.cap, rel=1e-9)


def test_fifo_link_is_class_blind():
    link = _link("fifo")
    coll = _FakeFlow(TrafficClass.MODEL_COLLECTIVE)
    kvs = [_FakeFlow(TrafficClass.KV_TRANSFER) for _ in range(9)]
    link.flows.update([coll] + kvs)
    # naive processor sharing: the collective is just one of ten flows
    assert link.rate_of(coll) == pytest.approx(link.cap / 10)
    assert link.rate_of(kvs[0]) == pytest.approx(link.cap / 10)


def test_kv_gets_full_link_when_no_collectives():
    for arb in ARBITERS:
        link = _link(arb)
        kvs = [_FakeFlow(TrafficClass.KV_TRANSFER) for _ in range(4)]
        link.flows.update(kvs)
        assert link.rate_of(kvs[0]) == pytest.approx(link.cap / 4)


def test_infinite_link_is_transparent():
    """cap=inf (the legacy no-congestion configuration): unbounded
    rates, zero congestion, no delay accounting."""
    link = _link("vl", cap=float("inf"))
    f = _FakeFlow(TrafficClass.KV_TRANSFER)
    link.flows.add(f)
    assert math.isinf(link.rate_of(f))
    assert link.congestion() == 0.0
    link.note_done(f, now=100.0)
    assert link.transfer_backlog_s == 0.0


def test_congestion_signal_tracks_collective_share():
    link = _link("vl")
    assert link.congestion() == 0.0           # idle
    kv = _FakeFlow(TrafficClass.KV_TRANSFER, nbytes=300)
    link.flows.add(kv)
    assert link.congestion() == 0.0           # KV only
    co = _FakeFlow(TrafficClass.MODEL_COLLECTIVE, nbytes=100)
    link.flows.add(co)
    assert link.congestion() == pytest.approx(0.25)   # 100 / 400
    link.flows.discard(kv)
    assert link.congestion() == pytest.approx(1.0)


def test_note_done_attributes_delay_by_class():
    link = _link("vl", cap=100.0)
    kv = _FakeFlow(TrafficClass.KV_TRANSFER, nbytes=100)   # 1 s alone
    kv.t_enter = 0.0
    link.note_done(kv, now=3.0)                 # took 3 s: 2 s delay
    assert link.transfer_backlog_s == pytest.approx(2.0)
    assert link.collective_delay_s == 0.0
    co = _FakeFlow(TrafficClass.MODEL_COLLECTIVE, nbytes=200)
    co.t_enter = 1.0
    link.note_done(co, now=3.0)                 # exactly the alone time
    assert link.collective_delay_s == pytest.approx(0.0)
    assert link.bytes_by_class[TrafficClass.MODEL_COLLECTIVE] == 200


def test_bad_arbiter_rejected():
    with pytest.raises(ValueError):
        SharedLink("net", 1e9, arbiter="strict")


# ---------------------------------------------------------------------------
# fluid two-class drain (the serving runtime's contention model)
# ---------------------------------------------------------------------------


def test_drain_times_vl_collectives_unharmed():
    """VL shares: collectives finish in ~their alone time; the KV
    backlog absorbs the whole contention delay (work conservation)."""
    share = kv_share_when_contended("vl")
    assert 0.0 < share <= 0.01      # §A.1 tables: leak = 0.0059
    kv_done, coll_done = drain_times(10.0, 1.0, share)
    assert coll_done == pytest.approx(1.0 / (1 - share))
    assert kv_done == pytest.approx(11.0)


def test_drain_times_fifo_interference():
    """FIFO halves: a deep KV backlog doubles the collectives' time."""
    kv_done, coll_done = drain_times(10.0, 1.0, 0.5)
    assert coll_done == pytest.approx(2.0)
    assert kv_done == pytest.approx(11.0)
    # and symmetrically when the collectives outlast the KV
    kv_done, coll_done = drain_times(1.0, 10.0, 0.5)
    assert kv_done == pytest.approx(2.0)
    assert coll_done == pytest.approx(11.0)


def test_drain_times_edges():
    assert drain_times(0.0, 5.0, 0.5) == (0.0, 5.0)
    assert drain_times(5.0, 0.0, 0.5) == (5.0, 0.0)
    assert drain_times(3.0, 4.0, 0.0) == (7.0, 4.0)   # KV fully starved
    assert drain_times(3.0, 4.0, 1.0) == (3.0, 7.0)


@given(kv=st.floats(0.0, 1e4), coll=st.floats(0.0, 1e4),
       share=st.floats(0.0, 1.0))
@settings(max_examples=100, deadline=None)
def test_drain_times_work_conserving(kv, coll, share):
    kv_done, coll_done = drain_times(kv, coll, share)
    assert kv_done >= kv - 1e-9 and coll_done >= coll - 1e-9
    if kv > 0 and coll > 0:
        # a work-conserving link finishes the later class at kv+coll
        assert max(kv_done, coll_done) == pytest.approx(kv + coll)
        assert min(kv_done, coll_done) <= kv + coll + 1e-9


# ---------------------------------------------------------------------------
# collective volumes
# ---------------------------------------------------------------------------


def test_serving_cn_seconds_contended_matches_drain():
    """ServingTimeModel.cn_seconds(nbytes, coll_bytes=) is the KV
    completion of the fluid drain under the configured arbiter —
    consistent with cn_drain, and the uncontended path unchanged."""
    from repro.configs import get_config
    from repro.serving.events import ServingTimeModel
    from repro.sim.spec import REDUCED_TEST_NODE as node
    cfg = get_config("qwen1.5-0.5b").reduced()
    for arb in ARBITERS:
        tm = ServingTimeModel.for_model(cfg, node, net_arbiter=arb,
                                        collective_group_size=8)
        assert tm.collectives is not None
        nbytes, coll = 3e6, 1e6
        assert tm.cn_seconds(nbytes) == pytest.approx(nbytes / node.cnic_bw)
        kv_done, coll_done = tm.cn_drain(nbytes / node.cnic_bw,
                                         coll / node.cnic_bw)
        assert tm.cn_seconds(nbytes, coll_bytes=coll) == \
            pytest.approx(kv_done)
        # work conservation: the KV side never finishes before the
        # combined service time when it is the later class
        assert kv_done == pytest.approx((nbytes + coll) / node.cnic_bw)
        if arb == "vl":
            assert coll_done == pytest.approx(
                coll / node.cnic_bw / DEFAULT_ARBITER.high_fraction())
    # group_size <= 1: collectives off entirely
    tm0 = ServingTimeModel.for_model(cfg, node)
    assert tm0.collectives is None


def test_shared_link_rate_cache_tracks_flow_changes():
    """The lazy census must follow joins/leaves (via the note hooks or
    the length fallback) — rates stay exact as the flow set mutates."""
    link = _link("vl")
    kv = _FakeFlow(TrafficClass.KV_TRANSFER)
    link.note_enter(kv)
    link.flows.add(kv)
    assert link.rate_of(kv) == pytest.approx(link.cap)
    co = _FakeFlow(TrafficClass.MODEL_COLLECTIVE)
    link.note_enter(co)
    link.flows.add(co)
    assert link.rate_of(co) >= 0.94 * link.cap
    assert link.rate_of(kv) < 0.06 * link.cap
    link.flows.discard(co)
    link.note_done(co, now=0.0)
    assert link.rate_of(kv) == pytest.approx(link.cap)


def test_collective_volume_analytic():
    m1 = CollectiveVolumeModel.analytic(4, 1024, group_size=1)
    assert m1.bytes_per_token == 0.0          # unsharded: nothing crosses
    m8 = CollectiveVolumeModel.analytic(4, 1024, group_size=8)
    m2 = CollectiveVolumeModel.analytic(4, 1024, group_size=2)
    assert m8.bytes_per_token > m2.bytes_per_token > 0
    assert m8.step_bytes(10) == pytest.approx(10 * m8.bytes_per_token)
    assert m8.bytes_per_token_layer == pytest.approx(m8.bytes_per_token / 4)


def test_collective_volume_from_spec_and_config():
    from repro.configs import get_config
    from repro.sim import DS_660B
    ms = CollectiveVolumeModel.from_spec(DS_660B, group_size=8)
    assert ms.bytes_per_token > 0 and ms.n_layers == DS_660B.n_layers
    cfg = get_config("qwen1.5-0.5b").reduced()
    mc = CollectiveVolumeModel.from_config(cfg, group_size=8)
    assert mc.bytes_per_token > 0 and mc.n_layers == cfg.n_layers


def test_collective_volume_from_hlo_text():
    """The measured constructor divides the parser's loop-aware
    collective bytes by the token count."""
    hlo = """\
HloModule m

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (p0: f32[16,128]) -> f32[16,128] {
  %p0 = f32[16,128] parameter(0)
  ROOT %ar = f32[16,128] all-reduce(%p0), to_apply=%add
}
"""
    m = CollectiveVolumeModel.from_hlo_text(hlo, n_tokens=16, n_layers=1)
    assert m.bytes_per_token == pytest.approx(16 * 128 * 4 / 16)


# ---------------------------------------------------------------------------
# simulator integration: the interference-avoidance claim
# ---------------------------------------------------------------------------


def _run_sim(arbiter, load, n_agents=8, **kw):
    from repro.sim import DS_660B, HOPPER_NODE, Sim, SimConfig, \
        generate_dataset
    trajs = generate_dataset(n_agents, 32768, seed=0)
    from repro.core.config import NetworkConfig
    cfg = SimConfig(node=HOPPER_NODE, model=DS_660B, P=1, D=2,
                    mode="dualpath",
                    net=NetworkConfig(net_bw=25e9, net_arbiter=arbiter,
                                      collective_bytes_per_token=0.4e6,
                                      net_bg_load=load),
                    **kw)
    return Sim(cfg, trajs).run()


def test_default_sim_has_no_network_accounting():
    """net_bw=None (the default) keeps the paper's no-congestion
    assumption: nothing stalls, nothing backlogs, nothing is counted."""
    from repro.sim import DS_660B, HOPPER_NODE, Sim, SimConfig, \
        generate_dataset
    trajs = generate_dataset(4, 32768, seed=0)
    cfg = SimConfig(node=HOPPER_NODE, model=DS_660B, P=1, D=1,
                    mode="dualpath")
    r = Sim(cfg, trajs).run().results()
    assert r["finished_agents"] == 4
    assert r["collective_stall_s"] == 0.0
    assert r["transfer_backlog_s"] == 0.0
    assert r["net_collective_bytes"] == 0.0


def test_collectives_on_infinite_link_terminate():
    """model_collectives=True without net_bw: the collective Flow's
    only resource is the infinite link — it must complete instantly
    (rate=inf), not spin the event loop on nan residuals."""
    from repro.sim import DS_660B, HOPPER_NODE, Sim, SimConfig
    from repro.sim.traces import Round, Trajectory
    trajs = [Trajectory(0, [Round(256, 8)])]
    from repro.core.config import NetworkConfig
    cfg = SimConfig(node=HOPPER_NODE, model=DS_660B, P=1, D=1,
                    mode="dualpath",
                    net=NetworkConfig(model_collectives=True))
    r = Sim(cfg, trajs).run().results()
    assert r["finished_agents"] == 1
    assert r["collective_stall_s"] == 0.0


def test_vl_arbiter_avoids_interference_fifo_does_not():
    """The paper's central online claim, reproduced: under background
    transfer load the VL arbiter keeps model-execution stall ~ 0 while
    naive FIFO sharing lets cache movement starve the collectives."""
    vl = _run_sim("vl", load=0.9).results()
    fifo = _run_sim("fifo", load=0.9).results()
    assert vl["finished_agents"] == fifo["finished_agents"] == 8
    assert vl["collective_stall_s"] <= 0.01 * vl["sim_time"]
    assert fifo["collective_stall_s"] > vl["collective_stall_s"]
    # the KV side pays instead under VL: its backlog exceeds FIFO's
    assert vl["transfer_backlog_s"] > 0
    assert vl["net_collective_bytes"] > 0
    assert vl["net_kv_bytes"] > 0


def test_finite_network_preserves_plan_byte_accounting():
    """The finite link changes WHEN bytes move, never HOW MANY: per
    round the charged bytes still equal the loading-plan sums."""
    from repro.core.loading import resource_bytes
    sim = _run_sim("fifo", load=0.5, n_agents=4)
    checked = 0
    for rs in sim.rounds:
        if rs.done_t < 0 or rs.req.read_path is None:
            continue
        legs = [leg for leg in sim._request_legs(rs.req)
                if leg.phase != "decode"]
        exp = {k: v for k, v in resource_bytes(legs).items() if v}
        got = {k: v for k, v in rs.charged.items() if v}
        assert got == exp, (rs.req.rid, got, exp)
        checked += 1
    assert checked > 0


def test_sim_slo_attainment_uses_serving_estimator():
    """Sim.slo_attainment goes through serving's slo_attainment, so the
    two runtimes share one SLO definition."""
    from repro.serving.events import slo_attainment
    sim = _run_sim("vl", load=0.0, n_agents=4)
    ms = sim.round_metrics()
    assert len(ms) == len(sim.rounds)
    att = sim.slo_attainment(4.0, 0.050)
    assert att == pytest.approx(slo_attainment(ms, 4.0, 0.050))
    assert 0.0 <= att <= 1.0
