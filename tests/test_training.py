"""Training substrate: optimizers, schedules, data, fault tolerance."""
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.ckpt import FaultTolerantRunner, restore_checkpoint, save_checkpoint
from repro.models import init_params
from repro.training import (SyntheticLM, TrajectoryLM, cosine,
                            make_optimizer, make_train_step, wsd)

KEY = jax.random.PRNGKey(0)
CFG = get_config("qwen1.5-0.5b").reduced()


def _setup():
    params = init_params(CFG, KEY)
    opt_init, train_step = make_train_step(CFG, lr=1e-3, n_microbatches=2)
    return params, opt_init, jax.jit(train_step)


def test_loss_decreases():
    params, opt_init, ts = _setup()
    opt = opt_init(params)
    pipe = SyntheticLM(CFG.vocab_size, batch=4, seq=32, seed=1)
    losses = []
    for _ in range(10):
        params, opt, loss = ts(params, opt, jnp.asarray(pipe.next_batch()))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.9, losses


def test_microbatching_equivalent():
    """Grad accumulation over n microbatches == one big batch (f32 grads)."""
    params = init_params(CFG, KEY)
    batch = jnp.asarray(
        SyntheticLM(CFG.vocab_size, batch=4, seq=16, seed=2).next_batch())
    outs = []
    for n in (1, 2, 4):
        opt_init, ts = make_train_step(CFG, lr=1e-3, n_microbatches=n)
        p, _, loss = ts(params, opt_init(params), batch)
        outs.append((loss, p))
    l0 = jax.tree.leaves(outs[0][1])[0]
    for loss, p in outs[1:]:
        # microbatch means of per-µb losses differ from the full-batch loss
        # only by averaging order
        assert abs(float(loss) - float(outs[0][0])) < 0.05
        np.testing.assert_allclose(
            np.asarray(jax.tree.leaves(p)[0], np.float32),
            np.asarray(l0, np.float32), atol=5e-2)


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_updates(name):
    init, update = make_optimizer(name)
    params = {"w": jnp.ones((8, 4)), "b": jnp.zeros((4,))}
    grads = {"w": jnp.full((8, 4), 0.5), "b": jnp.full((4,), -0.5)}
    st = init(params)
    p2, st2 = update(params, grads, st, lr=0.1)
    assert bool(jnp.all(p2["w"] < params["w"]))
    assert bool(jnp.all(p2["b"] > params["b"]))
    assert int(st2["step"]) == 1


def test_adafactor_state_is_factored():
    init, _ = make_optimizer("adafactor")
    params = {"w": jnp.ones((64, 32))}
    st = init(params)
    sizes = sum(int(np.prod(a.shape)) for a in jax.tree.leaves(st["fac"]))
    assert sizes == 64 + 32            # vr + vc, not 64*32


def test_wsd_schedule():
    kw = dict(peak_lr=1.0, warmup=10, stable=100, decay=20)
    assert wsd(0, **kw) < wsd(9, **kw) <= 1.0
    assert wsd(50, **kw) == 1.0
    assert wsd(129, **kw) < 0.2
    assert cosine(0, peak_lr=1.0, warmup=5, total=50) < 1.0


def test_pipeline_checkpointable():
    p1 = SyntheticLM(100, 2, 8, seed=3)
    p1.next_batch()
    b = p1.next_batch()
    p2 = SyntheticLM(100, 2, 8, seed=3)
    p2.load_state_dict(dict(seed=3, step=1))
    np.testing.assert_array_equal(p2.next_batch(), b)


def test_trajectory_pipeline():
    p = TrajectoryLM(100, 2, 64, max_len=32768, seed=0)
    batch = p.next_batch()
    assert batch.shape == (2, 64)


def test_crash_resume_bitwise():
    params, opt_init, ts = _setup()
    pipe = SyntheticLM(CFG.vocab_size, batch=4, seq=32, seed=1)
    d = tempfile.mkdtemp()
    try:
        r = FaultTolerantRunner(d, ts, params, opt_init(params), pipe,
                                ckpt_every=3)
        with pytest.raises(RuntimeError):
            r.run(8, crash_at=5)
        p2 = init_params(CFG, KEY)
        r2 = FaultTolerantRunner(
            d, ts, p2, opt_init(p2),
            SyntheticLM(CFG.vocab_size, batch=4, seq=32, seed=1),
            ckpt_every=3)
        assert r2.try_resume() and r2.step == 3
        r2.run(8)
        # uninterrupted reference
        p3 = init_params(CFG, KEY)
        d3 = tempfile.mkdtemp()
        r3 = FaultTolerantRunner(
            d3, ts, p3, opt_init(p3),
            SyntheticLM(CFG.vocab_size, batch=4, seq=32, seed=1),
            ckpt_every=100)
        ref = r3.run(8)
        assert np.allclose(ref[3:], r2.losses, atol=0), \
            (ref[3:], r2.losses)
        shutil.rmtree(d3)
    finally:
        shutil.rmtree(d)


def test_checkpoint_atomic_and_latest():
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    opt = {"m": jnp.zeros((4,), jnp.float32), "step": jnp.zeros((), jnp.int32)}
    d = tempfile.mkdtemp()
    try:
        save_checkpoint(d, 1, params, opt)
        save_checkpoint(d, 2, params, opt)
        r = restore_checkpoint(d, params, opt)
        assert r["step"] == 2
        assert r["params"]["w"].dtype == jnp.bfloat16
    finally:
        shutil.rmtree(d)
