"""KV serialisation: state -> FullBlock bytes -> state roundtrip."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.engines import kvio
from repro.models import init_decode_state, init_params
from repro.models.model import append_step

KEY = jax.random.PRNGKey(0)

PAGED_ARCHS = ["qwen1.5-0.5b", "gemma2-2b", "granite-moe-3b-a800m",
               "llama4-maverick-400b-a17b", "ds27b", "llava-next-34b"]


@pytest.mark.parametrize("arch", PAGED_ARCHS)
def test_serialize_roundtrip(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, KEY)
    b, s, cap = 2, 12, 24
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    st = init_decode_state(cfg, b, cap)
    _, st = append_step(params, cfg, toks, st,
                        jnp.zeros((b,), jnp.int32))
    # serialise slot 0 tokens [0, 12), restore into a fresh state
    kv = kvio.serialize_kv(cfg, st, 0, 0, s)
    assert kv.dtype == np.uint8
    assert kv.shape[0] == kvio.n_attn_layers(cfg)
    assert kv.shape[1] == s
    assert kv.shape[2] == kvio.kv_row_bytes(cfg)
    st2 = init_decode_state(cfg, b, cap)
    st2 = kvio.deserialize_kv(cfg, st2, 0, 0, kv)
    # all attention-cache leaves must agree on slot 0, [0, s)
    def check(a, b_):
        if a.ndim >= 3 and a.shape[-2:] == b_.shape[-2:]:
            pass
    axes = kvio.batch_axes_of_state(cfg)
    kv1 = kvio.serialize_kv(cfg, st2, 0, 0, s)
    np.testing.assert_array_equal(kv, kv1)


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "zamba2-2.7b", "ds27b"])
def test_slot_get_set_roundtrip(arch):
    cfg = get_config(arch).reduced()
    st = init_decode_state(cfg, 3, 16)
    axes = kvio.batch_axes_of_state(cfg)
    # fill slot 1 with random data, move to slot 2 of a fresh state
    st_r = jax.tree.map(
        lambda a: jax.random.normal(KEY, a.shape).astype(a.dtype), st)
    sub = kvio.slot_get(st_r, axes, 1)
    st2 = kvio.slot_set(st, axes, 2, sub)
    sub2 = kvio.slot_get(st2, axes, 2)
    for a, b in zip(jax.tree.leaves(sub), jax.tree.leaves(sub2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_deserialized_cache_continues_decode():
    """The restored cache is functionally identical: continuing decode
    from deserialised KV matches continuing from the live state."""
    from repro.models import decode_step
    cfg = get_config("qwen1.5-0.5b").reduced()
    params = init_params(cfg, KEY)
    b, s, cap = 1, 8, 16
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    st = init_decode_state(cfg, b, cap)
    _, st = append_step(params, cfg, toks, st, jnp.zeros((b,), jnp.int32))
    kv = kvio.serialize_kv(cfg, st, 0, 0, s)
    st2 = kvio.deserialize_kv(cfg, init_decode_state(cfg, b, cap), 0, 0, kv)
    nxt = jnp.array([5], jnp.int32)
    lengths = jnp.full((b,), s, jnp.int32)
    l1, _ = decode_step(params, cfg, nxt, st, lengths)
    l2, _ = decode_step(params, cfg, nxt, st2, lengths)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
