"""Fault injection for TrafficManager's flush/poll halves.

The pipelined serving runtime rides on two contracts:

* completion callbacks fire EXACTLY ONCE per flush, no matter how the
  poll side is sliced (partial polls, interleaved flush batches,
  re-entrant callbacks, faulting payload thunks);
* the per-class byte/WR accounting is exact — a doorbell batch neither
  loses nor double-counts a WR, including WRs the congestion pacing
  defers across flushes.

These tests break the manager on purpose along each of those axes."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.traffic import SubmitCostModel, TrafficClass, TrafficManager


def _kv(tm, fn=lambda: None, nbytes=1):
    tm.submit(fn, nbytes, TrafficClass.KV_TRANSFER)


# ---------------------------------------------------------------------------
# partial / out-of-order completion across interleaved flush batches
# ---------------------------------------------------------------------------


def test_partial_polls_fire_each_flush_callback_exactly_once():
    """Three interleaved flush batches, drained in ragged poll chunks:
    each on_complete fires exactly once, at its batch's last transfer."""
    tm = TrafficManager(doorbell_batch=2)
    fired = []
    sizes = (3, 1, 4)
    for i, n in enumerate(sizes):
        for _ in range(n):
            _kv(tm, nbytes=10)
        tm.flush(on_complete=lambda i=i: fired.append(i))
    # ragged completion: 2 + 1 + 2 + 3 = 8 transfers
    assert tm.poll(max_n=2) == 2 and fired == []
    assert tm.poll(max_n=1) == 1 and fired == [0]       # batch 0 done at 3
    assert tm.poll(max_n=2) == 2 and fired == [0, 1]    # batch 1 done at 4
    assert tm.poll() == 3 and fired == [0, 1, 2]
    assert not tm.busy
    assert tm.bytes[TrafficClass.KV_TRANSFER] == 80


def test_zero_then_nonzero_flush_interleaving():
    tm = TrafficManager()
    fired = []
    tm.flush(on_complete=lambda: fired.append("empty"))
    assert fired == ["empty"]                  # nothing queued: immediate
    _kv(tm)
    tm.flush(on_complete=lambda: fired.append("one"))
    tm.flush(on_complete=lambda: fired.append("empty2"))
    assert fired == ["empty", "empty2"]        # second flush saw no queue
    tm.poll()
    assert fired == ["empty", "empty2", "one"]


def test_completion_counts_are_per_flush_not_global():
    """A later flush's transfers must not satisfy an earlier flush's
    countdown (and vice versa) even when polls interleave them."""
    tm = TrafficManager()
    done = []
    _kv(tm)
    _kv(tm)
    tm.flush(on_complete=lambda: done.append("a"))      # a: 2 transfers
    _kv(tm)
    tm.flush(on_complete=lambda: done.append("b"))      # b: 1 transfer
    assert tm.poll(max_n=1) == 1 and done == []
    assert tm.poll(max_n=1) == 1 and done == ["a"]
    assert tm.poll(max_n=1) == 1 and done == ["a", "b"]


# ---------------------------------------------------------------------------
# faulting payload thunks — the CQE-error contract
# ---------------------------------------------------------------------------


def test_faulting_thunk_completes_exactly_once_and_poll_resumes():
    """A thunk that raises is still a completion (popped, callbacks
    fired, error propagated) — a retry poll cannot double-execute it,
    and the rest of the ring drains normally."""
    tm = TrafficManager()
    ran = []
    fired = []
    _kv(tm, fn=lambda: ran.append("ok1"))
    _kv(tm, fn=lambda: (_ for _ in ()).throw(RuntimeError("dma fault")))
    _kv(tm, fn=lambda: ran.append("ok2"))
    tm.flush(on_complete=lambda: fired.append(True))
    with pytest.raises(RuntimeError):
        tm.poll()
    assert ran == ["ok1"]
    assert tm.in_flight == 1                   # fault consumed its WR
    assert tm.poll() == 1                      # resume drains the rest
    assert ran == ["ok1", "ok2"]
    assert fired == [True]                     # batch callback exactly once
    assert not tm.busy


def test_faulting_callback_does_not_rerun_transfer():
    """A completion callback that raises must not leave the transfer
    re-executable."""
    tm = TrafficManager()
    ran = []
    _kv(tm, fn=lambda: ran.append(1))
    tm.flush(on_complete=lambda: (_ for _ in ()).throw(ValueError("cb")))
    with pytest.raises(ValueError):
        tm.poll()
    assert ran == [1]
    assert tm.poll() == 0 and not tm.busy      # nothing left to re-run
    assert ran == [1]


# ---------------------------------------------------------------------------
# re-entrancy: callbacks that drive the manager from inside poll
# ---------------------------------------------------------------------------


def test_reentrant_submit_flush_from_completion_callback():
    """The persist-completion path submits new WRs and flushes from
    inside a poll — counts and ordering must stay exact."""
    tm = TrafficManager()
    order = []
    fired = []

    def resubmit():
        tm.submit(lambda: order.append("child"), 5,
                  TrafficClass.KV_TRANSFER)
        tm.flush(on_complete=lambda: fired.append("child-batch"))

    tm.submit(lambda: order.append("parent"), 5, TrafficClass.KV_TRANSFER)
    tm.flush(on_complete=resubmit)
    n = tm.poll()          # parent executes, cb flushes the child in-ring
    n += tm.poll()
    assert n == 2
    assert order == ["parent", "child"]
    assert fired == ["child-batch"]
    assert tm.stats[TrafficClass.KV_TRANSFER] == 2
    assert tm.bytes[TrafficClass.KV_TRANSFER] == 10


def test_reentrant_poll_cannot_double_execute():
    tm = TrafficManager()
    ran = []

    def nested():
        ran.append("a")
        tm.poll()          # re-enter: must not re-run "a"

    _kv(tm, fn=nested)
    _kv(tm, fn=lambda: ran.append("b"))
    tm.flush()
    tm.poll()
    assert ran == ["a", "b"]


# ---------------------------------------------------------------------------
# randomized interleavings (fuzz): exactly-once + exact accounting
# ---------------------------------------------------------------------------


@given(seed=st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_fuzz_interleaved_flush_poll_accounting(seed):
    import numpy as np
    rng = np.random.default_rng(seed)
    tm = TrafficManager(doorbell_batch=int(rng.integers(1, 5)))
    executed = []
    submitted = 0
    submitted_bytes = 0
    completions = []       # (flush_id, n_in_batch)
    fired = {}
    flush_id = 0
    for _ in range(rng.integers(5, 30)):
        op = rng.integers(0, 3)
        if op == 0:
            n = int(rng.integers(1, 6))
            for i in range(n):
                nbytes = int(rng.integers(1, 100))
                tm.submit(lambda i=submitted + i: executed.append(i),
                          nbytes, TrafficClass.KV_TRANSFER)
                submitted_bytes += nbytes
            submitted += n
        elif op == 1:
            fid = flush_id
            flush_id += 1
            queued = tm.queued
            completions.append((fid, queued))
            fired[fid] = 0
            tm.flush(on_complete=lambda fid=fid:
                     fired.__setitem__(fid, fired[fid] + 1))
        else:
            tm.poll(max_n=int(rng.integers(0, 8)) or None)
    # drain everything
    tm.drain()
    assert len(executed) == submitted
    # posted order == submission order within the KV class
    assert executed == sorted(executed)
    assert tm.bytes[TrafficClass.KV_TRANSFER] == submitted_bytes
    assert tm.stats[TrafficClass.KV_TRANSFER] == submitted
    for fid, count in fired.items():
        assert count == 1, f"flush {fid} completion fired {count} times"


# ---------------------------------------------------------------------------
# congestion pacing: deferral keeps order, obligations and accounting
# ---------------------------------------------------------------------------


def test_paced_flush_defers_excess_kv_wrs():
    tm = TrafficManager(doorbell_batch=4)
    tm.net_congestion = 1.0
    for _ in range(10):
        _kv(tm)
    assert tm.flush() == 4                 # one doorbell batch posted
    assert tm.queued == 6 and tm.in_flight == 4
    assert tm.doorbells == 1
    assert tm.paced_flushes == 1 and tm.deferred_wrs == 6
    tm.net_congestion = 0.0                # link drained: post the rest
    assert tm.flush() == 6
    assert tm.doorbells == 1 + 2           # 6 WRs / batch of 4
    assert tm.poll() == 10


def test_paced_flush_lets_late_collective_overtake_deferred_kv():
    """The point of pacing: a collective submitted AFTER a deep KV
    backlog still reaches the ring first."""
    tm = TrafficManager(doorbell_batch=2)
    tm.net_congestion = 1.0
    order = []
    for i in range(5):
        _kv(tm, fn=lambda i=i: order.append(f"kv{i}"))
    tm.flush()                             # kv0, kv1 posted; 3 deferred
    tm.submit(lambda: order.append("coll"), 1,
              TrafficClass.MODEL_COLLECTIVE)
    tm.flush()                             # coll + one more KV batch
    tm.poll()
    assert order[:3] == ["kv0", "kv1", "coll"]
    tm.flush()
    tm.poll()
    assert order == ["kv0", "kv1", "coll", "kv2", "kv3", "kv4"]


def test_paced_flush_completion_covers_deferred_wrs():
    """A paced flush's on_complete must wait for the WRs it deferred —
    they were queued at the flush, and the caller's contract is 'my
    transfers are done'."""
    tm = TrafficManager(doorbell_batch=2)
    tm.net_congestion = 1.0
    done = []
    for _ in range(5):
        _kv(tm)
    tm.flush(on_complete=lambda: done.append(True))
    assert tm.poll() == 2 and done == []   # only the posted batch ran
    tm.flush()                             # repost two more (still paced)
    assert tm.poll() == 2 and done == []
    tm.flush()
    assert tm.poll() == 1 and done == [True]


def test_paced_flush_charges_submission_cost_exactly_once():
    """Deferred WRs pay the §5.2 submission cost when actually posted —
    never twice, never zero times."""
    c = SubmitCostModel()
    tm = TrafficManager(doorbell_batch=3)
    tm.net_congestion = 1.0
    for _ in range(7):
        _kv(tm)
    tm.flush()                             # 3 posted (1 doorbell)
    tm.flush()                             # 3 more
    tm.flush()                             # last one
    tm.poll()
    assert tm.doorbells == 3
    expect = 7 * c.rdma_wr_s + 3 * c.rdma_doorbell_s
    assert tm.submitted_seconds == pytest.approx(expect, abs=1e-15)


def test_drain_terminates_under_pacing():
    tm = TrafficManager(doorbell_batch=2)
    tm.net_congestion = 1.0
    ran = []
    for i in range(9):
        _kv(tm, fn=lambda i=i: ran.append(i))
    assert tm.drain() == 9
    assert ran == list(range(9)) and not tm.busy


def test_unpaced_behaviour_unchanged_below_threshold():
    tm = TrafficManager(doorbell_batch=4)
    tm.net_congestion = 0.49               # below the 0.5 default
    for _ in range(10):
        _kv(tm)
    assert tm.flush() == 10
    assert tm.paced_flushes == 0 and tm.deferred_wrs == 0
