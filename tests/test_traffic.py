"""CNIC-centric traffic manager (§5): VL arbiter + doorbell batching."""
from hypothesis import given, settings, strategies as st

from repro.core.traffic import (SubmitCostModel,
                                TrafficClass, TrafficManager,
                                VLArbiterConfig, allocate_bandwidth)


def test_collectives_get_99_percent():
    """§5.1: ~99% of bandwidth reserved for model-execution traffic."""
    alloc = allocate_bandwidth(
        {TrafficClass.MODEL_COLLECTIVE: 1, TrafficClass.KV_TRANSFER: 1},
        link_bw=100e9)
    frac = alloc[TrafficClass.MODEL_COLLECTIVE] / 100e9
    assert frac >= 0.94, frac
    # KV never starves
    assert alloc[TrafficClass.KV_TRANSFER] > 0


def test_kv_gets_full_link_when_idle():
    alloc = allocate_bandwidth(
        {TrafficClass.MODEL_COLLECTIVE: 0, TrafficClass.KV_TRANSFER: 3},
        link_bw=50e9)
    assert alloc[TrafficClass.KV_TRANSFER] == 50e9


@given(n_hi=st.integers(0, 5), n_kv=st.integers(0, 5),
       bw=st.floats(1e9, 400e9))
@settings(max_examples=100, deadline=None)
def test_allocation_conserves_bandwidth(n_hi, n_kv, bw):
    alloc = allocate_bandwidth(
        {TrafficClass.MODEL_COLLECTIVE: n_hi, TrafficClass.KV_TRANSFER: n_kv},
        link_bw=bw)
    total = sum(alloc.values())
    if n_hi or n_kv:
        assert total <= bw * (1 + 1e-9)
        assert total >= bw * 0.99      # work-conserving
    else:
        assert total == 0


def test_doorbell_batching_amortises():
    """§5.2: one RDMA WR ≈1 µs vs cudaMemcpyAsync 5–7 µs; batching wins."""
    c = SubmitCostModel()
    n = 1000
    assert c.rdma_batch_seconds(n) < c.rdma_unbatched_seconds(n)
    assert c.rdma_batch_seconds(n) < c.cuda_seconds(n) / 4
    # single-transfer comparison from the paper: ~1 µs vs 5–7 µs
    assert c.rdma_wr_s <= 1.5e-6
    assert 5e-6 <= c.cuda_memcpy_s <= 7e-6


def test_manager_strict_priority_order():
    tm = TrafficManager()
    order = []
    tm.submit(lambda: order.append("kv1"), 10, TrafficClass.KV_TRANSFER)
    tm.submit(lambda: order.append("coll"), 10,
              TrafficClass.MODEL_COLLECTIVE)
    tm.submit(lambda: order.append("kv2"), 10, TrafficClass.KV_TRANSFER)
    n = tm.drain()
    assert n == 3
    assert order == ["coll", "kv1", "kv2"]   # collective first, KV FIFO


def test_manager_accounting():
    tm = TrafficManager(doorbell_batch=4)
    for i in range(10):
        tm.submit(lambda: None, 100, TrafficClass.KV_TRANSFER)
    tm.drain()
    assert tm.stats[TrafficClass.KV_TRANSFER] == 10
    assert tm.bytes[TrafficClass.KV_TRANSFER] == 1000
    # 10 WRs in batches of 4: 3 doorbells
    expect = 10 * tm.cost.rdma_wr_s + 3 * tm.cost.rdma_doorbell_s
    assert abs(tm.submitted_seconds - expect) < 1e-12


def test_high_fraction_from_paper_config():
    """§A.1 arbiter tables: high_limit 240/255 + low-table leak."""
    arb = VLArbiterConfig()
    hf = arb.high_fraction()
    assert 0.94 <= hf <= 1.0


# ---------------------------------------------------------------------------
# non-blocking issue/complete halves (flush/poll) — the pipelined serving
# runtime's transfer API
# ---------------------------------------------------------------------------


def test_flush_is_nonblocking_and_poll_completes():
    tm = TrafficManager(doorbell_batch=4)
    out = []
    for i in range(3):
        tm.submit(lambda i=i: out.append(i), 10, TrafficClass.KV_TRANSFER)
    fired = []
    n = tm.flush(on_complete=lambda: fired.append(True))
    # issue half: WRs posted, doorbell rung, NOTHING executed yet
    assert n == 3 and out == [] and tm.in_flight == 3 and not fired
    assert tm.queued == 0 and tm.busy
    assert tm.poll(max_n=2) == 2 and out == [0, 1] and not fired
    assert tm.poll() == 1 and out == [0, 1, 2]
    assert fired == [True]          # batch callback after the LAST transfer
    assert not tm.busy


def test_flush_preserves_arbiter_priority():
    tm = TrafficManager()
    order = []
    tm.submit(lambda: order.append("kv1"), 10, TrafficClass.KV_TRANSFER)
    tm.submit(lambda: order.append("coll"), 10,
              TrafficClass.MODEL_COLLECTIVE)
    tm.submit(lambda: order.append("kv2"), 10, TrafficClass.KV_TRANSFER)
    tm.flush()
    tm.poll()
    assert order == ["coll", "kv1", "kv2"]


def test_flush_doorbell_batching_vs_degenerate_drains():
    """One flush of n KV WRs rings ceil(n/batch) doorbells; the blocking
    pattern (submit+drain per transfer) rings one per transfer — the
    submission overhead the pipelined runtime amortises."""
    tm = TrafficManager(doorbell_batch=4)
    for _ in range(10):
        tm.submit(lambda: None, 1, TrafficClass.KV_TRANSFER)
    tm.flush()
    assert tm.doorbells == 3
    expect = 10 * tm.cost.rdma_wr_s + 3 * tm.cost.rdma_doorbell_s
    assert abs(tm.submitted_seconds - expect) < 1e-12
    tm.poll()
    tm2 = TrafficManager(doorbell_batch=4)
    for _ in range(10):
        tm2.submit(lambda: None, 1, TrafficClass.KV_TRANSFER)
        tm2.drain()
    assert tm2.doorbells == 10
    assert tm2.submitted_seconds > tm.submitted_seconds


def test_empty_flush_fires_callback_immediately():
    tm = TrafficManager()
    fired = []
    assert tm.flush(on_complete=lambda: fired.append(True)) == 0
    assert fired == [True]


def test_interleaved_flushes_complete_independently():
    tm = TrafficManager()
    done = []
    tm.submit(lambda: None, 1, TrafficClass.KV_TRANSFER)
    tm.flush(on_complete=lambda: done.append("a"))
    tm.submit(lambda: None, 1, TrafficClass.KV_TRANSFER)
    tm.submit(lambda: None, 1, TrafficClass.KV_TRANSFER)
    tm.flush(on_complete=lambda: done.append("b"))
    assert tm.poll(max_n=2) == 2 and done == ["a"]
    assert tm.poll() == 1 and done == ["a", "b"]


def test_drain_equals_flush_plus_poll():
    tm = TrafficManager(doorbell_batch=4)
    out = []
    for i in range(5):
        tm.submit(lambda i=i: out.append(i), 1, TrafficClass.KV_TRANSFER)
    assert tm.drain() == 5
    assert out == list(range(5)) and not tm.busy
