"""Elastic PE<->DE reconfiguration: controller, drain protocol, flips.

The drain protocol's contract (ISSUE: stop admitting, finish in-flight
lifecycle states, hand off tier-resident blocks, flip kind) is pinned
here at three layers: the PDController/DrainTracker units, the
scheduler's begin/finish_drain bookkeeping, and the simulator/serving
runtimes executing real flips — including the serving runtime's
bit-identical-generation invariant and exactly-once tier-pin release.
"""
import pytest

from repro.core.config import ElasticConfig, TierConfig
from repro.core.autoscale import (DE_TO_PE, PE_TO_DE, DrainTracker,
                                  LoadSignals, PDController, pick_victim)
from repro.core.scheduler import Request, Scheduler


# ---------------------------------------------------------------------------
# PDController
# ---------------------------------------------------------------------------


def _sig(pe_s, de_s, n_pe=2, n_de=2):
    return LoadSignals(n_pe=n_pe, n_de=n_de,
                       pe_queued_s=pe_s, pe_busy_s=0.0,
                       de_queued_s=de_s, de_busy_s=0.0)


def test_controller_dead_band_no_action():
    c = PDController(hi=2.0, lo=0.5, patience=1)
    for _ in range(10):
        assert c.observe(_sig(1.0, 1.0), now=0.0) is None
    assert c.n_proposed == 0


def test_controller_patience_and_directions():
    c = PDController(hi=2.0, lo=0.5, patience=2)
    assert c.observe(_sig(10.0, 1.0), now=0.0) is None   # streak 1
    assert c.observe(_sig(10.0, 1.0), now=1.0) == DE_TO_PE
    # streak resets after an action
    assert c.observe(_sig(1.0, 10.0), now=2.0) is None
    assert c.observe(_sig(1.0, 10.0), now=3.0) == PE_TO_DE


def test_controller_streak_resets_inside_band():
    c = PDController(hi=2.0, lo=0.5, patience=2)
    assert c.observe(_sig(10.0, 1.0), now=0.0) is None
    assert c.observe(_sig(1.0, 1.0), now=1.0) is None    # back in band
    assert c.observe(_sig(10.0, 1.0), now=2.0) is None   # streak restarts
    assert c.observe(_sig(10.0, 1.0), now=3.0) == DE_TO_PE


def test_controller_cooldown_blocks_second_action():
    c = PDController(hi=2.0, lo=0.5, patience=1, cooldown_s=10.0)
    assert c.observe(_sig(10.0, 1.0), now=0.0) == DE_TO_PE
    assert c.observe(_sig(10.0, 1.0), now=5.0) is None   # cooling down
    assert c.observe(_sig(10.0, 1.0), now=11.0) == DE_TO_PE


def test_controller_respects_role_floors():
    c = PDController(hi=2.0, lo=0.5, patience=1, min_pe=1, min_de=1)
    assert c.observe(_sig(10.0, 1.0, n_de=1), now=0.0) is None
    assert c.observe(_sig(0.1, 10.0, n_pe=1), now=1.0) is None


def test_controller_idle_floor_absorbs_noise():
    c = PDController(hi=2.0, lo=0.5, patience=1, idle_floor_s=1e-3)
    # both sides idle: ratio undefined, no evidence either way
    assert c.observe(_sig(1e-5, 0.0), now=0.0) is None
    assert c.n_proposed == 0
    # pe side real, de side idle: infinite ratio => more PEs
    assert c.observe(_sig(1.0, 0.0), now=1.0) == DE_TO_PE


# ---------------------------------------------------------------------------
# DrainTracker / pick_victim
# ---------------------------------------------------------------------------


def test_drain_tracker_lifecycle_and_accounting():
    t = DrainTracker()
    rec = t.begin((0, 0), "de", "pe", now=1.0)
    with pytest.raises(AssertionError):
        t.begin((0, 0), "de", "pe", now=1.5)     # one drain per engine
    with pytest.raises(AssertionError):
        t.finish((0, 0), now=2.0)                # flip before drained
    t.mark_drained((0, 0), now=3.0)
    t.finish((0, 0), now=5.0, tier_handoff_bytes=128)
    assert rec.t_drained == 3.0 and rec.t_flip == 5.0
    assert t.n_flips == 1
    assert t.drain_seconds() == pytest.approx(4.0)
    assert t.flips_by_direction() == {"de->pe": 1, "pe->de": 0}
    assert t.tier_handoff_bytes() == 128
    assert not t.active


def test_pick_victim_policies():
    class E:
        def __init__(self, eid, load):
            self.engine = eid
            self.load = load

    es = [E((0, 0), 5), E((1, 0), 1), E((2, 0), 9)]
    assert pick_victim(es, "idlest", lambda e: e.load) is es[1]
    assert pick_victim(es, "rotate", lambda e: e.load, rotation=2) is es[2]
    assert pick_victim(es, "rotate", lambda e: e.load, rotation=3) is es[0]
    with pytest.raises(ValueError):
        pick_victim(es, "bogus", lambda e: e.load)


# ---------------------------------------------------------------------------
# Scheduler drain protocol
# ---------------------------------------------------------------------------


def _sched(n_pe=2, n_de=2):
    s = Scheduler(alpha=1 << 30, beta=1 << 30)
    for i in range(n_pe):
        s.register_engine((i, 0), node=i, kind="pe", group=0)
    for j in range(n_de):
        st = s.register_engine((n_pe + j, 0), node=n_pe + j, kind="de",
                               group=1000 + j)
        st.free_hbm_tokens = 10000
    return s


def _req(rid, cached=0, new=64, gen=16, arrival=0.0):
    return Request(rid=rid, cached_tokens=cached, new_tokens=new,
                   gen_tokens=gen, arrival=arrival)


def test_draining_engine_never_accepts_new_admissions():
    s = _sched()
    s.begin_drain((0, 0))
    s.begin_drain((2, 0))
    for i in range(6):
        s.submit(_req(i))
    for a in s.on_pe_fetch(0):
        assert a.engine != (0, 0)
    for gid in list(s.groups("de")):
        for a in s.on_de_fetch(gid):
            assert a.engine != (2, 0)
    # phase 1 must not have parked anything in the drained group's queue
    assert not s.de_private[1000]


def test_begin_drain_requeues_fully_drained_groups_private_queue():
    s = _sched(n_de=1)                           # single singleton DE group
    for i in range(3):
        s.submit(_req(i))
    s.de_phase1()
    assert len(s.de_private[1000]) == 3
    s.begin_drain((2, 0))
    assert not s.de_private[1000]
    assert len(s.de_global_queue) == 3           # order-preserved requeue
    assert [r.rid for r in s.de_global_queue] == [0, 1, 2]


def test_requeue_unstarted_hands_back_only_unread_requests():
    s = _sched()
    rs = [_req(i, cached=64, arrival=float(i)) for i in range(3)]
    for r in rs:
        s.submit(r)
    asg = s.on_pe_fetch(0)
    assert len(asg) == 3
    victim = rs[0].pe
    st = s.engines[victim]
    mine = [r for r in rs if r.pe == victim]
    # one of the victim's requests has started its read: it must stay
    for r in rs:
        if r.de is None:
            r.de = (2, 0)
    started = mine[0]
    s.choose_read_path(started)
    tok0, seq0 = st.tok, st.seq
    s.begin_drain(victim)
    back = s.requeue_unstarted(victim, rs)
    assert started not in back
    assert all(r.pe is None for r in back)
    assert st.tok == tok0 - sum(r.prompt_tokens for r in back)
    assert st.seq == seq0 - len(back)
    # handed-back requests rejoin the queue in submission order
    assert [r.rid for r in s.pe_queue] == sorted(r.rid for r in back)


def test_pe_de_pe_round_trip_restores_scheduler_state():
    s = _sched()
    snap = {eid: (st.kind, st.group, st.free_hbm_tokens, st.draining)
            for eid, st in s.engines.items()}
    groups_snap = {g: list(es) for g, es in s._groups.items()}
    eid = (0, 0)
    s.begin_drain(eid)
    assert s.can_finish_drain(eid)
    s.finish_drain(eid, kind="de", group=2000, free_hbm_tokens=5000)
    assert s.engines[eid].kind == "de"
    assert eid in s.groups("de")[2000]
    assert s.de_private[2000] is not None
    s.begin_drain(eid)
    s.finish_drain(eid, kind="pe", group=0)
    assert {eid_: (st.kind, st.group, st.free_hbm_tokens, st.draining)
            for eid_, st in s.engines.items()} == snap
    assert {g: list(es) for g, es in s._groups.items()
            if es} == groups_snap
    assert 2000 not in s._groups                 # empty group dropped


def test_finish_drain_refuses_inflight_engine():
    s = _sched()
    s.submit(_req(0))
    s.on_pe_fetch(0)
    busy = next(st.engine for st in s.engines.values()
                if st.kind == "pe" and st.tok > 0)
    s.begin_drain(busy)
    assert not s.can_finish_drain(busy)
    with pytest.raises(AssertionError):
        s.finish_drain(busy, kind="de", group=2000)


def test_choose_read_path_steers_away_from_draining_side():
    s = _sched()
    r = _req(0, cached=100)
    r.pe, r.de = (0, 0), (2, 0)
    s.begin_drain((2, 0))
    assert s.choose_read_path(r) == "pe"
    s2 = _sched()
    r2 = _req(1, cached=100)
    r2.pe, r2.de = (0, 0), (2, 0)
    s2.begin_drain((0, 0))
    assert s2.choose_read_path(r2) == "de"


# ---------------------------------------------------------------------------
# simulator: the control loop executes real flips
# ---------------------------------------------------------------------------


def _two_phase_sim(elastic, drain_policy="idlest"):
    from dataclasses import replace

    from repro.sim import DS_660B, HOPPER_NODE, Sim, SimConfig
    from repro.sim.traces import Round, Trajectory

    trajs = [Trajectory(i, [Round(4096, 8)]) for i in range(24)] + \
            [Trajectory(100 + i, [Round(64, 512)]) for i in range(60)]
    arrivals = [0.0] * 24 + [20.0] * 60
    cfg = SimConfig(node=replace(HOPPER_NODE, g=1), model=DS_660B,
                    P=2, D=2, mode="dualpath", nodes_per_pe_group=1,
                    nodes_per_de_group=1, kv_hbm_frac=0.04,
                    elastic=ElasticConfig(enabled=elastic,
                                          drain_policy=drain_policy,
                                          reconfig_interval_s=4.0,
                                          reconfig_patience=2))
    return Sim(cfg, trajs).run(arrivals=arrivals)


def test_sim_elastic_flips_and_finishes_everything():
    sim = _two_phase_sim(elastic=True)
    r = sim.results()
    assert r["finished_agents"] == 84
    assert r["role_changes"] >= 1
    assert r["reconfig_drain_s"] > 0
    assert r["reconfig_weight_bytes"] > 0
    assert r["n_pe_final"] + r["n_de_final"] == 4
    # drain log is consistent: begin <= drained <= flip for every record
    for rec in sim.drains.log:
        assert rec.t_begin <= rec.t_drained <= rec.t_flip
    # scheduler state settled: nothing draining, no stranded queues
    assert not sim.drains.active
    assert all(not st.draining for st in sim.sched.engines.values())
    assert not sim.sched.pe_queue and not sim.sched.de_global_queue


def test_sim_elastic_off_reports_zero_reconfiguration():
    sim = _two_phase_sim(elastic=False)
    r = sim.results()
    assert r["finished_agents"] == 84
    assert r["role_changes"] == 0
    assert r["reconfig_drain_s"] == 0
    assert r["n_pe_final"] == 2 and r["n_de_final"] == 2


def test_sim_rotate_drain_policy_runs():
    sim = _two_phase_sim(elastic=True, drain_policy="rotate")
    r = sim.results()
    assert r["finished_agents"] == 84
    assert r["role_changes"] >= 1


def test_pe_drain_waits_for_inflight_read():
    """The PE drain gate must consult the rounds, not just the fetch
    reports: scheduler seq/tok are report-derived from the engine FIFO,
    which is EMPTY while a request's KV read is still in flight, so a
    report-only gate would flip a PE mid-read and strand the
    PrefillWork on a DE engine."""
    from dataclasses import replace

    from repro.sim import DS_660B, HOPPER_NODE, Sim, SimConfig
    from repro.sim.traces import Round, Trajectory

    # storage slow enough that round-2 hit reads stay in flight for
    # many seconds; small weights so the reload (same slow SNIC)
    # doesn't dominate the run
    node = replace(HOPPER_NODE, g=1, snic_bw=1e6)
    model = replace(DS_660B, total_param_bytes=2e6,
                    active_param_bytes=2e6)
    trajs = [Trajectory(i, [Round(256, 8), Round(256, 8)])
             for i in range(4)]
    cfg = SimConfig(node=node, model=model, P=2, D=1, mode="dualpath",
                    nodes_per_pe_group=1, nodes_per_de_group=1)
    sim = Sim(cfg, trajs)
    box = {}

    def inject():
        inflight = [rs for rs in sim.rounds
                    if rs.req.read_path is not None
                    and rs.read_done_t < 0 and rs.req.pe is not None]
        assert inflight, "expected a KV read in flight at the probe time"
        eid = box["eid"] = inflight[0].req.pe
        sim.sched.begin_drain(eid)
        sim.drains.begin(eid, "pe", "de", sim.loop.now)
        sim._advance_drains()
        # the read is in flight and the fifo empty: reports say idle,
        # the gate must still hold the drain open
        assert sim.drains.active[eid].t_drained < 0
        sim._drain_poll()

    sim.loop.at(6.0, inject)
    sim.run()
    # ...and once the in-flight work completed, the flip went through
    # and the whole workload still finished
    eid = box["eid"]
    assert sim.engines[eid].kind == "de"
    assert sim.drains.n_flips == 1
    assert all(a.end_t >= 0 for a in sim.agents)


def test_sim_rejects_unknown_drain_policy():
    from repro.sim import DS_660B, HOPPER_NODE, Sim, SimConfig

    cfg = SimConfig(node=HOPPER_NODE, model=DS_660B, P=1, D=1,
                    elastic=ElasticConfig(drain_policy="bogus"))
    with pytest.raises(ValueError):
        Sim(cfg, [])


# ---------------------------------------------------------------------------
# serving runtime: live flips, bit-identical generation, tier pins
# ---------------------------------------------------------------------------


def test_serving_elastic_identity_and_tier_pin_release():
    import jax

    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving import ServingSystem
    from repro.serving.events import EngineLifecycle
    from repro.sim.spec import REDUCED_TEST_NODE
    from repro.sim.traces import Round, Trajectory

    cfg = get_config("qwen1.5-0.5b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    # two rounds per prefill-phase session so the second round carries a
    # trie hit (tier pins are taken on the read path)
    trajs = [Trajectory(i, [Round(48, 1), Round(8, 1)]) for i in range(3)] \
        + [Trajectory(10 + i, [Round(4, 16)]) for i in range(3)]
    arrivals = [0.0] * 3 + [1.5] * 3

    def run(elastic):
        sys_ = ServingSystem(cfg, params, n_pe=2, n_de=2, block_tokens=16,
                             max_seq=96, de_slots=1, seed=0, pipelined=True,
                             node=REDUCED_TEST_NODE,
                             tier=TierConfig(dram_tier_bytes=64e3),
                             elastic=ElasticConfig(
                                 enabled=elastic,
                                 reconfig_interval_s=0.05,
                                 reconfig_patience=2,
                                 reconfig_idle_floor_s=1e-4))
        sessions = sys_.run_online(trajs, arrivals)
        return sys_, [s.context for s in sessions]

    sys_e, toks_e = run(elastic=True)
    sys_s, toks_s = run(elastic=False)
    # a role flip may change timing, never generation
    assert toks_e == toks_s
    st = sys_e.stats()
    assert st["role_changes"] >= 1
    assert st["reconfig_drain_s"] > 0
    assert sys_s.stats()["role_changes"] == 0
    # every tier pin taken during draining/flipping was released
    # exactly once: nothing stays pinned after the workload drains
    for tier in sys_e.tiers.values():
        assert tier.pinned_bytes() == 0
    # engines settled back to ACTIVE; the engine maps match the
    # scheduler's view of the final topology
    assert all(lc == EngineLifecycle.ACTIVE
               for lc in sys_e.engine_lifecycle.values())
    assert st["n_pe_final"] == len(sys_e.pes)
    assert st["n_de_final"] == len(sys_e.des)
    assert set(sys_e.pes) == {st_.engine for st_ in
                              sys_e.sched.engines.values()
                              if st_.kind == "pe"}
