"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops

KEY = jax.random.PRNGKey(0)


def rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


TOLS = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,hq,hkv,sq,skv,dh,bq,bk",
    [
        (1, 4, 4, 64, 64, 64, 32, 32),       # MHA square
        (2, 8, 2, 32, 256, 64, 32, 64),      # GQA append (short q, long kv)
        (1, 8, 1, 17, 130, 32, 16, 64),      # ragged (padding paths)
        (2, 4, 4, 128, 128, 128, 128, 128),  # MXU-aligned
        (1, 16, 8, 8, 512, 64, 8, 256),      # deep prefix
    ])
def test_flash_attention_sweep(dtype, b, hq, hkv, sq, skv, dh, bq, bk):
    ks = jax.random.split(KEY, 3)
    q = rand(ks[0], (b, hq, sq, dh), dtype)
    k = rand(ks[1], (b, hkv, skv, dh), dtype)
    v = rand(ks[2], (b, hkv, skv, dh), dtype)
    out = ops.flash_attention(q, k, v, block_q=bq, block_k=bk)
    ref = ops.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=TOLS[dtype], rtol=TOLS[dtype])


@pytest.mark.parametrize("softcap,window", [(30.0, 0), (0.0, 64), (50.0, 48)])
def test_flash_attention_softcap_window(softcap, window):
    ks = jax.random.split(KEY, 3)
    q = rand(ks[0], (1, 4, 96, 64), jnp.float32)
    k = rand(ks[1], (1, 2, 160, 64), jnp.float32)
    v = rand(ks[2], (1, 2, 160, 64), jnp.float32)
    out = ops.flash_attention(q, k, v, softcap=softcap, window=window,
                              block_q=32, block_k=32)
    ref = ops.flash_attention_ref(q, k, v, softcap=softcap, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


def test_flash_attention_noncausal():
    ks = jax.random.split(KEY, 3)
    q = rand(ks[0], (1, 4, 64, 32), jnp.float32)
    k = rand(ks[1], (1, 4, 64, 32), jnp.float32)
    v = rand(ks[2], (1, 4, 64, 32), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=False, block_q=32, block_k=32)
    ref = ops.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,hkv,g,dh,npool,pt,npages",
    [
        (2, 4, 2, 64, 16, 16, 6),
        (1, 1, 8, 128, 8, 32, 4),
        (3, 2, 1, 32, 32, 8, 10),
    ])
def test_paged_attention_sweep(dtype, b, hkv, g, dh, npool, pt, npages):
    ks = jax.random.split(KEY, 5)
    q = rand(ks[0], (b, hkv, g, dh), dtype)
    kp = rand(ks[1], (npool, pt, hkv, dh), dtype)
    vp = rand(ks[2], (npool, pt, hkv, dh), dtype)
    tbl = jax.random.randint(ks[3], (b, npages), 0, npool)
    lengths = jax.random.randint(ks[4], (b,), 1, npages * pt)
    out = ops.paged_attention(q, kp, vp, tbl, lengths)
    ref = ops.paged_attention_ref(q, kp, vp, tbl, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=TOLS[dtype], rtol=TOLS[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.uint8])
@pytest.mark.parametrize("npool,nl,pt,feat,n", [(8, 4, 16, 32, 5),
                                                (16, 2, 8, 128, 16)])
def test_kv_gather_scatter_sweep(dtype, npool, nl, pt, feat, n):
    ks = jax.random.split(KEY, 3)
    if dtype == jnp.uint8:
        pool = jax.random.randint(ks[0], (npool, nl, pt, feat), 0, 255
                                  ).astype(jnp.uint8)
        stream = jax.random.randint(ks[1], (n, pt, feat), 0, 255
                                    ).astype(jnp.uint8)
    else:
        pool = rand(ks[0], (npool, nl, pt, feat), dtype)
        stream = rand(ks[1], (n, pt, feat), dtype)
    tbl = jax.random.choice(ks[2], npool, (n,), replace=False)
    for layer in (0, nl - 1):
        g = ops.kv_layer_gather(pool, tbl, layer=layer)
        gr = ops.kv_layer_gather_ref(pool, tbl, layer=layer)
        np.testing.assert_array_equal(np.asarray(g), np.asarray(gr))
        s = ops.kv_layer_scatter(pool.copy(), tbl, stream, layer=layer)
        sr = ops.kv_layer_scatter_ref(pool, tbl, stream, layer=layer)
        np.testing.assert_array_equal(np.asarray(s), np.asarray(sr))


def test_flash_matches_model_attention():
    """Kernel agrees with the model-layer chunked attention path."""
    from repro.models.layers import attend
    ks = jax.random.split(KEY, 3)
    b, hq, hkv, sq, skv, dh = 2, 8, 4, 64, 192, 64
    q = rand(ks[0], (b, hq, sq, dh), jnp.float32)
    k = rand(ks[1], (b, hkv, skv, dh), jnp.float32)
    v = rand(ks[2], (b, hkv, skv, dh), jnp.float32)
    out = ops.flash_attention(q, k, v, block_q=32, block_k=64)
    # model layout is (b, s, h, dh)
    ref = attend(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                 v.transpose(0, 2, 1, 3), causal=True,
                 q_offset=skv - sq, force_dense=False)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.transpose(0, 2, 1, 3)),
                               atol=3e-5)
