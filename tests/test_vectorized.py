"""VectorSim == Sim: the vectorized engine's results contract.

The struct-of-arrays engine (sim/vectorized.py) re-expresses the
processor-sharing drain plane as array kernels but *shares* every other
subsystem with ``Sim`` (it is a ``Sim``).  The contract this suite pins:

* on any supported config, ``VectorSim.results()`` equals
  ``Sim.results()`` — **exactly** for counters/bytes/tokens, and within
  ``TIME_RTOL`` for time-valued keys (docs/testing.md).  In practice
  the settle arithmetic is the same IEEE ops at the same instants, so
  the time keys come out bit-identical too; the tolerance is the
  *documented* contract, the exactness is an observed (and asserted,
  for the zero-fault arm) property;
* two runs of either engine are bit-identical (determinism);
* the pooled byte ledgers conserve: per-round charged bytes equal the
  loading-plan sums, and the batch plan kernels
  (``resource_bytes_batch`` / ``hedge_water_fill_batch`` /
  ``water_fill_frac_batch``) equal their scalar counterparts
  element-for-element;
* unsupported features refuse loudly (``VectorSimUnsupported``) instead
  of silently mis-simulating.
"""
import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.config import (ElasticConfig, NetworkConfig,
                               ResilienceConfig, TierConfig)
from repro.core.loading import (hedge_water_fill, hedge_water_fill_batch,
                                plan_for, resource_bytes,
                                resource_bytes_batch)
from repro.core.scheduler import Scheduler, water_fill_frac_batch
from repro.sim import (DS_660B, HOPPER_NODE, Sim, SimConfig, VectorSim,
                       VectorSimUnsupported, generate_dataset)
from repro.sim.faults import (EngineDeath, FaultSchedule, SlowdownWindow,
                              StragglerModel)

#: results() keys that are simulated *times* (or derived from them):
#: the equivalence contract allows TIME_RTOL relative error here and
#: demands exactness everywhere else (counters, bytes, tokens, ratios
#: over counters).  See docs/testing.md.
TIME_KEYS = frozenset({
    "jct_mean", "jct_max", "ttft_mean", "ttft_p99", "ttst_mean",
    "tpot_mean", "tpot_p99", "sim_time", "collective_stall_s",
    "transfer_backlog_s", "net_collective_delay_s",
})
TIME_RTOL = 1e-9


def _cfg(**kw):
    kw.setdefault("P", 1)
    kw.setdefault("D", 2)
    return SimConfig(node=HOPPER_NODE, model=DS_660B, **kw)


def _assert_equivalent(cfg, trajs, arrivals=None, exact_times=False):
    r0 = Sim(cfg, trajs).run(arrivals=arrivals).results()
    r1 = VectorSim(cfg, trajs).run(arrivals=arrivals).results()
    assert set(r0) == set(r1), (set(r0) ^ set(r1))
    for k in sorted(r0):
        a, b = r0[k], r1[k]
        if isinstance(a, float) and math.isnan(a):
            assert isinstance(b, float) and math.isnan(b), (k, a, b)
        elif k in TIME_KEYS and not exact_times:
            assert b == pytest.approx(a, rel=TIME_RTOL), (k, a, b)
        else:
            assert a == b, (k, a, b)
    return r0, r1


FAULTS = FaultSchedule(
    windows=[SlowdownWindow("snic", 2.0, 20.0, 3.0, node=0),
             SlowdownWindow("net", 5.0, 9.0, 2.0),
             SlowdownWindow("net", 7.0, 15.0, 1.5)],
    straggler=StragglerModel(0.3, 4.0, seed=7))


# --------------------------------------------------------------------------
# engine equivalence
# --------------------------------------------------------------------------

@pytest.mark.parametrize("kw", [
    dict(),                                    # dualpath, infinite net
    dict(mode="basic"),
    dict(mode="oracle"),
    dict(split_reads=True),
    dict(tier=TierConfig(dram_tier_bytes=64e9, prefetch=True)),
    dict(tier=TierConfig(dram_tier_bytes=64e9, tier_policy="agentic-ttl",
                         tier_ttl_s=30.0)),
    dict(net=NetworkConfig(net_bw=400e9, net_bg_load=0.4)),  # VL + coll
    dict(net=NetworkConfig(net_bw=400e9, net_arbiter="fifo",
                           net_bg_load=0.4)),
    dict(resilience=ResilienceConfig(faults=FAULTS)),
    dict(resilience=ResilienceConfig(faults=FAULTS),
         net=NetworkConfig(net_bw=300e9, net_bg_load=0.3)),
    dict(online=True),
    dict(layerwise=False),
    dict(scheduler="rr"),
    dict(P=2, D=4, split_reads=True,
         tier=TierConfig(dram_tier_bytes=32e9),
         net=NetworkConfig(net_bw=300e9, net_bg_load=0.3),
         nodes_per_pe_group=1, nodes_per_de_group=1),
], ids=lambda kw: ",".join(sorted(kw)) or "dualpath")
def test_engine_equivalence_matrix(kw):
    """Every supported feature axis: results() key-for-key."""
    trajs = generate_dataset(5, 8192, seed=3)
    _assert_equivalent(_cfg(**kw), trajs)


@given(data=st.data())
@settings(max_examples=5, deadline=None)
def test_engine_equivalence_randomized(data):
    """Property arm: randomized small configs x workloads.  Keeps the
    matrix honest between the hand-picked axes."""
    n_agents = data.draw(st.integers(2, 6), label="n_agents")
    max_len = data.draw(st.sampled_from([2048, 8192, 16384]),
                        label="max_len")
    seed = data.draw(st.integers(0, 2 ** 10), label="seed")
    kw = {}
    kw["mode"] = data.draw(st.sampled_from(["dualpath", "basic"]),
                           label="mode")
    if data.draw(st.booleans(), label="split"):
        kw["split_reads"] = True
    if data.draw(st.booleans(), label="tier"):
        kw["tier"] = TierConfig(dram_tier_bytes=32e9)
    if data.draw(st.booleans(), label="net"):
        kw["net"] = NetworkConfig(
            net_bw=data.draw(st.sampled_from([200e9, 400e9]),
                             label="net_bw"),
            net_bg_load=data.draw(st.sampled_from([0.0, 0.5]), label="bg"))
    if data.draw(st.booleans(), label="online"):
        kw["online"] = True
    trajs = generate_dataset(n_agents, max_len, seed=seed)
    _assert_equivalent(_cfg(**kw), trajs)


def test_zero_fault_schedule_is_bit_identical():
    """Empty schedule == faults=None == event engine, all exactly."""
    trajs = generate_dataset(4, 8192, seed=5)
    cfg_none = _cfg(net=NetworkConfig(net_bw=300e9))
    cfg_empty = _cfg(net=NetworkConfig(net_bw=300e9),
                     resilience=ResilienceConfig(faults=FaultSchedule()))
    r_none, r_vec = _assert_equivalent(cfg_none, trajs, exact_times=True)
    _, r_vec_empty = _assert_equivalent(cfg_empty, trajs, exact_times=True)
    assert r_vec == r_vec_empty


def test_vectorized_engine_is_deterministic():
    trajs = generate_dataset(4, 8192, seed=9)
    cfg = _cfg(split_reads=True,
               net=NetworkConfig(net_bw=300e9, net_bg_load=0.4))
    r1 = VectorSim(cfg, trajs).run().results()
    r2 = VectorSim(cfg, trajs).run().results()
    assert r1 == r2


def test_equivalence_with_staggered_arrivals_and_horizon():
    """until= cutoff + arrivals: the fleet benchmark's exact shape."""
    trajs = generate_dataset(6, 8192, seed=11)
    arrivals = [0.3 * i for i in range(6)]
    cfg = _cfg(net=NetworkConfig(net_bw=200e9, net_bg_load=0.6))
    s0 = Sim(cfg, trajs).run(arrivals=list(arrivals), until=20.0)
    s1 = VectorSim(cfg, trajs).run(arrivals=list(arrivals), until=20.0)
    assert s0.results() == s1.results()


# --------------------------------------------------------------------------
# byte conservation
# --------------------------------------------------------------------------

def test_pooled_charges_match_loading_plans_to_the_byte():
    """Same ledger test the event engine passes (test_sim), on the
    pool: per-round charged bytes == core/loading plan sums."""
    trajs = generate_dataset(5, 16384, seed=2)
    for split, tier in ((False, 0.0), (True, 0.0), (True, 2e9)):
        cfg = _cfg(split_reads=split, tier=TierConfig(dram_tier_bytes=tier))
        sim = VectorSim(cfg, trajs).run()
        checked = 0
        for rs in sim.rounds:
            if rs.done_t < 0 or rs.req.read_path is None:
                continue
            legs = [leg for leg in sim._request_legs(rs.req)
                    if leg.phase != "decode"]
            exp = {k: v for k, v in resource_bytes(legs).items() if v}
            got = {k: v for k, v in rs.charged.items() if v}
            assert got == exp, (split, tier, rs.req.rid, got, exp)
            checked += 1
        assert checked > 0


def test_request_table_matches_round_objects():
    trajs = generate_dataset(5, 8192, seed=4)
    sim = VectorSim(_cfg(split_reads=True), trajs).run()
    t = sim.request_table()
    n = len(sim.rounds)
    assert all(len(v) == n for v in t.values())
    for i, rs in enumerate(sim.rounds):
        assert t["rid"][i] == rs.req.rid
        assert t["done_t"][i] == rs.done_t
        assert t["gen_tokens"][i] == rs.gen_total
    assert int(t["cached_tokens"].sum()) == \
        sum(rs.req.cached_tokens for rs in sim.rounds)


# --------------------------------------------------------------------------
# batch plan kernels == scalar kernels
# --------------------------------------------------------------------------

@given(data=st.data())
@settings(max_examples=20, deadline=None)
def test_resource_bytes_batch_matches_plan_sums(data):
    n = data.draw(st.integers(1, 40), label="n")
    rng = np.random.default_rng(data.draw(st.integers(0, 2 ** 20),
                                          label="seed"))
    hit = rng.integers(0, 1 << 32, n)
    miss = rng.integers(0, 1 << 30, n)
    gen = rng.integers(0, 1 << 28, n)
    cuts = np.sort((rng.random((n, 3)) * hit[:, None]).astype(np.int64),
                   axis=1)
    part = (cuts[:, 0], cuts[:, 1] - cuts[:, 0], cuts[:, 2] - cuts[:, 1],
            hit - cuts[:, 2])
    batch = resource_bytes_batch("dualpath", hit, miss, gen, *part)
    for i in range(n):
        tier = tuple(int(p[i]) for p in part)
        rb = resource_bytes(plan_for("pe", 1.0, int(hit[i]), int(miss[i]),
                                     int(gen[i]), tier=tier))
        for k, arr in batch.items():
            assert rb.get(k, 0) == arr[i], (i, k)
    for mode in ("basic", "oracle"):
        b = resource_bytes_batch(mode, hit, miss, gen)
        for i in range(0, n, 7):
            rb = resource_bytes(plan_for(mode, 1.0, int(hit[i]),
                                         int(miss[i]), int(gen[i])))
            for k, arr in b.items():
                assert rb.get(k, 0) == arr[i], (mode, i, k)


def test_resource_bytes_batch_rejects_bad_partition():
    one = np.asarray([10])
    with pytest.raises(ValueError):
        resource_bytes_batch("dualpath", one, one, one,
                             pe_snic=np.asarray([3]))
    with pytest.raises(ValueError):
        resource_bytes_batch("nope", one, one, one)


@given(data=st.data())
@settings(max_examples=20, deadline=None)
def test_hedge_water_fill_batch_matches_scalar(data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2 ** 20),
                                          label="seed"))
    n = 64
    rem = rng.integers(0, 1 << 30, n)
    sev = 1.0 + rng.random(n) * 9.0
    back = rng.integers(0, 1 << 30, n)
    out = hedge_water_fill_batch(rem, sev, back)
    for i in range(n):
        assert out[i] == hedge_water_fill(int(rem[i]), float(sev[i]),
                                          int(back[i])), i


@given(data=st.data())
@settings(max_examples=20, deadline=None)
def test_water_fill_frac_batch_matches_scalar(data):
    rng = np.random.default_rng(data.draw(st.integers(0, 2 ** 20),
                                          label="seed"))
    n = 64
    pe_q = rng.integers(0, 1 << 20, n)
    de_q = rng.integers(0, 1 << 20, n)
    h = rng.integers(1, 1 << 16, n)
    out = water_fill_frac_batch(pe_q, de_q, h)
    scalar = Scheduler.__dict__["_water_fill_frac"]
    stub = object.__new__(Scheduler)
    for i in range(n):
        assert out[i] == scalar(stub, int(pe_q[i]), int(de_q[i]),
                                int(h[i])), i
    assert np.all((out >= 0.0) & (out <= 1.0))


# --------------------------------------------------------------------------
# gating
# --------------------------------------------------------------------------

def test_unsupported_configs_refuse_loudly():
    trajs = generate_dataset(2, 2048, seed=0)
    deaths = FaultSchedule(deaths=[EngineDeath(5.0, (0, 0))])
    for kw in (dict(elastic=ElasticConfig(enabled=True)),
               dict(resilience=ResilienceConfig(hedge_reads=True)),
               dict(resilience=ResilienceConfig(faults=deaths))):
        with pytest.raises(VectorSimUnsupported):
            VectorSim(_cfg(**kw), trajs)
    # an *empty* death list is supported (structurally invisible)
    VectorSim(_cfg(resilience=ResilienceConfig(faults=FaultSchedule())),
              trajs)


def test_pool_flow_cancel_refuses():
    from repro.sim.vectorized import _PoolFlow
    f = _PoolFlow()
    with pytest.raises(VectorSimUnsupported):
        f.cancel()
