"""Finite, shared, priority-arbitrated compute-network link.

The paper's interference-avoidance claim (§5.1) is that storage-to-decode
KV traffic "avoids interference with latency-critical model execution
communications" because every byte rides the CNIC's virtual-lane
arbiter, where model collectives own ~99 % of the arbitration weight.
Until this module the repo *asserted* that claim: the simulator's
compute network was ``PSResource("net", INF)`` and the VL story lived in
a docstring (core/traffic.py).  :class:`SharedLink` makes it a model:

* a finite-capacity link multiplexing flows of different
  :class:`~repro.core.traffic.TrafficClass`;
* two arbitration arms — ``"vl"`` (the paper's weighted-VL arbiter,
  rates from :func:`~repro.core.traffic.allocate_bandwidth`) and
  ``"fifo"`` (naive processor sharing, class-blind) as the ablation the
  interference benchmark compares against;
* per-class accounting: bytes served, per-flow queueing delay versus
  having the link alone (``collective_delay_s`` / ``transfer_backlog_s``)
  and an instantaneous :meth:`congestion` signal in [0, 1] that the
  scheduler's read-path choice and the TrafficManager's KV pacing
  consume.

:func:`drain_times` is the closed-form (fluid) counterpart used by the
serving runtime's tick-quantised time model: two traffic classes start
together on one link with fixed contended shares until one empties; the
link is work-conserving, so the later class always finishes at
``kv_s + coll_s`` while arbitration decides who finishes *first* — i.e.
whether model execution stalls on its collectives or the KV backlog
absorbs the whole delay.
"""
from __future__ import annotations

import math
from collections import Counter
from typing import Dict, Optional

from repro.core.traffic import (DEFAULT_ARBITER, TrafficClass,
                                VLArbiterConfig, allocate_bandwidth)

ARBITERS = ("vl", "fifo")


class SharedLink:
    """Class-aware processor-sharing link (PSResource-compatible).

    The simulator's flow engine asks every resource ``rate_of(flow)``
    at each reshare; a plain PSResource answers ``cap / n_flows``.
    SharedLink answers per the arbiter: under ``"vl"`` the active
    classes split capacity by the InfiniBand-style WRR tables (model
    collectives ≈ 99 % whenever they are backlogged — §A.1's
    high_fraction() = 0.994 — KV never starved) and flows share equally
    within a class; under ``"fifo"`` every flow gets an equal share
    regardless of class — the interference the paper's design exists to
    prevent.

    An infinite ``cap`` degenerates to the pre-finite-network behaviour
    (every flow rate-unbounded, no accounting), so the default simulator
    configuration is unchanged byte-for-byte and event-for-event.
    """

    __slots__ = ("name", "cap", "arbiter", "arb", "flows",
                 "bytes_by_class", "collective_delay_s",
                 "transfer_backlog_s", "contended_joins",
                 "_counts_cache", "_counts_n", "_alloc_cache")

    def __init__(self, name: str, cap: float, arbiter: str = "vl",
                 arb: VLArbiterConfig = DEFAULT_ARBITER):
        if arbiter not in ARBITERS:
            raise ValueError(f"arbiter {arbiter!r} (valid: {ARBITERS})")
        self.name = name
        self.cap = cap
        self.arbiter = arbiter
        self.arb = arb
        self.flows: set = set()
        self.bytes_by_class: Dict[TrafficClass, float] = {
            c: 0.0 for c in TrafficClass}
        # per-flow delay vs having the link alone, split by class — the
        # simulator surfaces these as collective_stall / transfer_backlog
        self.collective_delay_s = 0.0
        self.transfer_backlog_s = 0.0
        self.contended_joins = 0     # flows that joined a busy link
        # lazy per-class census + WRR allocation, rebuilt only when the
        # flow set changes — a reshare sweep asks rate_of once per
        # affected flow, and without the cache each ask re-walked every
        # flow on the link (O(flows^2) per sweep under a deep backlog)
        self._counts_cache: Optional[Counter] = None
        self._counts_n = -1
        self._alloc_cache: Optional[Dict[TrafficClass, float]] = None

    # -- rate allocation ---------------------------------------------------
    def _invalidate(self):
        self._counts_n = -1
        self._alloc_cache = None

    def _class_counts(self) -> Counter:
        if self._counts_cache is None or self._counts_n != len(self.flows):
            self._counts_cache = Counter(
                getattr(f, "tclass", TrafficClass.KV_TRANSFER)
                for f in self.flows)
            self._counts_n = len(self.flows)
            self._alloc_cache = None
        return self._counts_cache

    def rate_of(self, flow) -> float:
        n = len(self.flows)
        if n == 0 or not math.isfinite(self.cap):
            return self.cap
        tclass = getattr(flow, "tclass", TrafficClass.KV_TRANSFER)
        if self.arbiter == "fifo":
            return self.cap / n
        counts = self._class_counts()
        if self._alloc_cache is None:
            self._alloc_cache = allocate_bandwidth(dict(counts), self.cap,
                                                   self.arb)
        return self._alloc_cache.get(tclass, 0.0) / \
            max(counts.get(tclass, 1), 1)

    # -- signals / accounting ---------------------------------------------
    def congestion(self) -> float:
        """Instantaneous congestion in [0, 1]: the fraction of in-flight
        bytes that belong to model collectives.  0 on an idle or
        infinite link.  High values mean KV traffic on this link is (or
        is about to be) throttled to the low-priority leak — the signal
        the read-path water-fill and the KV-pacing flush consume."""
        if not math.isfinite(self.cap) or not self.flows:
            return 0.0
        tot = coll = 0.0
        for f in self.flows:
            left = max(getattr(f, "nbytes_left", 0.0), 0.0)
            tot += left
            if getattr(f, "tclass", None) == TrafficClass.MODEL_COLLECTIVE:
                coll += left
        return (coll / tot) if tot > 0 else 0.0

    def note_enter(self, flow) -> None:
        self._invalidate()
        if math.isfinite(self.cap) and self.flows:
            self.contended_joins += 1

    def note_done(self, flow, now: float) -> None:
        """Per-flow delay accounting at completion.  ``delay`` compares
        against the flow having this link alone; a flow bottlenecked
        elsewhere attributes its extra time here too, which makes the
        stall numbers conservative (never under-reported)."""
        self._invalidate()
        if not math.isfinite(self.cap):
            return
        tclass = getattr(flow, "tclass", TrafficClass.KV_TRANSFER)
        nbytes = getattr(flow, "nbytes_total", 0.0)
        self.bytes_by_class[tclass] = \
            self.bytes_by_class.get(tclass, 0.0) + nbytes
        t_enter = getattr(flow, "t_enter", now)
        delay = max(0.0, (now - t_enter) - nbytes / self.cap)
        if tclass == TrafficClass.MODEL_COLLECTIVE:
            self.collective_delay_s += delay
        else:
            self.transfer_backlog_s += delay


# ---------------------------------------------------------------------------
# fluid (closed-form) two-class drain — the serving runtime's model
# ---------------------------------------------------------------------------


def kv_share_when_contended(arbiter: str,
                            arb: VLArbiterConfig = DEFAULT_ARBITER) -> float:
    """Share of link bandwidth KV traffic receives while collectives are
    backlogged: the low-priority leak under the VL arbiter (~0.6 % with
    the §A.1 tables — 1 − high_fraction() = 0.0059), an equal split
    under naive FIFO sharing."""
    if arbiter == "fifo":
        return 0.5
    return 1.0 - arb.high_fraction()


def drain_times(kv_s: float, coll_s: float, kv_share: float
                ) -> tuple:
    """Completion times ``(kv_done, coll_done)`` of two fluid traffic
    classes that start together on one work-conserving link.

    ``kv_s`` / ``coll_s`` are each class's service time *alone at full
    bandwidth* (seconds = bytes / link_bw, which is how the serving
    runtime's TickIo ledger already measures transfers).  While both
    classes are backlogged they receive fixed shares ``kv_share`` /
    ``1 - kv_share``; when one empties the other takes the whole link.
    Work conservation pins the later finisher at exactly
    ``kv_s + coll_s`` — arbitration only chooses the *first* finisher:

    * VL arm (``kv_share`` ≈ 0.006): collectives finish at ≈ ``coll_s``
      — model execution never waits — and the KV backlog absorbs the
      whole contention delay;
    * FIFO arm (``kv_share`` = 0.5): a large KV backlog doubles the
      collectives' completion time — the interference the paper's
      arbiter exists to prevent.
    """
    kv_s = max(kv_s, 0.0)
    coll_s = max(coll_s, 0.0)
    if kv_s <= 0.0 or coll_s <= 0.0:
        return kv_s, coll_s
    kv_share = min(max(kv_share, 0.0), 1.0)
    coll_share = 1.0 - kv_share
    if coll_share <= 0.0:
        return kv_s, kv_s + coll_s
    if kv_share <= 0.0:
        return kv_s + coll_s, coll_s
    t_kv = kv_s / kv_share
    t_coll = coll_s / coll_share
    if t_coll <= t_kv:                 # collectives empty first
        return kv_s + coll_s, t_coll
    return t_kv, kv_s + coll_s         # KV empties first
