"""Finite compute-network model with priority arbitration (paper §5.1).

``SharedLink`` multiplexes per-layer model collectives against
PD-transfer / dual-path RDMA traffic under the weighted-VL arbiter (or
a naive FIFO arm for ablation); ``CollectiveVolumeModel`` supplies the
collective volumes; ``drain_times`` is the closed-form two-class drain
the serving runtime's tick-quantised clock uses.
"""
from repro.network.collectives import CollectiveVolumeModel
from repro.network.link import (ARBITERS, SharedLink, drain_times,
                                kv_share_when_contended)

__all__ = [
    "ARBITERS",
    "CollectiveVolumeModel",
    "SharedLink",
    "drain_times",
    "kv_share_when_contended",
]
