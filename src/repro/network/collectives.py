"""Per-layer model-collective volumes for the finite compute network.

The interference model needs to know how many bytes of latency-critical
model-execution traffic (TP all-reduces, EP all-to-alls, PD handoffs)
one processed token puts on the compute network.  Two sources:

* :meth:`CollectiveVolumeModel.from_hlo_text` — exact, from the
  compiled program: ``roofline.hlo.parse_hlo_metrics`` already counts
  result-shape bytes of every all-gather / all-reduce / reduce-scatter /
  all-to-all / collective-permute (loop-aware), so dividing by the
  batch's token count gives the measured per-token volume.
* :meth:`from_config` / :meth:`from_spec` — analytic estimate for
  models we cannot compile at CI scale (DS 660B and friends): per layer
  a TP-sharded transformer all-reduces the attention output and the FFN
  output, each moving ``2·(g−1)/g`` of one hidden activation vector
  across the link (ring all-reduce), so

      bytes/token ≈ n_layers · 2 · d_model · dtype_bytes · 2(g−1)/g.

  ``ModelSimSpec`` carries no ``d_model``, so ``from_spec`` uses the
  attention width ``n_heads · qk_head_dim`` as the activation-width
  proxy (equal for the dense configs, a documented over-estimate for
  MLA's widened QK heads — conservative in the direction that makes
  interference *harder* to avoid).

Both constructors produce the same dataclass, so the simulator, the
serving time model and the interference benchmark consume one
definition of "collective load".
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.roofline.hlo import parse_hlo_metrics


@dataclass(frozen=True)
class CollectiveVolumeModel:
    """Collective bytes the compute network carries per processed token
    (prefill and decode alike — the collectives are per forward step and
    scale with the tokens in it), with the per-layer breakdown the
    doorbell-granular runtimes submit at."""

    bytes_per_token: float
    n_layers: int

    @property
    def bytes_per_token_layer(self) -> float:
        return self.bytes_per_token / max(self.n_layers, 1)

    def step_bytes(self, tokens: int) -> float:
        """Collective volume of one forward/decode step over ``tokens``
        freshly-processed tokens."""
        return self.bytes_per_token * max(tokens, 0)

    # -- constructors ------------------------------------------------------
    @classmethod
    def analytic(cls, n_layers: int, act_width: int, group_size: int,
                 dtype_bytes: int = 2) -> "CollectiveVolumeModel":
        g = max(group_size, 1)
        if g == 1:                     # unsharded: nothing crosses the net
            return cls(0.0, n_layers)
        per_layer = 2.0 * act_width * dtype_bytes * 2.0 * (g - 1) / g
        return cls(per_layer * n_layers, n_layers)

    @classmethod
    def from_config(cls, cfg, group_size: int,
                    dtype_bytes: int = 2) -> "CollectiveVolumeModel":
        """Analytic volume for a real ModelConfig (serving runtime)."""
        return cls.analytic(cfg.n_layers, cfg.d_model, group_size,
                            dtype_bytes)

    @classmethod
    def from_spec(cls, spec, group_size: int,
                  dtype_bytes: int = 2) -> "CollectiveVolumeModel":
        """Analytic volume for a ModelSimSpec (simulator)."""
        return cls.analytic(spec.n_layers,
                            max(spec.n_heads * spec.qk_head_dim, 1),
                            group_size, dtype_bytes)

    @classmethod
    def from_hlo_text(cls, hlo_text: str, n_tokens: int,
                      n_layers: int = 1) -> "CollectiveVolumeModel":
        """Measured volume from a compiled program's HLO text: the
        loop-aware collective byte count divided by the tokens the
        program processes."""
        metrics = parse_hlo_metrics(hlo_text)
        return cls(metrics.get("collective_bytes", 0.0) / max(n_tokens, 1),
                   n_layers)
