"""Event-driven serving runtime scaffolding.

The real-bytes :class:`~repro.serving.system.ServingSystem` is driven by
the pieces here, replacing the old blocking ``_schedule()`` /
``_step_engines()`` lock-step with a per-request lifecycle state machine
and an event loop, so storage reads and compute-network transfers
genuinely overlap engine ``step()`` compute (the simulator's legs, made
functional):

* :class:`ReqState` — the request lifecycle
  ``SCHEDULED → READING → PREFILL → PD_TRANSFER → DECODE → PERSIST →
  DONE``; transitions happen at TrafficManager flush-completion
  callbacks and engine step boundaries.
* :class:`VirtualClock` / :class:`EventLoop` — the runtime's wall
  clock.  Serving runs real token generation and real KV bytes but on
  CPU hardware whose NICs we cannot measure, so the clock advances by
  *modelled* seconds (:class:`ServingTimeModel`): per tick the
  pipelined runtime charges ``max(transfer, compute)`` where the
  blocking runtime charges ``transfer + compute`` — the overlap the
  paper's online claim rests on, made observable and deterministic.
  Timed events (online arrivals, inter-round think gaps) live on the
  loop's heap and the clock jumps over idle gaps instead of sleeping.
  The same clock supplies real seconds to DRAM-tier TTLs and the
  think-time prefetcher (kvcache/tiers.py), which in offline serving
  degenerate to tick counts.
* :class:`RoundMetrics` + :func:`latency_summary` /
  :func:`slo_attainment` — per-round TTFT/TTST/TPOT accounting
  mirroring ``Sim.results()`` so the real-bytes runtime reports the
  same SLO columns the simulator does.
"""
from __future__ import annotations

import heapq
import itertools
from collections import defaultdict
from dataclasses import dataclass
from enum import Enum
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.intra import attn_flops
from repro.network import (CollectiveVolumeModel, drain_times,
                           kv_share_when_contended)
from repro.sim.spec import HOPPER_NODE, ModelSimSpec, NodeSpec


class ReqState(Enum):
    """Lifecycle of one round (request) through the serving runtime."""

    SCHEDULED = "scheduled"      # submitted, awaiting (PE, DE) + read path
    READING = "reading"          # storage/tier read legs in flight
    PREFILL = "prefill"          # hit KV installed, in the PE's fifo
    # chunked-prefill sub-state (core/config.SloConfig
    # prefill_chunk_tokens): some slices computed, more to come —
    # decode steps interleave between them.  Entered only when the
    # chunk cap is configured, so unchunked runs keep the legacy
    # PREFILL-only lifecycle event-for-event.
    PREFILL_CHUNKED = "prefill_chunked"
    PD_TRANSFER = "pd_transfer"  # prompt state PE→DE on the compute net
    DECODE = "decode"            # slot-batched decode on the DE
    PERSIST = "persist"          # new FullBlocks persisting to storage
    DONE = "done"


class EngineLifecycle(Enum):
    """Lifecycle of one *engine* under elastic role reconfiguration
    (core/autoscale.py), driven by the existing tick loop: a role flip
    moves the engine ACTIVE → DRAINING (admissions stopped, in-flight
    requests finishing through their normal ReqState transitions) →
    RECONFIGURING (drained; the target role's weight shard reloading
    over the node's storage NIC) → ACTIVE under the other kind.  With
    ``elastic=False`` every engine stays ACTIVE forever.  DEAD is the
    fail-stop terminal state (sim/faults.py EngineDeath): the engine
    left the scheduler registry at once, its in-flight rounds were
    re-homed, and it never returns."""

    ACTIVE = "active"
    DRAINING = "draining"
    RECONFIGURING = "reconfiguring"
    DEAD = "dead"


@dataclass
class RoundMetrics:
    """Timestamps of one round on the runtime's wall clock (mirrors the
    simulator's RoundSim timing fields; -1 = not reached yet).
    Milestones are stamped at the END of the tick they occur in — after
    the clock charges that tick's modelled seconds — so a latency never
    excludes the work that produced it; ``submit_t`` is the submission
    event's own time (an arrival/think event or the start of the tick
    whose persist completion triggered it)."""

    rid: int
    gen_tokens: int
    submit_t: float
    read_done_t: float = -1.0
    prefill_done_t: float = -1.0     # first token ready (TTFT)
    first_decode_t: float = -1.0
    second_token_t: float = -1.0     # TTST
    done_t: float = -1.0
    # SLO class of the round (core/config.SloConfig): feeds the
    # per-class latency summaries in both runtimes' results
    slo_class: str = "batch"

    @property
    def finished(self) -> bool:
        return self.done_t >= 0

    @property
    def ttft(self) -> float:
        return self.prefill_done_t - self.submit_t

    @property
    def ttst(self) -> Optional[float]:
        if self.second_token_t < 0:
            return None
        return self.second_token_t - self.submit_t

    @property
    def tpot(self) -> Optional[float]:
        """Time per output token over the decode phase (gen > 1 only)."""
        if self.gen_tokens <= 1 or self.first_decode_t < 0:
            return None
        return (self.done_t - self.first_decode_t) / (self.gen_tokens - 1)


def latency_summary(metrics: Iterable[RoundMetrics]) -> dict:
    """TTFT/TTST/TPOT summary over finished rounds — the same keys (and
    the same definitions) as ``Sim.results()``.

    NaN contract: with no finished rounds every mean/percentile is NaN
    (never an exception), and the NaN flows — unchanged — through
    ``slo_attainment``, ``ServingSystem.stats()``, the fig_* smoke
    asserts and the perf gate (whose comparator rejects a gated metric
    decaying to NaN against a finite baseline).  Pinned by
    tests/test_metrics_regression.py."""
    done = [m for m in metrics if m.finished]
    # a finished round without a prefill stamp (possible only for
    # exotic recovery interleavings) must not contribute a garbage
    # negative TTFT — it is excluded, like Sim.results() does
    ttfts = [m.ttft for m in done if m.prefill_done_t >= 0]
    ttsts = [m.ttst for m in done if m.ttst is not None]
    tpots = [m.tpot for m in done if m.tpot is not None]
    pct = lambda xs, q: float(np.percentile(xs, q)) if xs else float("nan")
    mean = lambda xs: float(np.mean(xs)) if xs else float("nan")
    return dict(
        finished_rounds=len(done),
        ttft_mean=mean(ttfts), ttft_p99=pct(ttfts, 99),
        ttst_mean=mean(ttsts),
        tpot_mean=mean(tpots), tpot_p99=pct(tpots, 99),
    )


def latency_by_class(metrics: Iterable[RoundMetrics]) -> dict:
    """Per-SLO-class latency summaries (the ``latency_by_class`` obs
    key): one :func:`latency_summary` dict per class.  Classes with no
    *finished* rounds are omitted (their summary would be all-NaN, and
    NaN != NaN breaks the runtimes' results()-equality contracts —
    e.g. a horizon-truncated run where no round completes)."""
    ms = list(metrics)
    out = {}
    for c in ("interactive", "batch"):
        sub = [m for m in ms if m.slo_class == c]
        if any(m.finished for m in sub):
            out[c] = latency_summary(sub)
    return out


def slo_attainment(metrics: Iterable[RoundMetrics], ttft_slo_s: float,
                   tpot_slo_s: float) -> float:
    """Fraction of finished rounds meeting BOTH the TTFT and TPOT SLOs
    (rounds with a single output token have no TPOT and are judged on
    TTFT alone, as in the simulator's accounting)."""
    done = [m for m in metrics if m.finished]
    if not done:
        return float("nan")
    ok = 0
    for m in done:
        if m.ttft > ttft_slo_s:
            continue
        t = m.tpot
        if t is not None and t > tpot_slo_s:
            continue
        ok += 1
    return ok / len(done)


# ---------------------------------------------------------------------------
# wall clock + timed events
# ---------------------------------------------------------------------------


class VirtualClock:
    """The runtime's wall clock [s].  Monotonic: work advances it by
    modelled durations, idle periods jump it to the next timed event."""

    def __init__(self):
        self.now = 0.0

    def advance(self, dt: float) -> float:
        if dt > 0:
            self.now += dt
        return self.now

    def jump_to(self, t: float) -> float:
        if t > self.now:
            self.now = t
        return self.now


class EventLoop:
    """Timed-event heap over a :class:`VirtualClock` (arrivals and
    think-gap round submissions in online serving)."""

    def __init__(self, clock: VirtualClock):
        self.clock = clock
        self._heap: List[Tuple[float, int, Callable[[], None]]] = []
        self._seq = itertools.count()

    def at(self, t: float, fn: Callable[[], None]) -> None:
        heapq.heappush(self._heap, (t, next(self._seq), fn))

    def after(self, dt: float, fn: Callable[[], None]) -> None:
        self.at(self.clock.now + max(dt, 0.0), fn)

    @property
    def pending(self) -> int:
        return len(self._heap)

    def next_time(self) -> Optional[float]:
        return self._heap[0][0] if self._heap else None

    def fire_due(self) -> int:
        """Run every event scheduled at or before ``clock.now``."""
        n = 0
        while self._heap and self._heap[0][0] <= self.clock.now:
            _, _, fn = heapq.heappop(self._heap)
            fn()
            n += 1
        return n


# ---------------------------------------------------------------------------
# modelled durations (the clock's time source)
# ---------------------------------------------------------------------------


class TickIo:
    """Per-tick transfer-seconds ledger, bucketed by physical resource
    (``("snic", node)``, ``("cn", node)``, ``("dram", node)``).  Distinct
    buckets are independent NICs/links, so the pipelined runtime charges
    their *max* (they drain concurrently) while the blocking runtime —
    whose inline ``drain()`` serialises every transfer — charges the
    *sum*."""

    def __init__(self):
        self.buckets: Dict[tuple, float] = defaultdict(float)

    def add(self, bucket: tuple, seconds: float) -> None:
        if seconds > 0:
            self.buckets[bucket] += seconds

    def parallel_seconds(self) -> float:
        return max(self.buckets.values(), default=0.0)

    def serial_seconds(self) -> float:
        return sum(self.buckets.values())


@dataclass
class ServingTimeModel:
    """Modelled durations for the serving runtime's clock.

    Transfers use the node's NIC/DRAM bandwidths; compute uses the same
    analytic forms the simulator uses (attention+linear FLOPs for PE
    batches, HBM-bandwidth-vs-FLOPs roofline for DE steps).  Only
    *relative* magnitudes matter to the blocking-vs-pipelined
    comparison, and both arms share this model; the layerwise install
    gathers are identical inline work in both arms and are deliberately
    left unmodelled."""

    cfg: ModelConfig
    node: NodeSpec
    spec: ModelSimSpec
    # --- finite compute network (repro.network) ------------------------
    # ``collectives`` (None = the legacy infinite-network behaviour)
    # supplies per-token model-collective volumes; ``net_arbiter``
    # selects how KV transfers and collectives share a contended CNIC
    # link: 'vl' (the paper's weighted-VL arbiter) or 'fifo' (naive
    # class-blind sharing, the interference-ablation arm).
    net_arbiter: str = "vl"
    collectives: Optional[CollectiveVolumeModel] = None

    @classmethod
    def for_model(cls, cfg: ModelConfig,
                  node: Optional[NodeSpec] = None,
                  net_arbiter: str = "vl",
                  collective_group_size: int = 0) -> "ServingTimeModel":
        coll = CollectiveVolumeModel.from_config(cfg, collective_group_size) \
            if collective_group_size > 1 else None
        return cls(cfg=cfg, node=node or HOPPER_NODE,
                   spec=ModelSimSpec.from_config(cfg),
                   net_arbiter=net_arbiter, collectives=coll)

    # -- transfers ---------------------------------------------------------
    def snic_seconds(self, nbytes: float) -> float:
        return nbytes / self.node.snic_bw

    def cn_seconds(self, nbytes: float, coll_bytes: float = 0.0) -> float:
        """Seconds for ``nbytes`` of KV traffic on the compute network;
        with ``coll_bytes`` of model collectives contending, the KV
        completion time under the configured arbiter (via the fluid
        two-class drain — see repro.network.drain_times)."""
        kv_s = nbytes / self.node.cnic_bw
        if coll_bytes <= 0:
            return kv_s
        kv_done, _ = drain_times(kv_s, coll_bytes / self.node.cnic_bw,
                                 kv_share_when_contended(self.net_arbiter))
        return kv_done

    def collective_seconds(self, nbytes: float) -> float:
        """Uncontended service time of collective traffic on the link."""
        return nbytes / self.node.cnic_bw

    def cn_drain(self, kv_s: float, coll_s: float) -> Tuple[float, float]:
        """(kv_done, coll_done) for KV/collective service-time ledgers
        contending on one CNIC link under the configured arbiter."""
        return drain_times(kv_s, coll_s,
                           kv_share_when_contended(self.net_arbiter))

    def dram_seconds(self, nbytes: float) -> float:
        return nbytes / self.node.dram_bw

    # -- compute -----------------------------------------------------------
    def pe_step_seconds(self, items: Sequence[Tuple[int, int]]) -> float:
        """One PE forward batch over ``(cached, bsz)`` items."""
        if not items:
            return 0.0
        a = attn_flops(self.cfg, items)
        lin = self.spec.linear_flops_per_token() * sum(b for _, b in items)
        return (a + lin) / (self.node.gpu.flops * self.node.gpu.mfu_prefill)

    def de_step_seconds(self, ctxs: Sequence[int]) -> float:
        """One slot-batched decode step over active context lengths."""
        if not ctxs:
            return 0.0
        kv = sum(self.spec.decode_step_bytes(c) for c in ctxs)
        w = self.spec.active_param_bytes_resident(1)
        fl = sum(self.spec.decode_step_flops(c) for c in ctxs)
        return max((kv + w) / (self.node.gpu.hbm_bw * self.node.gpu.mbu_decode),
                   fl / (self.node.gpu.flops * self.node.gpu.mfu_prefill))
