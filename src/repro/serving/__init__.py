from repro.serving.events import (EventLoop, ReqState, RoundMetrics,
                                  ServingTimeModel, VirtualClock,
                                  latency_summary, slo_attainment)
from repro.serving.system import AgentSession, ServingSystem
