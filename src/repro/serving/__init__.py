from repro.serving.system import AgentSession, ServingSystem
