"""DualPath serving system: scheduler + engines + storage, end to end.

Single-process orchestration of the full request lifecycle with *real*
token generation and *real* KV bytes moving along the dual-path legs —
the functional counterpart of the discrete-event simulator (which owns
the cluster-scale timing claims).  Used by the examples, the online
benchmark and the integration tests.

Per round (paper Fig. 4), as a lifecycle state machine
(serving/events.py)::

  SCHEDULED    client computes the trie hit for ``context ‖ append``
               (§A.4); scheduler assigns (PE, DE) + read path
               (§6.1 / Alg. 1) across every registered PE/DE group
  READING      the chosen side(s)' TrafficManagers carry the FullBlock
               reads (storage→PE directly, or storage→DE→compute
               network→PE; DRAM-tier prefixes skip the SNIC)
  PREFILL      PE runs quota-packed chunked prefill (§6.2) over the
               append chunk, hit KV installed layerwise double-buffered
  PD_TRANSFER  prompt state PE→DE, one submission per attention layer,
               batched per doorbell
  DECODE       DE decodes ``gen`` tokens greedily, slot-batched
  PERSIST      newly-filled FullBlocks + trie entries persist (§A.5)

Two runtimes share every one of those mechanisms:

* **pipelined** (default) — an event-driven tick loop: reads are issued
  non-blocking (``TrafficManager.flush``) and stay in flight while the
  engines ``step()``, completing at the tick's ``poll``; PD transfers
  and persists likewise.  The runtime's wall clock advances by modelled
  seconds, ``max(transfer, compute)`` per tick — transfers overlap
  compute, the paper's online claim.
* **blocking** (``pipelined=False``) — the legacy lock-step loop: every
  submission is drained inline, so the clock charges
  ``transfer + compute``.  Kept as the reference arm; generation and
  byte accounting are bit-identical between the two (pinned by
  tests/test_serving_runtime.py).

``run_offline`` drives all sessions from t=0; ``run_online(arrivals)``
adds online arrivals and inter-round think gaps on the wall clock
(which also gives DRAM-tier TTLs and the think-time prefetcher real
seconds instead of tick counts) and records per-round TTFT/TTST/TPOT
into ``stats()``, mirroring ``Sim.results()``.
"""
from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.admission import AdmissionGate
from repro.core.autoscale import (DE_TO_PE, DrainTracker, LoadSignals,
                                  PDController, pick_victim)
from repro.core.blocks import layout_for
from repro.core.config import (ElasticConfig, NetworkConfig,
                               ResilienceConfig, SloConfig, TierConfig,
                               resolve_groups)
from repro.core.scheduler import Request, Scheduler
from repro.core.traffic import TrafficClass, TrafficManager
from repro.engines import kvio
from repro.engines.runtime import (DecodeEngine, EngineRequest,
                                   PrefillEngine, uses_state_blob)
from repro.obs.schema import conforming
from repro.kvcache.store import MemoryKVStore, StateBlobStore
from repro.kvcache.tiers import DramTier, ThinkTimePrefetcher
from repro.kvcache.trie import BlockTrie
from repro.serving import events
from repro.serving.events import (EngineLifecycle, EventLoop, ReqState,
                                  RoundMetrics, ServingTimeModel, TickIo,
                                  VirtualClock)
from repro.sim.faults import FaultSchedule
from repro.sim.spec import NodeSpec
from repro.sim.traces import Trajectory


@dataclass
class AgentSession:
    traj: Trajectory
    rng: np.random.Generator
    context: List[int] = field(default_factory=list)
    next_round: int = 0
    rounds_done: int = 0
    current: Optional[EngineRequest] = None

    def done(self) -> bool:
        return self.next_round >= self.traj.n_rounds and self.current is None


class ServingSystem:
    def __init__(self, cfg: ModelConfig, params, *, n_pe: int = 1,
                 n_de: int = 1, mode: str = "dualpath",
                 block_tokens: int = 16, max_seq: int = 512,
                 de_slots: int = 8, quota_s: float = 0.3, seed: int = 0,
                 split_reads: bool = False, layerwise: bool = True,
                 pe_group_size: Optional[int] = None,
                 de_group_size: Optional[int] = None,
                 pipelined: bool = True, node: Optional[NodeSpec] = None,
                 tracer=None,
                 tier: Optional[TierConfig] = None,
                 net: Optional[NetworkConfig] = None,
                 elastic=None,
                 resilience: Optional[ResilienceConfig] = None,
                 slo: Optional[SloConfig] = None,
                 **legacy):
        assert mode in ("dualpath", "basic")
        # --- shared config groups (repro.core.config) ------------------
        # The same five groups SimConfig holds; subsystem knobs arrive
        # here (tier=TierConfig(...), elastic=ElasticConfig(...), ...).
        # The old flat kwargs (dram_tier_bytes=..., elastic=True, ...)
        # are folded in through the one-release deprecation shim.
        groups = resolve_groups(legacy, tier=tier, net=net,
                                elastic=elastic, resilience=resilience,
                                slo=slo)
        tcfg = self.tier_cfg = groups["tier"]
        ncfg = self.net_cfg = groups["net"]
        ecfg = self.elastic_cfg = groups["elastic"]
        rcfg = self.resilience_cfg = groups["resilience"]
        scfg = self.slo_cfg = groups["slo"]
        self.cfg = cfg
        self.params = params            # role flips build new engines
        self.mode = mode
        self.max_seq = max_seq
        self.pipelined = pipelined
        self.layout = layout_for(cfg, block_tokens)
        self.store = MemoryKVStore(self.layout)
        self.blob_store = StateBlobStore()
        self.trie = BlockTrie(block_tokens)
        self.sched = Scheduler(alpha=1 << 30, beta=1 << 30,
                               split_reads=split_reads,
                               class_aware=scfg.class_aware)
        # the runtime's wall clock (serving/events.py): modelled seconds,
        # advanced per tick, jumped over idle gaps in online mode.
        # ``collective_group_size > 1`` puts per-layer model collectives
        # on the compute network (repro.network) and makes the clock's
        # cn charges contention-aware under ``net_arbiter``.
        self.time_model = ServingTimeModel.for_model(
            cfg, node, net_arbiter=ncfg.net_arbiter,
            collective_group_size=ncfg.collective_group_size)
        self.clock = VirtualClock()
        self.loop = EventLoop(self.clock)
        self.metrics: Dict[int, RoundMetrics] = {}
        self._online = False
        # node-local DRAM tiers over the remote store (kvcache/tiers.py):
        # reads served from a tier never reach the store (= the SNIC).
        # Tier timestamps come from the modelled wall clock in BOTH
        # offline and online serving (_tier_now), so an agentic-ttl
        # ``tier_ttl_s`` always means seconds — matching the simulator.
        self.tiers: Dict[int, DramTier] = {}
        if tcfg.dram_tier_bytes:
            for node_id in range(n_pe + n_de):
                tier = DramTier(tcfg.dram_tier_bytes,
                                policy=tcfg.tier_policy,
                                ttl_s=tcfg.tier_ttl_s,
                                backing=self.store)
                # clock-agnostic call sites (DE persists through the
                # plain store interface) still stamp modelled seconds
                tier.clock_fn = self._tier_now
                self.tiers[node_id] = tier
        self.prefetcher = ThinkTimePrefetcher(tcfg.prefetch_chunk_blocks) \
            if (tcfg.prefetch and self.tiers) else None
        # engine groups: ``*_group_size`` engines per scheduler group
        # (default: one group spanning all engines of that kind); the
        # fetch loop visits every group, so DE phase-1 balancing across
        # groups runs end-to-end with ≥ 2 DE groups
        self.pes: Dict[Tuple[int, int], PrefillEngine] = {}
        self.des: Dict[Tuple[int, int], DecodeEngine] = {}
        pe_gsz = max(int(pe_group_size or n_pe), 1)
        de_gsz = max(int(de_group_size or n_de), 1)
        for i in range(n_pe):
            eid = (i, 0)
            self.sched.register_engine(eid, node=i, kind="pe",
                                       group=i // pe_gsz)
            self.pes[eid] = PrefillEngine(
                eid, cfg, params, self.store, self.layout, max_seq,
                quota_s, layerwise=layerwise,
                chunk_tokens=scfg.prefill_chunk_tokens,
                class_aware=scfg.class_aware)
        for j in range(n_de):
            eid = (n_pe + j, 0)
            st = self.sched.register_engine(eid, node=n_pe + j, kind="de",
                                            group=1000 + j // de_gsz)
            # the DE persists through its node tier (write-through + tier
            # warm-up) when one is configured
            de_store = self.tiers.get(n_pe + j, self.store)
            de = DecodeEngine(eid, cfg, params, de_store, self.trie,
                              self.layout, max_seq, n_slots=de_slots,
                              blob_store=self.blob_store)
            st.free_hbm_tokens = de_slots * max_seq
            de.defer_persist = pipelined
            self.des[eid] = de
        # --- elastic role reconfiguration (core/autoscale.py) -------------
        # Engines flip between PrefillEngine and DecodeEngine objects at
        # runtime; the controller/tracker plumbing exists even when
        # elastic is off (zero-cost, zero state drift) so stats() always
        # reports the reconfiguration columns.
        if ecfg.drain_policy not in ("idlest", "rotate"):
            raise ValueError(f"unknown drain_policy {ecfg.drain_policy!r}")
        self.elastic = bool(ecfg)
        self.reconfig_interval_s = ecfg.reconfig_interval_s
        self.drain_policy = ecfg.drain_policy
        self.drains = DrainTracker()
        self.controller = PDController(
            hi=ecfg.reconfig_hi, lo=ecfg.reconfig_lo,
            patience=ecfg.reconfig_patience,
            cooldown_s=ecfg.reconfig_cooldown_s,
            idle_floor_s=ecfg.reconfig_idle_floor_s)
        self.engine_lifecycle: Dict[Tuple[int, int], EngineLifecycle] = {
            eid: EngineLifecycle.ACTIVE
            for eid in (*self.pes, *self.des)}
        self._next_gid = itertools.count(5000)
        self._next_obs_t = ecfg.reconfig_interval_s
        self._drain_rotation = 0
        self._reconfig_ready: List = []   # drained DrainRecords to flip
        self._quota_s = quota_s
        self._layerwise = layerwise
        self._de_slots = de_slots
        self.reconfig_weight_bytes = 0.0
        self._rid = itertools.count()
        self._pending_admit: deque = deque()
        self._inflight: Dict[int, EngineRequest] = {}
        self._install_ready: List[EngineRequest] = []
        self._pd_queue: List[EngineRequest] = []
        # milestone timestamps are stamped AFTER the tick's clock advance
        # (a milestone reached during tick t happened by the END of t, and
        # the tick's modelled seconds must count against it) — deferred
        # here until then
        self._pending_stamps: List[Tuple[RoundMetrics, str]] = []
        self._tick_io = TickIo()
        self._tick_compute = 0.0
        # per-tick collective seconds per node's CNIC link + interference
        # accounting (repro.network; zeros when collectives are off)
        self._tick_coll: Dict[int, float] = {}
        self.collective_stall_s = 0.0
        self.transfer_backlog_s = 0.0
        self.net_congestion = 0.0
        self._submit_seconds_seen = 0.0
        self.rng = np.random.default_rng(seed)
        self.read_bytes_by_side = {"pe": 0, "de": 0}
        self.dram_bytes_by_side = {"pe": 0, "de": 0}
        self.n_split_reads = 0
        self.gen_tokens_done = 0
        # --- fault injection (sim/faults.py, shared with the simulator) ---
        # An empty schedule is normalised to None so every fault hook is
        # a structural no-op on the happy path: zero-rate runs stay
        # bit-identical to faults=None (pinned by tests/test_faults.py).
        faults = rcfg.faults
        self.faults = faults if (faults is not None
                                 and not faults.empty) else None
        self.hedge_reads = rcfg.hedge_reads
        self.hedge_min_severity = rcfg.hedge_min_severity
        self._deaths_pending = list(self.faults.deaths) \
            if self.faults is not None else []
        self.dead_engines: List[Tuple[int, int]] = []
        self.recovered_rounds = 0
        self.hedged_reads = 0
        self.hedge_moved_tokens = 0
        # --- online SLO layer (core/config.SloConfig) ------------------
        # gate is None when admission is off (or in offline serving,
        # where there is no arrival process to shed) — arrivals then go
        # straight to sched.submit, structurally identical to pre-SLO
        self.gate = AdmissionGate(scfg) if scfg.admission else None
        self.prefill_chunks = 0
        # --- flight recorder (repro.obs) -------------------------------
        # Optional; ``tracer=None`` keeps every hook a structural no-op
        # so untraced runs stay bit-identical.  Lifecycle spans are
        # closed at end-of-tick (the same deferred-timestamp rule
        # _stamp uses), so span edges match the stamped milestones.
        self.tracer = tracer
        self._pending_states: List[Tuple[EngineRequest, ReqState]] = []
        if tracer is not None:
            tracer.bind_clock(lambda: self.clock.now)
            if self.faults is not None:
                tracer.annotate_faults(self.faults)
            self.sched.tracer = tracer
            self.controller.tracer = tracer
            for node_id, tier in self.tiers.items():
                tier.tracer = tracer
                tier.track = f"tier/node{node_id}"
            for eng in (*self.pes.values(), *self.des.values()):
                eng.tm.tracer = tracer
                eng.tm.track = f"traffic/node{eng.eid[0]}"

    # ------------------------------------------------------------------
    def _all_tms(self) -> Iterator[TrafficManager]:
        for pe in self.pes.values():
            yield pe.tm
        for de in self.des.values():
            yield de.tm

    def _tier_now(self) -> float:
        """Tier timestamps: the modelled wall clock, in BOTH modes.
        The clock advances by modelled seconds every tick whether or not
        an arrival process drives the loop, so offline runs get real
        seconds too — an agentic-ttl ``tier_ttl_s`` means seconds
        everywhere, matching the simulator (it used to fall back to the
        tier's internal operation counter offline, so the same TTL
        meant 'operations' there; regression-pinned in
        tests/test_config.py)."""
        return self.clock.now

    # ------------------------------------------------------------------
    # fault-aware service times: the schedule's multipliers compose onto
    # the healthy time model.  With ``faults is None`` both helpers
    # return the base value untouched (same floats, same arithmetic).
    # ------------------------------------------------------------------
    def _snic_s(self, node: int, nbytes: float, rid: Optional[int] = None,
                side: Optional[str] = None) -> float:
        """SNIC service seconds on ``node``, degraded by any active
        slowdown window and — for a storage read leg identified by
        ``(rid, side)`` — the straggler draw.  Tier (DRAM) reads never
        come through here: tier hits are never re-charged to a SNIC."""
        s = self.time_model.snic_seconds(nbytes)
        if self.faults is not None:
            s *= self.faults.snic_factor(node, self.clock.now)
            if rid is not None:
                s *= self.faults.leg_factor(rid, side)
        return s

    def _cn_s(self, nbytes: float) -> float:
        s = self.time_model.cn_seconds(nbytes)
        if self.faults is not None:
            s *= self.faults.net_factor(self.clock.now)
        return s

    # ------------------------------------------------------------------
    def _submit_round(self, sess: AgentSession):
        rnd = sess.traj.rounds[sess.next_round]
        append = list(sess.rng.integers(
            2, self.cfg.vocab_size, size=rnd.append))
        prompt = sess.context + append
        if uses_state_blob(self.cfg):
            blob, hit = self.blob_store.get(sess.context)
            refs = []
            hit = hit if blob is not None else 0
        else:
            hit, refs = self.trie.match(prompt)
            blob = None
        new_tokens = len(prompt) - hit
        if self.gate is not None and self._online:
            # load-aware admission (core/admission.py); offline serving
            # admits unconditionally — no arrival process to shed, and a
            # deferral event would never fire outside the online loop
            sig = self._elastic_signals()
            read_s = self.time_model.snic_seconds(
                hit * self.layout.n_layers *
                self.layout.bytes_per_token_layer)
            prefill_s = self.time_model.pe_step_seconds(
                [(hit, max(new_tokens, 1))])
            verdict = self.gate.decide(
                (sess.traj.tid, sess.next_round),
                self.gate.ttft_estimate(sig, read_s, prefill_s))
            if verdict == "defer":
                self.loop.after(self.slo_cfg.admission_defer_s,
                                lambda s=sess: self._submit_round(s))
                return
            if verdict == "reject":
                # shed the load: the session's trajectory ends here
                sess.next_round = sess.traj.n_rounds
                sess.current = None
                return
        req = Request(rid=next(self._rid), cached_tokens=hit,
                      new_tokens=new_tokens, gen_tokens=rnd.gen,
                      arrival=self.clock.now, slo_class=sess.traj.slo_class)
        er = EngineRequest(req=req, context_tokens=prompt[:hit],
                           append_tokens=prompt[hit:], hit_refs=refs)
        er._blob = blob
        er._session = sess
        er._tier_pinned = None
        er._pd_ready = False
        er._cancelled = False
        er.lifecycle = ReqState.SCHEDULED
        self._trace_submit(er)
        sess.current = er
        sess.next_round += 1
        self._inflight[req.rid] = er
        self.metrics[req.rid] = RoundMetrics(rid=req.rid,
                                             gen_tokens=rnd.gen,
                                             submit_t=self.clock.now,
                                             slo_class=sess.traj.slo_class)
        for tier in self.tiers.values():
            tier.note_alive(sess.traj.tid, now=self._tier_now())
        self.sched.submit(req)

    # ------------------------------------------------------------------
    # scheduling: group fetches + read-path decisions (tick phase 1)
    # ------------------------------------------------------------------
    def _fetch_groups(self):
        """Leader fetch for every registered group — DE groups first
        (HBM reservation), then PE groups, as in the simulator.  With
        ≥ 2 DE groups the fetch exercises ``Scheduler.de_phase1``'s
        cross-group balancing on the global queue."""
        for gid, members in self.sched.groups("de").items():
            reports = {eid: (sum(s is not None for s in self.des[eid].slots),
                             sum(int(n) for n in self.des[eid].lengths),
                             0, self.des[eid].free_slots * self.max_seq)
                       for eid in members}
            for asg in self.sched.on_de_fetch(gid, reports):
                pass
        for gid, members in self.sched.groups("pe").items():
            reports = {eid: (len(self.pes[eid].fifo),
                             sum(w.remaining for w, _ in self.pes[eid].fifo),
                             0)
                       for eid in members}
            for asg in self.sched.on_pe_fetch(gid, reports):
                pass

    def _schedule_tick(self) -> int:
        self._fetch_groups()
        # decide paths for every ready request first (read queues build up
        # across the batch of decisions, as on a live cluster), then read
        ready = []
        for er in list(self._inflight.values()):
            req = er.req
            if req.pe is None or req.de is None or req.read_path is not None:
                continue
            if self.mode == "basic":
                req.read_path = "pe"
                self.sched.engines[req.pe].read_q += req.cached_tokens
            else:
                tier_tokens = None
                if self.tiers and er.hit_refs:
                    bt = self.layout.block_tokens
                    tier_tokens = {
                        "pe": self.tiers[req.pe[0]]
                              .resident_prefix(er.hit_refs) * bt,
                        "de": self.tiers[req.de[0]]
                              .resident_prefix(er.hit_refs) * bt,
                    }
                self.sched.choose_read_path(
                    req, tier_tokens=tier_tokens,
                    net_congestion=self.net_congestion)
                if self.hedge_reads and self.faults is not None:
                    self._maybe_hedge(req)
                if req.dram_tokens:
                    # pin the tier-resident prefix NOW: reads of other
                    # ready requests admit blocks (and may evict) before
                    # this one's turn — pinned blocks cannot disappear
                    # between the path decision and the read
                    bt = self.layout.block_tokens
                    node = (req.pe if req.dram_side == "pe" else req.de)[0]
                    prefix = er.hit_refs[:req.dram_tokens // bt]
                    self.tiers[node].pin(prefix)
                    er._tier_pinned = (node, prefix)
            ready.append(er)
        for er in ready:
            self._set_state(er, ReqState.READING)
            if self.pipelined:
                self._issue_read(er)
            else:
                self._do_read(er)
        return len(ready)

    def _maybe_hedge(self, req: Request) -> int:
        """Hedged split read (issue-time): if one side's storage leg is
        degraded — straggler draw and/or an active SNIC slowdown window
        on its node — by ``hedge_min_severity``× or more relative to the
        other, re-water-fill that side's *remainder* to the healthy side
        via ``Scheduler.rebalance_remainder`` before the legs are built.
        The serving runtime's reads are issued and completed within one
        tick, so the hedge decision lands at issue; the simulator owns
        the mid-flight variant of the same re-fill.  Tier-hit tokens are
        untouched (they are not SNIC charge to begin with)."""
        toks = req.read_tokens_by_side()
        if not (toks["pe"] > 0 and toks["de"] > 0):
            return 0
        now = self.clock.now
        f = {s: self.faults.leg_factor(req.rid, s) *
             self.faults.snic_factor(
                 (req.pe if s == "pe" else req.de)[0], now)
             for s in ("pe", "de")}
        for slow, fast in (("pe", "de"), ("de", "pe")):
            if f[fast] <= 0 or f[slow] / f[fast] < self.hedge_min_severity:
                continue
            healthy = req.pe if fast == "pe" else req.de
            st = self.sched.engines.get(healthy)
            # backlog ahead of this request on the healthy NIC = its
            # reading queue minus this request's own charge there
            backlog = max((st.read_q if st is not None else 0)
                          - toks[fast], 0)
            moved = self.sched.rebalance_remainder(
                req, slow, toks[slow], f[slow] / f[fast],
                healthy_backlog_tokens=backlog)
            if moved:
                self.hedged_reads += 1
                self.hedge_moved_tokens += moved
            return moved
        return 0

    # ------------------------------------------------------------------
    # the read, split into issue/complete halves
    # ------------------------------------------------------------------
    def _read_transfers(self, er: EngineRequest
                        ) -> List[Tuple[TrafficManager, callable, int]]:
        """Issue half of a read: perform the store/tier accesses and the
        byte accounting NOW and return ``(tm, thunk, nbytes)`` transfer
        descriptors whose execution (the completion half) models the
        bytes landing in the PE's buffers.

        Pure reads ride one side's TrafficManager (storage→PE directly,
        or storage→DE→compute-network→PE).  Split reads (scheduler
        ``split_reads=True``, §6.1 future work) partition the hit
        FullBlocks at page granularity: the PE side reads the leading
        pages while the DE side reads the trailing ones concurrently,
        and only the DE share crosses the compute network — the engine
        realisation of core/loading.split_read_plan.  Transfer seconds
        are charged to the tick's io ledger per physical resource."""
        req = er.req
        pe = self.pes[req.pe]
        de_tm = self.des[req.de].tm
        pe_node, de_node = req.pe[0], req.de[0]
        tmod = self.time_model
        out: List[Tuple[TrafficManager, callable, int]] = []
        if uses_state_blob(self.cfg):
            # one opaque state snapshot: unsplittable, rides the chosen side
            side = req.read_path
            payload = er._blob
            nbytes = len(payload) if payload else 0
            self.read_bytes_by_side[side] += nbytes
            if nbytes and self.tracer is not None:
                self.tracer.event(f"req/{req.rid}", "storage_read",
                                  side=side, nbytes=nbytes)
            er._read_box = {}
            node = pe_node if side == "pe" else de_node
            self._tick_io.add(("snic", node),
                              self._snic_s(node, nbytes, rid=req.rid,
                                           side=side))
            out.append((pe.tm if side == "pe" else de_tm,
                        lambda p=payload, box=er._read_box: box.update(p=p),
                        nbytes))
            if side == "de":
                self._tick_io.add(("cn", pe_node), self._cn_s(nbytes))
                out.append((pe.tm, lambda: None, nbytes))
            return out
        n = len(er.hit_refs)
        tid = er._session.traj.tid
        # ---- source segments: (kind, side, refs, lo) --------------------
        # The DRAM-tier prefix (when any) is served by the tier side's
        # node without touching the store; the cold remainder is read
        # from storage, PE side first then DE side (page order).  The
        # block partition comes from the request itself (the same one
        # the simulator's admission sets use).
        part = req.hit_blocks_by_side(n)
        k_tier, k_pe = part["tier"], part["pe"]
        segs = [("tier", req.dram_side, er.hit_refs[:k_tier], 0),
                ("snic", "pe", er.hit_refs[k_tier:k_tier + k_pe], k_tier),
                ("snic", "de", er.hit_refs[k_tier + k_pe:], k_tier + k_pe)]
        # a split read means both storage NICs served this request (PR 1
        # semantics) — tier-served segments don't count
        if part["pe"] and part["de"]:
            self.n_split_reads += 1
        er._read_payload = [None] * n
        payload = er._read_payload
        for kind, side, refs, lo in segs:
            if not refs:
                continue
            node = pe_node if side == "pe" else de_node
            # read_bytes_by_side stays per-side *storage* (SNIC) traffic,
            # matching the sim's snic accounting; DRAM-served bytes are
            # tracked separately in dram_bytes_by_side
            if kind == "tier":
                tier = self.tiers[node]
                # pinned since the path decision — every ref is resident,
                # so none of these reads reaches the backing store
                blocks = tier.read_blocks(refs, owner=tid,
                                          now=self._tier_now())
                hit_b = sum(b.nbytes for b in blocks)
                self.dram_bytes_by_side[side] += hit_b
                if hit_b and self.tracer is not None:
                    self.tracer.event(f"req/{req.rid}", "tier_hit",
                                      side=side, nbytes=hit_b)
                self._tick_io.add(("dram", node), tmod.dram_seconds(hit_b))
            elif node in self.tiers:
                # read through the node tier: misses hit the store (the
                # SNIC) and are admitted, warming the tier for the next
                # round on this node; stray resident blocks (outside the
                # probed prefix) still serve from DRAM
                tier = self.tiers[node]
                m0, h0 = tier.miss_bytes, tier.dram_hit_bytes
                blocks = tier.read_blocks(refs, owner=tid,
                                          now=self._tier_now())
                miss_b = tier.miss_bytes - m0
                hit_b = tier.dram_hit_bytes - h0
                self.read_bytes_by_side[side] += miss_b
                self.dram_bytes_by_side[side] += hit_b
                if self.tracer is not None:
                    if miss_b:
                        self.tracer.event(f"req/{req.rid}", "storage_read",
                                          side=side, nbytes=miss_b)
                    if hit_b:
                        self.tracer.event(f"req/{req.rid}", "tier_hit",
                                          side=side, nbytes=hit_b)
                self._tick_io.add(("snic", node),
                                  self._snic_s(node, miss_b, rid=req.rid,
                                               side=side))
                self._tick_io.add(("dram", node), tmod.dram_seconds(hit_b))
            else:
                blocks = self.store.read_blocks(refs)
                nb = sum(b.nbytes for b in blocks)
                self._tick_io.add(("snic", node),
                                  self._snic_s(node, nb, rid=req.rid,
                                               side=side))
                self.read_bytes_by_side[side] += nb
                if nb and self.tracer is not None:
                    self.tracer.event(f"req/{req.rid}", "storage_read",
                                      side=side, nbytes=nb)
            nbytes = sum(b.nbytes for b in blocks)
            out.append((pe.tm if side == "pe" else de_tm,
                        lambda blocks=blocks, lo=lo:
                        payload.__setitem__(slice(lo, lo + len(blocks)),
                                            blocks),
                        nbytes))
            if side == "de":
                # DE buffer -> PE over the compute network (layerwise)
                self._tick_io.add(("cn", pe_node), self._cn_s(nbytes))
                out.append((pe.tm, lambda: None, nbytes))
        if er._tier_pinned is not None:
            # the tier segment is read (copied out) — the pin taken at
            # the path decision has done its job
            node, prefix = er._tier_pinned
            self.tiers[node].unpin(prefix)
            er._tier_pinned = None
        return out

    def _do_read(self, er: EngineRequest):
        """Blocking read: every transfer drains inline (one degenerate
        single-item doorbell each) before the hit KV installs."""
        for tm, fn, nbytes in self._read_transfers(er):
            tm.submit(fn, nbytes, TrafficClass.KV_TRANSFER)
            tm.drain()
        self._read_complete(er)

    def _issue_read(self, er: EngineRequest) -> int:
        """Pipelined read: submit every transfer and flush each involved
        TrafficManager once (multi-WR doorbell batches) — the transfers
        stay in flight across this tick's engine compute and land at the
        tick's poll, which marks the request install-ready."""
        transfers = self._read_transfers(er)
        by_tm: Dict[int, Tuple[TrafficManager, list]] = {}
        for tm, fn, nbytes in transfers:
            by_tm.setdefault(id(tm), (tm, []))[1].append((fn, nbytes))
        if not by_tm:
            self._install_ready.append(er)
            return 0
        pending = [len(by_tm)]

        def tm_done():
            pending[0] -= 1
            if pending[0] == 0:
                self._install_ready.append(er)

        for tm, items in by_tm.values():
            for fn, nbytes in items:
                tm.submit(fn, nbytes, TrafficClass.KV_TRANSFER)
            tm.flush(on_complete=tm_done)
        return len(transfers)

    def _read_complete(self, er: EngineRequest):
        """Completion half: release the read-queue charge, record the
        timestamp and install the hit KV on the PE (layerwise
        double-buffered through kvio.layer_stream)."""
        req = er.req
        self._release_read_q(req)
        self._stamp(req.rid, "read_done_t")
        self._set_state(er, ReqState.PREFILL)
        pe = self.pes[req.pe]
        if uses_state_blob(self.cfg):
            pe.install_hit_kv(er, er._read_box.get("p"))
        else:
            pe.install_hit_kv(er, [b for b in er._read_payload
                                   if b is not None])

    def _release_read_q(self, req: Request):
        """Release exactly what choose_read_path charged — with
        split_reads the charge may span both sides."""
        tokens = req.read_tokens_by_side()
        for side in ("pe", "de"):
            if tokens[side]:
                self.sched.on_read_done(
                    req.pe if side == "pe" else req.de, tokens[side])

    # ------------------------------------------------------------------
    # engine phases
    # ------------------------------------------------------------------
    def _charge_collectives(self, node: int, tokens: int) -> None:
        """Per-layer model collectives of a forward/decode step over
        ``tokens`` land on the stepping node's CNIC link; they contend
        with that link's KV traffic at the tick's contention resolution
        (``_apply_net_contention``)."""
        coll = self.time_model.collectives
        if coll is None or tokens <= 0:
            return
        self._tick_coll[node] = self._tick_coll.get(node, 0.0) + \
            self.time_model.collective_seconds(coll.step_bytes(tokens))

    def _step_pes(self) -> int:
        act = 0
        pe_max = 0.0
        for pe in self.pes.values():
            before = pe.prefill_tokens
            done = pe.step()
            pe_max = max(pe_max,
                         self.time_model.pe_step_seconds(pe.last_step_items))
            self._charge_collectives(
                pe.eid[0], sum(b for _, b in pe.last_step_items))
            act += (pe.prefill_tokens - before) + len(done)
            if self.slo_cfg.prefill_chunk_tokens is not None:
                # chunked-prefill sub-state: a capped slice ran and the
                # round stays in the PE fifo for its next slice; decode
                # steps interleave in the meantime.  Entered only when
                # the chunk cap is configured, so unchunked runs keep
                # the legacy PREFILL-only lifecycle event-for-event.
                for er in pe.last_step_chunked:
                    self.prefill_chunks += 1
                    if er.lifecycle != ReqState.PREFILL_CHUNKED:
                        self._set_state(er, ReqState.PREFILL_CHUNKED)
            for er in done:
                self.sched.on_request_done(er.req.pe, er.req)
                self._stamp(er.req.rid, "prefill_done_t")
                self._set_state(er, ReqState.PD_TRANSFER)
                self._queue_pd_transfer(er)
        self._tick_compute += pe_max
        return act

    def _queue_pd_transfer(self, er: EngineRequest):
        # PE -> DE prompt-state transfer (compute network), one
        # submission per attention layer: the DE-side doorbell batching
        # sees the same LayerBlock granularity the layerwise install
        # used on the PE side
        n_l = max(kvio.n_attn_layers(self.cfg), 1)
        nbytes = er.req.prompt_tokens * self.cfg.kv_bytes_per_token()
        de_tm = self.des[er.req.de].tm
        per_layer, rem = divmod(nbytes, n_l)
        for li in range(n_l):
            # last layer carries the remainder: byte totals stay
            # exact across the per-layer submissions
            de_tm.submit(lambda: None,
                         per_layer + (rem if li == n_l - 1 else 0),
                         TrafficClass.KV_TRANSFER)
        self._tick_io.add(("cn", er.req.de[0]), self._cn_s(nbytes))
        if self.pipelined:
            self._pd_queue.append(er)
            de_tm.flush(on_complete=lambda er=er:
                        setattr(er, "_pd_ready", True))
        else:
            de_tm.drain()
            self._pending_admit.append(er)

    def _collect_pd(self) -> int:
        """Move PD-complete requests to the admission queue, preserving
        the order their prefills finished (= the blocking runtime's
        admission order)."""
        still: List[EngineRequest] = []
        n = 0
        for er in self._pd_queue:
            if er._cancelled:
                continue               # re-homed after an engine death
            if er._pd_ready:
                er._pd_ready = False
                self._pending_admit.append(er)
                n += 1
            else:
                still.append(er)
        self._pd_queue = still
        return n

    def _admit_pending(self) -> int:
        n = 0
        still = deque()
        while self._pending_admit:
            er = self._pending_admit.popleft()
            if er._cancelled:
                continue               # re-homed after an engine death
            de = self.des[er.req.de]
            if de.free_slots:
                self._set_state(er, ReqState.DECODE)
                de.admit(er)
                n += 1
            else:
                still.append(er)
        self._pending_admit = still
        return n

    def _step_des(self) -> int:
        act = 0
        de_max = 0.0
        for de in self.des.values():
            de_node = de.eid[0]
            active_before = [er for er in de.slots if er is not None]
            steps0 = de.decode_steps
            b0 = de.tm.bytes[TrafficClass.KV_TRANSFER]
            finished = de.step()
            de_max = max(de_max,
                         self.time_model.de_step_seconds(de.last_step_ctxs))
            self._charge_collectives(de_node, len(de.last_step_ctxs))
            act += (de.decode_steps - steps0) + len(finished)
            persist_b = de.tm.bytes[TrafficClass.KV_TRANSFER] - b0
            if persist_b and self.tracer is not None:
                self.tracer.event(f"engine/node{de_node}", "persist",
                                  nbytes=persist_b)
            self._tick_io.add(("snic", de_node),
                              self._snic_s(de_node, persist_b))
            for er in active_before:
                m = self.metrics.get(er.req.rid)
                if m is None:
                    continue
                if m.first_decode_t < 0:
                    self._stamp(er.req.rid, "first_decode_t")
                if len(er.generated) >= 2 and m.second_token_t < 0:
                    self._stamp(er.req.rid, "second_token_t")
            for er in finished:
                self.sched.on_request_done(er.req.de, er.req)
                self._stamp(er.req.rid, "done_t")
            if self.pipelined:
                pend, de.pending_persist = de.pending_persist, []
                if pend:
                    for er, _ in pend:
                        self._set_state(er, ReqState.PERSIST)

                    def persists_done(pend=pend):
                        for er, fin in pend:
                            if er._cancelled:
                                continue   # engine died; round re-runs
                            if fin is not None:
                                fin()
                            self._finish_round(er)

                    de.tm.flush(on_complete=persists_done)
            else:
                for er in finished:
                    self._finish_round(er)
        self._tick_compute += de_max
        return act

    def _finish_round(self, er: EngineRequest):
        """Round completion (after the persist landed): session context
        rolls forward, tier warm-up/prefetch runs, and the next round
        submits — immediately offline, after the think gap online."""
        sess = er._session
        sess.context = (er.context_tokens + er.append_tokens +
                        er.generated)
        sess.rounds_done += 1
        sess.current = None
        self._set_state(er, ReqState.DONE)
        self.gen_tokens_done += len(er.generated)
        del self._inflight[er.req.rid]
        if self.tiers:
            self._round_finished_tier(sess, er.req.de[0])
        if sess.next_round < sess.traj.n_rounds:
            think = sess.traj.rounds[sess.next_round].think
            if self._online and think > 0:
                self.loop.after(think, lambda s=sess: self._submit_round(s))
            else:
                self._submit_round(sess)

    # ------------------------------------------------------------------
    def _round_finished_tier(self, sess: AgentSession, de_node: int):
        """Inter-round tier maintenance (think-time window).

        1. Warm the decode node's tier with the round's full context —
           every one of those blocks just staged through that node's
           DRAM (decode_start H2D + block persists), so admission moves
           no new storage bytes (``store.peek``).
        2. Think-time prefetch: the next round's predicted hit is
           exactly the trie match of the current context; stage any
           blocks capacity pressure evicted back into the tier ahead of
           the round start.  Reads go through the backing store (real
           SNIC traffic, paid during the idle gap).  The prefetch fires
           right after warm-up — in online mode that is the start of
           the think gap, whose seconds also age the TTL policy; the
           simulator additionally models the late-window issue timing
           (Sim._schedule_prefetch).
        """
        tid = sess.traj.tid
        tier = self.tiers[de_node]
        now = self._tier_now()
        if uses_state_blob(self.cfg):
            return
        if sess.next_round >= sess.traj.n_rounds:
            # finished trajectory: never hit again (§A.4) — warming the
            # tier with it would only evict live sessions' prefixes
            for t in self.tiers.values():
                t.note_done(tid)
            return
        _, refs = self.trie.match(sess.context)
        # tail-first: keeps the leading blocks most recent, so LRU
        # eviction trims the tail and the servable prefix survives
        for r in reversed(refs):
            tier.admit(r, self.layout.full_block_bytes, owner=tid,
                       payload=self.store.peek(r), now=now)
        if self.prefetcher is not None:
            for chunk in self.prefetcher.plan(tier, refs):
                for r in chunk:
                    tier.prefetch_block(r, owner=tid, now=now)

    # ------------------------------------------------------------------
    # the event loop tick
    # ------------------------------------------------------------------
    def _poll_all(self) -> int:
        """Complete every in-flight transfer (tick phase 4): completion
        callbacks mark requests install-ready / PD-ready and run persist
        finalisation + next-round submission."""
        n = 0
        progress = True
        while progress:
            progress = False
            for tm in self._all_tms():
                if tm.queued:
                    tm.flush()
                k = tm.poll()
                if k:
                    progress = True
                    n += k
        return n

    def _run_installs(self) -> int:
        """Install the hit KV of read-complete requests, in decision
        (rid) order — the blocking runtime's install order."""
        ready, self._install_ready = self._install_ready, []
        ready.sort(key=lambda er: er.req.rid)
        n = 0
        for er in ready:
            if er._cancelled:
                continue       # stale completion of a re-homed request:
            n += 1             # its charges were already released
            self._read_complete(er)
        return n

    def _set_state(self, er: EngineRequest, state: ReqState):
        """Lifecycle transition.  With a tracer attached the previous
        state is closed as a span on the request's track at the end of
        the current tick (``_flush_stamps``) so span edges line up with
        the stamped milestones."""
        er.lifecycle = state
        if self.tracer is not None:
            self._pending_states.append((er, state))

    def _trace_submit(self, er: EngineRequest):
        """Open the lifecycle span chain at submission time itself (not
        end-of-tick): the first span's t0 must equal the metrics'
        ``submit_t`` so the attribution window matches measured TTFT."""
        if self.tracer is not None:
            er._span_state = "scheduled"
            er._state_t0 = self.clock.now

    def _stamp(self, rid: int, field_name: str):
        """Defer a milestone timestamp to the end of the current tick
        (after the clock charges the tick's modelled seconds) — stamping
        with the pre-advance time would make every latency metric
        exclude the tick its milestone occurred in."""
        m = self.metrics.get(rid)
        if m is not None:
            self._pending_stamps.append((m, field_name))

    def _flush_stamps(self):
        now = self.clock.now
        for m, fld in self._pending_stamps:
            if getattr(m, fld) < 0:
                setattr(m, fld, now)
                if fld == "prefill_done_t" and self.tracer is not None:
                    # TTFT endpoint (events.RoundMetrics.ttft)
                    self.tracer.event(f"req/{m.rid}", "first_token")
        self._pending_stamps = []
        for er, state in self._pending_states:
            prev = getattr(er, "_span_state", None)
            t0 = getattr(er, "_state_t0", now)
            if prev is not None and now > t0:
                self.tracer.span(f"req/{er.req.rid}", prev, t0, now)
            er._span_state = state.name.lower()
            er._state_t0 = now
        self._pending_states.clear()

    def _submit_overhead_delta(self) -> float:
        tot = sum(tm.submitted_seconds for tm in self._all_tms())
        d = tot - self._submit_seconds_seen
        self._submit_seconds_seen = tot
        return d

    def _apply_net_contention(self) -> None:
        """Resolve this tick's KV-vs-collective contention per CNIC link
        (repro.network.drain_times): each link's KV ledger inflates to
        the contended completion time (``transfer_backlog_s``) and any
        time the collectives finish after their uncontended service —
        model execution stalling on communication — is charged to the
        tick's compute (``collective_stall_s``): ≈ 0 under the VL
        arbiter, growing with transfer load under FIFO sharing.  The
        aggregate collective share of the link becomes the congestion
        signal next tick's read-path choices and KV pacing consume.
        No-op (all-zero ledgers) when collectives are off, keeping the
        legacy clock arithmetic bit-identical."""
        tot_coll = sum(self._tick_coll.values())
        tot_kv = 0.0
        for node, coll_s in self._tick_coll.items():
            if coll_s <= 0:
                continue
            kv_s = self._tick_io.buckets.get(("cn", node), 0.0)
            tot_kv += kv_s
            kv_done, coll_done = self.time_model.cn_drain(kv_s, coll_s)
            if kv_s > 0:
                self._tick_io.buckets[("cn", node)] = kv_done
            stall = max(0.0, coll_done - coll_s)
            self._tick_compute += stall
            self.collective_stall_s += stall
            self.transfer_backlog_s += max(0.0, kv_done - kv_s)
        tot = tot_coll + tot_kv
        self.net_congestion = (tot_coll / tot) if tot > 0 else 0.0
        for tm in self._all_tms():
            tm.net_congestion = self.net_congestion

    # ------------------------------------------------------------------
    # elastic role reconfiguration (core/autoscale.py), driven by the
    # existing tick loop
    # ------------------------------------------------------------------
    def _elastic_signals(self) -> LoadSignals:
        sched = self.sched
        spec = self.time_model.spec
        node = self.time_model.node
        pe_rate = max(node.gpu.flops * node.gpu.mfu_prefill /
                      max(spec.linear_flops_per_token(), 1.0), 1.0)
        pe_queued = sum(r.new_tokens for r in sched.pe_queue)
        pe_busy = sum(w.remaining for pe in self.pes.values()
                      for w, _ in pe.fifo)
        de_busy_tok = 0
        n_active = 0
        ctxs: List[float] = []
        for de in self.des.values():
            for slot, er in enumerate(de.slots):
                if er is None:
                    continue
                n_active += 1
                de_busy_tok += er.req.gen_tokens - len(er.generated)
                ctxs.append(float(de.lengths[slot]))
        de_q_tok = 0
        for q in (sched.de_global_queue, *sched.de_private.values()):
            for r in q:
                de_q_tok += r.gen_tokens
                ctxs.append(float(r.prompt_tokens))
        n_de_now = max(len(self.des), 1)
        n_ref = max(n_active / n_de_now, 1.0)
        ctx_ref = (sum(ctxs) / len(ctxs)) if ctxs else 1.0
        kv_step = spec.decode_step_bytes(ctx_ref)
        w = spec.active_param_bytes_resident(1)
        de_rate = max(n_ref * node.gpu.hbm_bw * node.gpu.mbu_decode /
                      max(n_ref * kv_step + w, 1.0), 1.0)
        kv_tok = max(spec.kv_bytes_per_token, 1)
        snic_tok_rate = max(node.snic_bw / kv_tok, 1.0)
        pe_rq = sum(st.read_q for st in sched.engines.values()
                    if st.kind == "pe" and not st.draining)
        de_rq = sum(st.read_q for st in sched.engines.values()
                    if st.kind == "de" and not st.draining)
        tiers = list(self.tiers.values())
        dram_hit = sum(t.dram_hit_bytes for t in tiers)
        denom = dram_hit + sum(self.read_bytes_by_side.values())
        # class-aware signals: interactive queue depth feeds the elastic
        # controller extra pressure (core/autoscale.LoadSignals); 0.0
        # whenever class scheduling is off so pressures stay identical
        pe_q_int = de_q_int = 0.0
        if sched.class_aware:
            pe_q_int = sum(r.new_tokens for r in sched.pe_queue
                           if r.class_rank == 0) / pe_rate
            de_q_int = sum(r.gen_tokens
                           for q in (sched.de_global_queue,
                                     *sched.de_private.values())
                           for r in q if r.class_rank == 0) / de_rate
        return LoadSignals(
            n_pe=len(sched.admitting("pe")),
            n_de=len(sched.admitting("de")),
            pe_queued_s=pe_queued / pe_rate,
            pe_busy_s=pe_busy / pe_rate,
            de_queued_s=de_q_tok / de_rate,
            de_busy_s=de_busy_tok / de_rate,
            pe_read_q_s=pe_rq / snic_tok_rate,
            de_read_q_s=de_rq / snic_tok_rate,
            net_congestion=self.net_congestion,
            dram_hit_ratio=(dram_hit / denom) if denom else 0.0,
            pe_queued_interactive_s=pe_q_int,
            de_queued_interactive_s=de_q_int,
        )

    def _begin_reconfig(self, action: str):
        src = "de" if action == DE_TO_PE else "pe"
        cands = self.sched.admitting(src)
        if len(cands) <= 1:
            return

        def load_of(st):
            if st.kind == "de":
                de = self.des[st.engine]
                return st.tok + (de.n_slots - de.free_slots) * self.max_seq
            return st.tok + st.read_q

        victim = pick_victim(cands, self.drain_policy, load_of,
                             rotation=self._drain_rotation)
        self._drain_rotation += 1
        self.sched.begin_drain(victim.engine)
        self.sched.requeue_unstarted(
            victim.engine, [er.req for er in self._inflight.values()])
        self.engine_lifecycle[victim.engine] = EngineLifecycle.DRAINING
        self.drains.begin(victim.engine, src,
                          "pe" if src == "de" else "de", self.clock.now)

    def _engine_drained(self, eid: Tuple[int, int], kind: str) -> bool:
        """In-flight lifecycle states emptied?  The scheduler's seq/tok
        gate covers assigned requests end-to-end; the engine-local
        checks cover work the scheduler has already released but whose
        completion half is still parked (deferred persists, unflushed
        doorbells)."""
        if not self.sched.can_finish_drain(eid):
            return False
        if kind == "pe":
            pe = self.pes[eid]
            return not pe.fifo and not pe.tm.busy
        de = self.des[eid]
        return de.free_slots == de.n_slots and not de.pending_persist \
            and not de.tm.busy and \
            not any(er.req.de == eid for er in self._inflight.values())

    def _finish_flip(self, rec):
        eid = rec.engine
        node_id = eid[0]
        gid = next(self._next_gid)
        tier = self.tiers.get(node_id)
        handoff = int(tier.used_bytes) if tier is not None else 0
        if rec.to_kind == "pe":
            del self.des[eid]
            self.pes[eid] = PrefillEngine(
                eid, self.cfg, self.params, self.store, self.layout,
                self.max_seq, self._quota_s, layerwise=self._layerwise,
                chunk_tokens=self.slo_cfg.prefill_chunk_tokens,
                class_aware=self.slo_cfg.class_aware)
            self.sched.finish_drain(eid, kind="pe", group=gid)
        else:
            del self.pes[eid]
            de_store = self.tiers.get(node_id, self.store)
            de = DecodeEngine(eid, self.cfg, self.params, de_store,
                              self.trie, self.layout, self.max_seq,
                              n_slots=self._de_slots,
                              blob_store=self.blob_store)
            de.defer_persist = self.pipelined
            self.des[eid] = de
            self.sched.finish_drain(eid, kind="de", group=gid,
                                    free_hbm_tokens=self._de_slots *
                                    self.max_seq)
        # the DE-group topology changed: re-route queued requests
        self.sched.rebalance_de_private()
        self.engine_lifecycle[eid] = EngineLifecycle.ACTIVE
        rec = self.drains.finish(eid, self.clock.now,
                                 tier_handoff_bytes=handoff)
        if self.tracer is not None:
            eng = self.pes.get(eid) or self.des[eid]
            eng.tm.tracer = self.tracer
            eng.tm.track = f"traffic/node{eid[0]}"
            self.tracer.span(
                "reconfig", "drain", rec.t_begin, self.clock.now,
                engine=list(eid),
                direction=f"{rec.from_kind}->{rec.to_kind}")

    def _elastic_tick(self):
        """Phase 0 of an elastic tick: flip engines whose RECONFIGURING
        weight reload was charged last tick, advance active drains
        (drained -> RECONFIGURING + weight-reload io), then let the
        controller observe once per ``reconfig_interval_s``."""
        for rec in self._reconfig_ready:
            self._finish_flip(rec)
        self._reconfig_ready = []
        for eid, rec in list(self.drains.active.items()):
            if rec.t_drained >= 0:
                continue
            if not self._engine_drained(eid, rec.from_kind):
                continue
            self.drains.mark_drained(eid, self.clock.now)
            self.engine_lifecycle[eid] = EngineLifecycle.RECONFIGURING
            w = self.time_model.spec.active_param_bytes_resident(1)
            self.reconfig_weight_bytes += w
            self._tick_io.add(("snic", eid[0]), self._snic_s(eid[0], w))
            self._reconfig_ready.append(rec)
        if self.clock.now >= self._next_obs_t:
            self._next_obs_t = self.clock.now + self.reconfig_interval_s
            if not self.drains.active and not self._reconfig_ready:
                action = self.controller.observe(self._elastic_signals(),
                                                 self.clock.now)
                if action is not None:
                    self._begin_reconfig(action)

    # ------------------------------------------------------------------
    # engine failure (sim/faults.py EngineDeath): fail-stop + re-home
    # ------------------------------------------------------------------
    def _fault_tick(self):
        """Process every death whose time has arrived (tick phase -1,
        before scheduling) — the serving analogue of the simulator's
        death events."""
        while self._deaths_pending and \
                self._deaths_pending[0].t <= self.clock.now:
            d = self._deaths_pending.pop(0)
            self._engine_death(tuple(d.engine))

    def _engine_death(self, eid: Tuple[int, int]):
        """Fail-stop of engine ``eid``: abort any drain it was part of,
        hand unstarted assignments back to the queues, re-home every
        round with physical state on the engine (restart from persisted
        KV — the trie still holds every block persisted *before* the
        death, and blocks whose persist writes had not landed are
        re-persisted exactly once by the recovery run), then remove the
        engine from the scheduler registry so nothing routes to it.
        Role backfill is emergent: the survivors' pressure shift feeds
        the PDController, which proposes a compensating flip."""
        if eid not in self.pes and eid not in self.des:
            return                     # already dead / never existed
        self.dead_engines.append(eid)
        if self.tracer is not None:
            kind = "pe" if eid in self.pes else "de"
            self.tracer.event("faults/deaths", "engine_death",
                              engine=list(eid), kind=kind)
        # a victim dying mid-drain is not a role change: drop the record
        self.drains.abort(eid)
        self._reconfig_ready = [r for r in self._reconfig_ready
                                if r.engine != eid]
        # assigned-but-unstarted requests go back to the queues whole —
        # nothing physical happened for them on this engine
        self.sched.requeue_unstarted(
            eid, [er.req for er in self._inflight.values()])
        # rounds with physical state on the engine restart.  PE
        # involvement ends once the prompt state left for the DE
        # (PD_TRANSFER rides the DE's TrafficManager); DE involvement
        # lasts until the round's persist lands.
        for er in list(self._inflight.values()):
            req = er.req
            if req.de == eid or (req.pe == eid and er.lifecycle in (
                    ReqState.SCHEDULED, ReqState.READING,
                    ReqState.PREFILL)):
                self._resubmit_round(er)
        self.sched.fail_engine(eid)
        self.pes.pop(eid, None)
        self.des.pop(eid, None)
        self.engine_lifecycle[eid] = EngineLifecycle.DEAD
        # the group topology changed: re-route queued DE requests
        self.sched.rebalance_de_private()

    def _resubmit_round(self, er: EngineRequest):
        """Partial-leg cancellation + restart of one re-homed round.

        The old EngineRequest is marked ``_cancelled`` so every stale
        completion half (a surviving read leg's install, a parked PD
        entry, a pending admit) discards itself; its scheduler charges
        are released per lifecycle state (the dead engine's own charges
        are forfeited by the tolerant hooks).  A fresh request under a
        new rid restarts from the *persisted* prefix — the trie match
        of the same prompt tokens, no session-RNG redraw — and inherits
        the original RoundMetrics (same submit_t), so TTFT/TPOT include
        the recovery gap honestly.  Greedy decode regenerates the same
        tokens, which keeps session context and persisted blocks
        identical to a fault-free run."""
        if er._cancelled:
            return
        er._cancelled = True
        req = er.req
        sess = er._session
        if er._tier_pinned is not None:
            node, prefix = er._tier_pinned
            self.tiers[node].unpin(prefix)
            er._tier_pinned = None
        lc = er.lifecycle
        if lc == ReqState.READING:
            # the read never completed: the full path-decision charge is
            # still held on both sides' reading queues
            self._release_read_q(req)
        if lc in (ReqState.SCHEDULED, ReqState.READING, ReqState.PREFILL):
            if req.pe is not None:
                self.sched.on_request_done(req.pe, req)
                pe = self.pes.get(req.pe)
                if pe is not None:
                    pe.fifo = [(w, e) for (w, e) in pe.fifo if e is not er]
        if req.de is not None and lc in (
                ReqState.SCHEDULED, ReqState.READING, ReqState.PREFILL,
                ReqState.PD_TRANSFER, ReqState.DECODE):
            # the DE charge (seq/tok/HBM reservation) is held from
            # assignment until decode finishes
            self.sched.on_request_done(req.de, req)
        del self._inflight[req.rid]
        # -- fresh request over the same tokens -------------------------
        prompt = er.context_tokens + er.append_tokens
        if uses_state_blob(self.cfg):
            blob, hit = self.blob_store.get(sess.context)
            refs = []
            hit = hit if blob is not None else 0
        else:
            hit, refs = self.trie.match(prompt)
            blob = None
        if hit >= len(prompt):         # keep >= 1 token to prefill
            hit = len(prompt) - 1
            refs = refs[:hit // self.layout.block_tokens]
        req2 = Request(rid=next(self._rid), cached_tokens=hit,
                       new_tokens=len(prompt) - hit,
                       gen_tokens=req.gen_tokens,
                       arrival=req.arrival,   # original queue priority
                       slo_class=req.slo_class)
        er2 = EngineRequest(req=req2, context_tokens=prompt[:hit],
                            append_tokens=prompt[hit:], hit_refs=refs)
        er2._blob = blob
        er2._session = sess
        er2._tier_pinned = None
        er2._pd_ready = False
        er2._cancelled = False
        er2.lifecycle = ReqState.SCHEDULED
        self._trace_submit(er2)
        sess.current = er2
        self._inflight[req2.rid] = er2
        m = self.metrics.pop(req.rid)
        m.rid = req2.rid
        self.metrics[req2.rid] = m
        self.recovered_rounds += 1
        if self.tracer is not None:
            self.tracer.event(f"req/{req2.rid}", "recovered",
                              old_rid=req.rid, cached_tokens=hit)
        self.sched.submit(req2)

    def _tick(self) -> int:
        """One event-loop tick; returns an activity count (0 = idle).

        Pipelined: reads issued in phase 1 and PD/persist transfers
        flushed in phases 2–3 stay in flight across the engine compute
        and land at phase 4's poll, so the clock charges
        ``max(transfer, compute)``.  Blocking: the same phases with
        inline drains — the clock charges ``transfer + compute``.
        """
        self._tick_io = TickIo()
        self._tick_compute = 0.0
        self._tick_coll = {}
        act = 0
        if self._deaths_pending:
            self._fault_tick()
        if self.elastic:
            self._elastic_tick()
        if self.pipelined:
            act += self._schedule_tick()     # 1. decide + issue reads
            act += self._step_pes()          # 2. prefill compute
            act += self._step_des()          # 3. decode compute
            act += self._poll_all()          # 4. transfer completions
            act += self._run_installs()      # 5. hit-KV installs
            self._collect_pd()
            act += self._admit_pending()     # 6. DE admissions
            self._apply_net_contention()
            dt = max(self._tick_io.parallel_seconds(), self._tick_compute)
        else:
            act += self._schedule_tick()
            act += self._step_pes()
            act += self._admit_pending()
            act += self._step_des()
            self._apply_net_contention()
            dt = self._tick_io.serial_seconds() + self._tick_compute
        self.clock.advance(dt + self._submit_overhead_delta())
        self._flush_stamps()
        if self.tracer is not None:
            self.tracer.counter("system/load",
                                inflight=len(self._inflight))
        return act

    # ------------------------------------------------------------------
    # drivers
    # ------------------------------------------------------------------
    def run_offline(self, trajectories: List[Trajectory],
                    max_iters: int = 100000) -> List[AgentSession]:
        sessions = [AgentSession(t, np.random.default_rng(1000 + t.tid))
                    for t in trajectories]
        self._online = False
        for s in sessions:
            self._submit_round(s)
        for _ in range(max_iters):
            if all(s.done() for s in sessions):
                break
            self._tick()
        else:
            raise RuntimeError("serving system did not converge")
        return sessions

    def run_online(self, trajectories: List[Trajectory],
                   arrivals: List[float],
                   max_iters: int = 1000000) -> List[AgentSession]:
        """Online serving: trajectory i starts at ``arrivals[i]`` seconds
        on the runtime's wall clock; inter-round think gaps
        (``Round.think``) are honoured.  The clock jumps over idle gaps
        instead of sleeping, so a low-rate sweep costs no real time."""
        assert len(arrivals) == len(trajectories), "one arrival per trajectory"
        sessions = [AgentSession(t, np.random.default_rng(1000 + t.tid))
                    for t in trajectories]
        self._online = True
        try:
            for s, t0 in zip(sessions, arrivals):
                self.loop.at(float(t0), lambda s=s: self._submit_round(s))
            # wake-up markers at death times so an idle clock jump never
            # lands past a death (the tick's _fault_tick processes it)
            for d in self._deaths_pending:
                self.loop.at(float(d.t), lambda: None)
            for _ in range(max_iters):
                self.loop.fire_due()
                if all(s.done() for s in sessions) and not self.loop.pending:
                    break
                if self._tick() == 0:
                    nt = self.loop.next_time()
                    if nt is None:
                        raise RuntimeError(
                            "serving runtime stalled with no pending events")
                    self.clock.jump_to(nt)
            else:
                raise RuntimeError("serving system did not converge")
        finally:
            self._online = False
        return sessions

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        tiers = list(self.tiers.values())
        return conforming(dict(
            store_reads=self.store.bytes_read,
            store_writes=self.store.bytes_written,
            read_bytes_pe_side=self.read_bytes_by_side["pe"],
            read_bytes_de_side=self.read_bytes_by_side["de"],
            split_reads=self.n_split_reads,
            trie_blocks=self.trie.n_blocks,
            prefill_tokens=sum(p.prefill_tokens for p in self.pes.values()),
            decode_steps=sum(d.decode_steps for d in self.des.values()),
            gen_tokens=self.gen_tokens_done,
            # --- wall clock / submission overhead ----------------------
            wall_s=self.clock.now,
            doorbells=sum(tm.doorbells for tm in self._all_tms()),
            submitted_seconds=sum(tm.submitted_seconds
                                  for tm in self._all_tms()),
            # --- finite compute network (zeros when collectives off) ----
            collective_stall_s=self.collective_stall_s,
            transfer_backlog_s=self.transfer_backlog_s,
            net_congestion=self.net_congestion,
            paced_flushes=sum(tm.paced_flushes for tm in self._all_tms()),
            deferred_wrs=sum(tm.deferred_wrs for tm in self._all_tms()),
            # --- per-round latency (mirrors Sim.results()) -------------
            **events.latency_summary(self.metrics.values()),
            # --- DRAM tier (zeros when disabled) -----------------------
            dram_hit_bytes=sum(t.dram_hit_bytes for t in tiers),
            dram_bytes_pe_side=self.dram_bytes_by_side["pe"],
            dram_bytes_de_side=self.dram_bytes_by_side["de"],
            tier_miss_bytes=sum(t.miss_bytes for t in tiers),
            tier_prefetch_bytes=sum(t.prefetch_bytes for t in tiers),
            tier_evicted_bytes=sum(t.evicted_bytes for t in tiers),
            # --- elastic reconfiguration (zeros when elastic off) -------
            role_changes=self.drains.n_flips,
            role_changes_by_direction=self.drains.flips_by_direction(),
            reconfig_drain_s=self.drains.drain_seconds(),
            reconfig_weight_bytes=self.reconfig_weight_bytes,
            tier_handoff_bytes=self.drains.tier_handoff_bytes(),
            n_pe_final=len(self.pes),
            n_de_final=len(self.des),
            # --- faults / resilience (zeros when faults off) -------------
            engine_deaths=len(self.dead_engines),
            recovered_rounds=self.recovered_rounds,
            hedged_reads=self.hedged_reads,
            hedge_moved_tokens=self.hedge_moved_tokens,
            # --- online SLO layer (zeros/defaults when off) --------------
            admitted_rounds=(self.gate.admitted_rounds
                             if self.gate is not None else len(self.metrics)),
            deferred_rounds=(self.gate.deferred_rounds
                             if self.gate is not None else 0),
            rejected_rounds=(self.gate.rejected_rounds
                             if self.gate is not None else 0),
            prefill_chunks=self.prefill_chunks,
            latency_by_class=events.latency_by_class(self.metrics.values()),
        ), "serving")

    def slo_attainment(self, ttft_slo_s: float = 4.0,
                       tpot_slo_s: float = 0.050) -> float:
        """Fraction of finished rounds meeting both SLOs (paper §7.4
        defaults: TTFT ≤ 4 s, TPOT ≤ 50 ms)."""
        return events.slo_attainment(self.metrics.values(),
                                     ttft_slo_s, tpot_slo_s)
