"""DualPath serving system: scheduler + engines + storage, end to end.

Single-process orchestration of the full request lifecycle with *real*
token generation and *real* KV bytes moving along the dual-path legs —
the functional counterpart of the discrete-event simulator (which owns
the timing claims).  Used by the examples and integration tests.

Per round (paper Fig. 4):
 1. client computes the trie hit for ``context ‖ append`` (§A.4),
 2. scheduler assigns (PE, DE) + read path (§6.1 / Alg. 1),
 3. the chosen side's TrafficManager carries the FullBlock reads
    (storage→PE directly, or storage→DE→compute-network→PE),
 4. PE runs quota-packed chunked prefill (§6.2) over the append chunk,
 5. prompt state transfers PE→DE; DE decodes ``gen`` tokens greedily and
    persists newly-filled FullBlocks + trie entries (§A.5).
"""
from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.blocks import BlockLayout, layout_for
from repro.core.scheduler import Request, Scheduler
from repro.core.traffic import TrafficClass
from repro.engines import kvio
from repro.engines.runtime import (DecodeEngine, EngineRequest,
                                   PrefillEngine, uses_state_blob)
from repro.kvcache.store import MemoryKVStore, StateBlobStore
from repro.kvcache.tiers import DramTier, ThinkTimePrefetcher
from repro.kvcache.trie import BlockTrie
from repro.sim.traces import Trajectory


@dataclass
class AgentSession:
    traj: Trajectory
    rng: np.random.Generator
    context: List[int] = field(default_factory=list)
    next_round: int = 0
    rounds_done: int = 0
    current: Optional[EngineRequest] = None

    def done(self) -> bool:
        return self.next_round >= self.traj.n_rounds and self.current is None


class ServingSystem:
    def __init__(self, cfg: ModelConfig, params, *, n_pe: int = 1,
                 n_de: int = 1, mode: str = "dualpath",
                 block_tokens: int = 16, max_seq: int = 512,
                 de_slots: int = 8, quota_s: float = 0.3, seed: int = 0,
                 split_reads: bool = False, layerwise: bool = True,
                 dram_tier_bytes: float = 0, tier_policy: str = "lru",
                 tier_ttl_s: Optional[float] = None, prefetch: bool = False):
        assert mode in ("dualpath", "basic")
        self.cfg = cfg
        self.mode = mode
        self.max_seq = max_seq
        self.layout = layout_for(cfg, block_tokens)
        self.store = MemoryKVStore(self.layout)
        self.blob_store = StateBlobStore()
        self.trie = BlockTrie(block_tokens)
        self.sched = Scheduler(alpha=1 << 30, beta=1 << 30,
                               split_reads=split_reads)
        # node-local DRAM tiers over the remote store (kvcache/tiers.py):
        # reads served from a tier never reach the store (= the SNIC).
        # NOTE: serving has no wall clock — the tier's internal tick
        # counter supplies "time", so an agentic-ttl ``tier_ttl_s`` is
        # measured in tier operations here (the simulator, which has a
        # clock, passes real seconds).
        self.tiers: Dict[int, DramTier] = {}
        if dram_tier_bytes:
            for node in range(n_pe + n_de):
                self.tiers[node] = DramTier(dram_tier_bytes,
                                            policy=tier_policy,
                                            ttl_s=tier_ttl_s,
                                            backing=self.store)
        self.prefetcher = ThinkTimePrefetcher() \
            if (prefetch and self.tiers) else None
        self.pes: Dict[Tuple[int, int], PrefillEngine] = {}
        self.des: Dict[Tuple[int, int], DecodeEngine] = {}
        for i in range(n_pe):
            eid = (i, 0)
            self.sched.register_engine(eid, node=i, kind="pe", group=0)
            self.pes[eid] = PrefillEngine(eid, cfg, params, self.store,
                                          self.layout, max_seq, quota_s,
                                          layerwise=layerwise)
        for j in range(n_de):
            eid = (n_pe + j, 0)
            st = self.sched.register_engine(eid, node=n_pe + j, kind="de",
                                            group=1000)
            # the DE persists through its node tier (write-through + tier
            # warm-up) when one is configured
            de_store = self.tiers.get(n_pe + j, self.store)
            de = DecodeEngine(eid, cfg, params, de_store, self.trie,
                              self.layout, max_seq, n_slots=de_slots,
                              blob_store=self.blob_store)
            st.free_hbm_tokens = de_slots * max_seq
            self.des[eid] = de
        self._rid = itertools.count()
        self._pending_admit: deque = deque()
        self._inflight: Dict[int, EngineRequest] = {}
        self.rng = np.random.default_rng(seed)
        self.read_bytes_by_side = {"pe": 0, "de": 0}
        self.dram_bytes_by_side = {"pe": 0, "de": 0}
        self.n_split_reads = 0

    # ------------------------------------------------------------------
    def _submit_round(self, sess: AgentSession):
        rnd = sess.traj.rounds[sess.next_round]
        append = list(sess.rng.integers(
            2, self.cfg.vocab_size, size=rnd.append))
        prompt = sess.context + append
        if uses_state_blob(self.cfg):
            blob, hit = self.blob_store.get(sess.context)
            refs = []
            hit = hit if blob is not None else 0
        else:
            hit, refs = self.trie.match(prompt)
            blob = None
        new_tokens = len(prompt) - hit
        req = Request(rid=next(self._rid), cached_tokens=hit,
                      new_tokens=new_tokens, gen_tokens=rnd.gen)
        er = EngineRequest(req=req, context_tokens=prompt[:hit],
                           append_tokens=prompt[hit:], hit_refs=refs)
        er._blob = blob
        er._session = sess
        er._tier_pinned = None
        sess.current = er
        sess.next_round += 1
        self._inflight[req.rid] = er
        for tier in self.tiers.values():
            tier.note_alive(sess.traj.tid)
        self.sched.submit(req)

    # ------------------------------------------------------------------
    def _schedule(self):
        de_reports = {eid: (sum(s is not None for s in de.slots),
                            sum(int(l) for l in de.lengths),
                            0, de.free_slots * self.max_seq)
                      for eid, de in self.des.items()}
        for asg in self.sched.on_de_fetch(1000, de_reports):
            pass
        pe_reports = {eid: (len(pe.fifo),
                            sum(w.remaining for w, _ in pe.fifo), 0)
                      for eid, pe in self.pes.items()}
        for asg in self.sched.on_pe_fetch(0, pe_reports):
            pass
        # decide paths for every ready request first (read queues build up
        # across the batch of decisions, as on a live cluster), then read
        ready = []
        for er in list(self._inflight.values()):
            req = er.req
            if req.pe is None or req.de is None or req.read_path is not None:
                continue
            if self.mode == "basic":
                req.read_path = "pe"
                self.sched.engines[req.pe].read_q += req.cached_tokens
            else:
                tier_tokens = None
                if self.tiers and er.hit_refs:
                    bt = self.layout.block_tokens
                    tier_tokens = {
                        "pe": self.tiers[req.pe[0]]
                              .resident_prefix(er.hit_refs) * bt,
                        "de": self.tiers[req.de[0]]
                              .resident_prefix(er.hit_refs) * bt,
                    }
                self.sched.choose_read_path(req, tier_tokens=tier_tokens)
                if req.dram_tokens:
                    # pin the tier-resident prefix NOW: reads of other
                    # ready requests admit blocks (and may evict) before
                    # this one's turn — pinned blocks cannot disappear
                    # between the path decision and the read
                    bt = self.layout.block_tokens
                    node = (req.pe if req.dram_side == "pe" else req.de)[0]
                    prefix = er.hit_refs[:req.dram_tokens // bt]
                    self.tiers[node].pin(prefix)
                    er._tier_pinned = (node, prefix)
            ready.append(er)
        for er in ready:
            self._do_read(er)

    def _do_read(self, er: EngineRequest):
        """Execute the storage read and deliver the payload to the PE.

        Pure reads ride one side's TrafficManager (storage→PE directly,
        or storage→DE→compute-network→PE).  Split reads (scheduler
        ``split_reads=True``, §6.1 future work) partition the hit
        FullBlocks at page granularity: the PE side reads the leading
        pages while the DE side reads the trailing ones concurrently,
        and only the DE share crosses the compute network — the engine
        realisation of core/loading.split_read_plan."""
        req = er.req
        pe = self.pes[req.pe]
        de_tm = self.des[req.de].tm
        if uses_state_blob(self.cfg):
            # one opaque state snapshot: unsplittable, rides the chosen side
            side = req.read_path
            payload = er._blob
            nbytes = len(payload) if payload else 0
            self.read_bytes_by_side[side] += nbytes
            tm = pe.tm if side == "pe" else de_tm
            box = {}
            tm.submit(lambda: box.update(p=payload), nbytes,
                      TrafficClass.KV_TRANSFER)
            tm.drain()
            if side == "de":
                pe.tm.submit(lambda: None, nbytes, TrafficClass.KV_TRANSFER)
                pe.tm.drain()
            pe.install_hit_kv(er, box.get("p"))
            self._release_read_q(req)
            return
        n = len(er.hit_refs)
        tid = er._session.traj.tid
        # ---- source segments: (kind, side, refs, lo) --------------------
        # The DRAM-tier prefix (when any) is served by the tier side's
        # node without touching the store; the cold remainder is read
        # from storage, PE side first then DE side (page order).  The
        # block partition comes from the request itself (the same one
        # the simulator's admission sets use).
        part = req.hit_blocks_by_side(n)
        k_tier, k_pe = part["tier"], part["pe"]
        segs = [("tier", req.dram_side, er.hit_refs[:k_tier], 0),
                ("snic", "pe", er.hit_refs[k_tier:k_tier + k_pe], k_tier),
                ("snic", "de", er.hit_refs[k_tier + k_pe:], k_tier + k_pe)]
        # a split read means both storage NICs served this request (PR 1
        # semantics) — tier-served segments don't count
        if part["pe"] and part["de"]:
            self.n_split_reads += 1
        payload: List = [None] * n
        for kind, side, refs, lo in segs:
            if not refs:
                continue
            node = (req.pe if side == "pe" else req.de)[0]
            # read_bytes_by_side stays per-side *storage* (SNIC) traffic,
            # matching the sim's snic accounting; DRAM-served bytes are
            # tracked separately in dram_bytes_by_side
            if kind == "tier":
                tier = self.tiers[node]
                # pinned since the path decision — every ref is resident,
                # so none of these reads reaches the backing store
                blocks = tier.read_blocks(refs, owner=tid)
                self.dram_bytes_by_side[side] += sum(b.nbytes
                                                     for b in blocks)
            elif node in self.tiers:
                # read through the node tier: misses hit the store (the
                # SNIC) and are admitted, warming the tier for the next
                # round on this node; stray resident blocks (outside the
                # probed prefix) still serve from DRAM
                tier = self.tiers[node]
                m0, h0 = tier.miss_bytes, tier.dram_hit_bytes
                blocks = tier.read_blocks(refs, owner=tid)
                self.read_bytes_by_side[side] += tier.miss_bytes - m0
                self.dram_bytes_by_side[side] += tier.dram_hit_bytes - h0
            else:
                blocks = self.store.read_blocks(refs)
                self.read_bytes_by_side[side] += sum(b.nbytes
                                                     for b in blocks)
            nbytes = sum(b.nbytes for b in blocks)
            tm = pe.tm if side == "pe" else de_tm
            tm.submit(lambda blocks=blocks, lo=lo:
                      payload.__setitem__(slice(lo, lo + len(blocks)),
                                          blocks),
                      nbytes, TrafficClass.KV_TRANSFER)
            tm.drain()
            if side == "de":
                # DE buffer -> PE over the compute network (layerwise)
                pe.tm.submit(lambda: None, nbytes, TrafficClass.KV_TRANSFER)
                pe.tm.drain()
        if er._tier_pinned is not None:
            node, prefix = er._tier_pinned
            self.tiers[node].unpin(prefix)
            er._tier_pinned = None
        pe.install_hit_kv(er, [b for b in payload if b is not None])
        self._release_read_q(req)

    def _release_read_q(self, req: Request):
        """Release exactly what choose_read_path charged — with
        split_reads the charge may span both sides."""
        tokens = req.read_tokens_by_side()
        for side in ("pe", "de"):
            if tokens[side]:
                self.sched.on_read_done(
                    req.pe if side == "pe" else req.de, tokens[side])

    # ------------------------------------------------------------------
    def _step_engines(self):
        for pe in self.pes.values():
            for er in pe.step():
                self.sched.on_request_done(er.req.pe, er.req)
                # PE -> DE prompt-state transfer (compute network), one
                # submission per attention layer: the DE-side doorbell
                # batching sees the same LayerBlock granularity the
                # layerwise install used on the PE side
                n_l = max(kvio.n_attn_layers(self.cfg), 1)
                nbytes = er.req.prompt_tokens * self.cfg.kv_bytes_per_token()
                de_tm = self.des[er.req.de].tm
                per_layer, rem = divmod(nbytes, n_l)
                for li in range(n_l):
                    # last layer carries the remainder: byte totals stay
                    # exact across the per-layer submissions
                    de_tm.submit(lambda: None,
                                 per_layer + (rem if li == n_l - 1 else 0),
                                 TrafficClass.KV_TRANSFER)
                de_tm.drain()
                self._pending_admit.append(er)
        still = deque()
        while self._pending_admit:
            er = self._pending_admit.popleft()
            de = self.des[er.req.de]
            if de.free_slots:
                de.admit(er)
            else:
                still.append(er)
        self._pending_admit = still
        for de in self.des.values():
            for er in de.step():
                self.sched.on_request_done(er.req.de, er.req)
                sess = er._session
                sess.context = (er.context_tokens + er.append_tokens +
                                er.generated)
                sess.rounds_done += 1
                sess.current = None
                del self._inflight[er.req.rid]
                if self.tiers:
                    self._round_finished_tier(sess, er.req.de[0])
                if sess.next_round < sess.traj.n_rounds:
                    self._submit_round(sess)

    # ------------------------------------------------------------------
    def _round_finished_tier(self, sess: AgentSession, de_node: int):
        """Inter-round tier maintenance (think-time window).

        1. Warm the decode node's tier with the round's full context —
           every one of those blocks just staged through that node's
           DRAM (decode_start H2D + block persists), so admission moves
           no new storage bytes (``store.peek``).
        2. Think-time prefetch: the next round's predicted hit is
           exactly the trie match of the current context; stage any
           blocks capacity pressure evicted back into the tier ahead of
           the round start.  Reads go through the backing store (real
           SNIC traffic, paid during the idle gap).  Serving has no wall
           clock, so "during the gap" degenerates to right-after-warm-up
           here — it repairs evictions other sessions inflicted earlier
           in the step; the simulator, which has a clock, additionally
           models the late-window timing (Sim._schedule_prefetch).
        """
        tid = sess.traj.tid
        tier = self.tiers[de_node]
        if uses_state_blob(self.cfg):
            return
        if sess.next_round >= sess.traj.n_rounds:
            # finished trajectory: never hit again (§A.4) — warming the
            # tier with it would only evict live sessions' prefixes
            for t in self.tiers.values():
                t.note_done(tid)
            return
        _, refs = self.trie.match(sess.context)
        # tail-first: keeps the leading blocks most recent, so LRU
        # eviction trims the tail and the servable prefix survives
        for r in reversed(refs):
            tier.admit(r, self.layout.full_block_bytes, owner=tid,
                       payload=self.store.peek(r))
        if self.prefetcher is not None:
            for chunk in self.prefetcher.plan(tier, refs):
                for r in chunk:
                    tier.prefetch_block(r, owner=tid)

    # ------------------------------------------------------------------
    def run_offline(self, trajectories: List[Trajectory],
                    max_iters: int = 100000) -> List[AgentSession]:
        sessions = [AgentSession(t, np.random.default_rng(1000 + t.tid))
                    for t in trajectories]
        for s in sessions:
            self._submit_round(s)
        for _ in range(max_iters):
            if all(s.done() for s in sessions):
                break
            self._schedule()
            self._step_engines()
        else:
            raise RuntimeError("serving system did not converge")
        return sessions

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        tiers = list(self.tiers.values())
        return dict(
            store_reads=self.store.bytes_read,
            store_writes=self.store.bytes_written,
            read_bytes_pe_side=self.read_bytes_by_side["pe"],
            read_bytes_de_side=self.read_bytes_by_side["de"],
            split_reads=self.n_split_reads,
            trie_blocks=self.trie.n_blocks,
            prefill_tokens=sum(p.prefill_tokens for p in self.pes.values()),
            decode_steps=sum(d.decode_steps for d in self.des.values()),
            # --- DRAM tier (zeros when disabled) -----------------------
            dram_hit_bytes=sum(t.dram_hit_bytes for t in tiers),
            dram_bytes_pe_side=self.dram_bytes_by_side["pe"],
            dram_bytes_de_side=self.dram_bytes_by_side["de"],
            tier_miss_bytes=sum(t.miss_bytes for t in tiers),
            tier_prefetch_bytes=sum(t.prefetch_bytes for t in tiers),
            tier_evicted_bytes=sum(t.evicted_bytes for t in tiers),
        )
