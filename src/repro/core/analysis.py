"""Bottleneck-free traffic analysis (paper §4.2, Eq. 1–9).

Closed-form per-link traffic of the dual-path loading scheme, used
(a) to validate deployments (is this P/D ratio safe?), (b) by the
elastic re-configuration logic to pick a new P/D split after node
failures, and (c) as the ground truth the discrete-event simulator is
property-tested against (simulated steady-state link utilisation must
match these expressions).

Notation mirrors the paper: P/D prefill/decode node counts, g engines
(accelerators) per node, each engine paired with a compute NIC of
bandwidth B; storage NIC bandwidth per node is s·B (shared); M is the
DRAM bandwidth per node.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple


@dataclass(frozen=True)
class ClusterSpec:
    g: int = 8             # engines per node
    B: float = 50e9        # compute-NIC bandwidth per engine [bytes/s]
    s: float = 1.0         # storage NIC bandwidth, in units of B, per node
    M: float = 500e9       # DRAM bandwidth per node [bytes/s]

    @property
    def snic_bw(self) -> float:
        return self.s * self.B


def pair_traffic(P: int, D: int, spec: ClusterSpec) -> Tuple[float, float]:
    """(T_p, T_c): per-(PE,DE)-pair traffic of the PE-read and DE-read
    paths when all storage NICs are saturated and load is balanced."""
    g, B, s = spec.g, spec.B, spec.s
    T_p = B * s / (D * g * g)
    T_c = B * s / (P * g * g)
    return T_p, T_c


def link_utilisation(P: int, D: int, spec: ClusterSpec) -> Dict[str, float]:
    """Utilisation fraction (traffic / capacity) of every constrained
    resource, Eq. 1–8.  Values ≤ 1.0 mean bottleneck-free."""
    g, B, s, M = spec.g, spec.B, spec.s, spec.M
    T_p, T_c = pair_traffic(P, D, spec)
    util = {
        # Eq.1: PE CNIC read — PE paths (3) and (5)
        "pe_cnic_read": 2 * T_p * D * g / B,
        # Eq.2: PE CNIC write — PE path (4) + DE path (5)
        "pe_cnic_write": (T_p + T_c) * D * g / B,
        # Eq.4: DE CNIC read — PE path (8) + DE paths (3)/(6)
        "de_cnic_read": (T_p + 2 * T_c) * P * g / B,
        # Eq.6: DE CNIC write — PE paths (7)/(9) + DE path (7)
        "de_cnic_write": (2 * T_p + T_c) * P * g / B,
        # DRAM, half-duplex: sum of read+write pressure
        "pe_dram": 2 * s * B / M,
        "de_dram": (3 + 2 * P / D) * B * s / M,
    }
    return util


def link_utilisation_mix(P: int, D: int, spec: ClusterSpec,
                         phi: Optional[float] = None) -> Dict[str, float]:
    """Eq. 1–8 generalised to an arbitrary *read mix* φ — the fraction
    of hit bytes entering via PE-side storage NICs (split reads make φ
    a continuous knob instead of the per-request binary 'pe'|'de').

    Aggregate load bandwidth is L(φ) = min(P·sB/φ, D·sB/(1−φ)), i.e.
    whichever side's storage NICs saturate first; the maximiser
    φ* = P/(P+D) saturates both sides simultaneously and recovers the
    paper's L = (P+D)·sB.  Per-(PE,DE)-pair traffic follows as
    T_p(φ) = φ·L/(P·D·g²) and T_c(φ) = (1−φ)·L/(P·D·g²), and every
    Eq. 1–8 expression keeps its coefficient structure — at φ=φ* this
    function is exactly ``link_utilisation`` (property-tested).

    DRAM terms, derived from the plan legs (core/loading.py):
    per PE node 2·φL/P (storage-in + buf→HBM read); per DE node
    L(3−φ)/D (storage-in and stream-out of the DE share, write-in of
    the PE share, and the full de_buf→de_hbm pass every byte makes).
    """
    g, B, s, M = spec.g, spec.B, spec.s, spec.M
    if phi is None:
        phi = P / (P + D)
    if not 0.0 <= phi <= 1.0:
        raise ValueError(f"read mix phi must be in [0, 1], got {phi}")
    sides = []
    if phi > 0:
        sides.append(P * s * B / phi)
    if phi < 1:
        sides.append(D * s * B / (1 - phi))
    L = min(sides)
    T_p = phi * L / (P * D * g * g)
    T_c = (1 - phi) * L / (P * D * g * g)
    util = {
        "pe_cnic_read": 2 * T_p * D * g / B,
        "pe_cnic_write": (T_p + T_c) * D * g / B,
        "de_cnic_read": (T_p + 2 * T_c) * P * g / B,
        "de_cnic_write": (2 * T_p + T_c) * P * g / B,
        "pe_dram": 2 * phi * L / P / M,
        "de_dram": (3 - phi) * L / D / M,
    }
    return util


def bottleneck_free_range(spec: ClusterSpec) -> Tuple[float, float]:
    """Eq. 9: s/(g−s) ≤ P/D ≤ min{(g−2s)/s, (g−s)/2s, (M/Bs−3)/2}."""
    g, s = spec.g, spec.s
    lo = s / (g - s)
    hi = min((g - 2 * s) / s,
             (g - s) / (2 * s),
             (spec.M / (spec.B * spec.s) - 3) / 2)
    return lo, hi


def is_bottleneck_free(P: int, D: int, spec: ClusterSpec,
                       tol: float = 1e-9) -> Tuple[bool, str]:
    """Check a deployment; returns (ok, binding-constraint-name)."""
    util = link_utilisation(P, D, spec)
    worst = max(util, key=util.get)
    return util[worst] <= 1.0 + tol, worst


def max_aggregate_load_bw(P: int, D: int, spec: ClusterSpec,
                          dualpath: bool = True) -> float:
    """Aggregate KV-load bandwidth available to prefill.

    Basic systems read only via PE-side storage NICs; DualPath pools all
    nodes' storage NICs (§7.3's 'equivalent available storage bandwidth'
    observation: Basic 2P1D == DualPath 1P1D == 2 SNICs etc.)."""
    nodes = P if not dualpath else P + D
    return nodes * spec.snic_bw


def safe_pd_splits(n_nodes: int, spec: ClusterSpec):
    """All (P, D) integer splits of n_nodes inside the bottleneck-free
    range — the candidate set for elastic re-configuration after a node
    failure."""
    lo, hi = bottleneck_free_range(spec)
    out = []
    for P in range(1, n_nodes):
        D = n_nodes - P
        r = P / D
        if lo - 1e-12 <= r <= hi + 1e-12:
            out.append((P, D))
    return out
