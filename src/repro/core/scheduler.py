"""Adaptive request scheduler (paper §6.1).

Two-level: *inter-engine* scheduling assigns each request a (PE, DE)
pair and a KV read path; *intra-engine* scheduling (core/intra.py)
packs PE forward batches under a compute quota.

Faithful to the paper:

* **PE scheduling (Algorithm 1)** — FIFO queue; engines classified per
  fetch into C1 (overloaded: tok_e > β), C2 (short disk read queue:
  read_q ≤ α ∧ tok_e ≤ β), C3 (long read queue ∧ tok_e ≤ β).  Requests
  go to argmin-tok in C2, else C3, else the fetch terminates.  tok_e is
  updated after every assignment (and categories re-evaluated, since an
  assignment can push an engine over β).
* **DE scheduling phase 1 (across groups)** — a global queue drains into
  per-group private queues; each request goes to the group with minimum
  Σ tok_e.
* **DE scheduling phase 2 (within a group)** — bounded by aggregate free
  HBM (set R); threshold Z = 1.05·(Σ_{r∈R} len_r + Σ_e tok_e)/|E|;
  among DEs with enough HBM prefer the low-token class (tok_e+len ≤ Z)
  by min seq_e, else min tok_e in the high class; stop when no DE fits.
* **Read-path selection** — the side (PE node / DE node) with the
  shorter disk reading queue.  Splitting one request's read across both
  sides is the paper's named future work (§6.1); ``split_reads=True``
  implements it: the hit is partitioned by water-filling over the two
  sides' disk-queue depths (equalising both NICs' drain times) and the
  request carries ``(read_path, read_split)`` — the majority side and
  its fraction — which core/loading.py turns into a split plan whose
  storage legs occupy both ``snic`` resources concurrently.  Default
  off (beyond-paper option).

The same scheduler object drives both the discrete-event simulator and
the real JAX engines.
"""
from __future__ import annotations

import bisect
import itertools
from collections import deque
from dataclasses import dataclass
from typing import Deque, Dict, List, Optional, Sequence, Tuple

EngineId = Tuple[int, int]          # (node_id, local_rank)


@dataclass
class Request:
    rid: int
    cached_tokens: int              # KV-hit tokens (loaded, not computed)
    new_tokens: int                 # appended tokens (prefill compute)
    gen_tokens: int                 # expected generation length
    arrival: float = 0.0
    # SLO class (core/config.SloConfig): 'interactive' rounds overtake
    # 'batch' rounds in every class-aware queue order
    slo_class: str = "batch"
    # filled by the scheduler:
    pe: Optional[EngineId] = None
    de: Optional[EngineId] = None
    read_path: Optional[str] = None   # 'pe' | 'de'
    read_split: float = 1.0           # fraction read on `read_path` side
    # DRAM-tier serving (kvcache/tiers.py): ``dram_tokens`` hit tokens
    # are already resident in the ``dram_side`` node's DRAM tier and
    # never touch a storage NIC; ``snic_tokens`` is the explicit
    # per-side partition of the remaining (SNIC-served) hit tokens.
    dram_side: Optional[str] = None   # 'pe' | 'de'
    dram_tokens: int = 0
    snic_tokens: Optional[Dict[str, int]] = None

    @property
    def class_rank(self) -> int:
        """Priority rank: interactive (0) ahead of batch (1)."""
        return 0 if self.slo_class == "interactive" else 1

    @property
    def prompt_tokens(self) -> int:
        return self.cached_tokens + self.new_tokens

    @property
    def hbm_tokens(self) -> int:
        """KV residency a DE must reserve (prompt + generated)."""
        return self.prompt_tokens + self.gen_tokens

    @property
    def pe_read_frac(self) -> float:
        """Fraction of hit bytes entering via the PE side (tier + SNIC).

        Derived from (read_path, read_split): 1.0 for a pure PE read,
        0.0 for a pure DE read, in between for a split read.  With a
        DRAM-tier hit the explicit token partition is authoritative
        (no float-derived flooring can drift from it).  This is the
        single source of truth the scheduler's read_q accounting, the
        simulator's storage legs and the engines' block partition all
        derive from."""
        if self.snic_tokens is not None:
            if not self.cached_tokens:
                return 0.0
            pe_total = self.snic_tokens["pe"] + \
                (self.dram_tokens if self.dram_side == "pe" else 0)
            return pe_total / self.cached_tokens
        if self.read_path is None:
            return 0.0
        if self.read_path == "pe":
            return self.read_split
        return 1.0 - self.read_split

    def read_tokens_by_side(self) -> Dict[str, int]:
        """Hit tokens charged to each side's disk reading queue.

        Tier-served tokens never enter a reading queue, so with an
        explicit partition this is just the SNIC share per side.
        Otherwise PE gets floor(cached * pe_frac) and DE the remainder,
        so the two sides always sum to exactly ``cached_tokens``."""
        if self.snic_tokens is not None:
            return dict(self.snic_tokens)
        pe_t = int(self.cached_tokens * self.pe_read_frac)
        return {"pe": pe_t, "de": self.cached_tokens - pe_t}

    def hit_blocks_by_side(self, n_blocks: int) -> Dict[str, int]:
        """Block-granular realisation of the hit partition: the leading
        ``tier`` blocks come from the ``dram_side`` node's DRAM tier,
        the next ``pe`` blocks via the PE-side storage NIC, the rest
        via the DE side.  The single source both the engines' page
        split and the simulator's tier-admission sets derive from, so
        they can never disagree on which blocks entered where."""
        if n_blocks <= 0 or not self.cached_tokens:
            return {"tier": 0, "pe": 0, "de": max(n_blocks, 0)}
        # exact when cached_tokens == n_blocks * block_tokens, which both
        # callers guarantee (the sim floors cached_tokens to whole blocks
        # at submit; serving's trie hit is block-granular) — dram_tokens
        # is a whole-block prefix, so the division recovers its count
        k_tier = (self.dram_tokens * n_blocks) // self.cached_tokens
        tok = self.read_tokens_by_side()
        rem_blocks = n_blocks - k_tier
        rem_tok = tok["pe"] + tok["de"]
        k_pe = int(round(rem_blocks * tok["pe"] / rem_tok)) if rem_tok else 0
        return {"tier": k_tier, "pe": k_pe, "de": rem_blocks - k_pe}

    def hit_bytes_partition(self, kv_per_token: int) -> Optional[tuple]:
        """(pe_snic, de_snic, pe_tier, de_tier) hit bytes — the ``tier``
        argument of loading.plan_for.  None when no DRAM tier served
        this request (the read_split-derived partition applies)."""
        if self.snic_tokens is None:
            return None
        return (self.snic_tokens["pe"] * kv_per_token,
                self.snic_tokens["de"] * kv_per_token,
                (self.dram_tokens if self.dram_side == "pe" else 0)
                * kv_per_token,
                (self.dram_tokens if self.dram_side == "de" else 0)
                * kv_per_token)


@dataclass
class EngineState:
    """Scheduler-side view of one engine (refreshed by fetch reports)."""

    engine: EngineId
    node: int
    kind: str                       # 'pe' | 'de'
    group: int
    seq: int = 0                    # unfinished requests
    tok: int = 0                    # unfinished tokens
    read_q: int = 0                 # node disk reading queue (tokens)
    free_hbm_tokens: int = 0        # decode engines only
    # Elastic reconfiguration (core/autoscale.py): a draining engine is
    # excluded from every admission pool (PE classes, DE fits, phase-1
    # group sums) and read-path water-fills steer around it, but its
    # in-flight work keeps all its accounting until it completes — the
    # "stop admitting, finish in-flight" half of the drain protocol.
    draining: bool = False


@dataclass
class Assignment:
    request: Request
    engine: EngineId


class Scheduler:
    """Central request scheduler (one per deployment).

    ``alpha``: short-reading-queue threshold [tokens] — paper sets it to
    the tokens readable in 3 s at storage bandwidth.
    ``beta``: unfinished-token upper limit [tokens] — tokens one engine
    processes in 5 s.  Both profiled in advance (§A.4).
    """

    #: optional flight recorder (repro.obs.Tracer), attached by the
    #: owning runtime; None = untraced, every hook is a no-op branch
    tracer = None

    def __init__(self, alpha: int, beta: int, *, z_factor: float = 1.05,
                 split_reads: bool = False, class_aware: bool = False):
        self.alpha = alpha
        self.beta = beta
        self.z_factor = z_factor
        self.split_reads = split_reads
        # SLO-class-differentiated scheduling (core/config.SloConfig):
        # when set, every queue order becomes (class rank, arrival, rid)
        # so interactive rounds overtake batch rounds at submission, in
        # DE phase-1 routing and in drain/recovery re-sorts.  Off (the
        # default) the rank term is a constant 0 and every order reduces
        # to the legacy (arrival, rid) — structurally identical queues.
        self.class_aware = class_aware
        # read-path tie-breaker state (see _shorter_queue_side): False so
        # the first tie goes to the PE side
        self._tie_toggle = False
        self.engines: Dict[EngineId, EngineState] = {}
        self.pe_queue: Deque[Request] = deque()
        self.de_global_queue: Deque[Request] = deque()
        self.de_private: Dict[int, Deque[Request]] = {}
        self._groups: Dict[int, List[EngineId]] = {}

    # ------------------------------------------------------------------
    # registry / submission
    # ------------------------------------------------------------------
    def register_engine(self, engine: EngineId, *, node: int, kind: str,
                        group: int) -> EngineState:
        st = EngineState(engine=engine, node=node, kind=kind, group=group)
        self.engines[engine] = st
        self._groups.setdefault(group, []).append(engine)
        if kind == "de":
            self.de_private.setdefault(group, deque())
        return st

    def groups(self, kind: str) -> Dict[int, List[EngineId]]:
        return {g: es for g, es in self._groups.items()
                if es and self.engines[es[0]].kind == kind}

    def _order_key(self, r: Request):
        """The queue order: (class rank, arrival, rid) when class-aware,
        degenerating to (0, arrival, rid) == submission order otherwise."""
        return (r.class_rank if self.class_aware else 0, r.arrival, r.rid)

    def _priority_insert(self, q: Deque[Request], req: Request):
        """Stable insert before the first lower-priority queued request
        (FIFO within a class).  Arrivals come in time order, so the scan
        from the right is O(number of lower-priority requests)."""
        k = self._order_key(req)
        idx = len(q)
        while idx > 0 and self._order_key(q[idx - 1]) > k:
            idx -= 1
        q.insert(idx, req)

    def submit(self, req: Request):
        if not self.class_aware:
            self.pe_queue.append(req)
            self.de_global_queue.append(req)
            return
        self._priority_insert(self.pe_queue, req)
        self._priority_insert(self.de_global_queue, req)

    # ------------------------------------------------------------------
    # elastic role reconfiguration (core/autoscale.py drives this)
    # ------------------------------------------------------------------
    def begin_drain(self, engine: EngineId) -> EngineState:
        """Stop admitting to ``engine``.  In-flight work is untouched —
        its seq/tok/read_q accounting drains through the normal
        completion hooks.  If this empties a DE group's admitting set,
        the group's private queue is pushed back onto the global queue
        (order-preserving) so phase 1 re-routes those requests to groups
        that can still take them."""
        st = self.engines[engine]
        if st.draining:
            return st
        st.draining = True
        if st.kind == "de":
            members = [self.engines[e] for e in self._groups[st.group]]
            if all(m.draining for m in members):
                q = self.de_private.get(st.group)
                while q:
                    self.de_global_queue.appendleft(q.pop())
        return st

    def can_finish_drain(self, engine: EngineId) -> bool:
        """True once the draining engine's request-level in-flight state
        has emptied (no unfinished requests, no unfinished tokens).
        ``read_q`` is deliberately NOT part of the gate: it tracks the
        *node's* disk reading queue, which other engines on the node
        (and the flip's own weight reload) keep busy — a request's read
        always completes before its prefill, so ``tok == 0`` already
        implies this engine's own reads are done."""
        st = self.engines[engine]
        return st.draining and st.seq == 0 and st.tok == 0

    def finish_drain(self, engine: EngineId, *, kind: str, group: int,
                     free_hbm_tokens: int = 0) -> EngineState:
        """Flip the drained engine's role: remove it from its old group
        (dropping the group when it empties) and re-register it under
        ``kind``/``group``.  A PE->DE->PE round trip through
        begin/finish restores the original scheduler state exactly
        (pinned by tests/test_autoscale.py)."""
        st = self.engines[engine]
        assert st.draining, f"{engine} was not draining"
        assert st.seq == 0 and st.tok == 0, \
            f"{engine} still has in-flight work"
        old = self._groups[st.group]
        old.remove(engine)
        if not old:
            del self._groups[st.group]
            q = self.de_private.pop(st.group, None)
            assert not q, f"drained group {st.group} still had queued work"
        st.kind = kind
        st.group = group
        st.draining = False
        # every charge this engine's own requests made has been released
        # (reads complete before prefill, and seq == 0); anything left is
        # a stale node-backlog report from the old role — drop it, the
        # next fetch's report refreshes the live value
        st.read_q = 0
        st.free_hbm_tokens = free_hbm_tokens if kind == "de" else 0
        # keep group member order = engine-id order (how register_engine
        # builds groups), so min()-tie-breaking priority is restored by
        # a round trip instead of depending on flip history
        members = self._groups.setdefault(group, [])
        bisect.insort(members, engine)
        if kind == "de":
            self.de_private.setdefault(group, deque())
        return st

    def admitting(self, kind: str) -> List[EngineState]:
        """Engines of ``kind`` still accepting work (the controller's
        n_pe/n_de and the drain victim-candidate set)."""
        return [st for st in self.engines.values()
                if st.kind == kind and not st.draining]

    def requeue_unstarted(self, engine: EngineId, requests):
        """Drain-protocol step: hand back ``engine``'s assigned requests
        whose KV read has not begun (``read_path is None``).  Nothing
        has physically happened for them on this engine — no read, no
        compute, no transfer — so reassignment is free, and without it
        a drain is hostage to requests blocked on the *other* role
        (e.g. a PE waiting on a request that cannot start reading until
        a DE grants it HBM).  ``requests`` is the runtime's in-flight
        request set; returns the requests given back, so the runtime
        can mirror the reservation release (sim ``resident_tokens``)."""
        st = self.engines[engine]
        back: List[Request] = []
        for req in requests:
            if req.read_path is not None:
                continue
            if st.kind == "pe" and req.pe == engine:
                req.pe = None
            elif st.kind == "de" and req.de == engine:
                req.de = None
                st.free_hbm_tokens += req.hbm_tokens
            else:
                continue
            st.seq = max(0, st.seq - 1)
            st.tok = max(0, st.tok - req.prompt_tokens)
            back.append(req)
        if back:
            # an assigned request is no longer in its queue (popped at
            # assignment), so concatenate-and-sort restores submission
            # order without duplicates
            if st.kind == "pe":
                self.pe_queue = deque(sorted(
                    list(self.pe_queue) + back, key=self._order_key))
            else:
                self.de_global_queue = deque(sorted(
                    list(self.de_global_queue) + back,
                    key=self._order_key))
        return back

    def rebalance_de_private(self):
        """Pull every un-assigned request out of the per-group private
        queues back into the global queue (submission order), so the
        next ``de_phase1`` re-routes them against the *current* group
        topology.  Called after a role flip adds or removes a DE group —
        without it, requests parked in an old group's private queue
        would never reach a group that did not exist when phase 1 first
        routed them."""
        pend = list(self.de_global_queue)
        for q in self.de_private.values():
            while q:
                pend.append(q.popleft())
        pend.sort(key=self._order_key)
        self.de_global_queue = deque(pend)

    # ------------------------------------------------------------------
    # PE scheduling — Algorithm 1
    # ------------------------------------------------------------------
    def _classify_pe(self, engines: Sequence[EngineState]):
        c2 = [e for e in engines if not e.draining
              and e.read_q <= self.alpha and e.tok <= self.beta]
        c3 = [e for e in engines if not e.draining
              and e.read_q > self.alpha and e.tok <= self.beta]
        return c2, c3

    def on_pe_fetch(self, group: int,
                    reports: Optional[Dict[EngineId, Tuple[int, int, int]]] = None
                    ) -> List[Assignment]:
        """Leader-engine fetch for a PE group.  ``reports`` optionally
        refreshes (seq, tok, read_q) per engine."""
        members = [self.engines[e] for e in self._groups[group]]
        self._apply_reports(members, reports)
        out: List[Assignment] = []
        while self.pe_queue:
            c2, c3 = self._classify_pe(members)
            pool = c2 if c2 else c3
            if not pool:
                break                       # terminate fetch (Alg.1)
            req = self.pe_queue.popleft()
            pe = min(pool, key=lambda e: e.tok)
            req.pe = pe.engine
            pe.tok += req.prompt_tokens
            pe.seq += 1
            out.append(Assignment(req, pe.engine))
        return out

    # ------------------------------------------------------------------
    # DE scheduling
    # ------------------------------------------------------------------
    def de_phase1(self):
        """Drain the global DE queue into per-group private queues
        (group with minimum Σ tok_e wins each request)."""
        if not self.de_global_queue:
            # nothing to drain: skip the O(engines) group scan below —
            # phase 1 runs on *every* DE fetch, so at fleet scale this
            # scan is the difference between O(E) and O(E^2) per
            # scheduler tick.  With an empty global queue the body is a
            # structural no-op (gtok is built and discarded untouched).
            return
        # groups whose every member is draining cannot admit: requests
        # routed there would be stranded until the flip.  One fused pass
        # (inlining groups("de")) instead of three generator sweeps —
        # this runs on every DE fetch and dominated fleet-scale ticks.
        eng = self.engines
        gtok = {}
        for g, es in self._groups.items():
            if not es or eng[es[0]].kind != "de":
                continue
            tot = 0
            admits = False
            for e in es:
                st = eng[e]
                tot += st.tok
                if not st.draining:
                    admits = True
            if admits:
                gtok[g] = tot
        if not gtok:
            return
        while self.de_global_queue:
            req = self.de_global_queue.popleft()
            g = min(gtok, key=gtok.get)
            self.de_private[g].append(req)
            gtok[g] += req.prompt_tokens

    def on_de_fetch(self, group: int,
                    reports: Optional[Dict[EngineId, Tuple[int, int, int, int]]] = None
                    ) -> List[Assignment]:
        """Two-phase DE scheduling; phase 1 runs lazily on every fetch."""
        self.de_phase1()
        members = [self.engines[e] for e in self._groups[group]]
        self._apply_reports(members, reports)
        queue = self.de_private[group]
        free = {e.engine: e.free_hbm_tokens for e in members}

        # R: FIFO prefix fitting aggregate free HBM (no-fragmentation bound)
        total_free = sum(free.values())
        acc, R_len = 0, []
        for r in queue:
            if acc + r.hbm_tokens > total_free:
                break
            acc += r.hbm_tokens
            R_len.append(r.prompt_tokens)
        n_engines = max(len(members), 1)
        Z = self.z_factor * ((sum(R_len) +
                              sum(e.tok for e in members)) / n_engines)

        out: List[Assignment] = []
        while queue:
            req = queue[0]
            fits = [e for e in members
                    if not e.draining and free[e.engine] >= req.hbm_tokens]
            if not fits:
                break
            low = [e for e in fits if e.tok + req.prompt_tokens <= Z]
            if low:
                de = min(low, key=lambda e: e.seq)
            else:
                de = min(fits, key=lambda e: e.tok)
            queue.popleft()
            req.de = de.engine
            de.tok += req.prompt_tokens
            de.seq += 1
            free[de.engine] -= req.hbm_tokens
            de.free_hbm_tokens = free[de.engine]
            out.append(Assignment(req, de.engine))
        return out

    # ------------------------------------------------------------------
    # read-path selection (§6.1 "KV-Cache Read Task Scheduling")
    # ------------------------------------------------------------------
    def _water_fill_frac(self, pe_q: int, de_q: int, h: int) -> float:
        """PE share x of ``h`` tokens equalising both sides' queue drain
        times — pe_q + x·h = de_q + (1−x)·h, clamped to [0, 1]: with
        equal NIC bandwidth the read finishes when the slower side
        drains, so this is the unique split minimising the request's own
        read completion time."""
        return min(1.0, max(0.0, (de_q - pe_q + h) / (2.0 * h)))

    def _shorter_queue_side(self, pe_q: int, de_q: int) -> str:
        if pe_q == de_q:
            # ties are frequent between queue build-ups; a fixed
            # preference systematically overloads one side (measured
            # Max/Avg 1.71 vs 1.49 RR) — alternate instead
            self._tie_toggle = not self._tie_toggle
            return "pe" if self._tie_toggle else "de"
        return "pe" if pe_q < de_q else "de"

    def _finalise_partition(self, req: Request, side: str, t: int,
                            snic: Dict[str, int]) -> str:
        """Install a tier/SNIC hit partition on the request, derive the
        (read_path, read_split) majority view plan_for consumes, and
        charge both sides' disk reading queues their SNIC share.  Shared
        by the adaptive and round-robin schedulers so the tier-aware
        accounting cannot diverge between them."""
        req.dram_side, req.dram_tokens = side, t
        req.snic_tokens = snic
        pe_total = snic["pe"] + (t if side == "pe" else 0)
        de_total = snic["de"] + (t if side == "de" else 0)
        if pe_total == de_total:
            req.read_path = side
        else:
            req.read_path = "pe" if pe_total > de_total else "de"
        major = pe_total if req.read_path == "pe" else de_total
        req.read_split = major / req.cached_tokens
        self.engines[req.pe].read_q += snic["pe"]
        self.engines[req.de].read_q += snic["de"]
        if self.tracer is not None:
            self.tracer.event("sched", "read_path", rid=req.rid,
                              path=req.read_path, split=req.read_split,
                              tier_side=side, tier_tokens=t,
                              pe_tokens=snic["pe"],
                              de_tokens=snic["de"])
        return req.read_path

    def choose_read_path(self, req: Request,
                         tier_tokens: Optional[Dict[str, int]] = None,
                         net_congestion: float = 0.0) -> str:
        """``net_congestion`` ∈ [0, 1] is the compute network's
        back-pressure signal (repro.network.SharedLink.congestion): only
        DE-side reads cross the PE<->DE link (Fig. 4b streams
        storage→DE buffer→network→PE HBM), so a congested link inflates
        the DE side's effective queue depth by ``congestion · hit`` in
        the water-fill / shorter-queue comparison, shifting read
        fractions toward the PE side until the collectives drain."""
        assert req.pe is not None and req.de is not None, req.rid
        pe_q = self.engines[req.pe].read_q
        de_q = self.engines[req.de].read_q
        # A draining side must empty, not refill: inflate its effective
        # queue depth by the whole hit so the water-fill (and the
        # shorter-queue choice) steers this read to the surviving side.
        # Same mechanism as the congestion bias, so role flips cannot
        # thrash the split-read partition — the drain looks like one
        # more pressure signal, absorbed by the same arithmetic.
        # No-op while nothing drains (elastic off: bit-identical).
        if self.engines[req.pe].draining:
            pe_q += req.cached_tokens
        if self.engines[req.de].draining:
            de_q += req.cached_tokens
        if tier_tokens and req.cached_tokens:
            t_pe = min(tier_tokens.get("pe", 0), req.cached_tokens)
            t_de = min(tier_tokens.get("de", 0), req.cached_tokens)
        else:
            t_pe = t_de = 0
        if t_pe or t_de:
            # Tier-aware selection: prefer the side whose DRAM tier
            # already holds (a prefix of) the hit — those tokens skip
            # the storage NIC entirely.  The cold remainder is routed by
            # disk-queue depth exactly like a tier-less read (a small
            # warm prefix must not drag the whole cold read onto a
            # backlogged NIC): shorter queue wins, or water-filled
            # across both SNICs when split_reads is on.
            if t_pe > t_de:
                side, t = "pe", t_pe
            elif t_de > t_pe:
                side, t = "de", t_de
            else:
                # equal prefixes: shorter queue wins, full ties alternate
                # (a fixed preference would bias one side — see
                # _shorter_queue_side)
                side, t = self._shorter_queue_side(pe_q, de_q), t_pe
            rem = req.cached_tokens - t
            snic = {"pe": 0, "de": 0}
            if rem:
                bias = int(net_congestion * rem)
                if self.split_reads:
                    frac_pe = self._water_fill_frac(pe_q, de_q + bias, rem)
                    snic["pe"] = int(rem * frac_pe)
                    snic["de"] = rem - snic["pe"]
                else:
                    snic[self._shorter_queue_side(pe_q, de_q + bias)] = rem
            return self._finalise_partition(req, side, t, snic)
        if self.split_reads and req.cached_tokens:
            # Split read (§6.1 future work): partition the hit across
            # both sides' storage NICs in proportion to their disk-queue
            # depths (water-filling, see _water_fill_frac).
            bias = int(net_congestion * req.cached_tokens)
            frac_pe = self._water_fill_frac(pe_q, de_q + bias,
                                            req.cached_tokens)
            req.read_path = "pe" if frac_pe >= 0.5 else "de"
            req.read_split = max(frac_pe, 1.0 - frac_pe)
        else:
            bias = int(net_congestion * req.cached_tokens)
            req.read_path = self._shorter_queue_side(pe_q, de_q + bias)
            req.read_split = 1.0
        tokens = req.read_tokens_by_side()
        self.engines[req.pe].read_q += tokens["pe"]
        self.engines[req.de].read_q += tokens["de"]
        if self.tracer is not None:
            self.tracer.event("sched", "read_path", rid=req.rid,
                              path=req.read_path, split=req.read_split,
                              tier_side="", tier_tokens=0,
                              pe_tokens=tokens["pe"],
                              de_tokens=tokens["de"])
        return req.read_path

    # ------------------------------------------------------------------
    # hedged split reads (fault tolerance — sim/faults.py)
    # ------------------------------------------------------------------
    def rebalance_remainder(self, req: Request, from_side: str,
                            remaining_tokens: int, severity: float,
                            healthy_backlog_tokens: int = 0) -> int:
        """Mid-read hedge: one side's in-flight read leg has straggled
        (service-time ratio ``severity`` >= 1 vs the healthy side) with
        ``remaining_tokens`` of its SNIC share still unserved; move the
        water-filled portion of that remainder to the healthy side.

        This is ``choose_read_path`` re-run over the *remainder*: the
        moved share is ``loading.hedge_water_fill`` (equalise both
        sides' completion given the healthy side's current backlog),
        applied through an explicit token partition so bytes already
        served stay charged where they were served.  Accounting moved
        atomically with the partition:

        * ``req.snic_tokens`` becomes explicit (conserving the per-side
          sum exactly — only SNIC tokens move, tier tokens never);
        * the disk reading queues transfer exactly the moved charge
          (``from`` releases, ``to`` acquires), so the later
          ``on_read_done`` calls — which release each side's *current*
          share — balance to zero;
        * the (read_path, read_split) majority view is re-derived for
          ``plan_for``.

        Returns the moved token count (0 = no hedge; the caller skips
        the physical re-enqueue).
        """
        assert from_side in ("pe", "de"), from_side
        to_side = "de" if from_side == "pe" else "pe"
        tokens = req.read_tokens_by_side()
        # never move bytes that were not going to a SNIC: the remainder
        # is capped by the straggling side's SNIC share (tier-hit tokens
        # are not in `tokens` at all, so they cannot be re-charged)
        rem = max(0, min(int(remaining_tokens), tokens[from_side]))
        from repro.core.loading import hedge_water_fill
        moved = hedge_water_fill(rem, max(severity, 1.0),
                                 max(int(healthy_backlog_tokens), 0))
        if moved <= 0:
            return 0
        snic = {from_side: tokens[from_side] - moved,
                to_side: tokens[to_side] + moved}
        req.snic_tokens = snic
        # dram_side/dram_tokens untouched: tier hits stay tier hits
        from_eng = req.pe if from_side == "pe" else req.de
        to_eng = req.pe if to_side == "pe" else req.de
        st_from = self.engines.get(from_eng)
        if st_from is not None:
            st_from.read_q = max(0, st_from.read_q - moved)
        st_to = self.engines.get(to_eng)
        if st_to is not None:
            st_to.read_q += moved
        # re-derive the majority view (same arithmetic as
        # _finalise_partition, without re-charging the queues)
        t = req.dram_tokens
        pe_total = snic["pe"] + (t if req.dram_side == "pe" else 0)
        de_total = snic["de"] + (t if req.dram_side == "de" else 0)
        if pe_total != de_total:
            req.read_path = "pe" if pe_total > de_total else "de"
        elif req.read_path not in ("pe", "de"):
            req.read_path = to_side
        major = pe_total if req.read_path == "pe" else de_total
        if req.cached_tokens:
            req.read_split = major / req.cached_tokens
        if self.tracer is not None:
            # one event per hedge that actually moved tokens — the
            # trace audit pins count/sum against hedged_reads /
            # hedge_moved_tokens in BOTH runtimes
            self.tracer.event("sched", "hedge", rid=req.rid,
                              from_side=from_side,
                              moved_tokens=moved)
        return moved

    # ------------------------------------------------------------------
    # engine failure (fail-stop — sim/faults.py)
    # ------------------------------------------------------------------
    def fail_engine(self, engine: EngineId) -> EngineState:
        """Involuntary, immediate removal — the fail-stop analogue of
        the begin_drain/finish_drain pair.  The engine stops admitting
        NOW, its outstanding charges are forfeited (the runtime re-homes
        the affected requests; the tolerant completion hooks below
        swallow their late releases), and it leaves the registry so
        nothing routes to it.  If this empties a DE group's admitting
        set the private queue is pushed back for phase-1 re-routing,
        exactly like a drain."""
        st = self.engines[engine]
        if not st.draining:
            self.begin_drain(engine)       # reuse the queue-handback path
        grp = self._groups[st.group]
        grp.remove(engine)
        if not grp:
            del self._groups[st.group]
            q = self.de_private.pop(st.group, None)
            if q:
                # orphaned private queue: back to global for re-routing
                pend = sorted(list(self.de_global_queue) + list(q),
                              key=self._order_key)
                self.de_global_queue = deque(pend)
        del self.engines[engine]
        return st

    # ------------------------------------------------------------------
    # completion / accounting hooks (engines & simulator call these)
    # ------------------------------------------------------------------
    def on_read_done(self, engine: EngineId, tokens: int):
        st = self.engines.get(engine)
        if st is None:                 # engine failed: charge forfeited
            return
        st.read_q = max(0, st.read_q - tokens)

    def on_request_done(self, engine: EngineId, req: Request):
        st = self.engines.get(engine)
        if st is None:                 # engine failed: charge forfeited
            return
        st.seq = max(0, st.seq - 1)
        st.tok = max(0, st.tok - req.prompt_tokens)
        if st.kind == "de":
            st.free_hbm_tokens += req.hbm_tokens

    # ------------------------------------------------------------------
    def _apply_reports(self, members, reports):
        if not reports:
            return
        for st in members:
            if st.engine in reports:
                vals = reports[st.engine]
                st.seq, st.tok, st.read_q = vals[0], vals[1], vals[2]
                if len(vals) > 3:
                    st.free_hbm_tokens = vals[3]


class RoundRobinScheduler(Scheduler):
    """Baseline for the Fig. 13 load-balance comparison: round-robin
    engine assignment, alternating read path (ignores queues/load)."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self._rr_pe = itertools.count()
        self._rr_de = itertools.count()
        self._rr_path = itertools.count()

    def on_pe_fetch(self, group, reports=None):
        members = [self.engines[e] for e in self._groups[group]]
        self._apply_reports(members, reports)
        # the drain protocol's never-admit invariant holds for every
        # scheduling policy: draining engines leave the rotation
        members = [e for e in members if not e.draining]
        out = []
        while self.pe_queue and members:
            req = self.pe_queue.popleft()
            pe = members[next(self._rr_pe) % len(members)]
            req.pe = pe.engine
            pe.tok += req.prompt_tokens
            pe.seq += 1
            out.append(Assignment(req, pe.engine))
        return out

    def on_de_fetch(self, group, reports=None):
        self.de_phase1()
        members = [self.engines[e] for e in self._groups[group]]
        self._apply_reports(members, reports)
        queue = self.de_private[group]
        out = []
        while queue:
            req = queue[0]
            fits = [e for e in members
                    if not e.draining and e.free_hbm_tokens >= req.hbm_tokens]
            if not fits:
                break
            de = fits[next(self._rr_de) % len(fits)]
            queue.popleft()
            req.de = de.engine
            de.tok += req.prompt_tokens
            de.seq += 1
            de.free_hbm_tokens -= req.hbm_tokens
            out.append(Assignment(req, de.engine))
        return out

    def choose_read_path(self, req: Request, tier_tokens=None,
                         net_congestion: float = 0.0) -> str:
        """Tier-aware like the base class — a DRAM-resident prefix skips
        the storage NIC regardless of scheduling policy, so ignoring it
        would make the RR baseline artificially storage-bound on tiered
        workloads — but the cold remainder keeps the round-robin
        alternation (no queue depths, no congestion signal), which is
        the property the Fig. 13 comparison isolates."""
        if tier_tokens and req.cached_tokens:
            t_pe = min(tier_tokens.get("pe", 0), req.cached_tokens)
            t_de = min(tier_tokens.get("de", 0), req.cached_tokens)
        else:
            t_pe = t_de = 0
        if t_pe or t_de:
            # one draw per request: drawing again for the remainder
            # would consume two counter values and freeze the parity,
            # so the "alternation" would never alternate
            flip = next(self._rr_path) % 2 == 0
            if t_pe > t_de:
                side, t = "pe", t_pe
            elif t_de > t_pe:
                side, t = "de", t_de
            else:   # equal prefixes: alternate, like every other RR choice
                side, t = ("pe" if flip else "de"), t_pe
            rem = req.cached_tokens - t
            snic = {"pe": 0, "de": 0}
            if rem:
                snic["pe" if flip else "de"] = rem
            return self._finalise_partition(req, side, t, snic)
        req.read_path = "pe" if next(self._rr_path) % 2 == 0 else "de"
        req.read_split = 1.0
        side = self.engines[req.pe if req.read_path == "pe" else req.de]
        side.read_q += req.cached_tokens
        return req.read_path


def water_fill_frac_batch(pe_q, de_q, h):
    """:meth:`Scheduler._water_fill_frac` over request arrays.

    Same expression, same IEEE doubles — ``clip((de_q - pe_q + h) /
    (2h), 0, 1)`` elementwise equals the scalar min/max chain bit-for-
    bit (property-tested in tests/test_vectorized.py).  ``h`` must be
    positive, as in the scalar path."""
    import numpy as np
    pe_q = np.asarray(pe_q, dtype=np.float64)
    de_q = np.asarray(de_q, dtype=np.float64)
    h = np.asarray(h, dtype=np.float64)
    return np.clip((de_q - pe_q + h) / (2.0 * h), 0.0, 1.0)
