"""CNIC-centric traffic manager (paper §5).

The paper's mechanism: *every* byte in or out of an accelerator —
including local host↔device copies — is carried by the engine's paired
compute NIC (GPUDirect-RDMA loopback), making the NIC's virtual-lane
arbiter the single QoS scheduler for all PCIe traffic.  Model-execution
collectives ride a high-priority VL with ~99 % of arbitration weight;
KV-cache transfers ride a low-priority VL with a starvation floor.

TPU adaptation (DESIGN.md §2): ICI collectives are hardware-isolated
from host DMA, so the loopback *mechanism* is unnecessary — but the
*policy* (single arbiter, strict priority, batched submission) is kept:
it is what the simulator models and what the engine runtime enforces
for its host-side transfer queues.

This module is runtime-agnostic: the discrete-event simulator uses the
arbiter math (``allocate_bandwidth``) for link sharing, and the engines
use :class:`TrafficManager` to order/batch real (CPU) transfers.
"""
from __future__ import annotations

import heapq
import itertools
from collections import deque
from dataclasses import dataclass, field
from enum import IntEnum
from typing import Callable, Deque, Dict, List, Optional, Tuple


class TrafficClass(IntEnum):
    MODEL_COLLECTIVE = 0      # EP AllToAll, TP ReduceScatter/AllGather, PD KV handoff
    KV_TRANSFER = 1           # dual-path loading, H2D/D2H, storage persists
    BULK = 2                  # checkpoints, dataset reads


@dataclass(frozen=True)
class VLArbiterConfig:
    """InfiniBand-style two-arbiter WRR (paper §A.1 values).

    ``high_weights``/``low_weights``: VL -> WRR weight in the
    high/low-priority arbiter.  ``high_limit=240`` (of 255) ≈ 99 % of
    bandwidth reserved for the high-priority arbiter before the low one
    is consulted; the low-priority table keeps a small weight for the KV
    VL so it never starves.
    """

    n_vls: int = 4
    high_limit: int = 240
    high_weights: Tuple[int, ...] = (192, 192, 0, 192)
    low_weights: Tuple[int, ...] = (192, 192, 64, 192)
    class_to_vl: Tuple[int, ...] = (0, 2, 2)   # TrafficClass -> VL

    def high_fraction(self) -> float:
        """Fraction of link bandwidth the high-priority arbiter owns when
        both arbiters have backlogged traffic."""
        return self.high_limit / 255.0 + (1 - self.high_limit / 255.0) * (
            sum(w for v, w in enumerate(self.low_weights)
                if self.high_weights[v] > 0) /
            max(sum(self.low_weights), 1))


DEFAULT_ARBITER = VLArbiterConfig()


def allocate_bandwidth(active: Dict[TrafficClass, int], link_bw: float,
                       arb: VLArbiterConfig = DEFAULT_ARBITER
                       ) -> Dict[TrafficClass, float]:
    """Share ``link_bw`` among active flows per the VL arbiter.

    ``active``: number of backlogged flows per class.  Classes mapped to
    a high-arbiter VL split the high fraction; low-VL classes share the
    remainder (plus everything when no high traffic is active).  Within
    a class, flows share equally (fair queuing approximation).
    """
    hi_classes = [c for c, n in active.items()
                  if n > 0 and arb.high_weights[arb.class_to_vl[c]] > 0]
    lo_classes = [c for c, n in active.items()
                  if n > 0 and arb.high_weights[arb.class_to_vl[c]] == 0]
    out: Dict[TrafficClass, float] = {c: 0.0 for c in active}
    if hi_classes and lo_classes:
        hf = arb.high_fraction()
        hi_bw, lo_bw = link_bw * hf, link_bw * (1 - hf)
    elif hi_classes:
        hi_bw, lo_bw = link_bw, 0.0
    else:
        hi_bw, lo_bw = 0.0, link_bw
    for pool_bw, classes in ((hi_bw, hi_classes), (lo_bw, lo_classes)):
        if not classes:
            continue
        tot_w = sum(arb.low_weights[arb.class_to_vl[c]] or 1 for c in classes)
        for c in classes:
            w = arb.low_weights[arb.class_to_vl[c]] or 1
            out[c] = pool_bw * w / tot_w
    return out


# ---------------------------------------------------------------------------
# Submission cost model (§5.2): RDMA WR vs cudaMemcpyAsync, doorbell batching
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SubmitCostModel:
    rdma_wr_s: float = 1e-6          # one RDMA work request (mmio writes)
    rdma_doorbell_s: float = 0.3e-6  # one doorbell ring (amortisable)
    cuda_memcpy_s: float = 6e-6      # paper: 5–7 µs per cudaMemcpyAsync

    def rdma_batch_seconds(self, n: int) -> float:
        """Doorbell batching: n WRs posted, one doorbell."""
        return n * self.rdma_wr_s + self.rdma_doorbell_s

    def rdma_unbatched_seconds(self, n: int) -> float:
        return n * (self.rdma_wr_s + self.rdma_doorbell_s)

    def cuda_seconds(self, n: int) -> float:
        return n * self.cuda_memcpy_s


# ---------------------------------------------------------------------------
# Engine-side transfer manager
# ---------------------------------------------------------------------------


@dataclass(order=True)
class _QueuedTransfer:
    sort_key: Tuple[int, int] = field(compare=True)
    fn: Callable[[], None] = field(compare=False)
    nbytes: int = field(compare=False, default=0)
    tclass: TrafficClass = field(compare=False,
                                 default=TrafficClass.KV_TRANSFER)
    # completion obligations: one countdown per flush whose batch this
    # transfer appeared in (a congestion-deferred WR can belong to more
    # than one flush — each on_complete must still see it land)
    cbs: Optional[List[Callable[[], None]]] = field(compare=False,
                                                    default=None)


class TrafficManager:
    """Per-engine transfer orderer.

    Engines enqueue transfer thunks with a traffic class.  The lifecycle
    has two halves, mirroring an RDMA send queue:

    * ``flush()`` — the *issue* half: every queued WR is posted to the
      in-flight ring in VL-arbiter order (strict priority, FIFO within a
      class) and the doorbells are rung — KV WRs are batched per
      doorbell (``doorbell_batch``), which is where the §5.2 submission
      cost is charged.  Non-blocking: no thunk runs here.
    * ``poll()`` — the *completion* half: in-flight thunks execute in
      posted order and per-flush completion callbacks fire once every
      transfer of that flush has landed.

    ``drain()`` (= flush + poll until idle) is the blocking legacy API;
    the lock-step serving runtime still uses it, the pipelined runtime
    flushes at issue points and polls once per event-loop tick so
    storage reads and compute-network transfers stay in flight across
    engine ``step()`` compute.  On real hardware the thunks would be
    RDMA WR posts; on CPU they are the actual numpy/jax copies, so the
    ordering/batching logic is exercised end-to-end by the integration
    tests.
    """

    #: optional flight recorder (repro.obs.Tracer) + track label,
    #: attached by the owning runtime; None = untraced
    tracer = None
    track = "traffic"

    def __init__(self, cost: SubmitCostModel = SubmitCostModel(),
                 doorbell_batch: int = 32, pace_threshold: float = 0.5):
        self.cost = cost
        self.doorbell_batch = doorbell_batch
        self._q: List[_QueuedTransfer] = []
        self._inflight: Deque[_QueuedTransfer] = deque()
        self._seq = itertools.count()
        self.submitted_seconds = 0.0     # modelled submission overhead
        self.doorbells = 0
        self.stats = {c: 0 for c in TrafficClass}
        self.bytes = {c: 0 for c in TrafficClass}
        # --- compute-network back-pressure (repro.network) --------------
        # ``net_congestion`` ∈ [0, 1] is set by the runtime from the
        # shared link's congestion signal; at or above ``pace_threshold``
        # each flush posts collectives unconditionally but at most ONE
        # doorbell batch of low-priority WRs, deferring the rest — so a
        # collective submitted later still overtakes a backlog of KV WRs
        # and model execution never stalls behind cache movement.
        self.net_congestion = 0.0
        self.pace_threshold = pace_threshold
        self.paced_flushes = 0
        self.deferred_wrs = 0

    def submit(self, fn: Callable[[], None], nbytes: int,
               tclass: TrafficClass):
        heapq.heappush(self._q, _QueuedTransfer(
            (int(tclass != TrafficClass.MODEL_COLLECTIVE), next(self._seq)),
            fn, nbytes, tclass))
        self.stats[tclass] += 1
        self.bytes[tclass] += nbytes

    # -- issue half --------------------------------------------------------
    def flush(self, on_complete: Optional[Callable[[], None]] = None) -> int:
        """Post every queued WR (arbiter order) to the in-flight ring and
        ring the doorbells.  Non-blocking — thunks execute at ``poll``.
        ``on_complete`` fires once every transfer queued at THIS flush
        has executed (immediately when nothing was queued) — including
        WRs the KV pacing defers to a later flush.

        When ``net_congestion >= pace_threshold`` the flush is *paced*:
        collectives post unconditionally, low-priority WRs post at most
        one doorbell batch, and the remainder returns to the queue (in
        order, submission cost uncharged — it is charged when they are
        actually posted).  Returns the number of WRs posted."""
        batch: List[_QueuedTransfer] = []
        while self._q:
            batch.append(heapq.heappop(self._q))
        if not batch:
            if on_complete is not None:
                on_complete()
            return 0
        posted = batch
        deferred: List[_QueuedTransfer] = []
        if self.net_congestion >= self.pace_threshold:
            posted = []
            kv_budget = self.doorbell_batch
            for t in batch:
                if t.tclass == TrafficClass.MODEL_COLLECTIVE:
                    posted.append(t)
                elif kv_budget > 0:
                    posted.append(t)
                    kv_budget -= 1
                else:
                    deferred.append(t)
            if deferred:
                self.paced_flushes += 1
                self.deferred_wrs += len(deferred)
        kv_batch = 0
        for t in posted:
            if t.tclass == TrafficClass.MODEL_COLLECTIVE:
                self.submitted_seconds += self.cost.rdma_batch_seconds(1)
                self.doorbells += 1
            else:
                kv_batch += 1
                if kv_batch == self.doorbell_batch:
                    self.submitted_seconds += \
                        self.cost.rdma_batch_seconds(kv_batch)
                    self.doorbells += 1
                    kv_batch = 0
        if kv_batch:
            self.submitted_seconds += self.cost.rdma_batch_seconds(kv_batch)
            self.doorbells += 1
        if on_complete is not None:
            pending = [len(batch)]

            def countdown():
                pending[0] -= 1
                if pending[0] == 0:
                    on_complete()

            for t in batch:
                if t.cbs is None:
                    t.cbs = []
                t.cbs.append(countdown)
        self._inflight.extend(posted)
        for t in deferred:       # sort_key intact: order is preserved
            heapq.heappush(self._q, t)
        if self.tracer is not None:
            self.tracer.event(self.track, "flush", posted=len(posted),
                              deferred=len(deferred),
                              posted_bytes=sum(t.nbytes for t in posted))
        return len(posted)

    # -- completion half ---------------------------------------------------
    def poll(self, max_n: Optional[int] = None) -> int:
        """Execute up to ``max_n`` in-flight transfers (all if None) in
        posted order, firing completion callbacks; returns the count.
        Pop-based, so a callback that re-enters drain/poll cannot
        double-execute a transfer; a payload thunk that faults still
        completes exactly once (callbacks fire, the error propagates) —
        the CQE-reports-errors-exactly-once contract the fault-injection
        tests pin."""
        n = 0
        while self._inflight and (max_n is None or n < max_n):
            t = self._inflight.popleft()
            n += 1
            try:
                t.fn()
            finally:
                cbs, t.cbs = t.cbs, None
                for cb in cbs or ():
                    cb()
        if n and self.tracer is not None:
            self.tracer.event(self.track, "poll", completed=n)
        return n

    @property
    def queued(self) -> int:
        return len(self._q)

    @property
    def in_flight(self) -> int:
        return len(self._inflight)

    @property
    def busy(self) -> bool:
        return bool(self._q or self._inflight)

    def drain(self) -> int:
        """Blocking issue+complete: flush and poll until idle; returns
        the number of transfers executed."""
        n = 0
        while self._q or self._inflight:
            self.flush()
            n += self.poll()
        return n
