# The paper's primary contribution, as composable pieces:
#   blocks    — Layer/Full block layouts (§A.5)
#   analysis  — bottleneck-free traffic analysis (§4.2)
#   loading   — dual-path loading plans (§4.1, Fig. 4)
#   traffic   — CNIC-centric traffic manager / VL arbiter (§5)
#   scheduler — inter-engine scheduling (§6.1, Alg. 1)
#   intra     — compute-quota batch packing (§6.2)
#   autoscale — elastic PE<->DE role reconfiguration (abstract / §6)
from repro.core.analysis import (
    ClusterSpec,
    bottleneck_free_range,
    is_bottleneck_free,
    link_utilisation,
    max_aggregate_load_bw,
    pair_traffic,
    safe_pd_splits,
)
from repro.core.autoscale import (
    DE_TO_PE,
    PE_TO_DE,
    DrainRecord,
    DrainTracker,
    LoadSignals,
    PDController,
    pick_victim,
)
from repro.core.blocks import BlockLayout, layout_for
from repro.core.intra import AttnTimeModel, BatchItem, PrefillWork, QuotaPacker
from repro.core.loading import PLANS, Leg, basic_plan, de_read_plan, pe_read_plan
from repro.core.scheduler import (
    Assignment,
    EngineState,
    Request,
    RoundRobinScheduler,
    Scheduler,
)
from repro.core.traffic import (
    DEFAULT_ARBITER,
    SubmitCostModel,
    TrafficClass,
    TrafficManager,
    VLArbiterConfig,
    allocate_bandwidth,
)
