"""Shared grouped runtime configuration (the config-API redesign).

The two runtimes' entry points grew the same knobs twice: ``SimConfig``
(sim/simulator.py) accumulated ~40 flat dataclass fields while
``ServingSystem.__init__`` (serving/system.py) mirrored ~22 of them as
flat kwargs — and the copies drifted (``reconfig_interval_s`` 10.0 vs
5.0, ``tier_ttl_s`` 120.0 vs None).  This module is the single
definition both consume by composition:

* :class:`TierConfig`        — node-local DRAM KV tier + prefetcher
* :class:`NetworkConfig`     — finite compute network / collectives
* :class:`ElasticConfig`     — PE<->DE role reconfiguration
* :class:`ResilienceConfig`  — fault injection + hedged reads
* :class:`SloConfig`         — online SLO layer: admission control,
  chunked prefill, priority classes (new in this module)

``SimConfig`` and ``ServingSystem`` each hold one instance of every
group; a future knob lands in exactly one place.  The old flat kwargs
keep working for one release through :func:`resolve_groups`, which
folds them into the right group and emits a
:class:`ConfigDeprecationWarning` (turned into an error for internal
code by the test suite — only the shim tests may trigger it).

Default-drift resolution (documented here, asserted by
tests/test_config.py):

* ``reconfig_interval_s`` — **5.0 wins** (the serving runtime's
  default).  The simulator's old 10.0 was never load-bearing: every
  elastic-enabled benchmark and test passes the interval explicitly,
  and the tighter loop is the safer default for the small-scale
  deployments both runtimes construct by default.
* ``tier_ttl_s`` — **None wins** (the serving runtime's default),
  meaning "the policy's own default" (AgenticTTLPolicy's 120 s).  The
  simulator's old explicit 120.0 equalled that policy default, so the
  unification is behaviour-neutral.
* ``block_tokens`` — intentionally NOT unified (64 sim vs 16 serving):
  the simulator models the paper's production block size while the
  real-bytes runtime runs reduced test models whose trie needs finer
  granularity.  It stays a per-runtime core field, listed in
  :data:`PARITY_EXCLUSIONS`.
"""
from __future__ import annotations

import dataclasses
import warnings
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple


class ConfigDeprecationWarning(DeprecationWarning):
    """Flat runtime-config kwargs (pre-grouped API) were used."""


# ---------------------------------------------------------------------------
# the groups
# ---------------------------------------------------------------------------


@dataclass
class TierConfig:
    """Node-local DRAM KV tier over the remote store (kvcache/tiers.py).

    ``dram_tier_bytes == 0`` disables the tier entirely (both runtimes'
    legacy behaviour).  ``tier_ttl_s=None`` defers to the policy's own
    default (agentic-ttl: 120 s)."""

    dram_tier_bytes: float = 0.0      # per-node tier capacity [bytes]
    tier_policy: str = "lru"          # lru | agentic-ttl
    tier_ttl_s: Optional[float] = None  # None = policy default (120 s)
    prefetch: bool = False            # think-time prefetcher
    prefetch_chunk_blocks: int = 32   # blocks per staged prefetch chunk


@dataclass
class NetworkConfig:
    """Finite compute network + model collectives (repro.network).

    ``net_bw``/``net_bg_*``/``model_collectives`` drive the simulator's
    SharedLink; ``collective_group_size`` is the serving runtime's knob
    for the same mechanism (its link model derives volumes from the
    group size) — each is ignored by the other runtime (see
    PARITY_EXCLUSIONS)."""

    net_bw: Optional[float] = None    # shared PE<->DE link [B/s]; None = inf
    net_arbiter: str = "vl"           # 'vl' (paper) | 'fifo' (ablation)
    model_collectives: Optional[bool] = None   # None: on iff net finite
    collective_dtype_bytes: int = 2
    collective_bytes_per_token: Optional[float] = None
    net_bg_load: float = 0.0          # background traffic, frac of net_bw
    net_bg_chunk_bytes: float = 512e6
    collective_group_size: int = 0    # serving: >1 puts collectives on CN


@dataclass
class ElasticConfig:
    """Elastic PE<->DE role reconfiguration (core/autoscale.py).

    Truthiness follows ``enabled`` so ``if cfg.elastic:`` reads the
    same whether ``elastic`` holds the legacy bool or this group."""

    enabled: bool = False
    reconfig_interval_s: float = 5.0  # unified default (was 10.0 in sim)
    drain_policy: str = "idlest"      # idlest | rotate
    reconfig_hi: float = 2.0          # pressure-ratio hysteresis band
    reconfig_lo: float = 0.5
    reconfig_patience: int = 2
    reconfig_cooldown_s: float = 0.0
    reconfig_idle_floor_s: float = 1e-3
    elastic_min_pe: int = 1           # simulator-only floors
    elastic_min_de: int = 1

    def __bool__(self) -> bool:
        return self.enabled


@dataclass
class ResilienceConfig:
    """Fault injection + hedged split reads (sim/faults.py)."""

    faults: Optional[object] = None   # FaultSchedule (or None)
    hedge_reads: bool = False
    hedge_threshold_s: float = 0.25   # simulator-only (mid-flight hedge)
    hedge_min_severity: float = 2.0


@dataclass
class SloConfig:
    """Online SLO layer: admission control, chunked prefill, priority
    classes.  Every knob's default keeps the feature structurally off —
    an all-default SloConfig is event-identical to the pre-SLO
    runtimes (pinned by the conservation/identity tests).

    * **Admission control** — when ``admission`` is set, arrivals pass
      a load-aware gate (core/admission.AdmissionGate) fed by the same
      per-role seconds-of-service signals the elastic controller uses:
      a queueing-delay-aware TTFT estimate above
      ``admission_ttft_slo_s`` defers the round by
      ``admission_defer_s`` (up to ``admission_max_defers`` times,
      then rejects — load shedding).  Offline serving admits
      unconditionally (there is no arrival process to shed).
    * **Chunked prefill** — ``prefill_chunk_tokens`` caps each packed
      prefill slice (core/intra.QuotaPacker) so a long-prompt round
      can no longer head-of-line-block decode steps for a whole
      quota; requests mid-chunk surface as the PREFILL_CHUNKED
      lifecycle sub-state in the serving runtime.
    * **Priority classes** — ``class_aware`` orders the scheduler's
      global queues by (class rank, arrival): ``interactive`` rounds
      overtake ``batch`` rounds at submission, in DE phase-1 routing
      and in every drain/recovery re-sort, and per-class queue
      pressure feeds the elastic controller.
    """

    admission: bool = False
    admission_ttft_slo_s: float = 0.5
    admission_defer_s: float = 0.05
    admission_max_defers: int = 40
    prefill_chunk_tokens: Optional[int] = None  # None = quota-only packing
    class_aware: bool = False


#: the group field names, in declaration order
GROUP_FIELDS: Tuple[str, ...] = ("tier", "net", "elastic", "resilience",
                                 "slo")

_GROUP_TYPES = dict(tier=TierConfig, net=NetworkConfig,
                    elastic=ElasticConfig, resilience=ResilienceConfig,
                    slo=SloConfig)

#: flat (pre-redesign) kwarg -> (group, field).  ``elastic`` as a bool
#: is special-cased by resolve_groups (it collides with the group name).
FLAT_FIELDS: Dict[str, Tuple[str, str]] = {
    # --- tier ---------------------------------------------------------
    "dram_tier_bytes": ("tier", "dram_tier_bytes"),
    "tier_policy": ("tier", "tier_policy"),
    "tier_ttl_s": ("tier", "tier_ttl_s"),
    "prefetch": ("tier", "prefetch"),
    "prefetch_chunk_blocks": ("tier", "prefetch_chunk_blocks"),
    # --- network ------------------------------------------------------
    "net_bw": ("net", "net_bw"),
    "net_arbiter": ("net", "net_arbiter"),
    "model_collectives": ("net", "model_collectives"),
    "collective_dtype_bytes": ("net", "collective_dtype_bytes"),
    "collective_bytes_per_token": ("net", "collective_bytes_per_token"),
    "net_bg_load": ("net", "net_bg_load"),
    "net_bg_chunk_bytes": ("net", "net_bg_chunk_bytes"),
    "collective_group_size": ("net", "collective_group_size"),
    # --- elastic ------------------------------------------------------
    "reconfig_interval_s": ("elastic", "reconfig_interval_s"),
    "drain_policy": ("elastic", "drain_policy"),
    "reconfig_hi": ("elastic", "reconfig_hi"),
    "reconfig_lo": ("elastic", "reconfig_lo"),
    "reconfig_patience": ("elastic", "reconfig_patience"),
    "reconfig_cooldown_s": ("elastic", "reconfig_cooldown_s"),
    "reconfig_idle_floor_s": ("elastic", "reconfig_idle_floor_s"),
    "elastic_min_pe": ("elastic", "elastic_min_pe"),
    "elastic_min_de": ("elastic", "elastic_min_de"),
    # --- resilience ---------------------------------------------------
    "faults": ("resilience", "faults"),
    "hedge_reads": ("resilience", "hedge_reads"),
    "hedge_threshold_s": ("resilience", "hedge_threshold_s"),
    "hedge_min_severity": ("resilience", "hedge_min_severity"),
}

#: shared-looking fields deliberately NOT held to cross-runtime default
#: parity, with the reason — the config-parity test consumes this.
PARITY_EXCLUSIONS: Dict[str, str] = {
    "block_tokens": "sim models the paper's production 64-token "
                    "FullBlocks; serving runs reduced test models whose "
                    "trie needs 16-token granularity",
    "elastic_min_pe": "simulator-only floor (serving derives its floor "
                      "from the admitting set)",
    "elastic_min_de": "simulator-only floor",
    "hedge_threshold_s": "simulator-only: gates the mid-flight hedge; "
                         "serving hedges at issue time",
    "net_bw": "simulator-only: serving's link model derives capacity "
              "from the node spec",
    "model_collectives": "simulator-only switch",
    "collective_bytes_per_token": "simulator-only override",
    "collective_dtype_bytes": "simulator-only",
    "net_bg_load": "simulator-only background traffic",
    "net_bg_chunk_bytes": "simulator-only",
    "collective_group_size": "serving-only: >1 enables collectives "
                             "there (sim uses net_bw/model_collectives)",
}


def resolve_groups(flat: Dict[str, object], *,
                   tier: Optional[TierConfig] = None,
                   net: Optional[NetworkConfig] = None,
                   elastic=None,
                   resilience: Optional[ResilienceConfig] = None,
                   slo: Optional[SloConfig] = None,
                   stacklevel: int = 3) -> Dict[str, object]:
    """Resolve grouped + deprecated-flat kwargs into the five groups.

    ``flat`` is the caller's ``**legacy`` dict.  Unknown keys raise
    TypeError (exactly like a wrong kwarg on the old signatures); known
    keys emit one :class:`ConfigDeprecationWarning` and are folded into
    a *copy* of the corresponding group (explicit groups passed by the
    caller are never mutated).  ``elastic`` may arrive as the legacy
    bool switch — it is routed to ``ElasticConfig.enabled``."""
    if isinstance(elastic, bool):
        flat = dict(flat)
        flat["elastic"] = elastic
        elastic = None
    groups = {
        "tier": tier if tier is not None else TierConfig(),
        "net": net if net is not None else NetworkConfig(),
        "elastic": elastic if elastic is not None else ElasticConfig(),
        "resilience": resilience if resilience is not None
        else ResilienceConfig(),
        "slo": slo if slo is not None else SloConfig(),
    }
    if not flat:
        return groups
    unknown = sorted(k for k in flat
                     if k not in FLAT_FIELDS and k != "elastic")
    if unknown:
        raise TypeError(f"unexpected config kwargs: {unknown}")
    warnings.warn(
        f"flat config kwargs {sorted(flat)} are deprecated; pass the "
        f"grouped dataclasses from repro.core.config instead "
        f"(TierConfig/NetworkConfig/ElasticConfig/ResilienceConfig/"
        f"SloConfig) — the flat spelling is removed next release",
        ConfigDeprecationWarning, stacklevel=stacklevel)
    overrides: Dict[str, Dict[str, object]] = {}
    for k, v in flat.items():
        grp, fld = FLAT_FIELDS.get(k, ("elastic", "enabled"))
        overrides.setdefault(grp, {})[fld] = v
    for grp, kw in overrides.items():
        groups[grp] = dataclasses.replace(groups[grp], **kw)
    return groups


def group_defaults(name: str):
    """A fresh all-default instance of group ``name``."""
    return _GROUP_TYPES[name]()


__all__ = [
    "TierConfig", "NetworkConfig", "ElasticConfig", "ResilienceConfig",
    "SloConfig", "ConfigDeprecationWarning", "FLAT_FIELDS",
    "GROUP_FIELDS", "PARITY_EXCLUSIONS", "resolve_groups",
    "group_defaults", "field",
]
