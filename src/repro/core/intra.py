"""Intra-engine scheduling (paper §6.2): compute-quota batch packing.

Only PEs run this.  Under DP attention every GPU serves different
requests but all synchronise before the FFN stage; imbalanced attention
time ⇒ bubbles.  The packer bounds each forward batch's *predicted
attention time* by a quota (300 ms in the paper), chunking the
straddling request via binary search on its bsz'.

Each request in a forward batch is (cached, bsz): ``cached`` tokens have
KV available (storage hits or previous chunks), ``bsz`` tokens need
compute this batch.  Theoretical attention FLOPs for a causal append:

    F(cached, bsz) = 4 · n_heads · head_dim · bsz · (cached + (bsz+1)/2)

(QK^T + PV, two matmuls → factor 4=2·2) summed per layer.  Wall time is
fitted affine in FLOPs (profiled in advance, as in PrefillOnly/Sarathi).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

from repro.configs.base import ModelConfig


@dataclass
class AttnTimeModel:
    """t(flops) = base_overhead + flops / effective_flops_per_s."""

    effective_flops: float          # attention-kernel FLOP/s actually achieved
    base_overhead_s: float = 30e-6  # per-layer launch overhead

    @classmethod
    def from_config(cls, cfg: ModelConfig, peak_flops: float = 197e12,
                    attn_efficiency: float = 0.35):
        """Napkin default: attention kernels reach ~35% of peak on TPU
        (bandwidth-bound at small bsz).  Engines re-fit from measurements
        via ``fit``."""
        return cls(effective_flops=peak_flops * attn_efficiency)

    @classmethod
    def fit(cls, samples: Sequence[Tuple[float, float]]):
        """Least-squares fit of (flops, seconds) measurement pairs."""
        n = len(samples)
        sx = sum(f for f, _ in samples)
        sy = sum(t for _, t in samples)
        sxx = sum(f * f for f, _ in samples)
        sxy = sum(f * t for f, t in samples)
        denom = n * sxx - sx * sx
        if denom == 0:
            return cls(effective_flops=1e12)
        slope = (n * sxy - sx * sy) / denom
        intercept = (sy - slope * sx) / n
        slope = max(slope, 1e-18)
        return cls(effective_flops=1.0 / slope,
                   base_overhead_s=max(intercept, 0.0))

    def seconds(self, flops: float) -> float:
        return self.base_overhead_s + flops / self.effective_flops


def attn_flops_per_layer(cfg: ModelConfig, cached: int, bsz: int) -> float:
    """Theoretical attention FLOPs for one layer of a (cached, bsz) item."""
    if cfg.attn_variant == "none":
        # SSD cost is linear in bsz; treat state-chunk work as d_state-wide
        d_inner = cfg.ssm.expand * cfg.d_model
        return 6.0 * bsz * d_inner * cfg.ssm.d_state
    qk_dim = cfg.head_dim if cfg.attn_variant != "mla" else (
        cfg.mla.nope_head_dim + cfg.mla.rope_head_dim)
    return 4.0 * cfg.n_heads * qk_dim * bsz * (cached + (bsz + 1) / 2.0)


def attn_flops(cfg: ModelConfig, items: Sequence[Tuple[int, int]]) -> float:
    n_attn = sum(1 for k in cfg.layer_kinds() if k != "ssm")
    if cfg.hybrid_period:
        n_attn += cfg.n_layers // cfg.hybrid_period
    n_attn = max(n_attn, cfg.n_layers if cfg.attn_variant == "none" else n_attn)
    per_layer = sum(attn_flops_per_layer(cfg, c, b) for c, b in items)
    return per_layer * max(n_attn, 1)


@dataclass
class PrefillWork:
    """Mutable prefill progress of one request on a PE."""

    rid: int
    cached: int                     # tokens whose KV exists already
    remaining: int                  # append tokens still to compute
    rank: int = 0                   # SLO-class rank (0 = interactive)
    arrival: float = 0.0            # round arrival time (tie-break)

    def advance(self, bsz: int):
        self.cached += bsz
        self.remaining -= bsz

    def key(self) -> Tuple[int, float, int]:
        return (self.rank, self.arrival, self.rid)


def class_insert_index(keys: Sequence[Tuple[int, float, int]],
                       new_key: Tuple[int, float, int]) -> int:
    """Stable insertion point for class-aware prefill fifos: after the
    last entry whose (rank, arrival, rid) key is <= ``new_key``.  Global
    queue priority alone is a no-op for TTFT — the wait accrues *inside*
    the engine (read queue + this fifo), so the class order must extend
    here.  An interactive round may land ahead of a partially-prefilled
    batch head; the preempted work just resumes on a later pack."""
    i = len(keys)
    while i > 0 and keys[i - 1] > new_key:
        i -= 1
    return i


@dataclass
class BatchItem:
    rid: int
    cached: int
    bsz: int
    chunked: bool = False           # True if this is a partial (chunked) fill


class QuotaPacker:
    """FIFO packing under a compute quota with binary-search chunking.

    ``chunk_tokens`` (SloConfig.prefill_chunk_tokens) additionally caps
    any single request's contribution to one batch, independent of the
    quota: a long-prompt round is sliced into ≤chunk_tokens pieces so
    decode steps interleave between the slices instead of waiting a
    whole quota behind it.  ``None`` (the default) preserves the
    quota-only arithmetic bit-for-bit.
    """

    def __init__(self, cfg: ModelConfig, time_model: AttnTimeModel,
                 quota_s: float = 0.300, min_chunk: int = 16,
                 chunk_tokens: Optional[int] = None):
        self.cfg = cfg
        self.time_model = time_model
        self.quota_s = quota_s
        self.min_chunk = min_chunk
        self.chunk_tokens = None if chunk_tokens is None \
            else max(int(chunk_tokens), min_chunk)

    def predict_batch_seconds(self, items: Sequence[Tuple[int, int]]) -> float:
        return self.time_model.seconds(attn_flops(self.cfg, items))

    def pack(self, fifo: List[PrefillWork]) -> List[BatchItem]:
        """Select the next forward batch; mutates ``fifo`` (consumed work
        is advanced, fully-prefilled requests are removed)."""
        batch: List[BatchItem] = []
        items: List[Tuple[int, int]] = []
        while fifo:
            w = fifo[0]
            take = w.remaining if self.chunk_tokens is None \
                else min(w.remaining, self.chunk_tokens)
            cand = items + [(w.cached, take)]
            if self.predict_batch_seconds(cand) <= self.quota_s:
                if take == w.remaining:
                    items.append((w.cached, w.remaining))
                    batch.append(BatchItem(w.rid, w.cached, w.remaining))
                    w.advance(w.remaining)
                    fifo.pop(0)
                    continue
                # capped slice: a chunked item always closes the batch so
                # the engine's step (and any interleaved decode) runs now
                batch.append(BatchItem(w.rid, w.cached, take, chunked=True))
                w.advance(take)
                break
            # straddling request: binary search the largest bsz' that fits
            lo, hi = 0, take
            while lo < hi:
                mid = (lo + hi + 1) // 2
                if self.predict_batch_seconds(
                        items + [(w.cached, mid)]) <= self.quota_s:
                    lo = mid
                else:
                    hi = mid - 1
            if lo >= self.min_chunk:
                batch.append(BatchItem(w.rid, w.cached, lo, chunked=True))
                w.advance(lo)
            break
        return batch
