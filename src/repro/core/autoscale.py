"""Elastic PE<->DE role reconfiguration (the paper's "global scheduler
that dynamically balances load across prefill and decode engines",
abstract + §6, made a control loop).

The static runtime freezes engine roles at construction, so the PD
ratio is a grid-search parameter (fig8) rather than something the
system adapts.  This module closes that loop:

* :class:`LoadSignals` — one observation of the deployment: queued and
  in-flight work per role (in *seconds* of service, so prefill and
  decode pressure are commensurable), read-queue depth, net congestion
  and tier hit ratio — exactly the signals the scheduler, simulator and
  serving runtime already expose.
* :class:`PDController` — a hysteresis controller over the pressure
  ratio.  It proposes at most one role flip per observation, only after
  ``patience`` consecutive observations agree, never inside the
  ``cooldown_s`` window after the previous action, and never below one
  engine per role.  The dead band [lo, hi] absorbs transient skew so
  the split-read water-fill (scheduler ``choose_read_path``) is not
  whipsawed by flapping roles.
* :class:`DrainTracker` — bookkeeping for the safe drain protocol:
  ``begin`` stops admissions (scheduler ``begin_drain``), the runtime
  polls ``can_flip`` until the engine's in-flight lifecycle states have
  emptied, then hands off tier-resident blocks and flips ``kind``
  (scheduler ``finish_drain``).

The same controller object drives the discrete-event simulator
(``SimConfig(elastic=True)``) and the real-bytes serving runtime
(``ServingSystem(elastic=True)``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

EngineId = Tuple[int, int]

#: role-flip actions the controller can propose
DE_TO_PE = "de->pe"
PE_TO_DE = "pe->de"

#: victim-selection policies for the drain
DRAIN_POLICIES = ("idlest", "rotate")


@dataclass
class LoadSignals:
    """One observation of the deployment's load, per engine role.

    Work is expressed in **seconds of service** (tokens divided by that
    role's per-engine token rate): prefill tokens and decode tokens
    differ by orders of magnitude in cost, so raw token counts cannot
    be compared across roles, but seconds can.
    """

    n_pe: int                       # admitting (non-draining) PEs
    n_de: int                       # admitting (non-draining) DEs
    pe_queued_s: float              # un-assigned + assigned-unstarted work
    pe_busy_s: float                # in-flight prefill work
    de_queued_s: float              # waiting in the DE global/private queues
    de_busy_s: float                # remaining decode work of active slots
    pe_read_q_s: float = 0.0        # PE-side disk reading queue backlog
    de_read_q_s: float = 0.0        # DE-side disk reading queue backlog
    net_congestion: float = 0.0     # SharedLink.congestion() in [0, 1]
    dram_hit_ratio: float = 0.0     # tier hits / (tier hits + SNIC reads)
    # SLO-class signals (core/config.SloConfig class_aware): the share
    # of each role's *queued* seconds owed to interactive-class rounds.
    # Interactive backlog is double-counted into the pressure so the
    # elastic controller reacts to an interactive pile-up before the
    # aggregate queue alone would trip the hysteresis band.  Both stay
    # 0.0 when class-aware scheduling is off — pressures then reduce
    # exactly to the legacy expressions.
    pe_queued_interactive_s: float = 0.0
    de_queued_interactive_s: float = 0.0

    @property
    def pe_pressure(self) -> float:
        """Seconds of outstanding prefill-side work per admitting PE
        (storage reads feed the prefill, so their backlog counts;
        interactive-class backlog counts twice)."""
        tot = self.pe_queued_s + self.pe_busy_s + self.pe_read_q_s \
            + self.pe_queued_interactive_s
        return tot / max(self.n_pe, 1)

    @property
    def de_pressure(self) -> float:
        tot = self.de_queued_s + self.de_busy_s + self.de_read_q_s \
            + self.de_queued_interactive_s
        return tot / max(self.n_de, 1)


@dataclass
class PDController:
    """Hysteresis controller choosing the PD ratio from observed load.

    ``observe`` returns one of DE_TO_PE / PE_TO_DE / None.  A flip is
    proposed only when the pressure ratio has sat outside the [lo, hi]
    dead band for ``patience`` consecutive observations, at least
    ``cooldown_s`` after the previous proposal, and only while both
    roles keep ``min_pe`` / ``min_de`` engines.  ``idle_floor_s``
    guards the ratio against noise: when both sides' pressure is below
    it the system is idle and no evidence accumulates either way.
    """

    hi: float = 2.0                 # pe_pressure/de_pressure above => +PE
    lo: float = 0.5                 # below => +DE
    patience: int = 2               # consecutive out-of-band observations
    cooldown_s: float = 0.0         # min seconds between proposals
    min_pe: int = 1
    min_de: int = 1
    idle_floor_s: float = 1e-3
    # --- state ----------------------------------------------------------
    _streak: int = 0                # signed: +k toward PE, -k toward DE
    _last_action_t: float = field(default=float("-inf"))
    n_proposed: int = 0

    #: optional flight recorder (repro.obs.Tracer) — a plain class
    #: attribute, NOT a dataclass field: attaching a tracer must not
    #: change the controller's repr/eq or its constructor signature
    tracer = None

    def target_ratio(self, sig: LoadSignals) -> float:
        """pe/de pressure ratio this observation (inf when DEs idle)."""
        de = sig.de_pressure
        if de <= self.idle_floor_s:
            return float("inf") if sig.pe_pressure > self.idle_floor_s \
                else 1.0
        return sig.pe_pressure / de

    def observe(self, sig: LoadSignals, now: float) -> Optional[str]:
        if sig.pe_pressure <= self.idle_floor_s and \
                sig.de_pressure <= self.idle_floor_s:
            self._streak = 0            # idle: no evidence either way
            return None
        r = self.target_ratio(sig)
        if r > self.hi:
            self._streak = self._streak + 1 if self._streak > 0 else 1
        elif r < self.lo:
            self._streak = self._streak - 1 if self._streak < 0 else -1
        else:
            self._streak = 0            # inside the dead band
            return None
        if abs(self._streak) < self.patience:
            return None
        if now - self._last_action_t < self.cooldown_s:
            return None
        if self._streak > 0:
            if sig.n_de <= self.min_de:
                return None
            action = DE_TO_PE
        else:
            if sig.n_pe <= self.min_pe:
                return None
            action = PE_TO_DE
        self._streak = 0
        self._last_action_t = now
        self.n_proposed += 1
        if self.tracer is not None:
            self.tracer.event("autoscale", "proposal", t=now,
                              action=action,
                              ratio=(-1.0 if r == float("inf") else r),
                              n_pe=sig.n_pe, n_de=sig.n_de)
        return action


@dataclass
class DrainRecord:
    """One in-progress role reconfiguration."""

    engine: EngineId
    from_kind: str
    to_kind: str
    t_begin: float
    t_drained: float = -1.0         # in-flight states emptied
    t_flip: float = -1.0            # kind flipped (after weight reload)
    tier_handoff_bytes: int = 0     # tier-resident bytes kept at flip


class DrainTracker:
    """Bookkeeping for in-progress drains and the reconfiguration log.

    The runtime owns the actual protocol (it knows its in-flight
    lifecycle states); this tracker owns the invariants: one drain per
    engine at a time, drained-before-flip ordering, and the aggregate
    accounting ``results()``/``stats()`` report."""

    def __init__(self):
        self.active: Dict[EngineId, DrainRecord] = {}
        self.log: List[DrainRecord] = []

    def begin(self, engine: EngineId, from_kind: str, to_kind: str,
              now: float) -> DrainRecord:
        assert engine not in self.active, f"{engine} is already draining"
        rec = DrainRecord(engine, from_kind, to_kind, t_begin=now)
        self.active[engine] = rec
        return rec

    def mark_drained(self, engine: EngineId, now: float) -> DrainRecord:
        rec = self.active[engine]
        assert rec.t_drained < 0, f"{engine} drained twice"
        rec.t_drained = now
        return rec

    def finish(self, engine: EngineId, now: float,
               tier_handoff_bytes: int = 0) -> DrainRecord:
        rec = self.active[engine]
        assert rec.t_drained >= 0, f"{engine} flipped before draining"
        del self.active[engine]
        rec.t_flip = now
        rec.tier_handoff_bytes = tier_handoff_bytes
        self.log.append(rec)
        return rec

    def abort(self, engine: EngineId) -> Optional[DrainRecord]:
        """Cancel an in-progress drain without a flip — the victim died
        (sim/faults.py fail-stop) before the protocol completed.  The
        record is dropped, not logged: an aborted drain is not a role
        change and must not count toward n_flips/drain_seconds."""
        return self.active.pop(engine, None)

    # ------------------------------------------------------------------
    @property
    def n_flips(self) -> int:
        return len(self.log)

    def drain_seconds(self) -> float:
        """Total admission-stopped-to-flip seconds across completed
        reconfigurations (the protocol's aggregate latency)."""
        return sum(r.t_flip - r.t_begin for r in self.log)

    def flips_by_direction(self) -> Dict[str, int]:
        out = {DE_TO_PE: 0, PE_TO_DE: 0}
        for r in self.log:
            out[f"{r.from_kind}->{r.to_kind}"] += 1
        return out

    def tier_handoff_bytes(self) -> int:
        return sum(r.tier_handoff_bytes for r in self.log)


def pick_victim(candidates, policy: str, load_of, rotation: int = 0):
    """Select the engine to drain.  ``candidates`` is a non-empty list;
    ``load_of`` maps a candidate to its current load (seconds or
    tokens).  ``idlest`` (default) drains the least-loaded engine — the
    cheapest drain and the one whose loss the survivors absorb most
    easily; ``rotate`` round-robins by ``rotation`` so repeated flips
    spread wear (and tier churn) across the fleet."""
    if policy == "rotate":
        ordered = sorted(candidates, key=lambda e: tuple(_eid_of(e)))
        return ordered[rotation % len(ordered)]
    if policy != "idlest":
        raise ValueError(f"unknown drain_policy {policy!r}; "
                         f"expected one of {DRAIN_POLICIES}")
    return min(candidates, key=load_of)


def _eid_of(candidate):
    eid = getattr(candidate, "eid", None)
    if eid is None:
        eid = getattr(candidate, "engine", candidate)
    return eid
