"""KV-Cache block layouts (paper §A.5).

* ``LayerBlock`` — byte tensor ``[1, tokens, bytes]``: one layer's KV for
  ``block_tokens`` tokens.  Used by all layerwise streaming paths
  (storage→HBM per layer, PE→DE per layer).
* ``FullBlock``  — ``[layers, tokens, bytes]``: all layers for the same
  tokens.  The only unit persistent storage sees; trie nodes map 1:1 to
  FullBlocks.

The payoff of this layout (and the reason we reproduce it bit-exactly):
``n`` LayerBlocks concatenate into a FullBlock **without any layout
conversion** — ``jnp.concatenate`` / ``np.concatenate`` on axis 0 — so
the layerwise prefill stream can be persisted, and a loaded FullBlock
can be sliced per layer, with zero reshuffling.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence

import numpy as np

from repro.configs.base import ModelConfig

DEFAULT_BLOCK_TOKENS = 64


@dataclass(frozen=True)
class BlockLayout:
    """Geometry of KV blocks for one model."""

    n_layers: int                 # layers that carry loadable per-token state
    block_tokens: int             # tokens per block (paper: e.g. 64)
    bytes_per_token_layer: int    # KV bytes per token per layer

    @property
    def layer_block_bytes(self) -> int:
        return self.block_tokens * self.bytes_per_token_layer

    @property
    def full_block_bytes(self) -> int:
        return self.n_layers * self.layer_block_bytes

    def layer_block_shape(self):
        return (1, self.block_tokens, self.bytes_per_token_layer)

    def full_block_shape(self):
        return (self.n_layers, self.block_tokens, self.bytes_per_token_layer)

    def n_blocks(self, n_tokens: int) -> int:
        """Whole blocks covering n_tokens (partial tails are not persisted —
        the paper persists only once a full block accumulates)."""
        return n_tokens // self.block_tokens

    def loadable_bytes(self, n_tokens: int) -> int:
        return self.n_blocks(n_tokens) * self.full_block_bytes


def layout_for(cfg: ModelConfig, block_tokens: int = DEFAULT_BLOCK_TOKENS,
               kv_dtype_bytes: int = 2) -> BlockLayout:
    """Derive the block geometry from a model config.

    Per-layer per-token bytes follow the arch's attention variant; for
    attention-free layers (SSM) there is no per-token state and the
    'loadable' KV is only the constant-size recurrent state, handled
    separately (see kv_bytes_per_token / ssm_state_bytes in configs).
    """
    per_token = cfg.kv_bytes_per_token(kv_dtype_bytes)
    attn_layers = sum(1 for k in cfg.layer_kinds() if k != "ssm")
    if cfg.hybrid_period:
        attn_layers += cfg.n_layers // cfg.hybrid_period
    if attn_layers == 0:
        # SSM-only: a single pseudo-layer row so the machinery still works
        # for the O(1) state block.
        return BlockLayout(1, block_tokens, 0)
    return BlockLayout(attn_layers, block_tokens,
                       per_token // attn_layers)


# ---------------------------------------------------------------------------
# Host-side block tensors (numpy; engines wrap jnp views)
# ---------------------------------------------------------------------------


def new_layer_block(layout: BlockLayout) -> np.ndarray:
    return np.zeros(layout.layer_block_shape(), np.uint8)


def new_full_block(layout: BlockLayout) -> np.ndarray:
    return np.zeros(layout.full_block_shape(), np.uint8)


def full_from_layer_blocks(layer_blocks: Sequence[np.ndarray]) -> np.ndarray:
    """Concatenate n LayerBlocks -> FullBlock.  No layout conversion."""
    for lb in layer_blocks:
        assert lb.ndim == 3 and lb.shape[0] == 1, lb.shape
    return np.concatenate(list(layer_blocks), axis=0)


def layer_blocks_from_full(full: np.ndarray) -> List[np.ndarray]:
    """Split a FullBlock into LayerBlock views (zero-copy slices)."""
    return [full[i:i + 1] for i in range(full.shape[0])]


def pack_kv_to_blocks(kv_bytes: np.ndarray, layout: BlockLayout) -> List[np.ndarray]:
    """(layers, tokens, bytes_per_token_layer) -> list of FullBlocks
    covering the whole-token-blocks prefix.  Tail tokens that do not fill
    a block are dropped (persisted on a later step, as in the paper)."""
    L, T, Bp = kv_bytes.shape
    assert L == layout.n_layers and Bp == layout.bytes_per_token_layer
    n = layout.n_blocks(T)
    return [np.ascontiguousarray(
        kv_bytes[:, i * layout.block_tokens:(i + 1) * layout.block_tokens])
        for i in range(n)]


def unpack_blocks_to_kv(blocks: Sequence[np.ndarray],
                        layout: BlockLayout) -> np.ndarray:
    if not blocks:
        return np.zeros((layout.n_layers, 0, layout.bytes_per_token_layer),
                        np.uint8)
    return np.concatenate(list(blocks), axis=1)
