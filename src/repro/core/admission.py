"""Load-aware admission control (the online SLO layer's front gate).

Sits in front of ``core.scheduler.Scheduler`` in both runtimes: every
round arrival is first shown to :class:`AdmissionGate`, which holds a
queueing-delay-aware TTFT estimate built from the same per-role
seconds-of-service signals (:class:`core.autoscale.LoadSignals`) that
feed the elastic controller.  An arrival whose estimated TTFT exceeds
the admission SLO is *deferred* — resubmitted ``admission_defer_s``
later, when the backlog it would have joined has partly drained — and
after ``admission_max_defers`` consecutive deferrals it is *rejected*
(load shedding: the client's trajectory ends rather than occupying
queue slots it can never serve within budget).

The TTFT estimate is deliberately the simple queueing-network one:

    est = (queued + busy + read-backlog seconds) / admitting PEs
          + own storage-read seconds + own prefill seconds

i.e. "the work ahead of me, divided by the servers, plus my own
service time".  Both runtimes already maintain every term for the
elastic controller, so admission adds no new accounting.

With ``SloConfig.admission`` unset the gate is never constructed and
arrivals flow straight to ``Scheduler.submit`` — the admission-off
configuration is structurally identical to the pre-SLO runtimes.
"""
from __future__ import annotations

from typing import Dict, Hashable

from repro.core.autoscale import LoadSignals
from repro.core.config import SloConfig

#: decisions returned by :meth:`AdmissionGate.decide`
ADMIT = "admit"
DEFER = "defer"
REJECT = "reject"


class AdmissionGate:
    """SLO-budget gate over round arrivals.

    ``key`` identifies one logical arrival across its re-submissions
    (the runtimes use ``(trajectory id, round index)``), so the defer
    counter survives the deferral round-trips and the gate can escalate
    to rejection.
    """

    def __init__(self, slo: SloConfig):
        self.slo = slo
        self.admitted_rounds = 0
        self.deferred_rounds = 0
        self.rejected_rounds = 0
        self._defers: Dict[Hashable, int] = {}

    def ttft_estimate(self, sig: LoadSignals, read_s: float,
                      prefill_s: float) -> float:
        """Queueing-delay-aware TTFT estimate for a new arrival.

        ``read_s``/``prefill_s`` are the arrival's own storage-read and
        prefill service times; the queueing term is the prefill-side
        backlog already in the system, amortised over admitting PEs.
        """
        backlog = sig.pe_queued_s + sig.pe_busy_s + sig.pe_read_q_s
        return backlog / max(sig.n_pe, 1) + read_s + prefill_s

    def decide(self, key: Hashable, ttft_est: float) -> str:
        """ADMIT / DEFER / REJECT one arrival given its TTFT estimate."""
        if ttft_est <= self.slo.admission_ttft_slo_s:
            self._defers.pop(key, None)
            self.admitted_rounds += 1
            return ADMIT
        n = self._defers.get(key, 0)
        if n >= self.slo.admission_max_defers:
            self._defers.pop(key, None)
            self.rejected_rounds += 1
            return REJECT
        self._defers[key] = n + 1
        self.deferred_rounds += 1
        return DEFER

    def counters(self) -> Dict[str, int]:
        """The three obs-schema counters, ready to merge into results."""
        return dict(admitted_rounds=self.admitted_rounds,
                    deferred_rounds=self.deferred_rounds,
                    rejected_rounds=self.rejected_rounds)


__all__ = ["AdmissionGate", "ADMIT", "DEFER", "REJECT"]
