"""Dual-path KV-Cache loading plans (paper §4.1, Figure 4).

A *plan* is the ordered list of transfer legs a request's KV-Cache makes
through the machine, each leg annotated with the resources it occupies
(storage NIC, compute-NIC PCIe read/write side, DRAM, inter-node network)
and its byte count.  The discrete-event simulator charges each leg to
its resources; the engine runtime executes the same legs as real buffer
movements.  Keeping the byte accounting in one place guarantees the
simulator, the engines, and the §4.2 closed-form analysis agree — this
is property-tested (tests/test_loading.py asserts the per-resource sums
match Eq. 1–8's coefficients).

Resource keys are *symbolic* (pe_/de_ prefixed); the simulator binds
them to concrete node resources:

    snic       storage NIC (half-duplex FIFO, shared per node)
    cnic_rd    compute-NIC PCIe read side (NIC pulls from DRAM/HBM)
    cnic_wr    compute-NIC PCIe write side (NIC pushes to DRAM/HBM)
    dram       host DRAM (half-duplex: reads+writes share)
    net        inter-node compute network (PE<->DE)

Layerwise legs (``layerwise=True``) stream LayerBlocks and overlap with
prefill compute; the sim models them as running concurrently with the
forward pass, matching "transfers overlap with computation".
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from repro.core.traffic import TrafficClass


@dataclass(frozen=True)
class Leg:
    name: str
    nbytes: int
    resources: tuple                 # symbolic resource keys
    layerwise: bool = False          # streams per layer, overlaps compute
    phase: str = "prefill"           # 'load' | 'prefill' | 'decode_start' | 'decode'
    tclass: TrafficClass = TrafficClass.KV_TRANSFER


def pe_read_plan(hit_bytes: int, miss_bytes: int, gen_bytes: int) -> List[Leg]:
    """Figure 4a: storage→PE buffer→PE HBM→DE buffer→DE HBM."""
    full = hit_bytes + miss_bytes
    return [
        Leg("storage_to_pe_buf", hit_bytes,
            ("pe_snic", "pe_dram"), phase="load"),
        Leg("pe_buf_to_pe_hbm", hit_bytes,
            ("pe_cnic_rd", "pe_cnic_wr", "pe_dram"), layerwise=True),
        Leg("pe_hbm_to_de_buf", full,
            ("pe_cnic_rd", "net", "de_cnic_wr", "de_dram"), layerwise=True),
        Leg("de_buf_to_de_hbm", full,
            ("de_cnic_rd", "de_cnic_wr", "de_dram"), phase="decode_start"),
        Leg("persist_new_kv", miss_bytes + gen_bytes,
            ("de_cnic_rd", "de_cnic_wr", "de_dram", "de_snic"),
            phase="decode"),
    ]


def de_read_plan(hit_bytes: int, miss_bytes: int, gen_bytes: int) -> List[Leg]:
    """Figure 4b: storage→DE buffer→(stream)→PE HBM; miss KV merged back."""
    full = hit_bytes + miss_bytes
    return [
        Leg("storage_to_de_buf", hit_bytes,
            ("de_snic", "de_dram"), phase="load"),
        Leg("de_buf_to_pe_hbm", hit_bytes,
            ("de_cnic_rd", "de_dram", "net", "pe_cnic_wr"), layerwise=True),
        Leg("miss_kv_to_de_buf", miss_bytes,
            ("pe_cnic_rd", "net", "de_cnic_wr", "de_dram"), layerwise=True),
        Leg("de_buf_to_de_hbm", full,
            ("de_cnic_rd", "de_cnic_wr", "de_dram"), phase="decode_start"),
        Leg("persist_new_kv", miss_bytes + gen_bytes,
            ("de_cnic_rd", "de_cnic_wr", "de_dram", "de_snic"),
            phase="decode"),
    ]


def basic_plan(hit_bytes: int, miss_bytes: int, gen_bytes: int) -> List[Leg]:
    """The Basic baseline: PE-only storage reads, no DE buffer staging —
    KV goes storage→PE DRAM→PE HBM, then PE→DE over the compute network
    directly into DE HBM (classic PD disaggregation)."""
    full = hit_bytes + miss_bytes
    return [
        Leg("storage_to_pe_buf", hit_bytes,
            ("pe_snic", "pe_dram"), phase="load"),
        Leg("pe_buf_to_pe_hbm", hit_bytes,
            ("pe_cnic_rd", "pe_cnic_wr", "pe_dram"), layerwise=True),
        Leg("pe_hbm_to_de_hbm", full,
            ("pe_cnic_rd", "net", "de_cnic_wr"), layerwise=True),
        Leg("persist_new_kv", miss_bytes + gen_bytes,
            ("de_cnic_rd", "de_cnic_wr", "de_dram", "de_snic"),
            phase="decode"),
    ]


def oracle_plan(hit_bytes: int, miss_bytes: int, gen_bytes: int) -> List[Leg]:
    """Oracle baseline: all disk reads, D2H/H2D and inter-PD transfers
    bypassed (zero I/O overhead upper bound)."""
    return []


def split_read_plan(hit_bytes: int, miss_bytes: int, gen_bytes: int,
                    pe_bytes: int) -> List[Leg]:
    """Split read (paper §6.1 future work): one request's hit bytes are
    partitioned across *both* storage NICs — ``pe_bytes`` enter via the
    PE side (Figure 4a legs) and ``hit_bytes - pe_bytes`` via the DE
    side (Figure 4b legs), so both ``snic`` resources serve the same
    request's load phase concurrently.

    The miss/persist legs are path-independent (they occupy identical
    resources in Fig. 4a and 4b), so the per-resource byte sums of a
    split plan are the *exact* convex combination of the two pure plans
    with weight r = pe_bytes / hit_bytes — property-tested byte-for-byte
    in tests/test_loading.py.  Zero-byte legs are dropped, making the
    r=1 / r=0 endpoints structurally identical to the pure plans.
    """
    assert 0 <= pe_bytes <= hit_bytes, (pe_bytes, hit_bytes)
    de_bytes = hit_bytes - pe_bytes
    full = hit_bytes + miss_bytes
    legs = [
        # both storage NICs engaged concurrently on one request
        Leg("storage_to_pe_buf", pe_bytes,
            ("pe_snic", "pe_dram"), phase="load"),
        Leg("storage_to_de_buf", de_bytes,
            ("de_snic", "de_dram"), phase="load"),
        # PE-side share climbs into PE HBM locally
        Leg("pe_buf_to_pe_hbm", pe_bytes,
            ("pe_cnic_rd", "pe_cnic_wr", "pe_dram"), layerwise=True),
        # DE-side share streams over the compute network into PE HBM
        Leg("de_buf_to_pe_hbm", de_bytes,
            ("de_cnic_rd", "de_dram", "net", "pe_cnic_wr"), layerwise=True),
        # PE-resident KV (PE-side hit + computed miss) forwarded to DE buf
        Leg("pe_hbm_to_de_buf", pe_bytes + miss_bytes,
            ("pe_cnic_rd", "net", "de_cnic_wr", "de_dram"), layerwise=True),
        Leg("de_buf_to_de_hbm", full,
            ("de_cnic_rd", "de_cnic_wr", "de_dram"), phase="decode_start"),
        Leg("persist_new_kv", miss_bytes + gen_bytes,
            ("de_cnic_rd", "de_cnic_wr", "de_dram", "de_snic"),
            phase="decode"),
    ]
    return [leg for leg in legs if leg.nbytes > 0]


def tiered_read_plan(hit_bytes: int, miss_bytes: int, gen_bytes: int,
                     pe_snic_bytes: int, de_snic_bytes: int,
                     pe_tier_bytes: int, de_tier_bytes: int) -> List[Leg]:
    """Split read with node-local DRAM-tier hits (kvcache/tiers.py).

    The hit partitions four ways: per side, ``*_snic_bytes`` are read
    from remote storage (Fig. 4a/4b load legs) and ``*_tier_bytes`` are
    already resident in that side's DRAM tier — they skip the storage
    NIC entirely and appear as a zero-transfer ``*_tier_hit`` leg whose
    only resource is the accounting key ``{side}_tier``.  Everything
    downstream of the DRAM buffer is unchanged: tier bytes ride the same
    buf→HBM / cross-network legs as freshly-read bytes, so the plan's
    non-load resources equal ``split_read_plan`` with
    ``pe_bytes = pe_snic + pe_tier`` byte-for-byte (property-tested in
    tests/test_tiers.py), and the load legs conserve exactly:
    ``pe_snic + de_snic + pe_tier + de_tier == hit_bytes``.
    """
    assert pe_snic_bytes >= 0 and de_snic_bytes >= 0
    assert pe_tier_bytes >= 0 and de_tier_bytes >= 0
    total = pe_snic_bytes + de_snic_bytes + pe_tier_bytes + de_tier_bytes
    assert total == hit_bytes, (total, hit_bytes)
    pe_total = pe_snic_bytes + pe_tier_bytes
    de_total = de_snic_bytes + de_tier_bytes
    full = hit_bytes + miss_bytes
    legs = [
        # DRAM-tier hits: already staged in that side's buffer — no SNIC
        Leg("pe_tier_hit", pe_tier_bytes, ("pe_tier",), phase="load"),
        Leg("de_tier_hit", de_tier_bytes, ("de_tier",), phase="load"),
        # cold remainder still pays the storage NICs
        Leg("storage_to_pe_buf", pe_snic_bytes,
            ("pe_snic", "pe_dram"), phase="load"),
        Leg("storage_to_de_buf", de_snic_bytes,
            ("de_snic", "de_dram"), phase="load"),
        # downstream movement is source-agnostic (tier == warm buffer)
        Leg("pe_buf_to_pe_hbm", pe_total,
            ("pe_cnic_rd", "pe_cnic_wr", "pe_dram"), layerwise=True),
        Leg("de_buf_to_pe_hbm", de_total,
            ("de_cnic_rd", "de_dram", "net", "pe_cnic_wr"), layerwise=True),
        Leg("pe_hbm_to_de_buf", pe_total + miss_bytes,
            ("pe_cnic_rd", "net", "de_cnic_wr", "de_dram"), layerwise=True),
        Leg("de_buf_to_de_hbm", full,
            ("de_cnic_rd", "de_cnic_wr", "de_dram"), phase="decode_start"),
        Leg("persist_new_kv", miss_bytes + gen_bytes,
            ("de_cnic_rd", "de_cnic_wr", "de_dram", "de_snic"),
            phase="decode"),
    ]
    return [leg for leg in legs if leg.nbytes > 0]


def rebalance_remainder(pe_snic_bytes: int, de_snic_bytes: int,
                        from_side: str, remaining_bytes: int,
                        moved_bytes: int) -> tuple:
    """Hedged split read: re-water-fill part of one side's *remainder*
    onto the other side mid-read, byte-exactly.

    A split read was issued with SNIC shares ``(pe_snic_bytes,
    de_snic_bytes)``; the ``from_side`` leg has straggled with
    ``remaining_bytes`` still unserved, and the hedging policy wants to
    move ``moved_bytes`` of that remainder to the healthy side.  This is
    the pure arithmetic: the move is clamped to what is actually movable
    (never more than the remainder, never more than the side's share —
    bytes already served stay where they were served) and the new
    partition is returned.

    Invariants (property-tested in tests/test_loading.py):

    * conservation — ``new_pe + new_de == pe + de`` exactly;
    * the rebalanced fraction ``moved / remainder`` lies in [0, 1];
    * only SNIC shares move — DRAM-tier hit bytes are not an input, so a
      tier-hit leg can never be re-charged to a storage NIC.
    """
    assert from_side in ("pe", "de"), from_side
    assert pe_snic_bytes >= 0 and de_snic_bytes >= 0
    assert remaining_bytes >= 0
    src = pe_snic_bytes if from_side == "pe" else de_snic_bytes
    assert remaining_bytes <= src, (remaining_bytes, src)
    moved = max(0, min(int(moved_bytes), int(remaining_bytes)))
    if from_side == "pe":
        new = (pe_snic_bytes - moved, de_snic_bytes + moved)
    else:
        new = (pe_snic_bytes + moved, de_snic_bytes - moved)
    assert new[0] + new[1] == pe_snic_bytes + de_snic_bytes
    assert new[0] >= 0 and new[1] >= 0
    return new


def hedge_water_fill(remainder: int, severity: float,
                     healthy_backlog: int = 0) -> int:
    """How much of a straggling leg's remainder to move to the healthy
    side: the water-fill that equalises both sides' completion.

    The straggler serves at ``1/severity`` of the healthy side's rate
    (``severity`` >= 1 is the observed service-time ratio); the healthy
    side already has ``healthy_backlog`` units queued.  Moving ``x``
    equalises ``healthy_backlog + x == (remainder - x) * severity``::

        x = (severity * remainder - healthy_backlog) / (1 + severity)

    clamped to ``[0, remainder]``.  Monotone non-decreasing in
    ``severity`` (d/ds = (remainder + backlog)/(1+s)^2 > 0) and exactly
    0 when the straggler is healthy and unloaded (s=1, backlog >=
    remainder) — both property-tested in tests/test_scheduler.py.
    Units are caller's choice (bytes or tokens), as long as they match.
    """
    assert remainder >= 0 and healthy_backlog >= 0
    assert severity >= 1.0, severity
    x = (severity * remainder - healthy_backlog) / (1.0 + severity)
    return max(0, min(int(x), int(remainder)))


def hedge_water_fill_batch(remainder: np.ndarray, severity: np.ndarray,
                           healthy_backlog: np.ndarray) -> np.ndarray:
    """:func:`hedge_water_fill` over request arrays, element-exact.

    ``int(x)`` truncates toward zero and so does ``astype(int64)`` for
    the post-clamp range, so each element equals the scalar kernel
    bit-for-bit (property-tested in tests/test_vectorized.py)."""
    remainder = np.asarray(remainder, dtype=np.int64)
    x = ((severity * remainder - healthy_backlog) /
         (1.0 + np.asarray(severity, dtype=np.float64)))
    return np.maximum(0, np.minimum(x.astype(np.int64), remainder))


def resource_bytes_batch(mode: str, hit: np.ndarray, miss: np.ndarray,
                         gen: np.ndarray,
                         pe_snic: Optional[np.ndarray] = None,
                         de_snic: Optional[np.ndarray] = None,
                         pe_tier: Optional[np.ndarray] = None,
                         de_tier: Optional[np.ndarray] = None,
                         ) -> Dict[str, np.ndarray]:
    """``resource_bytes(plan_for(...))`` closed over request arrays.

    One call gives the per-resource byte ledger for a whole fleet of
    requests at once — the quantity the fleet benchmark and the
    byte-conservation property tests sum, without building ``Leg``
    objects per request.  ``mode`` is the plan family:

    * ``"dualpath"`` — the unified tiered/split algebra.  The hit
      partition ``(pe_snic, de_snic, pe_tier, de_tier)`` must sum to
      ``hit`` elementwise; pure Fig. 4a/4b paths are the degenerate
      partitions (everything on one SNIC), plain splits have zero tier
      columns, so one formula covers ``pe``/``de``/split/tiered plans.
    * ``"basic"`` / ``"oracle"`` — the baselines (partition ignored).

    Equality with the per-request ``resource_bytes(plan_for(...))``
    dict, key by key and element by element, is the contract
    (tests/test_vectorized.py checks it over randomized workloads).
    Zero-valued entries are kept: absent resource == zero bytes.
    """
    hit = np.asarray(hit, dtype=np.int64)
    miss = np.asarray(miss, dtype=np.int64)
    gen = np.asarray(gen, dtype=np.int64)
    z = np.zeros_like(hit)
    full = hit + miss
    persist = miss + gen
    if mode == "oracle":
        keys = ("pe_snic", "de_snic", "pe_dram", "de_dram", "pe_cnic_rd",
                "pe_cnic_wr", "de_cnic_rd", "de_cnic_wr", "net",
                "pe_tier", "de_tier")
        return {k: z.copy() for k in keys}
    if mode == "basic":
        return {
            "pe_snic": hit.copy(),
            "pe_dram": 2 * hit,
            "pe_cnic_rd": hit + full,
            "pe_cnic_wr": hit.copy(),
            "net": full.copy(),
            "de_cnic_wr": full + persist,
            "de_cnic_rd": persist.copy(),
            "de_dram": persist.copy(),
            "de_snic": persist.copy(),
            "pe_tier": z.copy(),
            "de_tier": z.copy(),
        }
    if mode != "dualpath":
        raise ValueError(f"mode {mode!r} (valid: dualpath, basic, oracle)")
    pe_snic = z if pe_snic is None else np.asarray(pe_snic, dtype=np.int64)
    de_snic = z if de_snic is None else np.asarray(de_snic, dtype=np.int64)
    pe_tier = z if pe_tier is None else np.asarray(pe_tier, dtype=np.int64)
    de_tier = z if de_tier is None else np.asarray(de_tier, dtype=np.int64)
    part = pe_snic + de_snic + pe_tier + de_tier
    if not np.array_equal(part, hit):
        raise ValueError("hit partition does not sum to hit_bytes")
    pe_total = pe_snic + pe_tier
    de_total = de_snic + de_tier
    fwd = pe_total + miss                 # pe_hbm_to_de_buf leg
    return {
        "pe_snic": pe_snic.copy(),
        "de_snic": de_snic + persist,
        "pe_tier": pe_tier.copy(),
        "de_tier": de_tier.copy(),
        "pe_dram": pe_snic + pe_total,
        "de_dram": de_snic + de_total + fwd + full + persist,
        "pe_cnic_rd": pe_total + fwd,
        "pe_cnic_wr": pe_total + de_total,
        "de_cnic_rd": de_total + full + persist,
        "de_cnic_wr": fwd + full + persist,
        "net": de_total + fwd,
    }


PLANS = {
    "pe": pe_read_plan,
    "de": de_read_plan,
    "basic": basic_plan,
    "oracle": oracle_plan,
}


def plan_for(read_path: str, read_split: float, hit_bytes: int,
             miss_bytes: int, gen_bytes: int,
             tier: Optional[tuple] = None) -> List[Leg]:
    """The legs a scheduled request actually executes.

    ``read_path``/``read_split`` come straight from the scheduler
    (core/scheduler.py): ``read_split`` is the fraction of hit bytes
    read on the ``read_path`` side; 1.0 means a pure Fig. 4a/4b plan,
    anything below means a split plan.  The simulator, the engines and
    the tests all dispatch through here so the byte accounting cannot
    diverge between them.

    ``tier`` — optional explicit hit partition
    ``(pe_snic, de_snic, pe_tier, de_tier)`` in bytes (from
    ``Request.hit_bytes_partition``) for requests whose hit is partly
    served by a node-local DRAM tier; it overrides the
    ``read_split``-derived partition and must sum to ``hit_bytes``.
    """
    if tier is not None:
        return tiered_read_plan(hit_bytes, miss_bytes, gen_bytes, *tier)
    if read_path not in PLANS:
        raise ValueError(
            f"read_path {read_path!r} (valid: {sorted(PLANS)}); did the "
            f"scheduler choose a path for this request yet?")
    if read_split >= 1.0 or read_path not in ("pe", "de"):
        return PLANS[read_path](hit_bytes, miss_bytes, gen_bytes)
    pe_frac = read_split if read_path == "pe" else 1.0 - read_split
    pe_bytes = int(hit_bytes * pe_frac)
    return split_read_plan(hit_bytes, miss_bytes, gen_bytes, pe_bytes)


def resource_bytes(plan: List[Leg]) -> dict:
    """Aggregate bytes per symbolic resource — the quantity the §4.2
    analysis constrains.  Used by tests to pin the plan against Eq. 1–8."""
    out: dict = {}
    for leg in plan:
        for r in leg.resources:
            out[r] = out.get(r, 0) + leg.nbytes
    return out
