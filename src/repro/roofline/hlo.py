"""HLO-text analysis: loop-aware FLOPs, bytes and collective volume.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body **once**
(verified empirically: a scan of 7 matmuls reports 1 matmul of FLOPs),
which under-counts scanned-layer models by ~n_layers×n_microbatches.
This parser rebuilds the numbers from the compiled HLO text with a
computation call graph and trip-count multiplication:

* FLOPs        — 2·prod(out_dims)·prod(contracting_dims) per ``dot``;
* bytes        — per top-level instruction, operand+result shape bytes
                 (fusions appear as single instructions, so this matches
                 HloCostAnalysis fusion semantics); parameters, tuples,
                 GTEs, bitcasts and control ops are excluded;
* collectives  — result-shape bytes per all-gather / all-reduce /
                 reduce-scatter / all-to-all / collective-permute.

Trip counts come from ``known_trip_count`` backend-config hints when
present, else the largest integer constant in the while condition
computation (the scan induction bound), else 1.
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from typing import Dict, List, Tuple

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e4m3b11fnuz": 1,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f32": 4, "s32": 4, "u32": 4,
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
}

_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_DEF = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.*)$")
_OPNAME = re.compile(r"^(?:\([^)]*\)|[^\s(]+)\s+([\w\-]+)\(")
_OPERANDS = re.compile(r"(%[\w.\-]+)")
_SKIP_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "partition-id",
    "replica-id", "copy-start", "copy-done", "all-gather-done",
    "all-reduce-done", "collective-permute-done", "opt-barrier",
    "iota", "rng-bit-generator",
}
_TRIP = re.compile(r"known_trip_count[^0-9]*(\d+)")


def xla_cost_analysis(compiled) -> Dict[str, float]:
    """Normalise ``Compiled.cost_analysis()`` across JAX versions.

    Older JAX returns a bare dict; newer JAX (>= 0.4.3x) returns a
    one-element list of per-device dicts (and an empty list when the
    analysis is unavailable).  Callers always want a flat dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost or {})


def _shape_info(text: str) -> Tuple[int, List[int]]:
    """(total bytes over all shapes, dims of the first shape)."""
    total, first_dims = 0, None
    for m in _SHAPE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        dl = [int(d) for d in dims.split(",")] if dims else []
        n = math.prod(dl) if dl else 1
        total += DTYPE_BYTES[dt] * n
        if first_dims is None:
            first_dims = dl
    return total, (first_dims if first_dims is not None else [])


def shape_bytes(text: str) -> int:
    return _shape_info(text)[0]


def _split_computations(hlo_text: str) -> Dict[str, Tuple[str, list]]:
    """name -> (header_line, body_lines)."""
    comps: Dict[str, Tuple[str, list]] = {}
    cur = None
    header = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\(|->)")
    for line in hlo_text.splitlines():
        ls = line.rstrip()
        if ls.endswith("{") and "->" in ls:
            m = header.match(ls.strip())
            if m:
                cur = m.group(1)
                comps[cur] = (ls, [])
                continue
        if cur is not None and ls.strip() != "}":
            comps[cur][1].append(ls)
    return comps


def parse_hlo_metrics(hlo_text: str) -> Dict[str, float]:
    comps = _split_computations(hlo_text)

    direct: Dict[str, Dict[str, float]] = {}
    calls: Dict[str, list] = defaultdict(list)
    body_cond: Dict[str, str] = {}
    body_tc: Dict[str, int] = {}
    fusion_callees = set()

    for name, (header, lines) in comps.items():
        # symbol table: %name -> (bytes, dims) from defs + header params
        sym: Dict[str, Tuple[int, List[int]]] = {}
        pm = re.search(r"\((.*?)\)\s*->", header)
        if pm:
            for pdecl in re.finditer(r"([\w.\-]+)\s*:\s*([^,()]+(?:\([^)]*\))?)",
                                     pm.group(1)):
                sym["%" + pdecl.group(1)] = _shape_info(pdecl.group(2))
        parsed = []
        for line in lines:
            dm = _DEF.match(line)
            if not dm:
                continue
            lhs_name, rhs = dm.group(1), dm.group(2)
            info = _shape_info(rhs.split("(", 1)[0])
            sym[lhs_name] = info
            parsed.append((lhs_name, rhs, info))

        st = dict(flops=0.0, bytes=0.0, **{k: 0.0 for k in _COLL_KINDS})
        for lhs_name, rhs, (res_bytes, res_dims) in parsed:
            om = _OPNAME.match(rhs)
            op = om.group(1) if om else ""
            if op == "while":
                bm = re.search(r"body=%?([\w.\-]+)", rhs)
                cm = re.search(r"condition=%?([\w.\-]+)", rhs)
                tm = _TRIP.search(rhs)
                if bm:
                    calls[name].append((bm.group(1), "while"))
                    if cm:
                        body_cond[bm.group(1)] = cm.group(1)
                    if tm:
                        body_tc[bm.group(1)] = int(tm.group(1))
                continue
            if op in ("conditional",):
                for cg in re.finditer(
                        r"(?:true_computation|false_computation)=%?([\w.\-]+)",
                        rhs):
                    calls[name].append((cg.group(1), "call"))
                bc = re.search(r"branch_computations=\{([^}]*)\}", rhs)
                if bc:
                    for c in re.split(r"[,\s]+", bc.group(1)):
                        c = c.lstrip("%")
                        if c:
                            calls[name].append((c, "call"))
                continue
            for cg in re.finditer(r"(?:to_apply|calls)=%?([\w.\-]+)", rhs):
                calls[name].append((cg.group(1), "call"))
                if op == "fusion":
                    fusion_callees.add(cg.group(1))
            # collectives
            base_op = op.replace("-start", "")
            if base_op in _COLL_KINDS:
                st[base_op] += res_bytes
            # dot flops
            if op == "dot":
                args = rhs[rhs.index("("):]
                ops_ = _OPERANDS.findall(args.split("),", 1)[0])
                cdm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
                if ops_ and cdm is not None:
                    lhs_dims = sym.get(ops_[0], (0, []))[1]
                    cprod = 1
                    if cdm.group(1):
                        for ci in cdm.group(1).split(","):
                            ci = int(ci)
                            if ci < len(lhs_dims):
                                cprod *= lhs_dims[ci]
                    st["flops"] += 2.0 * math.prod(res_dims or [1]) * cprod
            # bytes: result + operands (fusion == one instruction).
            # dynamic-(update-)slice access only the slice, not the full
            # operand (HloCostAnalysis semantics).
            if op and op not in _SKIP_OPS:
                paren = rhs[rhs.index("("):] if "(" in rhs else ""
                arglist = paren.split("),", 1)[0]
                opnds = _OPERANDS.findall(arglist)
                if op == "dynamic-slice":
                    b = 2 * res_bytes
                elif op == "dynamic-update-slice":
                    upd = sym.get(opnds[1], (0, []))[0] if len(opnds) > 1 \
                        else 0
                    b = 3 * upd
                else:
                    b = res_bytes
                    for opnd in opnds:
                        b += sym.get(opnd, (0, []))[0]
                st["bytes"] += b
        direct[name] = st

    def trip_count(body: str) -> int:
        if body in body_tc:
            return body_tc[body]
        cond = body_cond.get(body)
        if cond and cond in comps:
            consts = [int(x) for x in
                      re.findall(r"constant\((\d+)\)",
                                 "\n".join(comps[cond][1]))]
            big = [c for c in consts if c > 1]
            if big:
                return max(big)
        return 1

    memo: Dict[str, Dict[str, float]] = {}

    def total_of(comp: str) -> Dict[str, float]:
        if comp in memo:
            return memo[comp]
        memo[comp] = defaultdict(float)      # cycle guard
        out = defaultdict(float, direct.get(comp, {}))
        for callee, kind in calls.get(comp, []):
            if callee not in comps:
                continue
            mult = trip_count(callee) if kind == "while" else 1
            sub = total_of(callee)
            for k, v in sub.items():
                # fusion bodies never materialise: the fusion instruction
                # already accounts operand/result bytes — only flops and
                # collectives propagate out of fusion callees
                if k == "bytes" and callee in fusion_callees:
                    continue
                out[k] += v * mult
        memo[comp] = dict(out)
        return memo[comp]

    entry = None
    for line in hlo_text.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w.\-]+)", line)
            if m:
                entry = m.group(1)
                break
    if entry is None or entry not in comps:
        callees = {c for cl in calls.values() for c, _ in cl}
        roots = [c for c in comps if c not in callees and
                 c not in fusion_callees]
        entry = roots[0] if roots else next(iter(comps), None)
    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "collective_bytes": 0.0}
    res = dict(total_of(entry))
    res["collective_bytes"] = sum(res.get(k, 0.0) for k in _COLL_KINDS)
    return res


def parse_collectives(hlo_text: str) -> Dict[str, float]:
    res = parse_hlo_metrics(hlo_text)
    out = {k: v for k, v in res.items() if k in _COLL_KINDS and v}
    out["collective_bytes"] = res["collective_bytes"]
    return out
