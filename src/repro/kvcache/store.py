"""KV-Cache storage backends.

``KVStore`` is the abstract distributed store (the paper uses 3FS);
FullBlocks in, FullBlocks out, with byte accounting so simulators,
benchmarks and tests can observe I/O volume.  ``MemoryKVStore`` holds
real numpy FullBlocks (used by the CPU engines); the simulator uses the
accounting-only subclass (no payloads).
"""
from __future__ import annotations

import itertools
import threading
from typing import Dict, List, Sequence

import numpy as np

from repro.core.blocks import BlockLayout


class KVStore:
    """Abstract FullBlock store with read/write byte accounting."""

    def __init__(self, layout: BlockLayout):
        self.layout = layout
        self._refs = itertools.count(1)
        self.bytes_read = 0
        self.bytes_written = 0
        self.reads = 0
        self.writes = 0

    def alloc_ref(self) -> int:
        return next(self._refs)

    def write_block(self, ref: int, block) -> None:
        self.bytes_written += self.layout.full_block_bytes
        self.writes += 1
        self._put(ref, block)

    def read_block(self, ref: int):
        self.bytes_read += self.layout.full_block_bytes
        self.reads += 1
        return self._get(ref)

    def read_blocks(self, refs: Sequence[int]) -> List:
        return [self.read_block(r) for r in refs]

    def peek(self, ref: int):
        """Payload access with NO byte accounting — for warming a DRAM
        tier with blocks that already moved through the node (e.g. the
        decode side's full context at round end): those bytes were paid
        by the plan legs that staged them, so peeking must not charge
        the storage NIC a second time."""
        return self._get(ref)

    # storage-layer hooks
    def _put(self, ref, block):  # pragma: no cover - abstract
        raise NotImplementedError

    def _get(self, ref):  # pragma: no cover - abstract
        raise NotImplementedError


class MemoryKVStore(KVStore):
    """In-memory FullBlock store (engine runtime / tests)."""

    def __init__(self, layout: BlockLayout):
        super().__init__(layout)
        self._data: Dict[int, np.ndarray] = {}
        self._lock = threading.Lock()

    def _put(self, ref: int, block: np.ndarray):
        assert block.shape == self.layout.full_block_shape(), (
            block.shape, self.layout.full_block_shape())
        with self._lock:
            self._data[ref] = block

    def _get(self, ref: int) -> np.ndarray:
        with self._lock:
            return self._data[ref]

    def delete(self, refs: Sequence[int]):
        with self._lock:
            for r in refs:
                self._data.pop(r, None)

    @property
    def stored_bytes(self) -> int:
        return len(self._data) * self.layout.full_block_bytes


class AccountingKVStore(KVStore):
    """Byte-accounting-only store for the discrete-event simulator."""

    def _put(self, ref, block):
        pass

    def _get(self, ref):
        return None


class StateBlobStore:
    """Exact-prefix state snapshots for SSM/hybrid archs.

    Attention-free layers have no per-token KV — their 'cache' is the
    O(1) recurrent state, only reusable at the exact prefix where it was
    snapshotted.  Agentic replay continues exactly at the previous round
    end, so an exact-match store mirrors the trie's role (DESIGN.md §5).
    """

    def __init__(self):
        self._blobs: Dict[tuple, tuple] = {}
        self.bytes_read = 0
        self.bytes_written = 0

    def put(self, key_tokens: Sequence[int], blob: bytes, length: int):
        self._blobs[tuple(key_tokens)] = (blob, length)
        self.bytes_written += len(blob)

    def get(self, key_tokens: Sequence[int]):
        hit = self._blobs.get(tuple(key_tokens))
        if hit is None:
            return None, 0
        self.bytes_read += len(hit[0])
        return hit
