from repro.kvcache.store import AccountingKVStore, KVStore, MemoryKVStore
from repro.kvcache.tiers import (AgenticTTLPolicy, DramTier, LRUPolicy,
                                 ThinkTimePrefetcher, make_policy)
from repro.kvcache.trie import BlockTrie

__all__ = ["AccountingKVStore", "KVStore", "MemoryKVStore", "BlockTrie",
           "DramTier", "LRUPolicy", "AgenticTTLPolicy",
           "ThinkTimePrefetcher", "make_policy"]
