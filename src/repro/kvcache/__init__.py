from repro.kvcache.store import AccountingKVStore, KVStore, MemoryKVStore
from repro.kvcache.trie import BlockTrie

__all__ = ["AccountingKVStore", "KVStore", "MemoryKVStore", "BlockTrie"]
