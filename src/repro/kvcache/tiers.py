"""Tiered KV-Cache: a capacity-bounded node-local DRAM tier.

The remote ``KVStore`` (3FS in the paper) is reachable only through the
storage NIC, so every hit byte a round-start read pulls pays the SNIC —
the exact resource DualPath identifies as the bottleneck.  ``DramTier``
layers a node-local DRAM cache over the store: blocks staged there are
served at round start without touching the SNIC, turning the
storage-to-decode path into a cache-warming path.

Design points (mirroring DUAL-BLADE's dual-path offloading and the
heterogeneous-memory KV-placement line of work, PAPERS.md):

* **capacity-bounded** — admissions never push ``used_bytes`` past
  ``capacity_bytes``; if eviction cannot free enough space the admission
  is *rejected* (the block simply stays remote), never over-committed;
* **ref-count pinning** — blocks referenced by an in-flight request (or
  otherwise held, e.g. by the trie) carry a pin count and are never
  eviction victims; a fully-pinned tier rejects admissions rather than
  evict pinned data;
* **pluggable eviction** — ``LRUPolicy`` (recency) and
  ``AgenticTTLPolicy`` (trajectory liveness: blocks of finished
  trajectories first, then blocks whose trajectory has been idle past a
  TTL, then LRU) choose victims;
* **dual accounting/payload use** — with a ``backing`` store the tier
  serves *real* FullBlocks (serving/engines); without one it is a pure
  occupancy model (the discrete-event simulator drives admissions and
  reads itself and charges resources from the loading plans).

``ThinkTimePrefetcher`` is the policy half of the inter-round prefetch:
given the predicted next-round hit refs it plans which missing blocks to
stage (in chunks, so a round start mid-prefetch still finds a useful
resident prefix).  The *mechanism* — moving the bytes — belongs to the
caller: the simulator enqueues chunk reads on the storage-NIC FIFO, the
serving system reads through the backing store.
"""
from __future__ import annotations

import itertools
from collections import OrderedDict
from dataclasses import dataclass
from typing import Callable, Dict, Hashable, Iterable, Iterator, List, \
    Optional, Sequence, Set


@dataclass
class TierEntry:
    ref: Hashable
    nbytes: int
    owner: Optional[Hashable] = None      # trajectory / session id
    payload: object = None                # FullBlock (None in sim mode)
    last_used: float = 0.0
    pins: int = 0
    prefetched: bool = False


class EvictionPolicy:
    """Victim selection strategy.  ``victims`` yields *candidate* entries
    in eviction order; the tier skips pinned ones and stops once enough
    bytes are freed."""

    name = "base"

    def victims(self, tier: "DramTier", now: float) -> Iterator[TierEntry]:
        raise NotImplementedError  # pragma: no cover - abstract


class LRUPolicy(EvictionPolicy):
    """Least-recently-used: the tier keeps entries in recency order."""

    name = "lru"

    def victims(self, tier: "DramTier", now: float) -> Iterator[TierEntry]:
        # lazy: the tier collects victims and drops them only after the
        # iteration stops, so no copy of the entry table is needed and a
        # satisfied eviction touches only the stale front of the order
        yield from tier._entries.values()


class AgenticTTLPolicy(EvictionPolicy):
    """Trajectory-liveness eviction for agentic workloads.

    A trajectory's blocks stay useful exactly as long as the trajectory
    is alive: once the agent finishes, its KV prefix will never be hit
    again (hits occur only within a trajectory, paper §A.4).  Victim
    order is therefore

    1. blocks of trajectories marked *done* (``note_done``),
    2. blocks whose trajectory has been idle longer than ``ttl_s``
       (agent abandoned / stuck in a long tool call),
    3. plain LRU over the rest.
    """

    name = "agentic-ttl"

    def __init__(self, ttl_s: float = 120.0):
        self.ttl_s = ttl_s

    def victims(self, tier: "DramTier", now: float) -> Iterator[TierEntry]:
        done = tier._done_owners
        for owner in list(done):                # 1. dead trajectories
            for ref in list(tier._by_owner.get(owner, ())):
                e = tier._entries.get(ref)
                if e is not None:
                    yield e
        # owner liveness is evaluated once per eviction pass (owners are
        # few — one per trajectory — so this stays cheap under pressure)
        expired = {o for o, last in tier._owner_alive.items()
                   if o not in done and now - last > self.ttl_s}
        if expired:
            for e in tier._entries.values():    # 2. TTL-expired
                if e.owner in expired:
                    yield e
        for e in tier._entries.values():        # 3. LRU fallback
            if e.owner not in done and e.owner not in expired:
                yield e


def make_policy(name: str, **kw) -> EvictionPolicy:
    if isinstance(name, EvictionPolicy):
        return name
    if name == "lru":
        return LRUPolicy()
    if name in ("agentic-ttl", "ttl"):
        ttl = kw.get("ttl_s")
        return AgenticTTLPolicy(ttl) if ttl is not None else \
            AgenticTTLPolicy()
    raise ValueError(f"unknown tier eviction policy {name!r} "
                     f"(valid: lru, agentic-ttl)")


class DramTier:
    """Node-local DRAM tier over a remote KVStore.

    With ``backing`` set the tier duck-types the store's hot-path API
    (``alloc_ref`` / ``read_block`` / ``read_blocks`` / ``write_block``)
    so engines can be pointed at it transparently: reads served from
    DRAM never reach the backing store (no SNIC bytes), misses read
    through and are admitted, writes write through and warm the tier.
    """

    #: optional flight recorder (repro.obs.Tracer) + track label,
    #: attached by the owning runtime; None = untraced
    tracer = None
    track = "tier"

    def __init__(self, capacity_bytes: float, policy="lru",
                 backing=None, ttl_s: Optional[float] = None):
        self.capacity_bytes = float(capacity_bytes)
        kw = {"ttl_s": ttl_s} if ttl_s is not None else {}
        self.policy = make_policy(policy, **kw)
        self.backing = backing
        self._entries: "OrderedDict[Hashable, TierEntry]" = OrderedDict()
        self._by_owner: Dict[Hashable, Set[Hashable]] = {}
        self._owner_alive: Dict[Hashable, float] = {}
        self._done_owners: Set[Hashable] = set()
        self._tick = itertools.count()
        # owner-provided wall clock (e.g. ServingSystem._tier_now):
        # consulted before the per-operation tick fallback, so call
        # sites that cannot thread ``now`` through (engine persists via
        # the plain store interface) still stamp modelled seconds —
        # otherwise ``tier_ttl_s`` silently means *operations* there
        self.clock_fn: Optional[Callable[[], float]] = None
        self.used_bytes = 0
        self._pinned_bytes = 0
        # --- accounting -------------------------------------------------
        self.dram_hit_bytes = 0       # hit bytes served from DRAM (no SNIC)
        self.miss_bytes = 0           # demand reads through the backing store
        self.prefetch_bytes = 0       # bytes staged ahead of demand
        self.evicted_bytes = 0
        self.rejected_bytes = 0       # admissions refused (pinned/capacity)
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    # ------------------------------------------------------------------
    # occupancy queries
    # ------------------------------------------------------------------
    @property
    def free_bytes(self) -> float:
        return self.capacity_bytes - self.used_bytes

    def __len__(self) -> int:
        return len(self._entries)

    def contains(self, ref) -> bool:
        return ref in self._entries

    def resident_prefix(self, refs: Sequence) -> int:
        """Number of *leading* refs resident — hit lengths are always
        prefixes (trie granularity), so only a resident prefix can be
        served without a hole."""
        n = 0
        for r in refs:
            if r not in self._entries:
                break
            n += 1
        return n

    # ------------------------------------------------------------------
    # pinning (in-flight requests / trie holds)
    # ------------------------------------------------------------------
    def pin(self, refs: Iterable) -> None:
        n_pinned = 0
        for r in refs:
            e = self._entries.get(r)
            if e is not None:
                if e.pins == 0:
                    self._pinned_bytes += e.nbytes
                e.pins += 1
                n_pinned += 1
        if n_pinned and self.tracer is not None:
            self.tracer.event(self.track, "pin", n=n_pinned,
                              pinned_bytes=self._pinned_bytes)

    def unpin(self, refs: Iterable) -> None:
        for r in refs:
            e = self._entries.get(r)
            if e is not None and e.pins > 0:
                e.pins -= 1
                if e.pins == 0:
                    self._pinned_bytes -= e.nbytes

    def pinned_bytes(self) -> int:
        return self._pinned_bytes

    def can_admit(self, nbytes: int) -> bool:
        """Whether an admission of ``nbytes`` could possibly succeed:
        free space plus every evictable (unpinned) byte covers it.
        Lets callers (e.g. the prefetcher) skip paying a backing-store
        read for data the tier would immediately reject."""
        return 0 < nbytes <= self.capacity_bytes - self._pinned_bytes

    # ------------------------------------------------------------------
    # trajectory liveness (AgenticTTLPolicy signals)
    # ------------------------------------------------------------------
    def note_alive(self, owner, now: Optional[float] = None) -> None:
        if owner is None:
            return
        self._owner_alive[owner] = self._now(now)
        self._done_owners.discard(owner)

    def note_done(self, owner) -> None:
        if owner is None:
            return
        if not self._by_owner.get(owner):
            # no blocks left: purge immediately so long-lived deployments
            # don't accumulate one bookkeeping record per dead trajectory
            self._forget_owner(owner)
        else:
            self._done_owners.add(owner)

    def _forget_owner(self, owner) -> None:
        self._by_owner.pop(owner, None)
        self._owner_alive.pop(owner, None)
        self._done_owners.discard(owner)

    # ------------------------------------------------------------------
    # admission / eviction
    # ------------------------------------------------------------------
    def _now(self, now: Optional[float]) -> float:
        if now is not None:
            return float(now)
        if self.clock_fn is not None:
            return float(self.clock_fn())
        return float(next(self._tick))

    def touch(self, refs: Iterable, now: Optional[float] = None) -> None:
        t = self._now(now)
        for r in refs:
            e = self._entries.get(r)
            if e is not None:
                e.last_used = t
                self._entries.move_to_end(r)

    def admit(self, ref, nbytes: int, owner=None, payload=None,
              now: Optional[float] = None, prefetch: bool = False) -> bool:
        """Stage one block; returns False when it cannot fit (eviction
        could not free enough unpinned bytes).  Re-admitting a resident
        ref refreshes recency (and payload, if one is supplied)."""
        t = self._now(now)
        e = self._entries.get(ref)
        if e is not None:
            e.last_used = t
            if payload is not None:
                e.payload = payload
            if owner is not None:
                self._reown(e, owner)
            self._entries.move_to_end(ref)
            return True
        nbytes = int(nbytes)
        if nbytes > self.capacity_bytes or nbytes <= 0:
            self.rejected_bytes += max(nbytes, 0)
            return False
        if self.used_bytes + nbytes > self.capacity_bytes and \
                not self._evict(self.used_bytes + nbytes -
                                self.capacity_bytes, t):
            self.rejected_bytes += nbytes
            return False
        e = TierEntry(ref=ref, nbytes=nbytes, owner=owner, payload=payload,
                      last_used=t, prefetched=prefetch)
        self._entries[ref] = e
        self.used_bytes += nbytes
        if owner is not None:
            self._by_owner.setdefault(owner, set()).add(ref)
        if prefetch:
            self.prefetch_bytes += nbytes
            if self.tracer is not None:
                self.tracer.event(self.track, "prefetch_admit",
                                  nbytes=nbytes)
        return True

    def _reown(self, e: TierEntry, owner) -> None:
        if e.owner == owner:
            return
        if e.owner is not None:
            self._by_owner.get(e.owner, set()).discard(e.ref)
        e.owner = owner
        self._by_owner.setdefault(owner, set()).add(e.ref)

    def _evict(self, need_bytes: float, now: float) -> bool:
        """Free at least ``need_bytes`` of *unpinned* entries, in policy
        order.  Returns False if the tier cannot free enough."""
        freed = 0.0
        victims: List[TierEntry] = []
        for e in self.policy.victims(self, now):
            if freed >= need_bytes:
                break
            if e.pins > 0 or e.ref not in self._entries:
                continue
            victims.append(e)
            freed += e.nbytes
        if freed < need_bytes:
            return False
        for e in victims:
            self._drop(e)
        return True

    def _drop(self, e: TierEntry) -> None:
        self._entries.pop(e.ref, None)
        self.used_bytes -= e.nbytes
        self.evicted_bytes += e.nbytes
        self.evictions += 1
        if self.tracer is not None:
            self.tracer.event(self.track, "evict", nbytes=e.nbytes)
            self.tracer.counter(f"{self.track}/occupancy",
                                used_bytes=self.used_bytes)
        if e.owner is not None:
            held = self._by_owner.get(e.owner)
            if held is not None:
                held.discard(e.ref)
                if not held and e.owner in self._done_owners:
                    self._forget_owner(e.owner)   # last dead block gone

    def evict_bytes(self, nbytes: float, now: Optional[float] = None) -> bool:
        """External pressure hook (tests / capacity rebalancing)."""
        return self._evict(nbytes, self._now(now))

    # ------------------------------------------------------------------
    # accounting-only serving (the simulator's path)
    # ------------------------------------------------------------------
    def serve(self, refs: Sequence, now: Optional[float] = None) -> int:
        """Mark ``refs`` (all resident) as served from DRAM; returns the
        byte count.  The simulator calls this for the resident prefix it
        charged to the ``*_tier`` plan leg."""
        t = self._now(now)
        served = 0
        for r in refs:
            e = self._entries[r]
            e.last_used = t
            self._entries.move_to_end(r)
            served += e.nbytes
            self.hits += 1
        self.dram_hit_bytes += served
        return served

    # ------------------------------------------------------------------
    # payload serving (KVStore duck-type for engines / serving)
    # ------------------------------------------------------------------
    @property
    def layout(self):
        return self.backing.layout

    def alloc_ref(self) -> int:
        return self.backing.alloc_ref()

    def read_block(self, ref, owner=None, now: Optional[float] = None):
        e = self._entries.get(ref)
        if e is not None and e.payload is not None:
            t = self._now(now)
            e.last_used = t
            self._entries.move_to_end(ref)
            self.hits += 1
            self.dram_hit_bytes += e.nbytes
            return e.payload
        block = self.backing.read_block(ref)       # SNIC read-through
        nbytes = self.backing.layout.full_block_bytes
        self.misses += 1
        self.miss_bytes += nbytes
        self.admit(ref, nbytes, owner=owner, payload=block, now=now)
        return block

    def read_blocks(self, refs: Sequence, owner=None,
                    now: Optional[float] = None) -> List:
        return [self.read_block(r, owner=owner, now=now) for r in refs]

    def write_block(self, ref, block, owner=None,
                    now: Optional[float] = None) -> None:
        """Write-through + tier warm-up: the block just materialised in
        this node's DRAM buffer on its way to storage, so admit it."""
        self.backing.write_block(ref, block)
        self.admit(ref, self.backing.layout.full_block_bytes, owner=owner,
                   payload=block, now=now)

    def prefetch_block(self, ref, owner=None,
                       now: Optional[float] = None) -> int:
        """Stage one block from the backing store ahead of demand;
        returns the bytes moved (0 if already resident or inadmissible).
        The admissibility check runs BEFORE the backing read: a full or
        heavily-pinned tier must not burn the very SNIC bandwidth the
        prefetch exists to save on data it would immediately drop."""
        if ref in self._entries:
            self.touch([ref], now)
            return 0
        nbytes = self.backing.layout.full_block_bytes
        if not self.can_admit(nbytes):
            return 0
        block = self.backing.read_block(ref)
        if self.admit(ref, nbytes, owner=owner, payload=block, now=now,
                      prefetch=True):
            return nbytes
        return 0

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        return dict(
            used_bytes=self.used_bytes,
            capacity_bytes=self.capacity_bytes,
            entries=len(self._entries),
            dram_hit_bytes=self.dram_hit_bytes,
            miss_bytes=self.miss_bytes,
            prefetch_bytes=self.prefetch_bytes,
            evicted_bytes=self.evicted_bytes,
            rejected_bytes=self.rejected_bytes,
            hits=self.hits, misses=self.misses, evictions=self.evictions,
        )


class ThinkTimePrefetcher:
    """Plans which predicted next-round hit blocks to stage during the
    inter-round think gap.

    Between rounds an agent thinks (tool calls, environment latency) and
    the storage NICs sit idle; this window is free bandwidth.  The
    predicted hit for the next round is the trajectory's current context
    — exactly the blocks the trie would match — so the plan is simply
    the non-resident ones, chunked so that a round starting mid-prefetch
    still finds a useful resident *prefix* (chunks are staged in order).
    """

    def __init__(self, chunk_blocks: int = 32):
        self.chunk_blocks = max(int(chunk_blocks), 1)
        self.rounds_planned = 0
        self.blocks_planned = 0

    def plan(self, tier: DramTier, refs: Sequence) -> List[List]:
        """Missing refs, in order, grouped into stage-order chunks."""
        missing = [r for r in refs if not tier.contains(r)]
        self.rounds_planned += 1
        self.blocks_planned += len(missing)
        return [missing[i:i + self.chunk_blocks]
                for i in range(0, len(missing), self.chunk_blocks)]
