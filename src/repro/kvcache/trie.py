"""Trie-indexed KV-Cache store (paper §4.1/§A.5).

"KV-Cache is stored in distributed storage using a trie structure, where
each tree node corresponds to a Full Block."  Keys are whole token
blocks (block_tokens ids); a prefix match walks the trie block-by-block,
so hit lengths are always multiples of the block size — exactly the
granularity the loading paths move.

Per §A.4 the hit length is computed client-side (no eviction inside a
trajectory); the trie supports optional LRU eviction for the shared
online-serving working set.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


@dataclass
class _Node:
    ref: Optional[int] = None                 # FullBlock storage ref
    children: Dict[Tuple[int, ...], "_Node"] = field(default_factory=dict)
    last_used: int = 0


class BlockTrie:
    def __init__(self, block_tokens: int):
        self.block_tokens = block_tokens
        self.root = _Node()
        self._clock = itertools.count()
        self.n_blocks = 0

    # ------------------------------------------------------------------
    def _blocks_of(self, tokens: Sequence[int]):
        bt = self.block_tokens
        n = len(tokens) // bt
        for i in range(n):
            yield tuple(tokens[i * bt:(i + 1) * bt])

    def match(self, tokens: Sequence[int]) -> Tuple[int, List[int]]:
        """Longest cached prefix: returns (hit_tokens, block refs)."""
        node, refs = self.root, []
        tick = next(self._clock)
        for key in self._blocks_of(tokens):
            child = node.children.get(key)
            if child is None or child.ref is None:
                break
            child.last_used = tick
            refs.append(child.ref)
            node = child
        return len(refs) * self.block_tokens, refs

    def insert(self, tokens: Sequence[int],
               new_refs: Sequence[int]) -> List[int]:
        """Insert blocks covering ``tokens``; ``new_refs`` supplies storage
        refs for blocks not yet present (consumed in order).  Returns the
        refs of the newly-inserted blocks."""
        node = self.root
        it = iter(new_refs)
        inserted = []
        tick = next(self._clock)
        for key in self._blocks_of(tokens):
            child = node.children.get(key)
            if child is None:
                child = _Node(ref=next(it))
                node.children[key] = child
                inserted.append(child.ref)
                self.n_blocks += 1
            child.last_used = tick
            node = child
        return inserted

    def missing_blocks(self, tokens: Sequence[int]) -> int:
        """Number of whole blocks of ``tokens`` not yet in the trie."""
        hit, _ = self.match(tokens)
        return len(tokens) // self.block_tokens - hit // self.block_tokens

    # ------------------------------------------------------------------
    def evict_lru(self, n: int) -> List[int]:
        """Evict up to n least-recently-used *leaf* blocks; returns refs."""
        out = []
        for _ in range(n):
            leaf = self._lru_leaf()
            if leaf is None:
                break
            parent, key, child = leaf
            del parent.children[key]
            if child.ref is not None:
                out.append(child.ref)
                self.n_blocks -= 1
        return out

    def _lru_leaf(self):
        best = None

        def walk(node):
            nonlocal best
            for key, child in node.children.items():
                if not child.children:
                    if best is None or child.last_used < best[2].last_used:
                        best = (node, key, child)
                else:
                    walk(child)

        walk(self.root)
        return best
