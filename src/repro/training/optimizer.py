"""Optimizers: AdamW and Adafactor, with configurable state dtype.

Optimizer state is a pytree mirroring the params, so it inherits the
parameter sharding (FSDP-sharded params ⇒ FSDP-sharded moments): that is
what lets the llama4-maverick train_4k cell fit 16 GB/chip (DESIGN.md §4
— Adafactor + bf16 accumulators there).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Tree = Any


def _cast(tree, dtype):
    return jax.tree.map(lambda a: a.astype(dtype), tree)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw_init(params, state_dtype: str = "float32") -> Dict:
    dt = jnp.dtype(state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, state, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1) -> Tuple[Tree, Dict]:
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + g32 * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + jnp.square(g32) * (1 - b2)
        u = (m32 / c1) / (jnp.sqrt(v32 / c2) + eps)
        u = u + weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * u
        return newp.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    flat = jax.tree.map(upd, params, grads, state["m"], state["v"])
    newp = jax.tree.map(lambda t3: t3[0], flat,
                        is_leaf=lambda x: isinstance(x, tuple))
    newm = jax.tree.map(lambda t3: t3[1], flat,
                        is_leaf=lambda x: isinstance(x, tuple))
    newv = jax.tree.map(lambda t3: t3[2], flat,
                        is_leaf=lambda x: isinstance(x, tuple))
    return newp, {"m": newm, "v": newv, "step": step}


# ---------------------------------------------------------------------------
# Adafactor (factored second moment for >=2D params)
# ---------------------------------------------------------------------------


def adafactor_init(params, state_dtype: str = "float32") -> Dict:
    dt = jnp.dtype(state_dtype)

    def init(p):
        if p.ndim >= 2:
            return {"vr": jnp.zeros(p.shape[:-1], dt),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], dt)}
        return {"v": jnp.zeros(p.shape, dt)}

    return {"fac": jax.tree.map(init, params,
                                is_leaf=lambda x: hasattr(x, "ndim")),
            "step": jnp.zeros((), jnp.int32)}


def adafactor_update(params, grads, state, *, lr, decay=0.8, eps=1e-30,
                     clip_threshold=1.0, weight_decay=0.0):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    beta2 = 1.0 - jnp.power(t, -decay)

    def upd(p, g, s):
        g32 = g.astype(jnp.float32)
        g2 = jnp.square(g32) + eps
        if p.ndim >= 2:
            vr = s["vr"].astype(jnp.float32) * beta2 + \
                jnp.mean(g2, axis=-1) * (1 - beta2)
            vc = s["vc"].astype(jnp.float32) * beta2 + \
                jnp.mean(g2, axis=-2) * (1 - beta2)
            denom = jnp.sqrt(
                vr[..., None] / jnp.mean(vr, axis=-1, keepdims=True)[..., None]
                * vc[..., None, :])
            u = g32 / jnp.maximum(denom, 1e-30)
            news = {"vr": vr.astype(s["vr"].dtype),
                    "vc": vc.astype(s["vc"].dtype)}
        else:
            v = s["v"].astype(jnp.float32) * beta2 + g2 * (1 - beta2)
            u = g32 / jnp.sqrt(v + 1e-30)
            news = {"v": v.astype(s["v"].dtype)}
        rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
        u = u / jnp.maximum(1.0, rms / clip_threshold)
        newp = p.astype(jnp.float32) - lr * (u + weight_decay *
                                             p.astype(jnp.float32))
        return newp.astype(p.dtype), news

    pairs = jax.tree.map(upd, params, grads, state["fac"],
                         is_leaf=lambda x: hasattr(x, "ndim"))
    # pairs has tuples at param leaves
    is_pair = lambda x: isinstance(x, tuple)
    newp = jax.tree.map(lambda pr: pr[0], pairs, is_leaf=is_pair)
    news = jax.tree.map(lambda pr: pr[1], pairs, is_leaf=is_pair)
    return newp, {"fac": news, "step": step}


def make_optimizer(name: str, state_dtype: str = "float32"):
    if name == "adamw":
        return (partial(adamw_init, state_dtype=state_dtype), adamw_update)
    if name == "adafactor":
        return (partial(adafactor_init, state_dtype=state_dtype),
                adafactor_update)
    raise ValueError(name)
