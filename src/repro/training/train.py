"""Training step: microbatched gradient accumulation + per-layer remat.

``make_train_step(cfg)`` builds the jit-able step the dry-run lowers for
the train_4k cells: batch (global_batch, seq) int32 tokens; loss is
next-token cross-entropy; gradients accumulate over
``cfg.microbatches_train_4k`` microbatches via lax.scan so activation
residency is one microbatch deep (the 400 B llama4 cell fits 16 GB/chip
this way — DESIGN.md §4).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import forward, lm_loss
from repro.training.optimizer import make_optimizer


def loss_fn(params, cfg: ModelConfig, batch, *, moe_impl: str = "ragged",
            remat="full"):
    """batch: {'tokens': (b, s)} for token LMs (causal shift internally)
    or {'inputs': (b, s, frontend_dim), 'labels': (b, s)} for stubbed-
    frontend archs (llava/hubert)."""
    if "tokens" in batch:
        inputs, labels = batch["tokens"][:, :-1], batch["tokens"][:, 1:]
    else:
        inputs, labels = batch["inputs"], batch["labels"]
    logits, _ = forward(params, cfg, inputs, remat=remat, moe_impl=moe_impl)
    return lm_loss(logits, labels)


def make_train_step(cfg: ModelConfig, *, lr: float = 3e-4,
                    moe_impl: str = "ragged",
                    n_microbatches: int | None = None,
                    remat="full"):
    """Returns (init_fn(params)->opt_state, train_step)."""
    opt_init, opt_update = make_optimizer(cfg.optimizer, cfg.opt_state_dtype)
    n_micro = n_microbatches or cfg.microbatches_train_4k

    def train_step(params, opt_state, batch):
        if not isinstance(batch, dict):
            batch = {"tokens": batch}
        gb = jax.tree.leaves(batch)[0].shape[0]
        assert gb % n_micro == 0, (gb, n_micro)
        mb = gb // n_micro
        micro = jax.tree.map(
            lambda a: a.reshape((n_micro, mb) + a.shape[1:]), batch)

        grad_fn = jax.value_and_grad(
            lambda p, mbatch: loss_fn(p, cfg, mbatch, moe_impl=moe_impl,
                                      remat=remat))

        def body(carry, mbatch):
            loss_acc, grads_acc = carry
            loss, grads = grad_fn(params, mbatch)
            grads_acc = jax.tree.map(
                lambda a, g: a + g.astype(a.dtype), grads_acc, grads)
            return (loss_acc + loss, grads_acc), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, grads), _ = jax.lax.scan(body, (jnp.zeros(()), zeros),
                                            micro)
        grads = jax.tree.map(lambda g: g / n_micro, grads)
        new_params, new_opt = opt_update(params, grads, opt_state, lr=lr)
        return new_params, new_opt, loss_sum / n_micro

    return opt_init, train_step
