"""Token data pipeline: deterministic, seeded, checkpointable.

Two sources:
* ``SyntheticLM``   — seeded random token stream (markov-ish bigram bias
  so loss actually decreases);
* ``TrajectoryLM``  — packs agent trajectories (repro.sim.traces) into
  training sequences, the data the paper's RL rollout phase would emit.

State is (seed, step): save/restore is exact — a restarted job resumes
on the same batch sequence, which the fault-tolerance test asserts.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.sim.traces import generate_dataset


@dataclass
class PipelineState:
    seed: int
    step: int


class SyntheticLM:
    def __init__(self, vocab_size: int, batch: int, seq: int, seed: int = 0):
        self.vocab = vocab_size
        self.batch = batch
        self.seq = seq
        self.state = PipelineState(seed=seed, step=0)

    def _rng(self) -> np.random.Generator:
        return np.random.default_rng(
            (self.state.seed, self.state.step))

    def next_batch(self) -> np.ndarray:
        rng = self._rng()
        # bigram structure: next token ~ (prev*7 + noise) mod vocab
        base = rng.integers(0, self.vocab, size=(self.batch, 1))
        noise = rng.integers(0, max(self.vocab // 16, 2),
                             size=(self.batch, self.seq))
        toks = np.zeros((self.batch, self.seq), np.int64)
        toks[:, 0] = base[:, 0]
        for i in range(1, self.seq):
            toks[:, i] = (toks[:, i - 1] * 7 + noise[:, i]) % self.vocab
        self.state.step += 1
        return toks.astype(np.int32)

    # checkpointing
    def state_dict(self) -> dict:
        return dict(seed=self.state.seed, step=self.state.step)

    def load_state_dict(self, d: dict):
        self.state = PipelineState(seed=d["seed"], step=d["step"])


class TrajectoryLM(SyntheticLM):
    """Packs agent-trajectory token streams into fixed-length rows."""

    def __init__(self, vocab_size: int, batch: int, seq: int,
                 max_len: int = 32768, seed: int = 0):
        super().__init__(vocab_size, batch, seq, seed)
        self.trajs = generate_dataset(64, max_len, seed=seed)

    def next_batch(self) -> np.ndarray:
        rng = self._rng()
        rows = []
        for _ in range(self.batch):
            t = self.trajs[rng.integers(0, len(self.trajs))]
            total = t.total_tokens
            toks = rng.integers(0, self.vocab, size=min(total, self.seq))
            if len(toks) < self.seq:
                toks = np.pad(toks, (0, self.seq - len(toks)))
            rows.append(toks)
        self.state.step += 1
        return np.stack(rows).astype(np.int32)
