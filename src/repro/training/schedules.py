"""LR schedules, including MiniCPM's WSD (warmup–stable–decay)."""
from __future__ import annotations

import math


def wsd(step: int, *, peak_lr: float, warmup: int, stable: int,
        decay: int, final_frac: float = 0.1) -> float:
    """Warmup–Stable–Decay (arXiv:2404.06395 §4): linear warmup, long
    constant stage, short exponential-ish decay to final_frac·peak."""
    if step < warmup:
        return peak_lr * (step + 1) / warmup
    if step < warmup + stable:
        return peak_lr
    d = min(step - warmup - stable, decay)
    return peak_lr * final_frac ** (d / max(decay, 1))


def cosine(step: int, *, peak_lr: float, warmup: int, total: int,
           final_frac: float = 0.1) -> float:
    if step < warmup:
        return peak_lr * (step + 1) / warmup
    t = min((step - warmup) / max(total - warmup, 1), 1.0)
    return peak_lr * (final_frac + (1 - final_frac) *
                      0.5 * (1 + math.cos(math.pi * t)))
