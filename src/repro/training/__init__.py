from repro.training.data import SyntheticLM, TrajectoryLM
from repro.training.optimizer import (adafactor_init, adafactor_update,
                                      adamw_init, adamw_update,
                                      make_optimizer)
from repro.training.schedules import cosine, wsd
from repro.training.train import loss_fn, make_train_step
