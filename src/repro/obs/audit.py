"""Trace audit: cross-validate span sums against conservation ledgers.

A trace that silently drops or double-counts records is worse than no
trace — attribution built on it lies.  The audit makes the recorder
correctness tooling: every byte the runtimes' own conservation
counters saw must reappear, exactly, as trace records.

* :func:`audit_sim` — per-node storage-NIC spans (tagged ``read`` /
  ``weights`` / ``blob`` / ``persist`` / ``prefetch``) must sum to the
  ``_FifoNic`` byte counters **exactly** (the span is emitted at the
  same completion event that bumps the counter, with the same float,
  in the same order — so even float addition agrees); hedge events
  must reproduce ``hedged_reads`` / ``hedge_moved_tokens``.
* :func:`audit_serving` — per-side storage-read and tier-hit event
  bytes must match ``read_bytes_by_side`` / ``dram_bytes_by_side``;
  persist-event bytes must equal the store's ``bytes_written``
  (exactly-once persists; requires a fully-drained run — pass
  ``check_persists=False`` for runs cut off mid-flight); hedge events
  as above.

All checks raise :class:`TraceAuditError` on the first mismatch and
return the tallied sums on success (benchmarks embed them in their
reports).
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict


class TraceAuditError(AssertionError):
    """A trace record sum disagrees with a runtime conservation
    ledger."""


def _expect(what: str, got, want) -> None:
    if got != want:
        raise TraceAuditError(
            f"trace audit: {what}: trace says {got!r}, ledger says "
            f"{want!r}")


def _hedge_check(tracer, hedged_reads: int,
                 hedge_moved_tokens: int) -> Dict[str, int]:
    n = 0
    moved = 0
    for _, _, _, args in tracer.iter_events("hedge"):
        n += 1
        moved += args["moved_tokens"]
    _expect("hedge event count vs hedged_reads", n, hedged_reads)
    _expect("hedge moved-token sum vs hedge_moved_tokens", moved,
            hedge_moved_tokens)
    return {"hedge_events": n, "hedge_moved_tokens": moved}


def audit_sim(sim, tracer) -> dict:
    """Validate a traced :class:`repro.sim.simulator.Sim` run."""
    # every NIC transfer span, summed by (node, tag) ------------------
    by_node: Dict[int, Dict[str, float]] = defaultdict(
        lambda: defaultdict(float))
    for track, _, _, _, args in tracer.iter_spans("snic/", "nic_xfer"):
        node = int(track.split("node", 1)[1])
        by_node[node][args["tag"]] += args["nbytes"]
    for node, nic in sorted(sim.snic.items()):
        tags = by_node.get(node, {})
        reads = tags.get("read", 0.0) + tags.get("weights", 0.0) + \
            tags.get("blob", 0.0)
        _expect(f"node{node} read span bytes", reads, nic.read_bytes)
        _expect(f"node{node} persist span bytes",
                tags.get("persist", 0.0), nic.write_bytes)
        _expect(f"node{node} prefetch span bytes",
                tags.get("prefetch", 0.0), nic.prefetch_bytes)
        unknown = set(tags) - {"read", "weights", "blob", "persist",
                               "prefetch"}
        if unknown:
            raise TraceAuditError(
                f"trace audit: node{node} has spans with unknown "
                f"tags {sorted(unknown)}")
    out = {"snic_bytes_by_node": {n: dict(t)
                                  for n, t in sorted(by_node.items())}}
    out.update(_hedge_check(tracer, sim.hedged_reads,
                            sim.hedge_moved_tokens))
    return out


def audit_serving(system, tracer, check_persists: bool = True) -> dict:
    """Validate a traced
    :class:`repro.serving.system.ServingSystem` run."""
    read_by_side: Dict[str, int] = defaultdict(int)
    for _, _, _, args in tracer.iter_events("storage_read"):
        read_by_side[args["side"]] += args["nbytes"]
    for side, want in system.read_bytes_by_side.items():
        _expect(f"{side}-side storage_read event bytes",
                read_by_side.get(side, 0), want)

    dram_by_side: Dict[str, int] = defaultdict(int)
    for _, _, _, args in tracer.iter_events("tier_hit"):
        dram_by_side[args["side"]] += args["nbytes"]
    for side, want in system.dram_bytes_by_side.items():
        _expect(f"{side}-side tier_hit event bytes",
                dram_by_side.get(side, 0), want)

    out = {"read_bytes_by_side": dict(read_by_side),
           "dram_bytes_by_side": dict(dram_by_side)}

    if check_persists:
        persist = 0
        for _, _, _, args in tracer.iter_events("persist"):
            persist += args["nbytes"]
        _expect("persist event bytes vs store.bytes_written (exactly-"
                "once persists; needs a fully-drained run)",
                persist, system.store.bytes_written)
        out["persist_bytes"] = persist

    out.update(_hedge_check(tracer, system.hedged_reads,
                            system.hedge_moved_tokens))
    return out
