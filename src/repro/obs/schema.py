"""Canonical metric-name schema for both runtimes' results dicts.

``Sim.results()`` and ``ServingSystem.stats()`` historically mirrored
each other by convention only — a key renamed in one silently drifted
in the other.  This registry ends that: every headline metric either
runtime emits is registered here with a kind, a unit and the set of
runtimes that emit it, and both dicts are passed through
:func:`conforming` before being returned, so an unregistered key is a
hard error at the emission site (and an *orphaned* registration — a
registered key neither runtime emits any more — is caught by
tests/test_obs.py's two-way assertion).

Naming rules (enforced on registration and by ``MetricsRegistry``):

* lower_snake_case, ``[a-z][a-z0-9_]*``;
* unit suffixes where a unit applies: ``*_s`` seconds, ``*_bytes``,
  ``*_tokens``, ``*_ratio`` (``*_gb`` only in benchmark headline
  dicts, which are not this registry's domain);
* counts carry no suffix (``finished_rounds``, ``engine_deaths``).
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, FrozenSet, Iterable, Set

SIM = "sim"
SERVING = "serving"

NAME_RE = re.compile(r"^[a-z][a-z0-9_]*$")


@dataclass(frozen=True)
class MetricSpec:
    name: str
    kind: str                      # counter | gauge | summary | mixed
    unit: str                      # s | bytes | tokens | count | ratio | mixed
    runtimes: FrozenSet[str]
    description: str = ""


REGISTRY: Dict[str, MetricSpec] = {}

_KINDS = ("counter", "gauge", "summary", "mixed")
_UNITS = ("s", "bytes", "tokens", "count", "ratio", "mixed")


def register(name: str, kind: str, unit: str, runtimes: Iterable[str],
             description: str = "") -> MetricSpec:
    if not NAME_RE.match(name):
        raise ValueError(f"metric name {name!r} violates the naming "
                         f"rule {NAME_RE.pattern}")
    if kind not in _KINDS:
        raise ValueError(f"{name}: unknown kind {kind!r}")
    if unit not in _UNITS:
        raise ValueError(f"{name}: unknown unit {unit!r}")
    spec = MetricSpec(name, kind, unit, frozenset(runtimes), description)
    prev = REGISTRY.get(name)
    if prev is not None and prev != spec:
        raise ValueError(f"metric {name!r} re-registered with a "
                         f"different spec")
    REGISTRY[name] = spec
    return spec


def registered_keys(runtime: str) -> Set[str]:
    """Every metric name ``runtime`` is expected to emit."""
    return {n for n, s in REGISTRY.items() if runtime in s.runtimes}


def conforming(d: dict, runtime: str) -> dict:
    """Validate that ``d`` (a results/stats dict) emits only registered
    names for ``runtime``; returns ``d`` unchanged.  Called at the end
    of ``Sim.results()`` and ``ServingSystem.stats()`` so an unreviewed
    key cannot ship."""
    unknown = set(d) - registered_keys(runtime)
    if unknown:
        raise KeyError(
            f"{runtime} emits metric keys not registered in "
            f"repro.obs.schema: {sorted(unknown)} — register them "
            f"(name, kind, unit) before emitting")
    return d


def orphans(d: dict, runtime: str) -> Set[str]:
    """Registered-for-``runtime`` names missing from ``d`` — dead
    registrations (or a silently dropped metric).  The schema test
    asserts this is empty for both runtimes."""
    return registered_keys(runtime) - set(d)


# ---------------------------------------------------------------------------
# the registry: every key Sim.results() / ServingSystem.stats() emits
# ---------------------------------------------------------------------------

_BOTH = (SIM, SERVING)

# --- shared latency summary (serving/events.latency_summary + sim) --------
register("finished_rounds", "counter", "count", _BOTH,
         "rounds with done_t stamped")
register("ttft_mean", "summary", "s", _BOTH, "time to first token, mean")
register("ttft_p99", "summary", "s", _BOTH, "time to first token, p99")
register("ttst_mean", "summary", "s", _BOTH, "time to second token, mean")
register("tpot_mean", "summary", "s", _BOTH, "time per output token, mean")
register("tpot_p99", "summary", "s", _BOTH, "time per output token, p99")

# --- simulator-only workload/latency columns ------------------------------
register("finished_agents", "counter", "count", (SIM,),
         "trajectories run to completion")
register("jct_mean", "summary", "s", (SIM,), "job completion time, mean")
register("jct_max", "summary", "s", (SIM,), "job completion time, max")
register("sim_time", "gauge", "s", (SIM,), "modelled clock at exit")
register("prompt_tokens", "counter", "tokens", (SIM,),
         "prefill tokens processed")
register("gen_tokens", "counter", "tokens", _BOTH,
         "decode tokens generated")
register("snic_hit_read_bytes", "counter", "bytes", (SIM,),
         "demand hit bytes that paid a storage NIC")
register("dram_hit_ratio", "gauge", "ratio", (SIM,),
         "tier hits / (tier hits + SNIC hit reads)")
register("tier_evictions", "counter", "count", (SIM,),
         "tier entries evicted")
register("net_collective_delay_s", "summary", "s", (SIM,),
         "collective completion beyond uncontended service")
register("net_collective_bytes", "counter", "bytes", (SIM,),
         "model-collective bytes on the shared link")
register("net_kv_bytes", "counter", "bytes", (SIM,),
         "KV-transfer bytes on the shared link")
register("net_contended_joins", "counter", "count", (SIM,),
         "flows that joined a contended link")

# --- serving-only columns --------------------------------------------------
register("store_reads", "counter", "bytes", (SERVING,),
         "bytes read from the remote KV store")
register("store_writes", "counter", "bytes", (SERVING,),
         "bytes written to the remote KV store")
register("read_bytes_pe_side", "counter", "bytes", (SERVING,),
         "storage read bytes on the PE side")
register("read_bytes_de_side", "counter", "bytes", (SERVING,),
         "storage read bytes on the DE side")
register("split_reads", "counter", "count", (SERVING,),
         "requests whose hit was read by both sides' NICs")
register("trie_blocks", "counter", "count", (SERVING,),
         "blocks registered in the prefix trie")
register("prefill_tokens", "counter", "tokens", (SERVING,),
         "prefill tokens processed")
register("decode_steps", "counter", "count", (SERVING,),
         "slot-batched decode steps executed")
register("wall_s", "gauge", "s", (SERVING,), "modelled wall clock at exit")
register("doorbells", "counter", "count", (SERVING,),
         "doorbell rings across all TrafficManagers")
register("submitted_seconds", "counter", "s", (SERVING,),
         "modelled submission overhead")
register("net_congestion", "gauge", "ratio", (SERVING,),
         "last tick's collective share of CNIC traffic")
register("paced_flushes", "counter", "count", (SERVING,),
         "flushes that deferred KV WRs under congestion")
register("deferred_wrs", "counter", "count", (SERVING,),
         "KV WRs deferred by congestion pacing")
register("dram_bytes_pe_side", "counter", "bytes", (SERVING,),
         "tier-served bytes on the PE side")
register("dram_bytes_de_side", "counter", "bytes", (SERVING,),
         "tier-served bytes on the DE side")
register("tier_miss_bytes", "counter", "bytes", (SERVING,),
         "demand reads through the tier's backing store")

# --- shared subsystem columns ---------------------------------------------
register("dram_hit_bytes", "counter", "bytes", _BOTH,
         "hit bytes served from a DRAM tier (no SNIC)")
register("tier_prefetch_bytes", "counter", "bytes", _BOTH,
         "bytes staged ahead of demand")
register("tier_evicted_bytes", "counter", "bytes", _BOTH,
         "bytes evicted from DRAM tiers")
register("collective_stall_s", "summary", "s", _BOTH,
         "step time lost waiting on collectives")
register("transfer_backlog_s", "summary", "s", _BOTH,
         "KV completion beyond uncontended service")
register("role_changes", "counter", "count", _BOTH,
         "completed PE<->DE role flips")
register("role_changes_by_direction", "mixed", "mixed", _BOTH,
         "flip counts keyed by direction")
register("reconfig_drain_s", "summary", "s", _BOTH,
         "admission-stop-to-flip seconds, total")
register("reconfig_weight_bytes", "counter", "bytes", _BOTH,
         "weight-shard bytes reloaded by flips")
register("tier_handoff_bytes", "counter", "bytes", _BOTH,
         "tier-resident bytes kept across flips")
register("n_pe_final", "gauge", "count", _BOTH, "PEs at exit")
register("n_de_final", "gauge", "count", _BOTH, "DEs at exit")
register("engine_deaths", "counter", "count", _BOTH,
         "fail-stopped engines")
register("recovered_rounds", "counter", "count", _BOTH,
         "rounds re-homed after an engine death")
register("hedged_reads", "counter", "count", _BOTH,
         "read legs hedged to the healthy side")
register("hedge_moved_tokens", "counter", "tokens", _BOTH,
         "tokens re-water-filled by hedges")

# --- online SLO layer (core/config.SloConfig) -----------------------------
register("admitted_rounds", "counter", "count", _BOTH,
         "arrivals passed by the admission gate (== submissions when "
         "admission control is off)")
register("deferred_rounds", "counter", "count", _BOTH,
         "admission-gate deferrals (one arrival may defer repeatedly)")
register("rejected_rounds", "counter", "count", _BOTH,
         "arrivals shed after exhausting admission deferrals")
register("prefill_chunks", "counter", "count", _BOTH,
         "partial (chunked) prefill batch items executed")
register("latency_by_class", "mixed", "mixed", _BOTH,
         "per-SLO-class latency summaries (interactive | batch)")
