"""Critical-path attribution: where did each request's TTFT go?

Decomposes the window from a request's submission to its first token
into per-resource waiting seconds, from the spans the runtimes record
on the request's ``req/<rid>`` track:

* **storage** — storage-NIC read legs (``read_leg`` spans in the sim,
  the ``reading`` lifecycle span in serving);
* **compute** — prefill steps and the first decode block
  (``prefill`` / ``decode_first``);
* **net** — compute-network PD transfers (``pd_transfer``);
* **drain** — elastic-reconfiguration drain windows (``drain`` spans
  on the global ``reconfig`` track) overlapping the request, counted
  only where no request-level span explains the time;
* **queue** — the residual: time covered by none of the above
  (admission queues, scheduler waits, tick granularity).

The decomposition is a *partition*: the window is swept over the
breakpoints of every contributing interval and each segment is
assigned to exactly one category by the priority order above, so the
five components sum to the measured TTFT **exactly** (floating-point
addition aside).  That exact-sum property is the acceptance gate in
``benchmarks/fig_bottleneck.py --smoke``.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, List, Optional, Tuple

#: category -> span names feeding it, in attribution priority order.
CATEGORY_SPANS = (
    ("storage_s", ("read_leg", "reading")),
    ("compute_s", ("prefill", "decode_first")),
    ("net_s", ("pd_transfer",)),
)
#: all categories in output order (drain + residual appended).
CATEGORIES = tuple(c for c, _ in CATEGORY_SPANS) + ("drain_s", "queue_s")

FIRST_TOKEN = "first_token"


def _clip(ivs: List[Tuple[float, float]], t0: float,
          t1: float) -> List[Tuple[float, float]]:
    out = []
    for a, b in ivs:
        a, b = max(a, t0), min(b, t1)
        if b > a:
            out.append((a, b))
    return out


def _covered(ivs: List[Tuple[float, float]], t: float) -> bool:
    return any(a <= t < b for a, b in ivs)


def attribute_ttft(tracer, rid: Optional[int] = None) -> Dict[int, dict]:
    """Per-request TTFT decomposition from ``tracer``'s records.

    Returns ``{rid: {"ttft_s", "t0", "storage_s", "compute_s",
    "net_s", "drain_s", "queue_s"}}`` for every request with a
    recorded ``first_token`` event (restricted to ``rid`` if given).
    The five category values partition ``ttft_s``.
    """
    # gather per-request spans and first-token stamps ------------------
    by_rid: Dict[int, List[tuple]] = defaultdict(list)
    t_first: Dict[int, float] = {}
    t_sub: Dict[int, float] = {}
    for track, name, t0, t1, args in tracer.iter_spans("req/"):
        r = int(track.split("/", 1)[1])
        by_rid[r].append((name, t0, t1))
        t_sub[r] = min(t_sub.get(r, t0), t0)
    for track, name, t, args in tracer.iter_events(FIRST_TOKEN):
        if track.startswith("req/"):
            t_first[int(track.split("/", 1)[1])] = t
    drains = [(t0, t1) for _, _, t0, t1, _ in
              tracer.iter_spans("reconfig", "drain")]

    out: Dict[int, dict] = {}
    for r in sorted(t_first):
        if rid is not None and r != rid:
            continue
        if r not in t_sub:
            continue
        w0, w1 = t_sub[r], t_first[r]
        if w1 <= w0:
            continue
        # clip each category's intervals to the TTFT window ------------
        cat_ivs: List[Tuple[str, List[Tuple[float, float]]]] = []
        for cat, names in CATEGORY_SPANS:
            ivs = [(a, b) for nm, a, b in by_rid[r] if nm in names]
            cat_ivs.append((cat, _clip(ivs, w0, w1)))
        cat_ivs.append(("drain_s", _clip(list(drains), w0, w1)))
        # priority sweep over all breakpoints --------------------------
        pts = {w0, w1}
        for _, ivs in cat_ivs:
            for a, b in ivs:
                pts.add(a)
                pts.add(b)
        cuts = sorted(pts)
        acc = {c: 0.0 for c in CATEGORIES}
        for a, b in zip(cuts, cuts[1:]):
            mid = 0.5 * (a + b)
            for cat, ivs in cat_ivs:
                if _covered(ivs, mid):
                    acc[cat] += b - a
                    break
            else:
                acc["queue_s"] += b - a
        rec = {"ttft_s": w1 - w0, "t0": w0}
        rec.update(acc)
        out[r] = rec
    return out


def bottleneck_report(per_request: Dict[int, dict]) -> dict:
    """Aggregate a per-request decomposition into an arm-level report:
    mean seconds and TTFT fraction per category, the dominant category
    (``bottleneck``), and the worst residual-vs-measured mismatch
    (``max_decomp_err_s`` — ~0 by construction; the smoke gate pins
    it)."""
    n = len(per_request)
    if n == 0:
        nan = float("nan")
        rep = {"n": 0, "ttft_mean_s": nan, "bottleneck": "none",
               "max_decomp_err_s": nan}
        for c in CATEGORIES:
            rep[f"{c.removesuffix('_s')}_mean_s"] = nan
            rep[f"{c.removesuffix('_s')}_frac"] = nan
        return rep
    tot = {c: 0.0 for c in CATEGORIES}
    ttft_tot = 0.0
    max_err = 0.0
    for rec in per_request.values():
        ttft_tot += rec["ttft_s"]
        parts = 0.0
        for c in CATEGORIES:
            tot[c] += rec[c]
            parts += rec[c]
        max_err = max(max_err, abs(parts - rec["ttft_s"]))
    rep = {"n": n, "ttft_mean_s": ttft_tot / n,
           "bottleneck": max(CATEGORIES, key=lambda c: tot[c])
           .removesuffix("_s"),
           "max_decomp_err_s": max_err}
    for c in CATEGORIES:
        base = c.removesuffix("_s")
        rep[f"{base}_mean_s"] = tot[c] / n
        rep[f"{base}_frac"] = (tot[c] / ttft_tot if ttft_tot > 0
                               else float("nan"))
    return rep
