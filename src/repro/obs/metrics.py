"""Typed metric instruments under the schema's naming rules.

:class:`MetricsRegistry` is a small, deterministic instrument store —
counters, gauges and histograms — whose names are validated against
:mod:`repro.obs.schema`'s naming rule at creation time.  The runtimes'
headline dicts remain plain dicts (validated by
:func:`repro.obs.schema.conforming`); this module serves ad-hoc
instrumentation in benchmarks and tests, where a histogram's
deterministic percentiles and a ``snapshot()`` that always renders the
same keys beat hand-rolled lists.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional

from repro.obs.schema import NAME_RE


def _check_name(name: str) -> str:
    if not NAME_RE.match(name):
        raise ValueError(f"metric name {name!r} violates the naming "
                         f"rule {NAME_RE.pattern}")
    return name


class Counter:
    """Monotonically increasing value; ``inc`` rejects negatives."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = _check_name(name)
        self.value = 0.0

    def inc(self, by: float = 1.0) -> None:
        if by < 0:
            raise ValueError(f"{self.name}: counters only increase "
                             f"(got {by})")
        self.value += by


class Gauge:
    """A point-in-time value; set freely."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = _check_name(name)
        self.value = float("nan")

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Sample accumulator with deterministic summary statistics.

    Percentiles use the nearest-rank method on the sorted samples —
    no interpolation, no numpy, so the summary is bit-stable across
    platforms.  Empty histograms summarise to NaN (the same contract
    as ``serving/events.latency_summary``; see docs/observability.md).
    """

    __slots__ = ("name", "samples")

    def __init__(self, name: str):
        self.name = _check_name(name)
        self.samples: List[float] = []

    def observe(self, v: float) -> None:
        self.samples.append(float(v))

    @property
    def count(self) -> int:
        return len(self.samples)

    def mean(self) -> float:
        if not self.samples:
            return float("nan")
        return sum(self.samples) / len(self.samples)

    def percentile(self, q: float) -> float:
        """Nearest-rank percentile, ``q`` in [0, 100]."""
        if not self.samples:
            return float("nan")
        if not 0.0 <= q <= 100.0:
            raise ValueError(f"percentile {q} outside [0, 100]")
        s = sorted(self.samples)
        rank = max(1, math.ceil(q / 100.0 * len(s)))
        return s[rank - 1]

    def summary(self) -> Dict[str, float]:
        return {
            "count": float(self.count),
            "mean": self.mean(),
            "p50": self.percentile(50.0),
            "p99": self.percentile(99.0),
            "max": self.percentile(100.0),
        }


class MetricsRegistry:
    """Namespace of instruments; one instance per run/arm.

    ``counter``/``gauge``/``histogram`` are get-or-create, so call
    sites need no pre-declaration, but a name may not change kind
    mid-run (that is exactly the drift the schema exists to stop).
    """

    def __init__(self):
        self._instruments: Dict[str, object] = {}

    def _get(self, name: str, cls):
        inst = self._instruments.get(name)
        if inst is None:
            inst = cls(name)
            self._instruments[name] = inst
        elif not isinstance(inst, cls):
            raise TypeError(
                f"metric {name!r} already registered as "
                f"{type(inst).__name__}, requested {cls.__name__}")
        return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def snapshot(self) -> Dict[str, object]:
        """Flat, name-sorted dict of current values: scalars for
        counters/gauges, summary dicts for histograms."""
        out: Dict[str, object] = {}
        for name in sorted(self._instruments):
            inst = self._instruments[name]
            if isinstance(inst, Histogram):
                out[name] = inst.summary()
            else:
                out[name] = inst.value  # type: ignore[union-attr]
        return out

    def get(self, name: str) -> Optional[object]:
        return self._instruments.get(name)
