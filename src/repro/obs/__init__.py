"""Flight-recorder observability layer shared by both runtimes.

A deterministic, zero-overhead-when-disabled tracing + metrics
subsystem for the discrete-event simulator (``repro.sim``) and the
event-driven serving runtime (``repro.serving``):

* :mod:`repro.obs.tracer` — spans/events/counters on the *modelled*
  clock, exported as Chrome-trace JSON (open in Perfetto);
* :mod:`repro.obs.schema` — the canonical metric-name registry both
  runtimes' results dicts are validated against;
* :mod:`repro.obs.metrics` — counters/gauges/histograms under the
  schema's naming rules;
* :mod:`repro.obs.attribution` — critical-path decomposition of each
  request's TTFT into per-resource waiting seconds;
* :mod:`repro.obs.audit` — cross-validation of span byte sums against
  the runtimes' conservation ledgers (the recorder is correctness
  tooling, not just logging).

Every hook in the runtimes is guarded by ``if tracer is not None`` —
with no tracer attached the instrumented code paths execute the exact
pre-instrumentation arithmetic (bit-identical token streams and stats,
pinned by tests/test_obs.py).
"""
from repro.obs.attribution import attribute_ttft, bottleneck_report
from repro.obs.audit import TraceAuditError, audit_serving, audit_sim
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry)
from repro.obs.schema import conforming, orphans, registered_keys
from repro.obs.tracer import Tracer

__all__ = [
    "Tracer", "conforming", "orphans", "registered_keys",
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "attribute_ttft", "bottleneck_report",
    "audit_sim", "audit_serving", "TraceAuditError",
]
