"""Flight recorder: spans, events and counters on the modelled clock.

The :class:`Tracer` is the single recording surface both runtimes
instrument against.  Design constraints (ISSUE 7 tentpole):

* **zero overhead when disabled** — every call site is guarded by
  ``if tracer is not None``; the runtimes take ``tracer=None`` by
  default, so a disabled run executes the exact pre-instrumentation
  code (no record allocation, no clock reads, no branches beyond the
  None check);
* **deterministic** — records carry only the runtime's *modelled*
  clock (``Sim.loop.now`` / ``VirtualClock.now``; never
  ``time.time()``), are appended in event-execution order, and the
  export sorts with a stable per-record sequence tie-breaker, so the
  same (workload, seed, FaultSchedule) produces a byte-identical JSON
  trace (pinned by tests/test_obs.py);
* **Perfetto-compatible export** — :meth:`Tracer.to_chrome_trace`
  emits the Chrome trace-event format (``ph: X/i/C/M``): one thread
  track per engine/NIC/link/request, counter tracks for queue depths,
  tier occupancy and link congestion.  Load the JSON at
  https://ui.perfetto.dev (docs/observability.md has the walkthrough).

Track names are hierarchical strings (``"snic/node0"``,
``"engine/pe(0, 0)"``, ``"req/12"``): the first path component becomes
the Perfetto process, the full name the thread, both assigned ids in
first-seen order (deterministic given deterministic recording).
"""
from __future__ import annotations

import json
from typing import Callable, Dict, List, Optional

#: timestamp unit of the Chrome trace format (microseconds)
_US = 1e6


class Tracer:
    """Append-only recorder of spans, instant events and counters.

    ``now_fn`` (bound by the runtime via :meth:`bind_clock`) supplies
    the modelled time for records whose call site does not pass an
    explicit timestamp — the seam components (scheduler, traffic
    manager, tier, controller) have no clock of their own.
    """

    def __init__(self, now_fn: Optional[Callable[[], float]] = None):
        self._now = now_fn
        # (seq, track, name, t0, t1, args) — t1 < 0 marks an instant
        self.spans: List[tuple] = []
        self.counters: List[tuple] = []    # (seq, track, t, values)
        self._seq = 0

    # ------------------------------------------------------------------
    # clock binding
    # ------------------------------------------------------------------
    def bind_clock(self, now_fn: Callable[[], float]) -> "Tracer":
        """Attach the owning runtime's modelled clock (``loop.now`` /
        ``clock.now``).  Never a wall clock: determinism depends on it."""
        self._now = now_fn
        return self

    @property
    def now(self) -> float:
        if self._now is None:
            raise RuntimeError("Tracer has no clock bound; the owning "
                               "runtime must call bind_clock() first")
        return self._now()

    # ------------------------------------------------------------------
    # recording
    # ------------------------------------------------------------------
    def span(self, track: str, name: str, t0: float, t1: float,
             **args) -> None:
        """A complete span [t0, t1] on ``track`` (Chrome ``ph: X``)."""
        self.spans.append((self._seq, track, name, float(t0), float(t1),
                           args))
        self._seq += 1

    def event(self, track: str, name: str, t: Optional[float] = None,
              **args) -> None:
        """An instant event (Chrome ``ph: i``) at ``t`` (default: the
        bound clock's now)."""
        tt = self.now if t is None else float(t)
        self.spans.append((self._seq, track, name, tt, -1.0, args))
        self._seq += 1

    def counter(self, track: str, t: Optional[float] = None,
                **values) -> None:
        """A counter sample (Chrome ``ph: C``): one numeric series per
        keyword, rendered as a stacked counter track in Perfetto."""
        tt = self.now if t is None else float(t)
        self.counters.append((self._seq, track, tt, values))
        self._seq += 1

    # ------------------------------------------------------------------
    # queries (attribution / audit consume these, not the raw tuples)
    # ------------------------------------------------------------------
    def iter_spans(self, track_prefix: Optional[str] = None,
                   name: Optional[str] = None):
        """Yield ``(track, name, t0, t1, args)`` for complete spans,
        optionally filtered; recording order."""
        for _, track, nm, t0, t1, args in self.spans:
            if t1 < 0:
                continue
            if track_prefix is not None and \
                    not track.startswith(track_prefix):
                continue
            if name is not None and nm != name:
                continue
            yield track, nm, t0, t1, args

    def iter_events(self, name: Optional[str] = None):
        """Yield ``(track, name, t, args)`` for instant events."""
        for _, track, nm, t0, t1, args in self.spans:
            if t1 >= 0:
                continue
            if name is not None and nm != name:
                continue
            yield track, nm, t0, args

    # ------------------------------------------------------------------
    # export
    # ------------------------------------------------------------------
    def _track_ids(self) -> Dict[str, tuple]:
        """track name -> (pid, tid), assigned in first-seen order."""
        pids: Dict[str, int] = {}
        tids: Dict[str, tuple] = {}
        for rec in sorted(self.spans + self.counters,
                          key=lambda r: r[0]):
            track = rec[1]
            if track in tids:
                continue
            group = track.split("/", 1)[0]
            pid = pids.setdefault(group, len(pids) + 1)
            tids[track] = (pid, len(tids) + 1)
        return tids

    def to_chrome_trace(self) -> dict:
        """The Chrome trace-event representation (a JSON-ready dict)."""
        tids = self._track_ids()
        out: List[dict] = []
        for track, (pid, tid) in tids.items():
            out.append({"ph": "M", "name": "process_name", "pid": pid,
                        "tid": 0,
                        "args": {"name": track.split("/", 1)[0]}})
            out.append({"ph": "M", "name": "thread_name", "pid": pid,
                        "tid": tid, "args": {"name": track}})
        recs = []
        for seq, track, name, t0, t1, args in self.spans:
            pid, tid = tids[track]
            if t1 >= 0:
                recs.append((t0, seq, {
                    "ph": "X", "name": name, "cat": track,
                    "ts": round(t0 * _US, 3),
                    "dur": round(max(t1 - t0, 0.0) * _US, 3),
                    "pid": pid, "tid": tid, "args": args}))
            else:
                recs.append((t0, seq, {
                    "ph": "i", "name": name, "cat": track, "s": "t",
                    "ts": round(t0 * _US, 3),
                    "pid": pid, "tid": tid, "args": args}))
        for seq, track, t, values in self.counters:
            pid, tid = tids[track]
            recs.append((t, seq, {
                "ph": "C", "name": track, "ts": round(t * _US, 3),
                "pid": pid, "tid": tid, "args": values}))
        recs.sort(key=lambda r: (r[0], r[1]))
        out.extend(r[2] for r in recs)
        return {"traceEvents": out, "displayTimeUnit": "ms"}

    def export_json(self, path: str) -> str:
        """Write the Perfetto-loadable trace to ``path``.  Sorted keys
        and fixed separators keep the bytes deterministic."""
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f, sort_keys=True,
                      separators=(",", ":"))
            f.write("\n")
        return path

    def export_bytes(self) -> bytes:
        """The exported trace as bytes (what export_json writes) — the
        determinism tests compare these directly."""
        return (json.dumps(self.to_chrome_trace(), sort_keys=True,
                           separators=(",", ":")) + "\n").encode()

    # ------------------------------------------------------------------
    # fault-window annotations (sim/faults.py)
    # ------------------------------------------------------------------
    def annotate_faults(self, faults) -> None:
        """Record a FaultSchedule's slowdown windows as spans on the
        ``faults`` track (one sub-track per resource) and its engine
        deaths as instant events, so every chaos run's injected
        degradations are visible alongside the request lifecycles."""
        if faults is None:
            return
        for w in faults.windows:
            self.span(f"faults/{w.resource}", "fault_window",
                      w.t0, w.t1, factor=w.factor,
                      node=w.node if w.node is not None else "all")
        for d in faults.deaths:
            self.event("faults/deaths", "engine_death_scheduled",
                       t=d.t, engine=list(d.engine))
