"""Multi-head latent attention (DeepSeek-style) — used by the paper's own
ds27b evaluation model.

Two paths:
* prefill/train: expand the latent to per-head K/V (compute-bound, fine);
* decode: **absorbed** form — queries are projected into the latent space
  (q @ W_uk) so attention runs directly against the cached latent; the
  value expansion is likewise folded after the softmax.  The KV cache
  per token is only (kv_lora_rank + rope_head_dim) — this is exactly why
  DeepSeek models sit at the bottom of the paper's Table 1.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, attend, rms_norm

def _split_q(cfg, q):
    m = cfg.mla
    return q[..., :m.nope_head_dim], q[..., m.nope_head_dim:]


def mla_latent(p, cfg: ModelConfig, x, positions):
    """Compute the cacheable latent: c_kv (b,s,r) + roped k_rope (b,s,rd)."""
    c_kv = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])
    c_kv = rms_norm(c_kv, p["kv_norm"], cfg.rms_norm_eps)
    k_rope = jnp.einsum("bsd,dr->bsr", x, p["w_krope"])
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_rope


def mla_q(p, cfg: ModelConfig, x, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_nope, q_rope = _split_q(cfg, q)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_full(p, cfg: ModelConfig, x, positions, *, causal=True,
             prefix=None):
    """Prefill/train path (expanded K/V).

    prefix: optional (c_kv, k_rope, valid_len) of already-cached tokens.
    Returns (attn_out (b,s,d), (c_kv, k_rope) for the new tokens).
    """
    m = cfg.mla
    b, s, _ = x.shape
    q_nope, q_rope = mla_q(p, cfg, x, positions)
    c_kv, k_rope = mla_latent(p, cfg, x, positions)
    kv_offset, kv_valid = 0, None
    if prefix is not None:
        pc, pk, plen = prefix
        c_all = jnp.concatenate([pc, c_kv], axis=1)
        k_rope_all = jnp.concatenate([pk, k_rope], axis=1)
    else:
        c_all, k_rope_all = c_kv, k_rope
    # expand latent to per-head K/V
    k_nope = jnp.einsum("bsr,rhk->bshk", c_all, p["w_uk"])
    v = jnp.einsum("bsr,rhk->bshk", c_all, p["w_uv"])
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope_all[:, :, None, :],
                                  k_nope.shape[:3] + (m.rope_head_dim,))],
        axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    q_off = c_all.shape[1] - s
    o = attend(q, k, v, causal=causal, q_offset=q_off,
               scale=1.0 / math.sqrt(m.nope_head_dim + m.rope_head_dim))
    b_, s_, h, vd = o.shape
    out = jnp.einsum("bsm,md->bsd", o.reshape(b_, s_, h * vd), p["wo"])
    return out, (c_kv, k_rope)


def mla_append(p, cfg: ModelConfig, x, c_cache, krope_cache, lengths):
    """Engine append path: write the chunk's latents into the padded
    caches at [lengths, lengths+s), expand the whole cache to per-head
    K/V and attend with ragged causal masking.

    x (b,s,d); c_cache (b,S,r); krope_cache (b,S,rd); lengths (b,).
    Returns (out (b,s,d), (c_cache, krope_cache) updated).
    """
    from repro.models.layers import append_attend
    m = cfg.mla
    b, s, _ = x.shape
    bidx = jnp.arange(b)[:, None]
    positions = lengths[:, None] + jnp.arange(s)[None, :]
    q_nope, q_rope = mla_q(p, cfg, x, positions)
    c_new, kr_new = mla_latent(p, cfg, x, positions)
    c_cache = c_cache.at[bidx, positions].set(c_new.astype(c_cache.dtype))
    krope_cache = krope_cache.at[bidx, positions].set(
        kr_new.astype(krope_cache.dtype))
    k_nope = jnp.einsum("bsr,rhk->bshk", c_cache, p["w_uk"])
    v = jnp.einsum("bsr,rhk->bshk", c_cache, p["w_uv"])
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(krope_cache[:, :, None, :],
                                  k_nope.shape[:3] + (m.rope_head_dim,))],
        axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    o = append_attend(q, k, v, lengths,
                      scale=1.0 / math.sqrt(m.nope_head_dim + m.rope_head_dim))
    out = jnp.einsum("bsm,md->bsd", o.reshape(b, s, -1), p["wo"])
    return out, (c_cache, krope_cache)


def mla_decode(p, cfg: ModelConfig, x, c_cache, krope_cache, lengths):
    """Absorbed decode step.

    x: (b,1,d); c_cache (b,S,r); krope_cache (b,S,rd); lengths (b,)
    (the current token's latent is already written at lengths-1).
    """
    m = cfg.mla
    b = x.shape[0]
    positions = (lengths - 1)[:, None]                       # (b,1)
    q_nope, q_rope = mla_q(p, cfg, x, positions)             # (b,1,h,*)
    # absorb W_uk: q_lat[b,h,r] = sum_d q_nope[b,1,h,d] W_uk[r,h,d]
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], p["w_uk"])
    s_lat = jnp.einsum("bhr,bsr->bhs", q_lat,
                       c_cache.astype(q_lat.dtype))
    s_rope = jnp.einsum("bhd,bsd->bhs", q_rope[:, 0],
                        krope_cache.astype(q_rope.dtype))
    scale = 1.0 / math.sqrt(m.nope_head_dim + m.rope_head_dim)
    s = (s_lat + s_rope).astype(jnp.float32) * scale
    mask = jnp.arange(c_cache.shape[1])[None, :] < lengths[:, None]
    s = s + jnp.where(mask, 0.0, -1e30)[:, None, :]
    pw = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhs,bsr->bhr", pw.astype(c_cache.dtype), c_cache)
    o = jnp.einsum("bhr,rhk->bhk", o_lat, p["w_uv"])         # (b,h,vd)
    out = jnp.einsum("bm,md->bd", o.reshape(b, -1), p["wo"])[:, None, :]
    return out.astype(x.dtype)
