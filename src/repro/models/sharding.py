"""Logical-axis sharding: map schema axes -> mesh axes per profile.

Profiles (baseline; §Perf hillclimbs override per-arch):

* ``tp``      — Megatron-style tensor parallel over ``model``; weights
                replicated over ``data`` (small archs).
* ``fsdp``    — 2-D: ``embed`` dim sharded over ``data`` (FSDP/ZeRO-3
                style) on top of TP over ``model`` (llava-34b, nemotron).
* ``ep_fsdp`` — llama4-maverick: experts over ``data``, expert-FFN over
                ``model``, attention FSDP+TP.

Rule application is per-tensor and first-come-first-served: a mesh axis
already consumed by an earlier dim is skipped (e.g. expert weights
``(expert->data, embed->data?, mlp->model)`` resolve to
``P('data', None, 'model')``).

The ``pod`` axis never appears in weight rules — pods are pure DP
replicas (weights replicated, gradients all-reduced over ``pod``), which
is the deployment story for 1000+ nodes: elasticity at pod granularity.

Mesh context: model code calls :func:`constrain` which is a no-op unless
a mesh has been installed via :func:`use_mesh` (launch/dry-run code).
CPU smoke tests therefore run the exact same model code unconstrained.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import params as params_lib

_WEIGHT_RULES = {
    "tp": {
        "vocab": "model",
        "embed": None,
        "heads": "model",
        "kv_heads": "model",
        "heads_merged": "model",
        "head_dim": None,
        "mlp": "model",
        "expert": "model",
        "expert_mlp": "model",
        "mla_rank": None,
        "inner": "model",
        "state_proj": None,
        "ssm_heads": "model",
        "conv": None,
        "frontend": None,
        "layers": None,
    },
}
_WEIGHT_RULES["fsdp"] = dict(_WEIGHT_RULES["tp"], embed="data")
_WEIGHT_RULES["ep_fsdp"] = dict(_WEIGHT_RULES["fsdp"], expert="data")

# Activation logical axes (used via `constrain`).
# "batch" expands to ("pod","data") when a pod axis exists.
_ACT_RULES = {
    "batch": "data",
    "seq": None,
    "kv_seq": None,
    "embed_act": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "expert": "model",   # dispatch buffers; ep_fsdp overrides to "data"
    "inner": "model",
    "mla_rank": None,
    "layers": None,
}


class _MeshCtx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.profile: str = "tp"
        self.act_overrides: Optional[dict] = None


_CTX = _MeshCtx()


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], profile: str = "tp",
             act_overrides: Optional[dict] = None):
    prev = (_CTX.mesh, _CTX.profile, _CTX.act_overrides)
    _CTX.mesh, _CTX.profile, _CTX.act_overrides = mesh, profile, act_overrides
    try:
        yield
    finally:
        _CTX.mesh, _CTX.profile, _CTX.act_overrides = prev


def current_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def _expand(mesh_axes, name):
    """'data'->('pod','data') for batch-like dims when pod axis exists."""
    if name == "data" and "pod" in mesh_axes:
        return ("pod", "data")
    return name


def _spec_for(axes: Tuple[str, ...], rules, mesh_axes, batch_like=("batch",)) -> P:
    used, out = set(), []
    for ax in axes:
        mesh_ax = rules.get(ax)
        if ax in batch_like and mesh_ax is not None:
            mesh_ax = _expand(mesh_axes, mesh_ax)
        if mesh_ax is None:
            out.append(None)
            continue
        flat = (mesh_ax,) if isinstance(mesh_ax, str) else tuple(mesh_ax)
        if any(a in used for a in flat):
            out.append(None)
            continue
        used.update(flat)
        out.append(mesh_ax)
    return P(*out)


def weight_rules(profile: str, overrides=None):
    r = dict(_WEIGHT_RULES[profile])
    if overrides:
        r.update(overrides)
    return r


def sanitize_spec(shape, spec: P, mesh: Mesh) -> P:
    """Drop mesh axes that do not evenly divide the dim they shard.

    Explicit in/out shardings require divisibility (unlike
    with_sharding_constraint, which GSPMD pads).  Baseline policy:
    replicate the offending dim; §Perf hillclimbs re-shard these cases
    deliberately (e.g. llava's 56 heads)."""
    out = []
    entries = tuple(spec) + (None,) * (len(shape) - len(spec))
    for dim, ax in zip(shape, entries):
        if ax is None:
            out.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        total = 1
        for a in axes:
            total *= mesh.shape[a]
        out.append(ax if dim % total == 0 else None)
    return P(*out)


def param_partition_specs(cfg: ModelConfig, mesh: Mesh, overrides=None):
    rules = weight_rules(cfg.sharding_profile, overrides)
    schema = params_lib.model_schema(cfg)
    is_pspec = lambda x: isinstance(x, params_lib.PSpec)
    return jax.tree.map(
        lambda ps: sanitize_spec(
            ps.shape, _spec_for(ps.axes, rules, mesh.axis_names), mesh),
        schema, is_leaf=is_pspec)


def param_shardings(cfg: ModelConfig, mesh: Mesh, overrides=None):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_partition_specs(cfg, mesh, overrides))


def act_spec(mesh: Mesh, *axes: Optional[str], act_overrides=None) -> P:
    rules = dict(_ACT_RULES)
    if act_overrides:
        rules.update(act_overrides)
    cooked = tuple(a if a is not None else f"__none{i}"
                   for i, a in enumerate(axes))
    rules.update({f"__none{i}": None for i in range(len(axes))})
    return _spec_for(cooked, rules, mesh.axis_names)


def constrain(x, *axes: Optional[str], act_overrides=None):
    """Sharding-constrain an activation by logical axis names (or None)."""
    mesh = _CTX.mesh
    if mesh is None:
        return x
    if _CTX.profile == "ep_fsdp":
        act_overrides = dict(act_overrides or {}, expert="data")
    if _CTX.act_overrides:
        act_overrides = dict(act_overrides or {}, **_CTX.act_overrides)
    spec = act_spec(mesh, *axes, act_overrides=act_overrides)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
