from repro.models.model import (
    decode_step,
    embed,
    forward,
    init_decode_state,
    lm_loss,
    logits_from_hidden,
)
from repro.models.params import (
    abstract_params,
    count_active_params_analytic,
    count_params_analytic,
    init_params,
    logical_axes,
    model_schema,
)

__all__ = [
    "decode_step", "embed", "forward", "init_decode_state", "lm_loss",
    "logits_from_hidden", "abstract_params", "count_active_params_analytic",
    "count_params_analytic", "init_params", "logical_axes", "model_schema",
]
