"""Full-model assembly: embedding, per-family layer stacks (lax.scan over
stacked block params), logits, plus the three step flavours the system
needs:

* ``forward``       — full-sequence (train / whole-prompt prefill);
                      optionally returns the KV/state caches it produced.
* ``decode_step``   — one token per sequence against a decode state.
* ``append_forward``— engine path: prefill an appended chunk against an
                      existing (padded) prefix KV — the agentic
                      short-append pattern the paper optimises.

Decode state layout (stacked along layer groups, mirroring the param
stacking so a single scan consumes both):

* dense/vlm:  {"k": (L,b,S,hkv,dh), "v": ...}
* moe:        {"dense": {...(f)}, "pre": {...(n_super,p-1)}, "moe": {...(n_super)}}
* mla:        {"c": (L,b,S,r), "krope": (L,b,S,rd)}
* ssm:        {stacked ssm state dicts (L,...)}
* hybrid:     {"mamba": (n_super, period, ...), "shared": {"k","v": (n_apps,b,S,hkv,dh)}}
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers, mla as mla_lib, moe as moe_lib, ssm as ssm_lib
from repro.models.layers import rms_norm
from repro.models.sharding import constrain

BIG_WINDOW = 1 << 30


# ---------------------------------------------------------------------------
# Embedding / logits
# ---------------------------------------------------------------------------


def embed(params, cfg: ModelConfig, inputs):
    """Token ids (b,s) int -> (b,s,d); or precomputed frontend embeddings
    (b,s,frontend_dim) float -> (b,s,d) via the connector projection."""
    e = params["embed"]
    if inputs.ndim == 3:
        assert cfg.frontend_embed_dim, cfg.name
        h = jnp.einsum("bsf,fd->bsd", inputs.astype(e["tok"].dtype),
                       e["frontend_proj"])
    else:
        h = e["tok"][inputs]
    if cfg.embed_scale != 1.0:
        h = h * jnp.asarray(cfg.embed_scale, h.dtype)
    return constrain(h, "batch", "seq", None)


def logits_from_hidden(params, cfg: ModelConfig, h):
    h = rms_norm(h, params["final_norm"], cfg.rms_norm_eps)
    if cfg.tie_embeddings:
        out = jnp.einsum("bsd,vd->bsv", h, params["embed"]["tok"])
    else:
        out = jnp.einsum("bsd,dv->bsv", h, params["lm_head"])
    out = layers._softcap(out.astype(jnp.float32), cfg.final_logit_softcap)
    return constrain(out, "batch", "seq", "vocab")


# ---------------------------------------------------------------------------
# Block applies
# ---------------------------------------------------------------------------


def _window_for(cfg: ModelConfig, is_local):
    """None if the arch has no local layers; else a traced scalar window."""
    if not cfg.local_global_period and not cfg.local_window:
        return None
    return jnp.where(is_local, cfg.local_window, BIG_WINDOW)


def _attn_full(p, cfg: ModelConfig, x, positions, is_local):
    if cfg.attn_variant == "mla":
        o, kv = mla_lib.mla_full(p, cfg, x, positions, causal=cfg.causal)
        return o, {"c": kv[0], "krope": kv[1]}
    q, k, v = layers.gqa_qkv(p, cfg, x, positions)
    o = layers.attend(q, k, v, causal=cfg.causal,
                      window=_window_for(cfg, is_local),
                      softcap=cfg.attn_logit_softcap)
    o = constrain(o, "batch", "seq", "heads", "head_dim")
    return layers.attn_out(p, o), {"k": k, "v": v}


def _attn_decode(p, cfg: ModelConfig, x, cache, lengths, is_local):
    """x (b,1,d); cache holds padded buffers; lengths (b,) = tokens already
    cached.  Writes the new token at index `lengths`."""
    b = x.shape[0]
    bidx = jnp.arange(b)
    if cfg.attn_variant == "mla":
        c_new, kr_new = mla_lib.mla_latent(p, cfg, x, lengths[:, None])
        c_cache = cache["c"].at[bidx, lengths].set(c_new[:, 0])
        kr_cache = cache["krope"].at[bidx, lengths].set(kr_new[:, 0])
        o = mla_lib.mla_decode(p, cfg, x, c_cache, kr_cache, lengths + 1)
        return o, {"c": c_cache, "krope": kr_cache}
    q, k, v = layers.gqa_qkv(p, cfg, x, lengths[:, None])
    k_cache = cache["k"].at[bidx, lengths].set(k[:, 0])
    v_cache = cache["v"].at[bidx, lengths].set(v[:, 0])
    o = layers.decode_attend(q, k_cache, v_cache, lengths + 1,
                             window=_window_for(cfg, is_local),
                             softcap=cfg.attn_logit_softcap)
    return layers.attn_out(p, o), {"k": k_cache, "v": v_cache}


def _dense_block(p, cfg: ModelConfig, h, *, mode, positions=None,
                 cache=None, lengths=None, is_local=False,
                 moe_impl=None, is_moe=False, capacity_factor=1.25):
    """One transformer block (attention + FFN/MoE) in full or decode mode."""
    xn = rms_norm(h, p["ln1"], cfg.rms_norm_eps)
    if mode == "full":
        attn, kv = _attn_full(p["attn"], cfg, xn, positions, is_local)
    else:
        attn, kv = _attn_decode(p["attn"], cfg, xn, cache, lengths, is_local)
    if cfg.post_attn_norm:
        attn = rms_norm(attn, p["ln1b"], cfg.rms_norm_eps)
    h = h + attn * cfg.ffn_mult
    xn = rms_norm(h, p["ln2"], cfg.rms_norm_eps)
    if is_moe:
        f = moe_lib.moe_ffn(p["moe"], cfg, xn, impl=moe_impl,
                            capacity_factor=capacity_factor)
    else:
        f = layers.ffn(p["ffn"], cfg, xn)
    if cfg.post_attn_norm:
        f = rms_norm(f, p["ln2b"], cfg.rms_norm_eps)
    h = h + f * cfg.ffn_mult
    return constrain(h, "batch", "seq", None), kv


def _mamba_block(p, cfg: ModelConfig, h, *, mode, state=None):
    xn = rms_norm(h, p["ln"], cfg.rms_norm_eps)
    if mode == "full":
        # ssd_scan returns {"ssm", "conv_x", "conv_B", "conv_C"} — the full
        # recurrent state needed to continue decoding after a prefill.
        out, new_state = ssm_lib.ssd_scan(
            p, cfg, xn,
            initial_state=None if state is None else state["ssm"])
    else:
        out, new_state = ssm_lib.ssm_decode_step(p, cfg, xn, state)
    return h + out, new_state


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------


def _is_local_arr(cfg: ModelConfig):
    return jnp.asarray([k == "local_attn" for k in cfg.layer_kinds()],
                       dtype=bool)


REMAT_POLICIES = {
    "full": lambda: jax.checkpoint_policies.nothing_saveable,
    "dots": lambda: jax.checkpoint_policies.checkpoint_dots,
    "dots_no_batch": lambda:
        jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
}


def _maybe_remat(fn, remat):
    """remat: False | True ('full') | policy name from REMAT_POLICIES."""
    if not remat:
        return fn
    name = "full" if remat is True else remat
    return jax.checkpoint(fn, policy=REMAT_POLICIES[name]())


def forward(params, cfg: ModelConfig, inputs, *, positions=None,
            return_state: bool = False, moe_impl: str = "ragged",
            remat: bool = False, capacity_factor: float = 1.25,
            last_only: bool = False):
    """Full-sequence forward.  Returns (logits, state_or_None).

    ``return_state`` also returns the per-layer KV / SSM state produced —
    i.e. the prompt cache a prefill engine hands to a decode engine.
    Note: full-mode KV is *exact-length* (b,s,...); decode buffers are
    padded separately by the engine when it installs the cache.
    """
    b, s = inputs.shape[:2]
    if positions is None:
        positions = jnp.arange(s)
    h = embed(params, cfg, inputs)
    fam = cfg.family

    if fam in ("dense", "vlm", "encoder"):
        is_local = _is_local_arr(cfg)

        def body(hh, xs):
            blk, loc = xs
            hh, kv = _dense_block(blk, cfg, hh, mode="full",
                                  positions=positions, is_local=loc)
            return hh, (kv if return_state else 0)

        h, kvs = jax.lax.scan(_maybe_remat(body, remat), h,
                              (params["blocks"], is_local))
        state = {"kv": kvs} if return_state else None

    elif fam == "moe":
        m = cfg.moe
        state_parts = {}

        def dense_body(hh, blk):
            hh, kv = _dense_block(blk, cfg, hh, mode="full",
                                  positions=positions)
            return hh, (kv if return_state else 0)

        def moe_body(hh, blk):
            hh, kv = _dense_block(blk, cfg, hh, mode="full",
                                  positions=positions, is_moe=True,
                                  moe_impl=moe_impl,
                                  capacity_factor=capacity_factor)
            return hh, (kv if return_state else 0)

        if m.first_k_dense:
            h, kv_d = jax.lax.scan(_maybe_remat(dense_body, remat), h,
                                   params["dense_blocks"])
            state_parts["dense"] = kv_d

        if m.period > 1:
            def super_body(hh, xs):
                hh, kv_pre = jax.lax.scan(dense_body, hh, xs["pre"])
                hh, kv_moe = moe_body(hh, xs["moe"])
                return hh, ({"pre": kv_pre, "moe": kv_moe}
                            if return_state else 0)

            h, kv_s = jax.lax.scan(_maybe_remat(super_body, remat), h,
                                   params["super_blocks"])
            if return_state:
                state_parts.update(kv_s)
        else:
            h, kv_moe = jax.lax.scan(_maybe_remat(moe_body, remat), h,
                                     params["super_blocks"]["moe"])
            state_parts["moe"] = kv_moe
        state = state_parts if return_state else None

    elif fam == "ssm":
        def body(hh, blk):
            hh, st = _mamba_block(blk, cfg, hh, mode="full")
            return hh, (st if return_state else 0)

        h, sts = jax.lax.scan(_maybe_remat(body, remat), h, params["blocks"])
        state = {"mamba": sts} if return_state else None

    elif fam == "hybrid":
        shared = params["shared_block"]
        is_local = jnp.asarray(False)

        def super_body(hh, blks):
            def inner(hh2, blk):
                hh2, st = _mamba_block(blk, cfg, hh2, mode="full")
                return hh2, (st if return_state else 0)

            hh, sts = jax.lax.scan(inner, hh, blks)
            hh, kv = _dense_block(shared, cfg, hh, mode="full",
                                  positions=positions, is_local=is_local)
            return hh, ({"mamba": sts, "shared": kv} if return_state else 0)

        h, st = jax.lax.scan(_maybe_remat(super_body, remat), h,
                             params["blocks"])
        state = st if return_state else None
    else:  # pragma: no cover
        raise ValueError(fam)

    if last_only:
        h = h[:, -1:]            # prefill: only the next-token logits matter
    return logits_from_hidden(params, cfg, h), state


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def init_decode_state(cfg: ModelConfig, batch: int, max_seq: int,
                      abstract: bool = False) -> Dict[str, Any]:
    """Zero-initialised (or ShapeDtypeStruct) decode caches."""
    kvd = jnp.dtype(cfg.kv_cache_dtype)

    def kv(n_stack=()):
        shape = tuple(n_stack) + (batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
        return {"k": jax.ShapeDtypeStruct(shape, kvd) if abstract
                else jnp.zeros(shape, kvd),
                "v": jax.ShapeDtypeStruct(shape, kvd) if abstract
                else jnp.zeros(shape, kvd)}

    fam = cfg.family
    if fam in ("dense", "vlm"):
        return {"kv": kv((cfg.n_layers,))}
    if fam == "moe":
        m = cfg.moe
        n_super = (cfg.n_layers - m.first_k_dense) // m.period
        if cfg.attn_variant == "mla":
            r, rd = cfg.mla.kv_lora_rank, cfg.mla.rope_head_dim

            def mk(n_stack, dim):
                shape = tuple(n_stack) + (batch, max_seq, dim)
                return (jax.ShapeDtypeStruct(shape, kvd) if abstract
                        else jnp.zeros(shape, kvd))

            out = {}
            if m.first_k_dense:
                out["dense"] = {"c": mk((m.first_k_dense,), r),
                                "krope": mk((m.first_k_dense,), rd)}
            out["moe"] = {"c": mk((n_super,), r), "krope": mk((n_super,), rd)}
            if m.period > 1:
                out["pre"] = {"c": mk((n_super, m.period - 1), r),
                              "krope": mk((n_super, m.period - 1), rd)}
            return out
        out = {}
        if m.first_k_dense:
            out["dense"] = kv((m.first_k_dense,))
        out["moe"] = kv((n_super,))
        if m.period > 1:
            out["pre"] = kv((n_super, m.period - 1))
        return out
    if fam == "ssm":
        st = ssm_lib.init_ssm_state(cfg, batch)
        stacked = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape).copy(), st)
        if abstract:
            stacked = jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), stacked)
        return {"mamba": stacked}
    if fam == "hybrid":
        n_super = cfg.n_layers // cfg.hybrid_period
        st = ssm_lib.init_ssm_state(cfg, batch)
        stacked = jax.tree.map(
            lambda a: jnp.broadcast_to(
                a, (n_super, cfg.hybrid_period) + a.shape).copy(), st)
        if abstract:
            stacked = jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), stacked)
        return {"mamba": stacked, "shared": kv((n_super,))}
    raise ValueError(fam)  # pragma: no cover  (encoder: no decode)


def decode_step(params, cfg: ModelConfig, tokens, state, lengths, *,
                moe_impl: str = "ragged", capacity_factor: float = 1.25,
                cache_mode: str = "scan_xs"):
    """One decode step.  tokens (b,) int32; lengths (b,) = #tokens already
    cached.  Returns (logits (b, vocab), new_state).

    ``cache_mode``:
      * 'scan_xs' — caches stream through scan xs/ys (simple, but XLA
        double-buffers the stacked cache: ~2× KV residency);
      * 'carry'   — the stacked cache rides the scan *carry* with
        per-layer dynamic slice/update, which XLA aliases in place
        (§Perf iteration: ~1× KV residency).  Dense/vlm families.
    """
    assert cfg.supports_decode, cfg.name
    h = embed(params, cfg, tokens[:, None])
    fam = cfg.family

    if cache_mode == "carry" and fam in ("dense", "vlm"):
        is_local = _is_local_arr(cfg)

        def body(carry, xs):
            hh, kv = carry
            blk, loc, li = xs
            cache = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, li, 0,
                                                       keepdims=False), kv)
            hh, new_cache = _dense_block(blk, cfg, hh, mode="decode",
                                         cache=cache, lengths=lengths,
                                         is_local=loc)
            kv = jax.tree.map(
                lambda full, c: jax.lax.dynamic_update_index_in_dim(
                    full, c.astype(full.dtype), li, 0), kv, new_cache)
            return (hh, kv), None

        (h, kvs), _ = jax.lax.scan(
            body, (h, state["kv"]),
            (params["blocks"], is_local, jnp.arange(cfg.n_layers)))
        logits = logits_from_hidden(params, cfg, h)[:, 0]
        return logits, {"kv": kvs}

    if fam in ("dense", "vlm"):
        is_local = _is_local_arr(cfg)

        def body(hh, xs):
            blk, loc, cache = xs
            hh, kv = _dense_block(blk, cfg, hh, mode="decode", cache=cache,
                                  lengths=lengths, is_local=loc)
            return hh, kv

        h, kvs = jax.lax.scan(body, h,
                              (params["blocks"], is_local, state["kv"]))
        new_state = {"kv": kvs}

    elif fam == "moe":
        m = cfg.moe
        new_state = {}

        def dense_body(hh, xs):
            blk, cache = xs
            hh, kv = _dense_block(blk, cfg, hh, mode="decode", cache=cache,
                                  lengths=lengths)
            return hh, kv

        def moe_body(hh, xs):
            blk, cache = xs
            hh, kv = _dense_block(blk, cfg, hh, mode="decode", cache=cache,
                                  lengths=lengths, is_moe=True,
                                  moe_impl=moe_impl,
                                  capacity_factor=capacity_factor)
            return hh, kv

        if m.first_k_dense:
            h, kv_d = jax.lax.scan(dense_body, h,
                                   (params["dense_blocks"], state["dense"]))
            new_state["dense"] = kv_d
        if m.period > 1:
            def super_body(hh, xs):
                blks, caches = xs
                hh, kv_pre = jax.lax.scan(dense_body, hh,
                                          (blks["pre"], caches["pre"]))
                hh, kv_moe = moe_body(hh, (blks["moe"], caches["moe"]))
                return hh, {"pre": kv_pre, "moe": kv_moe}

            h, kv_s = jax.lax.scan(
                super_body, h,
                (params["super_blocks"],
                 {"pre": state["pre"], "moe": state["moe"]}))
            new_state.update(kv_s)
        else:
            h, kv_moe = jax.lax.scan(
                moe_body, h, (params["super_blocks"]["moe"], state["moe"]))
            new_state["moe"] = kv_moe

    elif fam == "ssm":
        def body(hh, xs):
            blk, st = xs
            hh, st2 = _mamba_block(blk, cfg, hh, mode="decode", state=st)
            return hh, st2

        h, sts = jax.lax.scan(body, h, (params["blocks"], state["mamba"]))
        new_state = {"mamba": sts}

    elif fam == "hybrid":
        shared = params["shared_block"]
        is_local = jnp.asarray(False)

        def super_body(hh, xs):
            blks, sts, cache = xs

            def inner(hh2, xs2):
                blk, st = xs2
                hh2, st2 = _mamba_block(blk, cfg, hh2, mode="decode",
                                        state=st)
                return hh2, st2

            hh, sts2 = jax.lax.scan(inner, hh, (blks, sts))
            hh, kv = _dense_block(shared, cfg, hh, mode="decode", cache=cache,
                                  lengths=lengths, is_local=is_local)
            return hh, (sts2, kv)

        h, (sts, kvs) = jax.lax.scan(
            super_body, h,
            (params["blocks"], state["mamba"], state["shared"]))
        new_state = {"mamba": sts, "shared": kvs}
    else:  # pragma: no cover
        raise ValueError(fam)

    logits = logits_from_hidden(params, cfg, h)[:, 0]
    return logits, new_state


# ---------------------------------------------------------------------------
# Append (engine prefill of a chunk against existing padded caches)
# ---------------------------------------------------------------------------


def _attn_append(p, cfg: ModelConfig, x, cache, lengths, is_local):
    """x (b,s,d); writes the chunk's K/V at [lengths, lengths+s)."""
    b, s, _ = x.shape
    bidx = jnp.arange(b)[:, None]
    positions = lengths[:, None] + jnp.arange(s)[None, :]
    if cfg.attn_variant == "mla":
        o, (c, kr) = mla_lib.mla_append(p, cfg, x, cache["c"],
                                        cache["krope"], lengths)
        return o, {"c": c, "krope": kr}
    q, k, v = layers.gqa_qkv(p, cfg, x, positions)
    k_cache = cache["k"].at[bidx, positions].set(k.astype(cache["k"].dtype))
    v_cache = cache["v"].at[bidx, positions].set(v.astype(cache["v"].dtype))
    o = layers.append_attend(q, k_cache, v_cache, lengths,
                             window=_window_for(cfg, is_local),
                             softcap=cfg.attn_logit_softcap)
    return layers.attn_out(p, o), {"k": k_cache, "v": v_cache}


def _append_block(p, cfg, h, cache, lengths, is_local=False, is_moe=False,
                  moe_impl="ragged", capacity_factor=1.25):
    xn = rms_norm(h, p["ln1"], cfg.rms_norm_eps)
    attn, kv = _attn_append(p["attn"], cfg, xn, cache, lengths, is_local)
    if cfg.post_attn_norm:
        attn = rms_norm(attn, p["ln1b"], cfg.rms_norm_eps)
    h = h + attn * cfg.ffn_mult
    xn = rms_norm(h, p["ln2"], cfg.rms_norm_eps)
    if is_moe:
        f = moe_lib.moe_ffn(p["moe"], cfg, xn, impl=moe_impl,
                            capacity_factor=capacity_factor)
    else:
        f = layers.ffn(p["ffn"], cfg, xn)
    if cfg.post_attn_norm:
        f = rms_norm(f, p["ln2b"], cfg.rms_norm_eps)
    return h + f * cfg.ffn_mult, kv


def _mamba_append(p, cfg, h, state):
    """Multi-token chunk through a mamba block with carried state."""
    xn = rms_norm(h, p["ln"], cfg.rms_norm_eps)
    # run the chunked scan from the carried state; conv tails carried too
    out, new_state = ssm_lib.ssd_scan_with_tails(p, cfg, xn, state)
    return h + out, new_state


def append_step(params, cfg: ModelConfig, tokens, state, lengths, *,
                moe_impl: str = "ragged", capacity_factor: float = 1.25):
    """Prefill an append chunk against existing decode state.

    tokens (b, s_app) int32 (or (b, s_app, frontend_dim) embeddings);
    lengths (b,) = tokens already cached.  Returns
    (logits (b, s_app, vocab), new_state).  This is the engine's
    layerwise-prefill compute step: the cache for layer l is consumed and
    produced per scan iteration, which is exactly the LayerBlock stream
    the dual-path loader moves.
    """
    h = embed(params, cfg, tokens)
    fam = cfg.family

    if fam in ("dense", "vlm"):
        is_local = _is_local_arr(cfg)

        def body(hh, xs):
            blk, loc, cache = xs
            hh, kv = _append_block(blk, cfg, hh, cache, lengths, is_local=loc)
            return hh, kv

        h, kvs = jax.lax.scan(body, h,
                              (params["blocks"], _is_local_arr(cfg),
                               state["kv"]))
        new_state = {"kv": kvs}

    elif fam == "moe":
        m = cfg.moe
        new_state = {}

        def dense_body(hh, xs):
            blk, cache = xs
            hh, kv = _append_block(blk, cfg, hh, cache, lengths)
            return hh, kv

        def moe_body(hh, xs):
            blk, cache = xs
            hh, kv = _append_block(blk, cfg, hh, cache, lengths, is_moe=True,
                                   moe_impl=moe_impl,
                                   capacity_factor=capacity_factor)
            return hh, kv

        if m.first_k_dense:
            h, kv_d = jax.lax.scan(dense_body, h,
                                   (params["dense_blocks"], state["dense"]))
            new_state["dense"] = kv_d
        if m.period > 1:
            def super_body(hh, xs):
                blks, caches = xs
                hh, kv_pre = jax.lax.scan(dense_body, hh,
                                          (blks["pre"], caches["pre"]))
                hh, kv_moe = moe_body(hh, (blks["moe"], caches["moe"]))
                return hh, {"pre": kv_pre, "moe": kv_moe}

            h, kv_s = jax.lax.scan(
                super_body, h,
                (params["super_blocks"],
                 {"pre": state["pre"], "moe": state["moe"]}))
            new_state.update(kv_s)
        else:
            h, kv_moe = jax.lax.scan(
                moe_body, h, (params["super_blocks"]["moe"], state["moe"]))
            new_state["moe"] = kv_moe

    elif fam == "ssm":
        def body(hh, xs):
            blk, st = xs
            hh, st2 = _mamba_append(blk, cfg, hh, st)
            return hh, st2

        h, sts = jax.lax.scan(body, h, (params["blocks"], state["mamba"]))
        new_state = {"mamba": sts}

    elif fam == "hybrid":
        shared = params["shared_block"]
        is_local = jnp.asarray(False)

        def super_body(hh, xs):
            blks, sts, cache = xs

            def inner(hh2, xs2):
                blk, st = xs2
                hh2, st2 = _mamba_append(blk, cfg, hh2, st)
                return hh2, st2

            hh, sts2 = jax.lax.scan(inner, hh, (blks, sts))
            hh, kv = _append_block(shared, cfg, hh, cache, lengths,
                                   is_local=is_local)
            return hh, (sts2, kv)

        h, (sts, kvs) = jax.lax.scan(
            super_body, h,
            (params["blocks"], state["mamba"], state["shared"]))
        new_state = {"mamba": sts, "shared": kvs}
    else:  # pragma: no cover
        raise ValueError(fam)

    return logits_from_hidden(params, cfg, h), new_state


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def lm_loss(logits, labels, mask=None):
    """Mean next-token cross-entropy.  logits (b,s,v) f32, labels (b,s)."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is None:
        return -jnp.mean(ll)
    mask = mask.astype(jnp.float32)
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
