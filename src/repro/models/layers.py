"""Core transformer layers: norms, RoPE, attention (dense/chunked/decode),
FFN variants.  Pure functions over param subtrees from ``params.model_schema``.

Layout convention: activations ``(batch, seq, d_model)``; per-head tensors
``(batch, seq, heads, head_dim)``.  All matmuls run in the param dtype
(bf16) with f32 softmax/normalisation statistics.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.sharding import constrain

NEG_INF = -1e30

# seq length above which full-attention switches to the chunked
# (flash-style online-softmax) implementation to avoid materialising
# (seq x seq) score tensors.
DENSE_ATTN_MAX_SEQ = 4096
Q_CHUNK = 1024
KV_CHUNK = 1024


def rms_norm(x, w, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def _rope_angles(positions, dim: int, theta: float):
    # positions: (...,) int32 -> (..., dim//2) angles
    freqs = jnp.exp(-math.log(theta) * jnp.arange(0, dim, 2, dtype=jnp.float32) / dim)
    return positions[..., None].astype(jnp.float32) * freqs


def apply_rope(x, positions, theta: float):
    """x: (b, s, h, dh); positions: (s,) or (b, s)."""
    dh = x.shape[-1]
    ang = _rope_angles(positions, dh, theta)          # (s, dh/2) or (b, s, dh/2)
    if ang.ndim == 2:
        ang = ang[None]                               # (1, s, dh/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _softcap(s, cap: float):
    if cap and cap > 0.0:
        return jnp.tanh(s / cap) * cap
    return s


# ---------------------------------------------------------------------------
# Attention cores
# ---------------------------------------------------------------------------


def _mask_bias(q_ids, kv_ids, *, causal, window, kv_valid):
    # window: None = unlimited; static int or traced scalar otherwise.
    """Additive mask (…,sq,skv) in f32.  q_ids (sq,), kv_ids (skv,),
    kv_valid: scalar/(b,) count of valid kv positions or None."""
    ok = jnp.ones((q_ids.shape[0], kv_ids.shape[0]), bool)
    if causal:
        ok &= q_ids[:, None] >= kv_ids[None, :]
    if window is not None:
        ok &= (q_ids[:, None] - kv_ids[None, :]) < window
    bias = jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)
    if kv_valid is not None:
        kv_valid = jnp.asarray(kv_valid)
        vmask = kv_ids[None, :] < kv_valid.reshape(-1, 1)          # (b|1, skv)
        bias = bias[None] + jnp.where(vmask, 0.0, NEG_INF)[:, None, :]
    return bias  # (sq,skv) or (b|1,sq,skv)


def _scores(qg, k, scale):
    # qg (b,sq,hkv,g,dh), k (b,skv,hkv,dh) -> (b,hkv,g,sq,skv) f32
    return jnp.einsum("bqngd,bknd->bngqk", qg, k,
                      preferred_element_type=jnp.float32) * scale


def attend(q, k, v, *, causal=True, window=None, softcap=0.0,
           q_offset=0, kv_offset=0, kv_valid=None, scale=None,
           force_dense: Optional[bool] = None):
    """Full attention; dispatches to dense or chunked implementation."""
    skv = k.shape[1]
    use_dense = force_dense if force_dense is not None else (
        skv <= DENSE_ATTN_MAX_SEQ and q.shape[1] <= DENSE_ATTN_MAX_SEQ)
    fn = _attend_dense_impl if use_dense else _attend_chunked_impl
    return fn(q, k, v, causal=causal, window=window, softcap=softcap,
              q_offset=q_offset, kv_offset=kv_offset, kv_valid=kv_valid,
              scale=scale)


def _attend_dense_impl(q, k, v, *, causal, window, softcap, q_offset,
                       kv_offset, kv_valid, scale):
    b, sq, hq, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    qg = q.reshape(b, sq, hkv, g, dh)
    s = _scores(qg, k, scale)                              # (b,hkv,g,sq,skv)
    s = _softcap(s, softcap)
    q_ids = q_offset + jnp.arange(sq)
    kv_ids = kv_offset + jnp.arange(skv)
    bias = _mask_bias(q_ids, kv_ids, causal=causal, window=window,
                      kv_valid=kv_valid)
    if bias.ndim == 2:
        s = s + bias
    else:
        s = s + bias[:, None, None]                        # (b,1,1,sq,skv)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bngqk,bknd->bqngd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    # v head dim may differ from qk head dim (MLA)
    return o.reshape(b, sq, hq, v.shape[-1]).astype(q.dtype)


def _attend_chunked_impl(q, k, v, *, causal, window, softcap, q_offset,
                         kv_offset, kv_valid, scale,
                         q_chunk=Q_CHUNK, kv_chunk=KV_CHUNK):
    """Flash-style online-softmax attention in pure jnp (O(chunk^2) memory).

    Used for long-sequence prefill where (seq x seq) scores cannot be
    materialised.  The Pallas kernel in repro.kernels.flash_attention is
    the TPU-optimised equivalent; this is the jit-compilable fallback the
    dry-run lowers (the kernel requires real TPU or interpret mode).
    """
    b, sq, hq, dh = q.shape
    vd = v.shape[-1]
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)

    pad_q = (-sq) % q_chunk
    pad_k = (-skv) % kv_chunk
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    nq, nk = qp.shape[1] // q_chunk, kp.shape[1] // kv_chunk
    if kv_valid is None:
        kv_valid_arr = jnp.full((1,), skv, jnp.int32)
    else:
        kv_valid_arr = jnp.reshape(jnp.asarray(kv_valid, jnp.int32), (-1,))
    qp = qp.reshape(b, nq, q_chunk, hkv, g, dh).transpose(1, 0, 3, 4, 2, 5)
    # (nq, b, hkv, g, qc, dh)

    def q_body(args):
        q_blk, q_ids = args                                  # ids (qc,)
        m0 = jnp.full((b, hkv, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, q_chunk, vd), jnp.float32)

        def kv_body(i, carry):
            m, lse, acc = carry
            k_blk = jax.lax.dynamic_slice_in_dim(kp, i * kv_chunk, kv_chunk, 1)
            v_blk = jax.lax.dynamic_slice_in_dim(vp, i * kv_chunk, kv_chunk, 1)
            kv_ids = kv_offset + i * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bngqd,bknd->bngqk", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * scale
            s = _softcap(s, softcap)
            ok = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                ok &= q_ids[:, None] >= kv_ids[None, :]
            if window is not None:
                ok &= (q_ids[:, None] - kv_ids[None, :]) < window
            sbias = jnp.where(ok, 0.0, NEG_INF)
            vmask = kv_ids[None, :] < kv_valid_arr[:, None]     # (b|1, kvc)
            sbias = sbias[None] + jnp.where(vmask, 0.0, NEG_INF)[:, None, :]
            s = s + sbias[:, None, None]                        # broadcast b
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = lse * corr + p.sum(-1)
            pv = jnp.einsum("bngqk,bknd->bngqd", p.astype(v_blk.dtype), v_blk,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return m_new, l_new, acc_new

        m, lse, acc = jax.lax.fori_loop(0, nk, kv_body, (m0, l0, a0))
        lse = jnp.where(lse == 0.0, 1.0, lse)
        return acc / lse[..., None]

    q_ids_all = (q_offset + jnp.arange(nq * q_chunk)).reshape(nq, q_chunk)
    out = jax.lax.map(q_body, (qp, q_ids_all))        # (nq,b,hkv,g,qc,dh)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(b, nq * q_chunk, hq, vd)
    return out[:, :sq].astype(q.dtype)


def append_attend(q, k_cache, v_cache, lengths, *, window=None, softcap=0.0,
                  scale=None):
    """Multi-token append attention against padded caches.

    q: (b, s_app, hq, dh) — the append chunk, already written into the
    caches at positions [lengths, lengths + s_app); caches (b, S, hkv, dh);
    lengths (b,) = tokens present *before* the append.  Row r attends to
    kv index < lengths + r + 1 (causal across the ragged batch).
    """
    b, s_app, hq, dh = q.shape
    S, hkv = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    qg = q.reshape(b, s_app, hkv, g, dh)
    s = jnp.einsum("bqngd,bknd->bngqk", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    s = _softcap(s, softcap)
    kv_ids = jnp.arange(S)[None, None, :]                     # (1,1,S)
    row_end = (lengths[:, None] + jnp.arange(s_app)[None, :] + 1)[..., None]
    ok = kv_ids < row_end                                     # (b,s_app,S)
    if window is not None:
        ok &= (row_end - 1 - kv_ids) < window
    s = s + jnp.where(ok, 0.0, NEG_INF)[:, None, None, :, :]  # (b,1,1,q,k)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bngqk,bknd->bqngd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, s_app, hq, v_cache.shape[-1]).astype(q.dtype)


def decode_attend(q, k_cache, v_cache, lengths, *, window=None, softcap=0.0,
                  scale=None):
    """Single-token decode attention.

    q: (b, 1, hq, dh); caches: (b, S, hkv, dh); lengths: (b,) valid length
    (the new token is already written at position lengths-1).
    """
    b, _, hq, dh = q.shape
    S, hkv = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(dh)
    qg = q.reshape(b, hkv, g, dh)
    s = jnp.einsum("bngd,bknd->bngk", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    s = _softcap(s, softcap)
    kv_ids = jnp.arange(S)
    ok = kv_ids[None, :] < lengths[:, None]
    if window is not None:
        ok &= (lengths[:, None] - 1 - kv_ids[None, :]) < window
    s = s + jnp.where(ok, 0.0, NEG_INF)[:, None, None, :]
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bngk,bknd->bngd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, 1, hq, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block plumbing
# ---------------------------------------------------------------------------


def gqa_qkv(p, cfg: ModelConfig, x, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", "seq", "heads", "head_dim")
    k = constrain(k, "batch", "seq", "kv_heads", "head_dim")
    return q, k, v


def attn_out(p, x_heads):
    b, s = x_heads.shape[:2]
    merged = x_heads.reshape(b, s, -1)
    return jnp.einsum("bsm,md->bsd", merged, p["wo"])


# ---------------------------------------------------------------------------
# FFN variants
# ---------------------------------------------------------------------------


def ffn(p, cfg: ModelConfig, x):
    act = cfg.ffn_activation
    if act in ("silu_gated", "gelu_gated"):
        gate = jnp.einsum("bsd,df->bsf", x, p["wi_gate"])
        up = jnp.einsum("bsd,df->bsf", x, p["wi_up"])
        gate = constrain(gate, "batch", "seq", "mlp")
        g = jax.nn.silu(gate) if act == "silu_gated" else jax.nn.gelu(gate)
        h = g * up
    else:
        h = jnp.einsum("bsd,df->bsf", x, p["wi"])
        h = constrain(h, "batch", "seq", "mlp")
        if act == "squared_relu":
            h = jnp.square(jax.nn.relu(h))
        else:  # gelu
            h = jax.nn.gelu(h)
    return jnp.einsum("bsf,fd->bsd", h, p["wo"])
