"""Parameter schema: single source of truth for shapes, init, sharding axes.

A schema is a pytree (nested dicts) of :class:`PSpec` leaves.  From it we
derive (a) materialised parameters, (b) logical-axis trees, (c) analytic
parameter counts — guaranteeing the three never diverge.

Stacking convention: repeated blocks carry leading stack dimensions with
logical axis name ``"layers"`` so the whole stack feeds a single
``lax.scan`` (fast compiles at 48–60 layers, small HLO for the dry-run).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

Tree = Dict


@dataclass(frozen=True)
class PSpec:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]           # logical axis per dim
    init: str = "normal"            # normal | zeros | ones | ssm_a | ssm_dt
    std: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)

    @property
    def size(self) -> int:
        return int(np.prod(self.shape))


def _stack(spec: PSpec, n: int) -> PSpec:
    return PSpec((n,) + spec.shape, ("layers",) + spec.axes, spec.init, spec.std)


def _stack_tree(tree, n: int):
    return jax.tree.map(lambda s: _stack(s, n), tree,
                        is_leaf=lambda x: isinstance(x, PSpec))


def _norm(d: int) -> PSpec:
    return PSpec((d,), ("embed",), "ones")


def _proj(d_in: int, *out, axes) -> PSpec:
    return PSpec((d_in,) + tuple(out), axes, "normal", std=1.0 / math.sqrt(d_in))


# ---------------------------------------------------------------------------
# Block schemas
# ---------------------------------------------------------------------------


def attn_schema(cfg: ModelConfig) -> Tree:
    d = cfg.d_model
    if cfg.attn_variant == "mla":
        m = cfg.mla
        qk_dim = m.nope_head_dim + m.rope_head_dim
        s = {
            "wq": _proj(d, cfg.n_heads, qk_dim, axes=("embed", "heads", "head_dim")),
            "w_dkv": _proj(d, m.kv_lora_rank, axes=("embed", "mla_rank")),
            "w_krope": _proj(d, m.rope_head_dim, axes=("embed", "head_dim")),
            "kv_norm": PSpec((m.kv_lora_rank,), ("mla_rank",), "ones"),
            "w_uk": _proj(m.kv_lora_rank, cfg.n_heads, m.nope_head_dim,
                          axes=("mla_rank", "heads", "head_dim")),
            "w_uv": _proj(m.kv_lora_rank, cfg.n_heads, m.v_head_dim,
                          axes=("mla_rank", "heads", "head_dim")),
            "wo": _proj(cfg.n_heads * m.v_head_dim, d, axes=("heads_merged", "embed")),
        }
        return s
    s = {
        "wq": _proj(d, cfg.n_heads, cfg.head_dim, axes=("embed", "heads", "head_dim")),
        "wk": _proj(d, cfg.n_kv_heads, cfg.head_dim, axes=("embed", "kv_heads", "head_dim")),
        "wv": _proj(d, cfg.n_kv_heads, cfg.head_dim, axes=("embed", "kv_heads", "head_dim")),
        "wo": _proj(cfg.n_heads * cfg.head_dim, d, axes=("heads_merged", "embed")),
    }
    if cfg.qkv_bias:
        s["bq"] = PSpec((cfg.n_heads, cfg.head_dim), ("heads", "head_dim"), "zeros")
        s["bk"] = PSpec((cfg.n_kv_heads, cfg.head_dim), ("kv_heads", "head_dim"), "zeros")
        s["bv"] = PSpec((cfg.n_kv_heads, cfg.head_dim), ("kv_heads", "head_dim"), "zeros")
    return s


def ffn_schema(cfg: ModelConfig, d_ff: int) -> Tree:
    d = cfg.d_model
    if cfg.ffn_activation in ("silu_gated", "gelu_gated"):
        return {
            "wi_gate": _proj(d, d_ff, axes=("embed", "mlp")),
            "wi_up": _proj(d, d_ff, axes=("embed", "mlp")),
            "wo": _proj(d_ff, d, axes=("mlp", "embed")),
        }
    return {
        "wi": _proj(d, d_ff, axes=("embed", "mlp")),
        "wo": _proj(d_ff, d, axes=("mlp", "embed")),
    }


def moe_schema(cfg: ModelConfig) -> Tree:
    d, m = cfg.d_model, cfg.moe
    s = {
        "router": PSpec((d, m.n_experts), ("embed", "expert"), "normal",
                        std=1.0 / math.sqrt(d)),
        "wg": PSpec((m.n_experts, d, m.d_ff_expert),
                    ("expert", "embed", "expert_mlp"),
                    "normal", std=1.0 / math.sqrt(d)),
        "wu": PSpec((m.n_experts, d, m.d_ff_expert),
                    ("expert", "embed", "expert_mlp"),
                    "normal", std=1.0 / math.sqrt(d)),
        "wd": PSpec((m.n_experts, m.d_ff_expert, d),
                    ("expert", "expert_mlp", "embed"),
                    "normal", std=1.0 / math.sqrt(m.d_ff_expert)),
    }
    if m.n_shared_experts:
        s["shared"] = ffn_schema(cfg, m.n_shared_experts * m.d_ff_expert)
    return s


def mamba_schema(cfg: ModelConfig) -> Tree:
    d, s = cfg.d_model, cfg.ssm
    d_inner = s.expand * d
    n_heads = d_inner // s.head_dim
    bc = s.n_groups * s.d_state
    return {
        "ln": _norm(d),
        "w_z": _proj(d, d_inner, axes=("embed", "inner")),
        "w_x": _proj(d, d_inner, axes=("embed", "inner")),
        "w_B": _proj(d, bc, axes=("embed", "state_proj")),
        "w_C": _proj(d, bc, axes=("embed", "state_proj")),
        "w_dt": _proj(d, n_heads, axes=("embed", "ssm_heads")),
        "conv_x": PSpec((s.conv_width, d_inner), ("conv", "inner"), "normal",
                        std=1.0 / math.sqrt(s.conv_width)),
        "conv_B": PSpec((s.conv_width, bc), ("conv", "state_proj"), "normal",
                        std=1.0 / math.sqrt(s.conv_width)),
        "conv_C": PSpec((s.conv_width, bc), ("conv", "state_proj"), "normal",
                        std=1.0 / math.sqrt(s.conv_width)),
        "A_log": PSpec((n_heads,), ("ssm_heads",), "ssm_a"),
        "D": PSpec((n_heads,), ("ssm_heads",), "ones"),
        "dt_bias": PSpec((n_heads,), ("ssm_heads",), "ssm_dt"),
        "out_norm": PSpec((d_inner,), ("inner",), "ones"),
        "out_proj": _proj(d_inner, d, axes=("inner", "embed")),
    }


def dense_block_schema(cfg: ModelConfig, d_ff: int | None = None) -> Tree:
    s = {
        "ln1": _norm(cfg.d_model),
        "attn": attn_schema(cfg),
        "ln2": _norm(cfg.d_model),
        "ffn": ffn_schema(cfg, d_ff or cfg.d_ff),
    }
    if cfg.post_attn_norm:
        s["ln1b"] = _norm(cfg.d_model)
        s["ln2b"] = _norm(cfg.d_model)
    return s


def moe_block_schema(cfg: ModelConfig) -> Tree:
    s = {
        "ln1": _norm(cfg.d_model),
        "attn": attn_schema(cfg),
        "ln2": _norm(cfg.d_model),
        "moe": moe_schema(cfg),
    }
    if cfg.post_attn_norm:
        s["ln1b"] = _norm(cfg.d_model)
        s["ln2b"] = _norm(cfg.d_model)
    return s


# ---------------------------------------------------------------------------
# Full model schema
# ---------------------------------------------------------------------------


def model_schema(cfg: ModelConfig) -> Tree:
    d = cfg.d_model
    s: Tree = {
        "embed": {"tok": PSpec((cfg.vocab_size, d), ("vocab", "embed"),
                               "normal", std=1.0)},
        "final_norm": _norm(d),
    }
    if cfg.frontend_embed_dim:
        # modality connector for the stubbed frontend (patch/frame embeds)
        s["embed"]["frontend_proj"] = _proj(cfg.frontend_embed_dim, d,
                                            axes=("frontend", "embed"))
    if not cfg.tie_embeddings:
        s["lm_head"] = _proj(d, cfg.vocab_size, axes=("embed", "vocab"))

    fam = cfg.family
    if fam in ("dense", "vlm", "encoder"):
        s["blocks"] = _stack_tree(dense_block_schema(cfg), cfg.n_layers)
    elif fam == "moe":
        m = cfg.moe
        n_rest = cfg.n_layers - m.first_k_dense
        assert n_rest % m.period == 0, cfg.name
        n_super = n_rest // m.period
        if m.first_k_dense:
            s["dense_blocks"] = _stack_tree(dense_block_schema(cfg),
                                            m.first_k_dense)
        sb: Tree = {"moe": _stack_tree(moe_block_schema(cfg), n_super)}
        if m.period > 1:
            sb["pre"] = _stack_tree(
                _stack_tree(dense_block_schema(cfg), m.period - 1), n_super)
        s["super_blocks"] = sb
    elif fam == "ssm":
        s["blocks"] = _stack_tree(mamba_schema(cfg), cfg.n_layers)
    elif fam == "hybrid":
        assert cfg.n_layers % cfg.hybrid_period == 0, cfg.name
        n_super = cfg.n_layers // cfg.hybrid_period
        s["blocks"] = _stack_tree(
            _stack_tree(mamba_schema(cfg), cfg.hybrid_period), n_super)
        s["shared_block"] = dense_block_schema(cfg, d_ff=cfg.hybrid_d_ff)
    else:  # pragma: no cover
        raise ValueError(fam)
    return s


# ---------------------------------------------------------------------------
# Materialisation / derived trees
# ---------------------------------------------------------------------------

_IS_LEAF = lambda x: isinstance(x, PSpec)


def _init_leaf(spec: PSpec, key, dtype):
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "ssm_a":
        # A in [1, 16] -> store log(A); discretised as exp(-exp(A_log) * dt)
        u = jax.random.uniform(key, spec.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(dtype)
    if spec.init == "ssm_dt":
        # dt_bias = softplus^-1(dt), dt ~ logU[1e-3, 1e-1]
        lo, hi = math.log(1e-3), math.log(1e-1)
        u = jax.random.uniform(key, spec.shape, jnp.float32)
        dt = jnp.exp(lo + u * (hi - lo))
        return jnp.log(jnp.expm1(dt)).astype(dtype)
    return (jax.random.normal(key, spec.shape, jnp.float32) * spec.std).astype(dtype)


def init_params(cfg: ModelConfig, key) -> Tree:
    schema = model_schema(cfg)
    leaves, treedef = jax.tree.flatten(schema, is_leaf=_IS_LEAF)
    keys = jax.random.split(key, len(leaves))
    dtype = jnp.dtype(cfg.param_dtype)
    vals = [_init_leaf(s, k, dtype) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(cfg: ModelConfig) -> Tree:
    """ShapeDtypeStruct tree — used by the dry-run (no allocation)."""
    dtype = jnp.dtype(cfg.param_dtype)
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
                        model_schema(cfg), is_leaf=_IS_LEAF)


def logical_axes(cfg: ModelConfig) -> Tree:
    return jax.tree.map(lambda s: s.axes, model_schema(cfg), is_leaf=_IS_LEAF)


def count_params_analytic(cfg: ModelConfig) -> int:
    return sum(s.size for s in
               jax.tree.leaves(model_schema(cfg), is_leaf=_IS_LEAF))


def count_active_params_analytic(cfg: ModelConfig) -> int:
    total = count_params_analytic(cfg)
    if cfg.moe is None:
        return total
    m = cfg.moe
    # routed experts are wg+wu (d*dff each) + wd (dff*d) = 3*d*dff per expert
    per_expert = 3 * cfg.d_model * m.d_ff_expert
    n_moe_layers = sum(cfg.moe_layer_mask())
    inactive = n_moe_layers * (m.n_experts - m.top_k) * per_expert
    return total - inactive
