"""Mixture-of-experts FFN.

Two interchangeable implementations:

* ``ragged``  — dropless: sort tokens by expert, ``jax.lax.ragged_dot``
                over expert groups, segment-sum combine.  Exact; used as
                the numerical reference and on CPU.
* ``ep``      — capacity-bounded dispatch (GShard/Switch style) built by
                scatter into an ``(experts, capacity, d)`` buffer and
                batched einsums.  This is the form that shards over an
                expert axis on the production mesh (the dispatch/combine
                reshards are the EP all-to-alls the paper's traffic
                manager protects).  Tokens beyond capacity are dropped,
                matching standard TPU MoE practice; with a large
                capacity_factor it agrees with ``ragged`` exactly
                (property-tested).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.sharding import constrain


def router_probs(p, cfg: ModelConfig, x2d):
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    if cfg.moe.router_logit_softcap:
        logits = layers._softcap(logits, cfg.moe.router_logit_softcap)
    return jax.nn.softmax(logits, axis=-1)


def route(p, cfg: ModelConfig, x2d) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Return (weights (T,k) f32, expert_idx (T,k) i32)."""
    probs = router_probs(p, cfg, x2d)
    vals, idx = jax.lax.top_k(probs, cfg.moe.top_k)
    vals = vals / jnp.sum(vals, axis=-1, keepdims=True)
    return vals, idx


def _sort_by_expert(idx, T, k, E):
    flat_e = idx.reshape(-1)
    order = jnp.argsort(flat_e)
    token_of = jnp.arange(T * k) // k
    tok_sorted = token_of[order]
    e_sorted = flat_e[order]
    group_sizes = jnp.bincount(flat_e, length=E)
    return order, tok_sorted, e_sorted, group_sizes


def moe_ragged(p, cfg: ModelConfig, x2d):
    T, d = x2d.shape
    m = cfg.moe
    vals, idx = route(p, cfg, x2d)
    order, tok_sorted, e_sorted, group_sizes = _sort_by_expert(
        idx, T, m.top_k, m.n_experts)
    xs = x2d[tok_sorted]
    gate = jax.lax.ragged_dot(xs, p["wg"], group_sizes)
    up = jax.lax.ragged_dot(xs, p["wu"], group_sizes)
    h = (jax.nn.silu(gate) * up).astype(x2d.dtype)
    ys = jax.lax.ragged_dot(h, p["wd"], group_sizes)
    w_sorted = vals.reshape(-1)[order].astype(ys.dtype)
    y = jax.ops.segment_sum(ys * w_sorted[:, None], tok_sorted,
                            num_segments=T)
    return y.astype(x2d.dtype)


def moe_ep(p, cfg: ModelConfig, x2d, capacity_factor: float = 1.25,
           constrain_acts: bool = True):
    T, d = x2d.shape
    m = cfg.moe
    E, k = m.n_experts, m.top_k
    vals, idx = route(p, cfg, x2d)
    order, tok_sorted, e_sorted, group_sizes = _sort_by_expert(idx, T, k, E)
    offsets = jnp.cumsum(group_sizes) - group_sizes
    rank = jnp.arange(T * k) - offsets[e_sorted]
    C = max(int(math.ceil(T * k * capacity_factor / E)), 8)
    # scatter into the dispatch buffer; out-of-capacity slots are dropped
    xs = x2d[tok_sorted]
    buf = jnp.zeros((E, C, d), x2d.dtype)
    buf = buf.at[e_sorted, rank].set(xs, mode="drop")
    if constrain_acts:
        buf = constrain(buf, "expert", None, None)
    gate = jnp.einsum("ecd,edf->ecf", buf, p["wg"])
    up = jnp.einsum("ecd,edf->ecf", buf, p["wu"])
    h = (jax.nn.silu(gate) * up).astype(x2d.dtype)
    if constrain_acts:
        h = constrain(h, "expert", None, "mlp")
    y_buf = jnp.einsum("ecf,efd->ecd", h, p["wd"])
    if constrain_acts:
        y_buf = constrain(y_buf, "expert", None, None)
    kept = rank < C
    ys = y_buf[e_sorted, jnp.minimum(rank, C - 1)]
    ys = jnp.where(kept[:, None], ys, 0.0)
    w_sorted = vals.reshape(-1)[order].astype(ys.dtype)
    y = jax.ops.segment_sum(ys * w_sorted[:, None], tok_sorted,
                            num_segments=T)
    return y.astype(x2d.dtype)


def moe_ep_local(p, cfg: ModelConfig, x3d, capacity_factor: float = 1.25):
    """Row-local EP dispatch (beyond-paper §Perf optimisation).

    Global sort/gather of a flattened token set is unpartitionable for
    GSPMD (it replicates everything — measured 300× compute blow-up via
    ragged_dot, and the flat moe_ep's global argsort reshards every
    layer).  Routing/sort/dispatch *per batch row* keeps every op's
    leading dim batch-sharded, so tokens never leave their data shard —
    the single-program analogue of DeepEP's node-local all-to-all
    grouping.  Capacity is per-row, so imbalance drops are slightly
    higher at equal capacity_factor (tested vs ragged in
    test_models.py)."""
    return jax.vmap(
        lambda xr: moe_ep(p, cfg, xr, capacity_factor,
                          constrain_acts=False))(x3d)


def moe_dense_all(p, cfg: ModelConfig, x2d):
    """Compute ALL experts for all tokens, mask with the sparse gates
    (beyond-paper §Perf option for *fine-grained* MoE like granite,
    40 experts of d_ff 512, top-8).  Trades top_k/n_experts-fold extra
    FLOPs (2.6× here — active/total = 0.88/3.3 B) for the complete
    elimination of dispatch: no sort, no scatter, no token movement —
    every op keeps the token dim data-sharded."""
    m = cfg.moe
    probs = router_probs(p, cfg, x2d)                      # (T, E) f32
    vals, idx = jax.lax.top_k(probs, m.top_k)
    gates = jnp.zeros_like(probs)
    gates = jnp.take_along_axis(gates, idx, axis=-1)       # zeros (T,k)
    gates = jnp.zeros_like(probs).at[
        jnp.arange(x2d.shape[0])[:, None], idx].set(
        vals / jnp.sum(vals, axis=-1, keepdims=True))
    h = jnp.einsum("td,edf->tef", x2d, p["wg"])
    u = jnp.einsum("td,edf->tef", x2d, p["wu"])
    h = (jax.nn.silu(h) * u).astype(x2d.dtype)
    # keep the (T,E,f) intermediate sharded: tokens over data, expert-ffn
    # over model (wd contraction partial-sums a (T,d) all-reduce, which is
    # far smaller than materialising (T,E,f) unsharded)
    h = constrain(h, "batch", None, "mlp")
    y = jnp.einsum("tef,efd,te->td", h, p["wd"],
                   gates.astype(x2d.dtype))
    return y.astype(x2d.dtype)


def moe_ffn(p, cfg: ModelConfig, x, impl: str = "ragged",
            capacity_factor: float = 1.25):
    """x: (b, s, d) -> (b, s, d); routed experts + optional shared expert."""
    b, s, d = x.shape
    if impl == "ragged":
        y = moe_ragged(p, cfg, x.reshape(b * s, d)).reshape(b, s, d)
    elif impl == "ep":
        y = moe_ep(p, cfg, x.reshape(b * s, d),
                   capacity_factor).reshape(b, s, d)
    elif impl == "ep_local":
        y = moe_ep_local(p, cfg, x, capacity_factor)
    elif impl == "dense":
        y = moe_dense_all(p, cfg, x.reshape(b * s, d)).reshape(b, s, d)
    else:  # pragma: no cover
        raise ValueError(impl)
    if cfg.moe.n_shared_experts:
        y = y + layers.ffn(p["shared"], cfg, x)
    return y
