"""Mamba2 / SSD (state-space duality) blocks.

Implements the chunked SSD scan (train/prefill: sub-quadratic, chunk-
local quadratic term + inter-chunk recurrence) and the O(1) recurrent
decode step.  State layout:

* ``ssm``    — (b, H, P, N): per-head state (P = head_dim, N = d_state)
* ``conv_*`` — (b, conv_width-1, dim): causal-conv tail for x / B / C

All state math runs in f32.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import rms_norm
from repro.models.sharding import constrain


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    return d_inner, n_heads, s.head_dim, s.n_groups * s.d_state


def _causal_conv(x, w, tail=None):
    """Depthwise causal conv over seq. x (b,s,c), w (cw,c), tail (b,cw-1,c)."""
    cw = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(cw))
    new_tail = xp[:, -(cw - 1):] if cw > 1 else tail
    return jax.nn.silu(out), new_tail


def _inputs(p, cfg: ModelConfig, x, conv_tails=None):
    """Shared projection + conv for both scan and step paths."""
    z = jnp.einsum("bsd,di->bsi", x, p["w_z"])
    xb = jnp.einsum("bsd,di->bsi", x, p["w_x"])
    B = jnp.einsum("bsd,dn->bsn", x, p["w_B"])
    C = jnp.einsum("bsd,dn->bsn", x, p["w_C"])
    dt = jnp.einsum("bsd,dh->bsh", x, p["w_dt"])
    tails = conv_tails or {}
    xb, tx = _causal_conv(xb, p["conv_x"], tails.get("conv_x"))
    B, tb = _causal_conv(B, p["conv_B"], tails.get("conv_B"))
    C, tc = _causal_conv(C, p["conv_C"], tails.get("conv_C"))
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))
    new_tails = {"conv_x": tx, "conv_B": tb, "conv_C": tc}
    return z, xb, B, C, dt, new_tails


def ssd_scan(p, cfg: ModelConfig, x, initial_state=None, conv_tails_in=None):
    """Chunked SSD over a full sequence.

    x: (b, s, d_model) -> (y (b, s, d_model), final state dict
    {"ssm" (b,H,P,N), "conv_x/B/C" tails}).  ``initial_state`` /
    ``conv_tails_in`` continue a previous chunk (engine append path).
    """
    s_cfg = cfg.ssm
    d_inner, H, P, N = _dims(cfg)
    b, s, _ = x.shape
    L = min(s_cfg.chunk_size, s)
    pad = (-s) % L
    z, xb, B, C, dt, conv_tails = _inputs(p, cfg, x, conv_tails_in)
    if pad:
        xb = jnp.pad(xb, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // L

    A = -jnp.exp(p["A_log"].astype(jnp.float32))              # (H,)
    # chunk-major for the scan: the recurrence runs over chunks anyway, so
    # computing the chunk-local quadratic term inside the scan keeps the
    # working set at one (b, L, L, H) block instead of nc of them
    # (critical at prefill_32k: nc=128 chunks).
    xh = xb.reshape(b, nc, L, H, P).transpose(1, 0, 2, 3, 4)
    Bc = B.reshape(b, nc, L, N).transpose(1, 0, 2, 3)
    Cc = C.reshape(b, nc, L, N).transpose(1, 0, 2, 3)
    dtc = dt.reshape(b, nc, L, H).transpose(1, 0, 2, 3)       # f32 already

    h0 = (jnp.zeros((b, H, P, N), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))
    idx = jnp.arange(L)
    causal = (idx[:, None] >= idx[None, :])[:, :, None]       # (Li,Lj,1)

    def chunk_body(h, xs):
        xh_c, B_c, C_c, dt_c = xs
        xh_c = xh_c.astype(jnp.float32)
        B_c = B_c.astype(jnp.float32)
        C_c = C_c.astype(jnp.float32)
        dA = dt_c * A                                          # (b,L,H)
        cs = jnp.cumsum(dA, axis=1)
        seg = cs[:, :, None, :] - cs[:, None, :, :]            # (b,Li,Lj,H)
        Lmat = jnp.where(causal[None], jnp.exp(seg), 0.0)
        CB = jnp.einsum("bin,bjn->bij", C_c, B_c)
        w = CB[..., None] * Lmat * dt_c[:, None, :, :]         # (b,i,j,H)
        y_c = jnp.einsum("bijh,bjhp->bihp", w, xh_c)
        # inter-chunk: contribution of the carried state
        y_c = y_c + jnp.einsum("bin,bhpn,bih->bihp",
                               C_c, h, jnp.exp(cs))
        # state update: h' = h * decay(chunk) + sum_j decay_to_end_j dt_j B_j x_j
        decay_to_end = jnp.exp(cs[:, -1:, :] - cs)             # (b,L,H)
        S_c = jnp.einsum("blh,bln,blhp->bhpn",
                         decay_to_end * dt_c, B_c, xh_c)
        h_new = h * jnp.exp(cs[:, -1, :])[:, :, None, None] + S_c
        return h_new, y_c

    h_final, ys = jax.lax.scan(chunk_body, h0, (xh, Bc, Cc, dtc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, nc * L, H, P)
    if pad:
        y = y[:, :s]
    y = y + xb.reshape(b, nc * L, H, P)[:, :s].astype(jnp.float32) * \
        p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(b, s, d_inner).astype(x.dtype)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"], cfg.rms_norm_eps)
    y = constrain(y, "batch", "seq", "inner")
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    final_state = dict(conv_tails, ssm=h_final.astype(jnp.float32))
    return out, final_state


def ssd_scan_with_tails(p, cfg: ModelConfig, x, state: Dict):
    """Continue the SSD scan from a carried state dict (engine appends)."""
    tails = {k: state[k] for k in ("conv_x", "conv_B", "conv_C")}
    return ssd_scan(p, cfg, x, initial_state=state["ssm"],
                    conv_tails_in=tails)


def ssm_decode_step(p, cfg: ModelConfig, x, state: Dict):
    """Single-token recurrent step.

    x: (b, 1, d_model); state dict with 'ssm' (b,H,P,N) + conv tails.
    Returns (y (b,1,d_model), new_state).
    """
    d_inner, H, P, N = _dims(cfg)
    tails = {k: state[k] for k in ("conv_x", "conv_B", "conv_C")}
    z, xb, B, C, dt, new_tails = _inputs(p, cfg, x, tails)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xb[:, 0].reshape(-1, H, P).astype(jnp.float32)       # (b,H,P)
    Bv = B[:, 0].astype(jnp.float32)                          # (b,N)
    Cv = C[:, 0].astype(jnp.float32)
    dtv = dt[:, 0]                                            # (b,H)
    h = state["ssm"].astype(jnp.float32)                      # (b,H,P,N)
    decay = jnp.exp(dtv * A)                                  # (b,H)
    h = h * decay[:, :, None, None] + \
        (dtv[:, :, None] * xh)[..., None] * Bv[:, None, None, :]
    y = jnp.einsum("bhpn,bn->bhp", h, Cv)
    y = y + xh * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(-1, 1, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"], cfg.rms_norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    new_state = dict(new_tails, ssm=h.astype(jnp.float32))
    return out, new_state


def init_ssm_state(cfg: ModelConfig, batch: int) -> Dict:
    d_inner, H, P, N = _dims(cfg)
    cw = cfg.ssm.conv_width
    bc = cfg.ssm.n_groups * cfg.ssm.d_state
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "ssm": jnp.zeros((batch, H, P, N), jnp.float32),
        "conv_x": jnp.zeros((batch, cw - 1, d_inner), dt),
        "conv_B": jnp.zeros((batch, cw - 1, bc), dt),
        "conv_C": jnp.zeros((batch, cw - 1, bc), dt),
    }
