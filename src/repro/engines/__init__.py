from repro.engines.runtime import DecodeEngine, EngineRequest, PrefillEngine
