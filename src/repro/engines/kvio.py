"""KV state ↔ FullBlock byte serialisation + slot utilities.

The engines keep decode state as padded jnp buffers (layer-leading, for
lax.scan); persistent storage holds FullBlocks ``[layers, tokens, bytes]``
(paper §A.5).  This module converts between them, per attention family:

* gqa (dense/vlm/moe): row = k ‖ v            (2·hkv·dh·dtype bytes/token)
* mla:                 row = c_kv ‖ k_rope    ((r+rd)·dtype bytes/token)

SSM/hybrid archs have no per-token KV; their recurrent state is carried
as an opaque *state blob* snapshot (see engines/runtime.py) — the
transfer paths are identical, only the payload differs.

:func:`layer_stream` is the engine-side realisation of layerwise
loading (paper §4.1): it delivers one attention layer's KV at a time,
gathered through the kernels/kv_gather.py Pallas path, with the next
layer's gather already submitted (in flight on the TrafficManager)
while the current layer is being installed — double buffering at
LayerBlock granularity.
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.traffic import TrafficClass, TrafficManager
from repro.models.model import init_decode_state


def batch_axes_of_state(cfg: ModelConfig):
    """Tree matching the decode state with each leaf's batch-axis index
    (stacking puts layers in front, so the axis varies per leaf)."""
    s3 = init_decode_state(cfg, 3, 8, abstract=True)
    s4 = init_decode_state(cfg, 4, 8, abstract=True)

    def find(a, b):
        for i, (x, y) in enumerate(zip(a.shape, b.shape)):
            if x != y:
                return i
        raise AssertionError((a.shape, b.shape))

    return jax.tree.map(find, s3, s4)


def slot_get(state, axes, slot: int):
    """Extract one sequence's state (batch size 1 view)."""
    return jax.tree.map(
        lambda a, ax: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=ax),
        state, axes)


def slot_set(state, axes, slot: int, sub):
    return jax.tree.map(
        lambda a, ax, s: jax.lax.dynamic_update_slice_in_dim(a, s, slot, ax),
        state, axes, sub)


# ---------------------------------------------------------------------------
# attention-layer enumeration (canonical layer order for serialisation)
# ---------------------------------------------------------------------------


def _kv_rows(cfg: ModelConfig) -> List[Tuple[str, tuple]]:
    """(state_key, stack_index) per attention layer, in layer order."""
    fam = cfg.family
    rows: List[Tuple[str, tuple]] = []
    if fam in ("dense", "vlm"):
        for li in range(cfg.n_layers):
            rows.append(("kv", (li,)))
    elif fam == "moe":
        m = cfg.moe
        for li in range(m.first_k_dense):
            rows.append(("dense", (li,)))
        n_super = (cfg.n_layers - m.first_k_dense) // m.period
        for i in range(n_super):
            if m.period > 1:
                for j in range(m.period - 1):
                    rows.append(("pre", (i, j)))
            rows.append(("moe", (i,)))
    elif fam == "hybrid":
        n_super = cfg.n_layers // cfg.hybrid_period
        for i in range(n_super):
            rows.append(("shared", (i,)))
    else:
        raise ValueError(fam)
    return rows


def kv_row_bytes(cfg: ModelConfig, dtype_bytes: int = 2) -> int:
    if cfg.attn_variant == "mla":
        return (cfg.mla.kv_lora_rank + cfg.mla.rope_head_dim) * dtype_bytes
    return 2 * cfg.n_kv_heads * cfg.head_dim * dtype_bytes


def n_attn_layers(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.hybrid_period
    return cfg.n_layers


def _to_bytes(a) -> np.ndarray:
    return np.asarray(a).reshape(a.shape[0], -1).view(np.uint8)


def serialize_kv_layer(cfg: ModelConfig, state, slot: int, t0: int,
                       t1: int, layer: int) -> np.ndarray:
    """One attention layer's KV rows -> (t1-t0, row_bytes) uint8."""
    key, idx = _kv_rows(cfg)[layer]
    comp = state[key]
    if cfg.attn_variant == "mla":
        c = np.asarray(comp["c"][idx + (slot, slice(t0, t1))])
        kr = np.asarray(comp["krope"][idx + (slot, slice(t0, t1))])
        return np.concatenate([_to_bytes(c), _to_bytes(kr)], axis=-1)
    k = np.asarray(comp["k"][idx + (slot, slice(t0, t1))])
    v = np.asarray(comp["v"][idx + (slot, slice(t0, t1))])
    return np.concatenate([_to_bytes(k), _to_bytes(v)], axis=-1)


def serialize_kv(cfg: ModelConfig, state, slot: int, t0: int,
                 t1: int) -> np.ndarray:
    """-> (n_attn_layers, t1-t0, row_bytes) uint8."""
    return np.stack([serialize_kv_layer(cfg, state, slot, t0, t1, li)
                     for li in range(len(_kv_rows(cfg)))], axis=0)


def deserialize_kv_layer(cfg: ModelConfig, state, slot: int, t0: int,
                         layer: int, row: np.ndarray):
    """Write one layer's (T, row_bytes) uint8 rows into the state —
    the per-LayerBlock HBM placement step of layerwise loading."""
    key, idx = _kv_rows(cfg)[layer]
    T = row.shape[0]
    dt = jnp.dtype(cfg.kv_cache_dtype)
    if cfg.attn_variant == "mla":
        r = cfg.mla.kv_lora_rank
        rd = cfg.mla.rope_head_dim
        c = row[:, :r * dt.itemsize].copy().view(dt).reshape(T, r)
        kr = row[:, r * dt.itemsize:].copy().view(dt).reshape(T, rd)
        upd = {"c": jnp.asarray(c), "krope": jnp.asarray(kr)}
    else:
        half = cfg.n_kv_heads * cfg.head_dim * dt.itemsize
        k = row[:, :half].copy().view(dt).reshape(
            T, cfg.n_kv_heads, cfg.head_dim)
        v = row[:, half:].copy().view(dt).reshape(
            T, cfg.n_kv_heads, cfg.head_dim)
        upd = {"k": jnp.asarray(k), "v": jnp.asarray(v)}
    new_state = dict(state)
    comp = dict(new_state[key])
    for ckey, val in upd.items():
        arr = comp[ckey]
        comp[ckey] = arr.at[
            idx + (slot, slice(t0, t0 + val.shape[0]))].set(
            val.astype(arr.dtype))
    new_state[key] = comp
    return new_state


def deserialize_kv(cfg: ModelConfig, state, slot: int, t0: int,
                   kv_bytes: np.ndarray):
    """Write (L, T, row_bytes) uint8 back into the padded state buffers."""
    rows = _kv_rows(cfg)
    L = kv_bytes.shape[0]
    assert L == len(rows), (L, len(rows))
    for li in range(L):
        state = deserialize_kv_layer(cfg, state, slot, t0, li, kv_bytes[li])
    return state


# ---------------------------------------------------------------------------
# layerwise double-buffered delivery (paper §4.1)
# ---------------------------------------------------------------------------


def layer_stream(cfg: ModelConfig, blocks: List[np.ndarray],
                 tm: Optional[TrafficManager] = None,
                 tclass: TrafficClass = TrafficClass.KV_TRANSFER,
                 interpret: bool = True
                 ) -> Iterator[Tuple[int, np.ndarray]]:
    """Double-buffered per-layer LayerBlock stream from FullBlock pages.

    ``blocks``: the request's hit FullBlocks, each (L, page_tokens,
    row_bytes) uint8.  Yields ``(layer, rows)`` with ``rows`` of shape
    (n_blocks·page_tokens, row_bytes), gathered through the
    kernels/kv_gather.py Pallas kernel (interpret mode on CPU) so the
    HBM-placement path is the same pipelined-DMA gather the TPU runs.

    Pipeline shape: layer ``i+1``'s gather is *submitted* to the
    TrafficManager before layer ``i`` is yielded, so while the consumer
    installs layer ``i`` the next LayerBlock sits in flight on the KV
    virtual lane — at most two layer buffers are ever live, exactly the
    double-buffering the paper overlaps with per-layer prefill compute.
    The TrafficManager charges each gather's bytes to the KV traffic
    class, exercising the §5 ordering/doorbell-batching machinery.
    """
    from repro.kernels.kv_gather import kv_layer_gather

    n_l = n_attn_layers(cfg)
    if not blocks or n_l == 0:
        return
    pool = jnp.asarray(np.stack(blocks))      # (n_blocks, L, pt, row)
    n, _, pt, row = pool.shape
    table = jnp.arange(n, dtype=jnp.int32)
    layer_bytes = int(n * pt * row)
    own_tm = tm is None
    if own_tm:
        tm = TrafficManager()
    buf: Dict[int, np.ndarray] = {}

    def fetch(layer: int):
        out = kv_layer_gather(pool, table, layer=layer, interpret=interpret)
        buf[layer] = np.asarray(out).reshape(n * pt, row)

    tm.submit(lambda: fetch(0), layer_bytes, tclass)
    for li in range(n_l):
        tm.drain()                            # layer li has landed
        if li + 1 < n_l:                      # layer li+1 goes in flight
            tm.submit(lambda nxt=li + 1: fetch(nxt), layer_bytes, tclass)
        yield li, buf.pop(li)
