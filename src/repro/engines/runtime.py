"""Inference engines: layerwise-prefill PE and paged-decode DE.

Single-process, CPU-runnable versions of the paper's engines that move
*real* KV bytes through the dual-path legs:

* ``PrefillEngine`` — quota-packed chunked prefill (core/intra.py) via
  ``model.append_step`` against a per-request padded state; hit-KV
  arrives as FullBlocks (deserialised into the state before compute);
  the prompt state then transfers to the DE.
* ``DecodeEngine``  — slot-batched continuous decode via
  ``model.decode_step``; persists newly-filled FullBlocks to storage and
  inserts them into the trie (paper: persist per 64-token block).

Transfers ride each engine's TrafficManager with TrafficClass.KV_TRANSFER
so the CNIC-centric ordering/batching logic (§5) is exercised for real.
SSM/hybrid archs carry an opaque state-blob instead of per-token KV
(constant-size recurrent state; see DESIGN.md §5).
"""
from __future__ import annotations

import pickle
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.blocks import BlockLayout
from repro.core.intra import (AttnTimeModel, BatchItem, PrefillWork,
                              QuotaPacker, class_insert_index)
from repro.core.scheduler import Request
from repro.core.traffic import TrafficClass, TrafficManager
from repro.engines import kvio
from repro.kvcache.store import MemoryKVStore, StateBlobStore
from repro.kvcache.trie import BlockTrie
from repro.models import decode_step, init_decode_state
from repro.models.model import append_step

PAGED_FAMILIES = ("dense", "vlm", "moe")


def uses_state_blob(cfg: ModelConfig) -> bool:
    return cfg.family in ("ssm", "hybrid")


@dataclass
class EngineRequest:
    """A request with its token payload, as the engines see it."""

    req: Request
    context_tokens: List[int]        # full previous context (hit source)
    append_tokens: List[int]         # new tokens to prefill
    hit_refs: List[int] = field(default_factory=list)
    state: object = None             # per-request (b=1) model state
    length: int = 0                  # tokens materialised in state
    generated: List[int] = field(default_factory=list)
    first_token: Optional[int] = None

    @property
    def prompt_len(self) -> int:
        return len(self.context_tokens) + len(self.append_tokens)


class PrefillEngine:
    def __init__(self, eid, cfg: ModelConfig, params, store: MemoryKVStore,
                 layout: BlockLayout, max_seq: int,
                 quota_s: float = 0.300, layerwise: bool = True,
                 chunk_tokens: Optional[int] = None,
                 class_aware: bool = False):
        self.eid = eid
        self.cfg = cfg
        self.params = params
        self.store = store
        self.layout = layout
        self.max_seq = max_seq
        self.layerwise = layerwise
        self.tm = TrafficManager()
        self.packer = QuotaPacker(cfg, AttnTimeModel.from_config(cfg),
                                  quota_s=quota_s, chunk_tokens=chunk_tokens)
        self.class_aware = class_aware
        self.fifo: List[Tuple[PrefillWork, EngineRequest]] = []
        self.prefill_tokens = 0
        # (cached, bsz) items of the batch the last step() executed — the
        # serving clock's compute-duration input (events.ServingTimeModel)
        self.last_step_items: List[Tuple[int, int]] = []
        # requests whose last-step batch item was a partial (chunked)
        # slice and whose prefill is still unfinished — the serving
        # runtime's PREFILL_CHUNKED sub-state + chunk-counter source
        self.last_step_chunked: List[EngineRequest] = []

    # -- loading ---------------------------------------------------------
    def install_hit_kv(self, er: EngineRequest, payload):
        """payload: list of FullBlocks (paged archs) or a state blob.

        With ``layerwise`` (default, paper §4.1) the hit KV is installed
        one LayerBlock at a time via kvio.layer_stream: each layer's
        rows are gathered through the kernels/kv_gather.py path while
        the next layer's gather is already in flight on this engine's
        TrafficManager (double buffering).  The non-layerwise path is
        the whole-prompt bulk install, kept for the Fig. 12 ablation.
        """
        er.state = init_decode_state(self.cfg, 1, self.max_seq)
        hit = er.req.cached_tokens
        if uses_state_blob(self.cfg):
            if payload is not None:
                er.state = jax.tree.map(jnp.asarray, pickle.loads(payload))
            er.length = hit
        elif payload:
            if self.layerwise:
                for li, rows in kvio.layer_stream(self.cfg, payload,
                                                  tm=self.tm):
                    er.state = kvio.deserialize_kv_layer(
                        self.cfg, er.state, 0, 0, li, rows[:hit])
            else:
                kv_bytes = np.concatenate(payload, axis=1)   # (L, hit, row)
                er.state = kvio.deserialize_kv(self.cfg, er.state, 0, 0,
                                               kv_bytes[:, :hit])
        er.length = hit
        work = PrefillWork(er.req.rid, hit, len(er.append_tokens),
                           rank=er.req.class_rank, arrival=er.req.arrival)
        if self.class_aware:
            # the serving-side mirror of the sim's class-ordered fifo:
            # TTFT wait accrues here, not in the scheduler's global queue
            self.fifo.insert(class_insert_index(
                [w.key() for w, _ in self.fifo], work.key()), (work, er))
        else:
            self.fifo.append((work, er))

    # -- compute ---------------------------------------------------------
    def step(self) -> List[EngineRequest]:
        """Run one quota-packed forward batch; returns requests whose
        prefill completed this step."""
        self.last_step_items = []
        self.last_step_chunked = []
        if not self.fifo:
            return []
        works = [w for w, _ in self.fifo]
        byrid = {w.rid: er for w, er in self.fifo}
        batch = self.packer.pack(works)
        if not batch and works:
            # quota smaller than min_chunk for the head request: force
            # minimal progress so the engine never stalls
            w = works[0]
            bsz = min(w.remaining, self.packer.min_chunk)
            batch = [BatchItem(w.rid, w.cached, bsz, chunked=True)]
            w.advance(bsz)
            if w.remaining == 0:
                works.pop(0)
        self.fifo = [(w, byrid[w.rid]) for w in works]
        self.last_step_items = [(bi.cached, bi.bsz) for bi in batch]
        done = []
        for bi in batch:
            er = byrid[bi.rid]
            toks = er.append_tokens[bi.cached - er.req.cached_tokens:
                                    bi.cached - er.req.cached_tokens + bi.bsz]
            t = jnp.asarray([toks], jnp.int32)
            lengths = jnp.asarray([er.length], jnp.int32)
            logits, er.state = append_step(self.params, self.cfg, t,
                                           er.state, lengths)
            er.length += bi.bsz
            self.prefill_tokens += bi.bsz
            if er.length == er.prompt_len:
                er.first_token = int(jnp.argmax(logits[0, -1]))
                done.append(er)
            elif bi.chunked:
                self.last_step_chunked.append(er)
        return done


class DecodeEngine:
    def __init__(self, eid, cfg: ModelConfig, params, store: MemoryKVStore,
                 trie: BlockTrie, layout: BlockLayout, max_seq: int,
                 n_slots: int = 8, blob_store: StateBlobStore | None = None):
        self.eid = eid
        self.cfg = cfg
        self.params = params
        self.store = store
        self.blob_store = blob_store
        self.trie = trie
        self.layout = layout
        self.max_seq = max_seq
        self.n_slots = n_slots
        self.tm = TrafficManager()
        self.state = init_decode_state(cfg, n_slots, max_seq)
        self.axes = kvio.batch_axes_of_state(cfg)
        self.slots: List[Optional[EngineRequest]] = [None] * n_slots
        self.lengths = np.zeros(n_slots, np.int32)
        self.next_token = np.zeros(n_slots, np.int32)
        self.decode_steps = 0
        # context lengths the last step() decoded over (serving clock)
        self.last_step_ctxs: List[int] = []
        # pipelined persistence (serving/events.py lifecycle PERSIST):
        # with defer_persist the block writes are *submitted* to the tm
        # but not drained, and (request, finalize) pairs park here until
        # the system flushes the tm — finalize inserts the trie entries
        # once the write completions have landed
        self.defer_persist = False
        self.pending_persist: List[Tuple[EngineRequest,
                                         Optional[callable]]] = []

    @property
    def free_slots(self) -> int:
        return sum(s is None for s in self.slots)

    def admit(self, er: EngineRequest) -> int:
        slot = self.slots.index(None)
        self.slots[slot] = er
        self.state = kvio.slot_set(self.state, self.axes, slot, er.state)
        self.lengths[slot] = er.length
        self.next_token[slot] = er.first_token
        er.generated.append(er.first_token)
        er.state = None                      # DE owns the state now
        return slot

    def step(self) -> List[EngineRequest]:
        """One decode step over all active slots; returns finished."""
        self.last_step_ctxs = [int(self.lengths[s])
                               for s, er in enumerate(self.slots)
                               if er is not None]
        if all(s is None for s in self.slots):
            return []
        toks = jnp.asarray(self.next_token, jnp.int32)
        lengths = jnp.asarray(self.lengths, jnp.int32)
        logits, self.state = decode_step(self.params, self.cfg, toks,
                                         self.state, lengths)
        self.decode_steps += 1
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        finished = []
        for slot, er in enumerate(self.slots):
            if er is None:
                continue
            self.lengths[slot] += 1
            self.next_token[slot] = nxt[slot]
            if len(er.generated) < er.req.gen_tokens:
                er.generated.append(int(nxt[slot]))
            if len(er.generated) >= er.req.gen_tokens:
                self._persist(slot, er)
                finished.append(er)
                self.slots[slot] = None
                self.lengths[slot] = 0
        return finished

    # -- persistence (per full block, as in the paper) --------------------
    def _persist(self, slot: int, er: EngineRequest):
        """Serialise the slot's new state and submit the storage writes.

        The state snapshot (serialize_kv / pickle) is taken NOW — the
        slot may be re-admitted before deferred writes land — but the
        write execution and the trie insert are the *completion* half:
        with ``defer_persist`` they wait parked in ``pending_persist``
        for the system's flush; otherwise they drain inline (the
        blocking runtime's behaviour)."""
        full_tokens = er.context_tokens + er.append_tokens + er.generated
        bt = self.layout.block_tokens
        n_blocks = len(full_tokens) // bt
        start_block = er.req.cached_tokens // bt
        if uses_state_blob(self.cfg):
            blob = pickle.dumps(jax.tree.map(
                np.asarray, kvio.slot_get(self.state, self.axes, slot)))
            self.tm.submit(
                lambda b=blob, k=tuple(full_tokens), n=int(self.lengths[slot]):
                self.blob_store.put(k, b, n),
                len(blob), TrafficClass.KV_TRANSFER)
            if self.defer_persist:
                self.pending_persist.append((er, None))
            else:
                self.tm.drain()
            return
        if n_blocks <= start_block:
            if self.defer_persist:
                self.pending_persist.append((er, None))
            return
        kv_bytes = kvio.serialize_kv(self.cfg, self.state, slot,
                                     start_block * bt, n_blocks * bt)
        new_refs = [self.store.alloc_ref()
                    for _ in range(n_blocks - start_block)]
        for i, ref in enumerate(new_refs):
            blk = np.ascontiguousarray(kv_bytes[:, i * bt:(i + 1) * bt])
            self.tm.submit(lambda r=ref, b=blk: self.store.write_block(r, b),
                           blk.nbytes, TrafficClass.KV_TRANSFER)
        finalize = lambda toks=full_tokens[:n_blocks * bt], refs=new_refs: \
            self.trie.insert(toks, refs)
        if self.defer_persist:
            self.pending_persist.append((er, finalize))
        else:
            self.tm.drain()
            finalize()
