"""Hardware / model / workload specs for the cluster simulator.

Two built-in hardware profiles:
* ``hopper_node``  — the paper's testbed (8 GPUs, 8×400 Gb CNIC,
  1×400 Gb SNIC, ~500 GB/s DRAM); used for paper-reproduction numbers.
* ``tpu_v5e_host`` — the TPU adaptation target (4 chips/host, shared
  host NIC, 819 GB/s HBM, 197 TFLOP/s bf16); used for the adapted runs
  recorded in DESIGN.md.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.configs.base import ModelConfig
from repro.core.analysis import ClusterSpec


@dataclass(frozen=True)
class GPUSpec:
    flops: float                 # effective dense FLOP/s for inference dtype
    hbm_bw: float                # bytes/s
    hbm_bytes: float
    mfu_prefill: float = 0.55    # achievable fraction during prefill
    mbu_decode: float = 0.70     # achievable HBM-bandwidth fraction in decode


@dataclass(frozen=True)
class NodeSpec:
    g: int                       # engines per node
    cnic_bw: float               # per-engine compute-NIC bandwidth [B/s]
    snic_bw: float               # per-node storage-NIC bandwidth [B/s]
    dram_bw: float               # per-node DRAM bandwidth [B/s]
    gpu: GPUSpec = field(default_factory=lambda: HOPPER_GPU)

    def cluster_spec(self) -> ClusterSpec:
        return ClusterSpec(g=self.g, B=self.cnic_bw,
                           s=self.snic_bw / self.cnic_bw, M=self.dram_bw)


HOPPER_GPU = GPUSpec(flops=990e12, hbm_bw=3.35e12, hbm_bytes=80e9)
TPU_V5E = GPUSpec(flops=197e12, hbm_bw=819e9, hbm_bytes=16e9,
                  mfu_prefill=0.5, mbu_decode=0.75)

# 400 Gbps = 50 GB/s
HOPPER_NODE = NodeSpec(g=8, cnic_bw=50e9, snic_bw=50e9, dram_bw=500e9,
                       gpu=HOPPER_GPU)
TPU_V5E_HOST = NodeSpec(g=4, cnic_bw=45e9, snic_bw=25e9, dram_bw=200e9,
                        gpu=TPU_V5E)

# A node scaled down to the `reduced()` test models: storage reads cost
# modelled seconds comparable to their compute, reproducing the paper's
# bandwidth-bound regime at CI scale.  The serving-runtime benchmark,
# tests and example all share this profile so the regime they measure
# stays a single definition.
REDUCED_TEST_NODE = NodeSpec(
    g=1, cnic_bw=2e6, snic_bw=1e6, dram_bw=20e6,
    gpu=GPUSpec(flops=50e9, hbm_bw=5e9, hbm_bytes=1e9))


@dataclass(frozen=True)
class ModelSimSpec:
    """Analytic per-token quantities the simulator needs."""

    name: str
    n_layers: int
    kv_bytes_per_token: int          # loadable KV bytes per context token
    active_param_bytes: float        # bytes touched per decode step
    active_params: float             # active parameter count
    n_heads: int
    qk_head_dim: int
    sparse_topk: int = 0             # DSA-style sparse attention (0 = dense)
    linear_ctx_flops: float = 0.0    # extra FLOPs per (token x ctx-token):
                                     # DSA lightning-indexer style terms
    ssm_state_bytes: int = 0
    total_param_bytes: float = 0.0   # full weight bytes (MoE: all experts)

    @classmethod
    def from_config(cls, cfg: ModelConfig, kv_dtype_bytes: int = 2,
                    param_dtype_bytes: int = 2) -> "ModelSimSpec":
        qk = cfg.head_dim if cfg.attn_variant != "mla" else (
            cfg.mla.nope_head_dim + cfg.mla.rope_head_dim)
        return cls(
            name=cfg.name,
            n_layers=cfg.n_layers,
            kv_bytes_per_token=cfg.kv_bytes_per_token(kv_dtype_bytes),
            active_param_bytes=cfg.active_param_count() * param_dtype_bytes,
            active_params=cfg.active_param_count(),
            n_heads=max(cfg.n_heads, 1),
            qk_head_dim=max(qk, 1),
            ssm_state_bytes=cfg.ssm_state_bytes(),
            total_param_bytes=cfg.param_count() * param_dtype_bytes,
        )

    def active_param_bytes_resident(self, group_size: int) -> float:
        """Weight bytes one engine touches per decode step: its shard of
        the resident weights (decode batches activate ~all experts)."""
        tot = self.total_param_bytes or self.active_param_bytes
        return tot / max(group_size, 1)

    # --- compute/IO models -------------------------------------------------
    def linear_flops_per_token(self) -> float:
        return 2.0 * self.active_params

    def attn_flops_per_token(self, ctx: int) -> float:
        """Attention FLOPs for one new token at context length ctx."""
        eff_ctx = min(ctx, self.sparse_topk) if self.sparse_topk else ctx
        return (4.0 * self.n_layers * self.n_heads * self.qk_head_dim *
                eff_ctx + self.linear_ctx_flops * ctx)

    def prefill_flops(self, cached: int, bsz: int) -> float:
        # append bsz tokens on top of `cached` context
        lin = self.linear_flops_per_token() * bsz
        attn = 4.0 * self.n_layers * self.n_heads * self.qk_head_dim * \
            bsz * (cached + (bsz + 1) / 2.0)
        if self.sparse_topk:
            attn = min(attn, 4.0 * self.n_layers * self.n_heads *
                       self.qk_head_dim * bsz * self.sparse_topk)
        attn += self.linear_ctx_flops * bsz * (cached + (bsz + 1) / 2.0)
        return lin + attn

    def decode_step_flops(self, ctx: int) -> float:
        return self.linear_flops_per_token() + self.attn_flops_per_token(ctx)

    def decode_step_bytes(self, ctx: int) -> float:
        """HBM bytes touched per decode step per sequence (KV read)."""
        eff_ctx = min(ctx, self.sparse_topk) if self.sparse_topk else ctx
        return self.kv_bytes_per_token * eff_ctx + self.ssm_state_bytes

    def cache_compute_ratio(self, ctx: int, append: int) -> float:
        """GB of KV to load per PFLOP of compute (paper Table 1)."""
        load = self.kv_bytes_per_token * ctx
        comp = self.prefill_flops(ctx, append)
        return (load / 1e9) / (comp / 1e15)


# --- the paper's evaluation models (sim-level descriptors) -----------------
# DS 660B (DeepSeek-V3.2): MLA rank 512 + 64 rope, 61 layers, DSA topk 2048,
# ~37B active params.  KV fp8 => 576 B/token/layer.
DS_660B = ModelSimSpec(
    name="ds660b", n_layers=61,
    kv_bytes_per_token=61 * (512 + 64),          # fp8 latent
    active_param_bytes=37e9 * 1,                 # fp8 weights
    active_params=37e9, n_heads=128, qk_head_dim=192,
    sparse_topk=2048,
    total_param_bytes=660e9,
)

QWEN25_32B = ModelSimSpec(
    name="qwen2.5-32b", n_layers=64,
    kv_bytes_per_token=64 * 2 * 8 * 128 * 2,     # GQA kv=8, fp16 (Table 1)
    active_param_bytes=32.8e9 * 2,
    active_params=32.8e9, n_heads=40, qk_head_dim=128,
    total_param_bytes=32.8e9 * 2,
)
