"""Fault model shared by the simulator and the serving runtime.

The paper's throughput/SLO claims assume healthy NICs and nodes;
production agentic serving means constant *partial* failure — a storage
NIC renegotiates to a lower PCIe width, a ToR link flaps, a decode
engine's host dies mid-wave, an object-store read straggles.  This
module is the single description of those processes so the discrete
simulator (``sim/simulator.py``) and the real-bytes serving runtime
(``serving/system.py``) inject *the same* fault timeline and the
resilience benchmark can compare arms apples-to-apples.

Design rules (load-bearing for the chaos suite in tests/test_faults.py):

* **Deterministic.**  A :class:`FaultSchedule` is pure data — windows,
  death times, and a hash-based straggler draw.  No RNG state is
  consumed at query time, so two runtimes (or two runs) asking in
  different orders see identical faults, and every chaos failure
  reproduces from ``(seed, rates)`` alone.
* **Empty = invisible.**  Every injection hook must be a structural
  no-op when the schedule is empty: a zero-rate schedule produces
  bit-identical tokens and event timelines to ``faults=None``.  The
  benchmark (fig_resilience) and the fuzz suite both assert this.
* **Slowdowns are service-time multipliers** (>= 1), never absolute
  rates, so the same schedule scales across node specs.

Fault taxonomy (tentpole spec):

=================  =====================================================
``SlowdownWindow``  resource ``"snic"`` (per-node storage-NIC
                    degradation) or ``"net"`` (compute-network link
                    flap); active on ``t0 <= t < t1``; overlapping
                    windows compose multiplicatively.
``EngineDeath``     an engine (pe or de) fails permanently at ``t``;
                    the runtime re-homes its requests and the elastic
                    controller may backfill the lost role.
``StragglerModel``  per-(request, side) read-leg slowdown: with
                    probability ``prob`` a leg's service time is
                    multiplied by ``severity`` — the tail the hedged
                    split-read path exists to cut.
=================  =====================================================
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from functools import lru_cache
from typing import List, Optional, Sequence, Tuple

__all__ = ["SlowdownWindow", "EngineDeath", "StragglerModel",
           "FaultSchedule"]

_RESOURCES = ("snic", "net")


@lru_cache(maxsize=65536)
def _straggle_draw(seed: int, rid: int, side: str) -> float:
    """The uniform draw behind :meth:`StragglerModel.factor`, memoized.

    The simulator asks for the same ``(rid, side)`` factor several times
    per request (leg issue, hedging probes, recovery re-issues); the md5
    is pure in ``(seed, rid, side)`` so the hash only ever needs to run
    once per key.

    md5, not crc32: crc is linear, so draws for keys differing only in
    the side suffix would be XOR-correlated — both sides of one request
    would (not) straggle together.
    """
    d = hashlib.md5(f"{seed}:{rid}:{side}".encode()).digest()
    return int.from_bytes(d[:8], "big") / float(1 << 64)


@dataclass(frozen=True)
class SlowdownWindow:
    """Service-time multiplier ``factor`` on one resource over
    ``[t0, t1)``.  ``node=None`` hits every node (a fabric-wide flap);
    an integer restricts the window to that node's SNIC."""
    resource: str                  # "snic" | "net"
    t0: float
    t1: float
    factor: float                  # >= 1: service-time multiplier
    node: Optional[int] = None

    def __post_init__(self):
        if self.resource not in _RESOURCES:
            raise ValueError(f"resource {self.resource!r} "
                             f"(valid: {_RESOURCES})")
        if not self.t1 > self.t0:
            raise ValueError(f"empty window [{self.t0}, {self.t1})")
        if self.factor < 1.0:
            raise ValueError(f"factor {self.factor} < 1 (slowdowns only; "
                             f"speedups would break conservation checks)")

    def active(self, t: float) -> bool:
        return self.t0 <= t < self.t1


@dataclass(frozen=True)
class EngineDeath:
    """Permanent fail-stop of engine ``engine`` (an ``(node, idx)`` id)
    at time ``t``.  Fail-stop, not fail-slow: in-flight work on the
    engine is lost and must be re-homed by the runtime."""
    t: float
    engine: Tuple[int, int]


@dataclass(frozen=True)
class StragglerModel:
    """Hash-seeded per-read-leg straggle draw.

    ``factor(rid, side)`` is a pure function of ``(seed, rid, side)`` —
    no RNG state — so the simulator's issue order can never change
    which legs straggle, and a straggler observed in a chaos failure
    reproduces exactly from the schedule's seed.
    """
    prob: float                    # P[leg straggles] in [0, 1]
    severity: float                # service-time multiplier when it does
    seed: int = 0

    def __post_init__(self):
        if not 0.0 <= self.prob <= 1.0:
            raise ValueError(f"prob {self.prob} outside [0, 1]")
        if self.severity < 1.0:
            raise ValueError(f"severity {self.severity} < 1")

    def factor(self, rid: int, side: str) -> float:
        if self.prob <= 0.0:
            return 1.0
        return (self.severity
                if _straggle_draw(self.seed, rid, side) < self.prob
                else 1.0)


@dataclass
class FaultSchedule:
    """The full fault timeline for one run.  Queried, never mutated."""
    windows: List[SlowdownWindow] = field(default_factory=list)
    deaths: List[EngineDeath] = field(default_factory=list)
    straggler: Optional[StragglerModel] = None

    def __post_init__(self):
        # deterministic processing order regardless of construction order
        self.windows = sorted(self.windows,
                              key=lambda w: (w.t0, w.t1, w.resource,
                                             -1 if w.node is None else w.node))
        self.deaths = sorted(self.deaths, key=lambda d: (d.t, d.engine))

    # -- queries -----------------------------------------------------------
    @property
    def empty(self) -> bool:
        """True iff every hook is guaranteed a no-op (used by both
        runtimes to skip fault plumbing entirely on the happy path)."""
        return (not self.windows and not self.deaths and
                (self.straggler is None or self.straggler.prob <= 0.0))

    def snic_factor(self, node: int, t: float) -> float:
        """Composed service-time multiplier on node ``node``'s storage
        NIC at time ``t`` (overlapping windows multiply)."""
        f = 1.0
        for w in self.windows:
            if (w.resource == "snic" and w.active(t)
                    and (w.node is None or w.node == node)):
                f *= w.factor
        return f

    def net_factor(self, t: float) -> float:
        """Composed multiplier on the compute-network link at ``t``."""
        f = 1.0
        for w in self.windows:
            if w.resource == "net" and w.active(t):
                f *= w.factor
        return f

    def leg_factor(self, rid: int, side: str) -> float:
        """Straggle multiplier for request ``rid``'s ``side`` read leg."""
        if self.straggler is None:
            return 1.0
        return self.straggler.factor(rid, side)

    def boundaries(self, resource: str) -> List[float]:
        """Sorted unique window edges for ``resource`` — the instants a
        runtime must re-evaluate rates at (the sim re-shares the shared
        link at each ``net`` boundary)."""
        return self.boundaries_array(resource).tolist()

    def boundaries_array(self, resource: str) -> "np.ndarray":
        """:meth:`boundaries` as a float64 ndarray (sorted, deduplicated).

        Both runtimes consume this form: the event loop schedules one
        re-share per edge, and the vectorized macro-stepper feeds it
        straight into its next-boundary argmin without a list->array
        conversion per step.  Kept as the single source of truth so the
        two engines can never disagree on where a window edge falls.
        """
        import numpy as np
        ts = [t for w in self.windows if w.resource == resource
              for t in (w.t0, w.t1)]
        return np.unique(np.asarray(ts, dtype=np.float64))

    # -- construction ------------------------------------------------------
    @classmethod
    def generate(cls, seed: int, duration_s: float, nodes: Sequence[int],
                 engines: Sequence[Tuple[int, int]] = (),
                 snic_fault_rate: float = 0.0,
                 snic_factor: float = 4.0,
                 snic_window_s: float = 10.0,
                 link_flap_rate: float = 0.0,
                 link_factor: float = 3.0,
                 link_window_s: float = 2.0,
                 straggler_prob: float = 0.0,
                 straggler_severity: float = 6.0,
                 n_deaths: int = 0,
                 death_frac: float = 0.5) -> "FaultSchedule":
        """Seeded random schedule: Poisson-ish window starts (expected
        ``rate * duration`` windows per process, uniform starts), plus
        ``n_deaths`` engine deaths clustered at ``death_frac`` of the
        run.  Same ``(seed, params)`` -> same schedule, always."""
        import numpy as np
        rng = np.random.default_rng(seed)
        windows: List[SlowdownWindow] = []
        n_snic = int(round(snic_fault_rate * duration_s))
        for _ in range(n_snic):
            t0 = float(rng.uniform(0.0, max(duration_s - snic_window_s,
                                            1e-9)))
            node = int(rng.choice(list(nodes))) if len(nodes) else None
            windows.append(SlowdownWindow("snic", t0, t0 + snic_window_s,
                                          snic_factor, node=node))
        n_flap = int(round(link_flap_rate * duration_s))
        for _ in range(n_flap):
            t0 = float(rng.uniform(0.0, max(duration_s - link_window_s,
                                            1e-9)))
            windows.append(SlowdownWindow("net", t0, t0 + link_window_s,
                                          link_factor))
        deaths: List[EngineDeath] = []
        if n_deaths and len(engines):
            idxs = rng.choice(len(engines), size=min(n_deaths,
                                                     len(engines)),
                              replace=False)
            for i in sorted(int(j) for j in idxs):
                t = float(duration_s * death_frac *
                          (1.0 + 0.1 * rng.uniform(-1.0, 1.0)))
                deaths.append(EngineDeath(t, tuple(engines[i])))
        strag = (StragglerModel(straggler_prob, straggler_severity,
                                seed=seed)
                 if straggler_prob > 0.0 else None)
        return cls(windows=windows, deaths=deaths, straggler=strag)
