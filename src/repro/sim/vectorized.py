"""Fleet-scale vectorized event engine (struct-of-arrays drain pool).

``Sim`` (sim/simulator.py) is a per-object discrete-event simulator: every
transfer leg is a Python ``Flow``, and every processor-sharing reshare
resettles each affected flow — and pushes one completion-check event per
flow — in a Python loop.  At fleet scale that loop is the simulator's own
bottleneck: a shared link carrying N flows costs O(N) Python per join or
leave plus O(N) heap events, so a 100-engine run spends nearly all of its
wall clock resettling flows one object at a time.

:class:`VectorSim` keeps the event loop and every request-lifecycle
handler of ``Sim`` (it *is* a ``Sim``; scheduling decisions, loading
plans, NIC FIFOs, tiers, step timing, metrics all run the exact shared
code), and replaces only the processor-sharing drain plane with a
struct-of-arrays pool (:class:`FlowPool`):

* per-flow state (``nbytes_left``, ``rate``, ``t_last``, absolute drain
  ``eta``) lives in parallel numpy arrays, not object attributes;
* a reshare settles all affected flows, recomputes every rate and every
  completion time with a handful of array ops (per-resource fair shares
  are gathered from incrementally-maintained ``cap``/``n_flows`` arrays;
  the VL-arbitered :class:`~repro.network.SharedLink` contributes one
  per-class rate vector via the same
  :func:`~repro.core.traffic.allocate_bandwidth` call ``Sim`` uses);
* instead of one check event per flow per reshare, the pool schedules a
  *single* "next-boundary" event at the vectorized argmin of the drain
  completions — the macro-step.  Arrivals, fault-window edges
  (``FaultSchedule.boundaries_array``) and NIC completions remain
  ordinary loop events, so the next event time is exactly the min over
  those and the pool boundary, and event *order* matches ``Sim`` by
  construction.

Semantics contract (property-tested in tests/test_vectorized.py): on any
supported config, ``VectorSim.results()`` equals ``Sim.results()`` —
exactly for counters/bytes/tokens, and to float tolerance for
time-valued keys (see docs/testing.md; settles use the same IEEE
arithmetic at the same instants, so observed runs are bit-identical).

Not supported (raise :class:`VectorSimUnsupported`): engine deaths,
hedged reads, elastic reconfiguration — the paths that cancel or shrink
in-flight work mid-drain.  Everything else — split reads, DRAM tiers,
FIFO/VL arbitration, background load, slowdown windows, stragglers,
prefetch, online arrivals — runs vectorized.
"""
from __future__ import annotations

import heapq
import math
import os
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core.traffic import TrafficClass, allocate_bandwidth
from repro.network.link import SharedLink
from repro.sim.simulator import INF, Sim, SimConfig

__all__ = ["VectorSim", "VectorSimUnsupported", "FlowPool"]

def _noop():
    return None


_TCLASSES = tuple(TrafficClass)
_TCODE = {c: i for i, c in enumerate(_TCLASSES)}
_COLL_CODE = _TCODE[TrafficClass.MODEL_COLLECTIVE]
_MAX_RES = 4                      # widest loading-plan leg (de_h2d: 3)


class VectorSimUnsupported(ValueError):
    """Config uses a feature the vectorized engine does not model."""


class _PoolFlow:
    """Handle for one slot of the struct-of-arrays pool.

    Resources keep these in their ``flows`` sets (SharedLink reads
    ``tclass`` / ``nbytes_left`` / ``nbytes_total`` / ``t_enter`` for
    arbitration, congestion and delay accounting), but all mutable drain
    state lives in the pool arrays — the handle is an index."""

    __slots__ = ("pool", "slot", "fid", "tclass", "nbytes_total",
                 "t_enter", "resources", "on_done", "done")

    @property
    def nbytes_left(self) -> float:
        # read live (and deliberately stale-between-settles, exactly like
        # Flow.nbytes_left) by SharedLink.congestion()
        return self.pool.nl[self.slot]

    @property
    def rate(self) -> float:
        return self.pool.rate[self.slot]

    def _finish(self):
        if self.done:
            return
        self.done = True
        pool = self.pool
        now = pool.sim.loop.now
        for r in self.resources:
            r.flows.discard(self)
            pool._leave(self, r)
            note = getattr(r, "note_done", None)
            if note is not None:
                note(self, now)
        pool._release(self)
        if self.resources:
            pool.sim._reshare(self.resources)
        self.on_done()

    def cancel(self):
        raise VectorSimUnsupported(
            "flow cancellation (engine-death recovery) is not modelled "
            "by the vectorized engine")


class _PoolLink(SharedLink):
    """SharedLink whose O(flows) congestion walk reads the pool arrays.

    ``congestion()`` sums every on-link flow's ``nbytes_left``; under a
    deep fleet backlog that walk is O(k) Python per scheduling decision
    — quadratic over a run.  The pool holds the identical settled
    values in one column, so the ratio reduces to two masked numpy
    sums.  Summation order differs from the set walk (slot order,
    pairwise), which the engine-equivalence suite pins as harmless: the
    signal's consumers (water-fill, pacing) are threshold comparisons
    fed from both engines' runs bit-identically in practice."""

    __slots__ = ("pool",)

    def congestion(self) -> float:
        if not math.isfinite(self.cap) or not self.flows:
            return 0.0
        return self.pool.link_congestion()


class FlowPool:
    """Struct-of-arrays drain state for every in-flight PS transfer."""

    def __init__(self, sim: "VectorSim", resources, link):
        self.sim = sim
        # --- fixed resource census (rid 0 is the padding pseudo-resource:
        # infinite cap, zero flows, so gathers through it yield +inf and
        # never win a min) --------------------------------------------------
        self._rid = {id(r): i + 1 for i, r in enumerate(resources)}
        self.res_cap = np.asarray([INF] + [r.cap for r in resources],
                                  dtype=np.float64)
        self.res_n = np.zeros(len(resources) + 1, dtype=np.int64)
        self.link = link
        self.link_rid = self._rid[id(link)]
        # scratch lookup table for vectorized affected-set discovery:
        # touched[rid] flips True for the reshare's resources, and one
        # gather through the res matrix replaces the Python set union
        self._touched = np.zeros(len(resources) + 2, dtype=bool)
        # per-class member count on the shared link, maintained on
        # enter/leave — the same census SharedLink._class_counts rebuilds
        # from its flow set
        self.link_counts = np.zeros(len(_TCLASSES), dtype=np.int64)
        self._link_vl = link.arbiter == "vl" and math.isfinite(link.cap)
        # --- per-flow arrays -------------------------------------------------
        n = 256
        self.nl = np.zeros(n)                 # bytes left (settled)
        self.rate = np.zeros(n)               # current PS share [B/s]
        self.t_last = np.zeros(n)             # last settle instant
        self.eta = np.full(n, INF)            # absolute drain completion
        # the heap sequence number this flow's completion check would
        # have consumed in the per-object loop — the same-timestamp
        # tie-break (see EventLoop.reserve)
        self.eseq = np.full(n, 2 ** 62, dtype=np.int64)
        self.fid = np.full(n, -1, dtype=np.int64)
        self.tcode = np.zeros(n, dtype=np.int16)
        # resource ids as _MAX_RES separate contiguous columns: short
        # inner-axis reductions on an (n, 4) matrix are numpy's worst
        # case, while chained 1-D gathers/minimums vectorize cleanly
        self.res = [np.zeros(n, dtype=np.int32) for _ in range(_MAX_RES)]
        self.on_link = np.zeros(n, dtype=bool)
        # resource-SET signature: flows sharing a set share a PS min —
        # the per-flow min-gather collapses to a per-signature min (a
        # few hundred rows at fleet scale) plus one gather through sig
        self.sig = np.zeros(n, dtype=np.int32)
        self._sig_of: Dict[Tuple[int, ...], int] = {}
        self._sig_res = np.zeros((1, _MAX_RES), dtype=np.int32)  # row 0: empty
        self.flows: List[Optional[_PoolFlow]] = [None] * n
        # bump allocation + periodic compaction: slots are handed out in
        # spawn order, and spawn order IS fid order, so the live region
        # [0, _next_slot) is always fid-sorted — reshares need no
        # argsort, and every scan stops at _next_slot
        self._next_slot = 0
        # widest leg seen so far: mask/min scans read only the first
        # _res_width res columns (legs rarely span all _MAX_RES)
        self._res_width = 1
        self._pending: Set[Tuple[float, int]] = set()  # armed boundary keys
        self._lcr_cache = None                # per-class link rates
        self._lcr_dirty = True                # census changed since cached
        self._lcr_cap = link.cap              # cap the cache was built at
        # Sim leaves every superseded per-flow check in the heap; those
        # stale pops are no-ops but still advance loop.now, so the final
        # clock (results' sim_time, hence throughput) is max over every
        # check ever scheduled.  Track that max and keep one no-op event
        # at it so the pooled engine's clock drains to the same instant.
        self._watermark = -INF
        self.n_reshares = 0
        self.n_live = 0
        self.peak_flows = 0

    # -- slot management ---------------------------------------------------
    def _compact(self):
        """Out of bump space: squeeze released slots out of the live
        region (preserving order, so it stays fid-sorted) and double the
        arrays when more than half the slots are genuinely live.
        Handles are re-pointed; a stale slot cached by a pending
        boundary event fails its (eta, eseq) validation and re-arms."""
        ns = self._next_slot
        live = np.nonzero(self.fid[:ns] >= 0)[0]
        n_live = len(live)
        cap = len(self.flows)
        new_cap = cap * 2 if n_live > cap // 2 else cap
        arrays = {"nl": np.zeros(new_cap), "rate": np.zeros(new_cap),
                  "t_last": np.zeros(new_cap),
                  "eta": np.full(new_cap, INF),
                  "eseq": np.full(new_cap, 2 ** 62, dtype=np.int64),
                  "fid": np.full(new_cap, -1, dtype=np.int64),
                  "tcode": np.zeros(new_cap, dtype=np.int16),
                  "on_link": np.zeros(new_cap, dtype=bool),
                  "sig": np.zeros(new_cap, dtype=np.int32)}
        for name, arr in arrays.items():
            arr[:n_live] = getattr(self, name)[live]
            setattr(self, name, arr)
        for j in range(_MAX_RES):
            col = np.zeros(new_cap, dtype=np.int32)
            col[:n_live] = self.res[j][live]
            self.res[j] = col
        flows: List[Optional[_PoolFlow]] = [None] * new_cap
        old = self.flows
        for i, s in enumerate(live.tolist()):
            f = old[s]
            f.slot = i
            flows[i] = f
        self.flows = flows
        self._next_slot = n_live

    def spawn(self, nbytes, resources, on_done, tclass) -> _PoolFlow:
        sim = self.sim
        f = _PoolFlow()
        f.pool = self
        f.fid = next(sim._flow_seq)
        f.resources = [r for r in resources if r is not None]
        f.tclass = tclass
        f.nbytes_total = float(max(nbytes, 1.0))
        f.t_enter = sim.loop.now
        f.on_done = on_done
        f.done = False
        ns = self._next_slot
        if ns == len(self.flows) or (ns > 2048 and self.n_live * 2 < ns):
            # out of bump space, or mostly dead: every reshare/arm scan
            # runs over [0, ns), so squeezing released slots out early
            # keeps the array kernels sized to the live population
            self._compact()
        s = f.slot = self._next_slot
        self._next_slot = s + 1
        self.nl[s] = f.nbytes_total
        self.rate[s] = 0.0
        self.t_last[s] = sim.loop.now
        self.eta[s] = INF
        self.eseq[s] = 2 ** 62
        self.fid[s] = f.fid
        self.tcode[s] = _TCODE[tclass]
        self.flows[s] = f
        res = self.res
        for j in range(_MAX_RES):
            res[j][s] = 0
        if not f.resources:
            sim.loop.after(0.0, f._finish)
            return f
        if len(f.resources) > _MAX_RES:
            raise VectorSimUnsupported(
                f"leg spans {len(f.resources)} resources (> {_MAX_RES})")
        if len(f.resources) > self._res_width:
            self._res_width = len(f.resources)
        onl = False
        key = []
        for j, r in enumerate(f.resources):
            rid = self._rid.get(id(r))
            if rid is None:
                raise VectorSimUnsupported(
                    f"flow on unregistered resource {r!r}")
            res[j][s] = rid
            key.append(rid)
            note = getattr(r, "note_enter", None)
            if note is not None:
                note(f)
            r.flows.add(f)
            self.res_n[rid] += 1
            if rid == self.link_rid:
                onl = True
                self.link_counts[self.tcode[s]] += 1
                self._lcr_dirty = True
        self.on_link[s] = onl
        key = tuple(key)
        sig = self._sig_of.get(key)
        if sig is None:
            sig = self._sig_of[key] = len(self._sig_res)
            row = np.zeros((1, _MAX_RES), dtype=np.int32)
            row[0, :len(key)] = key
            self._sig_res = np.concatenate([self._sig_res, row])
        self.sig[s] = sig
        self.n_live += 1
        if self.n_live > self.peak_flows:
            self.peak_flows = self.n_live
        sim._reshare(f.resources)
        return f

    def _leave(self, f: _PoolFlow, r) -> None:
        rid = self._rid[id(r)]
        self.res_n[rid] -= 1
        if rid == self.link_rid:
            self.link_counts[self.tcode[f.slot]] -= 1
            self._lcr_dirty = True

    def _release(self, f: _PoolFlow) -> None:
        s = f.slot
        self.eta[s] = INF
        self.eseq[s] = 2 ** 62
        self.fid[s] = -1
        self.on_link[s] = False
        self.sig[s] = 0
        for j in range(_MAX_RES):
            self.res[j][s] = 0
        self.flows[s] = None
        if f.resources:
            self.n_live -= 1

    def link_congestion(self) -> float:
        """Vectorized :meth:`SharedLink.congestion`: the collective
        share of in-flight bytes on the link, from the pool's settled
        ``nl`` column — the same deliberately-stale-between-settles
        values the per-object walk reads off each flow."""
        ns = self._next_slot
        onl = self.on_link[:ns]
        nl = np.maximum(self.nl[:ns], 0.0)
        tot = float(np.sum(nl, where=onl, initial=0.0))
        if tot <= 0.0:
            return 0.0
        coll = float(np.sum(nl, initial=0.0,
                            where=onl & (self.tcode[:ns] == _COLL_CODE)))
        return coll / tot

    # -- vectorized drain algebra -----------------------------------------
    def link_class_rates(self) -> np.ndarray:
        """Per-class flow rate on the VL-arbitered link — the same
        ``allocate_bandwidth`` arithmetic SharedLink.rate_of performs,
        evaluated once per census change instead of once per flow (the
        allocation is pure in ``(counts, cap)``, so enter/leave mark it
        dirty, a cap change — a fault-window flap — is caught by the
        cap compare, and everything else reuses the cached rates)."""
        if not self._lcr_dirty and self.link.cap == self._lcr_cap:
            return self._lcr_cache
        counts = self.link_counts
        active = {_TCLASSES[i]: int(c)
                  for i, c in enumerate(counts) if c}
        alloc = allocate_bandwidth(active, self.link.cap, self.link.arb)
        out = np.full(len(_TCLASSES), INF)
        for i, c in enumerate(_TCLASSES):
            n = int(counts[i])
            if n:
                out[i] = alloc.get(c, 0.0) / n
        self._lcr_cache = out
        self._lcr_dirty = False
        self._lcr_cap = self.link.cap
        return out

    def reshare(self, rids: List[int]) -> None:
        """Settle, re-rate and re-arm every flow on the resources in
        ``rids`` — the vectorized counterpart of Sim._reshare's per-flow
        loop.  Affected-set discovery is a table lookup through the res
        matrix, not a Python set union."""
        sim = self.sim
        loop = sim.loop
        now = loop.now
        self.n_reshares += 1
        ns = self._next_slot
        touched = self._touched
        touched[rids] = True
        # a signature is affected iff any of its resources is; the
        # per-flow membership test is one gather through sig (the
        # signature table is a few hundred rows, the pool thousands)
        sr = self._sig_res
        tsig = touched[sr[:, 0]]
        for j in range(1, self._res_width):
            tsig |= touched[sr[:, j]]
        mask = tsig[self.sig[:ns]]
        touched[rids] = False
        # released slots have zeroed res rows, so the mask is live-only;
        # the live region is fid-sorted by construction (bump allocation
        # in spawn = fid order), which is exactly the order Sim._reshare
        # sweeps — no argsort needed for the seq-number consumption
        idx = np.nonzero(mask)[0]
        k = len(idx)
        if k == 0:
            # a finish may have consumed the armed boundary even when it
            # leaves its resources empty — keep the pool armed
            self.arm()
            return
        if k <= 8:
            # numpy dispatch overhead (~30 kernel launches) dwarfs the
            # math below ~10 flows; run the same arithmetic scalar.
            # Python floats are IEEE doubles, so every branch produces
            # bit-identical values to the array path.
            self._reshare_scalar(idx, now)
            return
        with np.errstate(invalid="ignore", divide="ignore"):
            # settle at `now` with the *old* rates (inf-rate flows are
            # served instantaneously; inf * 0 would be nan)
            r_old = self.rate[idx]
            dt = now - self.t_last[idx]
            nlv = np.where(np.isinf(r_old), 0.0,
                           self.nl[idx] - r_old * dt)
            self.nl[idx] = nlv
            self.t_last[idx] = now
            # new rates: each resource's fair share is computed once on
            # the small per-resource arrays (cap / n_flows — identical
            # to PSResource.rate_of and Sim._reshare's share cache),
            # then one gather through the padded rid matrix gives every
            # flow's min; the VL link's class-aware share overrides its
            # generic column
            self.res_cap[self.link_rid] = self.link.cap   # track flaps
            shares = self.res_cap / np.maximum(self.res_n, 1)
            if self._link_vl:
                shares[self.link_rid] = INF
            # min fair share per *signature* (rid 0 pads gather INF —
            # res_cap[0] is the INF sentinel — so short legs are
            # unaffected), then one gather fans it out per flow
            smin = shares[sr[:, 0]]
            for j in range(1, self._res_width):
                np.minimum(smin, shares[sr[:, j]], out=smin)
            rmin = smin[self.sig[idx]]
            if self._link_vl:
                onl = self.on_link[idx]
                if onl.any():
                    lr = self.link_class_rates()[self.tcode[idx]]
                    rmin = np.where(onl, np.minimum(rmin, lr), rmin)
            self.rate[idx] = rmin
            # sub-byte residual or unbounded rate finishes now; the rest
            # get an absolute drain eta.  Sim pushes one heap event per
            # flow here (a zero-delay finish or a completion check); we
            # push only the finishes, but *reserve* every seq the checks
            # would have consumed and stamp each live flow with its
            # would-be seq — the armed boundary event then reuses the
            # winner's seq, so every same-timestamp ordering matches the
            # per-object loop.
            fin = (nlv <= 1.0) | np.isinf(rmin)
            live = ~fin & (rmin > 0)
            cs = np.cumsum(fin | live)         # the seqs Sim would burn
            seqs = cs + (loop.reserve(int(cs[-1])) - 1)
            self.eseq[idx[live]] = seqs[live]
            settled = sim._settle_kernel
            if settled is not None:            # optional jax/jit drain
                eta = np.where(live, np.asarray(settled(nlv, rmin, now)),
                               INF)
            else:
                eta = np.where(live, now + nlv / rmin, INF)
            self.eta[idx] = eta
        if live.any():
            self._bump_watermark(float(np.max(eta, initial=-INF,
                                              where=live)))
        if fin.any():
            heap = loop._heap
            flows = self.flows
            for j in np.nonzero(fin)[0]:
                heapq.heappush(heap, (now, int(seqs[j]),
                                      flows[int(idx[j])]._finish))
        self.arm()

    def _reshare_scalar(self, idx, now: float) -> None:
        """Small-affected-set reshare: identical arithmetic to the array
        path (and to Sim._reshare), executed with scalar ops.  Slot
        order is fid order, so seq consumption and finish scheduling
        interleave exactly as the sorted per-object sweep does."""
        loop = self.sim.loop
        nl = self.nl
        rate = self.rate
        t_last = self.t_last
        eta = self.eta
        eseq = self.eseq
        res = self.res
        res_cap = self.res_cap
        res_n = self.res_n
        link_rid = self.link_rid
        res_cap[link_rid] = self.link.cap     # track flaps
        link_vl = self._link_vl
        lr = None
        heap = loop._heap
        wm = -INF
        for s in idx.tolist():
            r_old = rate[s]
            if math.isinf(r_old):
                nlv = 0.0
            else:
                nlv = nl[s] - r_old * (now - t_last[s])
            nl[s] = nlv
            t_last[s] = now
            rmin = INF
            for col in res:
                rid = int(col[s])
                if rid and not (link_vl and rid == link_rid):
                    share = res_cap[rid] / max(res_n[rid], 1)
                    if share < rmin:
                        rmin = share
            if link_vl and self.on_link[s]:
                if lr is None:
                    lr = self.link_class_rates()
                cr = lr[self.tcode[s]]
                if cr < rmin:
                    rmin = cr
            rate[s] = rmin
            if nlv <= 1.0 or math.isinf(rmin):
                heapq.heappush(heap, (now, loop._take(),
                                      self.flows[s]._finish))
                eta[s] = INF
            elif rmin > 0:
                e = now + nlv / rmin
                eseq[s] = loop._take()
                eta[s] = e
                if e > wm:
                    wm = e
            else:
                eta[s] = INF
        if wm > -INF:
            self._bump_watermark(wm)
        self.arm()

    def _bump_watermark(self, t: float) -> None:
        if t > self._watermark and math.isfinite(t):
            self._watermark = t
            # seq 2**62 keeps the tuple unique (watermark times strictly
            # increase) and sorts after any real event at the same t
            heapq.heappush(self.sim.loop._heap, (t, 2 ** 62, _noop))

    def arm(self) -> None:
        """Arm the next-boundary event: the lexicographic ``(eta, eseq)``
        argmin over every in-flight drain — exactly the next pooled
        completion the per-object heap would pop."""
        ns = self._next_slot
        if ns == 0:
            return
        eta = self.eta[:ns]
        w = int(eta.argmin())
        m = eta[w]
        if not math.isfinite(m):
            return
        cand = np.nonzero(eta == m)[0]
        if len(cand) > 1:      # eta tie: earliest would-be check seq wins
            w = int(cand[np.argmin(self.eseq[cand])])
        key = (float(m), int(self.eseq[w]))
        if key in self._pending:
            return
        self._pending.add(key)
        heapq.heappush(self.sim.loop._heap,
                       (key[0], key[1], lambda: self._boundary(key, w)))

    def _boundary(self, key: Tuple[float, int], s: int) -> None:
        """The macro-step boundary.  Runs Sim._flow_check's arithmetic on
        the armed flow; a finish triggers a reshare, which re-arms the
        next boundary.  A slot whose ``(eta, eseq)`` no longer matches
        the armed key is a stale arming (the winner was resheared at
        this instant by an earlier event) — it degenerates to a re-arm,
        like a version-stale check."""
        self._pending.discard(key)
        t, seq = key
        if self.eseq[s] != seq or self.eta[s] != t:
            self.arm()
            return
        f = self.flows[s]
        loop = self.sim.loop
        now = loop.now
        rate = self.rate[s]
        if math.isinf(rate):
            f._finish()
            return
        nl = self.nl[s] - rate * (now - self.t_last[s])
        self.nl[s] = nl
        self.t_last[s] = now
        if nl <= 1.0:
            f._finish()
        else:
            # float drift: reschedule the residual instead of dropping
            # it, consuming one check seq as _flow_check would
            self.eseq[s] = loop.reserve(1)
            self.eta[s] = now + nl / max(rate, 1.0)
            self._bump_watermark(float(self.eta[s]))
            self.arm()


def _jax_settle_kernel():
    """Optional jax/jit drain kernel for the eta computation.

    Off by default (``REPRO_VECTORSIM_JAX=1`` opts in): jax computes in
    float32 unless x64 is enabled, which would demote the engine's
    bit-exact settles to tolerance-level agreement.  With
    ``jax.config.update("jax_enable_x64", True)`` the kernel is
    arithmetically identical to the numpy path."""
    if os.environ.get("REPRO_VECTORSIM_JAX") != "1":
        return None
    try:
        import jax
        import jax.numpy as jnp
    except ImportError:
        return None

    @jax.jit
    def eta(nl, rate, now):
        return now + nl / rate

    return eta


class VectorSim(Sim):
    """Drop-in ``Sim`` with the struct-of-arrays drain pool.

    Construction, ``run()`` and ``results()`` are the base class's; the
    only overridden machinery is flow creation (``_flow``) and the PS
    reshare (``_reshare``).  See the module docstring for the contract
    and :func:`check_supported` for the gated features."""

    def __init__(self, cfg: SimConfig, trajectories, tracer=None):
        check_supported(cfg)
        super().__init__(cfg, trajectories, tracer=tracer)
        # swap the shared link for the pool-backed one BEFORE any flow
        # exists: every Sim reference is a late-bound `self.net` lookup,
        # so the plain SharedLink built by Sim.__init__ is simply
        # dropped here
        link = _PoolLink(self.net.name, self.net.cap,
                         arbiter=self.net.arbiter, arb=self.net.arb)
        self.net = link
        resources = (list(self.dram.values()) +
                     list(self.cnic_rd.values()) +
                     list(self.cnic_wr.values()) + [self.net])
        self.pool = FlowPool(self, resources, self.net)
        link.pool = self.pool
        self._settle_kernel = _jax_settle_kernel()

    # -- drain plane overrides --------------------------------------------
    def _flow(self, nbytes, resources, on_done,
              tclass: TrafficClass = TrafficClass.KV_TRANSFER):
        return self.pool.spawn(nbytes, resources, on_done, tclass)

    def _reshare(self, resources):
        pool = self.pool
        rid = pool._rid
        pool.reshare([rid[id(r)] for r in resources])

    # -- struct-of-arrays request table -----------------------------------
    def request_table(self) -> Dict[str, np.ndarray]:
        """Every round's lifecycle as parallel arrays (rid-aligned):
        arrival/stamp columns, token counts and the per-side read
        partition — the fleet benchmark computes its SLO/throughput
        curves from these instead of iterating round objects."""
        rounds = self.rounds
        n = len(rounds)

        def col(fn, dtype=np.float64):
            return np.fromiter((fn(r) for r in rounds), dtype=dtype,
                               count=n)

        return {
            "rid": col(lambda r: r.req.rid, np.int64),
            "arrival": col(lambda r: r.req.arrival),
            "submit_t": col(lambda r: r.submit_t),
            "read_done_t": col(lambda r: r.read_done_t),
            "prefill_done_t": col(lambda r: r.prefill_done_t),
            "first_decode_t": col(lambda r: r.first_decode_t),
            "second_token_t": col(lambda r: r.second_token_t),
            "done_t": col(lambda r: r.done_t),
            "cached_tokens": col(lambda r: r.req.cached_tokens, np.int64),
            "new_tokens": col(lambda r: r.req.new_tokens, np.int64),
            "gen_tokens": col(lambda r: r.gen_total, np.int64),
            "dram_tokens": col(lambda r: r.req.dram_tokens, np.int64),
            "read_pe_tokens": col(
                lambda r: r.req.read_tokens_by_side()["pe"]
                if r.req.read_path else 0, np.int64),
            "read_de_tokens": col(
                lambda r: r.req.read_tokens_by_side()["de"]
                if r.req.read_path else 0, np.int64),
        }


def check_supported(cfg: SimConfig) -> None:
    """Raise :class:`VectorSimUnsupported` for configs whose semantics
    the pool cannot reproduce (paths that cancel or re-partition
    in-flight drains)."""
    bad = []
    if cfg.elastic:
        bad.append("elastic role reconfiguration")
    if cfg.hedge_reads:
        bad.append("hedged split reads")
    if cfg.faults is not None and not cfg.faults.empty and cfg.faults.deaths:
        bad.append("engine deaths")
    if bad:
        raise VectorSimUnsupported(
            f"VectorSim does not support: {', '.join(bad)} — "
            f"use sim.simulator.Sim for these configs")
