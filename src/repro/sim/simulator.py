"""Discrete-event cluster simulator for DualPath.

Validates the paper's system-level claims (Fig. 7–15, Table 3) on a
CPU-only container: network bandwidth effects cannot be *measured* here,
so they are *modelled* — with the same scheduler code
(repro.core.scheduler), the same loading plans (repro.core.loading) and
the closed-form §4.2 analysis as cross-checks.

Model:
* per-node storage NIC  — FIFO server (a disk read queue; its backlog in
  tokens is the scheduler's ``read_q`` signal),
* per-engine CNIC PCIe read/write sides, per-node DRAM, PE–DE network —
  processor-sharing resources (fair share among active legs; the VL
  arbiter guarantees model collectives are unaffected, so they are not
  simulated as contenders — see core/traffic.py),
* engines — grouped (EP/DP unit); groups step in lockstep.  PE groups
  pack forward batches under the compute quota (core/intra.py); DE
  groups run continuous-batching decode in token blocks.

Request lifecycle (round of a trajectory):
  submit → (PE,DE) assignment + read-path choice → storage read (FIFO on
  the chosen side; with ``split_reads`` the hit is partitioned and BOTH
  sides' NICs serve the request concurrently) → PE prefill (chunks;
  layerwise streaming legs overlap as PS flows) → PD transfer complete →
  DE H2D → decode blocks → done → next round of the trajectory.

All legs come from core/loading.plan_for, and every executed leg is
charged to ``RoundSim.charged`` per symbolic resource — the sim's byte
accounting therefore matches the plans (and, via tests/test_loading.py,
the §4.2 closed form) to the byte.
"""
from __future__ import annotations

import heapq
import itertools
import math
from collections import defaultdict, deque
from dataclasses import dataclass
from dataclasses import field as dc_field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.admission import AdmissionGate
from repro.core.autoscale import (DE_TO_PE, DrainTracker, LoadSignals,
                                  PDController, pick_victim)
from repro.core.config import (FLAT_FIELDS, ElasticConfig, NetworkConfig,
                               ResilienceConfig, SloConfig, TierConfig,
                               resolve_groups)
from repro.core.intra import (AttnTimeModel, PrefillWork, QuotaPacker,
                              class_insert_index)
from repro.core.loading import Leg, PLANS, plan_for
from repro.core.scheduler import Request, RoundRobinScheduler, Scheduler
from repro.core.traffic import TrafficClass
from repro.kvcache.tiers import DramTier, ThinkTimePrefetcher
from repro.network import CollectiveVolumeModel, SharedLink
from repro.obs.schema import conforming
from repro.sim.faults import FaultSchedule
from repro.sim.spec import ModelSimSpec, NodeSpec
from repro.sim.traces import Trajectory

INF = float("inf")


# ---------------------------------------------------------------------------
# Event engine
# ---------------------------------------------------------------------------


class EventLoop:
    def __init__(self):
        self.now = 0.0
        self._heap: List[Tuple[float, int, Callable]] = []
        self._next_seq = 0
        self.n_events = 0   # processed events — the events/sec numerator

    def _take(self) -> int:
        s = self._next_seq
        self._next_seq = s + 1
        return s

    def reserve(self, n: int) -> int:
        """Consume ``n`` sequence numbers without pushing events.

        Same-timestamp events pop in seq order, so seq consumption IS
        the tie-break.  The vectorized engine (sim/vectorized.py)
        reserves one seq per pooled drain completion — the seqs the
        per-flow check events would have consumed — and pushes its
        single boundary event under the winner's seq, which keeps every
        same-instant ordering bit-identical to the per-object loop."""
        s = self._next_seq
        self._next_seq = s + n
        return s

    def at(self, t: float, fn: Callable):
        heapq.heappush(self._heap, (t, self._take(), fn))

    def after(self, dt: float, fn: Callable):
        self.at(self.now + dt, fn)

    def run(self, until: float = INF):
        while self._heap:
            t, _, fn = heapq.heappop(self._heap)
            if t > until:
                self.now = until
                return
            self.now = t
            self.n_events += 1
            fn()


class PSResource:
    """Processor-sharing link: active flows share capacity equally."""

    __slots__ = ("name", "cap", "flows")

    def __init__(self, name: str, cap: float):
        self.name = name
        self.cap = cap
        self.flows: set = set()

    def rate_of(self, flow) -> float:
        """This flow's share: class-blind fair queuing.  SharedLink
        (repro.network) overrides this with VL-arbitered shares."""
        return self.cap / max(len(self.flows), 1)


class Flow:
    """A transfer leg across one or more PS resources."""

    __slots__ = ("sim", "nbytes_left", "resources", "on_done", "rate",
                 "t_last", "version", "done", "tclass", "t_enter",
                 "nbytes_total", "fid")

    def __init__(self, sim: "Sim", nbytes: float, resources, on_done,
                 tclass: TrafficClass = TrafficClass.KV_TRANSFER):
        self.sim = sim
        self.fid = next(sim._flow_seq)
        self.nbytes_left = float(max(nbytes, 1.0))
        self.nbytes_total = self.nbytes_left
        self.resources = [r for r in resources if r is not None]
        self.on_done = on_done
        self.tclass = tclass
        self.rate = 0.0
        self.t_last = sim.loop.now
        self.t_enter = sim.loop.now
        self.version = 0
        self.done = False
        if not self.resources:
            sim.loop.after(0.0, self._finish)
            return
        for r in self.resources:
            note = getattr(r, "note_enter", None)
            if note is not None:
                note(self)
            r.flows.add(self)
        sim._reshare(self.resources)

    def _settle(self, now: float):
        if math.isinf(self.rate):
            # unbounded rate: served instantaneously (inf * 0 is nan,
            # so never enter it into the residual arithmetic)
            self.nbytes_left = 0.0
        else:
            self.nbytes_left -= self.rate * (now - self.t_last)
        self.t_last = now

    def _finish(self):
        if self.done:
            return
        self.done = True
        for r in self.resources:
            r.flows.discard(self)
            note = getattr(r, "note_done", None)
            if note is not None:
                note(self, self.sim.loop.now)
        if self.resources:
            self.sim._reshare(self.resources)
        self.on_done()

    def cancel(self):
        """Abandon the flow (fault recovery): detach from every resource
        and never fire ``on_done``.  Bytes already moved stay moved; the
        residual is simply lost with the dead engine."""
        if self.done:
            return
        self.done = True
        for r in self.resources:
            r.flows.discard(self)
            # drop arbiter caches without note_done's byte accounting
            # (the flow did not complete; counting its bytes would
            # overstate delivered traffic)
            inv = getattr(r, "_invalidate", None)
            if inv is not None:
                inv()
        if self.resources:
            self.sim._reshare(self.resources)


@dataclass(init=False)
class SimConfig:
    """Simulator entry point: core fields + the five shared config
    groups from :mod:`repro.core.config` (held by composition, same as
    ``ServingSystem``).  Subsystem knobs live in the groups —
    ``SimConfig(..., tier=TierConfig(dram_tier_bytes=1e9))`` — while
    the old flat spellings (``dram_tier_bytes=1e9``, ``elastic=True``)
    still construct an identical config through the deprecation shim
    for one release (ConfigDeprecationWarning).  Flat *reads*
    (``cfg.dram_tier_bytes``) stay available as delegating properties
    so downstream analysis code keeps working unchanged."""

    node: NodeSpec
    model: ModelSimSpec
    P: int
    D: int
    mode: str = "dualpath"            # dualpath | basic | oracle
    scheduler: str = "adaptive"       # adaptive | rr
    nodes_per_pe_group: Optional[int] = None   # default: all P nodes
    nodes_per_de_group: Optional[int] = None   # default: all D nodes
    quota_s: float = 0.300
    block_tokens: int = 64
    decode_block: int = 64
    kv_hbm_frac: float = 0.55         # fraction of HBM available for KV
    layerwise: bool = True            # layerwise prefill (ablation: False)
    alpha_read_s: float = 3.0         # §A.4: alpha = tokens readable in 3 s
    beta_compute_s: float = 5.0       # beta = tokens processed in 5 s
    split_reads: bool = False         # beyond-paper read splitting
    kv_dtype_bytes: int = 1           # fp8 KV (paper default)
    online: bool = False
    seed: int = 0
    # --- shared config groups (repro.core.config) -----------------------
    tier: TierConfig = dc_field(default_factory=TierConfig)
    net: NetworkConfig = dc_field(default_factory=NetworkConfig)
    elastic: ElasticConfig = dc_field(default_factory=ElasticConfig)
    resilience: ResilienceConfig = dc_field(default_factory=ResilienceConfig)
    slo: SloConfig = dc_field(default_factory=SloConfig)

    def __init__(self, node: NodeSpec, model: ModelSimSpec, P: int, D: int,
                 mode: str = "dualpath", scheduler: str = "adaptive",
                 nodes_per_pe_group: Optional[int] = None,
                 nodes_per_de_group: Optional[int] = None,
                 quota_s: float = 0.300, block_tokens: int = 64,
                 decode_block: int = 64, kv_hbm_frac: float = 0.55,
                 layerwise: bool = True, alpha_read_s: float = 3.0,
                 beta_compute_s: float = 5.0, split_reads: bool = False,
                 kv_dtype_bytes: int = 1, online: bool = False,
                 seed: int = 0,
                 tier: Optional[TierConfig] = None,
                 net: Optional[NetworkConfig] = None,
                 elastic=None,
                 resilience: Optional[ResilienceConfig] = None,
                 slo: Optional[SloConfig] = None,
                 **legacy):
        self.node = node
        self.model = model
        self.P = P
        self.D = D
        self.mode = mode
        self.scheduler = scheduler
        self.nodes_per_pe_group = nodes_per_pe_group
        self.nodes_per_de_group = nodes_per_de_group
        self.quota_s = quota_s
        self.block_tokens = block_tokens
        self.decode_block = decode_block
        self.kv_hbm_frac = kv_hbm_frac
        self.layerwise = layerwise
        self.alpha_read_s = alpha_read_s
        self.beta_compute_s = beta_compute_s
        self.split_reads = split_reads
        self.kv_dtype_bytes = kv_dtype_bytes
        self.online = online
        self.seed = seed
        g = resolve_groups(legacy, tier=tier, net=net, elastic=elastic,
                           resilience=resilience, slo=slo)
        self.tier = g["tier"]
        self.net = g["net"]
        self.elastic = g["elastic"]
        self.resilience = g["resilience"]
        self.slo = g["slo"]


# Flat-name read/write compatibility: ``cfg.dram_tier_bytes`` etc.
# delegate to the owning group, so the simulator's internals and any
# downstream analysis keep the old spelling while the storage moved
# into the shared groups.  ``elastic`` is excluded — the attribute IS
# the ElasticConfig group, whose __bool__ keeps ``if cfg.elastic:``
# reading the legacy switch.
def _flat_alias(grp: str, fld: str) -> property:
    return property(lambda self: getattr(getattr(self, grp), fld),
                    lambda self, v: setattr(getattr(self, grp), fld, v))


for _flat, (_grp, _fld) in FLAT_FIELDS.items():
    if _flat != "elastic" and not hasattr(SimConfig, _flat):
        setattr(SimConfig, _flat, _flat_alias(_grp, _fld))


class _EngineSim:
    __slots__ = ("eid", "node", "kind", "group", "fifo", "packer",
                 "active_decode", "resident_tokens", "kv_capacity_tokens",
                 "attn_sample")

    def __init__(self, eid, node, kind, group):
        self.eid = eid
        self.node = node
        self.kind = kind
        self.group = group
        self.fifo: List[PrefillWork] = []
        self.packer = None              # PEs only (set at init / role flip)
        self.active_decode: List["RoundSim"] = []
        self.resident_tokens = 0
        self.kv_capacity_tokens = 0
        self.attn_sample = 0.0


class RoundSim:
    """One round (request) of a trajectory moving through the system."""

    __slots__ = ("req", "traj", "round_idx", "agent", "submit_t", "read_done_t",
                 "prefill_done_t", "first_decode_t", "done_t", "transfer_done",
                 "prefill_left", "gen_left", "ctx", "h2d_done", "tokens_out",
                 "second_token_t", "charged", "read_legs", "tier_pinned",
                 "read_recs", "read_pending", "hedged", "flows",
                 "gen_total", "n_recoveries")

    def __init__(self, req: Request, traj: Trajectory, round_idx: int, agent):
        self.req = req
        self.traj = traj
        self.round_idx = round_idx
        self.agent = agent
        self.submit_t = 0.0
        self.read_done_t = -1.0
        self.prefill_done_t = -1.0
        self.first_decode_t = -1.0
        self.second_token_t = -1.0
        self.done_t = -1.0
        self.transfer_done = False
        self.h2d_done = False
        self.prefill_left = req.new_tokens
        self.gen_left = req.gen_tokens
        self.ctx = req.prompt_tokens
        self.tokens_out = 0
        # per-symbolic-resource bytes this round charged (load + layerwise
        # + decode_start legs) — must equal the loading-plan byte sums
        self.charged: Dict[str, int] = {}
        # storage legs: [side, nbytes, t_service_start, t_done] — split
        # reads have one entry per side, letting tests assert both NICs
        # served this request's load phase concurrently
        self.read_legs: List[list] = []
        # (node, refs) of DRAM-tier blocks pinned while this round is in
        # flight — unpinned at round completion
        self.tier_pinned = None
        # live per-storage-leg records ({"side","engine","entry","job",
        # "release","refs","done"}) while the load phase is in flight —
        # the handles hedging and fault recovery act on
        self.read_recs = None
        self.read_pending = None
        self.hedged = False
        # in-flight transfer/h2d Flows, cancellable on engine death
        self.flows: List[Flow] = []
        # gen_tokens of the ORIGINAL request: recovery resubmits with
        # only the remaining generation, so TPOT math needs the total
        self.gen_total = req.gen_tokens
        self.n_recoveries = 0

    def charge(self, leg: Leg):
        for r in leg.resources:
            self.charged[r] = self.charged.get(r, 0) + leg.nbytes


class AgentSim:
    __slots__ = ("traj", "next_round", "start_t", "end_t", "prefetch_pinned")

    def __init__(self, traj: Trajectory):
        self.traj = traj
        self.next_round = 0
        self.start_t = -1.0
        self.end_t = -1.0
        # (node, refs) leased by the think-time prefetcher until the next
        # round is submitted (staged blocks must survive to round start)
        self.prefetch_pinned = None


class Sim:
    def __init__(self, cfg: SimConfig, trajectories: List[Trajectory],
                 tracer=None):
        self.cfg = cfg
        self.loop = EventLoop()
        self.model = cfg.model
        self.node_spec = cfg.node
        g = cfg.node.g
        self.kv_per_token = self.model.kv_bytes_per_token
        # monotone Flow ids: _reshare resettles affected flows in fid
        # order so PS rate updates are independent of set iteration
        # order (chaos failures must reproduce from a seed alone)
        self._flow_seq = itertools.count()
        # empty schedules are normalised away so every fault hook stays
        # a structural no-op on the happy path (zero-fault identity)
        f = cfg.faults
        self.faults = f if (f is not None and not f.empty) else None
        # --- flight recorder (repro.obs) -----------------------------------
        # None by default: every hook below is guarded by `if tracer is
        # not None`, so an untraced run executes the exact pre-obs
        # arithmetic (bit-identity pinned by tests/test_obs.py).
        self.tracer = tracer
        # per-rid lifecycle timestamps (RoundSim has __slots__, so the
        # trace scratch lives here, keyed by rid)
        self._tr: Dict[int, dict] = {}
        if tracer is not None:
            tracer.bind_clock(lambda: self.loop.now)
            tracer.annotate_faults(self.faults)

        # --- resources -----------------------------------------------------
        self.snic: Dict[int, "_FifoNic"] = {}
        self.dram: Dict[int, PSResource] = {}
        self.cnic_rd: Dict[Tuple[int, int], PSResource] = {}
        self.cnic_wr: Dict[Tuple[int, int], PSResource] = {}
        # PE<->DE compute network: a finite, priority-arbitrated shared
        # link when cfg.net_bw is set (repro.network.SharedLink); the
        # paper's no-congestion assumption (infinite capacity) otherwise
        self.net = SharedLink("net", cfg.net_bw if cfg.net_bw else INF,
                              arbiter=cfg.net_arbiter)
        n_nodes = cfg.P + cfg.D
        for n in range(n_nodes):
            self.snic[n] = _FifoNic(self, n, cfg.node.snic_bw)
            self.dram[n] = PSResource(f"dram{n}", cfg.node.dram_bw)
            for r in range(g):
                self.cnic_rd[(n, r)] = PSResource(f"cr{n}.{r}", cfg.node.cnic_bw)
                self.cnic_wr[(n, r)] = PSResource(f"cw{n}.{r}", cfg.node.cnic_bw)

        # --- node-local DRAM KV tier (capacity model; kvcache/tiers.py) ---
        # Refs are (trajectory id, block index); block bytes follow the
        # whole-block hit granularity the trie imposes.
        self.block_bytes = cfg.block_tokens * self.kv_per_token
        self.tiers: Dict[int, DramTier] = {}
        if cfg.dram_tier_bytes > 0 and self.block_bytes > 0:
            for n in range(n_nodes):
                self.tiers[n] = DramTier(cfg.dram_tier_bytes,
                                         policy=cfg.tier_policy,
                                         ttl_s=cfg.tier_ttl_s)
                self.tiers[n].clock_fn = lambda: self.loop.now
                if tracer is not None:
                    self.tiers[n].tracer = tracer
                    self.tiers[n].track = f"tier/node{n}"
        self.prefetcher = ThinkTimePrefetcher(cfg.prefetch_chunk_blocks) \
            if (cfg.prefetch and self.tiers) else None

        # --- engines / groups ----------------------------------------------
        npg = cfg.nodes_per_pe_group or cfg.P
        ndg = cfg.nodes_per_de_group or cfg.D
        self.engines: Dict[Tuple[int, int], _EngineSim] = {}
        self.pe_groups: Dict[int, List[_EngineSim]] = defaultdict(list)
        self.de_groups: Dict[int, List[_EngineSim]] = defaultdict(list)
        sched_cls = Scheduler if cfg.scheduler == "adaptive" else \
            RoundRobinScheduler
        alpha = int(cfg.alpha_read_s * cfg.node.snic_bw / max(self.kv_per_token, 1)) \
            if self.kv_per_token else 1 << 30
        tok_rate = cfg.node.gpu.flops * cfg.node.gpu.mfu_prefill / \
            max(self.model.linear_flops_per_token(), 1.0)
        beta = int(cfg.beta_compute_s * tok_rate)
        self.sched = sched_cls(alpha=alpha, beta=beta,
                               split_reads=cfg.split_reads,
                               class_aware=cfg.slo.class_aware)
        if tracer is not None:
            self.sched.tracer = tracer

        kv_cap_bytes = cfg.node.gpu.hbm_bytes * cfg.kv_hbm_frac
        kv_cap_tokens = int(kv_cap_bytes / max(self.kv_per_token, 1)) \
            if self.kv_per_token else 1 << 30
        self._kv_cap_tokens = kv_cap_tokens
        self._pe_tok_rate = max(tok_rate, 1.0)
        self._mk_packer = lambda: _SimPacker(
            self.model,
            AttnTimeModel(effective_flops=cfg.node.gpu.flops *
                          cfg.node.gpu.mfu_prefill),
            cfg.quota_s, chunk_tokens=cfg.slo.prefill_chunk_tokens)

        for n in range(cfg.P):
            grp = n // npg
            for r in range(g):
                e = _EngineSim((n, r), n, "pe", grp)
                tm = AttnTimeModel(effective_flops=cfg.node.gpu.flops *
                                   cfg.node.gpu.mfu_prefill)
                e.packer = _SimPacker(self.model, tm, cfg.quota_s,
                                      chunk_tokens=cfg.slo.prefill_chunk_tokens)
                self.engines[(n, r)] = e
                self.pe_groups[grp].append(e)
                self.sched.register_engine((n, r), node=n, kind="pe", group=grp)
        for dn in range(cfg.D):
            n = cfg.P + dn
            grp = 1000 + dn // ndg
            for r in range(g):
                e = _EngineSim((n, r), n, "de", grp)
                e.kv_capacity_tokens = kv_cap_tokens
                self.engines[(n, r)] = e
                self.de_groups[grp].append(e)
                st = self.sched.register_engine((n, r), node=n, kind="de",
                                                group=grp)
                st.free_hbm_tokens = kv_cap_tokens

        # engines-per-group for weight sharding in the compute model
        self.pe_group_size = npg * g
        self.de_group_size = ndg * g

        # --- model collectives on the shared link (repro.network) ----------
        collectives_on = cfg.model_collectives
        if collectives_on is None:
            collectives_on = cfg.net_bw is not None
        self._collectives_on = bool(collectives_on)
        if cfg.collective_bytes_per_token is not None:
            self.coll_model = CollectiveVolumeModel(
                cfg.collective_bytes_per_token, self.model.n_layers)
        else:
            self.coll_model = CollectiveVolumeModel.from_spec(
                self.model, max(self.pe_group_size, self.de_group_size),
                dtype_bytes=cfg.collective_dtype_bytes)
        self.collective_stall_s = 0.0     # step time lost waiting on colls

        # --- workload --------------------------------------------------------
        self.agents = [AgentSim(t) for t in trajectories]
        self.rounds: List[RoundSim] = []
        # rid -> RoundSim.  Recovery after an engine death resubmits a
        # round under a FRESH rid and unmaps the old one, so callbacks
        # captured against the dead incarnation (a prefill batch item in
        # a step barrier, a late NIC completion) resolve to None and are
        # dropped instead of corrupting the recovered round.
        self._by_rid: Dict[int, RoundSim] = {}
        self._rid = itertools.count()
        self._pe_stepping: Dict[int, bool] = {gid: False
                                              for gid in self.pe_groups}
        self._de_stepping: Dict[int, bool] = {gid: False
                                              for gid in self.de_groups}
        self._sched_pending = False

        # --- elastic role reconfiguration (core/autoscale.py) -------------
        if cfg.drain_policy not in ("idlest", "rotate"):
            raise ValueError(f"unknown drain_policy {cfg.drain_policy!r}")
        self.drains = DrainTracker()
        self.controller = PDController(
            hi=cfg.reconfig_hi, lo=cfg.reconfig_lo,
            patience=cfg.reconfig_patience,
            cooldown_s=cfg.reconfig_cooldown_s,
            idle_floor_s=cfg.reconfig_idle_floor_s,
            min_pe=cfg.elastic_min_pe, min_de=cfg.elastic_min_de)
        if tracer is not None:
            self.controller.tracer = tracer
        # role flips re-home the engine into a fresh singleton scheduler
        # group (groups are stepped in lockstep; a flipped engine shares
        # no step barrier with its old peers)
        self._next_gid = itertools.count(5000)
        self._drain_rotation = 0
        self.reconfig_weight_bytes = 0.0

        # --- metrics ---------------------------------------------------------
        self.snic_samples: List[Tuple[float, int, float]] = []  # (t, node, bytes)
        self.attn_balance: List[Tuple[float, float]] = []       # (t, max/avg)
        self.tps_samples: List[Tuple[float, int, int]] = []     # (t, prompt, gen)
        self.prompt_tokens_done = 0
        self.gen_tokens_done = 0
        self.snic_hit_read_bytes = 0   # demand hit bytes that paid a SNIC
        self.net_bg_bytes = 0          # injected background transfer bytes
        # --- faults / hedged reads / recovery ------------------------------
        self.dead_engines: List[Tuple[float, Tuple[int, int], str]] = []
        self.recovered_rounds = 0
        self.hedged_reads = 0
        self.hedge_moved_tokens = 0
        # --- online SLO layer (core/config.SloConfig) ----------------------
        # gate is None when admission control is off: arrivals then flow
        # straight to sched.submit, structurally identical to pre-SLO
        self.gate = AdmissionGate(cfg.slo) if cfg.slo.admission else None
        self.prefill_chunks = 0

    # ------------------------------------------------------------------
    # PS rate management
    # ------------------------------------------------------------------
    def _flow(self, nbytes, resources, on_done,
              tclass: TrafficClass = TrafficClass.KV_TRANSFER):
        """Flow factory: every PS transfer leg the sim launches goes
        through here, so the vectorized engine (sim/vectorized.py) can
        allocate into its struct-of-arrays drain pool by overriding one
        method instead of forking the request-lifecycle handlers."""
        return Flow(self, nbytes, resources, on_done, tclass)

    def _reshare(self, resources):
        now = self.loop.now
        affected = set()
        for r in resources:
            affected.update(r.flows)
        # A plain PS resource's share is class-blind (cap / n_flows) and
        # membership cannot change mid-sweep (finishes are deferred via
        # after(0.0)), so compute each resource's share once per sweep
        # instead of once per member flow.  SharedLink shares are
        # class-aware and stay on rate_of (it keeps its own caches).
        shares: Dict[int, float] = {}
        # resource flow-sets are unordered; resettle in creation order so
        # the event heap's tie-breaking (and thus every downstream
        # timestamp) is independent of set iteration order
        for f in sorted(affected, key=lambda f: f.fid):
            f._settle(now)
            new_rate = INF
            for r in f.resources:
                if type(r) is PSResource:
                    rate = shares.get(id(r))
                    if rate is None:
                        rate = shares[id(r)] = r.cap / max(len(r.flows), 1)
                else:
                    rate = r.rate_of(f)
                if rate < new_rate:
                    new_rate = rate
            f.rate = new_rate
            f.version += 1
            if f.nbytes_left <= 1.0 or math.isinf(new_rate):
                # sub-byte residual, or every resource unbounded (a flow
                # whose only resource is an infinite link — settling at
                # rate inf would produce inf*0 = nan residuals): done
                self.loop.after(0.0, f._finish)
            elif new_rate > 0:
                v = f.version
                eta = f.nbytes_left / new_rate
                self.loop.after(eta, lambda f=f, v=v: self._flow_check(f, v))

    def _flow_check(self, f: Flow, version: int):
        if f.done or f.version != version:
            return
        if math.isinf(f.rate):
            f._finish()
            return
        f._settle(self.loop.now)
        if f.nbytes_left <= 1.0:
            f._finish()
        else:
            # float drift: reschedule the residual instead of dropping it
            f.version += 1
            v = f.version
            eta = f.nbytes_left / max(f.rate, 1.0)
            self.loop.after(eta, lambda f=f, v=v: self._flow_check(f, v))

    # ------------------------------------------------------------------
    # run
    # ------------------------------------------------------------------
    def run(self, arrivals: Optional[List[float]] = None,
            until: float = INF):
        """arrivals: per-agent start times (None = all at t=0, offline)."""
        import numpy as np
        for i, a in enumerate(self.agents):
            t0 = 0.0 if arrivals is None else arrivals[i]
            self.loop.at(t0, lambda a=a: self._agent_start(a))
        cfg = self.cfg
        if cfg.net_bg_load > 0 and cfg.net_bw:
            # background transfer traffic on the shared link (other
            # tenants' dual-path reads / PD rebalancing): fixed-size KV
            # chunks offered at net_bg_load x net_bw, self-limiting once
            # the workload completes
            chunk = cfg.net_bg_chunk_bytes
            period = chunk / (cfg.net_bg_load * cfg.net_bw)

            def bg():
                if all(a.end_t >= 0 for a in self.agents):
                    return
                self.net_bg_bytes += chunk
                self._flow(chunk, [self.net], lambda: None)
                self.loop.after(period, bg)

            self.loop.after(period, bg)
        if cfg.elastic:
            self.loop.after(cfg.reconfig_interval_s, self._reconfig_tick)
        if self.faults is not None:
            for d in self.faults.deaths:
                self.loop.at(d.t,
                             lambda d=d: self._engine_death(tuple(d.engine)))
            # link flaps: the shared link's capacity changes at window
            # edges; every in-flight flow is resettled at each edge.
            # SNIC windows need no events (the FIFO server reads the
            # fault factor at each job's service start).
            if cfg.net_bw:
                base_cap = self.net.cap

                def flap(t):
                    self.net.cap = base_cap / self.faults.net_factor(t)
                    self.net._invalidate()
                    self._reshare([self.net])

                for t in self.faults.boundaries_array("net"):
                    t = float(t)
                    self.loop.at(t, lambda t=t: flap(t))
        self.loop.run(until)
        return self

    # ------------------------------------------------------------------
    # elastic control loop (core/autoscale.py)
    # ------------------------------------------------------------------
    def _workload_done(self) -> bool:
        return all(a.end_t >= 0 for a in self.agents)

    def _elastic_signals(self) -> LoadSignals:
        """One observation of the deployment, in seconds of service per
        role — built from the same state the scheduler and step loops
        already maintain (queue depths, FIFO backlogs, active decodes,
        disk reading queues, link congestion, tier hits)."""
        sched = self.sched
        gpu = self.cfg.node.gpu
        pe_queued = sum(r.new_tokens for r in sched.pe_queue)
        pe_busy = 0
        de_busy_tok = 0
        ctxs: List[int] = []
        for e in self.engines.values():
            if e.kind == "pe":
                pe_busy += sum(w.remaining for w in e.fifo)
            else:
                for r in e.active_decode:
                    de_busy_tok += r.gen_left
                    ctxs.append(r.ctx)
        de_q_tok = 0
        n_active = 0
        for e in self.engines.values():
            if e.kind == "de":
                n_active += len(e.active_decode)
        for q in (sched.de_global_queue, *sched.de_private.values()):
            for r in q:
                de_q_tok += r.gen_tokens
                ctxs.append(r.prompt_tokens)
        # continuous-batching decode rate per engine at the observed
        # batch size: n tokens advance per step of
        # (n * kv_step_bytes + weight_bytes) / effective HBM bandwidth —
        # the weight read amortises only across the actual batch, so
        # small batches are weight-bound (rate grows with n) and huge
        # ones kv-bound (rate saturates)
        n_de_now = max(sum(1 for e in self.engines.values()
                           if e.kind == "de"), 1)
        n_ref = max(n_active / n_de_now, 1.0)
        ctx_ref = (sum(ctxs) / len(ctxs)) if ctxs else 1.0
        kv_step = self.model.decode_step_bytes(ctx_ref)
        w = self.model.active_param_bytes_resident(self.de_group_size)
        de_rate = max(n_ref * gpu.hbm_bw * gpu.mbu_decode /
                      max(n_ref * kv_step + w, 1.0), 1.0)
        # disk reading backlogs, live from the per-node SNIC FIFOs (the
        # scheduler-side read_q copies go stale between fetches); one
        # count per (node, role) so multi-engine nodes aren't inflated
        snic_tok_rate = max(
            self.cfg.node.snic_bw / max(self.kv_per_token, 1), 1.0)
        pe_rq = de_rq = 0.0
        counted = set()
        for st in sched.engines.values():
            if st.draining:
                continue
            key = (st.node, st.kind)
            if key in counted:
                continue
            counted.add(key)
            q = self.snic[st.node].queued_bytes / max(self.kv_per_token, 1)
            if st.kind == "pe":
                pe_rq += q
            else:
                de_rq += q
        tiers = list(self.tiers.values())
        dram_hit = sum(t.dram_hit_bytes for t in tiers)
        denom = dram_hit + self.snic_hit_read_bytes
        # class signals: interactive share of the queued seconds, fed to
        # the elastic controller only under class-aware scheduling (both
        # stay 0.0 otherwise — legacy pressures unchanged)
        pe_q_int = de_q_int = 0.0
        if sched.class_aware:
            pe_q_int = sum(r.new_tokens for r in sched.pe_queue
                           if r.class_rank == 0) / self._pe_tok_rate
            de_q_int = sum(r.gen_tokens
                           for q in (sched.de_global_queue,
                                     *sched.de_private.values())
                           for r in q if r.class_rank == 0) / de_rate
        return LoadSignals(
            n_pe=len(sched.admitting("pe")),
            n_de=len(sched.admitting("de")),
            pe_queued_s=pe_queued / self._pe_tok_rate,
            pe_busy_s=pe_busy / self._pe_tok_rate,
            de_queued_s=de_q_tok / de_rate,
            de_busy_s=de_busy_tok / de_rate,
            pe_read_q_s=pe_rq / snic_tok_rate,
            de_read_q_s=de_rq / snic_tok_rate,
            net_congestion=self.net.congestion(),
            dram_hit_ratio=(dram_hit / denom) if denom else 0.0,
            pe_queued_interactive_s=pe_q_int,
            de_queued_interactive_s=de_q_int,
        )

    def _reconfig_tick(self):
        if self._workload_done():
            return                      # let the event loop terminate
        self._advance_drains()
        if not self.drains.active:
            action = self.controller.observe(self._elastic_signals(),
                                             self.loop.now)
            if action is not None:
                self._begin_reconfig(action)
        self.loop.after(self.cfg.reconfig_interval_s, self._reconfig_tick)

    def _begin_reconfig(self, action: str):
        src = "de" if action == DE_TO_PE else "pe"
        floor = self.cfg.elastic_min_de if src == "de" \
            else self.cfg.elastic_min_pe
        cands = self.sched.admitting(src)
        if len(cands) <= floor:
            return

        def load_of(st):
            used_hbm = 0
            if st.kind == "de":
                used_hbm = self._kv_cap_tokens - st.free_hbm_tokens
            return st.tok + st.read_q + used_hbm

        victim = pick_victim(cands, self.cfg.drain_policy, load_of,
                             rotation=self._drain_rotation)
        self._drain_rotation += 1
        self.sched.begin_drain(victim.engine)
        # requests assigned to the victim whose read never started are
        # handed back for reassignment (the drain must not be hostage to
        # work blocked on the other role's capacity)
        back = self.sched.requeue_unstarted(
            victim.engine, [rs.req for rs in self.rounds if rs.done_t < 0])
        if src == "de":
            e = self.engines[victim.engine]
            for req in back:
                e.resident_tokens -= req.hbm_tokens
        self.drains.begin(victim.engine, src,
                          "pe" if src == "de" else "de", self.loop.now)
        if back:
            self._kick_scheduler()
        self.loop.after(min(self.cfg.reconfig_interval_s / 8.0, 1.0),
                        self._drain_poll)

    def _drain_poll(self):
        self._advance_drains()
        if self.drains.active:
            self.loop.after(min(self.cfg.reconfig_interval_s / 8.0, 1.0),
                            self._drain_poll)

    def _engine_busy(self, eid, kind) -> bool:
        """Ground-truth in-flight check for the drain gate.  The
        scheduler's seq/tok are overwritten by fetch reports derived
        from the engine FIFOs, which are EMPTY while a request's KV
        read is still in flight (PrefillWork enters the fifo only at
        _read_done) — so a PE gate must consult the rounds themselves,
        not just the report-refreshed counters.  DEs are covered by
        their reservation ledger: resident_tokens is held from
        assignment to decode completion."""
        e = self.engines[eid]
        if kind == "de":
            return bool(e.active_decode) or e.resident_tokens != 0
        return bool(e.fifo) or any(
            rs.req.pe == eid and rs.done_t < 0 and rs.prefill_done_t < 0
            for rs in self.rounds)

    def _advance_drains(self):
        """Second half of the drain protocol: once a draining engine's
        in-flight lifecycle states have emptied, reload the target
        role's weight shard over the node's storage NIC (it contends
        with real reads, as on hardware), then flip."""
        for eid, rec in list(self.drains.active.items()):
            if rec.t_drained >= 0:
                continue                # weight reload already in flight
            if not self.sched.can_finish_drain(eid) or \
                    self._engine_busy(eid, rec.from_kind):
                continue
            e = self.engines[eid]
            self.drains.mark_drained(eid, self.loop.now)
            # reload exactly the shard the sim's compute model has the
            # engine hold: _pe_step/_de_step shard weights by the
            # STATIC pe/de_group_size regardless of actual group
            # membership, so a flipped engine (singleton scheduler
            # group) still computes — and therefore reloads — 1/gsz of
            # the weights.  (serving's ServingTimeModel shards by 1, so
            # its flip charges active_param_bytes_resident(1) there.)
            gsz = self.pe_group_size if rec.to_kind == "pe" \
                else self.de_group_size
            w = self.model.active_param_bytes_resident(gsz)
            self.reconfig_weight_bytes += w
            self.snic[e.node].enqueue(
                w, lambda rec=rec: self._finish_flip(rec), read=True,
                tag="weights")

    def _finish_flip(self, rec):
        eid = rec.engine
        if eid not in self.engines or eid not in self.drains.active:
            return      # the engine died while its weight reload was queued
        e = self.engines[eid]
        groups = self.pe_groups if rec.from_kind == "pe" else self.de_groups
        groups[e.group].remove(e)
        if not groups[e.group]:
            del groups[e.group]
        gid = next(self._next_gid)
        tier = self.tiers.get(e.node)
        # tier-resident blocks stay with the node across the flip (the
        # DRAM tier is node-local and role-agnostic): the handoff is
        # accounting, not movement
        handoff = int(tier.used_bytes) if tier is not None else 0
        e.kind, e.group = rec.to_kind, gid
        if rec.to_kind == "pe":
            if e.packer is None:
                e.packer = self._mk_packer()
            e.resident_tokens = 0
            self.pe_groups[gid].append(e)
            self._pe_stepping.setdefault(gid, False)
            self.sched.finish_drain(eid, kind="pe", group=gid)
        else:
            e.kv_capacity_tokens = self._kv_cap_tokens
            self.de_groups[gid].append(e)
            self._de_stepping.setdefault(gid, False)
            self.sched.finish_drain(eid, kind="de", group=gid,
                                    free_hbm_tokens=self._kv_cap_tokens)
        # the DE group topology changed: re-route queued requests
        # against it (requests parked in an old group's private queue
        # would otherwise never see the new group)
        self.sched.rebalance_de_private()
        self.drains.finish(eid, self.loop.now, tier_handoff_bytes=handoff)
        if self.tracer is not None:
            self.tracer.span(
                "reconfig", "drain", rec.t_begin, self.loop.now,
                engine=list(eid),
                direction=f"{rec.from_kind}->{rec.to_kind}")
        self._kick_scheduler()
        if rec.to_kind == "pe":
            self._wake_pe_group(gid)
        else:
            self._wake_de_group(gid)

    # ------------------------------------------------------------------
    # engine death & request recovery (sim/faults.py)
    # ------------------------------------------------------------------
    def _engine_death(self, eid):
        """Fail-stop of one engine (tentpole: role backfill).  The
        engine's unstarted assignments are handed back via the drain
        machinery, its in-flight rounds are recovered (prefill restarts
        from persisted whole-block KV, decode resumes from the trie),
        and the engine leaves the scheduler and topology.  Backfill is
        controller-driven: the dead engine drops out of the admitting
        sets the elastic LoadSignals count, so the resulting pressure
        shift makes the PDController propose a compensating flip."""
        e = self.engines.get(eid)
        if e is None or eid not in self.sched.engines:
            return                       # unknown or already dead
        kind = e.kind
        self.dead_engines.append((self.loop.now, eid, kind))
        if self.tracer is not None:
            self.tracer.event("faults/deaths", "engine_death",
                              engine=list(eid), kind=kind)
        # a victim dying mid-drain: the flip it was draining for is off
        if eid in self.drains.active:
            self.drains.abort(eid)
        # 1. assignments whose read never started are cheap: hand them
        # back for reassignment exactly like a drain does
        back = self.sched.requeue_unstarted(
            eid, [rs.req for rs in self.rounds if rs.done_t < 0])
        if kind == "de":
            for req in back:
                e.resident_tokens -= req.hbm_tokens
        # 2. started rounds that still depend on the engine are
        # recovered.  A PE's involvement ends once prefill AND the PD
        # transfer are done; a DE's only at round completion.
        for rs in self.rounds:
            if rs.done_t >= 0 or rs.req.read_path is None:
                continue
            req = rs.req
            lost = (req.de == eid) or (
                req.pe == eid and (rs.prefill_done_t < 0
                                   or not rs.transfer_done))
            if lost:
                self._recover_round(rs)
        # 3. drop the engine from the scheduler and the step topology
        self.sched.fail_engine(eid)
        groups = self.pe_groups if kind == "pe" else self.de_groups
        members = groups.get(e.group)
        if members and e in members:
            members.remove(e)
            if not members:
                del groups[e.group]
        del self.engines[eid]
        self.sched.rebalance_de_private()
        self._kick_scheduler()

    def _recover_round(self, rs: RoundSim):
        """Re-home one in-flight round after an engine death.

        Cancels everything physical (NIC read jobs, transfer flows),
        releases every hold the incarnation took (read_q, engine
        seq/tok/HBM reservations, tier pins), then resubmits the round
        under a fresh rid: whole blocks of context persisted so far —
        prompt AND generated — are cached (exactly what the trie would
        match), the tail re-prefills, and the remaining generation
        re-decodes.  Timing milestones already reached stay: TTFT/TPOT
        honestly include the recovery gap, which is what the SLO
        regression fixtures pin."""
        req = rs.req
        # (a) outstanding storage reads: abort, release read_q charge
        if rs.read_recs:
            for rec in rs.read_recs:
                if rec["done"]:
                    continue
                rec["done"] = True
                if rec["job"] is not None:
                    self.snic[rec["engine"][0]].abort(rec["job"])
                self.sched.on_read_done(rec["engine"], rec["release"])
        rs.read_recs = None
        rs.read_pending = None
        # (b) in-flight transfer / h2d flows die with the data
        for f in rs.flows:
            f.cancel()
        rs.flows = []
        # (c) engine-side holds (the dead engine's state is still
        # registered at this point; its releases are simply forfeited
        # when fail_engine removes it moments later)
        if req.pe is not None:
            if rs.prefill_done_t < 0:
                self.sched.on_request_done(req.pe, req)
            pe = self.engines.get(req.pe)
            if pe is not None:
                pe.fifo = [w for w in pe.fifo if w.rid != req.rid]
        if req.de is not None:
            de = self.engines.get(req.de)
            if de is not None:
                if rs in de.active_decode:
                    de.active_decode.remove(rs)
                de.resident_tokens -= req.hbm_tokens
            self.sched.on_request_done(req.de, req)
        # (d) tier pins from the dead incarnation
        if rs.tier_pinned is not None:
            node, refs = rs.tier_pinned
            tier = self.tiers.get(node)
            if tier is not None:
                tier.unpin(refs)
            rs.tier_pinned = None
        # (e) resubmit: persisted whole blocks (prompt + generated) are
        # the new hit; keep the ORIGINAL arrival so the round does not
        # lose its place in arrival-ordered queues
        bt = self.cfg.block_tokens
        ctx = req.prompt_tokens + rs.tokens_out
        cached = (ctx // bt) * bt
        new_req = Request(rid=next(self._rid), cached_tokens=cached,
                          new_tokens=max(ctx - cached, 1),
                          gen_tokens=max(rs.gen_left, 1),
                          arrival=req.arrival, slo_class=req.slo_class)
        del self._by_rid[req.rid]
        self._by_rid[new_req.rid] = rs
        new_req._sim_round = rs
        rs.req = new_req
        # accounting restarts for the new incarnation (NIC counters keep
        # the bytes the dead one physically moved)
        rs.charged = {}
        rs.read_legs = []
        rs.read_done_t = -1.0
        rs.transfer_done = False
        rs.h2d_done = False
        rs.hedged = False
        rs.prefill_left = new_req.new_tokens
        rs.gen_left = new_req.gen_tokens
        rs.ctx = new_req.prompt_tokens
        rs.n_recoveries += 1
        self.recovered_rounds += 1
        if self.tracer is not None:
            self.tracer.event(f"req/{new_req.rid}", "recovered",
                              old_rid=req.rid,
                              cached_tokens=new_req.cached_tokens)
        self.sched.submit(new_req)

    # ------------------------------------------------------------------
    # agent / request lifecycle
    # ------------------------------------------------------------------
    def _agent_start(self, agent: AgentSim):
        agent.start_t = self.loop.now
        self._submit_round(agent)

    def _submit_round(self, agent: AgentSim):
        if agent.prefetch_pinned is not None:
            # the prefetcher's lease ends at submission: the round's own
            # in-flight pin (taken at read start) protects what it uses
            node, refs = agent.prefetch_pinned
            self.tiers[node].unpin(refs)
            agent.prefetch_pinned = None
        i = agent.next_round
        traj = agent.traj
        if i >= traj.n_rounds:
            agent.end_t = self.loop.now
            return
        rnd = traj.rounds[i]
        cached = traj.context_before(i)
        # whole-block hits only (trie granularity)
        bt = self.cfg.block_tokens
        cached_blocks = (cached // bt) * bt
        new_tokens = rnd.append + (cached - cached_blocks)
        if self.gate is not None:
            # load-aware admission (core/admission.py): queueing-delay-
            # aware TTFT estimate from the elastic controller's signals
            # plus this arrival's own read + prefill service time
            sig = self._elastic_signals()
            read_s = cached_blocks * self.kv_per_token / \
                max(self.cfg.node.snic_bw, 1.0)
            prefill_s = max(new_tokens, 1) / self._pe_tok_rate
            verdict = self.gate.decide(
                (traj.tid, i), self.gate.ttft_estimate(sig, read_s,
                                                       prefill_s))
            if verdict == "defer":
                self.loop.after(self.cfg.slo.admission_defer_s,
                                lambda a=agent: self._submit_round(a))
                return
            if verdict == "reject":
                # shed the load: the client's trajectory ends here
                # rather than holding queue slots it cannot meet SLO in
                agent.end_t = self.loop.now
                return
        req = Request(rid=next(self._rid), cached_tokens=cached_blocks,
                      new_tokens=max(new_tokens, 1), gen_tokens=rnd.gen,
                      arrival=self.loop.now, slo_class=traj.slo_class)
        rs = RoundSim(req, traj, i, agent)
        rs.submit_t = self.loop.now
        self.rounds.append(rs)
        self._by_rid[req.rid] = rs
        rs.req._sim_round = rs          # backref
        for tier in self.tiers.values():
            tier.note_alive(traj.tid, now=self.loop.now)
        self.sched.submit(req)
        self._kick_scheduler()

    def _kick_scheduler(self):
        if self._sched_pending:
            return
        self._sched_pending = True
        self.loop.after(1e-4, self._sched_tick)

    def _sched_tick(self):
        self._sched_pending = False
        kvpt = self.kv_per_token
        # DE admission first (HBM reservation), then PE assignment.
        # Reports are built with explicit integer loops: the generator
        # version spent more time in frame switches than in the adds
        # once fleets grew past a few hundred standing decodes.
        for gid, members in self.de_groups.items():
            if not self.sched.de_private.get(gid) and \
                    not self.sched.de_global_queue:
                continue
            reports = {}
            for e in members:
                tok = 0
                for r in e.active_decode:
                    tok += r.ctx + r.gen_left
                reports[e.eid] = (len(e.active_decode), tok,
                                  self.snic[e.node].queue_tokens(kvpt),
                                  e.kv_capacity_tokens - e.resident_tokens)
            for asg in self.sched.on_de_fetch(gid, reports):
                rs = asg.request._sim_round
                e = self.engines[asg.engine]
                e.resident_tokens += asg.request.hbm_tokens
                self._maybe_start_read(rs)
        for gid, members in self.pe_groups.items():
            if not self.sched.pe_queue:
                break
            reports = {}
            for e in members:
                rem = 0
                for w in e.fifo:
                    rem += w.remaining
                reports[e.eid] = (len(e.fifo), rem,
                                  self.snic[e.node].queue_tokens(kvpt))
            for asg in self.sched.on_pe_fetch(gid, reports):
                self._maybe_start_read(asg.request._sim_round)

    def _maybe_start_read(self, rs: RoundSim):
        req = rs.req
        if req.pe is None or req.de is None or req.read_path is not None:
            return
        if self.cfg.mode == "oracle":
            req.read_path = "pe"
            self._read_done(rs)
            return
        bt = self.cfg.block_tokens
        hit_refs = [(rs.traj.tid, b) for b in range(req.cached_tokens // bt)]
        if self.cfg.mode == "basic":
            req.read_path = "pe"
            self.sched.engines[req.pe].read_q += req.cached_tokens
        else:
            tier_tokens = None
            if self.tiers and hit_refs:
                tier_tokens = {
                    "pe": self.tiers[req.pe[0]].resident_prefix(hit_refs) * bt,
                    "de": self.tiers[req.de[0]].resident_prefix(hit_refs) * bt,
                }
            self.sched.choose_read_path(
                req, tier_tokens=tier_tokens,
                net_congestion=self.net.congestion())
            if req.dram_tokens:
                # serve the resident prefix from the tier side's DRAM and
                # pin it for the round (in-flight blocks never evicted)
                node = (req.pe if req.dram_side == "pe" else req.de)[0]
                prefix = hit_refs[:req.dram_tokens // bt]
                self.tiers[node].serve(prefix, now=self.loop.now)
                self.tiers[node].pin(prefix)
                rs.tier_pinned = (node, prefix)
        load_legs = [leg for leg in self._request_legs(req)
                     if leg.phase == "load" and leg.nbytes > 0]
        # tier-hit legs move no new bytes (the data already sits in that
        # node's DRAM buffer): charge the accounting resource and drop
        # them from the SNIC work list
        snic_legs = []
        for leg in load_legs:
            if leg.name.endswith("_tier_hit"):
                rs.charge(leg)
            else:
                snic_legs.append(leg)
        # block-granular admission sets per side: the SNIC-read blocks
        # warm the reading node's tier when one is configured
        admit_refs = {"pe": [], "de": []}
        tokens = req.read_tokens_by_side()
        if self.tiers and hit_refs:
            part = req.hit_blocks_by_side(len(hit_refs))
            lo = part["tier"]
            admit_refs["pe"] = hit_refs[lo:lo + part["pe"]]
            admit_refs["de"] = hit_refs[lo + part["pe"]:]
        # an SSM/hybrid state blob is one opaque snapshot — it cannot be
        # partitioned, so it rides the majority side's storage NIC
        extra = self.model.ssm_state_bytes
        major = "pe" if req.pe_read_frac >= 0.5 else "de"
        rid = req.rid
        rs.read_recs = []
        if not snic_legs:
            # no SNIC bytes to read (pure-SSM models, or the whole hit
            # was served from the DRAM tier): release the read_q charge
            # on both sides, then complete (after the blob read, if any)
            for side, engine in (("pe", req.pe), ("de", req.de)):
                if tokens[side]:
                    rs.read_recs.append(
                        {"side": side, "engine": engine, "entry": None,
                         "refs": [], "release": tokens[side],
                         "done": False, "job": None})

            def finish(rs=rs):
                if rs.req.rid != rid:
                    return              # round re-homed after a death
                for rec in rs.read_recs:
                    if not rec["done"]:
                        rec["done"] = True
                        self.sched.on_read_done(rec["engine"],
                                                rec["release"])
                self._read_done(rs)

            if extra > 0:
                node = (req.pe if major == "pe" else req.de)[0]
                brec = {"side": major,
                        "engine": req.pe if major == "pe" else req.de,
                        "entry": None, "refs": [], "release": 0,
                        "done": False, "job": None}
                rs.read_recs.append(brec)
                brec["job"] = self.snic[node].enqueue(
                    extra, finish, tag="blob", rank=self._read_rank(req))
                return
            finish()
            return
        leg_sides = {("pe" if "pe_snic" in leg.resources else "de")
                     for leg in snic_legs}
        # the blob rides the majority side's SNIC; when the tier served
        # that side's whole hit there is no leg to piggyback on, so it
        # gets its own FIFO entry (its bytes must never vanish)
        blob_alone = extra > 0 and major not in leg_sides
        rs.read_pending = [len(snic_legs) + (1 if blob_alone else 0)]

        if blob_alone:
            node = (req.pe if major == "pe" else req.de)[0]
            brec = {"side": major,
                    "engine": req.pe if major == "pe" else req.de,
                    "entry": None, "refs": [], "release": 0,
                    "done": False, "job": None}
            rs.read_recs.append(brec)
            brec["job"] = self.snic[node].enqueue(
                extra, lambda: self._read_leg_done(rs, brec), tag="blob",
                rank=self._read_rank(req))
        for leg in snic_legs:
            side = "pe" if "pe_snic" in leg.resources else "de"
            engine = req.pe if side == "pe" else req.de
            nbytes = leg.nbytes + \
                (extra if side == major and not blob_alone else 0)
            rs.charge(leg)
            self.snic_hit_read_bytes += leg.nbytes
            entry = [side, nbytes, -1.0, -1.0]
            rs.read_legs.append(entry)
            rec = {"side": side, "engine": engine, "entry": entry,
                   "refs": admit_refs[side], "release": tokens[side],
                   "done": False, "job": None}
            rs.read_recs.append(rec)
            rec["job"] = self.snic[engine[0]].enqueue(
                nbytes, lambda rec=rec: self._read_leg_done(rs, rec),
                read=True,
                on_start=lambda t, entry=entry: entry.__setitem__(2, t),
                factor=(self.faults.leg_factor(rid, side)
                        if self.faults is not None else 1.0),
                rank=self._read_rank(req))
        if extra > 0:
            rs.hedged = True    # opaque blob rides a leg: byte-exact
            #                     remainder accounting impossible
        elif (self.cfg.hedge_reads and self.faults is not None
                and self.cfg.mode == "dualpath"):
            # timer covers the single-leg case, where no sibling
            # completion event re-evaluates the straggler
            self.loop.after(self.cfg.hedge_threshold_s,
                            lambda: self._maybe_hedge(rs, rid))

    def _read_leg_done(self, rs: RoundSim, rec: dict):
        """One storage leg landed: release its read_q charge, warm the
        reading node's tier with its blocks, and complete the load phase
        once every leg (original or hedged remainder) is in."""
        rec["done"] = True
        if rec["entry"] is not None:
            rec["entry"][3] = self.loop.now
            if self.tracer is not None and rec["entry"][2] >= 0:
                e = rec["entry"]
                self.tracer.span(f"req/{rs.req.rid}", "read_leg",
                                 e[2], e[3], side=e[0], nbytes=e[1])
        self.sched.on_read_done(rec["engine"], rec["release"])
        tier = self.tiers.get(rec["engine"][0])
        if tier is not None:
            now = self.loop.now
            for ref in rec["refs"]:
                tier.admit(ref, self.block_bytes, owner=rs.traj.tid,
                           now=now)
        rs.read_pending[0] -= 1
        if rs.read_pending[0] == 0:
            self._read_done(rs)
        elif self.cfg.hedge_reads:
            # a sibling leg is still out: the classic hedge moment
            self._maybe_hedge(rs, rs.req.rid)

    def _maybe_hedge(self, rs: RoundSim, rid: int):
        """Hedged split reads (tentpole): when exactly one storage leg
        is still in flight and it is *fault-slowed* relative to the
        healthy side (observed service-time factors, not queue depth —
        issue-time water-filling already balanced load), re-water-fill
        the unserved remainder onto the healthy side's NIC.

        Byte-exact by construction: the straggling FIFO job is shrunk
        by exactly the moved bytes, a new job for exactly those bytes is
        enqueued on the healthy NIC, and Scheduler.rebalance_remainder
        moves the same tokens between the authoritative per-side
        partition and the read_q charges.  Tier-hit bytes never appear
        here (they are not SNIC work and not movable)."""
        if (not self.cfg.hedge_reads or self.faults is None or rs.hedged
                or rs.req.rid != rid or rs.read_done_t >= 0
                or not rs.read_recs or not self.kv_per_token):
            return
        live = [rec for rec in rs.read_recs if not rec["done"]]
        if len(live) != 1:
            return
        rec = live[0]
        job = rec["job"]
        if job is None or job.state not in ("queued", "serving"):
            return
        req = rs.req
        s = rec["side"]
        h = "de" if s == "pe" else "pe"
        h_engine = req.pe if h == "pe" else req.de
        s_nic = self.snic[rec["engine"][0]]
        h_nic = self.snic[h_engine[0]]
        now = self.loop.now
        # observed straggle: the leg's own draw x the SNIC window it is
        # (or would be) served under, relative to the healthy side
        t_ref = job.t_start if job.state == "serving" else now
        f_s = job.factor * self.faults.snic_factor(s_nic.node, t_ref)
        f_h = self.faults.leg_factor(rid, h) * \
            self.faults.snic_factor(h_nic.node, now)
        severity = f_s / max(f_h, 1e-12)
        if severity < self.cfg.hedge_min_severity:
            return
        rem_bytes = s_nic.remaining_bytes(job, now)
        # whole unserved tokens only, never beyond the side's charged
        # SNIC share (the partition the remainder is carved from)
        rem_tok = min(int(rem_bytes // self.kv_per_token),
                      req.read_tokens_by_side()[s])
        if rem_tok <= 0:
            return
        # not worth a second queue entry if the straggler is nearly done
        if rem_bytes * f_s / s_nic.bw < self.cfg.hedge_threshold_s:
            return
        moved = self.sched.rebalance_remainder(
            req, s, rem_tok, severity,
            healthy_backlog_tokens=h_nic.queue_tokens(self.kv_per_token))
        if moved <= 0:
            return
        rs.hedged = True
        self.hedged_reads += 1
        self.hedge_moved_tokens += moved
        moved_bytes = moved * self.kv_per_token
        got = s_nic.shrink(job, moved_bytes)
        assert got == moved_bytes, (got, moved_bytes)
        rec["release"] -= moved
        if rec["entry"] is not None:
            rec["entry"][1] -= moved_bytes
        # the straggler serves front-to-back, so its unserved tail —
        # including its trailing admit blocks — is what moves
        bt = self.cfg.block_tokens
        m_blk = min(len(rec["refs"]), moved // bt) if bt else 0
        moved_refs = rec["refs"][-m_blk:] if m_blk else []
        if m_blk:
            del rec["refs"][-m_blk:]
        # byte-exact re-charge: the moved bytes now traverse the healthy
        # side's SNIC + DRAM instead of the straggler's
        for res_s, res_h in ((f"{s}_snic", f"{h}_snic"),
                             (f"{s}_dram", f"{h}_dram")):
            rs.charged[res_s] = rs.charged.get(res_s, 0) - moved_bytes
            rs.charged[res_h] = rs.charged.get(res_h, 0) + moved_bytes
        entry = [h, moved_bytes, -1.0, -1.0]
        rs.read_legs.append(entry)
        hrec = {"side": h, "engine": h_engine, "entry": entry,
                "refs": moved_refs, "release": moved, "done": False,
                "job": None}
        rs.read_recs.append(hrec)
        rs.read_pending[0] += 1
        hrec["job"] = h_nic.enqueue(
            moved_bytes, lambda: self._read_leg_done(rs, hrec), read=True,
            on_start=lambda t, entry=entry: entry.__setitem__(2, t),
            factor=self.faults.leg_factor(rid, h),
            rank=self._read_rank(rs.req))

    def _read_rank(self, req: Request) -> int:
        """SNIC-queue rank of a demand read: the request's class rank
        when class-aware, the neutral 1 (pure FIFO) otherwise.  The
        class-aware SLO layer must reach the storage NIC queue — under
        prefill overload an interactive round's TTFT is dominated by
        its KV read waiting behind multi-GB batch reads, not by the
        scheduler's global queue."""
        return req.class_rank if self.cfg.slo.class_aware else 1

    def _read_done(self, rs: RoundSim):
        rs.read_done_t = self.loop.now
        if self.tracer is not None:
            # the pre-read span: submission up to the first leg's
            # service start (pure wait — attribution's queue residual)
            starts = [rec["entry"][2] for rec in (rs.read_recs or [])
                      if rec["entry"] is not None
                      and rec["entry"][2] >= 0]
            self.tracer.span(f"req/{rs.req.rid}", "scheduled",
                             rs.submit_t,
                             min(starts) if starts else self.loop.now)
        req = rs.req
        pe = self.engines[req.pe]
        work = PrefillWork(req.rid, req.cached_tokens, req.new_tokens,
                           rank=req.class_rank, arrival=req.arrival)
        if self.cfg.slo.class_aware:
            pe.fifo.insert(class_insert_index([w.key() for w in pe.fifo],
                                              work.key()), work)
        else:
            pe.fifo.append(work)
        rs.prefill_left = req.new_tokens
        if self.cfg.layerwise:
            # layerwise streaming + PD transfer legs overlap the prefill
            self._launch_transfer_flows(rs)
        self._wake_pe_group(pe.group)
        self._kick_scheduler()

    # ------------------------------------------------------------------
    # transfer flows (loading plans, minus the storage leg handled above)
    # ------------------------------------------------------------------
    def _request_legs(self, req: Request) -> List[Leg]:
        """The loading-plan legs this request executes.  One dispatch
        point (core/loading.plan_for) shared with the engines and the
        property tests, so the sim's byte accounting is the plan's byte
        accounting by construction — including split plans, whose two
        load legs charge both snic resources concurrently."""
        if self.cfg.mode == "oracle":
            return []
        hit = req.cached_tokens * self.kv_per_token
        miss = req.new_tokens * self.kv_per_token
        if self.cfg.mode == "basic":
            return PLANS["basic"](hit, miss, 0)
        return plan_for(req.read_path, req.read_split, hit, miss, 0,
                        tier=req.hit_bytes_partition(self.kv_per_token))

    def _resmap(self, req: Request):
        (pn, pr), (dn, dr) = req.pe, req.de
        return {
            "pe_snic": None, "de_snic": None,  # handled by FIFO server
            "pe_dram": self.dram[pn], "de_dram": self.dram[dn],
            "pe_cnic_rd": self.cnic_rd[(pn, pr)],
            "pe_cnic_wr": self.cnic_wr[(pn, pr)],
            "de_cnic_rd": self.cnic_rd[(dn, dr)],
            "de_cnic_wr": self.cnic_wr[(dn, dr)],
            "net": self.net,
        }

    def _traced_leg_cb(self, rid: int, leg_name: str, nbytes: float,
                       cb: Callable) -> Callable:
        """Wrap a flow-completion callback with a ``pd_transfer`` span
        on the request's track (no-op passthrough when untraced)."""
        if self.tracer is None:
            return cb
        t0 = self.loop.now

        def done():
            self.tracer.span(f"req/{rid}", "pd_transfer", t0,
                             self.loop.now, leg=leg_name, nbytes=nbytes)
            cb()

        return done

    def _launch_transfer_flows(self, rs: RoundSim):
        if self.cfg.mode == "oracle":
            rs.transfer_done = True
            return
        req = rs.req
        legs = [leg for leg in self._request_legs(req) if leg.layerwise]
        rmap = self._resmap(req)
        pending = [len(legs)]
        if not legs:
            rs.transfer_done = True
            return

        def leg_done():
            pending[0] -= 1
            if pending[0] == 0:
                rs.transfer_done = True
                self._maybe_to_decode(rs)

        for leg in legs:
            rs.charge(leg)
            rs.flows.append(
                self._flow(leg.nbytes, [rmap[r] for r in leg.resources],
                           self._traced_leg_cb(req.rid, leg.name,
                                               leg.nbytes, leg_done),
                           tclass=leg.tclass))

    # ------------------------------------------------------------------
    # PE group stepping
    # ------------------------------------------------------------------
    def _wake_pe_group(self, gid: int):
        if self._pe_stepping[gid]:
            return
        self._pe_stepping[gid] = True
        self.loop.after(0.0, lambda: self._pe_step(gid))

    def _pe_step(self, gid: int):
        # a role flip can dissolve the group between wake and step
        members = self.pe_groups.get(gid, [])
        if not any(e.fifo for e in members):
            self._pe_stepping[gid] = False
            return
        t_max, attns = 0.0, []
        work: List[Tuple[_EngineSim, list]] = []
        kv_cap = None
        if not self.cfg.layerwise and self.kv_per_token:
            kv_cap = int(self.cfg.node.gpu.hbm_bytes * self.cfg.kv_hbm_frac /
                         self.kv_per_token)
        for e in members:
            batch = e.packer.pack(e.fifo)
            if batch and kv_cap is not None:
                # without layerwise prefill the whole batch's prompt KV
                # must reside in HBM: truncate to capacity (>=1 item)
                kept, resid = [], 0
                for bi in batch:
                    resid += bi.cached + bi.bsz
                    if kept and resid > kv_cap:
                        # push back unprocessed work
                        rq = self._by_rid[bi.rid].req
                        e.fifo.insert(0, PrefillWork(bi.rid, bi.cached,
                                                     bi.bsz,
                                                     rank=rq.class_rank,
                                                     arrival=rq.arrival))
                        continue
                    kept.append(bi)
                batch = kept
            if not batch:
                attns.append(0.0)
                continue
            items = [(bi.cached, bi.bsz) for bi in batch]
            a_fl = attn_flops_sim(self.model, items)
            lin = self.model.linear_flops_per_token() * \
                sum(b for _, b in items)
            eff = self.cfg.node.gpu.flops * self.cfg.node.gpu.mfu_prefill
            t_e = (a_fl + lin) / eff
            attns.append(a_fl / eff)
            t_max = max(t_max, t_e)
            work.append((e, batch))
        pos = [a for a in attns if a > 0]
        if pos and len(pos) > 1:
            self.attn_balance.append((self.loop.now,
                                      max(pos) / (sum(pos) / len(pos))))
        if t_max <= 0:
            self._pe_stepping[gid] = False
            return
        step_tokens = sum(bi.bsz for _, batch in work for bi in batch)
        t0 = self.loop.now
        self._step_barrier(t_max, self.coll_model.step_bytes(step_tokens),
                           lambda: self._pe_step_done(gid, work, t0))

    def _step_barrier(self, t_compute: float, coll_bytes: float,
                      done: Callable):
        """Complete a group step after BOTH its compute time and its
        model collectives (a Flow on the shared compute network,
        MODEL_COLLECTIVE class).  Any time the collectives finish after
        the compute is interference — the step stalls on communication —
        and is recorded as ``collective_stall_s``: ≈ 0 under the VL
        arbiter (collectives own ~99 % of a contended link), nonzero
        under FIFO sharing once KV transfer load builds up."""
        if not self._collectives_on or coll_bytes <= 0:
            self.loop.after(t_compute, done)
            return
        t0 = self.loop.now
        pending = [2]

        def arm():
            pending[0] -= 1
            if pending[0] == 0:
                self.collective_stall_s += max(
                    0.0, self.loop.now - (t0 + t_compute))
                done()

        self.loop.after(t_compute, arm)
        self._flow(coll_bytes, [self.net], arm,
                   tclass=TrafficClass.MODEL_COLLECTIVE)

    def _pe_step_done(self, gid, work, t0):
        for e, batch in work:
            for bi in batch:
                rs = self._round_by_rid(bi.rid)
                if rs is None:
                    # the round was re-homed (engine death) after this
                    # step launched: its new incarnation re-prefills
                    # from scratch, so the stale batch item is dropped
                    continue
                if self.tracer is not None:
                    self.tracer.span(f"req/{bi.rid}", "prefill", t0,
                                     self.loop.now, engine=list(e.eid),
                                     tokens=bi.bsz)
                if bi.chunked:
                    # partial slice (quota straddler or SloConfig chunk
                    # cap) — the sim's PREFILL_CHUNKED sub-state: more
                    # slices of this round follow in later batches
                    self.prefill_chunks += 1
                rs.prefill_left -= bi.bsz
                self.prompt_tokens_done += bi.bsz
                if rs.prefill_left <= 0 and rs.prefill_done_t < 0:
                    rs.prefill_done_t = self.loop.now
                    if self.tracer is not None:
                        # TTFT's endpoint in both runtimes: the first
                        # output token is ready when prefill completes
                        self.tracer.event(f"req/{bi.rid}", "first_token")
                    self.sched.on_request_done(rs.req.pe, rs.req)
                    if not self.cfg.layerwise and not rs.transfer_done:
                        # no layerwise streaming: transfers run after the
                        # forward pass instead of overlapping it
                        self._launch_transfer_flows(rs)
                    self._maybe_to_decode(rs)
        self.tps_samples.append((self.loop.now, self.prompt_tokens_done,
                                 self.gen_tokens_done))
        # keep stepping
        self._pe_stepping[gid] = False
        self._wake_pe_group(gid)
        self._kick_scheduler()

    def _round_by_rid(self, rid):
        return self._by_rid.get(rid)

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------
    def _maybe_to_decode(self, rs: RoundSim):
        if rs.prefill_done_t < 0 or not rs.transfer_done or rs.h2d_done:
            return
        if self.cfg.mode == "oracle":
            self._h2d_done(rs)
            return
        req = rs.req
        rmap = self._resmap(req)
        legs = [leg for leg in self._request_legs(req)
                if leg.phase == "decode_start"]
        if not legs:
            # the basic plan writes PE HBM -> DE HBM directly (no
            # decode_start leg); the sim still stages decode start
            # through DE DRAM like real PD-disaggregated systems do
            full = req.prompt_tokens * self.kv_per_token
            (dn, dr) = req.de
            rs.charge(Leg("de_h2d", full,
                          ("de_cnic_rd", "de_cnic_wr", "de_dram")))
            rs.flows.append(
                self._flow(full,
                           [self.cnic_rd[(dn, dr)], self.cnic_wr[(dn, dr)],
                            self.dram[dn]],
                           self._traced_leg_cb(req.rid, "de_h2d", full,
                                               lambda: self._h2d_done(rs))))
            return
        pending = [len(legs)]

        def leg_done():
            pending[0] -= 1
            if pending[0] == 0:
                self._h2d_done(rs)

        for leg in legs:
            rs.charge(leg)
            rs.flows.append(
                self._flow(leg.nbytes, [rmap[r] for r in leg.resources],
                           self._traced_leg_cb(req.rid, leg.name,
                                               leg.nbytes, leg_done),
                           tclass=leg.tclass))

    def _h2d_done(self, rs: RoundSim):
        rs.h2d_done = True
        e = self.engines[rs.req.de]
        e.active_decode.append(rs)
        self._wake_de_group(e.group)

    def _wake_de_group(self, gid: int):
        if self._de_stepping[gid]:
            return
        self._de_stepping[gid] = True
        self.loop.after(0.0, lambda: self._de_step(gid))

    def _de_step(self, gid: int):
        # a role flip can dissolve the group between wake and step
        members = self.de_groups.get(gid, [])
        active = [e for e in members if e.active_decode]
        if not active:
            self._de_stepping[gid] = False
            return
        # block length: 1 until every new seq has emitted its 2nd token
        block = self.cfg.decode_block
        if any(r.tokens_out < 2 for e in active for r in e.active_decode):
            block = 1
        block = min(block, min(r.gen_left for e in active
                               for r in e.active_decode))
        gpu = self.cfg.node.gpu
        t_max = 0.0
        for e in active:
            kv_bytes = sum(self.model.decode_step_bytes(r.ctx)
                           for r in e.active_decode)
            w_bytes = self.model.active_param_bytes_resident(
                self.de_group_size)
            step_bytes = kv_bytes + w_bytes
            step_flops = sum(self.model.decode_step_flops(r.ctx)
                             for r in e.active_decode)
            t_step = max(step_bytes / (gpu.hbm_bw * gpu.mbu_decode),
                         step_flops / (gpu.flops * gpu.mfu_prefill))
            t_max = max(t_max, t_step * block)
        step_tokens = block * sum(len(e.active_decode) for e in active)
        self._step_barrier(t_max, self.coll_model.step_bytes(step_tokens),
                           lambda: self._de_step_done(gid, block))

    def _de_step_done(self, gid: int, block: int):
        members = self.de_groups.get(gid, [])
        persist_bytes: Dict[int, int] = defaultdict(int)
        for e in members:
            done = []
            for r in e.active_decode:
                if r.first_decode_t < 0:
                    r.first_decode_t = self.loop.now
                r.tokens_out += block
                if r.tokens_out >= 2 and r.second_token_t < 0:
                    r.second_token_t = self.loop.now
                r.gen_left -= block
                r.ctx += block
                self.gen_tokens_done += block
                persist_bytes[e.node] += block * self.kv_per_token
                if r.gen_left <= 0:
                    done.append(r)
            for r in done:
                e.active_decode.remove(r)
                e.resident_tokens -= r.req.hbm_tokens
                self.sched.on_request_done(r.req.de, r.req)
                r.done_t = self.loop.now
                self._round_finished(r, e.node)
        if self.cfg.mode != "oracle":
            for node, nb in persist_bytes.items():
                # miss-token KV persists ride along with generated blocks
                self.snic[node].enqueue(nb, lambda: None, read=False)
        self._de_stepping[gid] = False
        self._wake_de_group(gid)
        self._kick_scheduler()

    def _round_finished(self, rs: RoundSim, de_node: int):
        """Round completion: release tier pins, warm the DE node's tier
        with the round's full context (every one of those blocks staged
        through DE DRAM on its way to HBM / storage), then enter the
        agent's think-time window — the idle gap the prefetcher uses to
        stage the *next* round's predicted hit — before submitting the
        next round."""
        agent, traj = rs.agent, rs.traj
        tid = traj.tid
        now = self.loop.now
        if self.tracer is not None and rs.first_decode_t >= 0:
            self.tracer.span(f"req/{rs.req.rid}", "decode",
                             rs.first_decode_t, rs.done_t,
                             tokens=rs.tokens_out)
        if rs.tier_pinned is not None:
            node, refs = rs.tier_pinned
            self.tiers[node].unpin(refs)
            rs.tier_pinned = None
        agent.next_round += 1
        i = agent.next_round
        if i >= traj.n_rounds:
            # finished trajectory: its blocks will never be hit again
            # (§A.4) — no warm-up (it would only evict live agents'
            # prefixes), just release the owner for eager reclamation
            for t in self.tiers.values():
                t.note_done(tid)
            self._submit_round(agent)     # records end_t
            return
        tier = self.tiers.get(de_node)
        if tier is not None:
            bt = self.cfg.block_tokens
            ctx = rs.req.prompt_tokens + rs.req.gen_tokens
            # tail-first admission: the LEADING blocks end up most
            # recent, so LRU pressure evicts the context tail first and
            # the resident-prefix (the only thing a round can serve)
            # survives — head-first order would evict block 0 first and
            # collapse the prefix to zero under any pressure
            for b in reversed(range(ctx // bt)):
                tier.admit((tid, b), self.block_bytes, owner=tid, now=now)
        think = traj.rounds[i].think
        if think > 0:
            if self.prefetcher is not None:
                self._schedule_prefetch(agent, de_node, think)
            self.loop.after(think, lambda a=agent: self._submit_round(a))
        else:
            self._submit_round(agent)

    def _schedule_prefetch(self, agent: AgentSim, node: int, think: float):
        """Think-time prefetch: stage the next round's predicted hit
        blocks (the trajectory's current context — exactly what the trie
        will match) into the previous decode node's DRAM tier.

        Fired *late* in the think window — just early enough to restage
        the whole hit at SNIC bandwidth (with slack) — so it repairs the
        evictions other trajectories inflicted during the gap instead of
        re-admitting what the round-end warm-up already left resident.
        Staged and already-resident predicted blocks are pinned (a
        lease) until the round submits, so a prefetch cannot itself be
        evicted before it pays off."""
        tier = self.tiers.get(node)
        if tier is None:
            return
        traj = agent.traj
        tid = traj.tid
        i = agent.next_round
        cached = traj.context_before(i)
        n_refs = cached // self.cfg.block_tokens
        if n_refs == 0:
            return
        stage_s = n_refs * self.block_bytes / self.cfg.node.snic_bw
        delay = max(0.0, min(think - 1.25 * stage_s, 0.9 * think))

        def issue(agent=agent, tier=tier, node=node, tid=tid, i=i):
            if agent.next_round != i or agent.prefetch_pinned is not None:
                return                       # stale wake-up
            refs = [(tid, b) for b in range(n_refs)]
            pinned: List = []
            resident = refs[:tier.resident_prefix(refs)]
            # extend the lease over blocks already resident...
            tier.pin(resident)
            pinned.extend(resident)
            agent.prefetch_pinned = (node, pinned)
            # ...and stage the missing ones in order, chunk by chunk,
            # bounded by what the tier could actually hold (free +
            # evictable bytes) — staging reads the tier must drop would
            # burn exactly the SNIC bandwidth prefetch exists to save
            budget = int((tier.capacity_bytes - tier.pinned_bytes()) //
                         max(self.block_bytes, 1))
            for chunk in self.prefetcher.plan(tier, refs):
                chunk = chunk[:budget]
                if not chunk:
                    break
                budget -= len(chunk)
                nbytes = len(chunk) * self.block_bytes

                def staged(chunk=chunk):
                    now = self.loop.now
                    # lease still open? (a chunk can drain from the FIFO
                    # after the round already submitted — still admit,
                    # but don't pin past the lease)
                    lease = agent.prefetch_pinned is not None and \
                        agent.prefetch_pinned[1] is pinned
                    for ref in chunk:
                        if tier.admit(ref, self.block_bytes, owner=tid,
                                      now=now, prefetch=True) and lease:
                            tier.pin([ref])
                            pinned.append(ref)

                self.snic[node].enqueue(nbytes, staged, read=True,
                                        prefetch=True)

        self.loop.after(delay, issue)

    # ------------------------------------------------------------------
    # metrics
    # ------------------------------------------------------------------
    def round_metrics(self) -> list:
        """The rounds' timing as serving RoundMetrics, so the serving
        layer's estimators (latency_summary / slo_attainment) apply to
        simulator output unchanged — one percentile/SLO definition for
        both runtimes (pinned by tests/test_metrics_regression.py)."""
        from repro.serving.events import RoundMetrics
        return [RoundMetrics(rid=rs.req.rid, gen_tokens=rs.gen_total,
                             submit_t=rs.submit_t,
                             read_done_t=rs.read_done_t,
                             prefill_done_t=rs.prefill_done_t,
                             first_decode_t=rs.first_decode_t,
                             second_token_t=rs.second_token_t,
                             done_t=rs.done_t,
                             slo_class=rs.req.slo_class)
                for rs in self.rounds]

    def slo_attainment(self, ttft_slo_s: float = 4.0,
                       tpot_slo_s: float = 0.050) -> float:
        """Fraction of finished rounds meeting both SLOs (paper §7.4
        defaults), via the serving layer's shared estimator."""
        from repro.serving.events import slo_attainment
        return slo_attainment(self.round_metrics(), ttft_slo_s, tpot_slo_s)

    def results(self) -> dict:
        from repro.serving.events import latency_by_class
        done_rounds = [r for r in self.rounds if r.done_t >= 0]
        jcts = [a.end_t - a.start_t for a in self.agents if a.end_t >= 0]
        ttfts = [r.prefill_done_t - r.submit_t for r in done_rounds]
        ttsts = [r.second_token_t - r.submit_t for r in done_rounds
                 if r.second_token_t >= 0]
        tpots = [(r.done_t - r.first_decode_t) / max(r.gen_total - 1, 1)
                 for r in done_rounds if r.gen_total > 1]
        import numpy as np
        pct = lambda xs, q: float(np.percentile(xs, q)) if xs else float("nan")
        mean = lambda xs: float(np.mean(xs)) if xs else float("nan")
        tiers = list(self.tiers.values())
        dram_hit = sum(t.dram_hit_bytes for t in tiers)
        denom = dram_hit + self.snic_hit_read_bytes
        return conforming(dict(
            finished_agents=len(jcts),
            finished_rounds=len(done_rounds),
            jct_mean=mean(jcts), jct_max=max(jcts) if jcts else float("nan"),
            ttft_mean=mean(ttfts), ttft_p99=pct(ttfts, 99),
            ttst_mean=mean(ttsts), tpot_mean=mean(tpots),
            tpot_p99=pct(tpots, 99),
            sim_time=self.loop.now,
            prompt_tokens=self.prompt_tokens_done,
            gen_tokens=self.gen_tokens_done,
            # --- DRAM tier (kvcache/tiers.py; zeros when disabled) -----
            dram_hit_bytes=dram_hit,
            snic_hit_read_bytes=self.snic_hit_read_bytes,
            dram_hit_ratio=(dram_hit / denom) if denom else 0.0,
            tier_prefetch_bytes=sum(t.prefetch_bytes for t in tiers),
            tier_evicted_bytes=sum(t.evicted_bytes for t in tiers),
            tier_evictions=sum(t.evictions for t in tiers),
            # --- finite compute network (repro.network; zeros when the
            # link is infinite — the legacy no-congestion configuration)
            collective_stall_s=self.collective_stall_s,
            transfer_backlog_s=self.net.transfer_backlog_s,
            net_collective_delay_s=self.net.collective_delay_s,
            net_collective_bytes=self.net.bytes_by_class.get(
                TrafficClass.MODEL_COLLECTIVE, 0.0),
            net_kv_bytes=self.net.bytes_by_class.get(
                TrafficClass.KV_TRANSFER, 0.0),
            net_contended_joins=self.net.contended_joins,
            # --- elastic reconfiguration (core/autoscale.py; zeros when
            # elastic is off — the static-topology configuration) -------
            role_changes=self.drains.n_flips,
            role_changes_by_direction=self.drains.flips_by_direction(),
            reconfig_drain_s=self.drains.drain_seconds(),
            reconfig_weight_bytes=self.reconfig_weight_bytes,
            tier_handoff_bytes=self.drains.tier_handoff_bytes(),
            n_pe_final=sum(1 for e in self.engines.values()
                           if e.kind == "pe"),
            n_de_final=sum(1 for e in self.engines.values()
                           if e.kind == "de"),
            # --- faults / hedged reads / recovery (sim/faults.py; zeros
            # when no schedule is injected) -----------------------------
            engine_deaths=len(self.dead_engines),
            recovered_rounds=self.recovered_rounds,
            hedged_reads=self.hedged_reads,
            hedge_moved_tokens=self.hedge_moved_tokens,
            # --- online SLO layer (core/config.SloConfig; admitted ==
            # submitted rounds and deferred/rejected are 0 when the
            # admission gate is off) ------------------------------------
            admitted_rounds=(self.gate.admitted_rounds
                             if self.gate is not None else len(self.rounds)),
            deferred_rounds=(self.gate.deferred_rounds
                             if self.gate is not None else 0),
            rejected_rounds=(self.gate.rejected_rounds
                             if self.gate is not None else 0),
            prefill_chunks=self.prefill_chunks,
            latency_by_class=latency_by_class(self.round_metrics()),
        ), "sim")


class _NicJob:
    """One FIFO entry on a storage NIC — a first-class handle so hedged
    reads can shrink it mid-flight and fault recovery can abort it."""

    __slots__ = ("nbytes", "cb", "read", "on_start", "prefetch", "factor",
                 "t_start", "rate", "version", "state", "tag", "rank")

    def __init__(self, nbytes, cb, read, on_start, prefetch, factor,
                 tag="", rank=1):
        # SLO-class rank (scheduler.Request.class_rank): only demand
        # reads of interactive rounds carry 0; all other traffic stays
        # at the neutral 1, so a non-class-aware run is pure FIFO
        self.rank = rank
        self.nbytes = nbytes
        self.cb = cb
        self.read = read
        self.on_start = on_start
        self.prefetch = prefetch
        # trace label for the NIC-span audit: demand "read" vs "blob" /
        # "weights" / "persist" / "prefetch" (derived in enqueue)
        self.tag = tag
        # per-job service-time multiplier (straggler draw); SNIC window
        # factors compose with it at service start
        self.factor = factor
        self.t_start = -1.0
        self.rate = 0.0
        self.version = 0        # bumped on shrink/abort to void the
        #                         completion event already in the heap
        self.state = "queued"   # queued | serving | done | cancelled


class _FifoNic:
    """Per-node storage NIC: serial FIFO server with byte accounting.

    Tracks reads (KV loads) and writes (block persists) separately so
    tests can pin the read totals against the loading-plan snic sums,
    and reports service start via ``on_start`` so split-read tests can
    assert two NICs were busy concurrently on one request.

    Fault semantics: a job's effective rate is fixed at service start —
    ``bw / (job.factor * FaultSchedule.snic_factor(node, t_start))`` —
    so degradation windows apply to jobs *starting* inside them (the
    granularity the chaos suite pins).  With no faults the arithmetic
    is bit-identical to the pre-fault server (``rate == bw`` exactly)."""

    def __init__(self, sim: Sim, node: int, bw: float):
        self.sim = sim
        self.node = node
        self.bw = bw
        self.queue: deque = deque()
        self.busy = False
        self.current: Optional[_NicJob] = None
        self.queued_bytes = 0
        self.total_bytes = 0
        self.read_bytes = 0
        self.write_bytes = 0
        self.prefetch_bytes = 0
        self.samples: List[Tuple[float, float]] = []   # (t_done, bytes)

    def queue_tokens(self, kv_per_token: float) -> int:
        if kv_per_token <= 0:
            return 0
        return int(self.queued_bytes / kv_per_token)

    def enqueue(self, nbytes: float, on_done, read=True, on_start=None,
                prefetch=False, factor: float = 1.0,
                tag: str = "", rank: int = 1) -> _NicJob:
        if not tag:
            tag = "prefetch" if prefetch else ("read" if read
                                               else "persist")
        job = _NicJob(nbytes, on_done, read, on_start, prefetch, factor,
                      tag, rank)
        if rank < 1 and any(j.rank > rank for j in self.queue):
            # class-aware: an interactive demand read overtakes queued
            # lower-priority traffic (stable among equals; the job in
            # service is never preempted)
            idx = next(i for i, j in enumerate(self.queue)
                       if j.rank > rank)
            self.queue.insert(idx, job)
        else:
            self.queue.append(job)
        self.queued_bytes += nbytes
        if not self.busy:
            self._serve()
        return job

    def _serve(self):
        if not self.queue:
            self.busy = False
            self.current = None
            return
        self.busy = True
        job = self.queue.popleft()
        self.current = job
        job.state = "serving"
        now = self.sim.loop.now
        job.t_start = now
        if job.on_start is not None:
            job.on_start(now)
        f = job.factor
        faults = self.sim.faults
        if faults is not None:
            f *= faults.snic_factor(self.node, now)
        job.rate = self.bw if f == 1.0 else self.bw / f
        v = job.version
        self.sim.loop.after(job.nbytes / job.rate,
                            lambda: self._complete(job, v))

    def _complete(self, job: _NicJob, version: int):
        if job.version != version or job.state != "serving":
            return              # voided by a shrink/abort
        job.state = "done"
        nbytes = job.nbytes
        self.queued_bytes -= nbytes
        self.total_bytes += nbytes
        if job.prefetch:
            # think-time staging reads — separated from demand reads
            # so round-start SNIC traffic stays directly observable
            self.prefetch_bytes += nbytes
        elif job.read:
            self.read_bytes += nbytes
        else:
            self.write_bytes += nbytes
        self.samples.append((self.sim.loop.now, nbytes))
        tr = self.sim.tracer
        if tr is not None:
            # one span per completed FIFO job, with the same float the
            # byte counters just accumulated — obs.audit pins the sums
            # equal, so a dropped or double-emitted span is an error
            tr.span(f"snic/node{self.node}", "nic_xfer", job.t_start,
                    self.sim.loop.now, tag=job.tag, nbytes=nbytes)
            tr.counter(f"snic/node{self.node}/queue",
                       queued_bytes=self.queued_bytes)
        if job.cb is not None:
            job.cb()
        self._serve()

    # -- hedged reads / fault recovery ---------------------------------
    def remaining_bytes(self, job: _NicJob, now: float) -> float:
        """Unserved bytes of ``job`` at ``now`` (0 once finished)."""
        if job.state == "serving":
            return max(0.0, job.nbytes - (now - job.t_start) * job.rate)
        if job.state == "queued":
            return job.nbytes
        return 0.0

    def shrink(self, job: _NicJob, delta: float) -> float:
        """Hedge: carve ``delta`` unserved bytes off the tail of the job
        (they will be served elsewhere).  The job keeps its callback and
        completes earlier at its reduced size; a queued job shrunk to
        nothing is unqueued and completes immediately having served
        zero bytes here.  Returns the bytes actually removed."""
        assert delta >= 0
        now = self.sim.loop.now
        if job.state == "serving":
            served = (now - job.t_start) * job.rate
            delta = min(delta, max(0.0, job.nbytes - served))
            job.nbytes -= delta
            self.queued_bytes -= delta
            job.version += 1
            v = job.version
            t_done = job.t_start + job.nbytes / job.rate
            self.sim.loop.after(max(t_done - now, 0.0),
                                lambda: self._complete(job, v))
            return delta
        if job.state == "queued":
            delta = min(delta, job.nbytes)
            job.nbytes -= delta
            self.queued_bytes -= delta
            if job.nbytes <= 0:
                self.queue.remove(job)
                job.state = "done"
                if job.cb is not None:
                    self.sim.loop.after(0.0, job.cb)
            return delta
        return 0.0

    def abort(self, job: _NicJob):
        """Fault recovery: drop the job.  Queued jobs vanish without a
        trace; an in-service job is truncated to the bytes already
        served (they were physically read and stay in the counters) and
        its callback is suppressed."""
        if job.state == "queued":
            self.queue.remove(job)
            self.queued_bytes -= job.nbytes
            job.state = "cancelled"
            job.cb = None
            return
        if job.state == "serving":
            served = (self.sim.loop.now - job.t_start) * job.rate
            delta = max(0.0, job.nbytes - served)
            job.nbytes -= delta
            self.queued_bytes -= delta
            job.cb = None
            job.version += 1
            v = job.version
            # complete immediately at the truncated size: the byte
            # accounting and FIFO hand-off reuse the normal path
            self.sim.loop.after(0.0, lambda: self._complete(job, v))


class _SimPacker(QuotaPacker):
    def __init__(self, model: ModelSimSpec, time_model: AttnTimeModel,
                 quota_s: float, chunk_tokens: Optional[int] = None):
        self.model = model
        self.time_model = time_model
        self.quota_s = quota_s
        self.min_chunk = 16
        self.chunk_tokens = None if chunk_tokens is None \
            else max(int(chunk_tokens), self.min_chunk)

    def predict_batch_seconds(self, items) -> float:
        return self.time_model.seconds(attn_flops_sim(self.model, items))


def attn_flops_sim(model: ModelSimSpec, items) -> float:
    tot = 0.0
    for cached, bsz in items:
        a = 4.0 * model.n_layers * model.n_heads * model.qk_head_dim * \
            bsz * (cached + (bsz + 1) / 2.0)
        if model.sparse_topk:
            a = min(a, 4.0 * model.n_layers * model.n_heads *
                    model.qk_head_dim * bsz * model.sparse_topk)
        tot += a
    return tot
