from repro.sim.faults import (
    EngineDeath,
    FaultSchedule,
    SlowdownWindow,
    StragglerModel,
)
from repro.sim.simulator import Sim, SimConfig
from repro.sim.spec import (
    DS_660B,
    HOPPER_NODE,
    QWEN25_32B,
    TPU_V5E_HOST,
    GPUSpec,
    ModelSimSpec,
    NodeSpec,
)
from repro.sim.traces import Trajectory, dataset_stats, generate_dataset
from repro.sim.vectorized import VectorSim, VectorSimUnsupported
