"""Checkpoint save/restore + fault-tolerant training runner.

Layout: one .npz per checkpoint holding every leaf (tree paths as keys)
+ a meta dict (step, config name, data-pipeline state).  Restore can
re-shard onto a different mesh (elastic restart: pods are DP replicas,
so losing a pod means restoring the same params with batch re-split —
the dry-run proves both meshes compile; see DESIGN.md §4).

``FaultTolerantRunner`` wraps a train loop with periodic checkpointing
and crash/resume semantics, property-tested to be bitwise resumable.
"""
from __future__ import annotations

import os
import pickle
import re
import tempfile
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

SEP = "//"


def _flatten(tree) -> Dict[str, Any]:
    """npz cannot store ml_dtypes (bfloat16 etc.): store a same-width
    integer view and record the true dtype alongside."""
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        try:
            np.dtype(arr.dtype.name)
            native = arr.dtype.kind in "biufc"
        except TypeError:
            native = False
        if not native:
            arr = arr.view(np.dtype(f"u{arr.dtype.itemsize}"))
        flat[key] = arr
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save_checkpoint(path: str, step: int, params, opt_state,
                    extra: Optional[dict] = None):
    """Atomic save (write temp + rename) — a crash mid-save never
    corrupts the latest checkpoint."""
    os.makedirs(path, exist_ok=True)
    flat = {"params" + SEP + k: v for k, v in _flatten(params).items()}
    flat.update({"opt" + SEP + k: v for k, v in _flatten(opt_state).items()})
    meta = dict(step=step, extra=extra or {})
    fname = os.path.join(path, f"ckpt_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=path, suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, __meta__=np.frombuffer(
            pickle.dumps(meta), dtype=np.uint8), **flat)
    os.replace(tmp, fname)
    return fname


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = [int(m.group(1)) for f in os.listdir(path)
             if (m := re.match(r"ckpt_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def restore_checkpoint(path: str, params_like, opt_like,
                       step: Optional[int] = None,
                       shardings: Optional[Tuple] = None):
    """Restore into the structure of (params_like, opt_like); optionally
    re-shard with (param_shardings, opt_shardings) — elastic restart."""
    step = step if step is not None else latest_step(path)
    if step is None:
        return None
    data = np.load(os.path.join(path, f"ckpt_{step:08d}.npz"))
    meta = pickle.loads(data["__meta__"].tobytes())

    def rebuild(tree_like, prefix, shard_tree=None):
        leaves_p, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
        shards = (jax.tree_util.tree_leaves(shard_tree)
                  if shard_tree is not None else [None] * len(leaves_p))
        out = []
        for (path_, leaf), sh in zip(leaves_p, shards):
            key = prefix + SEP + SEP.join(_path_str(p) for p in path_)
            raw = data[key]
            dt = np.dtype(leaf.dtype)
            if raw.dtype.kind == "u" and dt.kind not in "biu":
                raw = raw.view(dt)          # integer view of an ml_dtype
            arr = jnp.asarray(raw, dtype=leaf.dtype)
            if sh is not None:
                arr = jax.device_put(arr, sh)
            out.append(arr)
        return jax.tree_util.tree_unflatten(treedef.treedef if hasattr(
            treedef, "treedef") else treedef, out)

    p_sh, o_sh = shardings if shardings else (None, None)
    params = rebuild(params_like, "params", p_sh)
    opt = rebuild(opt_like, "opt", o_sh)
    return dict(step=meta["step"], params=params, opt_state=opt,
                extra=meta["extra"])


class FaultTolerantRunner:
    """Train loop with periodic checkpointing and resume.

    ``run(n_steps)`` executes from wherever the latest checkpoint left
    off; crash injection (``crash_at``) raises after that step to let
    tests verify recovery reproduces the uninterrupted run bitwise.
    """

    def __init__(self, ckpt_dir: str, train_step: Callable, params,
                 opt_state, pipeline, ckpt_every: int = 10):
        self.ckpt_dir = ckpt_dir
        self.train_step = train_step
        self.params = params
        self.opt_state = opt_state
        self.pipeline = pipeline
        self.ckpt_every = ckpt_every
        self.step = 0
        self.losses = []

    def try_resume(self) -> bool:
        r = restore_checkpoint(self.ckpt_dir, self.params, self.opt_state)
        if r is None:
            return False
        self.params, self.opt_state = r["params"], r["opt_state"]
        self.step = r["step"]
        if "pipeline" in r["extra"]:
            self.pipeline.load_state_dict(r["extra"]["pipeline"])
        return True

    def run(self, n_steps: int, crash_at: Optional[int] = None):
        while self.step < n_steps:
            batch = jnp.asarray(self.pipeline.next_batch())
            self.params, self.opt_state, loss = self.train_step(
                self.params, self.opt_state, batch)
            self.step += 1
            self.losses.append(float(loss))
            if self.step % self.ckpt_every == 0 or self.step == n_steps:
                save_checkpoint(self.ckpt_dir, self.step, self.params,
                                self.opt_state,
                                extra=dict(pipeline=self.pipeline.state_dict()))
            if crash_at is not None and self.step == crash_at:
                raise RuntimeError(f"injected crash at step {self.step}")
        return self.losses
