from repro.ckpt.checkpoint import (FaultTolerantRunner, latest_step,
                                   restore_checkpoint, save_checkpoint)
