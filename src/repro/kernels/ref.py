"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, softcap=0.0, window=0):
    """q (b,hq,sq,dh); k,v (b,hkv,skv,dh)."""
    b, hq, sq, dh = q.shape
    _, hkv, skv, _ = k.shape
    g = hq // hkv
    qg = q.reshape(b, hkv, g, sq, dh)
    s = jnp.einsum("bngqd,bnkd->bngqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(dh)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    rows = (skv - sq) + jnp.arange(sq)
    cols = jnp.arange(skv)
    ok = jnp.ones((sq, skv), bool)
    if causal:
        ok &= cols[None, :] <= rows[:, None]
    if window > 0:
        ok &= (rows[:, None] - cols[None, :]) < window
    s = jnp.where(ok, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bngqk,bnkd->bngqd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, sq, dh).astype(q.dtype)


def paged_attention_ref(q, k_pool, v_pool, block_table, lengths, *,
                        softcap=0.0):
    """q (b,hkv,g,dh); pools (n,pt,hkv,dh); table (b,np); lengths (b,)."""
    b, hkv, g, dh = q.shape
    n_pool, pt, _, _ = k_pool.shape
    np_ = block_table.shape[1]
    # materialise per-sequence KV: (b, np*pt, hkv, dh)
    k = k_pool[block_table].reshape(b, np_ * pt, hkv, dh)
    v = v_pool[block_table].reshape(b, np_ * pt, hkv, dh)
    s = jnp.einsum("bngd,bknd->bngk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(dh)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    valid = jnp.arange(np_ * pt)[None, :] < lengths[:, None]
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bngk,bknd->bngd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def kv_layer_gather_ref(pool, table, *, layer: int):
    return pool[table, layer]


def kv_layer_scatter_ref(pool, table, stream, *, layer: int):
    return pool.at[table, layer].set(stream)
