"""Prefix-append flash attention — the paper's prefill compute pattern.

In agentic serving ≥95 % of the prompt hits the KV-Cache: the engine
computes attention for a *short append chunk* of queries over a *long
loaded prefix* plus the chunk itself.  This kernel fuses that pattern:

    q:      (batch, heads, s_q, head_dim)      — append chunk
    k, v:   (batch, kv_heads, s_kv, head_dim)  — prefix ‖ append (concat)
    out:    (batch, heads, s_q, head_dim)

with causal masking at global positions (query row i sits at absolute
position ``kv_len - s_q + i``).  TPU mapping: grid is
(batch, kv_heads, q_blocks, kv_blocks) with the kv dimension innermost
("arbitrary" semantics) carrying the online-softmax state in VMEM
scratch; every matmul is shaped (block_q·group, block_k) /
(block_k, head_dim) to land on the MXU with 128-aligned dims.

VMEM budget at the default 128/512 blocking, head_dim 128, group ≤ 8:
q 256 KB + k,v 256 KB + acc(f32) 512 KB + m/l ≈ 1.1 MB — comfortably
double-bufferable in 16 MB VMEM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 512
NEG_INF = -1e30


def tpu_params(*semantics):
    try:
        return pltpu.CompilerParams(dimension_semantics=semantics)
    except Exception:  # older jax spelling
        return pltpu.TPUCompilerParams(dimension_semantics=semantics)


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, block_q: int, block_k: int, causal: bool,
                  q_start: int, n_kv_blocks: int, kv_len: int,
                  softcap: float, window: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                    # (g, block_q, dh)
    k = k_ref[0, 0]                    # (block_k, dh)
    v = v_ref[0, 0]
    g, bq, dh = q.shape

    q2 = q.reshape(g * bq, dh)
    s = jax.lax.dot_general(
        q2, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale     # (g*bq, block_k)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap

    # flattened (g, bq) row index: gi*bq + r -> global q position uses r only
    rows = q_start + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (g * bq, block_k), 0) % bq
    cols = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (g * bq, block_k), 1)
    mask = cols < kv_len
    if causal:
        mask &= cols <= rows
    if window > 0:
        mask &= (rows - cols) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                  # (g*bq,)
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=1)
    pv = jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)              # (g*bq, dh)
    acc_ref[...] = acc_ref[...] * corr[:, None] + pv
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == n_kv_blocks - 1)
    def _fin():
        lse = l_ref[...]
        lse = jnp.where(lse == 0.0, 1.0, lse)
        out = (acc_ref[...] / lse[:, None]).astype(o_ref.dtype)
        o_ref[0, 0] = out.reshape(g, bq, dh)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "softcap", "window", "block_q", "block_k",
                     "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, softcap: float = 0.0,
                    window: int = 0,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False):
    """q (b,hq,sq,dh); k,v (b,hkv,skv,dh) — append queries over
    prefix‖append keys.  Returns (b,hq,sq,dh)."""
    b, hq, sq, dh = q.shape
    _, hkv, skv, _ = k.shape
    g = hq // hkv
    scale = 1.0 / math.sqrt(dh)

    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    pad_q = (-sq) % block_q
    pad_k = (-skv) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    sq_p, skv_p = sq + pad_q, skv + pad_k
    nq, nk = sq_p // block_q, skv_p // block_k
    qg = q.reshape(b, hkv, g, sq_p, dh)

    q_start = skv - sq      # global position of the first query row

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, q_start=q_start, n_kv_blocks=nk, kv_len=skv,
        softcap=softcap, window=window)

    out = pl.pallas_call(
        kernel,
        grid=(b, hkv, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, g, block_q, dh),
                         lambda b_, h, qi, ki: (b_, h, 0, qi, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda b_, h, qi, ki: (b_, h, ki, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda b_, h, qi, ki: (b_, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, block_q, dh),
                               lambda b_, h, qi, ki: (b_, h, 0, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, sq_p, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g * block_q,), jnp.float32),
            pltpu.VMEM((g * block_q,), jnp.float32),
            pltpu.VMEM((g * block_q, dh), jnp.float32),
        ],
        compiler_params=tpu_params("parallel", "parallel", "parallel",
                                   "arbitrary"),
        interpret=interpret,
    )(qg, k, v)
    out = out.reshape(b, hq, sq_p, dh)
    return out[:, :, :sq]
